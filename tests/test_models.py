"""Model-family correctness: forward shapes, decode/train logit consistency,
MoE dispatch semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models import (
    ModelConfig,
    decode_step,
    forward_train,
    init_decode_state,
    init_params,
)

TINY = {
    "dense": ModelConfig(family="dense", num_layers=2, d_model=64,
                         num_heads=4, num_kv_heads=2, d_ff=128,
                         vocab_size=128, dtype="float32"),
    "swa-local-global": ModelConfig(family="dense", num_layers=4, d_model=64,
                                    num_heads=4, num_kv_heads=2, d_ff=128,
                                    vocab_size=128, sliding_window=4,
                                    global_every=2, dtype="float32"),
    "moe": ModelConfig(family="moe", num_layers=2, d_model=64, num_heads=4,
                       num_kv_heads=4, d_ff=64, vocab_size=128,
                       num_experts=8, num_experts_per_tok=2,
                       moe_capacity_factor=4.0, dtype="float32"),
    "ssm": ModelConfig(family="ssm", num_layers=2, d_model=64, num_heads=4,
                       num_kv_heads=4, d_ff=128, vocab_size=128,
                       ssm_head_dim=16, dtype="float32"),
    "hybrid": ModelConfig(family="hybrid", num_layers=4, d_model=64,
                          num_heads=4, num_kv_heads=4, d_ff=128,
                          vocab_size=128, ssm_head_dim=16, ssm_state=8,
                          shared_attn_every=2, dtype="float32"),
    "encdec": ModelConfig(family="encdec", num_layers=2, encoder_layers=2,
                          d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
                          vocab_size=128, encoder_seq=10, dtype="float32"),
    "vlm": ModelConfig(family="vlm", num_layers=2, d_model=64, num_heads=4,
                       num_kv_heads=2, d_ff=128, vocab_size=128,
                       num_prefix_embeddings=4, dtype="float32"),
}


def _batch(cfg, B, S, key):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["prefix_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 1),
            (B, cfg.num_prefix_embeddings, cfg.d_model))
    if cfg.family == "encdec":
        batch["encoder_frames"] = jax.random.normal(
            jax.random.fold_in(key, 2), (B, cfg.encoder_seq, cfg.d_model))
    return batch


@pytest.mark.parametrize("name", list(TINY))
def test_forward_shapes_and_finite(name):
    cfg = TINY[name]
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    B, S = 2, 16
    batch = _batch(cfg, B, S, jax.random.fold_in(key, 7))
    logits, aux = forward_train(params, batch, cfg)
    S_out = S + (cfg.num_prefix_embeddings if cfg.family == "vlm" else 0)
    assert logits.shape == (B, S_out, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    # remat path must be numerically identical
    logits_r, _ = forward_train(params, batch, cfg, remat=True)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(logits_r),
                               atol=1e-5)


@pytest.mark.parametrize("name", list(TINY))
def test_decode_matches_forward(name):
    """The serving invariant: step-by-step decode reproduces training
    logits at every position (exact cache semantics for every family)."""
    cfg = TINY[name]
    key = jax.random.PRNGKey(3)
    params = init_params(cfg, key)
    B, S = 2, 12
    batch = _batch(cfg, B, S, jax.random.fold_in(key, 9))
    if cfg.family == "vlm":
        # decode starts from an empty cache (no image prefilled), so compare
        # against a forward with an empty prefix — same text-only semantics.
        batch = dict(batch,
                     prefix_embeds=jnp.zeros((B, 0, cfg.d_model)))
    logits, _ = forward_train(params, batch, cfg)
    st_ = init_decode_state(params, cfg, B, max_len=S,
                            encoder_frames=batch.get("encoder_frames"))
    errs = []
    toks = batch["tokens"]
    for t in range(S):
        lg, st_ = decode_step(params, st_, toks[:, t], cfg)
        errs.append(float(jnp.abs(lg - logits[:, t]).max()))
    assert max(errs) < 3e-4, errs


def test_moe_matches_dense_per_token():
    """With capacity ≥ S·k nothing drops, and the MoE layer must equal the
    explicit per-token top-k mixture."""
    from repro.models.moe import moe_forward, moe_init

    cfg = TINY["moe"]
    key = jax.random.PRNGKey(1)
    p = moe_init(key, cfg)
    B, S, d = 2, 8, cfg.d_model
    x = jax.random.normal(jax.random.fold_in(key, 2), (B, S, d))
    y, aux = moe_forward(p, x, cfg)
    assert int(aux["dropped"]) == 0

    # explicit reference
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    logits = x @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)
    top_p = top_p / top_p.sum(-1, keepdims=True)
    want = jnp.zeros_like(x)
    for b in range(B):
        for s in range(S):
            acc = jnp.zeros((d,))
            for j in range(k):
                e = int(top_e[b, s, j])
                h = jax.nn.silu(x[b, s] @ p["w_gate"][e]) * (x[b, s] @ p["w_up"][e])
                acc = acc + top_p[b, s, j] * (h @ p["w_down"][e])
            want = want.at[b, s].set(acc)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), atol=1e-4)


def test_moe_capacity_drops_tokens():
    from repro.models.moe import moe_forward, moe_init
    import dataclasses

    cfg = dataclasses.replace(TINY["moe"], moe_capacity_factor=0.1)
    key = jax.random.PRNGKey(5)
    p = moe_init(key, cfg)
    x = jax.random.normal(key, (2, 64, cfg.d_model))
    _, aux = moe_forward(p, x, cfg)
    assert int(aux["dropped"]) > 0


@given(S=st.integers(2, 24))
@settings(max_examples=8)
def test_rwkv_state_carry_equals_full_run(S):
    """Splitting a sequence at any point and carrying state is exact."""
    from repro.models.rwkv6 import rwkv_time_mix, rwkv_time_mix_init

    cfg = TINY["ssm"]
    key = jax.random.PRNGKey(2)
    p = rwkv_time_mix_init(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (1, S, cfg.d_model))
    full, _ = rwkv_time_mix(p, x, cfg)
    cut = S // 2
    if cut == 0:
        return
    a, state = rwkv_time_mix(p, x[:, :cut], cfg)
    b, _ = rwkv_time_mix(p, x[:, cut:], cfg, state=state)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([a, b], axis=1)), np.asarray(full),
        atol=1e-4)


@given(S=st.integers(2, 24))
@settings(max_examples=8)
def test_mamba_state_carry_equals_full_run(S):
    from repro.models.mamba2 import mamba2_forward, mamba2_init

    cfg = TINY["hybrid"]
    key = jax.random.PRNGKey(4)
    p = mamba2_init(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (1, S, cfg.d_model))
    full, _ = mamba2_forward(p, x, cfg)
    cut = S // 2
    if cut == 0:
        return
    a, state = mamba2_forward(p, x[:, :cut], cfg)
    b, _ = mamba2_forward(p, x[:, cut:], cfg, state=state)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([a, b], axis=1)), np.asarray(full),
        atol=2e-4)


def test_chunked_scan_matches_plain():
    from repro.models.scan_utils import chunked_scan

    def step(c, x):
        c = 0.9 * c + x
        return c, c * 2.0

    xs = jnp.arange(512, dtype=jnp.float32)
    c1, y1 = jax.lax.scan(step, 0.0, xs)
    c2, y2 = chunked_scan(step, 0.0, xs, chunk=64)
    np.testing.assert_allclose(float(c1), float(c2), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-6)
    # gradient path too
    g1 = jax.grad(lambda c0: jax.lax.scan(step, c0, xs)[1].sum())(1.0)
    g2 = jax.grad(lambda c0: chunked_scan(step, c0, xs, chunk=64)[1].sum())(1.0)
    np.testing.assert_allclose(float(g1), float(g2), rtol=1e-5)


def test_sliding_window_cache_is_ring_sized():
    cfg = TINY["swa-local-global"]
    from repro.models.attention import init_kv_cache

    local = init_kv_cache(cfg, batch=2, max_len=100, is_global=False)
    glob = init_kv_cache(cfg, batch=2, max_len=100, is_global=True)
    assert local["k"].shape[2] == cfg.sliding_window
    assert glob["k"].shape[2] == 100


def test_int8_kv_cache_decode_close_to_fp():
    """Quantized KV serving: per-position symmetric int8 stays within
    quantization noise of the fp cache (production memory lever)."""
    import dataclasses

    cfg = TINY["dense"]
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    key = jax.random.PRNGKey(11)
    params = init_params(cfg, key)
    B, S = 2, 12
    toks = jax.random.randint(jax.random.fold_in(key, 1), (B, S),
                              0, cfg.vocab_size)
    logits, _ = forward_train(params, {"tokens": toks}, cfg)
    st8 = init_decode_state(params, cfg8, B, max_len=S)
    assert st8.layers[0]["k"].dtype == jnp.int8
    errs = []
    for t in range(S):
        lg, st8 = decode_step(params, st8, toks[:, t], cfg8)
        errs.append(float(jnp.abs(lg - logits[:, t]).max()))
    assert max(errs) < 0.15, errs
