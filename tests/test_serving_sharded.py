"""Sharded serving runtime: the QueryScheduler answering queries against
per-shard slab blocks (no full-slab reassembly), deadline-aware admission,
and the plan/kernel machinery underneath.

Single-device tests exercise the runtime's host-loop dispatch of the same
per-shard wave program the mesh path runs (the mesh `shard_map` twin lives
in tests/test_multidevice.py); all three dispatch paths draw from the same
key stream, so gathered and sharded answers must agree *byte-for-byte* on
the same slab — and statistically (chi-square + TV) across independent
seeds, which is the acceptance claim that survives future RNG-plumbing
changes.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.graph import chung_lu_powerlaw, uniform_random
from repro.kernels import ops
from repro.query import (QueryRequest, QueryScheduler, ShardedWalkIndex,
                         WalkIndexConfig, build_walk_index, load_walk_index,
                         plan_query, save_walk_index, save_walk_index_shard,
                         shard_walk_index)


def _graph_and_index(n=512, R=8, L=3, seed=2):
    g = chung_lu_powerlaw(n=n, avg_out_deg=8, seed=seed)
    idx = build_walk_index(g, WalkIndexConfig(
        segments_per_vertex=R, segment_len=L, num_shards=2))
    return g, idx


def _run(g, index, reqs, seed=11, **kw):
    sched = QueryScheduler(g, index, max_walks=1024, max_queries=4,
                           max_steps=24, seed=seed, **kw)
    for r in reqs:
        assert sched.submit(r).admitted
    return sched, sorted(sched.run(), key=lambda r: r.rid)


def _reqs():
    return [QueryRequest(rid=0, kind="topk", k=10, epsilon=0.4),
            QueryRequest(rid=1, kind="ppr", source=7, k=10, epsilon=0.4),
            QueryRequest(rid=2, kind="topk", k=5, num_walks=300)]


# --- sharded-slab serving == gathered serving --------------------------------


def test_sharded_loop_wave_matches_gathered_exactly():
    """Same seed + same slab ⇒ the host-loop sharded wave and the gathered
    wave are the *same program* (shared key stream): identical answers."""
    g, idx = _graph_and_index()
    sh = shard_walk_index(idx, 4)
    sched_g, res_g = _run(g, idx, _reqs())
    sched_s, res_s = _run(g, sh, _reqs())
    assert sched_s.runtime is not None and not sched_s.runtime.is_mesh
    assert [r.rid for r in res_s] == [0, 1, 2]
    for a, b in zip(res_g, res_s):
        assert (a.vertices == b.vertices).all(), a.rid
        assert np.allclose(a.scores, b.scores), a.rid
        assert a.num_walks == b.num_walks and a.waves == b.waves


def test_sharded_local_stitch_kernel_path_matches_xla():
    """impl="ref"/"pallas" route the sharded wave's gather through the
    local-index stitch kernel — answers must match the masked-take path."""
    g, idx = _graph_and_index(n=256, R=6, L=2, seed=3)
    sh = shard_walk_index(idx, 2)
    out = {}
    for impl in ("xla", "ref", "pallas"):
        sched = QueryScheduler(g, sh, max_walks=512, max_queries=2,
                               max_steps=10, seed=5, impl=impl)
        sched.submit(QueryRequest(rid=0, kind="topk", k=5, num_walks=400,
                                  epsilon=0.5))
        out[impl] = sched.run()[0]
    for impl in ("ref", "pallas"):
        assert (out[impl].vertices == out["xla"].vertices).all(), impl
        assert np.allclose(out[impl].scores, out["xla"].scores), impl


def test_sharded_vs_gathered_statistical_equivalence():
    """Across independent seeds the two paths sample the same distribution:
    chi-square + TV over pooled per-vertex stop counts (top-k and PPR)."""
    g, idx = _graph_and_index(n=128, R=8, L=2, seed=4)
    sh = shard_walk_index(idx, 4)
    counts = {"gathered": np.zeros((2, g.n)), "sharded": np.zeros((2, g.n))}
    walks = 2000
    for trial in range(6):
        for name, index in (("gathered", idx), ("sharded", sh)):
            # decouple the seeds so this is a genuine two-sample test
            seed = 100 + trial + (1000 if name == "sharded" else 0)
            sched = QueryScheduler(g, index, max_walks=2048, max_queries=2,
                                   max_steps=12, seed=seed)
            # k = n so the results carry the full stop-count histogram
            sched.submit(QueryRequest(rid=0, kind="topk", k=g.n,
                                      num_walks=walks))
            sched.submit(QueryRequest(rid=1, kind="ppr", source=3, k=g.n,
                                      num_walks=walks))
            for r in sched.run():
                est = np.zeros(g.n)
                est[r.vertices] = r.scores * r.num_walks
                counts[name][0 if r.kind == "topk" else 1] += est
    for row, kind in ((0, "topk"), (1, "ppr")):
        a, b = counts["gathered"][row], counts["sharded"][row]
        support = (a + b) > 0
        x2 = float((((a - b) ** 2) / np.maximum(a + b, 1))[support].sum())
        df = max(int(support.sum()) - 1, 1)
        assert x2 < df + 4.0 * np.sqrt(2 * df), (kind, x2, df)
        tv = 0.5 * np.abs(a / a.sum() - b / b.sum()).sum()
        # sample-size-aware bound: for two independent multinomial samples
        # of size A ≈ B over these cells, E[TV] ≈ Σ√(pᵢ(1−pᵢ)) / √(πA) —
        # a fixed 0.05 sits right at that noise floor and flips on the
        # realized seeds, not on any distributional difference.
        p = (a + b) / (a + b).sum()
        e_tv = float(np.sqrt(p * (1 - p)).sum() / np.sqrt(np.pi * a.sum()))
        assert tv < 1.5 * e_tv, (kind, tv, e_tv)


def test_sharded_index_checkpoint_roundtrip_no_reassembly(tmp_path):
    """Per-shard persistence → load_walk_index(reassemble=False) hands the
    scheduler per-shard blocks directly; answers match the gathered path
    over the monolithic checkpoint of the same slab."""
    g, idx = _graph_and_index(n=200, R=5, L=2, seed=6)
    sh = shard_walk_index(idx, 4)
    d = str(tmp_path / "walk_index")
    for s in range(4):
        save_walk_index_shard(d, s, 4, g.n, sh.blocks[s], sh.segment_len,
                              sh.seed)
    loaded = load_walk_index(d, reassemble=False)
    assert isinstance(loaded, ShardedWalkIndex)
    assert loaded.num_shards == 4 and loaded.n == g.n
    assert (loaded.blocks == sh.blocks).all()
    # the reassembling reader still agrees with the dense slab
    dense = load_walk_index(d)
    assert (np.asarray(dense.endpoints) == np.asarray(idx.endpoints)).all()
    # a monolithic checkpoint read sharded comes back as one shard
    d2 = str(tmp_path / "mono")
    save_walk_index(d2, idx)
    mono = load_walk_index(d2, reassemble=False)
    assert isinstance(mono, ShardedWalkIndex) and mono.num_shards == 1
    _, res_s = _run(g, loaded, _reqs())
    _, res_g = _run(g, dense, _reqs())
    for a, b in zip(res_g, res_s):
        assert (a.vertices == b.vertices).all() and np.allclose(
            a.scores, b.scores)


# --- local-index stitch kernel ----------------------------------------------


@pytest.mark.parametrize("W,n,R,S", [(1000, 300, 8, 4), (128, 64, 3, 2)])
def test_stitch_local_kernel_matches_ref_and_composes(W, n, R, S):
    rng = np.random.default_rng(W + n)
    pos = jnp.asarray(rng.integers(0, n, W), jnp.int32)
    stop = jnp.asarray(rng.integers(0, 2, W), jnp.int32)
    bits = jnp.asarray(rng.integers(0, 1 << 30, W), jnp.int32)
    endpoints = jnp.asarray(rng.integers(0, n, (n, R)), jnp.int32)
    ng, cg = ops.stitch_step(pos, stop, bits, endpoints, n, impl="ref")
    sz = -(-n // S)
    ep = np.zeros((S * sz, R), np.int32)
    ep[:n] = np.asarray(endpoints)
    acc_n = jnp.zeros_like(pos)
    acc_c = []
    for s in range(S):
        block = jnp.asarray(ep[s * sz:(s + 1) * sz])
        np_, cp = ops.stitch_step_local(pos, stop, bits, block, s * sz,
                                        impl="pallas")
        nr, cr = ops.stitch_step_local(pos, stop, bits, block, s * sz,
                                       impl="ref")
        assert (np.asarray(np_) == np.asarray(nr)).all(), s
        assert (np.asarray(cp) == np.asarray(cr)).all(), s
        acc_n = acc_n + np_
        acc_c.append(np.asarray(cp))
    # per-shard outputs sum to the global stitch (each walk has one owner)
    assert (np.asarray(acc_n) == np.asarray(ng)).all()
    assert (np.concatenate(acc_c)[:n] == np.asarray(cg)).all()
    assert sum(int(c.sum()) for c in acc_c) == int(stop.sum())


def test_device_rng_interpret_gate():
    """rng="device" (pltpu.prng_random_bits) lowers only on TPU — interpret
    mode must refuse it loudly, keeping the seeded-bits determinism path."""
    g = uniform_random(64, avg_out_deg=4, seed=0)
    pos = jnp.zeros(16, jnp.int32)
    with pytest.raises(ValueError, match="interpret"):
        ops.frog_step(pos, jnp.zeros_like(pos), None, g.row_ptr, g.col_idx,
                      g.out_deg, g.n, impl="pallas", rng="device")
    endpoints = jnp.zeros((64, 4), jnp.int32)
    with pytest.raises(ValueError, match="interpret"):
        ops.stitch_step(pos, jnp.zeros_like(pos), None, endpoints, 64,
                        rng="device")
    with pytest.raises(ValueError, match="interpret"):
        ops.stitch_step_local(pos, jnp.zeros_like(pos), None,
                              endpoints[:32], 0, rng="device")
    with pytest.raises(ValueError, match="unknown rng"):
        ops.stitch_step(pos, jnp.zeros_like(pos), pos, endpoints, 64,
                        rng="nonsense")


# --- plan clamp via the index's segment budget -------------------------------


def test_plan_query_clamps_to_index_segment_budget():
    free = plan_query(10, 0.2, max_steps=64)
    assert free.num_steps > 9          # the clamp below must actually bind
    capped = plan_query(10, 0.2, max_steps=64, segments_per_vertex=4,
                        segment_len=2)
    assert capped.num_steps == 4 * 2 + 1               # ⌊t/L⌋ ≤ R
    assert capped.num_rounds(2) <= 4
    assert capped.epsilon_bound > capped.epsilon       # recorded, not silent
    # a roomy index leaves the plan untouched
    roomy = plan_query(10, 0.2, max_steps=64, segments_per_vertex=64,
                       segment_len=2)
    assert roomy.num_steps == free.num_steps
    assert roomy.epsilon_bound == pytest.approx(free.epsilon_bound)
    with pytest.raises(ValueError, match="pair"):
        plan_query(10, 0.2, segments_per_vertex=4)


def test_scheduler_plans_never_exceed_index_budget():
    """An undersized index (R < t/L) must produce clamped plans with a
    recorded epsilon_bound — no reuse-bias warning path at serve time."""
    g, _ = _graph_and_index(n=128, R=2, L=2, seed=8)
    idx = build_walk_index(g, WalkIndexConfig(
        segments_per_vertex=2, segment_len=2, num_shards=2))
    sched = QueryScheduler(g, idx, max_walks=256, max_queries=2, max_steps=32)
    d = sched.submit(QueryRequest(rid=0, kind="topk", k=10, epsilon=0.2,
                                  num_walks=200))
    assert d.plan.num_steps <= 2 * 2 + 1
    res = sched.run()[0]
    assert res.num_steps == d.plan.num_steps
    assert res.epsilon_bound > 0.2


# --- deadline-aware admission ------------------------------------------------


def _admission_sched(g, idx, wave_time=1.0, **kw):
    return QueryScheduler(g, idx, max_walks=512, max_queries=4, max_steps=12,
                          wave_time_estimate_s=wave_time, **kw)


def test_admission_rejects_infeasible_slo():
    g, idx = _graph_and_index(n=128, R=6, L=2, seed=9)
    sched = _admission_sched(g, idx, wave_time=1.0)
    # 2000 walks need ⌈2000/512⌉ = 4 waves ≈ 4 s — a 2 s SLO cannot fit
    d = sched.submit(QueryRequest(rid=0, kind="topk", k=5, num_walks=2000,
                                  slo_s=2.0))
    assert not d.admitted and "waves" in d.reason
    assert sched.rejected == [d] and not sched.queue
    # an SLO shorter than a single wave is rejected outright
    d2 = sched.submit(QueryRequest(rid=1, kind="topk", k=5, num_walks=100,
                                   slo_s=0.5))
    assert not d2.admitted and "shorter than one wave" in d2.reason
    # a feasible SLO is admitted unchanged
    d3 = sched.submit(QueryRequest(rid=2, kind="topk", k=5, num_walks=1000,
                                   slo_s=10.0))
    assert d3.admitted and not d3.downgraded and d3.num_walks == 1000
    with pytest.raises(ValueError, match="slo_s"):
        sched.submit(QueryRequest(rid=3, slo_s=-1.0))


def test_admission_downgrades_to_fit_budget():
    g, idx = _graph_and_index(n=128, R=6, L=2, seed=9)
    sched = _admission_sched(g, idx, wave_time=1.0)
    d = sched.submit(QueryRequest(rid=0, kind="topk", k=5, epsilon=0.2,
                                  slo_s=2.0, allow_downgrade=True))
    # ε = 0.2 wants 4k/(δε²) = 5000 walks ≫ 2 waves × 512 slots
    assert d.admitted and d.downgraded
    assert d.num_walks == 2 * 512
    assert d.plan.epsilon_bound > 0.2      # the weakened guarantee is recorded
    res = sched.run()[0]
    assert res.num_walks == 1024 and res.downgraded
    assert res.epsilon_bound == d.plan.epsilon_bound
    assert res.met_slo is not None


def test_admission_without_estimate_is_optimistic():
    g, idx = _graph_and_index(n=128, R=6, L=2, seed=9)
    sched = QueryScheduler(g, idx, max_walks=512, max_queries=2, max_steps=12)
    assert sched._wave_time is None
    d = sched.submit(QueryRequest(rid=0, kind="topk", k=5, num_walks=600,
                                  slo_s=1e-9))
    assert d.admitted                       # nothing to judge against yet
    res = sched.run()[0]
    assert res.met_slo is False             # …but the miss is reported
    assert sched._wave_time is not None     # and the next submit can judge


def test_edf_ordering_within_wave():
    """Earliest deadline first: slot claiming and walk-slot allocation both
    order by deadline, so a tight-SLO query overtakes earlier FIFO arrivals."""
    g, idx = _graph_and_index(n=128, R=6, L=2, seed=9)
    sched = _admission_sched(g, idx, wave_time=1.0, seed=3)
    sched.submit(QueryRequest(rid=0, kind="topk", k=5, num_walks=400))
    sched.submit(QueryRequest(rid=1, kind="topk", k=5, num_walks=400,
                              slo_s=100.0))
    sched.submit(QueryRequest(rid=2, kind="topk", k=5, num_walks=100,
                              slo_s=50.0))
    sched._admit()
    # slots are claimed in EDF order: rid=2 (50s) < rid=1 (100s) < rid=0 (∞)
    assert [sched.active[s].req.rid for s in sorted(sched.active)] == [2, 1, 0]
    order = sched._edf_order()
    assert [sched.active[s].req.rid for s in order] == [2, 1, 0]
    alloc = sched._allocate()
    # fair shares first (170 each, capped by remaining), then leftovers
    # EDF-greedy: rid=2 takes its 100, the 512-slot residue tops up rid=1
    # before rid=0.
    by_rid = {sched.active[s].req.rid: w for s, w in alloc.items()}
    assert by_rid[2] == 100
    assert by_rid[1] > by_rid[0]
    assert sum(by_rid.values()) == 512
    res = sched.run()
    assert sorted(r.rid for r in res) == [0, 1, 2]


def test_edf_claims_scarce_slots_first():
    g, idx = _graph_and_index(n=128, R=6, L=2, seed=9)
    sched = QueryScheduler(g, idx, max_walks=256, max_queries=1, max_steps=12,
                           wave_time_estimate_s=0.01)
    sched.submit(QueryRequest(rid=0, kind="topk", k=5, num_walks=200))
    sched.submit(QueryRequest(rid=1, kind="topk", k=5, num_walks=200,
                              slo_s=1000.0))
    sched._admit()
    # one slot: the deadline-carrying query gets it despite arriving second
    assert [a.req.rid for a in sched.active.values()] == [1]
    res = sched.run()
    assert sorted(r.rid for r in res) == [0, 1]
