"""Serving, data pipeline, checkpointing, engine helpers, HLO analyzer."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data import SyntheticTokens
from repro.checkpoint import (
    Checkpointer,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.models import ModelConfig, forward_train, init_params
from repro.serving import BatchScheduler, Request, prefill, sample_token, serve_step

CFG = ModelConfig(family="dense", num_layers=2, d_model=64, num_heads=4,
                  num_kv_heads=2, d_ff=128, vocab_size=64, dtype="float32")


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def test_prefill_matches_forward():
    key = jax.random.PRNGKey(0)
    params = init_params(CFG, key)
    toks = jax.random.randint(key, (2, 7), 0, CFG.vocab_size)
    logits, st_ = prefill(params, CFG, toks, max_len=16)
    full, _ = forward_train(params, {"tokens": toks}, CFG)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full[:, -1]),
                               atol=2e-4)
    assert int(st_.pos) == 7


def test_serve_step_greedy_deterministic():
    key = jax.random.PRNGKey(1)
    params = init_params(CFG, key)
    toks = jax.random.randint(key, (2, 5), 0, CFG.vocab_size)
    _, st1 = prefill(params, CFG, toks, max_len=16)
    _, st2 = prefill(params, CFG, toks, max_len=16)
    t1, _ = serve_step(params, st1, toks[:, -1], CFG)
    t2, _ = serve_step(params, st2, toks[:, -1], CFG)
    assert (np.asarray(t1) == np.asarray(t2)).all()


@given(temp=st.floats(0.2, 3.0), k=st.integers(0, 8))
@settings(max_examples=10)
def test_sample_token_valid_range(temp, k):
    logits = jnp.asarray(np.random.default_rng(0).standard_normal((3, 16)),
                         dtype=jnp.float32)
    tok = sample_token(logits, jax.random.PRNGKey(0), temperature=temp,
                       top_k=k)
    assert tok.shape == (3,)
    assert (np.asarray(tok) >= 0).all() and (np.asarray(tok) < 16).all()


def test_sample_token_topk_restricts():
    logits = jnp.asarray([[10.0, 5.0, 0.0, -5.0]])
    for i in range(20):
        tok = sample_token(logits, jax.random.PRNGKey(i), temperature=1.0,
                           top_k=2)
        assert int(tok[0]) in (0, 1)


def test_scheduler_completes_all_requests():
    params = init_params(CFG, jax.random.PRNGKey(2))
    sched = BatchScheduler(params, CFG, max_batch=2, max_len=64)
    for i in range(5):
        sched.submit(Request(rid=i, prompt=[2, 3, 4 + i], max_new_tokens=6))
    done = sched.run()
    assert len(done) == 5
    assert all(r.done and 1 <= len(r.output) <= 6 for r in done)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

@given(step=st.integers(0, 1000))
@settings(max_examples=10)
def test_data_deterministic_resumable(step):
    ds = SyntheticTokens(vocab_size=64, seq_len=32, global_batch=4, seed=1)
    a = ds.batch(step)
    b = ds.batch(step)                      # "after restart"
    assert (np.asarray(a["tokens"]) == np.asarray(b["tokens"])).all()
    assert (np.asarray(a["labels"]) == np.asarray(b["labels"])).all()
    # labels are next-token shifted
    nxt = ds.batch(step)
    assert a["tokens"].shape == (4, 32)


def test_data_differs_across_steps_and_hosts():
    ds0 = SyntheticTokens(vocab_size=64, seq_len=32, global_batch=4, seed=1)
    ds1 = SyntheticTokens(vocab_size=64, seq_len=32, global_batch=8, seed=1,
                          process_index=1, process_count=2)
    assert not (np.asarray(ds0.batch(0)["tokens"])
                == np.asarray(ds0.batch(1)["tokens"])).all()
    assert ds1.local_batch == 4


def test_data_is_learnable():
    """The stream has structure (n-gram pool) — unigram entropy must be well
    below uniform."""
    ds = SyntheticTokens(vocab_size=512, seq_len=64, global_batch=8, seed=0)
    toks = np.asarray(ds.batch(0)["tokens"]).ravel()
    assert len(np.unique(toks)) < 512


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def _tree():
    return {"a": jnp.arange(6).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16),
                  "d": jnp.zeros((), jnp.int32)}}


def test_checkpoint_roundtrip_dtypes():
    t = _tree()
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 7, t)
        assert latest_step(d) == 7
        r = restore_checkpoint(d, 7, t)
        for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
            assert a.dtype == b.dtype
            assert (np.asarray(a) == np.asarray(b)).all()


def test_checkpoint_atomicity_keeps_old_on_gc():
    t = _tree()
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, keep=2)
        for s in (1, 2, 3, 4):
            ck.save_async(s, t)
        ck.wait()
        steps = sorted(int(p.split("_")[1]) for p in os.listdir(d)
                       if p.startswith("step_"))
        assert steps == [3, 4]


def test_checkpoint_no_tmp_left_behind():
    t = _tree()
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, t)
        assert not any(p.endswith(".tmp") for p in os.listdir(d))


# ---------------------------------------------------------------------------
# engine pack helper (property)
# ---------------------------------------------------------------------------

@given(
    B=st.integers(4, 128),
    S=st.integers(2, 8),
    cap=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 50),
)
@settings(max_examples=20)
def test_pack_by_shard_conserves(B, S, cap, seed):
    from repro.engine.gas import _pack_by_shard

    rng = np.random.default_rng(seed)
    shard_size = 10
    dest = rng.integers(-1, S * shard_size, size=B).astype(np.int32)
    buf, n_sent, ovf = _pack_by_shard(jnp.asarray(dest), S, shard_size, cap)
    valid = int((dest >= 0).sum())
    assert int(n_sent) + int(ovf) == valid
    assert int((np.asarray(buf) >= 0).sum()) == int(n_sent)
    # every placed frog's destination shard matches its row
    bufn = np.asarray(buf)
    for s in range(S):
        placed = bufn[s][bufn[s] >= 0]
        assert ((placed // shard_size) == s).all()


# ---------------------------------------------------------------------------
# HLO analyzer
# ---------------------------------------------------------------------------

def test_hlo_analyzer_counts_scan_trip_counts():
    """XLA's own cost_analysis drops while trip counts; ours must not."""
    from repro.launch.hlo_analysis import analyze_hlo

    d, L, B = 64, 10, 8

    def scanned(x, ws):
        y, _ = jax.lax.scan(lambda c, w: (jnp.tanh(c @ w), None), x, ws)
        return y

    lowered = jax.jit(scanned).lower(
        jax.ShapeDtypeStruct((B, d), jnp.float32),
        jax.ShapeDtypeStruct((L, d, d), jnp.float32))
    cost = analyze_hlo(lowered.compile().as_text())
    expected = L * 2 * B * d * d
    assert abs(cost.flops - expected) / expected < 0.01, cost.flops


def test_hlo_analyzer_shape_parsing():
    from repro.launch.hlo_analysis import _shape_bytes

    assert _shape_bytes("f32[8,128]{1,0}") == 8 * 128 * 4
    assert _shape_bytes("(f32[4], bf16[2,2])") == 16 + 8
    assert _shape_bytes("(s32[], f32[8,32]{1,0}, /*index=5*/bf16[16,256]) ") \
        == 4 + 8 * 32 * 4 + 16 * 256 * 2
