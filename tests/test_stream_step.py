"""HBM-streaming walker superstep: equivalence, dispatch, sharded builds.

The streamed kernel must be *byte-for-byte* the resident kernel / jnp
oracle under every shape misalignment (n, N not multiples of the block
sizes), every implementation must share one dangling-vertex convention,
and the mesh-sharded index build must round-trip through the per-shard
checkpoint layout.
"""
import os
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from conftest import run_with_devices
from repro.core import FrogWildConfig, frogwild
from repro.graph import chung_lu_powerlaw, uniform_random
from repro.graph.csr import CSRGraph, uniform_successor
from repro.kernels import ops, ref
from repro.kernels.frog_step_stream import block_csr
from repro.query import (WalkIndexConfig, build_walk_index,
                         build_walk_index_sharded, load_walk_index)


def _random_step_inputs(n, N, seed):
    rng = np.random.default_rng(seed)
    pos = jnp.asarray(rng.integers(0, n, N), jnp.int32)
    die = jnp.asarray(rng.random(N) < 0.2, jnp.int32)
    bits = jnp.asarray(rng.integers(0, 1 << 30, N), jnp.int32)
    return pos, die, bits


# ---------------------------------------------------------------------------
# streamed kernel ≡ oracle
# ---------------------------------------------------------------------------

@given(
    n=st.integers(16, 900),
    N=st.integers(8, 4000),
    seed=st.integers(0, 50),
)
@settings(max_examples=10)
def test_frog_step_stream_matches_ref(n, N, seed):
    g = uniform_random(n, avg_out_deg=5, seed=seed)
    pos, die, bits = _random_step_inputs(n, N, seed)
    nxt_s, cnt_s = ops.frog_step(
        pos, die, bits, g.row_ptr, g.col_idx, g.out_deg, g.n, impl="stream",
        vertex_block=128, frog_block=256)
    nxt_r, cnt_r = ops.frog_step(
        pos, die, bits, g.row_ptr, g.col_idx, g.out_deg, g.n, impl="ref")
    assert (np.asarray(nxt_s) == np.asarray(nxt_r)).all()
    assert (np.asarray(cnt_s) == np.asarray(cnt_r)).all()


@pytest.mark.parametrize("n,N,bv,fb", [
    (513, 1025, 100, 96),        # nothing divides anything
    (97, 53, 16, 8),             # N < fb·num_vb, tiny blocks
    (300, 2000, 512, 1024),      # n < vertex_block (block shrinks to n_pad)
    (769, 111, 64, 1024),        # N < frog_block
])
def test_frog_step_stream_nondivisible_blocks(n, N, bv, fb):
    """Byte-for-byte equivalence when (n, N) are not block-size multiples."""
    g = uniform_random(n, avg_out_deg=6, seed=n + N)
    pos, die, bits = _random_step_inputs(n, N, n * 7 + N)
    got = ops.frog_step(pos, die, bits, g.row_ptr, g.col_idx, g.out_deg,
                        g.n, impl="stream", vertex_block=bv, frog_block=fb)
    want = ops.frog_step(pos, die, bits, g.row_ptr, g.col_idx, g.out_deg,
                         g.n, impl="ref")
    for a, b in zip(got, want):
        assert (np.asarray(a) == np.asarray(b)).all()


def test_frog_step_stream_skewed_hub():
    """All frogs on one vertex — one block soaks every frog block."""
    g = uniform_random(200, avg_out_deg=3, seed=7)
    N = 500
    pos = jnp.full((N,), 123, jnp.int32)
    _, die, bits = _random_step_inputs(200, N, 0)
    got = ops.frog_step(pos, die, bits, g.row_ptr, g.col_idx, g.out_deg,
                        g.n, impl="stream", vertex_block=32, frog_block=64)
    want = ops.frog_step(pos, die, bits, g.row_ptr, g.col_idx, g.out_deg,
                         g.n, impl="ref")
    for a, b in zip(got, want):
        assert (np.asarray(a) == np.asarray(b)).all()


def test_frog_step_auto_dispatch():
    """auto = resident while the graph block fits VMEM, streamed beyond.

    Both sides of the switch must agree with the oracle — here the budget
    is squeezed so this graph's CSR (``resident_graph_bytes``) exceeds it,
    i.e. the regime where the resident kernel could not run on real TPU.
    """
    g = chung_lu_powerlaw(n=700, avg_out_deg=8, seed=2)
    pos, die, bits = _random_step_inputs(g.n, 2000, 9)
    want = ops.frog_step(pos, die, bits, g.row_ptr, g.col_idx, g.out_deg,
                         g.n, impl="ref")
    assert ops.resident_graph_bytes(g.n, g.nnz) > 1024
    for kw in (dict(vmem_budget=1024),          # → stream
               dict(vmem_budget=1 << 30)):      # → resident pallas
        got = ops.frog_step(pos, die, bits, g.row_ptr, g.col_idx, g.out_deg,
                            g.n, impl="auto", vertex_block=128, **kw)
        for a, b in zip(got, want):
            assert (np.asarray(a) == np.asarray(b)).all()


def test_frogwild_run_stream_equals_ref():
    """Whole-run equality: the fused scan draws identical bits per impl."""
    g = chung_lu_powerlaw(n=900, avg_out_deg=8, seed=3)
    runs = {}
    for impl in ("stream", "ref", "pallas"):
        cfg = FrogWildConfig(num_frogs=3000, num_steps=4, step_impl=impl)
        runs[impl] = np.asarray(frogwild(g, cfg, seed=11).counts)
    assert (runs["stream"] == runs["ref"]).all()
    assert (runs["pallas"] == runs["ref"]).all()
    assert int(runs["stream"].sum()) == 3000


def test_block_csr_layout():
    g = uniform_random(130, avg_out_deg=4, seed=5)
    b = block_csr(g.row_ptr, g.col_idx, g.out_deg, g.n, vertex_block=32)
    assert b.num_blocks == 5 and b.n_pad == 160
    rp = np.asarray(g.row_ptr)
    for i in range(b.num_blocks):
        v0, v1 = i * 32, min((i + 1) * 32, g.n)
        nnz = int(rp[v1] - rp[v0])
        assert nnz <= b.e_blk
        got = np.asarray(b.col[i, :nnz])
        assert (got == np.asarray(g.col_idx[rp[v0]:rp[v1]])).all()
        assert (np.asarray(b.deg[i, v1 - v0:]) == 0).all()


# ---------------------------------------------------------------------------
# one dangling-vertex convention across every implementation
# ---------------------------------------------------------------------------

def test_dangling_guard_identical_everywhere():
    """deg == 0 ⇒ stay put — the single self-loop convention, asserted for
    graph/csr.py:uniform_successor, every kernels/frog_step* impl, and the
    walk-index/stitch path (a dangling vertex's precomputed endpoints are
    all itself, so a stitch round from it cannot move either)."""
    # vertex 2 dangling (deg 0); vertices 0, 1 point at 2.
    g = CSRGraph(
        n=3,
        row_ptr=jnp.asarray([0, 1, 2, 2], jnp.int32),
        col_idx=jnp.asarray([2, 2], jnp.int32),
        out_deg=jnp.asarray([1, 1, 0], jnp.int32),
    )
    pos = jnp.asarray([2, 0, 2, 1], jnp.int32)
    die = jnp.zeros((4,), jnp.int32)
    bits = jnp.asarray([5, 9, 13, 2], jnp.int32)

    stay = uniform_successor(g.row_ptr, g.col_idx, g.out_deg, pos, bits)
    assert np.asarray(stay).tolist() == [2, 2, 2, 2]

    for impl in ("ref", "pallas", "stream"):
        nxt, cnt = ops.frog_step(pos, die, bits, g.row_ptr, g.col_idx,
                                 g.out_deg, g.n, impl=impl,
                                 vertex_block=2, frog_block=2)
        assert np.asarray(nxt).tolist() == [2, 2, 2, 2], impl
        assert int(cnt.sum()) == 0, impl

    # the index build walks through the same guard → endpoints[2] ≡ 2, and
    # both stitch backends therefore hold a walk at the dangling vertex.
    index = build_walk_index(
        g, WalkIndexConfig(segments_per_vertex=4, segment_len=3,
                           num_shards=1))
    assert (np.asarray(index.endpoints)[2] == 2).all()
    wpos = jnp.full((4,), 2, jnp.int32)
    for impl in ("ref", "pallas"):
        nxt, _ = ops.stitch_step(wpos, jnp.zeros((4,), jnp.int32), bits,
                                 index.endpoints, g.n, impl=impl)
        assert np.asarray(nxt).tolist() == [2, 2, 2, 2], impl


# ---------------------------------------------------------------------------
# sort-compacted frog_count
# ---------------------------------------------------------------------------

def test_frog_count_presorted_fast_path():
    rng = np.random.default_rng(3)
    dest = jnp.asarray(rng.integers(0, 777, 5000), jnp.int32)
    want = np.asarray(ops.frog_count(dest, 777, impl="ref"))
    got = ops.frog_count(jnp.sort(dest), 777, impl="sort",
                         assume_sorted=True)
    assert (np.asarray(got) == want).all()
    # assume_sorted honours the padding-sentinel contract too
    padded = jnp.sort(jnp.concatenate(
        [dest, jnp.full((100,), -1, jnp.int32)]))
    got = ops.frog_count(padded, 777, impl="sort", assume_sorted=True)
    assert (np.asarray(got) == want).all()


def test_frog_count_auto_dispatch():
    rng = np.random.default_rng(4)
    for n, N in [(64, 5000), (5000, 300)]:
        dest = jnp.asarray(rng.integers(0, n, N), jnp.int32)
        a = ops.frog_count(dest, n, impl="auto")
        b = ops.frog_count(dest, n, impl="ref")
        assert (np.asarray(a) == np.asarray(b)).all(), (n, N)


# ---------------------------------------------------------------------------
# mesh-sharded index build + per-shard persistence
# ---------------------------------------------------------------------------

def test_sharded_index_build_roundtrip_mesh():
    out = run_with_devices("""
import os, tempfile
import jax, numpy as np
from repro.graph import chung_lu_powerlaw
from repro.query import (WalkIndexConfig, build_walk_index_sharded,
                         load_walk_index)
mesh = jax.make_mesh((4,), ("vertex",), axis_types=(jax.sharding.AxisType.Auto,))
g = chung_lu_powerlaw(n=1030, avg_out_deg=6, seed=4)   # 1030 % 4 != 0
cfg = WalkIndexConfig(segments_per_vertex=3, segment_len=2, seed=5)
with tempfile.TemporaryDirectory() as d:
    index = build_walk_index_sharded(g, cfg, mesh, directory=d)
    assert index.endpoints.shape == (g.n, 3)
    ep = np.asarray(index.endpoints)
    assert (ep >= 0).all() and (ep < g.n).all()
    shard_dirs = sorted(x for x in os.listdir(d) if x.startswith("shard_"))
    assert shard_dirs == [f"shard_{s:04d}" for s in range(4)], shard_dirs
    loaded = load_walk_index(d)
    assert loaded.segment_len == 2 and loaded.seed == 5
    assert (np.asarray(loaded.endpoints) == ep).all()
    # a missing shard must fail loudly, not silently truncate the slab
    import shutil
    shutil.rmtree(os.path.join(d, "shard_0002"))
    try:
        load_walk_index(d)
        raise SystemExit("expected FileNotFoundError")
    except FileNotFoundError as e:
        assert "0002" in str(e) or "[2]" in str(e), e
print("SHARDED-INDEX-OK")
""", n_devices=4)
    assert "SHARDED-INDEX-OK" in out


def test_sharded_index_matches_host_loop_distribution():
    """Mesh build and host-loop build sample the same P^L kernel: endpoint
    marginals from a fixed start vertex must agree statistically."""
    g = chung_lu_powerlaw(n=256, avg_out_deg=6, seed=8)
    mesh = jax.make_mesh((1,), ("vertex",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    cfg = WalkIndexConfig(segments_per_vertex=128, segment_len=2)
    a = np.asarray(build_walk_index_sharded(g, cfg, mesh).endpoints)
    b = np.asarray(build_walk_index(
        g, WalkIndexConfig(segments_per_vertex=128, segment_len=2,
                           num_shards=2)).endpoints)
    # pooled endpoint histograms over all vertices: TV within sampling noise
    # (two independent multinomials over 256 bins, 32768 samples each →
    # E[TV] ≈ 0.045; 0.08 is a ≳4σ margin).
    ha = np.bincount(a.reshape(-1), minlength=g.n) / a.size
    hb = np.bincount(b.reshape(-1), minlength=g.n) / b.size
    tv = 0.5 * np.abs(ha - hb).sum()
    assert tv < 0.08, tv
