"""Gateway-tier fault-tolerance contract tests (PR 8).

Five claims are enforced here:

* **Failover byte-identity**: a replica crashing mid-query replays the
  query on a healthy replica, and — because every replica is seeded
  identically with a key stream starting at wave 0 — the survived answer
  is byte-identical to the fault-free run. Joined handles migrate with
  their parent.

* **Supervision**: crashes and missed heartbeats open the replica's
  circuit breaker (quarantined out of ``route()``); the breaker walks
  closed → open → half_open → closed; a crashed replica restarts over
  the *same* shared slab (object identity, zero index rebuild).

* **Shedding, not blocking**: overload (backlog past the shed threshold
  or every breaker open) raises ``GatewayOverloadError`` with an honest
  ``retry_after_s``; the HTTP layer maps it to 503 + ``Retry-After``,
  and request deadlines to 504 — a sick tier answers *something* fast.

* **Hedging**: a slow query fires one duplicate on another replica;
  first certified answer wins, the loser is cancelled, the cache sees
  exactly one insert, and a hedge outliving a crashed primary is
  promoted instead of spawning a third copy.

* **Termination**: cancel-with-joiners settles with a classified
  ``WaveFailedError`` (never an infinite poll); a certificate earned
  under epoch e is refused by the cache after ``bump_epoch()`` moved the
  tier to e+1; ``drain()`` finishes in-flight work then closes.
"""
import json
import time
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro import (FrogWildService, Gateway, RuntimeConfig, ServingConfig,
                   ShardConfig)
from repro.distributed.faults import (FaultInjector, FaultPlan,
                                      ReplicaCrashed, WaveFailedError)
from repro.gateway import GatewayOverloadError, serve_http
from repro.graph import chung_lu_powerlaw

EPS_OK = 0.4   # feasible at max_steps=32 (certificate ≈ 0.392)


def _graph(n=256, seed=2):
    return chung_lu_powerlaw(n=n, avg_out_deg=6, seed=seed)


def _rc(faults=None, seed=11, **serving_kw):
    serving = dict(segments_per_vertex=12, segment_len=3, build_shards=2,
                   max_walks=512, max_queries=3, max_steps=32)
    serving.update(serving_kw)
    return RuntimeConfig(
        runtime=ShardConfig(num_shards=1, seed=seed),
        serving=ServingConfig(**serving), faults=faults)


@pytest.fixture(scope="module")
def graph():
    return _graph()


@pytest.fixture(scope="module")
def reference(graph):
    """The fault-free gateway answer every failover run must reproduce."""
    with Gateway.open(graph, _rc(), replicas=2, cache=False) as gw:
        return gw.topk(k=8, epsilon=EPS_OK, delta=0.1).result()


# ---------------------------------------------------------------------------
# the replica-level fault plan itself
# ---------------------------------------------------------------------------


class TestReplicaFaultPlan:
    def test_crash_is_consumed_once(self):
        inj = FaultInjector(FaultPlan(seed=1, replica_crashes=((1, 2),)))
        assert not inj.replica_crash_at(1, 0)
        assert not inj.replica_crash_at(0, 2)      # other replica untouched
        assert inj.replica_crash_at(1, 2)
        assert not inj.replica_crash_at(1, 2)      # consumed
        assert [e.kind for e in inj.fired] == ["replica_crash"]

    def test_stall_fires_once_slow_is_persistent(self):
        inj = FaultInjector(FaultPlan(
            seed=1, replica_stalls=((0, 1, 0.5),), replica_slow=((1, 0.2),)))
        assert inj.replica_stall_s(0, 0) == 0.0
        assert inj.replica_stall_s(0, 1) == 0.5
        assert inj.replica_stall_s(0, 1) == 0.0    # consumed
        for _ in range(3):                         # slow never drains
            assert inj.replica_slow_s(1) == 0.2
        assert inj.replica_slow_s(0) == 0.0

    def test_empty_plan_has_no_replica_faults(self):
        plan = FaultPlan(seed=0)
        assert plan.empty
        inj = FaultInjector(plan)
        assert not inj.replica_crash_at(0, 0)
        assert inj.replica_stall_s(0, 0) == 0.0
        assert inj.replica_slow_s(0) == 0.0


# ---------------------------------------------------------------------------
# failover
# ---------------------------------------------------------------------------


class TestFailover:
    def test_crash_midquery_failover_is_byte_identical(self, graph,
                                                       reference):
        plan = FaultPlan(seed=3, replica_crashes=((0, 0),))
        with Gateway.open(graph, _rc(plan), replicas=2, cache=False) as gw:
            h = gw.topk(k=8, epsilon=EPS_OK, delta=0.1)
            assert h.replica == 0                  # routed to the doomed one
            r = h.result()
            # migrated, and the survived answer is the fault-free answer.
            assert h.replica == 1
            assert h.failovers == 1
            assert gw.metrics.failovers == 1
            np.testing.assert_array_equal(r.vertices, reference.vertices)
            np.testing.assert_array_equal(r.scores, reference.scores)
            assert r.epsilon_bound == reference.epsilon_bound
            # the sick replica is quarantined out of routing...
            assert gw.pool.breaker_state(0) == "open"
            assert gw.pool.states[0].crashed
            assert gw.pool.routable() == [1]
            # ...and restarts over the SAME slab: object identity, no
            # rebuild, cold key stream.
            fresh = gw.pool.restart_replica(0)
            assert fresh is gw.pool.replicas[0]
            assert fresh.ensure_index() is gw.pool.index
            assert gw.pool.states[0].restarts == 1
            assert not gw.pool.states[0].crashed

    def test_joiners_migrate_with_their_parent(self, graph, reference):
        plan = FaultPlan(seed=3, replica_crashes=((0, 0),))
        with Gateway.open(graph, _rc(plan), replicas=2) as gw:
            parent = gw.topk(k=8, epsilon=EPS_OK, delta=0.1)
            joined = gw.topk(k=8, epsilon=EPS_OK, delta=0.1)
            assert joined.source == "joined"
            pr = parent.result()                   # crash + failover inside
            assert parent.replica == 1
            jr = joined.result()
            assert joined.replica == 1             # migrated with parent
            # identical target ⇒ the joined result IS the parent's object.
            assert jr is pr
            np.testing.assert_array_equal(jr.vertices, reference.vertices)

    def test_no_replica_left_is_classified_not_a_hang(self, graph):
        plan = FaultPlan(seed=3, replica_crashes=((0, 0),))
        with Gateway.open(graph, _rc(plan), replicas=1, cache=False) as gw:
            h = gw.topk(k=8, epsilon=EPS_OK, delta=0.1)
            with pytest.raises(WaveFailedError, match="failover impossible"):
                h.result()

    def test_zero_fault_gateway_matches_direct_service(self, graph,
                                                       reference):
        """The supervised drive path must not perturb the fault-free
        answer: gateway-over-pool ≡ a cold standalone service."""
        with FrogWildService.open(graph, _rc()) as svc:
            direct = svc.topk(k=8, epsilon=EPS_OK, delta=0.1).result()
        np.testing.assert_array_equal(direct.vertices, reference.vertices)
        np.testing.assert_array_equal(direct.scores, reference.scores)
        assert direct.epsilon_bound == reference.epsilon_bound


# ---------------------------------------------------------------------------
# supervision: stalls, breakers, health
# ---------------------------------------------------------------------------


class TestSupervision:
    def test_stall_quarantines_and_reroutes(self, graph, reference):
        plan = FaultPlan(seed=3, replica_stalls=((0, 0, 0.6),))
        with Gateway.open(graph, _rc(plan), replicas=2, cache=False,
                          heartbeat_timeout_s=0.25) as gw:
            h = gw.topk(k=8, epsilon=EPS_OK, delta=0.1)
            assert h.replica == 0
            r = h.result()                         # stall → migrate → serve
            assert h.replica == 1
            assert gw.pool.breaker_state(0) == "open"
            assert gw.pool.routable() == [1]
            np.testing.assert_array_equal(r.vertices, reference.vertices)
            # the stalled replica did not crash: its breaker can half-open
            # after the cooldown without a restart.
            assert not gw.pool.states[0].crashed

    def test_breaker_walks_closed_open_half_open_closed(self, graph):
        with Gateway.open(graph, _rc(), replicas=2, cache=False,
                          breaker_failure_threshold=3,
                          breaker_cooldown_s=0.05) as gw:
            pool = gw.pool
            assert pool.breaker_state(0) == "closed"
            pool.record_failure(0, "wave failed")
            pool.record_failure(0, "wave failed")
            assert pool.breaker_state(0) == "closed"   # below threshold
            pool.record_failure(0, "wave failed")
            assert pool.breaker_state(0) == "open"
            assert pool.routable() == [1]
            assert pool.health_score(0) == 0.0
            time.sleep(0.06)
            assert pool.breaker_state(0) == "half_open"  # cooldown elapsed
            assert pool.health_score(0) == 0.5
            assert pool.routable() == [0, 1]  # half_open stays probe-able
            gw.topk(k=8, epsilon=EPS_OK, delta=0.1).result()
            assert pool.breaker_state(0) == "closed"     # clean probe wave
            assert pool.health_score(0) > 0.5
            kinds = [e.kind for e in pool.fault_log]
            assert kinds == ["breaker_open", "breaker_half_open",
                             "breaker_close"]

    def test_half_open_failure_reopens(self, graph):
        with Gateway.open(graph, _rc(), replicas=2, cache=False,
                          breaker_failure_threshold=3,
                          breaker_cooldown_s=0.01) as gw:
            pool = gw.pool
            for _ in range(3):
                pool.record_failure(0, "wave failed")
            time.sleep(0.02)
            assert pool.breaker_state(0) == "half_open"
            pool.record_failure(0, "probe failed")   # one strike in probe
            assert pool.breaker_state(0) == "open"

    def test_crashed_replica_refuses_drive_until_restart(self, graph):
        plan = FaultPlan(seed=3, replica_crashes=((0, 0),))
        with Gateway.open(graph, _rc(plan), replicas=2, cache=False) as gw:
            gw.topk(k=8, epsilon=EPS_OK, delta=0.1).result()
            with pytest.raises(ReplicaCrashed):
                gw.pool.step_replica(0)
            gw.pool.restart_replica(0)
            gw.pool.step_replica(0)                # cold but alive again

    def test_stats_surface_supervision_state(self, graph):
        plan = FaultPlan(seed=3, replica_crashes=((0, 0),))
        with Gateway.open(graph, _rc(plan), replicas=2, cache=False) as gw:
            gw.topk(k=8, epsilon=EPS_OK, delta=0.1).result()
            snap = gw.stats()
            r0, r1 = snap["replicas"]
            assert r0["breaker"] == "open" and r0["crashed"]
            assert r0["health"] == 0.0
            assert r1["breaker"] == "closed" and not r1["crashed"]
            assert snap["failovers"] == 1
            assert {"hedges_fired", "hedges_won", "sheds",
                    "timeouts"} <= snap.keys()
            assert gw.healthy()                    # replica 1 still routable


# ---------------------------------------------------------------------------
# shedding + drain
# ---------------------------------------------------------------------------


class TestShedding:
    def test_overload_sheds_instead_of_blocking(self, graph):
        with Gateway.open(graph, _rc(), replicas=2, cache=False,
                          shed_backlog_walks=1) as gw:
            h = gw.topk(k=8, epsilon=EPS_OK, delta=0.1)   # fills the backlog
            with pytest.raises(GatewayOverloadError) as ei:
                gw.ppr(3, k=8, epsilon=EPS_OK, delta=0.1)
            assert ei.value.reason == "overload"
            assert ei.value.retry_after_s > 0
            assert gw.metrics.sheds == 1
            h.result()                             # the admitted one finishes

    def test_all_breakers_open_sheds_no_replica(self, graph):
        with Gateway.open(graph, _rc(), replicas=2, cache=False,
                          breaker_failure_threshold=1,
                          breaker_cooldown_s=60.0) as gw:
            gw.pool.record_failure(0, "dead")
            gw.pool.record_failure(1, "dead")
            with pytest.raises(GatewayOverloadError) as ei:
                gw.topk(k=8, epsilon=EPS_OK, delta=0.1)
            assert ei.value.reason == "no_replica"
            # Retry-After reflects the remaining breaker cooldown.
            assert 0 < ei.value.retry_after_s <= 60.0
            assert not gw.healthy()

    def test_drain_finishes_inflight_then_closes(self, graph):
        with Gateway.open(graph, _rc(), replicas=2) as gw:
            h = gw.topk(k=8, epsilon=EPS_OK, delta=0.1)
            results = gw.drain()
            assert [r.rid for r in results] == [h.result().rid]
            assert h.done()
            assert gw.closed
            assert gw.drain() == []                # idempotent after close

    def test_draining_rejects_new_submits(self, graph):
        with Gateway.open(graph, _rc(), replicas=2) as gw:
            gw._draining = True                    # freeze admission only
            with pytest.raises(GatewayOverloadError) as ei:
                gw.topk(k=8, epsilon=EPS_OK, delta=0.1)
            assert ei.value.reason == "draining"
            with pytest.raises(GatewayOverloadError):
                gw.pagerank(epsilon=EPS_OK, delta=0.1, k=8)


# ---------------------------------------------------------------------------
# hedging
# ---------------------------------------------------------------------------


class TestHedging:
    def test_primary_win_cancels_hedge_one_cache_insert(self, graph):
        plan = FaultPlan(seed=3, replica_slow=((0, 0.05),))
        with Gateway.open(graph, _rc(plan), replicas=2,
                          hedge_after_s=0.01) as gw:
            h = gw.topk(k=8, epsilon=EPS_OK, delta=0.1)
            assert h.replica == 0
            h.result()
            assert gw.metrics.hedges_fired == 1
            assert gw.metrics.hedges_won == 0      # primary stayed ahead
            assert h._hedge is None                # loser cancelled
            assert gw.cache.insertions == 1        # exactly one insert

    def test_hedge_promoted_when_primary_crashes(self, graph, reference):
        plan = FaultPlan(seed=3, replica_slow=((0, 0.2),),
                         replica_crashes=((0, 2),))
        with Gateway.open(graph, _rc(plan), replicas=2, cache=False,
                          hedge_after_s=0.05) as gw:
            h = gw.topk(k=8, epsilon=EPS_OK, delta=0.1)
            assert h.replica == 0
            r = h.result()
            assert h.replica == 1                  # the hedge's replica
            assert gw.metrics.hedges_fired == 1
            assert gw.metrics.hedges_won == 1      # promoted, not resubmit
            assert gw.metrics.failovers == 1
            # the promoted hedge ran cold on replica 1 ⇒ byte-identical.
            np.testing.assert_array_equal(r.vertices, reference.vertices)
            np.testing.assert_array_equal(r.scores, reference.scores)
            assert r.epsilon_bound == reference.epsilon_bound

    def test_hedging_disabled_by_default(self, graph):
        plan = FaultPlan(seed=3, replica_slow=((0, 0.05),))
        with Gateway.open(graph, _rc(plan), replicas=2, cache=False) as gw:
            gw.topk(k=8, epsilon=EPS_OK, delta=0.1).result()
            assert gw.metrics.hedges_fired == 0


# ---------------------------------------------------------------------------
# termination: joiner cancel, epoch race
# ---------------------------------------------------------------------------


class TestTermination:
    def test_cancel_with_joiners_is_classified_not_a_poll_loop(self, graph):
        with FrogWildService.open(graph, _rc()) as svc:
            qh = svc.topk(k=8, epsilon=EPS_OK, delta=0.1)
            joined = qh.join(EPS_OK, 0.2)
            assert qh.cancel()
            assert joined.done()                   # terminal, not pending
            with pytest.raises(WaveFailedError, match="cancelled"):
                joined.result()

    def test_bump_epoch_refuses_stale_certificate(self, graph):
        with Gateway.open(graph, _rc(), replicas=2) as gw:
            h = gw.topk(k=8, epsilon=EPS_OK, delta=0.1)   # epoch 0 query
            assert gw.bump_epoch() == 1
            rejected_before = gw.cache.rejected_inserts
            h.result()                             # finishes under epoch 1
            assert gw.cache.rejected_inserts == rejected_before + 1
            assert len(gw.cache) == 0              # nothing stale landed
            # a fresh query on the new epoch caches normally.
            gw.topk(k=8, epsilon=EPS_OK, delta=0.1).result()
            assert len(gw.cache) == 1


# ---------------------------------------------------------------------------
# HTTP: structured backpressure, no lock convoy
# ---------------------------------------------------------------------------


def _get(url):
    try:
        with urllib.request.urlopen(url, timeout=30) as resp:
            return resp.status, dict(resp.headers), json.load(resp)
    except urllib.error.HTTPError as e:
        return e.code, dict(e.headers), json.load(e)


class TestHTTP:
    def test_overload_maps_to_503_with_retry_after(self, graph):
        with Gateway.open(graph, _rc(), replicas=2, cache=False,
                          shed_backlog_walks=1) as gw:
            h = gw.topk(k=8, epsilon=EPS_OK, delta=0.1)
            with serve_http(gw) as srv:
                # a distinct key: the same key would ride the in-flight
                # join (dedup costs no new walks, so it is never shed).
                code, headers, body = _get(
                    f"{srv.url}/ppr?source=3&k=8&epsilon={EPS_OK}"
                    f"&delta=0.1")
                assert code == 503
                assert body["reason_code"] == "overload"
                assert int(headers["Retry-After"]) >= 1
                # /healthz and /metrics still answer while overloaded.
                code, _, hz = _get(f"{srv.url}/healthz")
                assert code == 200 and hz["healthy"]
                code, _, m = _get(f"{srv.url}/metrics")
                assert code == 200 and m["sheds"] == 1
            h.result()

    def test_deadline_maps_to_504(self, graph):
        with Gateway.open(graph, _rc(), replicas=2, cache=False) as gw:
            with serve_http(gw) as srv:
                code, _, body = _get(
                    f"{srv.url}/topk?k=8&epsilon={EPS_OK}&delta=0.1"
                    f"&timeout_s=0.000001")
                assert code == 504
                assert body["reason_code"] == "deadline"
                assert gw.metrics.timeouts == 1

    def test_healthz_reports_quarantine(self, graph):
        plan = FaultPlan(seed=3, replica_crashes=((0, 0),))
        with Gateway.open(graph, _rc(plan), replicas=2, cache=False) as gw:
            gw.topk(k=8, epsilon=EPS_OK, delta=0.1).result()
            with serve_http(gw) as srv:
                code, _, hz = _get(f"{srv.url}/healthz")
                assert code == 200                 # degraded, still serving
                assert hz["routable"] == [1]
