"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.graph import chung_lu_powerlaw, to_ell, uniform_random
from repro.kernels import ops, ref


# ---------------------------------------------------------------------------
# SpMV (hybrid ELL)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,deg,K", [
    (256, 4, 8), (300, 10, 16), (1000, 12, 32), (513, 30, 16),
])
def test_spmv_matches_ref(n, deg, K):
    g = chung_lu_powerlaw(n=n, avg_out_deg=deg, seed=n)
    ell = to_ell(g, K=K)
    x = jnp.asarray(np.random.default_rng(0).standard_normal(ell.n_rows),
                    dtype=jnp.float32)
    y_pal = ops.spmv(ell, x, impl="pallas")
    y_ref = ops.spmv(ell, x, impl="ref")
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               atol=1e-4)


def test_spmv_hub_spill():
    """Power-law hubs overflow the slab — spill path must stay exact."""
    g = chung_lu_powerlaw(n=400, avg_out_deg=20, seed=9)
    ell = to_ell(g, K=8)          # tiny slab forces heavy spill
    assert ell.spill_nnz > 0
    x = jnp.ones((ell.n_rows,), jnp.float32)
    y = ops.spmv(ell, x, impl="pallas")
    # P is column-stochastic: sum of y equals number of real vertices' mass
    assert float(y[: g.n].sum()) == pytest.approx(g.n, rel=1e-4)


# ---------------------------------------------------------------------------
# frog_count histogram
# ---------------------------------------------------------------------------

@given(
    n=st.integers(8, 2000),
    N=st.integers(1, 5000),
    seed=st.integers(0, 100),
)
@settings(max_examples=15)
def test_frog_count_matches_ref(n, N, seed):
    dest = jnp.asarray(
        np.random.default_rng(seed).integers(0, n, size=N), dtype=jnp.int32)
    a = ops.frog_count(dest, n, impl="pallas")
    b = ops.frog_count(dest, n, impl="ref")
    assert (np.asarray(a) == np.asarray(b)).all()
    assert int(a.sum()) == N


def test_frog_count_skewed():
    dest = jnp.zeros((4096,), jnp.int32)          # all frogs on vertex 0
    c = ops.frog_count(dest, 1024, impl="pallas")
    assert int(c[0]) == 4096 and int(c.sum()) == 4096


@given(
    n=st.integers(8, 2000),
    N=st.integers(1, 5000),
    seed=st.integers(0, 100),
)
@settings(max_examples=10)
def test_frog_count_sort_matches_ref(n, N, seed):
    dest = jnp.asarray(
        np.random.default_rng(seed).integers(0, n, size=N), dtype=jnp.int32)
    a = ops.frog_count(dest, n, impl="sort")
    b = ops.frog_count(dest, n, impl="ref")
    assert (np.asarray(a) == np.asarray(b)).all()


def test_frog_count_sort_ignores_padding():
    dest = jnp.asarray([-1, 0, 3, 3, -1, 7], jnp.int32)
    c = np.asarray(ops.frog_count(dest, 8, impl="sort"))
    assert c.tolist() == [1, 0, 0, 2, 0, 0, 0, 1]


# ---------------------------------------------------------------------------
# fused frog_step (plain walker superstep)
# ---------------------------------------------------------------------------

@given(
    n=st.integers(16, 800),
    N=st.integers(8, 4000),
    seed=st.integers(0, 50),
)
@settings(max_examples=10)
def test_frog_step_matches_ref(n, N, seed):
    g = uniform_random(n, avg_out_deg=5, seed=seed)
    rng = np.random.default_rng(seed)
    pos = jnp.asarray(rng.integers(0, n, N), jnp.int32)
    die = jnp.asarray(rng.random(N) < 0.2, jnp.int32)
    bits = jnp.asarray(rng.integers(0, 1 << 30, N), jnp.int32)
    nxt_p, cnt_p = ops.frog_step(
        pos, die, bits, g.row_ptr, g.col_idx, g.out_deg, g.n, impl="pallas")
    nxt_r, cnt_r = ops.frog_step(
        pos, die, bits, g.row_ptr, g.col_idx, g.out_deg, g.n, impl="ref")
    assert (np.asarray(nxt_p) == np.asarray(nxt_r)).all()
    assert (np.asarray(cnt_p) == np.asarray(cnt_r)).all()
    assert int(cnt_p.sum()) == int(die.sum())


def test_frog_step_dangling_stays_put():
    # vertex 1 dangling: frogs there must not move or crash
    row_ptr = jnp.asarray([0, 1, 1], jnp.int32)
    col_idx = jnp.asarray([1], jnp.int32)
    deg = jnp.asarray([1, 0], jnp.int32)
    pos = jnp.asarray([0, 1, 1, 0], jnp.int32)
    die = jnp.zeros((4,), jnp.int32)
    bits = jnp.asarray([5, 9, 13, 2], jnp.int32)
    for impl in ("pallas", "ref"):
        nxt, cnt = ops.frog_step(pos, die, bits, row_ptr, col_idx, deg, 2,
                                 impl=impl)
        assert np.asarray(nxt).tolist() == [1, 1, 1, 1], impl
        assert int(cnt.sum()) == 0


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

CASES = [
    # B, Hq, Hkv, S, D, window, causal, soft_cap, dtype
    (1, 4, 4, 256, 64, None, True, None, jnp.float32),
    (2, 4, 2, 256, 64, None, True, None, jnp.float32),
    (2, 8, 2, 384, 32, None, True, None, jnp.bfloat16),
    (1, 4, 1, 256, 128, None, False, None, jnp.float32),
    (2, 4, 2, 256, 64, 64, True, None, jnp.float32),
    (1, 2, 2, 512, 64, 128, True, None, jnp.float32),
    (1, 4, 4, 256, 64, None, True, 30.0, jnp.float32),
]


@pytest.mark.parametrize("B,Hq,Hkv,S,D,window,causal,cap,dtype", CASES)
def test_flash_attention_matches_ref(B, Hq, Hkv, S, D, window, causal, cap,
                                     dtype):
    rng = np.random.default_rng(B * 100 + S)
    q = jnp.asarray(rng.standard_normal((B, Hq, S, D)), dtype=dtype)
    k = jnp.asarray(rng.standard_normal((B, Hkv, S, D)), dtype=dtype)
    v = jnp.asarray(rng.standard_normal((B, Hkv, S, D)), dtype=dtype)
    out = ops.attention(q, k, v, causal=causal, window=window, soft_cap=cap,
                        impl="pallas")
    want = ops.attention(q, k, v, causal=causal, window=window, soft_cap=cap,
                         impl="ref")
    atol = 2e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), atol=atol)


@pytest.mark.parametrize("window", [None, 64, 100])
def test_chunked_attention_matches_ref(window):
    rng = np.random.default_rng(0)
    B, Hq, Hkv, S, D = 2, 4, 2, 384, 32
    q = jnp.asarray(rng.standard_normal((B, Hq, S, D)), dtype=jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, Hkv, S, D)), dtype=jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Hkv, S, D)), dtype=jnp.float32)
    out = ops.attention(q, k, v, causal=True, window=window,
                        impl="jnp_flash", chunk=128)
    want = ops.attention(q, k, v, causal=True, window=window, impl="ref")
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_decode_attention_ref_consistency():
    rng = np.random.default_rng(1)
    B, Hq, Hkv, S, D = 2, 4, 2, 128, 32
    q = jnp.asarray(rng.standard_normal((B, Hq, 1, D)), dtype=jnp.float32)
    kc = jnp.asarray(rng.standard_normal((B, Hkv, S, D)), dtype=jnp.float32)
    vc = jnp.asarray(rng.standard_normal((B, Hkv, S, D)), dtype=jnp.float32)
    L = 77
    out = ref.decode_attention_ref(q, kc, vc, jnp.asarray(L))
    want = ref.attention_ref(q, kc[:, :, :L], vc[:, :, :L], causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5)


@given(length=st.integers(1, 127), window=st.integers(1, 64))
@settings(max_examples=10)
def test_decode_attention_windowed(length, window):
    rng = np.random.default_rng(length)
    B, Hq, Hkv, S, D = 1, 2, 2, 128, 16
    q = jnp.asarray(rng.standard_normal((B, Hq, 1, D)), dtype=jnp.float32)
    kc = jnp.asarray(rng.standard_normal((B, Hkv, S, D)), dtype=jnp.float32)
    vc = jnp.asarray(rng.standard_normal((B, Hkv, S, D)), dtype=jnp.float32)
    out = ref.decode_attention_ref(q, kc, vc, jnp.asarray(length),
                                   window=window)
    lo = max(0, length - window)
    want = ref.attention_ref(q, kc[:, :, lo:length], vc[:, :, lo:length],
                             causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5)
