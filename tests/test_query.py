"""Walk-index query engine: build/persist correctness, fused stitch kernel
vs oracle, and the statistical acceptance test — the index-stitched walk
endpoint distribution must match the direct-walk distribution (chi-square +
TV, same style as tests/test_blocking_draw.py).

Stitching is only sound if a composed walk (``r`` direct steps + ``q``
uniformly-drawn precomputed segments) has exactly the τ-step transition
marginal; the index is regenerated per key so the comparison samples the
true marginal, not one fixed slab's conditional.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import normalized_mass_captured, power_iteration, theory
from repro.graph import build_csr, chung_lu_powerlaw, uniform_random
from repro.kernels import ops
from repro.query import (QueryRequest, QueryScheduler, WalkIndex,
                         WalkIndexConfig, build_walk_index, load_walk_index,
                         plan_query, query_counts, sample_walk_lengths,
                         save_walk_index)
from repro.query.engine import _plain_steps, walk_wave
from repro.query.index import _ShardWalker


def _max_tv(a: np.ndarray, b: np.ndarray) -> float:
    pa = a / np.maximum(a.sum(axis=1, keepdims=True), 1)
    pb = b / np.maximum(b.sum(axis=1, keepdims=True), 1)
    return float(0.5 * np.abs(pa - pb).sum(axis=1).max())


def _chi2_two_sample(a: np.ndarray, b: np.ndarray):
    support = (a + b) > 0
    x2 = float((((a - b) ** 2) / np.maximum(a + b, 1))[support].sum())
    df = int(support.sum(axis=1).clip(min=1).sum() - a.shape[0])
    thresh = df + 4.0 * np.sqrt(2 * df)
    return x2, df, thresh


def _transition_counts(draw_fn, n, num_keys, batch=500, seed0=0):
    """Empirical endpoint histogram per start vertex: int64[n, n]."""
    pos = jnp.arange(n, dtype=jnp.int32)
    fn = jax.jit(jax.vmap(lambda k: draw_fn(k, pos)))
    counts = np.zeros((n, n), dtype=np.int64)
    src = np.broadcast_to(np.arange(n), (batch, n))
    done = 0
    while done < num_keys:
        keys = jax.vmap(jax.random.PRNGKey)(seed0 + done + jnp.arange(batch))
        np.add.at(counts, (src, np.asarray(fn(keys))), 1)
        done += batch
    return counts


# --- index build + persistence ----------------------------------------------


def test_index_build_ring_exact():
    """On a directed ring every walk is deterministic: endpoint = v + L."""
    n, R, L = 64, 4, 5
    g = build_csr(n, np.arange(n), (np.arange(n) + 1) % n)
    idx = build_walk_index(g, WalkIndexConfig(
        segments_per_vertex=R, segment_len=L, num_shards=4))
    assert idx.endpoints.shape == (n, R)
    want = (np.arange(n)[:, None] + L) % n
    assert (np.asarray(idx.endpoints) == want).all()


def test_index_build_range_and_sharding_invariance():
    g = uniform_random(100, avg_out_deg=5, seed=3)
    for shards in (1, 4, 7):
        idx = build_walk_index(g, WalkIndexConfig(
            segments_per_vertex=6, segment_len=3, num_shards=shards))
        e = np.asarray(idx.endpoints)
        assert e.shape == (100, 6)
        assert e.min() >= 0 and e.max() < g.n


def test_index_checkpoint_roundtrip(tmp_path):
    g = uniform_random(50, avg_out_deg=4, seed=1)
    idx = build_walk_index(g, WalkIndexConfig(
        segments_per_vertex=5, segment_len=2, num_shards=2, seed=9))
    d = os.path.join(str(tmp_path), "walk_index")
    save_walk_index(d, idx)
    idx2 = load_walk_index(d)
    assert isinstance(idx2, WalkIndex)
    assert (np.asarray(idx2.endpoints) == np.asarray(idx.endpoints)).all()
    assert idx2.segment_len == idx.segment_len
    assert idx2.seed == 9
    with pytest.raises(FileNotFoundError):
        load_walk_index(os.path.join(str(tmp_path), "nowhere"))


# --- fused stitch kernel -----------------------------------------------------


@pytest.mark.parametrize("W,n,R", [(1000, 300, 8), (128, 50, 3), (4096, 1024, 16)])
def test_stitch_kernel_matches_ref(W, n, R):
    rng = np.random.default_rng(W + n)
    pos = jnp.asarray(rng.integers(0, n, W), jnp.int32)
    stop = jnp.asarray(rng.integers(0, 2, W), jnp.int32)
    bits = jnp.asarray(rng.integers(0, 1 << 30, W), jnp.int32)
    endpoints = jnp.asarray(rng.integers(0, n, (n, R)), jnp.int32)
    n1, c1 = ops.stitch_step(pos, stop, bits, endpoints, n, impl="pallas")
    n2, c2 = ops.stitch_step(pos, stop, bits, endpoints, n, impl="ref")
    assert (np.asarray(n1) == np.asarray(n2)).all()
    assert (np.asarray(c1) == np.asarray(c2)).all()
    assert int(c1.sum()) == int(stop.sum())


def test_walk_wave_fused_tally_equals_final_histogram():
    """The fused per-round tally must equal one histogram of the final
    positions (a stopped walk's position never changes)."""
    g = uniform_random(200, avg_out_deg=5, seed=5)
    idx = build_walk_index(g, WalkIndexConfig(
        segments_per_vertex=6, segment_len=3, num_shards=2))
    W = 3000
    key = jax.random.PRNGKey(3)
    k_pos, k_tau, k_run = jax.random.split(key, 3)
    pos0 = jax.random.randint(k_pos, (W,), 0, g.n, jnp.int32)
    tau = sample_walk_lengths(k_tau, W, 0.15, 17)
    pos, counts = walk_wave(
        g.row_ptr, g.col_idx, g.out_deg, idx.endpoints, pos0, tau, k_run,
        idx.segment_len, 17 // idx.segment_len, impl="ref")
    assert int(counts.sum()) == W                       # conservation
    want = np.bincount(np.asarray(pos), minlength=g.n)
    assert (np.asarray(counts) == want).all()


# --- the acceptance test: stitched == direct distribution --------------------


def test_stitched_distribution_matches_direct():
    """Endpoints of index-stitched walks vs direct walks of the same length,
    per start vertex. τ varies with the vertex (v mod 6 ∈ {0..5}) so every
    (q, r) decomposition of L = 2 is exercised, including τ = 0 and pure-
    residual / pure-stitch cases. The index is rebuilt per key so the test
    samples the true stitched marginal."""
    g = uniform_random(30, avg_out_deg=4, seed=7)
    n, R, L = g.n, 4, 2
    tau = jnp.arange(n, dtype=jnp.int32) % 6
    t_max = 5
    walker = _ShardWalker(
        row_ptr=g.row_ptr, col_idx=g.col_idx, deg=g.out_deg, n=n,
        shard_size=n,
        cfg=WalkIndexConfig(segments_per_vertex=R, segment_len=L,
                            num_shards=1),
        block_size=1)

    def stitched(k, pos, impl):
        k_build, k_walk = jax.random.split(k)
        endpoints, _ = walker(jnp.int32(0), k_build)
        out, _ = walk_wave(g.row_ptr, g.col_idx, g.out_deg, endpoints,
                           pos, tau, k_walk, L, t_max // L, impl=impl)
        return out

    def direct(k, pos):
        return _plain_steps(g.row_ptr, g.col_idx, g.out_deg, pos, tau, k,
                            t_max)

    # 5-step walks spread over ~25 support vertices, so per-vertex TV noise
    # is ≈ √(support / 2N); 6000 keys keeps the max over 30 rows under 0.08.
    num_keys = 6000
    counts = {
        "direct": _transition_counts(direct, n, num_keys),
        "xla": _transition_counts(
            lambda k, p: stitched(k, p, "xla"), n, num_keys, seed0=50_000),
        "fused": _transition_counts(
            lambda k, p: stitched(k, p, "ref"), n, num_keys, seed0=90_000),
    }
    for name in ("xla", "fused"):
        x2, df, thresh = _chi2_two_sample(counts[name], counts["direct"])
        assert x2 < thresh, (name, x2, df, thresh)
        tv = _max_tv(counts[name], counts["direct"])
        assert tv < 0.08, (name, tv)
        assert counts[name].sum() == counts["direct"].sum()
    # τ = 0 vertices never move in either implementation
    for v in range(n):
        if v % 6 == 0:
            assert counts["xla"][v, v] == num_keys


def test_walk_length_distribution():
    """τ ~ min(Geometric(p_T), t): empirical pmf matches the truncated
    geometric within chi-square tolerance."""
    p_T, t, W = 0.3, 6, 200_000
    tau = np.asarray(sample_walk_lengths(jax.random.PRNGKey(0), W, p_T, t))
    obs = np.bincount(tau, minlength=t + 1).astype(np.float64)
    want = np.array([p_T * (1 - p_T) ** m for m in range(t)]
                    + [(1 - p_T) ** t]) * W
    x2 = float(((obs - want) ** 2 / want).sum())
    assert x2 < len(want) + 4 * np.sqrt(2 * len(want)), (x2, obs, want)


# --- planning + end-to-end serving ------------------------------------------


def test_plan_query_inverts_theorem1():
    for eps in (0.5, 0.25, 0.1):
        plan = plan_query(k=10, epsilon=eps, delta=0.1)
        bound = theory.epsilon_bound(
            0.15, plan.num_steps, 10, 0.1, plan.num_walks, 1.0, 0.0)
        assert bound <= eps + 1e-9, (eps, plan, bound)
        assert plan.epsilon_bound == pytest.approx(bound)
    # tighter ε ⇒ monotonically more work
    p1 = plan_query(10, 0.4)
    p2 = plan_query(10, 0.1)
    assert p2.num_walks > p1.num_walks and p2.num_steps >= p1.num_steps
    assert plan_query(10, 0.1, max_walks=500).num_walks == 500
    assert plan_query(10, 0.2, max_steps=7).num_steps == 7
    assert plan_query(10, 0.2).num_rounds(4) == plan_query(10, 0.2).num_steps // 4
    # a binding cap is visible: the achieved bound exceeds the request
    capped = plan_query(10, 0.2, max_steps=5)
    assert capped.epsilon_bound > capped.epsilon


def test_segment_budget_warning():
    from repro.query.engine import check_segment_budget

    with pytest.warns(UserWarning, match="reread segments"):
        check_segment_budget(segments_per_vertex=4, num_rounds=8)
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error")             # R ≥ rounds must stay silent
        check_segment_budget(segments_per_vertex=8, num_rounds=8)


def test_query_counts_conservation_and_accuracy():
    g = chung_lu_powerlaw(n=2048, avg_out_deg=10, seed=1)
    # R = 16 ≥ the ε = 0.3 plan's ⌊t/L⌋ = 11 stitch rounds (reuse-free)
    idx = build_walk_index(g, WalkIndexConfig(
        segments_per_vertex=16, segment_len=3, num_shards=4))
    plan = plan_query(k=10, epsilon=0.3, delta=0.1)
    counts = query_counts(g, idx, plan, jax.random.PRNGKey(0))
    assert int(counts.sum()) == plan.num_walks
    pi = power_iteration(g, num_iters=60)
    pi_hat = counts.astype(jnp.float32) / plan.num_walks
    assert float(normalized_mass_captured(pi_hat, pi, 10)) > 0.8


def test_scheduler_continuous_batching_end_to_end():
    """More queries than query slots, walk budgets spanning several waves:
    every query finishes, top-k answers track exact PageRank, PPR ranks its
    source first."""
    g = chung_lu_powerlaw(n=1024, avg_out_deg=10, seed=2)
    idx = build_walk_index(g, WalkIndexConfig(
        segments_per_vertex=8, segment_len=3, num_shards=4))
    pi = power_iteration(g, num_iters=60)
    source = int(np.asarray(g.out_deg).argmax())
    sched = QueryScheduler(g, idx, max_walks=2048, max_queries=3,
                           max_steps=24, seed=4)
    for i in range(5):
        if i % 2:
            sched.submit(QueryRequest(rid=i, kind="ppr", source=source,
                                      k=10, epsilon=0.3))
        else:
            sched.submit(QueryRequest(rid=i, kind="topk", k=10, epsilon=0.3))
    results = sched.run()
    assert sorted(r.rid for r in results) == list(range(5))
    assert not sched.active and not sched.queue
    for r in results:
        assert r.waves > 1                # budgets forced continuous batching
        assert len(r.vertices) == 10
        assert (r.scores >= 0).all() and r.scores.sum() <= 1.0 + 1e-9
        if r.kind == "topk":
            est = np.zeros(g.n, np.float32)
            est[r.vertices] = r.scores
            m = float(normalized_mass_captured(jnp.asarray(est), pi, 10))
            assert m > 0.7, (r.rid, m)
        else:
            # P(τ = 0) = p_T puts ≥ 15% of PPR mass on the source itself
            assert int(r.vertices[0]) == source
            assert r.scores[0] > 0.10


def test_scheduler_num_walks_override_and_single_wave():
    g = uniform_random(256, avg_out_deg=5, seed=8)
    idx = build_walk_index(g, WalkIndexConfig(
        segments_per_vertex=4, segment_len=2, num_shards=2))
    sched = QueryScheduler(g, idx, max_walks=512, max_queries=2, max_steps=8)
    sched.submit(QueryRequest(rid=0, kind="topk", k=5, num_walks=300))
    res = sched.run()
    assert len(res) == 1 and res[0].num_walks == 300 and res[0].waves == 1


def test_scheduler_rejects_invalid_requests():
    """num_walks ≤ 0 would make run() spin forever (0-walk query is never
    allocated, never retires); an out-of-range PPR source would be clamped
    by XLA's gather and answer for the wrong vertex. Both must raise at
    submit time."""
    g = uniform_random(64, avg_out_deg=4, seed=8)
    idx = build_walk_index(g, WalkIndexConfig(
        segments_per_vertex=4, segment_len=2, num_shards=2))
    sched = QueryScheduler(g, idx, max_walks=128, max_queries=2, max_steps=8)
    with pytest.raises(ValueError, match="num_walks"):
        sched.submit(QueryRequest(rid=0, num_walks=0))
    with pytest.raises(ValueError, match="source"):
        sched.submit(QueryRequest(rid=1, kind="ppr", source=g.n))
    with pytest.raises(ValueError, match="source"):
        sched.submit(QueryRequest(rid=2, kind="ppr", source=-1))
    with pytest.raises(ValueError, match="kind"):
        sched.submit(QueryRequest(rid=3, kind="pagerank"))
    assert not sched.queue
    with pytest.raises(ValueError, match="source"):
        query_counts(g, idx, plan_query(5, 0.5, max_steps=8),
                     jax.random.PRNGKey(0), source=g.n + 5)
