"""Fault tolerance: injected shard loss, degraded waves with widened
ε-bounds, bounded retry/backoff + failover supervision, and checkpoint
integrity (crash-during-write, corrupt-payload quarantine + rebuild).

The organizing claim is FrogWild's own: missing contributions are priced,
not fatal. A lost shard turns into walks that die at its endpoint range —
the surviving tallies renormalize and the result's ``epsilon_bound`` widens
to exactly the ε Theorem 1 certifies at N = walks executed (the anytime
accounting applied to loss instead of budget). Zero faults must be
byte-identical to the unfaulted scheduler; retries replay the same wave
key, so a successful retry is byte-identical too.
"""
import math
import os

import numpy as np
import pytest

from conftest import run_with_devices
from repro.checkpoint import (CheckpointCorruptError, Checkpointer,
                              latest_step, restore_checkpoint,
                              save_checkpoint)
from repro.config import (RuntimeConfig, ServingConfig, ShardConfig)
from repro.core import theory
from repro.distributed.faults import (FaultInjector, FaultPlan,
                                      WaveFailedError)
from repro.graph import chung_lu_powerlaw
from repro.query import (QueryRequest, QueryScheduler, ShardedWalkIndex,
                         WalkIndexConfig, build_walk_index,
                         load_or_repair_walk_index, load_walk_index,
                         save_walk_index_shard, shard_walk_index)
from repro.service import FrogWildService


S = 4          # serving shards in these tests
R, L = 6, 2    # walk-index geometry


def _graph_and_shards(n=256, seed=2):
    """A graph plus a genuinely S-way-partitioned index (build partitioning
    == serving shards, so single-shard rebuilds are byte-identical)."""
    g = chung_lu_powerlaw(n=n, avg_out_deg=6, seed=seed)
    idx = build_walk_index(g, WalkIndexConfig(
        segments_per_vertex=R, segment_len=L, num_shards=S, seed=seed))
    return g, shard_walk_index(idx, S)


def _sched(g, sh, plan=None, **kw):
    inj = FaultInjector(plan) if plan is not None else None
    kw.setdefault("max_walks", 512)
    kw.setdefault("max_queries", 4)
    kw.setdefault("max_steps", 12)
    return QueryScheduler(g, sh, seed=7, fault_injector=inj, **kw)


def _reqs():
    return [QueryRequest(rid=0, kind="topk", k=8, num_walks=900),
            QueryRequest(rid=1, kind="ppr", source=5, k=8, num_walks=900)]


def _drain(sched, reqs):
    for r in reqs:
        assert sched._submit(r).admitted
    return sorted(sched._drain(), key=lambda r: r.rid)


# --- zero faults: byte identity and bounded overhead -------------------------


def test_zero_faults_byte_identical_with_supervision_armed():
    """Empty fault plan + armed timeout: the supervised scheduler answers
    bit-for-bit what the unsupervised one does (the masked wave program
    with an all-False eviction mask is the unmasked program)."""
    g, sh = _graph_and_shards()
    plain = _drain(_sched(g, sh), _reqs())
    armed = _drain(_sched(g, sh, plan=FaultPlan(), wave_timeout_s=60.0),
                   _reqs())
    for a, b in zip(plain, armed):
        assert (a.vertices == b.vertices).all()
        assert (a.scores == b.scores).all()
        assert not b.degraded and b.walks_lost == 0 and b.shards_lost == ()


# --- shard loss: degraded waves, renormalization, widened bound --------------


def test_shard_loss_degrades_with_theorem1_widened_bound():
    """A shard lost mid-query: results flag ``degraded``, tallies
    renormalize by the walks that completed, and ``epsilon_bound`` is
    exactly Theorem 1 at N = executed (p_s = 1, p_cap = 0)."""
    g, sh = _graph_and_shards()
    sched = _sched(g, sh, plan=FaultPlan(shard_losses=((1, 2),)))
    results = _drain(sched, _reqs())
    assert sched.lost_shards == {2}
    lo, hi = sh.shard_size * 2, sh.shard_size * 3
    for r in results:
        assert r.degraded and r.shards_lost == (2,)
        assert r.walks_lost > 0
        assert r.num_walks + r.walks_lost == 900   # every walk accounted
        want = theory.epsilon_bound(sched.p_T, r.num_steps, 8, 0.1,
                                    r.num_walks, 1.0, 0.0)
        assert math.isclose(r.epsilon_bound, want)
        # renormalized by executed: scores are integer tallies over the
        # walks that completed, and no mass lands in the evicted range
        counts = r.scores * r.num_walks
        assert np.allclose(counts, np.rint(counts))
        for v, sc in zip(r.vertices, r.scores):
            assert not (sc > 0 and lo <= int(v) < hi)

    # vs the unfaulted run: the degraded one executed strictly fewer walks
    # (the difference is exactly what it reported lost)
    base = _drain(_sched(g, sh), _reqs())
    for rb, rd in zip(base, results):
        assert rb.num_walks == 900 and rd.num_walks == 900 - rd.walks_lost


def test_partial_carries_degraded_provenance():
    g, sh = _graph_and_shards()
    sched = _sched(g, sh, plan=FaultPlan(shard_losses=((0, 1),)))
    req = QueryRequest(rid=0, kind="topk", k=8, num_walks=2000)
    assert sched._submit(req).admitted
    sched.step_wave()
    p = sched.partial(0)
    assert p.degraded and p.shards_lost == (1,) and p.walks_lost > 0
    assert p.walks_done + p.walks_lost == 512    # one full wave allocated
    sched._drain()
    done = sched.partial(0)
    assert done.done and done.degraded and done.shards_lost == (1,)


def test_evicting_everything_is_unservable():
    g, sh = _graph_and_shards()
    sched = _sched(g, sh)
    for s in range(S - 1):
        sched._evict_shard(s, wave_no=0)
    with pytest.raises(WaveFailedError, match="no shard left"):
        sched._evict_shard(S - 1, wave_no=0)
    # a dense slab has no shard granularity to degrade to
    g2 = chung_lu_powerlaw(n=64, avg_out_deg=4, seed=3)
    dense = build_walk_index(g2, WalkIndexConfig(
        segments_per_vertex=R, segment_len=L, num_shards=1, seed=3))
    with pytest.raises(WaveFailedError, match="dense"):
        QueryScheduler(g2, dense, max_walks=64, max_steps=8,
                       seed=1)._evict_shard(0, wave_no=0)


# --- retry / backoff / timeout supervision -----------------------------------


def test_transient_faults_retried_byte_identically_then_bounded():
    """Retries replay the same wave key → a run that needed retries
    answers bit-for-bit what a fault-free run answers; one more injected
    failure than max_retries allows raises WaveFailedError."""
    g, sh = _graph_and_shards()
    base = _drain(_sched(g, sh), _reqs())
    sched = _sched(g, sh, plan=FaultPlan(transient_faults=((0, 2),)),
                   max_retries=2, backoff_base_s=0.001, backoff_max_s=0.002)
    retried = _drain(sched, _reqs())
    for a, b in zip(base, retried):
        assert (a.vertices == b.vertices).all()
        assert (a.scores == b.scores).all()
    assert [e.kind for e in sched.fault_log] == ["retry", "retry"]
    assert max(e.attempt for e in sched.fault_log) == 2

    broke = _sched(g, sh, plan=FaultPlan(transient_faults=((0, 3),)),
                   max_retries=2, backoff_base_s=0.001, backoff_max_s=0.002)
    assert broke._submit(QueryRequest(rid=0, num_walks=100)).admitted
    with pytest.raises(WaveFailedError, match="after 3 attempts"):
        broke.step_wave()
    # the failed wave left nothing behind: no tallies, budget intact
    a = next(iter(broke.active.values()))
    assert a.executed == 0 and a.remaining == 100 and a.counts.sum() == 0


def test_stall_detected_as_timeout_and_retried():
    """An injected slow wave overruns ``wave_timeout_s``: the result is
    discarded, the wave retried from the same key (byte-identical), and
    the faulted wall time never reaches the admission EMA."""
    g, sh = _graph_and_shards()
    base = _drain(_sched(g, sh), _reqs())
    sched = _sched(g, sh, plan=FaultPlan(stalls=((1, 0.3),)),
                   wave_timeout_s=0.25, wave_time_estimate_s=0.01,
                   backoff_base_s=0.001, backoff_max_s=0.002)
    out = _drain(sched, _reqs())
    for a, b in zip(base, out):
        assert (a.vertices == b.vertices).all()
        assert (a.scores == b.scores).all()
    assert any(e.kind == "retry" for e in sched.fault_log)
    # EMA robustness: the 0.3s stall (30× the estimate) was skipped, and
    # clean waves are clamped — the estimate cannot have been poisoned
    # anywhere near the stall.
    assert sched._wave_time < 0.1


def test_ema_skips_faulted_waves_and_clamps_outliers():
    g, sh = _graph_and_shards()
    sched = _sched(g, sh, plan=FaultPlan(stalls=((1, 0.5),)),
                   wave_time_estimate_s=0.02)   # no timeout: wave lands
    _drain(sched, _reqs())
    # the stalled wave completed and its tallies counted, but its 0.5s wall
    # time was excluded from the EMA (non-clean), so the estimate stays at
    # machine speed.
    assert sched._wave_time < 0.25
    assert any(e.kind == "stall" for e in
               (sched._injector.fired if sched._injector else []))


# --- capacity loss: admission + re-admission ---------------------------------


def test_eviction_shrinks_capacity_and_readmits_queued_slo_work():
    g, sh = _graph_and_shards()
    sched = _sched(g, sh, wave_time_estimate_s=1.0, max_queries=1)
    assert sched._effective_walks() == 512
    # slot 0 is busy; the SLO queries wait in the queue
    assert sched._submit(QueryRequest(rid=0, num_walks=512)).admitted
    sched._admit()
    # feasible at full capacity: 1024 walks / 512-per-wave in a 4-wave SLO
    ok = sched._submit(QueryRequest(rid=1, num_walks=1024, slo_s=4.0))
    dg = sched._submit(QueryRequest(rid=2, num_walks=1024, slo_s=4.0,
                                    allow_downgrade=True))
    assert ok.admitted and dg.admitted
    # lose 3 of 4 shards → effective throughput 128 walks/wave
    for s in (0, 1, 3):
        sched._evict_shard(s, wave_no=0)
    assert sched._effective_walks() == 128
    # rid=1 can no longer fit and was honestly rejected; rid=2 downgraded
    assert sched.query_state(1) == "rejected"
    reason = next(d.reason for d in sched.rejected if d.rid == 1)
    assert "shard" in reason
    q2 = next(e for e in sched.queue if e.req.rid == 2)
    assert q2.downgraded and q2.walks < 1024
    assert any(e.kind == "readmit" for e in sched.fault_log)


def test_cancel_mid_degraded_leaves_scheduler_serviceable():
    g, sh = _graph_and_shards()
    sched = _sched(g, sh, plan=FaultPlan(shard_losses=((0, 3),)))
    for r in _reqs():
        assert sched._submit(r).admitted
    sched.step_wave()
    assert sched.cancel(0)
    assert sched.query_state(0) == "cancelled"
    sched._drain()
    assert not sched.active and not sched.queue
    assert {r.rid for r in sched.finished} == {1}
    # still serviceable after cancellation + degradation
    assert sched._submit(QueryRequest(rid=9, num_walks=300)).admitted
    sched._drain()
    assert sched.query_state(9) == "finished"
    assert sched.result_for(9).degraded     # shard 3 stays evicted


# --- checkpoint integrity ----------------------------------------------------


def test_crash_during_write_never_exposes_torn_checkpoint(tmp_path):
    d = str(tmp_path / "ckpt")
    tree = {"a": np.arange(12, dtype=np.int32).reshape(3, 4)}
    save_checkpoint(d, 0, tree)
    # simulate a crash mid-write of step 1: the tmp dir exists, partially
    # populated, and was never renamed
    torn = os.path.join(d, "step_00000001.tmp")
    os.makedirs(torn)
    with open(os.path.join(torn, "arrays.npz"), "wb") as f:
        f.write(b"partial")
    assert latest_step(d) == 0                      # .tmp is invisible
    out = restore_checkpoint(d, 0, {"a": np.zeros((3, 4), np.int32)})
    assert (np.asarray(out["a"]).reshape(3, 4) == tree["a"]).all()


def test_corrupt_and_truncated_payloads_are_detected(tmp_path):
    d = str(tmp_path / "ckpt")
    tree = {"a": np.arange(4096, dtype=np.int32)}
    save_checkpoint(d, 0, tree)
    payload = os.path.join(d, "step_00000000", "arrays.npz")
    like = {"a": np.zeros(4096, np.int32)}

    data = bytearray(open(payload, "rb").read())
    data[len(data) // 2] ^= 0xFF                    # silent bit flip
    open(payload, "wb").write(bytes(data))
    with pytest.raises(CheckpointCorruptError, match="step_00000000"):
        restore_checkpoint(d, 0, like)

    save_checkpoint(d, 0, tree)
    size = os.path.getsize(payload)
    with open(payload, "r+b") as f:
        f.truncate(size // 2)                       # torn write
    with pytest.raises(CheckpointCorruptError):
        restore_checkpoint(d, 0, like)


def test_async_checkpoint_write_failure_surfaces_at_wait(tmp_path):
    victim = tmp_path / "not_a_dir"
    victim.write_text("a file where the checkpointer wants a directory")
    ck = Checkpointer(str(victim))
    ck.save_async(0, {"a": np.zeros(3)})
    with pytest.raises(RuntimeError, match="background checkpoint write"):
        ck.wait()
    ck.wait()                                       # error is consumed


def test_corrupt_shards_quarantined_and_rebuilt_byte_identically(tmp_path):
    """The repair loader: corrupt / truncated / missing shard checkpoints
    are quarantined and rebuilt with the original build's key stream —
    byte-identical blocks, healthy shards never re-walked."""
    g, sh = _graph_and_shards()
    d = str(tmp_path / "walk_index")
    for s in range(S):
        save_walk_index_shard(d, s, S, g.n, sh.blocks[s], sh.segment_len,
                              sh.seed)
    inj = FaultInjector(FaultPlan(corrupt_ckpt_shards=(1,),
                                  truncate_ckpt_shards=(3,)))
    assert len(inj.mangle_checkpoints(d)) == 2

    # the plain loader refuses, actionably
    with pytest.raises(CheckpointCorruptError) as ei:
        load_walk_index(d, reassemble=False)
    msg = str(ei.value)
    assert "shard_0001" in msg                    # names the broken dir
    assert f"R={R}" in msg and f"L={L}" in msg    # and the expected (R, L)

    cfg = WalkIndexConfig(segments_per_vertex=R, segment_len=L,
                          num_shards=S, seed=sh.seed)
    fixed = load_or_repair_walk_index(d, g, cfg, reassemble=False)
    assert isinstance(fixed, ShardedWalkIndex)
    assert (np.asarray(fixed.blocks) == np.asarray(sh.blocks)).all()
    quarantined = [x for x in os.listdir(d) if x.startswith("quarantine")]
    assert sorted(quarantined) == ["quarantine.shard_0001",
                                   "quarantine.shard_0003"]
    # and the repaired layout round-trips through the plain loader
    again = load_walk_index(d, reassemble=False)
    assert (np.asarray(again.blocks) == np.asarray(sh.blocks)).all()

    # a missing shard dir is likewise rebuilt in place
    import shutil
    shutil.rmtree(os.path.join(d, "shard_0002"))
    fixed2 = load_or_repair_walk_index(d, g, cfg, reassemble=False)
    assert (np.asarray(fixed2.blocks) == np.asarray(sh.blocks)).all()


# --- the service front door --------------------------------------------------


def _service_config(tmp=None, faults=None):
    return RuntimeConfig(
        runtime=ShardConfig(num_shards=S, seed=3),
        serving=ServingConfig(segments_per_vertex=R, segment_len=L,
                              build_shards=S, max_walks=512, max_queries=4,
                              max_steps=12, checkpoint_dir=tmp),
        faults=faults)


def test_service_serves_degraded_and_exposes_fault_provenance():
    g = chung_lu_powerlaw(n=256, avg_out_deg=6, seed=2)
    svc = FrogWildService.open(
        g, _service_config(faults=FaultPlan(shard_losses=((1, 0),))))
    r = svc.topk(k=8, num_walks=1200, early_stop=False).result()
    assert r.degraded and r.shards_lost == (0,)
    want = theory.epsilon_bound(svc.config.p_T, r.num_steps, 8, 0.1,
                                r.num_walks, 1.0, 0.0)
    assert math.isclose(r.epsilon_bound, want)
    assert svc.lost_shards == frozenset({0})
    assert any(e.kind == "shard_loss" for e in svc.fault_log)


def test_service_repairs_mangled_checkpoints_before_serving(tmp_path):
    g, sh = _graph_and_shards()
    d = str(tmp_path / "walk_index")
    for s in range(S):
        save_walk_index_shard(d, s, S, g.n, sh.blocks[s], sh.segment_len,
                              sh.seed)
    svc = FrogWildService.open(
        g, _service_config(tmp=d, faults=FaultPlan(corrupt_ckpt_shards=(2,))))
    idx = svc.ensure_index()
    assert isinstance(idx, ShardedWalkIndex)
    assert (np.asarray(idx.blocks) == np.asarray(sh.blocks)).all()
    assert [x for x in os.listdir(d) if x.startswith("quarantine")] \
        == ["quarantine.shard_0002"]


# --- mesh failover (subprocess: needs multiple devices) ----------------------


def test_mesh_timeout_fails_over_to_host_loop_byte_identically():
    """A mesh whose waves keep timing out fails over once to the host-loop
    dispatch of the identical per-shard program — answers byte-identical
    to a scheduler that ran the host loop from the start."""
    run_with_devices("""
import numpy as np
from repro.distributed.faults import FaultInjector, FaultPlan
from repro.distributed.runtime import ShardRuntime
from repro.graph import chung_lu_powerlaw
from repro.query import (QueryRequest, QueryScheduler, WalkIndexConfig,
                         build_walk_index, shard_walk_index)

S, R, L = 4, 6, 2
g = chung_lu_powerlaw(n=256, avg_out_deg=6, seed=2)
idx = build_walk_index(g, WalkIndexConfig(
    segments_per_vertex=R, segment_len=L, num_shards=S, seed=2))
sh = shard_walk_index(idx, S)

def drain(sched):
    for rid in (0, 1):
        kind = "topk" if rid == 0 else "ppr"
        assert sched._submit(QueryRequest(
            rid=rid, kind=kind, source=5, k=8, num_walks=900)).admitted
    return sorted(sched._drain(), key=lambda r: r.rid)

loop = QueryScheduler(g, sh, max_walks=512, max_queries=4, max_steps=12,
                      seed=7, runtime=ShardRuntime(num_shards=S, mesh=None))
assert not loop.runtime.is_mesh
base = drain(loop)

mesh_rt = ShardRuntime.acquire(S)
assert mesh_rt.is_mesh
# wave 0 hangs through the mesh's whole retry budget (1 + max_retries
# attempts) -> failover to the host loop, whose first attempt succeeds
inj = FaultInjector(FaultPlan(wave_timeouts=((0, 2),)))
sched = QueryScheduler(g, sh, max_walks=512, max_queries=4, max_steps=12,
                       seed=7, runtime=mesh_rt, fault_injector=inj,
                       max_retries=1, backoff_base_s=0.001,
                       backoff_max_s=0.002)
out = drain(sched)
assert sched._failed_over and not sched.runtime.is_mesh
assert any(e.kind == "failover" for e in sched.fault_log)
for a, b in zip(base, out):
    assert (a.vertices == b.vertices).all()
    assert (a.scores == b.scores).all()
    assert not b.degraded
print("failover-ok")
""", n_devices=4)
