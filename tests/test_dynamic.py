"""Dynamic graphs: mutation semantics, invalidation soundness, incremental
refresh byte-identity, epoch'd checkpoints, and two-epoch serving."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import (RuntimeConfig, ServingConfig, ShardConfig,
                          WalkIndexConfig)
from repro.dynamic import (MutationBatch, MutationLog, apply_mutations,
                           invalidate_segments, list_epochs,
                           load_epoch_index, refresh_walk_index,
                           save_epoch_index)
from repro.graph.csr import CSRGraph, load_graph, save_graph
from repro.graph.generators import uniform_random
from repro.query.index import (_build_walk_index, load_or_repair_walk_index,
                               save_walk_index, segment_mask_block_size,
                               shard_walk_index)
from repro.service import FrogWildService


def _cfg(R=4, L=3, S=2):
    return WalkIndexConfig(segments_per_vertex=R, segment_len=L,
                           num_shards=S)


# --- mutation application ----------------------------------------------------


def test_apply_mutations_semantics():
    g = uniform_random(64, 4.0, seed=1)
    v = 5
    succ = list(g.successors(v))
    batch = MutationBatch.edges(insert=[(7, 30), (v, 11)],
                                delete=[(v, succ[0])])
    g2, changed = apply_mutations(g, batch)
    assert g2.epoch == g.epoch + 1
    assert g2.mutation_offset == g.mutation_offset + 3
    assert set(changed) == {5, 7}
    # delete removes the FIRST occurrence; insert appends at the end
    assert list(g2.successors(v)) == succ[1:] + [11]
    assert list(g2.successors(7)) == list(g.successors(7)) + [30]
    # untouched vertices keep their successor lists verbatim (order incl.)
    for u in range(g.n):
        if u not in (5, 7):
            assert np.array_equal(g.successors(u), g2.successors(u))
    # the original graph object is untouched (epochs are immutable)
    assert list(g.successors(v)) == succ and g.epoch == 0


def test_apply_mutations_loud_errors_and_dangling():
    g = uniform_random(32, 3.0, seed=2)
    absent = next(d for d in range(g.n)
                  if d not in set(int(x) for x in g.successors(0)))
    with pytest.raises(ValueError, match="absent edge"):
        apply_mutations(g, MutationBatch.edges(delete=[(0, absent)]))
    with pytest.raises(ValueError, match="outside"):
        apply_mutations(g, MutationBatch.edges(insert=[(0, g.n)]))
    # deleting every out-edge triggers the build_csr dangling repair
    v = 3
    batch = MutationBatch.edges(delete=[(v, int(d)) for d in g.successors(v)])
    g2, changed = apply_mutations(g, batch)
    assert v in changed
    t = (v * 2654435761 + 12345) % g.n
    if t == v:
        t = (t + 1) % g.n
    assert list(g2.successors(v)) == [t]
    assert int(np.asarray(g2.out_deg).min()) > 0


def test_mutation_log_replay():
    g = uniform_random(48, 4.0, seed=3)
    b1 = MutationBatch.edges(insert=[(1, 2)])
    b2 = MutationBatch.edges(insert=[(9, 9)], delete=[(1, 2)])
    log = MutationLog()
    assert log.append(b1) == 1 and log.append(b2) == 2
    assert log.offset == 3
    g2, changed = log.replay(g)
    assert g2.epoch == 2 and g2.mutation_offset == 3
    assert {1, 9} <= set(changed)
    # resume mid-log: a graph already at epoch 1 replays only batch 2
    g1, _ = apply_mutations(g, b1)
    g2b, _ = log.replay(g1)
    assert np.array_equal(np.asarray(g2b.col_idx), np.asarray(g2.col_idx))
    with pytest.raises(ValueError, match="outside log range"):
        log.replay(CSRGraph(n=g.n, row_ptr=g.row_ptr, col_idx=g.col_idx,
                            out_deg=g.out_deg, epoch=7))


# --- invalidation soundness + refresh byte-identity (property-checked) -------


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_invalidation_sound_and_refresh_equals_rebuild(seed):
    """The acceptance property: segments NOT marked stale are byte-identical
    under the new graph, and the refreshed slab equals a from-scratch build
    at the new epoch — endpoints and visited masks both."""
    rng = np.random.default_rng(seed)
    g = uniform_random(96, 4.0, seed=seed)
    cfg = _cfg()
    idx = _build_walk_index(g, cfg)
    k = int(rng.integers(1, 4))
    ins = [(int(rng.integers(g.n)), int(rng.integers(g.n)))
           for _ in range(k)]
    dels = []
    for _ in range(k):
        v = int(rng.integers(g.n))
        succ = g.successors(v)
        dels.append((v, int(succ[rng.integers(len(succ))])))
    # a delete can name an edge twice; keep the batch consistent
    batch = MutationBatch.edges(insert=ins, delete=list(dict.fromkeys(dels)))
    g2, changed = apply_mutations(g, batch)
    stale = invalidate_segments(idx, changed)
    full = _build_walk_index(g2, cfg)
    old_ep, new_ep = np.asarray(idx.endpoints), np.asarray(full.endpoints)
    assert np.array_equal(old_ep[~stale], new_ep[~stale]), (
        "unsound invalidation: a non-stale segment changed")
    new_idx, report = refresh_walk_index(idx, g2, changed, chunk=17)
    assert np.array_equal(np.asarray(new_idx.endpoints), new_ep)
    assert np.array_equal(new_idx.visited_blocks, full.visited_blocks)
    assert new_idx.graph_epoch == 1
    assert report.segments_rebuilt == int(stale.sum())
    assert report.stale_rows == len(np.unique(np.nonzero(stale)[0]))


def test_refresh_sharded_roundtrip_and_sparsity():
    """A sharded slab refreshes in place (same shard count) and a localized
    mutation invalidates far fewer segments than the slab holds."""
    g = uniform_random(256, 4.0, seed=5)
    cfg = _cfg(R=4, L=2, S=4)
    sharded = shard_walk_index(_build_walk_index(g, cfg), 4)
    # n = 256 ⇒ one vertex per mask block: invalidation is exact
    assert segment_mask_block_size(g.n) == 1
    v = 17
    batch = MutationBatch.edges(insert=[(v, 200)])
    g2, changed = apply_mutations(g, batch)
    new_idx, report = refresh_walk_index(sharded, g2, changed)
    assert new_idx.num_shards == 4
    full = shard_walk_index(_build_walk_index(g2, cfg), 4)
    assert np.array_equal(new_idx.blocks, full.blocks)
    assert np.array_equal(new_idx.visited_blocks, full.visited_blocks)
    # exactly the segments that sourced at — or walked through — v
    assert report.segments_rebuilt < report.total_segments // 4


def test_refresh_refuses_mismatched_pairs():
    g = uniform_random(64, 4.0, seed=6)
    idx = _build_walk_index(g, _cfg())
    with pytest.raises(ValueError, match="not ahead"):
        refresh_walk_index(idx, g, np.array([1]))
    legacy = _build_walk_index(g, _cfg())
    legacy = type(legacy)(endpoints=legacy.endpoints,
                          segment_len=legacy.segment_len, seed=legacy.seed,
                          visited_blocks=None)
    g2, changed = apply_mutations(g, MutationBatch.edges(insert=[(0, 1)]))
    with pytest.raises(ValueError, match="visited_blocks"):
        refresh_walk_index(legacy, g2, changed)


# --- epoch provenance: graph npz + walk-index checkpoints --------------------


def test_graph_npz_epoch_roundtrip(tmp_path):
    g = uniform_random(32, 3.0, seed=7)
    g2, _ = apply_mutations(g, MutationBatch.edges(insert=[(0, 5)]))
    p = save_graph(str(tmp_path / "g.npz"), g2)
    loaded = load_graph(p)
    assert loaded.epoch == 1 and loaded.mutation_offset == 1
    assert np.array_equal(np.asarray(loaded.col_idx), np.asarray(g2.col_idx))
    # pre-epoch files (no epoch leaf) load at the never-mutated provenance
    gn = g.to_numpy()
    np.savez_compressed(str(tmp_path / "legacy.npz"), n=np.int64(g.n),
                        row_ptr=gn.row_ptr, col_idx=gn.col_idx)
    legacy = load_graph(str(tmp_path / "legacy.npz"))
    assert legacy.epoch == 0 and legacy.mutation_offset == 0


def test_epoch_checkpoint_roundtrip_and_loud_mismatch(tmp_path):
    g = uniform_random(64, 4.0, seed=8)
    idx = _build_walk_index(g, _cfg())
    g2, changed = apply_mutations(g, MutationBatch.edges(insert=[(3, 4)]))
    idx2, _ = refresh_walk_index(idx, g2, changed)
    d = str(tmp_path / "ckpt")
    save_epoch_index(d, idx)
    save_epoch_index(d, idx2)
    assert list_epochs(d) == [0, 1]
    for epoch, want in ((0, idx), (1, idx2)):
        got = load_epoch_index(d, epoch)
        assert got.graph_epoch == epoch
        assert np.array_equal(np.asarray(got.endpoints),
                              np.asarray(want.endpoints))
        assert np.array_equal(got.visited_blocks, want.visited_blocks)
        assert got.mutation_offset == want.mutation_offset
    # sharded layout round-trips too
    sh = shard_walk_index(idx2, 2)
    d2 = str(tmp_path / "ckpt_sharded")
    save_epoch_index(d2, sh)
    got = load_epoch_index(d2, 1, reassemble=False)
    assert got.num_shards == 2 and got.graph_epoch == 1
    assert np.array_equal(got.blocks, sh.blocks)
    with pytest.raises(FileNotFoundError):
        load_epoch_index(d, 5)
    # a slab whose manifest claims a different epoch fails loudly
    from repro.dynamic import epoch_dir
    os.rename(epoch_dir(d, 1), epoch_dir(d, 3))
    with pytest.raises(ValueError, match="claims graph_epoch"):
        load_epoch_index(d, 3)


def test_load_or_repair_refuses_stale_epoch(tmp_path):
    g = uniform_random(64, 4.0, seed=9)
    cfg = _cfg(S=2)
    d = str(tmp_path / "shards")
    sh = shard_walk_index(_build_walk_index(g, cfg), 2)
    save_epoch_index(d, sh)          # epoch_000000/shard_*/...
    g2, _ = apply_mutations(g, MutationBatch.edges(insert=[(0, 1)]))
    from repro.dynamic import epoch_dir
    with pytest.raises(ValueError, match="graph epoch"):
        load_or_repair_walk_index(epoch_dir(d, 0), g2, cfg)


def test_service_refuses_stale_checkpoint(tmp_path):
    g = uniform_random(64, 4.0, seed=10)
    cfg = _cfg(S=1)
    d = str(tmp_path / "ckpt")
    save_walk_index(d, _build_walk_index(g, cfg))
    g2, _ = apply_mutations(g, MutationBatch.edges(insert=[(0, 1)]))
    rc = RuntimeConfig(
        runtime=ShardConfig(num_shards=1),
        serving=ServingConfig(segments_per_vertex=4, segment_len=3,
                              build_shards=1, checkpoint_dir=d))
    svc = FrogWildService.open(g2, rc)
    with pytest.raises(ValueError, match="stale slab|graph epoch"):
        svc.ensure_index()


# --- two-epoch serving (epoch pinning under concurrency) ---------------------


def _service(g, S=2, **serving_kw):
    rc = RuntimeConfig(
        runtime=ShardConfig(num_shards=S),
        serving=ServingConfig(segments_per_vertex=6, segment_len=3,
                              build_shards=S, max_walks=256, max_queries=2,
                              max_steps=32, **serving_kw))
    return FrogWildService.open(g, rc)


def test_epoch_pinning_under_concurrency():
    """A query in flight across an epoch commit finishes byte-identically
    to a run where no mutation ever happened, while new admissions land on
    the new epoch."""
    g = uniform_random(128, 4.0, seed=11)
    batch = MutationBatch.edges(insert=[(2, 100), (70, 3)])

    # control: same query on a never-mutated service
    ctrl = _service(g)
    hc = ctrl.topk(k=8, epsilon=0.5, delta=0.2, num_walks=4 * 256,
                   early_stop=False)
    rc_ = hc.result()

    svc = _service(g)
    h1 = svc.topk(k=8, epsilon=0.5, delta=0.2, num_walks=4 * 256,
                  early_stop=False)
    h1.poll()                         # in flight (spans multiple waves)
    assert h1.status() in ("active", "queued")
    report = svc.apply_mutations(batch)
    assert report.epoch == 1
    assert svc.graph_epoch == 1
    assert svc.retiring_epochs == [0]
    h2 = svc.topk(k=8, epsilon=0.5, delta=0.2)
    r1 = h1.result()
    r2 = h2.result()
    assert r1.epoch == 0 and r2.epoch == 1
    # byte-identical to the never-mutated control
    assert np.array_equal(r1.vertices, rc_.vertices)
    assert np.array_equal(r1.scores, rc_.scores)
    assert r1.num_walks == rc_.num_walks
    # the retired epoch is released once its last pinned query settled
    svc.step()
    assert svc.retiring_epochs == []
    assert svc.serving_stats().epoch == 1
    svc.close()
    ctrl.close()


def test_service_apply_mutations_persists_epoch(tmp_path):
    g = uniform_random(96, 4.0, seed=12)
    d = str(tmp_path / "ckpt")
    svc = _service(g, checkpoint_dir=d)
    svc.ensure_index()
    report = svc.apply_mutations(MutationBatch.edges(insert=[(1, 2)]))
    assert report.epoch == 1
    assert list_epochs(d) == [1]
    got = load_epoch_index(d, 1, reassemble=False)
    assert np.array_equal(got.blocks, svc.ensure_index().blocks)
    svc.close()


def test_commit_epoch_refuses_mismatches():
    g = uniform_random(64, 4.0, seed=13)
    svc = _service(g)
    idx = svc.ensure_index()
    g2, changed = apply_mutations(g, MutationBatch.edges(insert=[(0, 1)]))
    with pytest.raises(ValueError, match="does not match graph epoch"):
        svc.commit_epoch(g2, idx)     # stale slab at epoch 0
    small = uniform_random(32, 3.0, seed=13)
    with pytest.raises(ValueError, match="vertex count"):
        svc.commit_epoch(small, idx)
    svc.close()
