"""Direct coverage for ``core/theory.py`` (previously untested) and the
quickstart round-trip: the Remark-6 suggestions must actually drive the
Theorem-1 ε below the target they were derived for.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (FrogWildConfig, frogwild, normalized_mass_captured,
                        power_iteration, theory)
from repro.graph import chung_lu_powerlaw


@pytest.mark.parametrize("mu_k", [0.05, 0.1, 0.3, 0.6])
def test_suggested_steps_drives_mixing_below_quarter_target(mu_k):
    p_T = 0.15
    t = theory.suggested_steps(mu_k, p_T)
    assert theory.mixing_term(p_T, t) <= mu_k / 4.0 + 1e-12
    # and t is not wastefully large: one step fewer would overshoot
    if t > 1:
        assert theory.mixing_term(p_T, t - 1) > mu_k / 4.0


@pytest.mark.parametrize("mu_k,k,delta", [
    (0.1, 20, 0.1), (0.3, 5, 0.05), (0.5, 100, 0.2),
])
def test_suggested_frogs_drives_sampling_below_quarter_target(mu_k, k, delta):
    N = theory.suggested_frogs(k, mu_k, delta)
    # p_s = 1: the sampling term is exactly the 1/N part
    assert theory.sampling_term(k, delta, N, 1.0, 0.0) <= mu_k / 4.0 + 1e-12
    # and N is tight up to rounding: half the frogs would overshoot
    assert theory.sampling_term(k, delta, N // 2, 1.0, 0.0) > mu_k / 4.0


def test_remark6_roundtrip_epsilon_bound_below_target_mass():
    """The (t, N) pair suggested for a target μ_k gives ε ≤ μ_k/2 < μ_k —
    i.e. Theorem 1 then guarantees the estimator captures positive mass."""
    p_T, delta, k = 0.15, 0.1, 20
    for mu_k in (0.08, 0.2, 0.4):
        t = theory.suggested_steps(mu_k, p_T)
        N = theory.suggested_frogs(k, mu_k, delta)
        eps = theory.epsilon_bound(p_T, t, k, delta, N, p_s=1.0, p_cap=0.0)
        assert eps <= mu_k / 2.0 + 1e-12, (mu_k, t, N, eps)


def test_epsilon_bound_monotonicity():
    base = dict(p_T=0.15, t=8, k=10, delta=0.1, N=10_000, p_s=0.8,
                p_cap=1e-4)

    def eb(**kw):
        a = {**base, **kw}
        return theory.epsilon_bound(a["p_T"], a["t"], a["k"], a["delta"],
                                    a["N"], a["p_s"], a["p_cap"])

    assert eb(t=16) < eb()          # more steps → smaller mixing term
    assert eb(N=100_000) < eb()     # more frogs → smaller sampling term
    assert eb(p_s=1.0) < eb()       # more sync → smaller collision term
    assert eb(k=40) > eb()          # larger k → looser union bound
    assert eb(delta=0.01) > eb()    # higher confidence → looser ε


def test_p_cap_and_pi_inf_bounds():
    # Theorem 2 shape: linear in t, anchored at 1/n
    n, p_T, pi_inf = 10_000, 0.15, 1e-3
    b1 = theory.p_cap_bound(n, 1, pi_inf, p_T)
    b4 = theory.p_cap_bound(n, 4, pi_inf, p_T)
    assert b1 == pytest.approx(1.0 / n + pi_inf / p_T)
    assert b4 - b1 == pytest.approx(3 * pi_inf / p_T)
    # Proposition 7: ‖π‖∞ bound decreasing in n, equals n^{-γ}
    assert theory.pi_inf_powerlaw_bound(10_000) == pytest.approx(0.01)
    assert (theory.pi_inf_powerlaw_bound(10**6)
            < theory.pi_inf_powerlaw_bound(10**4))


def test_quickstart_roundtrip_on_graph():
    """The examples/quickstart.py flow, asserted: run FrogWild with the
    suggested (t, N) for the graph's measured μ_k and check the captured
    mass beats the 1 − ε/μ_k floor Theorem 1 promises (here ε ≤ μ_k/2)."""
    k, delta = 10, 0.1
    g = chung_lu_powerlaw(n=4096, avg_out_deg=12, seed=0)
    pi = power_iteration(g, num_iters=60)
    _, idx = jax.lax.top_k(pi, k)
    mu_k = float(pi[idx].sum())
    t = theory.suggested_steps(mu_k)
    N = theory.suggested_frogs(k, mu_k, delta)
    eps = theory.epsilon_bound(0.15, t, k, delta, N, 1.0, 0.0)
    assert eps <= mu_k / 2.0
    res = frogwild(g, FrogWildConfig(num_frogs=N, num_steps=t), seed=0)
    m = float(normalized_mass_captured(res.pi_hat, pi, k))
    assert m >= 1.0 - eps / mu_k, (mu_k, t, N, eps, m)
