"""API-surface snapshot: the public names of the service-facing packages
are pinned here, so a PR that grows / shrinks the surface has to say so in
a diff of this file (wired into ``scripts/ci_tier1.sh``).

Pinning rules: ``__all__`` must exist, match the snapshot exactly, and
every listed name must resolve. ``repro.configs`` is additionally pinned
to the graph family only — the LLM template registry must stay off the
public surface (ISSUE-5 satellite).
"""
import pytest

import repro
import repro.configs
import repro.dynamic
import repro.gateway
import repro.query
import repro.service

SURFACE = {
    repro: [
        "FrogWildService",
        "Gateway",
        "KernelConfig",
        "QueryHandle",
        "RuntimeConfig",
        "ServingConfig",
        "ShardConfig",
    ],
    repro.service: [
        "FrogWildService",
        "JoinedQueryHandle",
        "KernelConfig",
        "QueryHandle",
        "QueryPartial",
        "RuntimeConfig",
        "ServingConfig",
        "ShardConfig",
        "batch_pagerank",
        "build_index",
    ],
    repro.gateway: [
        "CacheEntry",
        "Certificate",
        "Gateway",
        "GatewayHTTPServer",
        "GatewayHandle",
        "GatewayMetrics",
        "GatewayOverloadError",
        "NoReplicaAvailable",
        "ReplicaPool",
        "ResultCache",
        "serve_http",
    ],
    repro.query: [
        "AdmissionDecision",
        "QueryPartial",
        "QueryPlan",
        "QueryRequest",
        "QueryResult",
        "QueryScheduler",
        "RejectReason",
        "SchedulerStats",
        "ShardedWalkIndex",
        "WalkIndex",
        "WalkIndexConfig",
        "build_walk_index",
        "build_walk_index_sharded",
        "load_or_repair_walk_index",
        "load_walk_index",
        "plan_query",
        "query_counts",
        "rebuild_shard_blocks",
        "sample_walk_lengths",
        "save_walk_index",
        "save_walk_index_shard",
        "shard_walk_index",
        "walk_wave",
    ],
    repro.dynamic: [
        "MutationBatch",
        "MutationLog",
        "RefreshReport",
        "apply_mutations",
        "dirty_block_mask",
        "epoch_dir",
        "invalidate_segments",
        "list_epochs",
        "load_epoch_index",
        "refresh_walk_index",
        "save_epoch_index",
    ],
    repro.configs: [
        "GRAPHS",
        "GraphConfig",
        "LIVEJOURNAL_BENCH",
        "LIVEJOURNAL_FULL",
        "TWITTER_BENCH",
        "TWITTER_FULL",
        "get_graph_config",
    ],
}


@pytest.mark.parametrize("mod", SURFACE, ids=lambda m: m.__name__)
def test_public_surface_pinned(mod):
    assert sorted(mod.__all__) == SURFACE[mod], (
        f"{mod.__name__}.__all__ changed — if intentional, update the "
        f"snapshot in tests/test_api_surface.py")
    for name in mod.__all__:
        assert getattr(mod, name, None) is not None, (mod.__name__, name)


def test_llm_registry_off_the_public_surface():
    """The LLM arch registry is a template leftover: reachable explicitly
    (model smoke tests / launch tooling), but not exported."""
    assert "ARCHS" not in repro.configs.__all__
    assert "get_config" not in repro.configs.__all__
    import repro.configs.registry as registry
    assert sorted(registry.__all__) == ["GRAPHS", "GraphConfig",
                                        "get_graph_config"]


def test_legacy_entry_points_are_deprecated_shims():
    """Every legacy entry point named in ISSUE-5 still exists and warns."""
    import warnings

    from repro.core import frogwild_run
    from repro.engine import distributed_frogwild
    from repro.query import (QueryScheduler, build_walk_index,
                             build_walk_index_sharded)

    for fn in (frogwild_run, distributed_frogwild, build_walk_index,
               build_walk_index_sharded, QueryScheduler.submit,
               QueryScheduler.run):
        assert "Deprecated" in (fn.__doc__ or ""), fn
