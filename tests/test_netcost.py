"""Unit coverage for the ``engine/netcost.py`` wire-byte cost models backing
the Fig-1c / Fig-8 reproductions: measured-counter accounting, the analytic
frog model's decay and open-channel scaling, and the dense GraphLab-PR
baseline it is contrasted against.
"""
import numpy as np
import pytest

from repro.engine.netcost import (FROG_PAYLOAD_BYTES, RANK_BYTES,
                                  SYNC_MSG_BYTES, BytesReport,
                                  frogwild_bytes_measured,
                                  frogwild_bytes_model, pagerank_bytes_model)


def test_measured_bytes_exact_accounting():
    sent = np.array([100, 50, 25])
    syncs = np.array([40, 20, 10])
    rep = frogwild_bytes_measured(sent, syncs)
    want = sent * FROG_PAYLOAD_BYTES + syncs * SYNC_MSG_BYTES
    assert np.allclose(rep.per_step, want)
    assert rep.total == pytest.approx(want.sum())
    assert len(rep.per_step) == 3
    assert "MB total" in str(rep) and "(3 steps)" in str(rep)


def test_model_alive_decay_and_first_step():
    N, t, p_T, p_s, S, m = 10_000, 6, 0.15, 0.7, 16, 3.0
    rep = frogwild_bytes_model(N, t, p_T, p_s, S, avg_mirrors=m)
    assert len(rep.per_step) == t
    alive0 = N * (1 - p_T)
    want0 = alive0 * FROG_PAYLOAD_BYTES + alive0 * p_s * m * SYNC_MSG_BYTES
    assert rep.per_step[0] == pytest.approx(want0)
    # alive frogs decay geometrically ⇒ per-step bytes do too
    ratios = rep.per_step[1:] / rep.per_step[:-1]
    assert np.allclose(ratios, 1 - p_T)


def test_model_open_channel_accounting_scales_with_p_s():
    """p_s throttles exactly the sync-message term: the payload term is
    p_s-independent and the sync term is linear in p_s."""
    N, t, p_T, S = 50_000, 5, 0.15, 8
    full = frogwild_bytes_model(N, t, p_T, 1.0, S)
    half = frogwild_bytes_model(N, t, p_T, 0.5, S)
    none = frogwild_bytes_model(N, t, p_T, 0.0, S)
    payload = none.total                       # p_s = 0 ⇒ payload only
    sync_full = full.total - payload
    sync_half = half.total - payload
    assert sync_full > 0
    assert sync_half == pytest.approx(0.5 * sync_full)


def test_pagerank_dense_baseline_formula():
    n, iters, S = 100_000, 12, 16
    rep = pagerank_bytes_model(n, iters, S)
    per_iter = 2.0 * (S - 1) * n * RANK_BYTES
    assert np.allclose(rep.per_step, per_iter)
    assert rep.total == pytest.approx(iters * per_iter)


def test_frogwild_beats_dense_sync_at_paper_scale():
    """Fig 1c's qualitative claim: frog traffic (N ≪ n walkers, p_s < 1) is
    orders of magnitude below dense per-iteration rank synchronization."""
    n, S = 4_847_571, 16                      # LiveJournal-scale
    frog = frogwild_bytes_model(N=800_000, t=4, p_T=0.15, p_s=0.7, S=S)
    dense = pagerank_bytes_model(n, num_iters=10, S=S)
    assert frog.total < dense.total / 10


def test_bytes_report_is_plain_dataclass():
    rep = BytesReport(total=2.5e6, per_step=np.array([2.5e6]))
    assert str(rep).startswith("2.500 MB total")
