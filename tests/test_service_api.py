"""Service-facade contract tests (PR 5).

Three claims are enforced here:

* **Back-compat**: the legacy entry points (``frogwild_run``,
  ``distributed_frogwild``, ``build_walk_index``, ``QueryScheduler.
  submit/run``) emit ``DeprecationWarning`` and return answers
  *byte-identical* to the service facade under one shared key stream —
  they are thin shims delegating through ``repro/service.py``, so the
  equality is structural, not parallel-edit discipline.

* **Anytime refinement**: ``QueryHandle.partial()`` snapshots carry a
  monotonically non-increasing Theorem-1 ``epsilon_bound``, and with a walk
  budget larger than the plan needs, early termination fires before the
  budget (and before ``max_waves``) on both the gathered and the sharded
  dispatch paths.

* **Queue-depth admission**: ``submit()`` charges an SLO for walks already
  admitted (queued + in-flight), not just the wave-time EMA.
"""
import dataclasses
import math
import os

import numpy as np
import jax
import pytest

from repro import (FrogWildService, KernelConfig, RuntimeConfig,
                   ServingConfig, ShardConfig)
from repro.config import (EngineConfig, FrogWildConfig, WalkIndexConfig)
from repro.core import frogwild_run
from repro.distributed.runtime import ShardRuntime
from repro.engine import build_distributed_graph, distributed_frogwild
from repro.graph import chung_lu_powerlaw
from repro.graph.csr import load_graph, save_graph
from repro.query import (QueryRequest, QueryScheduler, build_walk_index,
                         shard_walk_index)
from repro.query.index import _build_walk_index


def _graph(n=512, seed=2):
    return chung_lu_powerlaw(n=n, avg_out_deg=8, seed=seed)


def _rc(num_shards=1, **serving_kw):
    serving = dict(segments_per_vertex=12, segment_len=3, build_shards=2,
                   max_walks=512, max_queries=3, max_steps=32)
    serving.update(serving_kw)
    return RuntimeConfig(runtime=ShardConfig(num_shards=num_shards, seed=11),
                         serving=ServingConfig(**serving))


# --- back-compat shims -------------------------------------------------------


def test_frogwild_run_shim_byte_identical():
    g = _graph()
    cfg = FrogWildConfig(num_frogs=3000, num_steps=4, p_s=0.7,
                         erasure="channel", num_shards=4)
    key = jax.random.PRNGKey(5)
    with pytest.deprecated_call():
        legacy = frogwild_run(g, cfg, key)
    svc = FrogWildService.open(g, RuntimeConfig.from_frogwild(cfg))
    new = svc.pagerank(key=key)
    assert (np.asarray(legacy.counts) == np.asarray(new.counts)).all()
    assert int(new.counts.sum()) == cfg.num_frogs


def test_distributed_shim_byte_identical():
    g = _graph(n=256)
    ecfg = EngineConfig(num_frogs=2048, num_steps=3, p_s=0.5)
    mesh = ShardRuntime.acquire(1).require_mesh()
    dg = build_distributed_graph(g, 1)
    with pytest.deprecated_call():
        legacy = distributed_frogwild(dg, ecfg, mesh, seed=3)
    svc = FrogWildService.open(g, RuntimeConfig.from_engine(ecfg), mesh=mesh)
    new = svc.pagerank(seed=3)
    assert (np.asarray(legacy.counts) == np.asarray(new.counts)).all()
    assert legacy.overflow == new.overflow


def test_build_walk_index_shim_byte_identical():
    g = _graph(n=256)
    icfg = WalkIndexConfig(segments_per_vertex=6, segment_len=2,
                           num_shards=2, seed=4)
    with pytest.deprecated_call():
        legacy = build_walk_index(g, icfg)
    svc = FrogWildService.open(g, RuntimeConfig.from_walk_index(icfg))
    new = svc.ensure_index()
    assert (np.asarray(legacy.endpoints) == np.asarray(new.endpoints)).all()


@pytest.mark.parametrize("num_shards", [1, 4])
def test_scheduler_shims_match_service_handles(num_shards):
    """Legacy submit()/run() and service QueryHandles share one key stream
    → identical answers, on both the gathered and sharded dispatch."""
    g = _graph()
    rc = _rc(num_shards=num_shards)
    idx = _build_walk_index(g, rc.walk_index())
    svc = FrogWildService.open(g, rc)           # builds the same slab itself
    handles = []
    for i in range(4):
        if i % 3 == 2:
            handles.append(svc.ppr(17 * i + 1, k=5, epsilon=0.3,
                                   early_stop=False))
        else:
            handles.append(svc.topk(k=5, epsilon=0.3, early_stop=False))
    assert all(h.admitted for h in handles)
    results = {h.rid: h.result() for h in handles}

    sched = QueryScheduler(
        g, idx if num_shards <= 1 else shard_walk_index(idx, num_shards),
        max_walks=rc.serving.max_walks, max_queries=rc.serving.max_queries,
        max_steps=rc.serving.max_steps, seed=rc.runtime.seed)
    for i in range(4):
        kind = "ppr" if i % 3 == 2 else "topk"
        with pytest.deprecated_call():
            d = sched.submit(QueryRequest(rid=i, kind=kind,
                                          source=17 * i + 1, k=5,
                                          epsilon=0.3))
        assert d.admitted
    with pytest.deprecated_call():
        legacy = {r.rid: r for r in sched.run()}

    assert sorted(legacy) == sorted(results)
    for rid, lr in legacy.items():
        assert (lr.vertices == results[rid].vertices).all(), rid
        assert np.allclose(lr.scores, results[rid].scores), rid
        assert lr.epsilon_bound == results[rid].epsilon_bound


# --- anytime (ε, δ) refinement ----------------------------------------------


@pytest.mark.parametrize("num_shards", [1, 4])
def test_partial_bounds_monotone_and_early_termination(num_shards):
    g = _graph()
    svc = FrogWildService.open(g, _rc(num_shards=num_shards))
    budget = 8192                              # ≫ the ε = 0.4 plan's walks
    h = svc.topk(k=5, epsilon=0.4, delta=0.1, num_walks=budget)
    assert h.admitted and h.request.early_stop

    bounds = [h.partial().epsilon_bound]
    assert bounds[0] == math.inf               # queued: nothing tallied yet
    while not h.poll():
        bounds.append(h.partial().epsilon_bound)
    res = h.result()
    bounds.append(res.epsilon_bound)

    assert all(b1 >= b2 for b1, b2 in zip(bounds, bounds[1:])), bounds
    # early termination: bound met well before the budget drained
    budget_waves = -(-budget // svc.config.serving.max_walks)
    assert res.early_stopped
    assert res.num_walks < budget
    assert res.waves < budget_waves
    assert res.epsilon_bound <= 0.4
    # the walks executed genuinely certify the requested ε
    from repro.core import theory
    assert theory.epsilon_bound(0.15, res.num_steps, 5, 0.1,
                                res.num_walks, 1.0, 0.0) <= 0.4


def test_handle_poll_partial_result_cancel():
    g = _graph(n=256)
    svc = FrogWildService.open(g, _rc())
    h1 = svc.topk(k=5, epsilon=0.3, early_stop=False)
    h2 = svc.ppr(3, k=5, epsilon=0.3, early_stop=False)
    assert h1.status() == "queued" and not h1.done()
    h1.poll()                                  # one wave: both make progress
    p1, p2 = h1.partial(), h2.partial()
    assert p1.walks_done > 0 and p2.walks_done > 0
    assert p1.kind == "topk" and p2.kind == "ppr"
    assert h2.cancel()
    assert h2.status() == "cancelled" and h2.done()
    with pytest.raises(RuntimeError, match="cancelled"):
        h2.result()
    r1 = h1.result()
    assert r1.rid == h1.rid and len(r1.vertices) == 5
    assert not h1.cancel()                     # already finished
    # a finished handle's partial() reports done
    assert h1.partial().done


def test_rejected_handle_surface():
    g = _graph(n=256)
    rc = _rc(wave_time_estimate_s=1.0)
    svc = FrogWildService.open(g, rc)
    h = svc.topk(k=5, num_walks=4096, slo_s=2.0)   # needs 8 waves, 2 fit
    assert not h.admitted and h.status() == "rejected" and h.done()
    with pytest.raises(RuntimeError, match="rejected"):
        h.result()
    with pytest.raises(RuntimeError, match="rejected"):
        h.partial()
    assert not h.cancel()


# --- queue-depth admission (PR-4 leftover) -----------------------------------


def test_admission_charges_queue_depth():
    g = _graph(n=256)
    svc = FrogWildService.open(g, _rc(max_queries=4, max_steps=12,
                                      wave_time_estimate_s=1.0))
    sched = svc.scheduler
    # 1500 walks of deadline-carrying work queue up first (3 ≤ 3 waves)
    a = sched._submit(QueryRequest(rid=100, kind="topk", k=5,
                                   num_walks=1500, slo_s=3.0))
    assert a.admitted and not a.downgraded
    # alone, 1000 walks fit a 3 s SLO (2 ≤ 3 waves) — but the admitted
    # demand at earlier-or-equal deadlines outranks this request under
    # EDF: 2500 walks ⇒ 5 waves > 3 ⇒ reject.
    b = sched._submit(QueryRequest(rid=101, kind="topk", k=5,
                                   num_walks=1000, slo_s=3.0))
    assert not b.admitted and "queued ahead at earlier deadlines" in b.reason
    # with downgrade the query is clamped to the budget the backlog leaves
    c = sched._submit(QueryRequest(rid=102, kind="topk", k=5,
                                   num_walks=1000, slo_s=3.0,
                                   allow_downgrade=True))
    assert c.admitted and c.downgraded
    assert c.num_walks == 3 * 512 - 1500
    # no budget left at all ⇒ reject even with allow_downgrade
    d = sched._submit(QueryRequest(rid=103, kind="topk", k=5,
                                   num_walks=100, slo_s=3.5,
                                   allow_downgrade=True))
    assert not d.admitted
    results = {r.rid: r for r in svc.drain()}
    assert sorted(results) == [100, 102]
    assert results[102].num_walks == c.num_walks


def test_admission_does_not_charge_no_slo_backlog():
    """No-SLO work (deadline = ∞) is behind every deadline under EDF, and
    fair-share allocation guarantees a deadline query its per-wave share —
    so a huge batch query in flight must not get SLO queries rejected."""
    g = _graph(n=256)
    svc = FrogWildService.open(g, _rc(max_queries=4, max_steps=12,
                                      wave_time_estimate_s=1.0))
    sched = svc.scheduler
    assert sched._submit(QueryRequest(rid=0, kind="topk", k=5,
                                      num_walks=5000)).admitted
    d = sched._submit(QueryRequest(rid=1, kind="topk", k=5,
                                   num_walks=1000, slo_s=3.0))
    assert d.admitted and not d.downgraded and d.num_walks == 1000


# --- layered config ----------------------------------------------------------


def test_layered_config_single_definition_per_flag():
    # legacy defaults are sourced from the layer defaults — one definition
    k, s = KernelConfig(), ShardConfig()
    assert FrogWildConfig().draw == EngineConfig().draw == k.draw
    assert (FrogWildConfig().step_impl == EngineConfig().step_impl
            == WalkIndexConfig().step_impl == k.step_impl)
    assert EngineConfig().capacity_factor == s.capacity_factor
    assert EngineConfig().axis_name == s.axis_name
    assert WalkIndexConfig().seed == s.seed
    assert RuntimeConfig().p_s == FrogWildConfig().p_s == EngineConfig().p_s


def test_runtime_config_round_trips():
    fw = FrogWildConfig(num_frogs=7, num_steps=3, p_T=0.2, p_s=0.5,
                        erasure="independent", num_shards=4,
                        draw="cumsum", step_impl="ref")
    assert RuntimeConfig.from_frogwild(fw).frogwild() == fw
    ec = EngineConfig(num_frogs=9, num_steps=2, p_s=0.4,
                      capacity_factor=2.0, draw="rejection")
    assert RuntimeConfig.from_engine(ec).engine() == ec
    ic = WalkIndexConfig(segments_per_vertex=5, segment_len=2,
                         num_shards=3, step_impl="ref", seed=7)
    assert RuntimeConfig.from_walk_index(ic).walk_index() == ic


# --- lifecycle ---------------------------------------------------------------


def test_index_checkpoint_reuse(tmp_path):
    g = _graph(n=256)
    d = str(tmp_path / "ckpt")
    rc = _rc(checkpoint_dir=d, segments_per_vertex=6, segment_len=2)
    svc1 = FrogWildService.open(g, rc)
    idx1 = svc1.ensure_index()
    assert os.path.isdir(d)
    # a second service with a DIFFERENT build seed still reuses the saved
    # slab — proof it loaded rather than rebuilt
    rc2 = dataclasses.replace(rc, runtime=ShardConfig(seed=99))
    svc2 = FrogWildService.open(g, rc2)
    idx2 = svc2.ensure_index()
    assert (np.asarray(idx1.endpoints) == np.asarray(idx2.endpoints)).all()
    # geometry mismatch is an error, not a silent rebuild
    rc3 = dataclasses.replace(
        rc, serving=dataclasses.replace(rc.serving, segments_per_vertex=9))
    with pytest.raises(ValueError, match=r"\(R, L\)"):
        FrogWildService.open(g, rc3).ensure_index()


def test_checkpoint_reuse_resharded_to_config(tmp_path):
    """A reused checkpoint is re-split to the *configured* serving layout:
    a monolithic (or differently-sharded) on-disk index must never be
    silently served at the checkpoint's shard count."""
    g = _graph(n=256)
    d = str(tmp_path / "ckpt")
    rc = _rc(checkpoint_dir=d, segments_per_vertex=6, segment_len=2)
    FrogWildService.open(g, rc).ensure_index()       # monolithic save
    rc4 = dataclasses.replace(rc,
                              runtime=ShardConfig(num_shards=4, seed=11))
    svc4 = FrogWildService.open(g, rc4)
    idx4 = svc4.ensure_index()
    from repro.query.index import ShardedWalkIndex
    assert isinstance(idx4, ShardedWalkIndex) and idx4.num_shards == 4
    # same slab, same key stream ⇒ sharded serving matches dense exactly
    svc1 = FrogWildService.open(g, rc)
    r1 = svc1.topk(k=5, epsilon=0.35, early_stop=False).result()
    r4 = svc4.topk(k=5, epsilon=0.35, early_stop=False).result()
    assert (r1.vertices == r4.vertices).all()
    assert np.allclose(r1.scores, r4.scores)


def test_open_from_graph_path(tmp_path):
    g = _graph(n=128)
    path = save_graph(str(tmp_path / "g.npz"), g)
    g2 = load_graph(path)
    assert (np.asarray(g2.col_idx) == np.asarray(g.col_idx)).all()
    svc = FrogWildService.open(path, RuntimeConfig(num_frogs=500))
    res = svc.pagerank(seed=1)
    assert int(res.counts.sum()) == 500
    with pytest.raises(TypeError, match="CSRGraph or a path"):
        FrogWildService.open(12345)
