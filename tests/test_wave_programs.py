"""Fused wave programs + the AOT bucket-shape ladder (PR 9).

Three claims under test:

1. **Byte-identity across dispatch paths.** The fused single-dispatch wave
   (``lax.scan`` over stitch rounds against the stacked slab) is the *same
   program* as the legacy per-shard host loop and as the gathered dense
   wave — same key stream ⇒ same bytes, including at non-divisible
   (walk-slot, query-slot, shard) shapes where the slab carries padding
   rows.

2. **Zero retraces after warmup.** ``warm_ladder()`` compiles one program
   per (walk-bucket, query-bucket) pair; afterwards an arbitrary mixed
   topk/PPR sweep re-buckets into warm executables — the trace counter
   (``repro.distributed.runtime.wave_trace_count``) must not move.

3. **Ladder mechanics.** Default ladders are the cap and its halvings;
   user ladders are validated and always topped by the cap; bucketing
   picks the smallest member ≥ demand.
"""
import numpy as np
import pytest

from repro.distributed.runtime import (ShardRuntime, reset_wave_trace_count,
                                       wave_trace_count)
from repro.graph import chung_lu_powerlaw
from repro.query import (QueryRequest, QueryScheduler, WalkIndexConfig,
                         build_walk_index, shard_walk_index)


def _graph_and_index(n=250, R=6, L=2, seed=2, shards=4):
    """n=250 with 4 shards ⇒ shard_size 63, 252 slab rows: 2 padding rows
    the fused gather must never touch."""
    g = chung_lu_powerlaw(n=n, avg_out_deg=8, seed=seed)
    idx = build_walk_index(g, WalkIndexConfig(
        segments_per_vertex=R, segment_len=L, num_shards=2))
    return g, idx, shard_walk_index(idx, shards)


def _reqs():
    return [QueryRequest(rid=0, kind="topk", k=10, epsilon=0.4),
            QueryRequest(rid=1, kind="ppr", source=7, k=10, epsilon=0.4),
            QueryRequest(rid=2, kind="topk", k=5, num_walks=300)]


def _run(g, index, reqs, seed=11, **kw):
    kw.setdefault("max_walks", 640)      # non-power-of-two walk cap
    kw.setdefault("max_queries", 3)
    sched = QueryScheduler(g, index, max_steps=24, seed=seed, **kw)
    for r in reqs:
        assert sched.submit(r).admitted
    return sched, sorted(sched.run(), key=lambda r: r.rid)


# --- byte-identity across dispatch paths -------------------------------------


def test_fused_matches_legacy_loop_exactly():
    g, _, sh = _graph_and_index()
    sched_f, res_f = _run(g, sh, _reqs(), sharded_dispatch="fused")
    sched_l, res_l = _run(g, sh, _reqs(), sharded_dispatch="loop")
    assert sched_f.dispatch == "fused" and sched_l.dispatch == "loop"
    assert [r.rid for r in res_f] == [0, 1, 2]
    for a, b in zip(res_f, res_l):
        assert (a.vertices == b.vertices).all(), a.rid
        assert np.array_equal(a.scores, b.scores), a.rid
        assert a.num_walks == b.num_walks and a.waves == b.waves


def test_fused_sharded_matches_gathered_exactly():
    g, idx, sh = _graph_and_index()
    _, res_g = _run(g, idx, _reqs())
    sched_s, res_s = _run(g, sh, _reqs())
    assert sched_s.dispatch == "fused"
    for a, b in zip(res_g, res_s):
        assert (a.vertices == b.vertices).all(), a.rid
        assert np.array_equal(a.scores, b.scores), a.rid


@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_fused_kernel_paths_match_loop(impl):
    """The gather-only stitch kernels (tally=False) inside the fused scan
    must produce the same slots as the tallying kernels in the host loop."""
    g, _, sh = _graph_and_index(n=130, R=5, L=2, seed=3, shards=2)
    _, res_f = _run(g, sh, _reqs()[:2], impl=impl, max_walks=320,
                    sharded_dispatch="fused")
    _, res_l = _run(g, sh, _reqs()[:2], impl=impl, max_walks=320,
                    sharded_dispatch="loop")
    for a, b in zip(res_f, res_l):
        assert (a.vertices == b.vertices).all(), (impl, a.rid)
        assert np.array_equal(a.scores, b.scores), (impl, a.rid)


def test_donation_off_matches_donation_on():
    g, _, sh = _graph_and_index(n=130, R=5, L=2, seed=3, shards=2)
    _, res_d = _run(g, sh, _reqs(), donate_wave_buffers=True)
    _, res_n = _run(g, sh, _reqs(), donate_wave_buffers=False)
    for a, b in zip(res_d, res_n):
        assert (a.vertices == b.vertices).all(), a.rid
        assert np.array_equal(a.scores, b.scores), a.rid


def test_bucketing_does_not_change_answers():
    """A coarse single-bucket ladder and a fine ladder run different padded
    shapes — but the bucket choice is a pure host function of the same
    allocation, so the same ladder on both paths keeps bytes equal. Across
    *different* ladders only the distribution is shared (padding slots
    consume key draws), so here we assert the coarse ladder byte-matches
    the default — both bucket every wave to the full cap shape when demand
    exceeds the sub-cap rungs."""
    g, _, sh = _graph_and_index(n=130, R=5, L=2, seed=3, shards=2)
    _, res_a = _run(g, sh, _reqs(), max_walks=320,
                    walk_buckets=(320,), query_buckets=(3,))
    # default ladder: demand (3 queries, >160 walks) also buckets to cap
    _, res_b = _run(g, sh, _reqs(), max_walks=320)
    for a, b in zip(res_a, res_b):
        assert (a.vertices == b.vertices).all(), a.rid
        assert np.array_equal(a.scores, b.scores), a.rid


# --- AOT ladder: zero retraces after warmup ----------------------------------


def test_warm_ladder_then_mixed_sweep_zero_retraces():
    g, _, sh = _graph_and_index(n=130, R=5, L=2, seed=3, shards=2)
    sched = QueryScheduler(g, sh, max_walks=320, max_queries=3, max_steps=24,
                           seed=11, walk_buckets=(80, 160, 320),
                           query_buckets=(1, 2, 3))
    warmed = sched.warm_ladder()
    assert warmed == 9                       # 3 walk × 3 query buckets
    before = wave_trace_count()
    rid = 0
    for round_ in range(4):                  # shifting query mix per wave
        for spec in ([("topk", 60)], [("topk", 40), ("ppr", 70)],
                     [("topk", 300), ("ppr", 20), ("topk", 5)])[
                         round_ % 3:round_ % 3 + 1]:
            for kind, walks in spec:
                sched.submit(QueryRequest(
                    rid=rid, kind=kind, k=5, num_walks=walks,
                    source=7 if kind == "ppr" else None))
                rid += 1
            sched.run()
    assert wave_trace_count() == before, "query-mix change retraced a wave"


def test_aot_warmup_flag_compiles_at_build():
    g, _, sh = _graph_and_index(n=130, R=5, L=2, seed=3, shards=2)
    reset_wave_trace_count()
    sched = QueryScheduler(g, sh, max_walks=320, max_queries=2, max_steps=24,
                           walk_buckets=(320,), query_buckets=(2,),
                           aot_warmup=True)
    assert len(sched._wave_fns) == 1
    traced = wave_trace_count()
    assert traced >= 0                       # may be 0 on a cache hit
    sched.submit(QueryRequest(rid=0, kind="topk", k=5, num_walks=100))
    sched.run()
    assert wave_trace_count() == traced      # serving never traces


def test_wave_cache_shared_across_equal_geometry_schedulers():
    """Programs key on WaveSpec and take slab/graph arrays as operands, so
    a second scheduler over the same geometry reuses the executable."""
    g, _, sh = _graph_and_index(n=130, R=5, L=2, seed=3, shards=2)
    kw = dict(max_walks=320, max_queries=2, max_steps=24,
              walk_buckets=(320,), query_buckets=(2,))
    QueryScheduler(g, sh, **kw).warm_ladder()
    cache = ShardRuntime.wave_cache()
    h0, m0 = cache.hits, cache.misses
    QueryScheduler(g, sh, seed=99, **kw).warm_ladder()
    assert cache.misses == m0                # no new compile
    assert cache.hits > h0


# --- the per-poll top-k finalize ---------------------------------------------


def test_topk_stable_matches_full_stable_argsort():
    """Both the sparse (small positive support) and dense (partition)
    strategies must reproduce the head of the full stable argsort exactly,
    ties included."""
    from repro.query.scheduler import _topk_stable
    rng = np.random.default_rng(0)
    cases = [(1000, 10, 30), (1000, 10, 900), (1000, 25, 5),
             (50, 60, 20), (64, 64, 10), (128, 5, 0), (40, 40, 40)]
    for n, k, nnz in cases:
        counts = np.zeros(n, np.int64)
        if nnz:
            idx = rng.choice(n, nnz, replace=False)
            counts[idx] = rng.integers(1, 5, nnz)   # heavy ties
        want = np.argsort(-counts, kind="stable")[:k]
        got = _topk_stable(counts, k)
        assert np.array_equal(got, want), (n, k, nnz)
    # negative entries must route around the sparse path
    scores = rng.normal(size=500)
    want = np.argsort(-scores, kind="stable")[:7]
    assert np.array_equal(_topk_stable(scores, 7), want)


# --- ladder mechanics --------------------------------------------------------


def test_default_ladder_is_cap_and_halvings():
    norm = QueryScheduler._normalize_buckets
    assert norm(None, 1024, "walk_buckets", floor=128) == (128, 256, 512,
                                                           1024)
    assert norm(None, 12, "walk_buckets", floor=1) == (1, 3, 6, 12)
    assert norm(None, 1, "query_buckets", floor=1) == (1,)


def test_user_ladder_validated_and_topped_by_cap():
    norm = QueryScheduler._normalize_buckets
    assert norm((64, 256), 1024, "walk_buckets", floor=1) == (64, 256, 1024)
    assert norm((1024, 64), 1024, "walk_buckets", floor=1) == (64, 1024)
    with pytest.raises(ValueError, match="walk_buckets"):
        norm((0, 64), 1024, "walk_buckets", floor=1)
    with pytest.raises(ValueError, match="walk_buckets"):
        norm((2048,), 1024, "walk_buckets", floor=1)
    with pytest.raises(ValueError, match="sharded_dispatch"):
        g, _, sh = _graph_and_index(n=64, R=3, L=2, seed=1, shards=2)
        QueryScheduler(g, sh, sharded_dispatch="turbo")


def test_bucket_picks_smallest_fit():
    bucket = QueryScheduler._bucket
    ladder = (64, 256, 1024)
    assert bucket(ladder, 1) == 64
    assert bucket(ladder, 64) == 64
    assert bucket(ladder, 65) == 256
    assert bucket(ladder, 1024) == 1024
    assert bucket(ladder, 9999) == 1024      # top bucket bounds demand
