"""Distribution equivalence: rejection-sampled blocking draw vs the O(nnz)
cumsum/searchsorted reference (paper Process 19 / Definition 8).

The two implementations share no randomness, so equality is statistical: for
every source vertex we compare the empirical next-vertex distributions over
many fixed seeds and require the total-variation distance to sit within the
sampling-noise tolerance. Covered:

  * independent and channel erasure models (core oracle),
  * the all-edges-blocked Example-10 forced-edge repair path,
  * the engine's shard-local ``_blocking_draw`` (rejection vs cumsum with a
    shared fold_in coin grid),
  * dangling-vertex guards (the self-loop convention).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.frogwild import FrogWildConfig, draw_next
from repro.core.blocking import coin_uniform, num_rounds_for
from repro.graph import uniform_random
from repro.graph.csr import CSRGraph


def _transition_counts(draw_fn, n, num_keys, batch=500, seed0=0):
    """Empirical next-vertex histogram per source vertex: int64[n, n].

    One frog per vertex per key (coins are shared within a superstep, so
    multiple frogs on a vertex would be correlated samples and inflate the
    test's variance); keys are vmapped in batches for speed.
    """
    pos = jnp.arange(n, dtype=jnp.int32)
    fn = jax.jit(jax.vmap(lambda k: draw_fn(k, pos)))
    counts = np.zeros((n, n), dtype=np.int64)
    src = np.broadcast_to(np.arange(n), (batch, n))
    done = 0
    while done < num_keys:
        keys = jax.vmap(jax.random.PRNGKey)(
            seed0 + done + jnp.arange(batch)
        )
        nxt = np.asarray(fn(keys))
        np.add.at(counts, (src, nxt), 1)
        done += batch
    return counts


def _max_tv(a: np.ndarray, b: np.ndarray) -> float:
    """Max over source vertices of TV(row_a, row_b) (rows are histograms)."""
    pa = a / np.maximum(a.sum(axis=1, keepdims=True), 1)
    pb = b / np.maximum(b.sum(axis=1, keepdims=True), 1)
    return float(0.5 * np.abs(pa - pb).sum(axis=1).max())


def _chi2_two_sample(a: np.ndarray, b: np.ndarray):
    """Pooled two-sample chi-square over all (vertex, successor) cells.

    Returns (statistic, df, loose_threshold) with the threshold at roughly
    the 1e-4 tail via the normal approximation χ²_df ≈ df + z·sqrt(2·df).
    """
    support = (a + b) > 0
    x2 = float((((a - b) ** 2) / np.maximum(a + b, 1))[support].sum())
    df = int(support.sum(axis=1).clip(min=1).sum() - a.shape[0])
    thresh = df + 4.0 * np.sqrt(2 * df)
    return x2, df, thresh


@pytest.mark.parametrize("erasure,p_s", [
    ("independent", 0.7), ("independent", 0.35),
    ("channel", 0.7), ("channel", 0.35),
])
def test_rejection_matches_cumsum(erasure, p_s):
    g = uniform_random(30, avg_out_deg=4, seed=7)
    counts = {}
    for draw in ("rejection", "cumsum"):
        cfg = FrogWildConfig(p_s=p_s, erasure=erasure, num_shards=4, draw=draw)
        counts[draw] = _transition_counts(
            lambda k, pos, c=cfg: draw_next(g, c, k, pos),
            g.n, num_keys=3000,
        )
    x2, df, thresh = _chi2_two_sample(counts["rejection"], counts["cumsum"])
    assert x2 < thresh, (erasure, p_s, x2, df, thresh)
    # 3000 iid samples/vertex over ≤ ~8 support points ⇒ TV noise ≲ 0.04
    tv = _max_tv(counts["rejection"], counts["cumsum"])
    assert tv < 0.08, (erasure, p_s, tv)
    # conservation: every draw produced a real successor for every frog
    assert counts["rejection"].sum() == counts["cumsum"].sum()


def test_forced_repair_path_matches():
    """p_s ≈ 0 with one channel per vertex ⇒ nearly every draw goes through
    the Example-10 forced edge. Both impls must degrade to the same
    (uniform-over-out-edges) distribution."""
    g = uniform_random(24, avg_out_deg=3, seed=11)
    counts = {}
    for draw in ("rejection", "cumsum"):
        cfg = FrogWildConfig(p_s=0.02, erasure="channel", num_shards=1,
                             draw=draw)
        counts[draw] = _transition_counts(
            lambda k, pos, c=cfg: draw_next(g, c, k, pos),
            g.n, num_keys=2000,
        )
    x2, df, thresh = _chi2_two_sample(counts["rejection"], counts["cumsum"])
    assert x2 < thresh, (x2, df, thresh)
    tv = _max_tv(counts["rejection"], counts["cumsum"])
    assert tv < 0.09, tv
    # and both match the plain uniform walk marginally
    probs = counts["rejection"] / counts["rejection"].sum(axis=1, keepdims=True)
    for v in range(g.n):
        succ, mult = np.unique(g.successors(v), return_counts=True)
        want = np.zeros(g.n)
        want[succ] = mult / mult.sum()
        assert 0.5 * np.abs(probs[v] - want).sum() < 0.08, v


def test_engine_blocking_draw_matches_cumsum():
    """Shard-local engine draw: channel enumeration vs the cumsum reference
    over the *same* coin grid."""
    from repro.engine.gas import _blocking_draw

    g = uniform_random(32, avg_out_deg=4, seed=3)
    S = 4
    p_s = 0.4
    deg = g.out_deg
    row_ptr = g.row_ptr
    edge_src = g.edge_src
    edge_dst_shard = g.edge_dst_shard(S)
    col_sorted, chan_cnt, chan_off = g.channel_layout(S)

    def draw(k, pos, mode):
        k_coin, k_draw = jax.random.split(k)
        chan_grid = (jnp.arange(g.n, dtype=jnp.int32)[:, None] * S
                     + jnp.arange(S, dtype=jnp.int32)[None, :])
        coins = coin_uniform(k_coin, chan_grid) < p_s
        return _blocking_draw(
            pos, row_ptr, g.col_idx, deg, edge_src, edge_dst_shard,
            chan_cnt, chan_off, col_sorted, coins, p_s, k_draw, draw=mode,
        )

    counts = {
        mode: _transition_counts(
            lambda k, pos, m=mode: draw(k, pos, m), g.n, num_keys=3000,
        )
        for mode in ("rejection", "cumsum")
    }
    x2, df, thresh = _chi2_two_sample(counts["rejection"], counts["cumsum"])
    assert x2 < thresh, (x2, df, thresh)
    tv = _max_tv(counts["rejection"], counts["cumsum"])
    assert tv < 0.08, tv


def test_channel_skew_hub_matches_cumsum():
    """Regression: a hub with 99 edges on one channel and 1 on another must
    not be misrouted through the forced edge when the big channel closes —
    the failure mode of naive edge-rejection at channel granularity."""
    from repro.graph.csr import build_csr

    n = 200
    hub_dst = np.concatenate([np.arange(1, 100), [150]])   # shard 0 ×99, 1 ×1
    src = np.concatenate([np.zeros(100, np.int64), np.arange(1, n)])
    dst = np.concatenate([hub_dst, (np.arange(1, n) + 1) % n])
    g = build_csr(n, src, dst)
    pos = jnp.zeros((1,), jnp.int32)                        # frog on the hub
    hits = {}
    for draw in ("rejection", "cumsum"):
        cfg = FrogWildConfig(p_s=0.5, erasure="channel", num_shards=2,
                             draw=draw)
        fn = jax.jit(jax.vmap(lambda k: draw_next(g, cfg, k, pos)[0]))
        h = 0
        for b in range(0, 12_000, 2000):
            keys = jax.vmap(jax.random.PRNGKey)(b + jnp.arange(2000))
            h += int((np.asarray(fn(keys)) == 150).sum())
        hits[draw] = h / 12_000
    # exact value: p_s·(1-p_s)·(1/1) + p_s²·(1/100) + (1-p_s)²·(1/100) ≈ 0.2575
    assert abs(hits["rejection"] - hits["cumsum"]) < 0.03, hits
    assert abs(hits["rejection"] - 0.2575) < 0.03, hits


def test_num_rounds_budget():
    # residual (1 - p_s)^K stays below the statistical tolerance everywhere
    for p_s in (0.1, 0.3, 0.7, 0.95):
        K = num_rounds_for(p_s)
        assert (1 - p_s) ** K <= 1.1e-4, (p_s, K)
    assert num_rounds_for(0.001) == 256          # capped


def test_dangling_vertex_guards():
    """d_out == 0 must neither crash nor lose the frog: the walker parks on
    the vertex (self-loop convention) for plain and erasure draws alike."""
    # hand-built CSR with vertex 2 dangling (build_csr would repair it)
    row_ptr = jnp.asarray([0, 2, 4, 4], jnp.int32)
    col_idx = jnp.asarray([1, 2, 0, 2], jnp.int32)
    deg = jnp.asarray([2, 2, 0], jnp.int32)
    g = CSRGraph(n=3, row_ptr=row_ptr, col_idx=col_idx, out_deg=deg)
    pos = jnp.asarray([0, 1, 2, 2], jnp.int32)
    for cfg in (
        FrogWildConfig(p_s=1.0, erasure="none"),
        FrogWildConfig(p_s=0.5, erasure="channel", num_shards=2),
        FrogWildConfig(p_s=0.5, erasure="channel", num_shards=2,
                       draw="cumsum"),
        FrogWildConfig(p_s=0.5, erasure="independent"),
    ):
        if cfg.erasure == "none":
            from repro.core.frogwild import frogwild_run  # noqa: F401
            # plain_move is internal; exercise via a tiny full run below
            continue
        nxt = np.asarray(draw_next(g, cfg, jax.random.PRNGKey(0), pos))
        assert (nxt[2:] == 2).all(), nxt          # dangling frogs stay put
        assert ((nxt >= 0) & (nxt < 3)).all()
    # plain path end-to-end: all frogs tallied despite the dangling vertex
    from repro.core import frogwild

    res = frogwild(g, FrogWildConfig(num_frogs=500, num_steps=3), seed=0)
    assert int(res.counts.sum()) == 500


def test_build_csr_self_loop_policy():
    from repro.graph.csr import build_csr

    src = np.asarray([0, 1])
    dst = np.asarray([1, 0])
    g = build_csr(4, src, dst, dangling="self_loop")
    assert g.successors(2).tolist() == [2]
    assert g.successors(3).tolist() == [3]
    g2 = build_csr(4, src, dst)                   # default hash policy
    assert g2.successors(2).tolist() != [2]


def test_coin_uniform_is_uniform_and_consistent():
    key = jax.random.PRNGKey(5)
    idx = jnp.arange(20_000, dtype=jnp.int32)
    u = np.asarray(coin_uniform(key, idx))
    assert 0.0 <= u.min() and u.max() < 1.0
    assert abs(u.mean() - 0.5) < 0.01
    # deterministic per (key, idx): repeated evaluation returns same coins
    u2 = np.asarray(coin_uniform(key, idx))
    assert (u == u2).all()
    # and different keys decorrelate
    u3 = np.asarray(coin_uniform(jax.random.PRNGKey(6), idx))
    assert abs(np.corrcoef(u, u3)[0, 1]) < 0.03
