"""Per-assigned-architecture smoke tests (reduced configs, CPU).

For each of the 10 architectures: instantiate the same-family reduced
config, run one forward + one train step + one decode step, assert output
shapes and finiteness. The FULL configs are exercised only via the dry-run.
"""
import jax
import jax.numpy as jnp
import pytest

# The LLM arch registry is a template leftover kept off the public
# ``repro.configs`` surface — these smoke tests import it explicitly.
from repro.configs.registry import ARCHS, get_config, reduced_config
from repro.models import decode_step, forward_train, init_decode_state, init_params
from repro.training import AdamWConfig, TrainStepConfig
from repro.training.train_step import init_train_state, make_train_step


def _batch(cfg, B, S, key):
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(
            jax.random.fold_in(key, 1), (B, S), 0, cfg.vocab_size),
    }
    if cfg.family == "vlm":
        batch["prefix_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 2),
            (B, cfg.num_prefix_embeddings, cfg.d_model))
    if cfg.family == "encdec":
        batch["encoder_frames"] = jax.random.normal(
            jax.random.fold_in(key, 3), (B, cfg.encoder_seq, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_forward_train_decode(arch):
    full = get_config(arch)
    cfg = reduced_config(full)
    assert cfg.family == full.family            # same wiring
    key = jax.random.PRNGKey(0)
    B, S = 2, 16
    batch = _batch(cfg, B, S, key)

    # one train step
    tcfg = TrainStepConfig(opt=AdamWConfig(lr=1e-3, warmup_steps=1,
                                           total_steps=10), remat=True)
    state = init_train_state(cfg, key)
    step = jax.jit(make_train_step(cfg, tcfg))
    state, metrics = step(state, batch, key)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert float(metrics["loss"]) > 0

    # one decode step with the trained params
    st_ = init_decode_state(state["params"], cfg, B, max_len=32,
                            encoder_frames=batch.get("encoder_frames"))
    toks = jnp.zeros((B,), jnp.int32)
    logits, st_ = decode_step(state["params"], st_, toks, cfg)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert int(st_.pos) == 1


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_param_count_matches_tree(arch):
    """The analytic param_count (used for rooflines) must track the real
    parameter tree within 2% — checked on the reduced config (same formula)."""
    cfg = reduced_config(get_config(arch))
    params = init_params(cfg, jax.random.PRNGKey(0))
    actual = sum(x.size for x in jax.tree.leaves(params))
    analytic = cfg.param_count
    assert abs(actual - analytic) / actual < 0.06, (actual, analytic)
