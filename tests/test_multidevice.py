"""Multi-device behaviour (engine, distributed PR, partial sync, pipeline,
elastic resharding) — exercised in subprocesses with placeholder devices so
the rest of the suite keeps seeing exactly 1 device."""
import pytest

from conftest import run_with_devices


def test_engine_and_distributed_pagerank():
    out = run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.graph import chung_lu_powerlaw
from repro.core import power_iteration, normalized_mass_captured
from repro.engine import (EngineConfig, build_distributed_graph,
                          distributed_frogwild, distributed_power_iteration)
from repro.engine.baseline import build_pull_graph
mesh = jax.make_mesh((8,), ("vertex",), axis_types=(jax.sharding.AxisType.Auto,))
g = chung_lu_powerlaw(n=2048, avg_out_deg=10, seed=1)
pi = power_iteration(g, num_iters=60)

# distributed power iteration == single-device power iteration
pg = build_pull_graph(g, 8)
pi_d = distributed_power_iteration(pg, mesh, num_iters=60)
assert np.allclose(np.asarray(pi_d), np.asarray(pi), atol=1e-5)

# engine: conservation + accuracy + p_s byte scaling
sync_totals = {}
for ps in (1.0, 0.4):
    cfg = EngineConfig(num_frogs=100_000, num_steps=8, p_s=ps)
    res = distributed_frogwild(build_distributed_graph(g, 8), cfg, mesh, seed=0)
    assert int(res.counts.sum()) == 100_000, (ps, int(res.counts.sum()))
    assert res.overflow == 0
    m = float(normalized_mass_captured(res.pi_hat, pi, 20))
    assert m > (0.95 if ps == 1.0 else 0.80), (ps, m)
    sync_totals[ps] = int(res.sync_msgs_per_step.sum())
# partial sync must cut sync messages roughly proportionally
ratio = sync_totals[0.4] / sync_totals[1.0]
assert 0.25 < ratio < 0.55, ratio

# fused plain step through the HBM-streaming kernel: same process, same
# accuracy, exact conservation (blocked slabs via vertex_block=).
dgb = build_distributed_graph(g, 8, vertex_block=64)
cfg = EngineConfig(num_frogs=100_000, num_steps=8, p_s=1.0, step_impl="stream")
res = distributed_frogwild(dgb, cfg, mesh, seed=0)
assert int(res.counts.sum()) == 100_000, int(res.counts.sum())
assert res.overflow == 0
m = float(normalized_mass_captured(res.pi_hat, pi, 20))
assert m > 0.95, m
print("ENGINE-OK")
""", n_devices=8)
    assert "ENGINE-OK" in out


def test_partial_psum_unbiased_and_error_feedback():
    out = run_with_devices("""
import jax, jax.numpy as jnp, numpy as np, functools
from jax.sharding import PartitionSpec as P
from repro.core.partial_sync import partial_psum, partial_channel_mask
mesh = jax.make_mesh((8,), ("d",), axis_types=(jax.sharding.AxisType.Auto,))
x = jnp.arange(8.0).reshape(8, 1) + 1.0        # shard i holds i+1
true_sum = float(x.sum())

def run_unbiased(key):
    f = jax.shard_map(lambda a: partial_psum(a, "d", 0.5, key),
                      mesh=mesh, in_specs=P("d"), out_specs=P("d"),
                      check_vma=False)
    return f(x)

vals = np.stack([np.asarray(run_unbiased(jax.random.PRNGKey(i)))[0, 0]
                 for i in range(300)])
mean = vals.mean()
assert abs(mean - true_sum) / true_sum < 0.1, (mean, true_sum)

# error feedback: over T rounds, total synced mass ≈ total produced mass
def run_ef(key, T=30):
    def body(a):
        res = jnp.zeros_like(a)
        tot = jnp.zeros_like(a)
        for t in range(T):
            out, res = partial_psum(a, "d", 0.5, jax.random.fold_in(key, t),
                                    mode="error_feedback", residual=res)
            tot = tot + out
        return tot
    f = jax.shard_map(body, mesh=mesh, in_specs=P("d"), out_specs=P("d"),
                      check_vma=False)
    return f(x)

tot = float(np.asarray(run_ef(jax.random.PRNGKey(42)))[0, 0])
# per-round average of psum(x) ≈ true_sum → total ≈ T·true_sum (±resid)
assert abs(tot / 30 - true_sum) / true_sum < 0.25, tot

# channel mask: at least one channel open even at tiny p_s
def mask_fn(key):
    f = jax.shard_map(
        lambda: partial_channel_mask(key, 0.01, "d", 8)[None],
        mesh=mesh, in_specs=(), out_specs=P("d"), check_vma=False)
    return f()
for i in range(20):
    m = np.asarray(mask_fn(jax.random.PRNGKey(i)))
    assert m.sum(axis=1).min() >= 1
print("PSUM-OK")
""", n_devices=8)
    assert "PSUM-OK" in out


def test_partial_sync_training_and_pipeline():
    out = run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.models import ModelConfig
from repro.training import (AdamWConfig, PartialSyncConfig, TrainStepConfig,
                            make_train_step)
from repro.training.train_step import init_train_state
cfg = ModelConfig(family="dense", num_layers=2, d_model=64, num_heads=4,
                  num_kv_heads=2, d_ff=128, vocab_size=128, dtype="float32")
mesh = jax.make_mesh((4,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
key = jax.random.PRNGKey(0)
toks = jax.random.randint(key, (4, 17), 0, 128)
batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
for gran in ("shard", "layer"):
    tcfg = TrainStepConfig(
        opt=AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=200,
                        weight_decay=0.0),
        mode="partial_sync",
        partial_sync=PartialSyncConfig(p_s=0.5, granularity=gran))
    state = init_train_state(cfg, key, tcfg)
    step = jax.jit(make_train_step(cfg, tcfg, mesh=mesh, data_axes=("data",)))
    first = last = None
    for i in range(60):
        state, m = step(state, batch, jax.random.fold_in(key, i))
        if first is None: first = float(m["loss"])
        last = float(m["loss"])
    assert last < 0.5 * first, (gran, first, last)

# pipeline parallelism: GPipe schedule == sequential reference
from repro.distributed.pipeline import (PipelineConfig, pipeline_forward,
                                        split_layers_for_stages)
pmesh = jax.make_mesh((4,), ("stage",), axis_types=(jax.sharding.AxisType.Auto,))
L, d, M, mb = 8, 16, 5, 3
ws = jnp.stack([jax.random.normal(jax.random.fold_in(key, i), (d, d)) * 0.3
                for i in range(L)])
def stage_fn(p, x):
    y, _ = jax.lax.scan(lambda c, w: (jnp.tanh(c @ w), None), x, p)
    return y
x = jax.random.normal(key, (M, mb, d))
out = pipeline_forward(stage_fn, split_layers_for_stages(ws, 4), x,
                       PipelineConfig(4, M), pmesh)
ref = x
for i in range(L):
    ref = jnp.tanh(ref @ ws[i])
assert np.allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
print("TRAIN-PIPE-OK")
""", n_devices=8)
    assert "TRAIN-PIPE-OK" in out


def test_elastic_reshard_and_checkpoint_across_meshes():
    out = run_with_devices("""
import jax, jax.numpy as jnp, numpy as np, tempfile, os
from repro.models import ModelConfig
from repro.training.train_step import init_train_state
from repro.checkpoint import save_checkpoint, restore_checkpoint
from repro.distributed.elastic import reshard_train_state
from repro.distributed.sharding import MeshAxes, param_pspecs
cfg = ModelConfig(family="dense", num_layers=2, d_model=64, num_heads=4,
                  num_kv_heads=2, d_ff=128, vocab_size=128, dtype="float32")
key = jax.random.PRNGKey(0)
state = init_train_state(cfg, key)

# live reshard onto a (2, 4) mesh
mesh_a = jax.make_mesh((2, 4), ("data", "model"),
                       axis_types=(jax.sharding.AxisType.Auto,) * 2)
state_a = reshard_train_state(state, cfg, mesh_a)

# checkpoint written from mesh A restores onto a different mesh B
with tempfile.TemporaryDirectory() as d:
    save_checkpoint(d, 1, state_a["params"])
    mesh_b = jax.make_mesh((4, 2), ("data", "model"),
                           axis_types=(jax.sharding.AxisType.Auto,) * 2)
    ax = MeshAxes.for_mesh(mesh_b)
    ps = param_pspecs(cfg, mesh_b, state["params"], ax)
    restored = restore_checkpoint(d, 1, state_a["params"],
                                  mesh=mesh_b, pspecs=ps)
    for a, b in zip(jax.tree.leaves(state["params"]),
                    jax.tree.leaves(restored)):
        assert np.allclose(np.asarray(a), np.asarray(b))
print("ELASTIC-OK")
""", n_devices=8)
    assert "ELASTIC-OK" in out


def test_sharded_serving_no_reassembly_mesh():
    """Serving against per-shard slab blocks on a real mesh: build sharded
    (reassemble=False), place block s on device s, and answer top-k + PPR
    identically to the gathered path — with the full slab never
    materialized on any device."""
    out = run_with_devices("""
import jax, numpy as np, tempfile
from repro.distributed import ShardRuntime
from repro.graph import chung_lu_powerlaw
from repro.query import (QueryRequest, QueryScheduler, ShardedWalkIndex,
                         WalkIndexConfig, build_walk_index_sharded,
                         load_walk_index)
mesh = jax.make_mesh((8,), ("vertex",), axis_types=(jax.sharding.AxisType.Auto,))
g = chung_lu_powerlaw(n=2048, avg_out_deg=10, seed=1)
cfg = WalkIndexConfig(segments_per_vertex=8, segment_len=3, seed=7)
with tempfile.TemporaryDirectory() as d:
    build_walk_index_sharded(g, cfg, mesh, directory=d, reassemble=False)
    sharded = load_walk_index(d, reassemble=False)
    dense = load_walk_index(d)                       # legacy reader
assert isinstance(sharded, ShardedWalkIndex) and sharded.num_shards == 8
assert (sharded.reassemble().endpoints == dense.endpoints).all()

def serve(index, runtime=None):
    sched = QueryScheduler(g, index, max_walks=2048, max_queries=3,
                           max_steps=24, seed=11, runtime=runtime)
    for i in range(4):
        kind = "ppr" if i % 2 else "topk"
        assert sched.submit(QueryRequest(
            rid=i, kind=kind, source=17 * i, k=10, epsilon=0.3)).admitted
    return sched, sorted(sched.run(), key=lambda r: r.rid)

rt = ShardRuntime.for_mesh(mesh)
sched_s, res_s = serve(sharded, rt)
assert sched_s.runtime.is_mesh
# per-device slab placement: device s addresses exactly one [sz, R] block
placed = sched_s._placed_blocks
assert len(placed.sharding.device_set) == 8
shard_shapes = {s.data.shape for s in placed.addressable_shards}
assert shard_shapes == {(1, sharded.shard_size, 8)}, shard_shapes

_, res_g = serve(dense)
for a, b in zip(res_g, res_s):
    assert (a.vertices == b.vertices).all(), a.rid
    assert np.allclose(a.scores, b.scores), a.rid
print("SHARDED-SERVE-OK")
""", n_devices=8)
    assert "SHARDED-SERVE-OK" in out


def test_oracle_vs_engine_distribution_agreement():
    """The walker oracle and the distributed engine are two implementations
    of the same process — their estimators must agree up to sampling noise."""
    out = run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.graph import chung_lu_powerlaw
from repro.core import FrogWildConfig, frogwild
from repro.engine import EngineConfig, build_distributed_graph, distributed_frogwild
mesh = jax.make_mesh((8,), ("vertex",), axis_types=(jax.sharding.AxisType.Auto,))
g = chung_lu_powerlaw(n=2048, avg_out_deg=10, seed=3)
N, t = 150_000, 8
oracle = frogwild(g, FrogWildConfig(num_frogs=N, num_steps=t, p_s=1.0), seed=0)
eng = distributed_frogwild(build_distributed_graph(g, 8),
                           EngineConfig(num_frogs=N, num_steps=t, p_s=1.0),
                           mesh, seed=1)
tv = 0.5 * float(jnp.abs(oracle.pi_hat - eng.pi_hat).sum())
assert tv < 0.08, tv           # total-variation distance ≈ sampling noise
print("AGREE-OK", tv)
""", n_devices=8)
    assert "AGREE-OK" in out
