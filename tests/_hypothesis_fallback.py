"""Minimal deterministic stand-in for `hypothesis` (used when the real
package is absent — this container has no network and no wheel baked in).

Supports exactly the subset this suite uses:

  * ``strategies.integers(lo, hi)`` / ``floats(lo, hi)`` / ``sampled_from(xs)``
  * ``@given(...)`` with positional or keyword strategies
  * ``@settings(max_examples=..., deadline=...)`` as a decorator, plus
    ``settings.register_profile`` / ``settings.load_profile``

Example generation is deterministic: each test draws from a ``random.Random``
seeded by the test's qualified name, and the first example always pins every
integer/float strategy to its lower bound (a cheap "shrunk" case). This is
NOT property-based testing — just a reproducible example sweep so the suite
runs unchanged without the dependency.
"""
from __future__ import annotations

import functools
import random
import sys
import types


class _Strategy:
    def __init__(self, draw, lo_example=None):
        self._draw = draw
        self._lo = lo_example

    def example(self, rng, first: bool):
        if first and self._lo is not None:
            return self._lo
        return self._draw(rng)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value), min_value)


def floats(min_value: float, max_value: float, **_kw) -> _Strategy:
    return _Strategy(lambda rng: rng.uniform(min_value, max_value), min_value)


def sampled_from(elements) -> _Strategy:
    xs = list(elements)
    return _Strategy(lambda rng: rng.choice(xs), xs[0])


def booleans() -> _Strategy:
    return _Strategy(lambda rng: rng.random() < 0.5, False)


def just(value) -> _Strategy:
    return _Strategy(lambda rng: value, value)


class settings:  # noqa: N801 — mirrors hypothesis' public name
    _profiles: dict = {}
    _active: dict = {"max_examples": 20}

    def __init__(self, max_examples: int = None, **_kw):
        self.max_examples = max_examples

    def __call__(self, fn):
        if self.max_examples is not None:
            fn._fallback_max_examples = self.max_examples
        return fn

    @classmethod
    def register_profile(cls, name: str, max_examples: int = 20, **_kw):
        cls._profiles[name] = {"max_examples": max_examples}

    @classmethod
    def load_profile(cls, name: str):
        cls._active = dict(cls._profiles.get(name, cls._active))


def given(*arg_strats, **kw_strats):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*fixture_args, **fixture_kw):
            n = getattr(fn, "_fallback_max_examples", None)
            if n is None:
                n = settings._active.get("max_examples", 20)
            rng = random.Random(f"{fn.__module__}.{fn.__qualname__}")
            for i in range(n):
                first = i == 0
                args = [s.example(rng, first) for s in arg_strats]
                kw = {k: s.example(rng, first) for k, s in kw_strats.items()}
                fn(*fixture_args, *args, **fixture_kw, **kw)

        # hide the strategy params from pytest's fixture resolution
        del wrapper.__wrapped__
        return wrapper

    return deco


def install() -> types.ModuleType:
    """Registers this shim as ``hypothesis`` (+``hypothesis.strategies``)."""
    mod = types.ModuleType("hypothesis")
    strat = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "sampled_from", "booleans", "just"):
        setattr(strat, name, globals()[name])
    mod.given = given
    mod.settings = settings
    mod.strategies = strat
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strat
    return mod
