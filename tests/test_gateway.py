"""Serving-gateway contract tests (PR 7).

Four claims are enforced here:

* **Dominance**: a cached answer certified at (ε′, δ′) serves a request
  for (ε, δ) iff ε′ ≤ ε and δ′ ≤ δ — dominated repeats come back
  byte-identical with zero new walks; near-misses (ε < ε′) go live;
  degraded answers are never cached; bumping the graph epoch invalidates.

* **In-flight dedup**: a duplicate of a live query joins its handle
  instead of spawning walks; with an identical target the joined result
  is the parent's ``QueryResult`` object verbatim.

* **Replica economics**: N replicas share ONE walk-index slab (object
  identity), the router lands new work on the lowest EDF-charged queue,
  and a cold gateway replica answers byte-identically to a cold
  standalone service under the same config.

* **Lifecycle + structured rejection**: ``close()`` is idempotent and
  safe with handles in flight; ``AdmissionDecision.reason_code``
  distinguishes infeasible-SLO / capacity / shard-loss refusals.
"""
import json
import urllib.request

import numpy as np
import pytest

from repro import (FrogWildService, Gateway, RuntimeConfig, ServingConfig,
                   ShardConfig)
from repro.distributed.faults import FaultPlan
from repro.gateway import Certificate, ReplicaPool, ResultCache, serve_http
from repro.graph import chung_lu_powerlaw
from repro.query import (QueryRequest, QueryResult, RejectReason,
                         SchedulerStats)


# ε=0.4 plans are feasible at max_steps=32 (certificate ≈ 0.392 ≤ 0.4);
# tighter requests are honestly clamped wider — used for near-miss tests.
EPS_OK = 0.4


def _graph(n=256, seed=2):
    return chung_lu_powerlaw(n=n, avg_out_deg=6, seed=seed)


def _rc(num_shards=1, seed=11, **serving_kw):
    serving = dict(segments_per_vertex=12, segment_len=3, build_shards=2,
                   max_walks=512, max_queries=3, max_steps=32)
    serving.update(serving_kw)
    return RuntimeConfig(
        runtime=ShardConfig(num_shards=num_shards, seed=seed),
        serving=ServingConfig(**serving))


@pytest.fixture(scope="module")
def gw():
    with Gateway.open(_graph(), _rc(), replicas=2) as g:
        yield g


# --- the cache: dominance is the whole contract ------------------------------


def test_certificate_dominance_rule():
    c = Certificate(epsilon=0.3, delta=0.1)
    assert c.dominates(0.3, 0.1)            # equality is dominance
    assert c.dominates(0.5, 0.2)
    assert not c.dominates(0.2, 0.1)        # tighter ε refused
    assert not c.dominates(0.5, 0.05)       # tighter δ refused


def test_cache_keeps_a_pareto_frontier_per_key():
    cache = ResultCache()
    key = ResultCache.key("topk", 8, 0, 0)

    def res(eps):
        return QueryResult(rid=0, kind="topk",
                           vertices=np.arange(8), scores=np.ones(8),
                           num_walks=100, num_steps=8, waves=1,
                           latency_s=0.1, epsilon_bound=eps)

    assert cache.insert(key, res(0.3), delta=0.10)
    assert cache.insert(key, res(0.2), delta=0.20)   # incomparable: kept
    assert cache.lookup(key, 0.3, 0.1) is not None
    assert cache.lookup(key, 0.2, 0.2) is not None
    assert cache.lookup(key, 0.2, 0.1) is None       # dominated by neither
    # a certificate dominated by a stored one is refused; a dominating one
    # prunes what it obsoletes
    assert not cache.insert(key, res(0.35), delta=0.15)
    assert cache.insert(key, res(0.2), delta=0.10)
    assert len(cache._entries[key]) == 1


def test_degraded_and_uncertified_results_never_cached():
    cache = ResultCache()
    key = ResultCache.key("topk", 8, 0, 0)
    bad = QueryResult(rid=0, kind="topk", vertices=np.arange(8),
                      scores=np.ones(8), num_walks=50, num_steps=8,
                      waves=1, latency_s=0.1, epsilon_bound=0.3,
                      degraded=True)
    assert not cache.insert(key, bad, delta=0.1)
    no_cert = QueryResult(rid=1, kind="topk", vertices=np.arange(8),
                          scores=np.ones(8), num_walks=50, num_steps=8,
                          waves=1, latency_s=0.1, epsilon_bound=0.0)
    assert not cache.insert(key, no_cert, delta=0.1)
    assert cache.rejected_inserts == 2 and len(cache) == 0


def test_ppr_sources_split_keys_but_global_kinds_ignore_source():
    assert ResultCache.key("ppr", 8, 3, 0) != ResultCache.key("ppr", 8, 4, 0)
    assert ResultCache.key("topk", 8, 3, 0) == ResultCache.key("topk", 8, 4, 0)


# --- the gateway: hit / near-miss / join / epoch -----------------------------


def test_dominated_repeat_hits_with_zero_new_walks(gw):
    r1 = gw.topk(k=8, epsilon=EPS_OK, delta=0.1).result()
    waves = gw.pool.total_waves_run()
    # identical repeat and a strictly weaker request: both cache hits
    h2 = gw.topk(k=8, epsilon=EPS_OK, delta=0.1)
    h3 = gw.topk(k=8, epsilon=0.6, delta=0.2)
    assert h2.source == "cache" and h3.source == "cache"
    assert h2.result() is r1 and h3.result() is r1     # byte-identical
    assert gw.pool.total_waves_run() == waves          # zero new walks


def test_near_miss_tighter_than_certificate_goes_live(gw):
    r1 = gw.topk(k=10, epsilon=EPS_OK, delta=0.1).result()
    h = gw.topk(k=10, epsilon=r1.epsilon_bound * 0.9, delta=0.1)
    assert h.source == "live"
    h.result()
    # ... and a tighter δ alone also misses
    h2 = gw.topk(k=10, epsilon=EPS_OK, delta=0.05)
    assert h2.source == "live"
    h2.result()


def test_inflight_duplicate_joins_and_identical_target_is_verbatim(gw):
    h1 = gw.ppr(7, k=6, epsilon=0.34, delta=0.1)     # uncacheable: clamped
    assert h1.source == "live"
    h2 = gw.ppr(7, k=6, epsilon=0.5, delta=0.1)      # weaker: joins
    h3 = gw.ppr(7, k=6, epsilon=0.34, delta=0.1)     # identical: joins
    assert h2.source == "joined" and h3.source == "joined"
    waves = gw.pool.total_waves_run()
    r1 = h1.result()
    assert h3.result() is r1                          # verbatim object
    r2 = h2.result()                                  # certified no later
    assert r2.epsilon_bound <= 0.5
    # the joins rode h1's walks — finishing h2/h3 ran nothing new
    assert gw.pool.total_waves_run() == waves or h2.done()


def test_epoch_bump_invalidates_cached_certificates(gw):
    r1 = gw.topk(k=12, epsilon=EPS_OK, delta=0.1).result()
    assert gw.topk(k=12, epsilon=EPS_OK, delta=0.1).source == "cache"
    gw.bump_epoch()
    h = gw.topk(k=12, epsilon=EPS_OK, delta=0.1)
    assert h.source == "live"                         # stale cert orphaned
    assert h.result() is not r1


def test_batch_pagerank_is_cached_under_its_plan_certificate(gw):
    p1 = gw.pagerank(epsilon=0.5, delta=0.1, k=6)
    assert gw.pagerank(epsilon=0.5, delta=0.1, k=6) is p1
    assert gw.pagerank(epsilon=0.45, delta=0.1, k=6) is not p1


def test_metrics_snapshot_has_the_serving_numbers(gw):
    s = gw.stats()
    for k in ("requests", "completed", "cache_hits", "joins", "hit_rate",
              "join_rate", "qps", "p50_ms", "p99_ms", "rejects_by_reason",
              "cache", "replicas", "epoch"):
        assert k in s, k
    assert s["cache_hits"] >= 2 and s["joins"] >= 2
    assert len(s["replicas"]) == 2
    for r in s["replicas"]:
        assert r["lost_shards"] == []
        assert 0.0 <= r["wave_occupancy"] <= 1.0
    assert isinstance(gw.pool.replicas[0].serving_stats(), SchedulerStats)


# --- replica economics -------------------------------------------------------


def test_pool_shares_one_walk_index_slab():
    with ReplicaPool(_graph(), _rc(), num_replicas=3) as pool:
        idx = pool.replicas[0].ensure_index()
        for r in pool.replicas[1:]:
            assert r.ensure_index() is idx            # no N-fold slabs
        assert pool.replicas[0].graph is pool.replicas[1].graph


def test_router_prefers_the_lowest_charged_backlog():
    with Gateway.open(_graph(), _rc(), replicas=2, cache=False) as gw2:
        h1 = gw2.topk(k=8, epsilon=EPS_OK, delta=0.1)
        assert h1.replica == 0
        # replica 0 now carries h1's backlog → the next request (a
        # different key, so dedup can't capture it) routes away
        h2 = gw2.topk(k=9, epsilon=0.5, delta=0.1)
        assert h2.source == "live" and h2.replica == 1
        st = gw2.pool.replicas[0].serving_stats()
        assert st.backlog_walks > 0
        h1.result(), h2.result()
        # drained: both replicas report empty queues again
        assert all(r.serving_stats().backlog_walks == 0
                   for r in gw2.pool.replicas)


def test_cold_gateway_replica_matches_cold_standalone_service():
    """Byte-identity across the tier: the first query through a fresh
    gateway (replica 0) equals the same query on a fresh direct service
    under the same config — the gateway adds routing, not noise."""
    g = _graph()
    direct = FrogWildService.open(g, _rc()).topk(
        k=8, epsilon=EPS_OK, delta=0.1).result()
    with Gateway.open(g, _rc(), replicas=2) as gw2:
        viagw = gw2.topk(k=8, epsilon=EPS_OK, delta=0.1).result()
    assert (np.asarray(viagw.vertices) == np.asarray(direct.vertices)).all()
    assert (np.asarray(viagw.scores) == np.asarray(direct.scores)).all()
    assert viagw.epsilon_bound == direct.epsilon_bound
    assert viagw.num_walks == direct.num_walks


# --- degraded answers stay out of the cache ----------------------------------


def test_degraded_results_are_served_but_never_cached():
    cfg = RuntimeConfig(
        runtime=ShardConfig(num_shards=4, seed=3),
        serving=ServingConfig(segments_per_vertex=6, segment_len=2,
                              build_shards=4, max_walks=512, max_queries=4,
                              max_steps=12),
        faults=FaultPlan(shard_losses=((1, 0),)))
    with Gateway.open(_graph(), cfg, replicas=1) as gw2:
        h = gw2.topk(k=8, epsilon=0.6, delta=0.1)
        r = h.result()
        assert r.degraded
        assert gw2.cache.stats()["rejected_inserts"] >= 1
        assert len(gw2.cache) == 0
        # the repeat goes live — the outage is not pinned into the cache
        assert gw2.topk(k=8, epsilon=0.6, delta=0.1).source == "live"


# --- lifecycle: close() is idempotent and pool-safe --------------------------


def test_service_close_is_idempotent_with_inflight_handles():
    svc = FrogWildService.open(_graph(), _rc())
    h = svc.topk(k=8, epsilon=EPS_OK, delta=0.1)
    h.poll()                                  # mid-flight
    svc.close()
    svc.close()                               # double-close: no raise
    assert svc.closed
    assert h.status() == "cancelled" and h.done()
    assert not h.cancel()
    with pytest.raises(RuntimeError, match="closed"):
        svc.topk(k=4)
    with pytest.raises(RuntimeError, match="closed"):
        svc.pagerank(epsilon=0.5)
    assert svc.serving_stats() is None


def test_gateway_close_is_idempotent_and_closes_every_replica():
    gw2 = Gateway.open(_graph(), _rc(), replicas=2)
    h = gw2.topk(k=8, epsilon=EPS_OK, delta=0.1)
    h.poll()
    gw2.close()
    gw2.close()
    assert gw2.closed and gw2.pool.closed
    assert all(r.closed for r in gw2.pool.replicas)
    with pytest.raises(RuntimeError, match="closed"):
        gw2.topk(k=4)


# --- structured rejection reasons --------------------------------------------


def _sched(**kw):
    from repro.query import (QueryScheduler, WalkIndexConfig,
                             shard_walk_index)
    from repro.query.index import _build_walk_index
    g = _graph()
    idx = _build_walk_index(g, WalkIndexConfig(
        segments_per_vertex=6, segment_len=2, num_shards=4, seed=2))
    kw.setdefault("max_walks", 512)
    kw.setdefault("max_queries", 2)
    kw.setdefault("max_steps", 12)
    return QueryScheduler(g, shard_walk_index(idx, 4), seed=7, **kw)


def test_reject_reason_codes_distinguish_the_three_refusals():
    sched = _sched(wave_time_estimate_s=1.0, max_queries=1)
    ok = sched._submit(QueryRequest(rid=0, num_walks=512))
    assert ok.admitted and ok.reason_code == RejectReason.NONE
    # (a) SLO shorter than one wave
    d = sched._submit(QueryRequest(rid=1, num_walks=64, slo_s=0.5))
    assert not d.admitted and d.reason_code == RejectReason.INFEASIBLE_SLO
    # (b) feasible SLO, demand too large for the wave budget
    d = sched._submit(QueryRequest(rid=2, num_walks=4096, slo_s=3.0))
    assert not d.admitted and d.reason_code == RejectReason.CAPACITY
    # (c) shard loss re-admission: queued SLO work rejected by eviction
    sched._admit()
    assert sched._submit(QueryRequest(rid=3, num_walks=1024,
                                      slo_s=4.0)).admitted
    for s in (0, 1, 3):
        sched._evict_shard(s, wave_no=0)
    d = next(d for d in sched.rejected if d.rid == 3)
    assert d.reason_code == RejectReason.SHARD_LOSS


# --- HTTP front-end ----------------------------------------------------------


def _get(url):
    with urllib.request.urlopen(url) as resp:
        return resp.status, json.loads(resp.read())


def test_http_front_end_serves_queries_health_and_metrics(gw):
    with serve_http(gw) as srv:
        status, body = _get(srv.url + "/healthz")
        assert status == 200 and body["healthy"]
        status, body = _get(srv.url + f"/topk?k=4&epsilon={EPS_OK}")
        assert status == 200 and len(body["vertices"]) == 4
        assert body["epsilon_bound"] <= EPS_OK
        status, rep = _get(srv.url + f"/topk?k=4&epsilon={EPS_OK}")
        assert rep["source"] == "cache" and rep["vertices"] == body["vertices"]
        status, body = _get(srv.url + "/ppr?source=5&k=3&epsilon=0.6")
        assert status == 200 and body["kind"] == "ppr"
        status, body = _get(srv.url + "/metrics")
        assert body["requests"] >= 3 and body["cache_hits"] >= 1
        # bad params → 400; unknown route → 404 (stdlib raises HTTPError)
        with pytest.raises(urllib.error.HTTPError) as e:
            _get(srv.url + "/ppr?k=3")                # missing source
        assert e.value.code == 400
        with pytest.raises(urllib.error.HTTPError) as e:
            _get(srv.url + "/nope")
        assert e.value.code == 404


# --- dynamic graphs through the tier (PR 10) ---------------------------------


def test_mutation_stream_orphans_certificates_and_refreshes_replicas():
    """apply_mutations through the gateway: the cache's old-epoch
    certificates are orphaned (counted twice — gateway metric and cache
    stat), replicas serve the new epoch, and a repeat of a previously
    cached query goes live."""
    from repro.dynamic import MutationBatch

    g = _graph(n=128, seed=7)
    with Gateway.open(g, _rc(), replicas=2) as gw2:
        r1 = gw2.topk(k=8, epsilon=EPS_OK, delta=0.1).result()
        assert gw2.topk(k=8, epsilon=EPS_OK, delta=0.1).source == "cache"
        report = gw2.apply_mutations(MutationBatch.edges(insert=[(1, 100)]))
        assert report.epoch == 1
        assert report.segments_rebuilt == report.stale_segments
        assert gw2.epoch == 1
        assert gw2.metrics.epoch_orphaned >= 1
        assert gw2.cache.stats()["epoch_evictions"] >= 1
        s = gw2.stats()
        assert s["graph_epoch"] == 1
        assert s["epoch_orphaned"] >= 1
        h = gw2.topk(k=8, epsilon=EPS_OK, delta=0.1)
        assert h.source == "live"                 # stale cert orphaned
        r2 = h.result()
        assert r1.epoch == 0 and r2.epoch == 1


def test_inflight_gateway_query_spans_epoch_commit():
    """A live query admitted before the mutation finishes on its pinned
    epoch-0 slab, byte-identical to a gateway that never mutated — and
    its stale certificate is refused at cache-insert time."""
    from repro.dynamic import MutationBatch

    g = _graph(n=128, seed=8)
    with Gateway.open(g, _rc(), replicas=1) as ctrl:
        rc_ = ctrl.topk(k=8, epsilon=EPS_OK, delta=0.1).result()
    with Gateway.open(g, _rc(), replicas=1) as gw2:
        h = gw2.topk(k=8, epsilon=EPS_OK, delta=0.1)
        assert h.source == "live"
        gw2.apply_mutations(
            MutationBatch.edges(insert=[(3, 90), (60, 5)]))
        r = h.result()
        assert r.epoch == 0
        assert np.array_equal(r.vertices, rc_.vertices)
        assert np.array_equal(r.scores, rc_.scores)
        assert r.num_walks == rc_.num_walks
        # the old-epoch certificate never entered the cache: the same
        # query at the new epoch must go live, not hit
        assert gw2.cache.stats()["rejected_inserts"] >= 1
        assert gw2.topk(k=8, epsilon=EPS_OK, delta=0.1).source == "live"
