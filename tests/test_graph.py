"""Graph substrate invariants (unit + property)."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.graph import (
    CSRGraph,
    barabasi_albert,
    build_csr,
    chung_lu_powerlaw,
    partition_graph,
    ring_of_cliques,
    to_ell,
    transition_edges,
    uniform_random,
)


@given(st.integers(50, 400), st.floats(1.5, 20.0), st.integers(0, 5))
def test_generators_no_dangling(n, deg, seed):
    g = chung_lu_powerlaw(n=n, avg_out_deg=deg, seed=seed)
    assert g.n == n
    assert int(np.asarray(g.out_deg).min()) >= 1
    assert np.asarray(g.col_idx).min() >= 0
    assert np.asarray(g.col_idx).max() < n
    rp = np.asarray(g.row_ptr)
    assert rp[0] == 0 and rp[-1] == g.nnz
    assert (np.diff(rp) == np.asarray(g.out_deg)).all()


@pytest.mark.parametrize("gen", [barabasi_albert, uniform_random])
def test_other_generators(gen):
    g = gen(300)
    assert int(np.asarray(g.out_deg).min()) >= 1
    assert g.nnz > 300


def test_build_csr_fixes_dangling():
    # vertex 2 has no out-edges
    g = build_csr(4, np.array([0, 1, 3]), np.array([1, 2, 0]))
    assert int(np.asarray(g.out_deg).min()) >= 1
    assert g.nnz == 4


def test_transition_edges_column_stochastic():
    g = chung_lu_powerlaw(n=200, avg_out_deg=8, seed=3)
    src, dst, w = transition_edges(g)
    colsum = np.zeros(g.n)
    np.add.at(colsum, np.asarray(src), np.asarray(w))
    np.testing.assert_allclose(colsum, 1.0, atol=1e-5)


@given(st.integers(20, 150), st.integers(2, 8))
def test_partition_pads_consistently(n, shards):
    g = uniform_random(n, avg_out_deg=4, seed=1)
    gp, part = partition_graph(g, shards)
    assert gp.n % shards == 0
    assert part.shard_size * shards == gp.n
    # padded vertices self-loop
    for v in range(n, gp.n):
        succ = gp.to_numpy().successors(v)
        assert len(succ) == 1


@given(st.integers(30, 200), st.integers(8, 40))
def test_ell_roundtrip_spmv(n, K):
    """Hybrid ELL (slab + spill) must reproduce the COO SpMV exactly."""
    import jax

    g = chung_lu_powerlaw(n=n, avg_out_deg=6, seed=7)
    ell = to_ell(g, K=K)
    x = jnp.asarray(np.random.default_rng(0).random(ell.n_rows),
                    dtype=jnp.float32)
    from repro.kernels import ops

    y = ops.spmv(ell, x, impl="ref")[: g.n]
    src, dst, w = transition_edges(g)
    y_coo = jax.ops.segment_sum(x[src] * w, dst, num_segments=g.n)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_coo), atol=1e-5)


def test_ring_of_cliques_structure():
    g = ring_of_cliques(4, 5)
    assert g.n == 20
    deg = np.asarray(g.out_deg)
    assert (deg >= 4).all()
