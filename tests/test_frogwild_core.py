"""FrogWild! oracle invariants + paper-claim validation (single device)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    FrogWildConfig,
    frogwild,
    normalized_mass_captured,
    exact_identification,
    power_iteration,
    theory,
)
from repro.core.pagerank import pagerank_residual
from repro.graph import chung_lu_powerlaw, ring_of_cliques, uniform_random


@pytest.fixture(scope="module")
def graph_and_pi():
    g = chung_lu_powerlaw(n=1500, avg_out_deg=10, seed=1)
    pi = power_iteration(g, num_iters=60)
    return g, pi


@given(
    n=st.integers(30, 120),
    N=st.integers(100, 2000),
    t=st.integers(1, 6),
    p_s=st.sampled_from([1.0, 0.7, 0.3]),
    erasure=st.sampled_from(["none", "independent", "channel"]),
)
@settings(max_examples=15)
def test_frog_conservation(n, N, t, p_s, erasure):
    """Every frog is tallied exactly once — the core system invariant.

    (Example-10 repair means no frog is ever lost, unlike Example 9 alone —
    paper footnote 1.)"""
    g = uniform_random(n, avg_out_deg=4, seed=0)
    cfg = FrogWildConfig(num_frogs=N, num_steps=t, p_s=p_s,
                         erasure="none" if p_s == 1.0 else erasure,
                         num_shards=4)
    res = frogwild(g, cfg, seed=1)
    assert int(res.counts.sum()) == N
    assert float(res.pi_hat.sum()) == pytest.approx(1.0, abs=1e-5)
    assert (np.asarray(res.counts) >= 0).all()


def test_estimator_converges_to_pagerank(graph_and_pi):
    """Lemma 16 + Chernoff: π̂ → π for many frogs and enough steps."""
    g, pi = graph_and_pi
    cfg = FrogWildConfig(num_frogs=300_000, num_steps=24, p_s=1.0)
    res = frogwild(g, cfg, seed=0)
    l1 = float(jnp.abs(res.pi_hat - pi).sum())
    assert l1 < 0.12, l1                      # sampling noise at N=300k
    assert float(normalized_mass_captured(res.pi_hat, pi, 20)) > 0.97


def test_partial_sync_graceful_degradation(graph_and_pi):
    """Paper Fig 2: accuracy degrades gracefully as p_s drops."""
    g, pi = graph_and_pi
    masses = {}
    for p_s in (1.0, 0.4, 0.1):
        cfg = FrogWildConfig(num_frogs=100_000, num_steps=8, p_s=p_s,
                             erasure="channel", num_shards=16)
        res = frogwild(g, cfg, seed=2)
        masses[p_s] = float(normalized_mass_captured(res.pi_hat, pi, 50))
    assert masses[1.0] > 0.95
    assert masses[0.4] > 0.85
    assert masses[0.1] > 0.55
    assert masses[1.0] >= masses[0.1]


def test_theorem1_bound_holds(graph_and_pi):
    """μ_k(π̂) > μ_k(π) − ε with the paper's ε (Theorem 1)."""
    g, pi = graph_and_pi
    k, t, N, p_s, delta = 20, 12, 200_000, 0.7, 0.1
    cfg = FrogWildConfig(num_frogs=N, num_steps=t, p_s=p_s,
                         erasure="channel", num_shards=8)
    pi_inf = float(pi.max())
    p_cap = theory.p_cap_bound(g.n, t, pi_inf, 0.15)
    eps = theory.epsilon_bound(0.15, t, k, delta, N, p_s, p_cap)
    res = frogwild(g, cfg, seed=3)
    from repro.core.metrics import mass_captured

    mu_hat = float(mass_captured(res.pi_hat, pi, k))
    _, idx = jax.lax.top_k(pi, k)
    mu_opt = float(pi[idx].sum())
    assert mu_hat > mu_opt - eps


def test_power_iteration_fixed_point():
    g = chung_lu_powerlaw(n=500, avg_out_deg=8, seed=5)
    pi = power_iteration(g, num_iters=80)
    assert float(pagerank_residual(g, pi)) < 1e-5
    assert float(pi.sum()) == pytest.approx(1.0, abs=1e-5)
    assert float(pi.min()) >= 0.15 / g.n * 0.99   # teleport floor


def test_power_iteration_matches_dense_eig():
    g = ring_of_cliques(3, 4)
    pi = power_iteration(g, num_iters=200)
    from repro.graph.csr import adjacency_dense

    P = adjacency_dense(g)
    Q = 0.85 * P + 0.15 / g.n
    evals, evecs = np.linalg.eig(Q)
    i = np.argmax(evals.real)
    v = np.abs(evecs[:, i].real)
    v /= v.sum()
    np.testing.assert_allclose(np.asarray(pi), v, atol=1e-4)


def test_reduced_iterations_is_worse_than_frogwild_time_budget(graph_and_pi):
    """The paper's core claim, shape-level: a 1-iteration PR baseline is a
    *worse* approximation than FrogWild with a modest frog budget."""
    g, pi = graph_and_pi
    pr1 = power_iteration(g, num_iters=1)
    cfg = FrogWildConfig(num_frogs=200_000, num_steps=8, p_s=1.0)
    fw = frogwild(g, cfg, seed=4)
    k = 50
    m_pr1 = float(normalized_mass_captured(pr1, pi, k))
    m_fw = float(normalized_mass_captured(fw.pi_hat, pi, k))
    assert m_fw > m_pr1


@given(t=st.integers(1, 40))
def test_theory_mixing_term_decreases(t):
    assert theory.mixing_term(0.15, t + 1) < theory.mixing_term(0.15, t)


@given(N=st.integers(10, 10_000), k=st.integers(1, 50))
def test_theory_sampling_term_monotone(N, k):
    a = theory.sampling_term(k, 0.1, N, 1.0, 0.0)
    b = theory.sampling_term(k, 0.1, 2 * N, 1.0, 0.0)
    assert b < a
    assert theory.sampling_term(k + 1, 0.1, N, 1.0, 0.0) > a


def test_theory_suggestions_sane():
    assert theory.suggested_steps(0.1) >= 1
    assert theory.suggested_frogs(100, 0.3) >= 100
