"""Training substrate: optimizer math, loss, grad accumulation, memorization."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models import ModelConfig
from repro.training import AdamWConfig, TrainStepConfig, lm_loss, make_train_step
from repro.training.optimizer import adamw_init, adamw_update, lr_schedule
from repro.training.train_step import init_train_state

CFG = ModelConfig(family="dense", num_layers=2, d_model=64, num_heads=4,
                  num_kv_heads=2, d_ff=128, vocab_size=128, dtype="float32")


def _fixed_batch(B=4, S=16, key=None):
    key = key or jax.random.PRNGKey(0)
    toks = jax.random.randint(key, (B, S + 1), 0, CFG.vocab_size)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def test_memorization():
    tcfg = TrainStepConfig(opt=AdamWConfig(lr=3e-3, warmup_steps=5,
                                           total_steps=300, weight_decay=0.0))
    key = jax.random.PRNGKey(0)
    state = init_train_state(CFG, key)
    step = jax.jit(make_train_step(CFG, tcfg))
    batch = _fixed_batch()
    losses = []
    for i in range(80):
        state, m = step(state, batch, jax.random.fold_in(key, i))
        losses.append(float(m["loss"]))
    assert losses[-1] < 0.25 * losses[0], (losses[0], losses[-1])


def test_grad_accumulation_equivalent():
    """accum_steps=2 must produce the same update as the full batch (mean
    losses over equal microbatch sizes)."""
    key = jax.random.PRNGKey(1)
    batch = _fixed_batch(B=4)
    opt = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10,
                      weight_decay=0.0)
    outs = {}
    for accum in (1, 2):
        tcfg = TrainStepConfig(opt=opt, accum_steps=accum, remat=False)
        state = init_train_state(CFG, key)
        step = jax.jit(make_train_step(CFG, tcfg))
        new_state, m = step(state, batch, key)
        outs[accum] = (new_state["params"], float(m["loss"]))
    p1, l1 = outs[1]
    p2, l2 = outs[2]
    assert l1 == pytest.approx(l2, rel=1e-4)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_lm_loss_masking():
    logits = jnp.zeros((1, 4, 8))
    labels = jnp.zeros((1, 4), jnp.int32)
    full, m_full = lm_loss(logits, labels, z_loss=0.0)
    half, m_half = lm_loss(logits, labels,
                           mask=jnp.asarray([[1, 1, 0, 0]]), z_loss=0.0)
    # uniform logits → loss = log(V) regardless of mask weighting
    assert float(full) == pytest.approx(np.log(8), abs=1e-5)
    assert float(half) == pytest.approx(np.log(8), abs=1e-5)
    assert float(m_half["tokens"]) == 2


def test_lm_loss_perfect_prediction():
    V = 16
    labels = jnp.asarray([[3, 5]], dtype=jnp.int32)
    logits = jax.nn.one_hot(labels, V) * 100.0
    loss, m = lm_loss(logits, labels, z_loss=0.0)
    assert float(loss) < 1e-3
    assert float(m["accuracy"]) == 1.0


def test_adamw_against_manual_step():
    params = {"w": jnp.asarray([1.0, -2.0])}
    grads = {"w": jnp.asarray([0.1, 0.2])}
    cfg = AdamWConfig(lr=1e-2, warmup_steps=1, total_steps=10, b1=0.9,
                      b2=0.999, eps=1e-8, weight_decay=0.0, grad_clip=1e9)
    opt = adamw_init(params)
    new_p, new_opt, metrics = adamw_update(grads, opt, params, cfg)
    # manual: m=0.1g, v=0.001g², mhat=g, vhat=g² → delta=g/(|g|+eps)=sign
    lr0 = float(lr_schedule(cfg, jnp.zeros((), jnp.int32)))
    want = np.asarray([1.0, -2.0]) - lr0 * np.sign([0.1, 0.2])
    np.testing.assert_allclose(np.asarray(new_p["w"]), want, atol=1e-4)
    assert int(new_opt["step"]) == 1


@given(step=st.integers(0, 10_000))
def test_lr_schedule_bounds(step):
    cfg = AdamWConfig(lr=1e-3, warmup_steps=100, total_steps=10_000,
                      min_lr_ratio=0.1)
    lr = float(lr_schedule(cfg, jnp.asarray(step)))
    assert 0.0 < lr <= cfg.lr * 1.0001
    if step >= cfg.total_steps:
        assert lr == pytest.approx(cfg.lr * cfg.min_lr_ratio, rel=1e-3)


def test_grad_clipping_bounds_update():
    params = {"w": jnp.zeros((4,))}
    grads = {"w": jnp.full((4,), 1e6)}
    cfg = AdamWConfig(lr=1.0, warmup_steps=1, total_steps=2, grad_clip=1.0,
                      weight_decay=0.0)
    opt = adamw_init(params)
    _, _, metrics = adamw_update(grads, opt, params, cfg)
    assert float(metrics["grad_norm"]) > 1e5   # reported pre-clip
