"""Shared test config. NOTE: no XLA_FLAGS here — tests see 1 real device;
multi-device behaviour is exercised via subprocess (test_multidevice.py)."""
import os
import subprocess
import sys
import warnings

import pytest

# Donation of per-wave walk-state operands leaves the [Q+1, n] tally output
# unable to alias the [W] donated inputs — expected, not a leak (see
# repro/query/engine.py). pytest's warning capture overrides the library's
# import-time filter, so repeat it here.
warnings.filterwarnings(
    "ignore", message="Some donated buffers were not usable")


def pytest_configure(config):
    config.addinivalue_line(
        "filterwarnings",
        "ignore:Some donated buffers were not usable")

try:
    from hypothesis import settings
except ModuleNotFoundError:
    # No network in this container: fall back to the vendored deterministic
    # example sweep (tests/_hypothesis_fallback.py) so the suite still runs.
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    from _hypothesis_fallback import install

    install()
    from hypothesis import settings

# CPU container: keep hypothesis fast and deadline-free.
settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_with_devices(script: str, n_devices: int = 8, timeout: int = 600) -> str:
    """Runs a python snippet in a subprocess with N placeholder devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        env=env, timeout=timeout,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout
