import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb harness — lowers baseline + named variants of the three
chosen cells through the identical dry-run path and records roofline deltas.

  PYTHONPATH=src python experiments/perf_hillclimb.py [--cell rwkv|starcoder|engine]
"""
import argparse
import json
import time

from repro.launch.dryrun import run_cell  # noqa: E402  (sets XLA_FLAGS first)

OUT = "experiments/perf"


def show(r, base=None):
    if not r.get("ok"):
        print("   FAILED:", r.get("error", "")[:200])
        return
    ro = r["roofline"]
    line = (f"   compute={ro['compute_s']*1e3:10.1f}ms "
            f"memory={ro['memory_s']*1e3:12.1f}ms "
            f"collective={ro['collective_s']*1e3:10.1f}ms "
            f"dominant={ro['dominant']:10s} "
            f"live={r['memory']['live_bytes_per_device']/1e9:6.2f}GB")
    if base and base.get("ok"):
        b = base["roofline"]
        dom = b["dominant"]
        key = {"compute": "compute_s", "memory": "memory_s",
               "collective": "collective_s"}[dom]
        line += f"  Δ(dominant {dom}): {b[key] / max(ro[key], 1e-12):.2f}×"
    print(line)


def cell_rwkv():
    print("== rwkv6-3b × train_4k (worst roofline fraction: XLA-lowered "
          "recurrence is HBM-catastrophic) ==")
    print(" baseline (paper-faithful scan recurrence):")
    base = run_cell("rwkv6-3b", "train_4k", "single", OUT, tag="baseline")
    show(base)
    print(" V1: shard recurrence state value-dim over model axis "
          "(hypothesis: state read+write dominates HBM → ~10× on memory "
          "term; communication-free since per-step ops contract key dim):")
    v1 = run_cell("rwkv6-3b", "train_4k", "single", OUT,
                  overrides={"ssm_state_sharding": True}, tag="v1_state_tp")
    show(v1, base)
    return base, v1


def cell_starcoder():
    print("== starcoder2-7b × prefill_32k (36 heads don't divide TP=16 → "
          "baseline replicates attention over the model axis) ==")
    print(" baseline:")
    base = run_cell("starcoder2-7b", "prefill_32k", "single", OUT,
                    tag="baseline")
    show(base)
    print(" V1: context-parallel attention over KV (ring-lite, shard_map) "
          "(hypothesis: attention logits dominate HBO traffic; sharding KV "
          "1/16 cuts both memory and compute terms several-fold):")
    v1 = run_cell("starcoder2-7b", "prefill_32k", "single", OUT,
                  overrides={"attn_impl": "cp_kv"}, tag="v1_cp_kv")
    show(v1, base)
    print(" V2: + bf16 softmax probs (halve the p·V read traffic):")
    v2 = run_cell("starcoder2-7b", "prefill_32k", "single", OUT,
                  overrides={"attn_impl": "cp_kv", "attn_bf16_probs": True},
                  tag="v2_bf16_probs")
    show(v2, base)
    return base, v1, v2


def cell_engine():
    """The paper-representative cell: FrogWild on the Twitter-scale spec."""
    from repro.configs.frogwild_graphs import TWITTER_FULL
    from repro.engine.gas import (DistributedGraph, EngineConfig,
                                  channel_capacity, frogwild_dryrun_lowered)
    from repro.launch.hlo_analysis import analyze_hlo
    from repro.launch.mesh import make_vertex_mesh

    print("== engine frogwild × twitter-full (the paper's own workload) ==")
    mesh = make_vertex_mesh(multi_pod=False)
    S = mesh.devices.size
    n = TWITTER_FULL.n
    sz = ((-(-n // S) + 7) // 8) * 8
    nnz = ((int(TWITTER_FULL.avg_out_deg * sz * 2) + 7) // 8) * 8
    dg = DistributedGraph(num_shards=S, shard_size=sz, n=n, nnz_max=nnz)

    results = {}
    for tag, ecfg in (
        ("baseline_ps0.7_cap4", EngineConfig(num_frogs=800_000, num_steps=4,
                                             p_s=0.7, capacity_factor=4.0)),
        ("v1_cap2", EngineConfig(num_frogs=800_000, num_steps=4, p_s=0.7,
                                 capacity_factor=2.0)),
        ("ps1.0_cap4", EngineConfig(num_frogs=800_000, num_steps=4, p_s=1.0,
                                    capacity_factor=4.0)),
        ("ps0.4_cap4", EngineConfig(num_frogs=800_000, num_steps=4, p_s=0.4,
                                    capacity_factor=4.0)),
    ):
        t0 = time.time()
        lowered = frogwild_dryrun_lowered(dg, ecfg, mesh)
        compiled = lowered.compile()
        cost = analyze_hlo(compiled.as_text())
        mem = compiled.memory_analysis()
        live = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
        cap = channel_capacity(ecfg, S)
        res = {
            "tag": tag, "chips": S, "ok": True,
            "capacity_per_channel": cap,
            "collective_bytes_per_device": cost.collective_bytes,
            "collective_breakdown": cost.collective_breakdown,
            "live_bytes_per_device": live,
            "compile_s": round(time.time() - t0, 1),
        }
        os.makedirs(OUT, exist_ok=True)
        with open(os.path.join(OUT, f"engine_{tag}.json"), "w") as f:
            json.dump(res, f, indent=1)
        print(f"  {tag:22s} cap/channel={cap:5d} "
              f"a2a_bytes={cost.collective_bytes/1e6:8.2f}MB/dev "
              f"live={live/1e9:.3f}GB")
        results[tag] = res
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default="all",
                    choices=["all", "rwkv", "starcoder", "engine"])
    args = ap.parse_args()
    if args.cell in ("all", "rwkv"):
        cell_rwkv()
    if args.cell in ("all", "starcoder"):
        cell_starcoder()
    if args.cell in ("all", "engine"):
        cell_engine()


if __name__ == "__main__":
    main()
