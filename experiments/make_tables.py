"""Builds the EXPERIMENTS.md §Dry-run / §Roofline tables from the per-cell
JSONs in experiments/dryrun/."""
from __future__ import annotations

import glob
import json
import os
import sys

ORDER_SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ORDER_ARCHS = [
    "h2o-danube-3-4b", "starcoder2-7b", "gemma3-4b", "llama3.2-1b",
    "llava-next-mistral-7b", "olmoe-1b-7b", "phi3.5-moe-42b-a6.6b",
    "whisper-medium", "rwkv6-3b", "zamba2-1.2b",
]


def load(d="experiments/dryrun"):
    cells = {}
    for f in glob.glob(os.path.join(d, "*.json")):
        r = json.load(open(f))
        cells[(r["arch"], r["shape"], r["mesh"])] = r
    return cells


def fmt_bytes(b):
    return f"{b / 1e9:.2f}"


def roofline_table(cells, mesh="single"):
    """The single-pod roofline table (per brief) — 40 rows."""
    lines = [
        "| arch | shape | live GB/chip | fits | compute ms | memory ms | "
        "collective ms | dominant | useful | MFU* |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for a in ORDER_ARCHS:
        for s in ORDER_SHAPES:
            r = cells.get((a, s, mesh))
            if r is None:
                continue
            if "skipped" in r:
                lines.append(f"| {a} | {s} | — | — | — | — | — | SKIP | — | — |")
                continue
            ro = r["roofline"]
            m = r["memory"]
            lines.append(
                f"| {a} | {s} | {fmt_bytes(m['live_bytes_per_device'])} | "
                f"{'✓' if m['fits_hbm'] else '✗'} | "
                f"{ro['compute_s'] * 1e3:.1f} | {ro['memory_s'] * 1e3:.1f} | "
                f"{ro['collective_s'] * 1e3:.1f} | {ro['dominant']} | "
                f"{ro['useful_flops_ratio']:.2f} | {ro['hw_util']:.3f} |")
    return "\n".join(lines)


def multipod_table(cells):
    lines = [
        "| arch | shape | single live GB | multi live GB | multi compiles |",
        "|---|---|---|---|---|",
    ]
    for a in ORDER_ARCHS:
        for s in ORDER_SHAPES:
            r1 = cells.get((a, s, "single"))
            r2 = cells.get((a, s, "multi"))
            if r1 is None or r2 is None:
                continue
            if "skipped" in r1:
                lines.append(f"| {a} | {s} | SKIP | SKIP | — |")
                continue
            ok = "✓" if r2.get("ok") else "✗"
            g1 = fmt_bytes(r1["memory"]["live_bytes_per_device"])
            g2 = (fmt_bytes(r2["memory"]["live_bytes_per_device"])
                  if r2.get("ok") else "—")
            lines.append(f"| {a} | {s} | {g1} | {g2} | {ok} |")
    return "\n".join(lines)


def summary(cells):
    ok = sum(1 for r in cells.values() if r.get("ok"))
    skip = sum(1 for r in cells.values() if "skipped" in r)
    fail = len(cells) - ok - skip
    return f"{ok} lowered+compiled OK, {skip} skipped (justified), {fail} failed"


if __name__ == "__main__":
    cells = load(sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun")
    print("## summary\n", summary(cells), "\n")
    print("## roofline (single-pod, 256 chips)\n")
    print(roofline_table(cells))
    print("\n## multi-pod (512 chips)\n")
    print(multipod_table(cells))
