"""Layered runtime configuration — every dispatch flag defined exactly once.

Before PR 5 the same knobs (``draw``, ``step_impl``, ``p_s``, seed plumbing)
were declared independently on three per-subsystem dataclasses
(``FrogWildConfig`` for the walker oracle, ``EngineConfig`` for the
distributed engine, ``WalkIndexConfig`` for the index build), so a flag's
default — and its meaning — could drift between layers. This module is now
the single source of truth:

* :class:`KernelConfig`  — kernel dispatch flags (which backend executes a
  walker step / stitch round / tally — see ``kernels/README.md``);
* :class:`ShardConfig`   — placement and runtime shape (shard count, mesh
  axis, exchange-buffer slack, streaming block size, PRNG seed);
* :class:`ServingConfig` — walk-index geometry and scheduler shapes (the
  serving layer's fixed device-program dimensions);
* :class:`RuntimeConfig` — the walk process parameters (``N``, ``t``,
  ``p_T``, ``p_s``, erasure model) plus one instance of each layer above.
  This is the config :class:`repro.service.FrogWildService` consumes.

The legacy dataclasses still exist (tests and downstream code construct
them directly) but are **derived views**: they are defined here, their
shared-field defaults reference the layer defaults (one definition per
flag), and :meth:`RuntimeConfig.frogwild` / :meth:`RuntimeConfig.engine` /
:meth:`RuntimeConfig.walk_index` project a ``RuntimeConfig`` onto them.
The ``from_frogwild`` / ``from_engine`` / ``from_walk_index`` lifters go
the other way, so the deprecation shims can route a legacy call through
the service without changing a single bit of behaviour.

This module is dependency-free (no jax) so every layer can import it.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import TYPE_CHECKING, Optional, Tuple

if TYPE_CHECKING:  # stdlib-only module; safe for type checkers, but not
    # imported at runtime — this module stays jax- and repro-free.
    from repro.distributed.faults import FaultPlan

# Walk-process defaults (paper §2.2: N frogs, t supersteps, teleport p_T,
# synchronization probability p_s) — shared by RuntimeConfig and the legacy
# per-subsystem views.
DEFAULT_NUM_FROGS = 100_000
DEFAULT_NUM_STEPS = 4
DEFAULT_P_T = 0.15
DEFAULT_P_S = 1.0


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    """Kernel dispatch flags (see ``kernels/README.md`` for the full table).

    ``draw`` picks the blocking-walk scatter draw, ``step_impl`` the plain
    (p_s = 1) walker-step backend, ``stitch_impl`` the serving wave's
    stitch-round backend, ``tally_impl`` the endpoint histogram.
    """

    draw: str = "auto"          # auto | rejection | cumsum
    step_impl: str = "xla"      # xla | pallas | stream | auto | ref
    stitch_impl: str = "xla"    # xla | pallas | ref
    tally_impl: str = "ref"     # ref | sort | pallas | auto


@dataclasses.dataclass(frozen=True)
class ShardConfig:
    """Placement / runtime-shape layer.

    ``num_shards`` is the range-shard count used for the channel erasure
    granularity, engine placement, and sharded serving; ``vertex_block``
    enables the blocked CSR slabs the streaming step kernel needs.
    """

    num_shards: int = 1
    axis_name: str = "vertex"
    capacity_factor: float = 4.0     # engine per-channel buffer slack (≥ 1)
    vertex_block: Optional[int] = None
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    """Walk-index geometry + scheduler device-program shapes.

    ``build_shards`` is the *build-time* partitioning of the index (it
    determines the per-shard key folding, hence the slab content);
    ``checkpoint_dir`` makes the service persist / reuse the index through
    ``checkpoint/`` atomic step dirs.

    The fault-supervision knobs govern the scheduler's wave supervisor
    (``query/scheduler.py``): a wave that raises a transient fault or
    exceeds ``wave_timeout_s`` is retried up to ``max_retries`` times with
    exponential backoff + jitter before failing over (mesh → fused
    single-device dispatch) or raising; a permanent shard fault instead
    evicts the shard and serves degraded waves with a widened
    ``epsilon_bound``.

    The wave-program knobs govern dispatch and compilation:
    ``sharded_dispatch`` picks the single-device sharded wave — ``"fused"``
    (one compiled program: ``lax.scan`` over stitch rounds against the
    stacked slab) or ``"loop"`` (the legacy S × rounds host loop, kept as
    the byte-identity reference). ``walk_buckets`` / ``query_buckets``
    override the AOT wave-program ladder (each wave runs at the smallest
    bucket ≥ its allocation; ``None`` = the cap and its halvings), and
    ``aot_warmup`` pre-compiles every ladder bucket at scheduler build so
    serving never traces mid-wave. ``donate_wave_buffers`` donates the
    per-wave walk-state operands to the executable (buffer reuse instead
    of fresh allocations every wave).
    """

    segments_per_vertex: int = 16    # R — endpoints stored per vertex
    segment_len: int = 4             # L — steps per precomputed segment
    build_shards: int = 8            # index-build partitioning
    max_walks: int = 8192            # walk slots per wave
    max_queries: int = 8             # query slots per wave
    max_steps: int = 32              # walk-truncation cap for query plans
    checkpoint_dir: Optional[str] = None
    wave_time_estimate_s: Optional[float] = None  # seeds the admission EMA
    wave_timeout_s: Optional[float] = None  # per-wave deadline (None = off)
    max_retries: int = 2             # bounded retry of a faulted wave
    backoff_base_s: float = 0.02     # exponential backoff: base · 2^(a−1)
    backoff_max_s: float = 0.5       # … clamped here (± jitter)
    sharded_dispatch: str = "fused"  # single-device sharded wave: fused | loop
    donate_wave_buffers: bool = True  # donate walk-state operands to XLA
    walk_buckets: Optional[Tuple[int, ...]] = None   # AOT ladder override
    query_buckets: Optional[Tuple[int, ...]] = None  # AOT ladder override
    aot_warmup: bool = False         # pre-compile the ladder at build time


_KERNEL = KernelConfig()
_SHARD = ShardConfig()
_SERVING = ServingConfig()


@dataclasses.dataclass(frozen=True)
class RuntimeConfig:
    """The one config the :class:`repro.service.FrogWildService` consumes.

    Walk-process parameters live at the top level; everything about *how*
    the process executes lives in the three layers. Derive the legacy
    per-subsystem views with :meth:`frogwild` / :meth:`engine` /
    :meth:`walk_index`.
    """

    num_frogs: int = DEFAULT_NUM_FROGS
    num_steps: int = DEFAULT_NUM_STEPS
    p_T: float = DEFAULT_P_T
    p_s: float = DEFAULT_P_S
    erasure: str = "none"            # none | independent | channel
    kernel: KernelConfig = _KERNEL
    runtime: ShardConfig = _SHARD
    serving: ServingConfig = _SERVING
    # Deterministic fault-injection schedule (repro.distributed.faults.
    # FaultPlan) threaded to the scheduler's wave supervisor; None = no
    # injection (the supervisor still handles real faults/timeouts).
    faults: Optional["FaultPlan"] = None

    # --- projections onto the legacy per-subsystem views -----------------

    def frogwild(self) -> "FrogWildConfig":
        return FrogWildConfig(
            num_frogs=self.num_frogs, num_steps=self.num_steps,
            p_T=self.p_T, p_s=self.p_s, erasure=self.erasure,
            num_shards=max(1, self.runtime.num_shards),
            draw=self.kernel.draw, step_impl=self.kernel.step_impl,
        )

    def engine(self) -> "EngineConfig":
        return EngineConfig(
            num_frogs=self.num_frogs, num_steps=self.num_steps,
            p_T=self.p_T, p_s=self.p_s,
            capacity_factor=self.runtime.capacity_factor,
            axis_name=self.runtime.axis_name,
            draw=self.kernel.draw, step_impl=self.kernel.step_impl,
        )

    def walk_index(self) -> "WalkIndexConfig":
        return WalkIndexConfig(
            segments_per_vertex=self.serving.segments_per_vertex,
            segment_len=self.serving.segment_len,
            num_shards=self.serving.build_shards,
            step_impl=self.kernel.step_impl,
            seed=self.runtime.seed,
        )

    # --- lifters from the legacy views (used by the deprecation shims) ---

    @classmethod
    def from_frogwild(cls, cfg: "FrogWildConfig") -> "RuntimeConfig":
        return cls(
            num_frogs=cfg.num_frogs, num_steps=cfg.num_steps, p_T=cfg.p_T,
            p_s=cfg.p_s, erasure=cfg.erasure,
            kernel=KernelConfig(draw=cfg.draw, step_impl=cfg.step_impl),
            runtime=ShardConfig(num_shards=cfg.num_shards),
        )

    @classmethod
    def from_engine(cls, cfg: "EngineConfig",
                    num_shards: int = 1) -> "RuntimeConfig":
        return cls(
            num_frogs=cfg.num_frogs, num_steps=cfg.num_steps, p_T=cfg.p_T,
            p_s=cfg.p_s, erasure="channel" if cfg.p_s < 1.0 else "none",
            kernel=KernelConfig(draw=cfg.draw, step_impl=cfg.step_impl),
            runtime=ShardConfig(num_shards=num_shards,
                                axis_name=cfg.axis_name,
                                capacity_factor=cfg.capacity_factor),
        )

    @classmethod
    def from_walk_index(cls, cfg: "WalkIndexConfig") -> "RuntimeConfig":
        return cls(
            kernel=KernelConfig(step_impl=cfg.step_impl),
            runtime=ShardConfig(seed=cfg.seed),
            serving=ServingConfig(
                segments_per_vertex=cfg.segments_per_vertex,
                segment_len=cfg.segment_len, build_shards=cfg.num_shards),
        )


# ---------------------------------------------------------------------------
# Legacy per-subsystem views. Field *sets* are frozen for back-compat; the
# shared-flag defaults reference the layer defaults above so each flag has
# exactly one definition. New code should construct a RuntimeConfig and use
# the service facade; these remain for the deprecation shims and tests.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FrogWildConfig:
    """Walker-oracle view (``core/frogwild.py``). ``num_shards`` here is the
    channel-erasure granularity (destination range shards)."""

    num_frogs: int = DEFAULT_NUM_FROGS
    num_steps: int = DEFAULT_NUM_STEPS
    p_T: float = DEFAULT_P_T
    p_s: float = DEFAULT_P_S
    erasure: str = "none"            # none | independent | channel
    num_shards: int = 16             # channel model: destination shards
    draw: str = _KERNEL.draw
    step_impl: str = _KERNEL.step_impl


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Distributed-engine view (``engine/gas.py``); the shard count comes
    from the mesh, not the config."""

    num_frogs: int = DEFAULT_NUM_FROGS
    num_steps: int = DEFAULT_NUM_STEPS
    p_T: float = DEFAULT_P_T
    p_s: float = DEFAULT_P_S
    capacity_factor: float = _SHARD.capacity_factor
    axis_name: str = _SHARD.axis_name
    draw: str = _KERNEL.draw
    step_impl: str = _KERNEL.step_impl
    # "stream"/"auto" need the blocked slabs
    # (build_distributed_graph(vertex_block=...)).


@dataclasses.dataclass(frozen=True)
class WalkIndexConfig:
    """Index-build view (``query/index.py``). ``num_shards`` is the build
    partitioning — it determines the per-shard key folding and therefore
    the slab content."""

    segments_per_vertex: int = _SERVING.segments_per_vertex
    segment_len: int = _SERVING.segment_len
    num_shards: int = _SERVING.build_shards
    step_impl: str = _KERNEL.step_impl
    seed: int = _SHARD.seed


def warn_deprecated(old: str, new: str) -> None:
    """One-liner for the legacy entry-point shims (stacklevel points at the
    caller of the deprecated function, not the shim)."""
    warnings.warn(
        f"{old} is deprecated; use {new} (see repro/service.py)",
        DeprecationWarning, stacklevel=3,
    )


__all__ = [
    "KernelConfig",
    "ShardConfig",
    "ServingConfig",
    "RuntimeConfig",
    "FrogWildConfig",
    "EngineConfig",
    "WalkIndexConfig",
]
