"""Graph substrate: CSR storage, synthetic generators, vertex partitioning.

Graphs are immutable, host-generated (numpy) and converted to device arrays
once. All downstream code (core walkers, distributed engine, kernels) consumes
the :class:`~repro.graph.csr.CSRGraph` container.
"""
from repro.graph.csr import (CSRGraph, build_csr, load_graph, save_graph,
                             transition_edges, uniform_successor)
from repro.graph.generators import (
    barabasi_albert,
    chung_lu_powerlaw,
    uniform_random,
    ring_of_cliques,
)
from repro.graph.partition import VertexPartition, partition_graph, to_ell

__all__ = [
    "CSRGraph",
    "build_csr",
    "load_graph",
    "save_graph",
    "transition_edges",
    "uniform_successor",
    "barabasi_albert",
    "chung_lu_powerlaw",
    "uniform_random",
    "ring_of_cliques",
    "VertexPartition",
    "partition_graph",
    "to_ell",
]
