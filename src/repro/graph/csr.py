"""Compressed-sparse-row storage for directed graphs.

Conventions (paper §2.1):
  * ``A[i, j] = 1`` iff there is an edge ``j -> i``.
  * ``P[i, j] = A[i, j] / d_out(j)`` — column-stochastic transition matrix.
  * Every vertex has ``d_out(j) > 0`` (generators enforce this by adding a
    uniform random out-edge to any dangling vertex).

We store **out-edges in CSR by source vertex**: ``col_idx[row_ptr[v] :
row_ptr[v + 1]]`` are the successors of ``v``. This is the layout both the
walker oracle (gather successor by slot) and the distributed engine (each
shard owns a contiguous row block) want.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class CSRGraph:
    """A directed graph in CSR (by source vertex) form.

    Attributes:
      n:        number of vertices.
      row_ptr:  int32[n + 1]  — CSR offsets into ``col_idx``.
      col_idx:  int32[nnz]    — destination vertex of each out-edge.
      out_deg:  int32[n]      — ``row_ptr[1:] - row_ptr[:-1]`` (cached).
    """

    n: int
    row_ptr: jnp.ndarray
    col_idx: jnp.ndarray
    out_deg: jnp.ndarray

    @property
    def nnz(self) -> int:
        return int(self.col_idx.shape[0])

    @property
    def max_out_deg(self) -> int:
        return int(np.asarray(self.out_deg).max())

    def edge_range(self, v: int) -> Tuple[int, int]:
        rp = np.asarray(self.row_ptr)
        return int(rp[v]), int(rp[v + 1])

    def successors(self, v: int) -> np.ndarray:
        lo, hi = self.edge_range(v)
        return np.asarray(self.col_idx[lo:hi])

    def to_numpy(self) -> "CSRGraph":
        return CSRGraph(
            n=self.n,
            row_ptr=np.asarray(self.row_ptr),
            col_idx=np.asarray(self.col_idx),
            out_deg=np.asarray(self.out_deg),
        )


def build_csr(n: int, src: np.ndarray, dst: np.ndarray) -> CSRGraph:
    """Builds a CSRGraph from an edge list, fixing dangling vertices.

    Any vertex with zero out-degree receives a single out-edge to a
    deterministic pseudo-random target (hash of the vertex id), preserving the
    paper's assumption ``d_out > 0``. Duplicate edges are kept (multi-edges
    are legal and correspond to proportionally higher transition probability).
    """
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    if src.shape != dst.shape:
        raise ValueError(f"src/dst shape mismatch: {src.shape} vs {dst.shape}")
    if src.size and (src.min() < 0 or src.max() >= n or dst.min() < 0 or dst.max() >= n):
        raise ValueError("edge endpoints out of range")

    deg = np.bincount(src, minlength=n)
    dangling = np.nonzero(deg == 0)[0]
    if dangling.size:
        # Deterministic "random" target for reproducibility.
        fix_dst = (dangling * 2654435761 + 12345) % n
        # avoid pure self-loops on dangling fixes
        fix_dst = np.where(fix_dst == dangling, (fix_dst + 1) % n, fix_dst)
        src = np.concatenate([src, dangling])
        dst = np.concatenate([dst, fix_dst])
        deg = np.bincount(src, minlength=n)

    order = np.argsort(src, kind="stable")
    col = dst[order]
    row_ptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(deg, out=row_ptr[1:])
    return CSRGraph(
        n=n,
        row_ptr=jnp.asarray(row_ptr, dtype=jnp.int32),
        col_idx=jnp.asarray(col, dtype=jnp.int32),
        out_deg=jnp.asarray(deg, dtype=jnp.int32),
    )


def transition_edges(g: CSRGraph) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns ``(src, dst, weight)`` per edge with ``weight = 1/d_out(src)``.

    This is matrix ``P`` in COO form: ``(P x)[i] = sum_{e: dst==i} w_e x[src_e]``.
    Used by the power-iteration baseline and the jnp SpMV oracle.
    """
    rp = np.asarray(g.row_ptr)
    deg = np.asarray(g.out_deg)
    src = np.repeat(np.arange(g.n, dtype=np.int64), deg)
    w = 1.0 / deg[src].astype(np.float64)
    return (
        jnp.asarray(src, dtype=jnp.int32),
        jnp.asarray(g.col_idx, dtype=jnp.int32),
        jnp.asarray(w, dtype=jnp.float32),
    )


def adjacency_dense(g: CSRGraph) -> np.ndarray:
    """Dense column-stochastic P (tests only — O(n^2) memory)."""
    gn = g.to_numpy()
    P = np.zeros((g.n, g.n), dtype=np.float64)
    for v in range(g.n):
        lo, hi = gn.row_ptr[v], gn.row_ptr[v + 1]
        for u in gn.col_idx[lo:hi]:
            P[int(u), v] += 1.0 / (hi - lo)
    return P
