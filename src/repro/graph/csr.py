"""Compressed-sparse-row storage for directed graphs.

Conventions (paper §2.1):
  * ``A[i, j] = 1`` iff there is an edge ``j -> i``.
  * ``P[i, j] = A[i, j] / d_out(j)`` — column-stochastic transition matrix.
  * Every vertex has ``d_out(j) > 0`` (generators enforce this by adding a
    uniform random out-edge to any dangling vertex).

We store **out-edges in CSR by source vertex**: ``col_idx[row_ptr[v] :
row_ptr[v + 1]]`` are the successors of ``v``. This is the layout both the
walker oracle (gather successor by slot) and the distributed engine (each
shard owns a contiguous row block) want.
"""
from __future__ import annotations

import dataclasses

import jax
from typing import Tuple

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class CSRGraph:
    """A directed graph in CSR (by source vertex) form.

    Attributes:
      n:        number of vertices.
      row_ptr:  int32[n + 1]  — CSR offsets into ``col_idx``.
      col_idx:  int32[nnz]    — destination vertex of each out-edge.
      out_deg:  int32[n]      — ``row_ptr[1:] - row_ptr[:-1]`` (cached).
      epoch:    mutation epoch this CSR compacts (0 = never mutated; each
                applied :class:`~repro.dynamic.MutationBatch` produces a
                new CSR at ``epoch + 1``).
      mutation_offset: total edge mutations folded into this CSR across
                all epochs — the mutation-log offset checkpoint manifests
                carry so a loaded (graph, slab) pair can be cross-checked.

    Derived per-edge arrays (``edge_src``, ``edge_dst_shard``) are computed
    lazily and memoized on the instance: every ``frogwild_run`` / engine
    build over the same graph reuses them instead of re-deriving O(nnz)
    arrays per call.
    """

    n: int
    row_ptr: jnp.ndarray
    col_idx: jnp.ndarray
    out_deg: jnp.ndarray
    _derived: dict = dataclasses.field(
        default_factory=dict, repr=False, compare=False
    )
    epoch: int = 0
    mutation_offset: int = 0

    @property
    def nnz(self) -> int:
        return int(self.col_idx.shape[0])

    @property
    def edge_src(self) -> jnp.ndarray:
        """int32[nnz] — source vertex of each edge (memoized)."""
        if "edge_src" not in self._derived:
            # ensure_compile_time_eval: memoized arrays must be concrete even
            # when first touched inside a jit trace (else the cache would
            # leak tracers into later traces).
            with jax.ensure_compile_time_eval():
                self._derived["edge_src"] = jnp.repeat(
                    jnp.arange(self.n, dtype=jnp.int32),
                    self.out_deg,
                    total_repeat_length=self.nnz,
                )
        return self._derived["edge_src"]

    def shard_size(self, num_shards: int) -> int:
        """Vertices per range shard (ceil division)."""
        return max(1, -(-self.n // num_shards))

    def edge_dst_shard(self, num_shards: int) -> jnp.ndarray:
        """int32[nnz] — destination range-shard of each edge (memoized per
        shard count). This is the channel id granularity of the engine's
        mirror synchronization."""
        key = ("edge_dst_shard", num_shards)
        if key not in self._derived:
            with jax.ensure_compile_time_eval():
                self._derived[key] = (
                    self.col_idx.astype(jnp.int32)
                    // self.shard_size(num_shards)
                )
        return self._derived[key]

    def channel_layout(self, num_shards: int):
        """Channel-grouped edge layout for the exact blocking draw (memoized).

        Returns ``(col_sorted, chan_cnt, chan_off)``:
          * ``col_sorted`` int32[nnz] — ``col_idx`` with each vertex's edges
            stably reordered by destination shard;
          * ``chan_cnt``  int32[n, S] — edges of v into shard d;
          * ``chan_off``  int32[n, S] — offset of (v, d)'s first edge within
            v's CSR segment of ``col_sorted``.
        """
        key = ("channel_layout", num_shards)
        if key not in self._derived:
            rp = np.asarray(self.row_ptr).astype(np.int64)
            col = np.asarray(self.col_idx).astype(np.int64)
            src = np.asarray(self.edge_src).astype(np.int64)
            ds = col // self.shard_size(num_shards)
            # stable sort by (source vertex, destination shard)
            order = np.lexsort((ds, src))
            cnt = np.zeros((self.n, num_shards), dtype=np.int64)
            np.add.at(cnt, (src, ds), 1)
            off = np.cumsum(cnt, axis=1) - cnt
            with jax.ensure_compile_time_eval():
                self._derived[key] = (
                    jnp.asarray(col[order], dtype=jnp.int32),
                    jnp.asarray(cnt, dtype=jnp.int32),
                    jnp.asarray(off, dtype=jnp.int32),
                )
        return self._derived[key]

    @property
    def max_out_deg(self) -> int:
        return int(np.asarray(self.out_deg).max())

    def edge_range(self, v: int) -> Tuple[int, int]:
        rp = np.asarray(self.row_ptr)
        return int(rp[v]), int(rp[v + 1])

    def successors(self, v: int) -> np.ndarray:
        lo, hi = self.edge_range(v)
        return np.asarray(self.col_idx[lo:hi])

    def to_numpy(self) -> "CSRGraph":
        return CSRGraph(
            n=self.n,
            row_ptr=np.asarray(self.row_ptr),
            col_idx=np.asarray(self.col_idx),
            out_deg=np.asarray(self.out_deg),
            epoch=self.epoch,
            mutation_offset=self.mutation_offset,
        )


def build_csr(
    n: int, src: np.ndarray, dst: np.ndarray, dangling: str = "hash"
) -> CSRGraph:
    """Builds a CSRGraph from an edge list, fixing dangling vertices.

    The ``dangling`` policy restores the paper's assumption ``d_out > 0``:

    * ``"hash"``      — (default) one out-edge to a deterministic
                        pseudo-random target (hash of the vertex id); the
                        teleport-like convention every generator uses.
    * ``"self_loop"`` — one self-loop, so a walker parked on a dangling
                        vertex stays there until it dies. This matches the
                        walkers' runtime guard (``plain_move`` holds a frog in
                        place when ``d_out == 0``), making the guard and the
                        graph repair two views of the same convention.

    Duplicate edges are kept (multi-edges are legal and correspond to
    proportionally higher transition probability).
    """
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    if src.shape != dst.shape:
        raise ValueError(f"src/dst shape mismatch: {src.shape} vs {dst.shape}")
    if src.size and (src.min() < 0 or src.max() >= n or dst.min() < 0 or dst.max() >= n):
        raise ValueError("edge endpoints out of range")

    deg = np.bincount(src, minlength=n)
    dangling_v = np.nonzero(deg == 0)[0]
    if dangling_v.size:
        if dangling == "hash":
            # Deterministic "random" target for reproducibility.
            fix_dst = (dangling_v * 2654435761 + 12345) % n
            # avoid pure self-loops on dangling fixes
            fix_dst = np.where(fix_dst == dangling_v, (fix_dst + 1) % n, fix_dst)
        elif dangling == "self_loop":
            fix_dst = dangling_v
        else:
            raise ValueError(f"unknown dangling policy {dangling!r}")
        src = np.concatenate([src, dangling_v])
        dst = np.concatenate([dst, fix_dst])
        deg = np.bincount(src, minlength=n)

    order = np.argsort(src, kind="stable")
    col = dst[order]
    row_ptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(deg, out=row_ptr[1:])
    return CSRGraph(
        n=n,
        row_ptr=jnp.asarray(row_ptr, dtype=jnp.int32),
        col_idx=jnp.asarray(col, dtype=jnp.int32),
        out_deg=jnp.asarray(deg, dtype=jnp.int32),
    )


def save_graph(path: str, g: CSRGraph) -> str:
    """Persists a graph as a single ``.npz`` (the service-facade ingestion
    format — ``FrogWildService.open`` accepts this path directly).

    The manifest carries the graph's mutation ``epoch`` and
    ``mutation_offset`` so a loaded (graph, walk-index) pair can be
    epoch-checked — a slab built at a different epoch fails loudly at
    ``ensure_index`` instead of silently serving stale answers.
    """
    gn = g.to_numpy()
    np.savez_compressed(path, n=np.int64(g.n), row_ptr=gn.row_ptr,
                        col_idx=gn.col_idx, epoch=np.int64(g.epoch),
                        mutation_offset=np.int64(g.mutation_offset))
    return path if path.endswith(".npz") else path + ".npz"


def load_graph(path: str) -> CSRGraph:
    """Restores a :func:`save_graph` ``.npz`` (degrees are re-derived).

    Files written before epochs existed load at ``epoch = 0`` /
    ``mutation_offset = 0`` — the never-mutated provenance.
    """
    with np.load(path) as z:
        n = int(z["n"])
        row_ptr = np.asarray(z["row_ptr"], dtype=np.int64)
        col_idx = np.asarray(z["col_idx"], dtype=np.int64)
        epoch = int(z["epoch"]) if "epoch" in z else 0
        offset = int(z["mutation_offset"]) if "mutation_offset" in z else 0
    if row_ptr.shape != (n + 1,):
        raise ValueError(
            f"{path!r}: row_ptr has shape {row_ptr.shape}, wanted ({n + 1},)")
    deg = row_ptr[1:] - row_ptr[:-1]
    return CSRGraph(
        n=n,
        row_ptr=jnp.asarray(row_ptr, dtype=jnp.int32),
        col_idx=jnp.asarray(col_idx, dtype=jnp.int32),
        out_deg=jnp.asarray(deg, dtype=jnp.int32),
        epoch=epoch,
        mutation_offset=offset,
    )


def uniform_successor(
    row_ptr: jnp.ndarray,
    col_idx: jnp.ndarray,
    deg: jnp.ndarray,
    pos: jnp.ndarray,
    bits: jnp.ndarray,
) -> jnp.ndarray:
    """One uniform out-edge hop per walker, vectorized over ``pos``.

    ``next = col_idx[row_ptr[pos] + bits % d_out(pos)]``, with the dangling
    guard: ``d_out == 0`` ⇒ the walker stays put (the self-loop convention,
    see :func:`build_csr`). The single definition of the plain walker hop —
    used by the core oracle's ``plain_move``, the walk-index build, and the
    query engine's residual steps, so the dangling policy can never diverge
    between offline and online walks.
    """
    slot = bits % jnp.maximum(deg[pos], 1)
    nxt = col_idx[row_ptr[pos] + slot]
    return jnp.where(deg[pos] > 0, nxt, pos)


def transition_edges(g: CSRGraph) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns ``(src, dst, weight)`` per edge with ``weight = 1/d_out(src)``.

    This is matrix ``P`` in COO form: ``(P x)[i] = sum_{e: dst==i} w_e x[src_e]``.
    Used by the power-iteration baseline and the jnp SpMV oracle.
    """
    rp = np.asarray(g.row_ptr)
    deg = np.asarray(g.out_deg)
    src = np.repeat(np.arange(g.n, dtype=np.int64), deg)
    w = 1.0 / deg[src].astype(np.float64)
    return (
        jnp.asarray(src, dtype=jnp.int32),
        jnp.asarray(g.col_idx, dtype=jnp.int32),
        jnp.asarray(w, dtype=jnp.float32),
    )


def adjacency_dense(g: CSRGraph) -> np.ndarray:
    """Dense column-stochastic P (tests only — O(n^2) memory)."""
    gn = g.to_numpy()
    P = np.zeros((g.n, g.n), dtype=np.float64)
    for v in range(g.n):
        lo, hi = gn.row_ptr[v], gn.row_ptr[v + 1]
        for u in gn.col_idx[lo:hi]:
            P[int(u), v] += 1.0 / (hi - lo)
    return P
