"""Vertex partitioning and kernel-friendly formats.

The distributed engine range-shards vertices: shard ``s`` owns vertices
``[s * n_per, (s + 1) * n_per)`` and the CSR row-block of their out-edges.
This plays the role of GraphLab's vertex placement; the *frontier exchange*
between shards plays the role of mirror synchronization (DESIGN.md §2).

``to_ell`` converts CSR to a padded ELLPACK layout (``idx[n, K]`` +
``valid[n, K]``) consumed by the Pallas SpMV kernel: regular rows live in the
ELL slab, and rows with out-degree > K (power-law hubs) are split — their
first K edges stay in the slab and the remainder spills to a COO tail that
the ops wrapper applies with a segment-sum. The hybrid keeps the slab narrow
(memory ∝ n·K) while hubs stay exact.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp
import numpy as np

from repro.graph.csr import CSRGraph


@dataclasses.dataclass(frozen=True)
class VertexPartition:
    """Range partition of vertices over ``num_shards`` shards.

    Vertices are padded to a multiple of ``num_shards``; padded vertices have
    a single self-loop and never receive frogs (start distribution excludes
    them), so they do not perturb the process.
    """

    num_shards: int
    n: int                 # original vertex count
    n_padded: int          # padded to a multiple of num_shards
    shard_size: int        # n_padded // num_shards

    def shard_of(self, v: np.ndarray) -> np.ndarray:
        return v // self.shard_size

    def bounds(self, s: int) -> Tuple[int, int]:
        return s * self.shard_size, (s + 1) * self.shard_size


def partition_graph(g: CSRGraph, num_shards: int) -> Tuple[CSRGraph, VertexPartition]:
    """Pads ``g`` so ``n`` divides ``num_shards`` and returns the partition.

    Padding vertices get one self-loop (never visited; keeps CSR well-formed
    and out-degrees positive so vectorized code needs no special cases).
    """
    n = g.n
    n_padded = ((n + num_shards - 1) // num_shards) * num_shards
    part = VertexPartition(
        num_shards=num_shards, n=n, n_padded=n_padded,
        shard_size=n_padded // num_shards,
    )
    if n_padded == n:
        return g, part

    gn = g.to_numpy()
    pad = n_padded - n
    row_ptr = np.concatenate([
        gn.row_ptr,
        gn.row_ptr[-1] + 1 + np.arange(pad, dtype=gn.row_ptr.dtype),
    ])
    col_idx = np.concatenate([gn.col_idx, np.arange(n, n_padded, dtype=gn.col_idx.dtype)])
    out_deg = np.concatenate([gn.out_deg, np.ones(pad, dtype=gn.out_deg.dtype)])
    gp = CSRGraph(
        n=n_padded,
        row_ptr=jnp.asarray(row_ptr, dtype=jnp.int32),
        col_idx=jnp.asarray(col_idx, dtype=jnp.int32),
        out_deg=jnp.asarray(out_deg, dtype=jnp.int32),
    )
    return gp, part


@dataclasses.dataclass(frozen=True)
class EllGraph:
    """Hybrid ELL + COO-spill layout for the SpMV kernel.

    Attributes:
      idx:    int32[n_rows, K] — destination ids; garbage where ``~valid``.
      valid:  bool [n_rows, K]
      weight: f32  [n_rows, K] — 1/d_out(src) transition weights (0 if invalid).
      spill_src/spill_dst/spill_w: COO tail for rows with degree > K.

    Orientation note: the SpMV computes ``y = P @ x`` with
    ``P[i, j] = A[i, j]/d_out(j)``, i.e. *pull* form — row i of the ELL slab
    lists the **predecessors** of vertex i. ``to_ell`` therefore transposes
    the (source-CSR) graph internally.
    """

    n_rows: int
    K: int
    idx: jnp.ndarray
    valid: jnp.ndarray
    weight: jnp.ndarray
    spill_src: jnp.ndarray
    spill_dst: jnp.ndarray
    spill_w: jnp.ndarray

    @property
    def spill_nnz(self) -> int:
        return int(self.spill_src.shape[0])


def to_ell(g: CSRGraph, K: int = 32, row_pad: int = 8) -> EllGraph:
    """Converts to pull-oriented hybrid ELL (see :class:`EllGraph`).

    Args:
      g: source-CSR graph.
      K: ELL slab width (edges per row kept in the regular slab). Rounded up
        to a multiple of 8 for TPU lane friendliness.
      row_pad: rows are padded to a multiple of this.
    """
    K = int(np.ceil(K / 8) * 8)
    gn = g.to_numpy()
    deg = gn.out_deg.astype(np.int64)
    src = np.repeat(np.arange(g.n, dtype=np.int64), deg)
    dst = gn.col_idx.astype(np.int64)
    w = (1.0 / deg[src]).astype(np.float32)

    # Pull orientation: group edges by destination.
    order = np.argsort(dst, kind="stable")
    by_dst_src = src[order]
    by_dst_dst = dst[order]
    by_dst_w = w[order]
    in_deg = np.bincount(by_dst_dst, minlength=g.n)
    in_ptr = np.zeros(g.n + 1, dtype=np.int64)
    np.cumsum(in_deg, out=in_ptr[1:])

    n_rows = int(np.ceil(g.n / row_pad) * row_pad)
    idx = np.zeros((n_rows, K), dtype=np.int32)
    valid = np.zeros((n_rows, K), dtype=bool)
    weight = np.zeros((n_rows, K), dtype=np.float32)
    spill_s: list[np.ndarray] = []
    spill_d: list[np.ndarray] = []
    spill_w: list[np.ndarray] = []
    for i in range(g.n):
        lo, hi = in_ptr[i], in_ptr[i + 1]
        k = min(K, hi - lo)
        idx[i, :k] = by_dst_src[lo : lo + k]
        valid[i, :k] = True
        weight[i, :k] = by_dst_w[lo : lo + k]
        if hi - lo > K:
            spill_s.append(by_dst_src[lo + K : hi])
            spill_d.append(by_dst_dst[lo + K : hi])
            spill_w.append(by_dst_w[lo + K : hi])

    def _cat(parts, dtype):
        if parts:
            return jnp.asarray(np.concatenate(parts), dtype=dtype)
        return jnp.zeros((0,), dtype=dtype)

    return EllGraph(
        n_rows=n_rows,
        K=K,
        idx=jnp.asarray(idx),
        valid=jnp.asarray(valid),
        weight=jnp.asarray(weight),
        spill_src=_cat(spill_s, jnp.int32),
        spill_dst=_cat(spill_d, jnp.int32),
        spill_w=_cat(spill_w, jnp.float32),
    )
