"""Synthetic graph generators.

The paper evaluates on Twitter (41.6M vertices / 1.4B edges) and LiveJournal
(4.8M / 69M). Those datasets cannot be fetched in this offline container, so
we generate synthetic graphs with the property the paper's analysis leans on:
a **power-law PageRank tail** (paper §2.3, θ ≈ 2.2, [Becchetti & Castillo]).

``chung_lu_powerlaw`` draws destination vertices proportionally to power-law
weights, which yields power-law in-degree and hence power-law PageRank — the
regime where top-k approximation with few frogs is information-theoretically
easy and where Proposition 7's ‖π‖∞ ≤ n^{-γ} bound bites.

All generators are numpy-only, seeded, and return :class:`CSRGraph`.
"""
from __future__ import annotations

import numpy as np

from repro.graph.csr import CSRGraph, build_csr


def chung_lu_powerlaw(
    n: int,
    avg_out_deg: float = 16.0,
    theta: float = 2.2,
    seed: int = 0,
    self_loops: bool = False,
) -> CSRGraph:
    """Directed Chung–Lu-style graph with power-law *in*-degree.

    Vertex ``i`` receives edges with probability proportional to
    ``w_i = (i + 1)^(-1/(theta - 1))`` (Zipf-like weights whose empirical
    distribution is a power law with exponent ``theta``). Out-degrees are
    ``1 + Poisson(avg_out_deg - 1)`` so every vertex has at least one
    successor (paper assumption d_out > 0).
    """
    rng = np.random.default_rng(seed)
    out_deg = 1 + rng.poisson(max(avg_out_deg - 1.0, 0.0), size=n)
    m = int(out_deg.sum())
    src = np.repeat(np.arange(n, dtype=np.int64), out_deg)

    alpha = 1.0 / (theta - 1.0)
    w = (np.arange(1, n + 1, dtype=np.float64)) ** (-alpha)
    # Permute so that heavy vertices are scattered across the id space —
    # otherwise range partitioning would put every hub on shard 0.
    perm = rng.permutation(n)
    w = w[perm.argsort()]  # w_perm[i] = weight of vertex i
    cdf = np.cumsum(w)
    cdf /= cdf[-1]
    dst = np.searchsorted(cdf, rng.random(m), side="left").astype(np.int64)
    dst = np.minimum(dst, n - 1)
    if not self_loops:
        loop = dst == src
        dst[loop] = (dst[loop] + 1) % n
    return build_csr(n, src, dst)


def barabasi_albert(n: int, m: int = 8, seed: int = 0) -> CSRGraph:
    """Directed preferential-attachment graph (each new vertex points at m
    existing vertices chosen by degree-biased sampling)."""
    rng = np.random.default_rng(seed)
    m = max(1, min(m, n - 1))
    # Repeated-nodes list trick for preferential attachment.
    targets = list(range(m))
    repeated: list[int] = list(range(m))
    src_l: list[int] = []
    dst_l: list[int] = []
    for v in range(m, n):
        for t in targets:
            src_l.append(v)
            dst_l.append(t)
            repeated.append(t)
            repeated.append(v)
        k = min(m, len(repeated))
        idx = rng.integers(0, len(repeated), size=k)
        targets = [repeated[i] for i in idx]
    # Early vertices (0..m-1) get out-edges from build_csr's dangling fix,
    # plus a ring so they participate.
    for v in range(m):
        src_l.append(v)
        dst_l.append((v + 1) % n)
    return build_csr(n, np.asarray(src_l), np.asarray(dst_l))


def uniform_random(n: int, avg_out_deg: float = 8.0, seed: int = 0) -> CSRGraph:
    """Erdős–Rényi-style directed graph: destinations uniform over [n]."""
    rng = np.random.default_rng(seed)
    out_deg = 1 + rng.poisson(max(avg_out_deg - 1.0, 0.0), size=n)
    src = np.repeat(np.arange(n, dtype=np.int64), out_deg)
    dst = rng.integers(0, n, size=src.shape[0], dtype=np.int64)
    loop = dst == src
    dst[loop] = (dst[loop] + 1) % n
    return build_csr(n, src, dst)


def ring_of_cliques(num_cliques: int, clique_size: int) -> CSRGraph:
    """Deterministic test graph: cliques joined in a ring. Known structure
    makes PageRank analytically predictable (all vertices near-uniform except
    bridge vertices), handy for unit tests."""
    n = num_cliques * clique_size
    src_l: list[int] = []
    dst_l: list[int] = []
    for c in range(num_cliques):
        base = c * clique_size
        for i in range(clique_size):
            for j in range(clique_size):
                if i != j:
                    src_l.append(base + i)
                    dst_l.append(base + j)
        # bridge edge to next clique
        src_l.append(base)
        dst_l.append(((c + 1) % num_cliques) * clique_size)
    return build_csr(n, np.asarray(src_l), np.asarray(dst_l))
