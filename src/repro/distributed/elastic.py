"""Elastic scaling: move a training state between mesh shapes.

A checkpoint written on mesh A restores onto mesh B (different chip count /
topology) because checkpoints store *unsharded* host arrays and restore
re-places them with the target mesh's PartitionSpecs
(checkpoint/checkpointer.py). This module adds the live-resize path:
``reshard_state`` re-places an in-memory state onto a new mesh — the
node-failure / scale-up recovery primitive (lose a pod → rebuild the mesh
from survivors → reshard → continue).
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding

from repro.distributed.sharding import MeshAxes, param_pspecs
from repro.models.config import ModelConfig


def reshard_state(state: Any, pspecs: Any, new_mesh: Mesh) -> Any:
    """device_put every leaf with the new mesh's sharding. Works across any
    mesh-shape change whose axes still divide the leaf dims (the rules in
    distributed/sharding.py degrade to replication otherwise)."""
    return jax.tree.map(
        lambda a, s: jax.device_put(a, NamedSharding(new_mesh, s)),
        state, pspecs,
    )


def reshard_train_state(
    state: Any, cfg: ModelConfig, new_mesh: Mesh, fsdp: bool = False
) -> Any:
    ax = MeshAxes.for_mesh(new_mesh, fsdp=fsdp)
    pspecs = param_pspecs(cfg, new_mesh, state["params"], ax)
    out = dict(state)
    out["params"] = reshard_state(state["params"], pspecs, new_mesh)
    if "opt" in state:
        opt = dict(state["opt"])
        for k in ("m", "v"):
            opt[k] = reshard_state(state["opt"][k], pspecs, new_mesh)
        out["opt"] = opt
    return out
