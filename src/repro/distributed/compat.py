"""jax version-compat shims (container jax 0.4.37 vs jax ≥ 0.5 API).

The engine, training, and multidevice tests are written against the modern
public surface:

  * ``jax.shard_map``                 — promoted from ``jax.experimental``
  * ``jax.sharding.AxisType``         — mesh axis types (``Auto``/…)
  * ``jax.make_mesh(..., axis_types=)`` — the kwarg carrying them
  * ``shard_map(..., check_vma=)``    — renamed from ``check_rep``

On jax 0.4.37 none of these exist. :func:`install` back-fills each missing
piece from its 0.4-era equivalent (``jax.experimental.shard_map``, a
placeholder enum, a kwarg-dropping ``make_mesh`` wrapper) so the same source
runs on both versions. It is idempotent, a no-op on new jax, and invoked
from ``repro/__init__.py`` — importing any ``repro`` module is enough.

Only *additive* patches are made: nothing native is ever overwritten, so on
jax ≥ 0.5 this module does exactly nothing.
"""
from __future__ import annotations

import enum
import functools

import jax


def _shim_axis_type() -> None:
    if hasattr(jax.sharding, "AxisType"):
        return

    class AxisType(enum.Enum):
        """Placeholder for jax ≥ 0.5 mesh axis types. 0.4 meshes have no
        axis-type concept (everything behaves like ``Auto``), so the values
        only need to exist and compare."""

        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    jax.sharding.AxisType = AxisType


def _shim_make_mesh() -> None:
    native = jax.make_mesh
    try:
        import inspect

        accepts = "axis_types" in inspect.signature(native).parameters
    except (TypeError, ValueError):  # pragma: no cover — exotic wrappers
        accepts = True
    if accepts:
        return

    @functools.wraps(native)
    def make_mesh(axis_shapes, axis_names, *, axis_types=None, **kwargs):
        # 0.4 meshes are implicitly Auto on every axis — dropping the kwarg
        # is semantically faithful for Auto; other types have no 0.4
        # equivalent and still get the (Auto-like) legacy behaviour.
        return native(axis_shapes, axis_names, **kwargs)

    jax.make_mesh = make_mesh


def _shim_shard_map() -> None:
    if hasattr(jax, "shard_map"):
        return
    from jax.experimental.shard_map import shard_map as _shard_map

    @functools.wraps(_shard_map)
    def shard_map(f, /, *, mesh, in_specs, out_specs, check_vma=None,
                  check_rep=None, axis_names=None, **kwargs):
        # jax ≥ 0.5 renamed check_rep → check_vma; translate either spelling
        # onto the 0.4 kwarg.
        if check_vma is None:
            check_vma = True if check_rep is None else check_rep
        if axis_names is not None:
            # ≥ 0.5 names the *manual* axes; 0.4's ``auto`` names the
            # complement.
            kwargs["auto"] = frozenset(mesh.axis_names) - set(axis_names)
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_vma, **kwargs)

    jax.shard_map = shard_map


def _shim_pcast() -> None:
    if hasattr(jax.lax, "pcast"):
        return

    def pcast(x, axes=None, *, to=None):
        # ≥ 0.5 tracks varying-manual-axes (VMA) types inside shard_map and
        # ``pcast`` converts between them. 0.4 has no VMA tracking, so the
        # cast is the identity.
        return x

    jax.lax.pcast = pcast


def install() -> None:
    """Installs every missing shim (idempotent; no-op on jax ≥ 0.5)."""
    _shim_axis_type()
    _shim_make_mesh()
    _shim_shard_map()
    _shim_pcast()
