"""Shard-execution runtime: the one distributed layer under engine, index
build, and query serving.

Before this module existed the shard-execution pattern lived in three
copies: the ``shard_map`` plumbing in ``engine/gas.py``, the host shard loop
+ ``shard_map`` build in ``query/index.py``, and the gather-everything wave
program in ``query/scheduler.py``. Each reimplemented the same four moves:

  * **mesh acquisition** — build (or adopt) a 1-D mesh over a ``"vertex"``
    axis sized to the shard count;
  * **per-shard placement** — put stacked ``[S, ...]`` blocks on the mesh so
    device ``s`` holds exactly block ``s`` (``P(axis)``) and broadcast
    arguments replicated (``P()``);
  * **sharded-vs-single-device dispatch** — run a per-shard program either
    as one ``shard_map`` over the mesh, or as a host loop over shard ids
    when only one device is available (the two are the same program; only
    the reduction across shards moves from ``psum`` to the host);
  * **per-shard checkpoint round-trip** — persist / restore one atomic
    checkpoint dir per shard (``<dir>/shard_<s>/step_<k>/``) so a sharded
    job can crash/retry one shard at a time without exposing a torn
    artifact.

:class:`ShardRuntime` owns the first three; the module-level checkpoint
helpers own the fourth. ``engine/gas.py`` (superstep execution),
``query/index.py`` (sharded slab build + persistence) and
``query/scheduler.py`` (serving from per-shard slab blocks) are all built
on it — one execution layer, three workloads.

The runtime additionally owns the **AOT wave-program ladder cache**
(:class:`WaveProgramCache`, reached via :meth:`ShardRuntime.wave_cache`):
compiled wave programs keyed by their static geometry
(:class:`repro.query.engine.WaveSpec`), shared process-wide so every
scheduler/replica serving the same slab geometry reuses one executable —
and a trace counter (:func:`record_wave_trace` / :func:`wave_trace_count`)
incremented from *inside* the traced wave bodies, which is what lets tests
and the bench smoke assert "zero retraces after ladder warmup" directly.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Callable, Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint import (CheckpointCorruptError, latest_step,
                              restore_checkpoint, save_checkpoint)

DEFAULT_AXIS = "vertex"


# --- AOT wave-program ladder cache ------------------------------------------


class WaveProgramCache:
    """Process-wide cache of compiled wave programs, keyed by static
    geometry (a hashable spec — :class:`repro.query.engine.WaveSpec`).

    One entry per (walk-slots, query-slots, shards, …) bucket shape: the
    scheduler pads each wave's operands up to the nearest ladder bucket, so
    an admission-driven change in the query mix resolves to a spec already
    in the cache instead of retracing mid-serving. Programs close over no
    per-scheduler state (slab and graph arrays are operands), so replicas
    with identical geometry share executables.
    """

    def __init__(self):
        self._programs: Dict[Any, Callable] = {}
        self.hits = 0
        self.misses = 0

    def get_or_build(self, spec, builder: Callable) -> Callable:
        try:
            fn = self._programs[spec]
            self.hits += 1
            return fn
        except KeyError:
            self.misses += 1
            fn = self._programs[spec] = builder(spec)
            return fn

    def __len__(self) -> int:
        return len(self._programs)

    def clear(self) -> None:
        self._programs.clear()


_WAVE_CACHE = WaveProgramCache()

# Traces of wave bodies, counted from inside the traced function (tracing
# executes the Python body; steady-state executions do not) — the direct
# "did serving retrace?" signal the recompile-count test and the bench
# smoke gate assert on.
_WAVE_TRACES = 0


def record_wave_trace(spec: Any = None) -> None:
    """Called at the top of every wave-program body; increments only while
    jax is *tracing* the body (compile), never on a steady-state call."""
    global _WAVE_TRACES
    _WAVE_TRACES += 1


def wave_trace_count() -> int:
    return _WAVE_TRACES


def reset_wave_trace_count() -> int:
    """Resets the counter and returns the value it had."""
    global _WAVE_TRACES
    prev, _WAVE_TRACES = _WAVE_TRACES, 0
    return prev


@dataclasses.dataclass(frozen=True)
class ShardRuntime:
    """Mesh + dispatch context for per-shard programs.

    ``mesh is None`` means single-device dispatch: the same per-shard body
    runs as a host loop over shard ids (:meth:`map_shards`) instead of one
    ``shard_map``; callers branch on :attr:`is_mesh` for the pieces that
    genuinely differ (a ``psum`` vs a host-side sum).
    """

    num_shards: int
    axis_name: str = DEFAULT_AXIS
    mesh: Optional[Mesh] = None

    # --- acquisition -----------------------------------------------------

    @classmethod
    def acquire(
        cls,
        num_shards: Optional[int] = None,
        axis_name: str = DEFAULT_AXIS,
        devices: Optional[Sequence[Any]] = None,
    ) -> "ShardRuntime":
        """Builds a runtime for ``num_shards`` shards.

        With enough devices the runtime carries a 1-D mesh over the first
        ``num_shards`` of them; otherwise it is a single-device (host-loop)
        runtime for the same shard count — callers get the same API either
        way, which is the whole point.
        """
        devs = list(devices if devices is not None else jax.devices())
        if num_shards is None:
            num_shards = len(devs)
        if num_shards < 1:
            raise ValueError(f"num_shards must be ≥ 1, got {num_shards}")
        if len(devs) >= num_shards > 1 or (num_shards == 1):
            mesh = Mesh(np.asarray(devs[:num_shards]), (axis_name,))
            return cls(num_shards=num_shards, axis_name=axis_name, mesh=mesh)
        return cls(num_shards=num_shards, axis_name=axis_name, mesh=None)

    @classmethod
    def for_mesh(cls, mesh: Mesh, axis_name: Optional[str] = None) -> "ShardRuntime":
        """Adopts an existing 1-D mesh (the engine entry point)."""
        ax = axis_name if axis_name is not None else mesh.axis_names[0]
        if ax not in mesh.axis_names:
            raise ValueError(
                f"mesh axes {mesh.axis_names} do not include {ax!r}")
        return cls(num_shards=int(mesh.shape[ax]), axis_name=ax, mesh=mesh)

    @property
    def is_mesh(self) -> bool:
        return self.mesh is not None

    def require_mesh(self) -> Mesh:
        if self.mesh is None:
            raise ValueError(
                f"this runtime dispatches {self.num_shards} shards on a "
                "single device (host loop); the caller needs a mesh — "
                "acquire one with ShardRuntime.acquire(num_shards) on a "
                "multi-device backend")
        return self.mesh

    # --- placement -------------------------------------------------------

    def sharding(self, replicated: bool = False) -> NamedSharding:
        """NamedSharding for a stacked ``[S, ...]`` block array (or a
        replicated argument)."""
        return NamedSharding(self.require_mesh(),
                             P() if replicated else P(self.axis_name))

    def place_sharded(self, arr) -> jnp.ndarray:
        """Puts a stacked ``[S, ...]`` array so device ``s`` holds only
        block ``s`` — on a single-device runtime this is a plain
        ``jnp.asarray`` (the host *is* the only shard holder)."""
        if not self.is_mesh:
            return jnp.asarray(arr)
        if arr.shape[0] != self.num_shards:
            raise ValueError(
                f"leading dim {arr.shape[0]} != num_shards {self.num_shards}")
        return jax.device_put(arr, self.sharding())

    # --- dispatch --------------------------------------------------------

    def shard_map_fn(
        self,
        body: Callable,
        num_sharded: int,
        num_replicated: int = 0,
        num_outputs: int = 1,
        check_vma: bool = True,
    ) -> Callable:
        """Wraps a per-shard body as one ``shard_map`` over the mesh
        (unjitted — the dry-run path wants to control ``in_shardings``).

        The body sees its first ``num_sharded`` arguments as ``[1, ...]``
        per-shard blocks and the rest replicated; every output is a
        ``[1, ...]`` per-shard block (``P(axis)``). ``check_vma=False`` is
        for bodies that lower through ``pallas_call`` (jax has no
        replication rule for it).
        """
        ax = self.axis_name
        in_specs = (P(ax),) * num_sharded + (P(),) * num_replicated
        out_specs = P(ax) if num_outputs == 1 else (P(ax),) * num_outputs
        kwargs = {} if check_vma else {"check_vma": False}
        return jax.shard_map(body, mesh=self.require_mesh(),
                             in_specs=in_specs, out_specs=out_specs,
                             **kwargs)

    def sharded_call(self, body: Callable, num_sharded: int,
                     num_replicated: int = 0, num_outputs: int = 1,
                     check_vma: bool = True,
                     donate_argnums: Sequence[int] = ()) -> Callable:
        """Jitted :meth:`shard_map_fn` — the common execution entry.

        ``donate_argnums`` forwards to ``jax.jit``: callers donate operands
        that are dead after the body's prologue (e.g. the wave scheduler's
        per-wave walk state) so XLA can reuse their buffers instead of
        allocating fresh ones every dispatch."""
        return jax.jit(self.shard_map_fn(
            body, num_sharded, num_replicated, num_outputs,
            check_vma=check_vma), donate_argnums=tuple(donate_argnums))

    def map_shards(self, program: Callable, *args, **kwargs) -> list:
        """Single-device dispatch: runs ``program(shard_id, *args)`` for
        every shard id in order and returns the per-shard results — the
        host-loop twin of :meth:`sharded_call` for shard-parallel bodies
        (no collectives; cross-shard reductions happen on the host)."""
        return [program(s, *args, **kwargs) for s in range(self.num_shards)]

    # --- per-shard randomness -------------------------------------------

    @staticmethod
    def shard_key(key_data: jnp.ndarray, axis_name: str) -> jax.Array:
        """Inside a shard body: rebuild the PRNG key and fold in the shard
        id, so each shard draws an independent, mesh-shape-reproducible
        stream. ``key_data`` is the raw uint32 data (keys cannot cross the
        shard_map boundary as opaque key arrays on jax 0.4)."""
        key = jax.random.wrap_key_data(key_data, impl="threefry2x32")
        return jax.random.fold_in(key, jax.lax.axis_index(axis_name))

    @staticmethod
    def key_data(key: jax.Array) -> jnp.ndarray:
        return jax.random.key_data(key)

    # --- AOT wave-program ladder ----------------------------------------

    @staticmethod
    def wave_cache() -> WaveProgramCache:
        """The process-wide :class:`WaveProgramCache`. A staticmethod on the
        (frozen, hashable) runtime rather than a field: the cache is shared
        across runtimes by design — two schedulers over the same slab
        geometry must hit the same compiled program."""
        return _WAVE_CACHE


# --- per-shard checkpoint round-trip ----------------------------------------
#
# Layout: <directory>/shard_<s>/step_<k>/ — one atomic checkpoint/ step dir
# per shard, so a sharded job persists (and crash/retries) one shard at a
# time and a reader can detect a partial write (missing shards) instead of
# silently consuming a torn artifact.


def shard_dir(directory: str, shard: int) -> str:
    return os.path.join(directory, f"shard_{shard:04d}")


def list_shard_dirs(directory: str) -> list:
    """Sorted shard subdirectories under ``directory`` (empty if none —
    i.e. the directory holds a monolithic checkpoint or nothing)."""
    if not os.path.isdir(directory):
        return []
    return sorted(d for d in os.listdir(directory) if d.startswith("shard_"))


def save_shard_checkpoint(directory: str, shard: int, tree: Any,
                          step: int = 0) -> str:
    """Atomic save of one shard's tree under ``<dir>/shard_<s>/step_<k>/``."""
    return save_checkpoint(shard_dir(directory, shard), step, tree)


def load_checkpoint_tree(directory: str, step: Optional[int] = None) -> dict:
    """Self-describing restore: the template comes from the checkpoint's
    own ``tree.json`` metadata, so callers need not know shapes up front.

    A missing checkpoint raises :class:`FileNotFoundError`; a present but
    torn / corrupt one raises :class:`~repro.checkpoint.
    CheckpointCorruptError` naming the step dir — both actionable,
    neither a bare ``KeyError`` or shape mismatch.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory!r}")
    step_dir = os.path.join(directory, f"step_{step:08d}")
    meta_path = os.path.join(step_dir, "tree.json")
    if not os.path.isfile(meta_path):
        raise CheckpointCorruptError(
            f"checkpoint {step_dir!r} has no tree.json — partial or torn "
            f"write; quarantine and rebuild this shard dir")
    with open(meta_path) as f:
        try:
            meta = json.load(f)
        except ValueError as e:
            raise CheckpointCorruptError(
                f"checkpoint {step_dir!r} has unreadable tree.json: "
                f"{e}") from e
    like = {
        path: np.zeros(shape, dtype=np.dtype(dtype))
        for path, shape, dtype in zip(
            meta["paths"], meta["shapes"], meta["dtypes"])
    }
    return restore_checkpoint(directory, step, like)


def quarantine_shard_dir(directory: str, shard: int) -> str:
    """Moves a corrupt shard checkpoint dir aside (``quarantine.shard_<s>``
    — invisible to :func:`list_shard_dirs`) so a rebuild can atomically
    write a fresh one in its place. Returns the quarantine path."""
    src = shard_dir(directory, shard)
    dst = os.path.join(directory, f"quarantine.shard_{shard:04d}")
    k = 0
    while os.path.exists(dst):
        k += 1
        dst = os.path.join(directory, f"quarantine.shard_{shard:04d}.{k}")
    os.rename(src, dst)
    return dst


def load_shard_checkpoints(
    directory: str, step: Optional[int] = None, on_error: str = "raise"
) -> Dict[int, dict]:
    """Restores every shard checkpoint under ``directory``.

    Returns ``{shard_index_from_dirname: tree}``; shard-content validation
    (consistent metadata, no missing shards) belongs to the caller, which
    knows what the trees mean.

    ``on_error="raise"`` (default) propagates the first corrupt / partial
    shard; ``on_error="collect"`` instead maps each failing shard to its
    exception in the result (``{shard: tree_or_exception}``) so callers
    like :func:`repro.query.index.load_or_repair_walk_index` can
    quarantine and rebuild exactly the broken shards.
    """
    if on_error not in ("raise", "collect"):
        raise ValueError(f"on_error must be 'raise' or 'collect', "
                         f"got {on_error!r}")
    dirs = list_shard_dirs(directory)
    if not dirs:
        raise FileNotFoundError(f"no shard checkpoints under {directory!r}")
    out: Dict[int, dict] = {}
    for d in dirs:
        shard = int(d.split("_")[1])
        try:
            out[shard] = load_checkpoint_tree(os.path.join(directory, d),
                                              step)
        except (CheckpointCorruptError, FileNotFoundError) as e:
            if on_error == "raise":
                raise
            out[shard] = e
    return out
