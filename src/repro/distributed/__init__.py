"""Distribution substrate: shard-execution runtime, sharding rules,
pipeline parallelism, elastic resharding, fault injection."""
from repro.distributed.faults import (
    FaultError,
    FaultEvent,
    FaultInjector,
    FaultPlan,
    ShardFault,
    WaveFailedError,
    WaveTimeout,
)
from repro.distributed.runtime import (
    ShardRuntime,
    load_checkpoint_tree,
    load_shard_checkpoints,
    quarantine_shard_dir,
    save_shard_checkpoint,
    shard_dir,
)
from repro.distributed.sharding import (
    MeshAxes,
    batch_pspec,
    decode_state_pspecs,
    param_pspecs,
    with_rules,
)

__all__ = [
    "FaultError",
    "FaultEvent",
    "FaultInjector",
    "FaultPlan",
    "ShardFault",
    "WaveFailedError",
    "WaveTimeout",
    "ShardRuntime",
    "load_checkpoint_tree",
    "load_shard_checkpoints",
    "quarantine_shard_dir",
    "save_shard_checkpoint",
    "shard_dir",
    "MeshAxes",
    "batch_pspec",
    "decode_state_pspecs",
    "param_pspecs",
    "with_rules",
]
