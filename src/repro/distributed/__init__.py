"""Distribution substrate: shard-execution runtime, sharding rules,
pipeline parallelism, elastic resharding."""
from repro.distributed.runtime import (
    ShardRuntime,
    load_checkpoint_tree,
    load_shard_checkpoints,
    save_shard_checkpoint,
    shard_dir,
)
from repro.distributed.sharding import (
    MeshAxes,
    batch_pspec,
    decode_state_pspecs,
    param_pspecs,
    with_rules,
)

__all__ = [
    "ShardRuntime",
    "load_checkpoint_tree",
    "load_shard_checkpoints",
    "save_shard_checkpoint",
    "shard_dir",
    "MeshAxes",
    "batch_pspec",
    "decode_state_pspecs",
    "param_pspecs",
    "with_rules",
]
