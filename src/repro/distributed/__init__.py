"""Distribution substrate: sharding rules, pipeline parallelism, elastic
resharding."""
from repro.distributed.sharding import (
    MeshAxes,
    batch_pspec,
    decode_state_pspecs,
    param_pspecs,
    with_rules,
)

__all__ = [
    "MeshAxes",
    "batch_pspec",
    "decode_state_pspecs",
    "param_pspecs",
    "with_rules",
]
