"""Sharding rules: PartitionSpec trees for params, batches and decode state.

Megatron-style tensor parallelism over the ``model`` axis with divisibility-
aware fallbacks (heads that don't divide the TP degree stay replicated —
recorded per-arch in the dry-run report), plus optional FSDP: parameter
*storage* additionally sharded over the ``data`` axis on the first divisible
dimension; XLA inserts the all-gather (forward) / reduce-scatter (backward)
— exactly the ZeRO-3 dataflow.

Rules are name-based over the parameter tree:
  * input-side projections  (wq/wk/wv/w_gate/w_up/…)   → shard output dim
  * output-side projections (wo/w_down/w_out)          → shard input dim
  * expert tensors [E, …]                              → shard E (expert par.)
  * embedding [V, d]                                   → shard V
  * vectors / norms / small LoRA                       → replicate
Stacked-layer leading dims (scan-over-layers) are never sharded.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    """Logical → mesh-axis binding.

    data axes may be a tuple (("pod", "data")) — batch shards over both.
    """
    data: Tuple[str, ...] = ("data",)
    model: str = "model"
    fsdp: bool = False            # shard param storage over data axes too

    @staticmethod
    def for_mesh(mesh: Mesh, fsdp: bool = False) -> "MeshAxes":
        names = mesh.axis_names
        data = tuple(n for n in names if n in ("pod", "data"))
        return MeshAxes(data=data or (names[0],), model=names[-1], fsdp=fsdp)


# name sets driving the rules
_IN_SHARD = {  # 2-D [in, out] — shard the output (last) dim
    "wq", "wk", "wv", "w_gate", "w_up", "w_r", "w_k", "w_v", "w_g",
    "w_in", "w_in_z", "w_in_x", "kernel",
}
_OUT_SHARD = {  # 2-D [in, out] — shard the input (second-to-last) dim
    "wo", "w_down", "w_out", "w_o",
}
_REPLICATE = {
    "scale", "ln_scale", "norm_scale", "mu", "mu_r", "mu_k", "mu_v", "mu_w",
    "mu_g", "w0", "u", "dt_bias", "A_log", "D", "conv_w", "w_lora_a",
    "w_lora_b", "w_in_B", "w_in_C", "w_in_dt", "router",
}


def _axis_size(mesh: Mesh, name) -> int:
    if isinstance(name, tuple):
        out = 1
        for n in name:
            out *= mesh.shape[n]
        return out
    return mesh.shape[name]


def _leaf_spec(path, leaf, cfg: ModelConfig, mesh: Mesh, ax: MeshAxes,
               stacked_depth: int) -> P:
    name = None
    keys = [p.key for p in path if hasattr(p, "key")]
    if keys:
        name = keys[-1]
    ndim = leaf.ndim
    tp = mesh.shape[ax.model]
    dp = _axis_size(mesh, ax.data)
    spec = [None] * ndim

    def try_set(dim: int, axis) -> bool:
        size = leaf.shape[dim]
        if spec[dim] is None and size % _axis_size(mesh, axis) == 0:
            spec[dim] = axis
            return True
        return False

    base = stacked_depth            # leading scan dims stay unsharded
    if name == "embedding":
        try_set(0, ax.model)
    elif name in _REPLICATE:
        pass
    elif "w_gate" == name and ndim - base == 3 or (
            name in ("w_up", "w_down") and ndim - base == 3):
        # MoE expert stacks [*, E, d, f] — expert parallelism on E
        if not try_set(base, ax.model):
            # fall back to sharding the ff dim
            ff_dim = ndim - 1 if name != "w_down" else ndim - 2
            try_set(ff_dim, ax.model)
    elif name in _IN_SHARD and ndim - base == 2:
        try_set(ndim - 1, ax.model)
    elif name in _OUT_SHARD and ndim - base == 2:
        try_set(ndim - 2, ax.model)

    if ax.fsdp:
        # storage-only: shard the first still-unsharded, divisible dim over
        # the data axes (ZeRO-3 parameter sharding).
        for d in range(base, ndim):
            if spec[d] is None and leaf.shape[d] % dp == 0:
                spec[d] = ax.data if len(ax.data) > 1 else ax.data[0]
                break
    return P(*spec)


def _stacked_depth(path) -> int:
    """blocks/enc_blocks/dec_blocks subtrees carry a leading layer dim."""
    keys = [p.key for p in path if hasattr(p, "key")]
    return 1 if any(k in ("blocks", "enc_blocks", "dec_blocks") for k in keys) else 0


def param_pspecs(cfg: ModelConfig, mesh: Mesh, params_or_specs,
                 ax: Optional[MeshAxes] = None):
    """PartitionSpec tree matching the params tree (works on ShapeDtypeStructs)."""
    ax = ax or MeshAxes.for_mesh(mesh)
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _leaf_spec(path, leaf, cfg, mesh, ax,
                                      _stacked_depth(path)),
        params_or_specs,
    )


def batch_pspec(cfg: ModelConfig, mesh: Mesh, batch_specs,
                ax: Optional[MeshAxes] = None):
    """Batch dim sharded over the data axes; everything else replicated."""
    ax = ax or MeshAxes.for_mesh(mesh)
    data_axis = ax.data if len(ax.data) > 1 else ax.data[0]

    def spec(leaf):
        s = [None] * leaf.ndim
        if leaf.ndim >= 1 and leaf.shape[0] % _axis_size(mesh, ax.data) == 0:
            s[0] = data_axis
        return P(*s)

    return jax.tree.map(spec, batch_specs)


def decode_state_pspecs(cfg: ModelConfig, mesh: Mesh, state_specs,
                        ax: Optional[MeshAxes] = None):
    """Decode state: batch dim over data axes; KV-cache *sequence* dim over
    the model axis (split-KV layout — the memory answer for 32k/500k caches
    regardless of head divisibility)."""
    ax = ax or MeshAxes.for_mesh(mesh)
    data_axis = ax.data if len(ax.data) > 1 else ax.data[0]
    tp = mesh.shape[ax.model]
    dp = _axis_size(mesh, ax.data)

    def spec(path, leaf):
        keys = [p.key for p in path if hasattr(p, "key")]
        name = keys[-1] if keys else None
        s = [None] * leaf.ndim
        if leaf.ndim == 0:
            return P()
        if leaf.shape[0] % dp == 0:
            s[0] = data_axis
        if name in ("k", "v", "k_scale", "v_scale") and leaf.ndim == 4:
            # [B, Hkv, S, hd|1] — shard cache sequence over model axis
            if leaf.shape[2] % tp == 0:
                s[2] = ax.model
        elif name == "S" and leaf.ndim == 4:
            # rwkv state [B, H, D, D] — shard heads if divisible
            if leaf.shape[1] % tp == 0:
                s[1] = ax.model
        elif name == "h" and leaf.ndim == 4:
            # mamba state [B, H, hd, n]
            if leaf.shape[1] % tp == 0:
                s[1] = ax.model
        elif name == "conv_buf" and leaf.ndim == 3:
            if leaf.shape[2] % tp == 0:
                s[2] = ax.model
        elif leaf.ndim == 4 and name not in ("k", "v"):
            if leaf.shape[1] % tp == 0:
                s[1] = ax.model
        return P(*s)

    return jax.tree_util.tree_map_with_path(spec, state_specs)


def with_rules(x, mesh: Mesh, spec_tree):
    """with_sharding_constraint over a pytree of specs."""
    return jax.tree.map(
        lambda a, s: jax.lax.with_sharding_constraint(
            a, NamedSharding(mesh, s)),
        x, spec_tree,
    )
