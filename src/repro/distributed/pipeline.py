"""GPipe-style pipeline parallelism over a ``stage`` mesh axis.

The third parallelism dimension for >2-pod scales (DESIGN.md §7): layers are
split into S stages, the batch into M microbatches; activations flow
stage-to-stage with ``jax.lax.ppermute`` inside a shard_map. The classic
GPipe schedule runs S + M − 1 ticks with (S−1)/(M+S−1) bubble overhead.

Implementation: every stage runs every tick (SPMD); a tick counter decides
whether its output is real or bubble, and a rolling input buffer keeps the
microbatch stream aligned. Stage weights live only on their stage's devices
(leading stage dim sharded over the axis).

Used by ``examples/pipeline_demo.py`` and tests/test_pipeline.py; the
production mesh keeps PP optional (axis can be folded into "pod").
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    num_stages: int
    num_microbatches: int
    axis_name: str = "stage"


def pipeline_forward(
    stage_fn: Callable[[Any, jnp.ndarray], jnp.ndarray],
    stage_params: Any,                  # leaves with leading [S, ...]
    x: jnp.ndarray,                     # [M, mb, ...] microbatched input
    cfg: PipelineConfig,
    mesh: Mesh,
) -> jnp.ndarray:
    """Runs x through S stages with the GPipe schedule; returns [M, mb, ...]."""
    S, M = cfg.num_stages, cfg.num_microbatches
    ax = cfg.axis_name
    if x.shape[0] != M:
        raise ValueError(f"x leading dim {x.shape[0]} != microbatches {M}")

    def body(params, xm):
        params = jax.tree.map(lambda a: a[0], params)   # drop stage dim
        xm = xm[0]                                      # [M, mb, ...]
        sid = jax.lax.axis_index(ax)
        mb_shape = xm.shape[1:]
        ticks = S + M - 1

        def tick(carry, t):
            buf, outs = carry
            # stage 0 feeds microbatch t (if any); others read their buffer.
            feed = jnp.where(t < M, t, 0)
            x_in = jnp.where(sid == 0, xm[feed], buf)
            y = stage_fn(params, x_in)
            # forward the activation to the next stage
            nxt = jax.lax.ppermute(
                y, ax, [(i, (i + 1) % S) for i in range(S)])
            # last stage commits microbatch (t - (S-1)) when valid
            mb_idx = t - (S - 1)
            valid = (mb_idx >= 0) & (sid == S - 1)
            outs = jax.lax.cond(
                valid,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.maximum(mb_idx, 0), 0),
                lambda o: o,
                outs,
            )
            return (nxt, outs), None

        buf0 = jnp.zeros(mb_shape, xm.dtype)
        buf0 = jax.lax.pcast(buf0, (ax,), to="varying")
        outs0 = jnp.zeros_like(xm)          # zeros_like(varying) is varying
        (_, outs), _ = jax.lax.scan(tick, (buf0, outs0), jnp.arange(ticks))
        # only the last stage holds real outputs; broadcast them to all.
        outs = jax.lax.psum(
            jnp.where(sid == S - 1, outs, jnp.zeros_like(outs)), ax)
        return outs[None]

    fn = jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(ax), P(ax)),
        out_specs=P(ax),
    )
    # replicate microbatches to every stage (stage dim = leading)
    x_rep = jnp.broadcast_to(x[None], (S,) + x.shape)
    out = fn(stage_params, x_rep)
    return out[0]


def split_layers_for_stages(stacked_params: Any, num_stages: int) -> Any:
    """[L, ...] stacked block params → [S, L/S, ...] per-stage stacks."""
    def reshape(a):
        L = a.shape[0]
        assert L % num_stages == 0, (L, num_stages)
        return a.reshape(num_stages, L // num_stages, *a.shape[1:])

    return jax.tree.map(reshape, stacked_params)
