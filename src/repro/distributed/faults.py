"""Fault-injection harness + fault taxonomy for the serving stack.

FrogWild tolerates missing contributions *by design* — partial
synchronization drops a fraction of mirror updates and Theorem 1 prices the
loss — so the serving runtime should inherit that property operationally: a
shard that dies mid-wave degrades the answer's certified ``epsilon_bound``
instead of failing the query. This module makes every failure mode
testable in-process, deterministically:

* :class:`FaultPlan` — a frozen, seed-driven schedule of faults (permanent
  shard losses, transient wave failures, injected stalls, simulated hangs,
  corrupt / truncated checkpoint payloads). Pure data: the same plan
  replayed against the same scheduler produces the same fault sequence.
* :class:`FaultInjector` — the mutable runtime companion the
  :class:`~repro.query.scheduler.QueryScheduler` wave supervisor consults
  at each (wave, attempt). Consumable events (a transient fault scheduled
  for ``count`` attempts fires exactly ``count`` times, then clears) plus
  an optional seeded per-attempt transient probability for sweeps.
* The exception taxonomy the supervisor speaks: :class:`ShardFault`
  (transient → retry with backoff; permanent → evict the shard and serve
  degraded waves), :class:`WaveTimeout` (the wave exceeded its deadline —
  result discarded, retried), :class:`WaveFailedError` (retries exhausted
  and no failover path left — the only way a wave surfaces an error).
* The **replica-level** taxonomy the gateway tier speaks (PR 8):
  :class:`ReplicaCrashed` / :class:`ReplicaStalled`, raised at the pool
  boundary by :meth:`~repro.gateway.pool.ReplicaPool.step_replica` when a
  scheduled ``replica_crash`` fires or a wave misses the heartbeat
  deadline. The pool quarantines (breaker opens), restarts crashed
  replicas over the same shared slab, and the gateway fails in-flight
  queries over to a healthy replica.

The module is stdlib-only so the config layer can reference
:class:`FaultPlan` without pulling in jax.
"""
from __future__ import annotations

import dataclasses
import os
import random
from typing import Dict, List, Optional, Tuple


class FaultError(RuntimeError):
    """Base class for injected / detected serving faults."""


class ShardFault(FaultError):
    """One shard failed. ``transient=True`` means retry may succeed;
    ``transient=False`` means the shard (its slab block) is gone and the
    scheduler must evict it and serve degraded waves."""

    def __init__(self, message: str, shard: Optional[int] = None,
                 transient: bool = True):
        super().__init__(message)
        self.shard = shard
        self.transient = transient


class WaveTimeout(FaultError):
    """A wave exceeded ``wave_timeout_s`` (or an injected hang simulated
    one). The wave's result — if any — is discarded and the wave retried
    from the same key, so a successful retry is byte-identical."""


class WaveFailedError(FaultError):
    """Retries exhausted and no failover path left. The scheduler's state
    is untouched by the failed wave (no tallies landed, no budget spent),
    so the caller can evict capacity / re-admit and drive again."""


class ReplicaFault(FaultError):
    """A whole serving replica misbehaved (PR 8 — the pool boundary).

    Raised by :meth:`~repro.gateway.pool.ReplicaPool.step_replica`, never
    by the scheduler: shard-level faults degrade *within* a replica, while
    a replica fault takes the replica out of routing (breaker opens) and
    moves its in-flight queries to a healthy replica (gateway failover).
    """

    def __init__(self, message: str, replica: int):
        super().__init__(message)
        self.replica = replica


class ReplicaCrashed(ReplicaFault):
    """The replica process died: its service is closed (in-flight handles
    report ``cancelled``), the pool quarantines the slot and restarts a
    fresh :class:`~repro.service.FrogWildService` over the *same* shared
    slab — zero index rebuild, object identity preserved."""


class ReplicaStalled(ReplicaFault):
    """The replica missed its heartbeat deadline (wave wall-time exceeded
    ``heartbeat_timeout_s``): progress must never be hostage to one slow
    worker, so the pool quarantines it and the gateway reroutes. The
    replica itself stays open — after the breaker cooldown it is probed
    half-open and returns to rotation on the first clean wave."""


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One entry of the supervisor's fault log (provenance, not control)."""

    kind: str                       # shard_loss | transient | timeout |
                                    # stall | retry | failover | readmit
    wave: int
    attempt: int = 0
    shard: Optional[int] = None
    detail: str = ""


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Deterministic, seed-driven fault schedule.

    Wave indices count *successful* waves the scheduler has completed (so
    "wave 1" is the second wave a query stream drives); attempt indices
    count retries of one wave (0 = first try).

    Fields:
      seed:             drives the probabilistic faults and the payload
                        mangling offsets — same seed, same fault sequence.
      shard_losses:     ``((wave, shard), ...)`` — permanent loss of
                        ``shard`` surfacing at ``wave``: the scheduler
                        evicts it and serves degraded waves from then on.
      transient_faults: ``((wave, count), ...)`` — the wave fails
                        ``count`` consecutive attempts, then succeeds
                        (exercises bounded retry + backoff).
      stalls:           ``((wave, seconds), ...)`` — injected stall before
                        the wave body (a slow shard); fires once. With a
                        configured ``wave_timeout_s`` below ``seconds``
                        this becomes a detected timeout.
      wave_timeouts:    ``((wave, count), ...)`` — simulated hang: the
                        wave raises :class:`WaveTimeout` for ``count``
                        attempts without running, then succeeds.
      p_transient:      per-(wave, attempt) transient-failure probability,
                        drawn from ``seed`` (sweeps / soak tests).
      corrupt_ckpt_shards:  shard ids whose on-disk checkpoint payload
                        :meth:`FaultInjector.mangle_checkpoints` bit-flips.
      truncate_ckpt_shards: shard ids whose payload it truncates.

    Replica-level faults (PR 8) are injected at the **pool boundary** —
    :meth:`~repro.gateway.pool.ReplicaPool.step_replica` consults them
    before dispatching a wave to the replica's scheduler. Their wave
    indices count the *pool's* drives of that replica, independently of
    the scheduler-level schedule above:

      replica_crashes:  ``((replica, wave), ...)`` — the replica dies at
                        its ``wave``-th pool drive: its service closes,
                        :class:`ReplicaCrashed` surfaces, the pool
                        quarantines + restarts it over the same slab.
      replica_stalls:   ``((replica, wave, seconds), ...)`` — one
                        injected stall of ``seconds`` before that drive's
                        wave body; a stall past the pool's
                        ``heartbeat_timeout_s`` is detected as
                        :class:`ReplicaStalled` (quarantine + reroute).
      replica_slow:     ``((replica, seconds), ...)`` — persistent
                        per-wave extra latency (a degraded-but-alive
                        straggler): lowers the replica's health score and
                        trips the gateway's hedging threshold.
    """

    seed: int = 0
    shard_losses: Tuple[Tuple[int, int], ...] = ()
    transient_faults: Tuple[Tuple[int, int], ...] = ()
    stalls: Tuple[Tuple[int, float], ...] = ()
    wave_timeouts: Tuple[Tuple[int, int], ...] = ()
    p_transient: float = 0.0
    corrupt_ckpt_shards: Tuple[int, ...] = ()
    truncate_ckpt_shards: Tuple[int, ...] = ()
    replica_crashes: Tuple[Tuple[int, int], ...] = ()
    replica_stalls: Tuple[Tuple[int, int, float], ...] = ()
    replica_slow: Tuple[Tuple[int, float], ...] = ()

    @property
    def empty(self) -> bool:
        """True when the plan schedules nothing (the overhead-measurement
        arm: injector attached, no faults fire)."""
        return not (self.shard_losses or self.transient_faults
                    or self.stalls or self.wave_timeouts
                    or self.p_transient > 0.0
                    or self.corrupt_ckpt_shards
                    or self.truncate_ckpt_shards
                    or self.replica_crashes or self.replica_stalls
                    or self.replica_slow)


class FaultInjector:
    """Runtime companion of a :class:`FaultPlan`.

    Consumable state: each scheduled event fires its budgeted number of
    times and then clears, so a supervised retry loop always terminates on
    injected faults. All randomness derives from ``plan.seed`` keyed by
    (wave, attempt) — call order cannot change the fault sequence.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._losses: Dict[int, List[int]] = {}
        for wave, shard in plan.shard_losses:
            self._losses.setdefault(int(wave), []).append(int(shard))
        self._transient = {int(w): int(c) for w, c in plan.transient_faults}
        self._timeouts = {int(w): int(c) for w, c in plan.wave_timeouts}
        self._stalls = {int(w): float(s) for w, s in plan.stalls}
        # replica-level schedules, keyed (replica, pool-wave) — consumed by
        # the ReplicaPool supervisor, invisible to scheduler-level hooks.
        self._replica_crashes = {(int(r), int(w))
                                 for r, w in plan.replica_crashes}
        self._replica_stalls = {(int(r), int(w)): float(s)
                                for r, w, s in plan.replica_stalls}
        self._replica_slow = {int(r): float(s) for r, s in plan.replica_slow}
        self.fired: List[FaultEvent] = []

    # --- wave-supervisor hooks -------------------------------------------

    def shard_losses_at(self, wave: int) -> List[int]:
        """Permanent shard losses surfacing at this wave (consumed once)."""
        shards = self._losses.pop(wave, [])
        for s in shards:
            self.fired.append(FaultEvent("shard_loss", wave, shard=s))
        return shards

    def stall_s(self, wave: int) -> float:
        """Injected stall (seconds) before this wave's body; fires once."""
        s = self._stalls.pop(wave, 0.0)
        if s:
            self.fired.append(FaultEvent("stall", wave,
                                         detail=f"{s:.3g}s"))
        return s

    def fail_attempt(self, wave: int, attempt: int) -> Optional[str]:
        """``"transient"`` / ``"timeout"`` when this (wave, attempt) is
        scheduled to fail, else None. Scheduled counts decrement; the
        seeded ``p_transient`` coin is keyed by (seed, wave, attempt)."""
        if self._timeouts.get(wave, 0) > 0:
            self._timeouts[wave] -= 1
            self.fired.append(FaultEvent("timeout", wave, attempt))
            return "timeout"
        if self._transient.get(wave, 0) > 0:
            self._transient[wave] -= 1
            self.fired.append(FaultEvent("transient", wave, attempt))
            return "transient"
        if self.plan.p_transient > 0.0:
            coin = random.Random((self.plan.seed, wave, attempt)).random()
            if coin < self.plan.p_transient:
                self.fired.append(FaultEvent("transient", wave, attempt,
                                             detail="p_transient"))
                return "transient"
        return None

    # --- pool-boundary (replica) hooks ------------------------------------

    def replica_crash_at(self, replica: int, wave: int) -> bool:
        """True when this (replica, pool-wave) is scheduled to crash
        (consumed once — a restarted replica does not re-crash)."""
        if (replica, wave) in self._replica_crashes:
            self._replica_crashes.discard((replica, wave))
            self.fired.append(FaultEvent("replica_crash", wave,
                                         detail=f"replica={replica}"))
            return True
        return False

    def replica_stall_s(self, replica: int, wave: int) -> float:
        """Injected stall (seconds) before this replica's pool drive;
        fires once."""
        s = self._replica_stalls.pop((replica, wave), 0.0)
        if s:
            self.fired.append(FaultEvent(
                "replica_stall", wave,
                detail=f"replica={replica} {s:.3g}s"))
        return s

    def replica_slow_s(self, replica: int) -> float:
        """Persistent per-wave extra latency for a straggler replica
        (0.0 for a healthy one). Not consumable — a slow replica stays
        slow until its plan says otherwise."""
        return self._replica_slow.get(replica, 0.0)

    # --- checkpoint-payload faults ---------------------------------------

    def mangle_checkpoints(self, directory: str) -> List[str]:
        """Applies the plan's corrupt / truncate faults to the per-shard
        checkpoints under ``directory`` (``shard_<s>/step_<k>/arrays.npz``)
        and returns the mangled paths. Deterministic in ``plan.seed``."""
        mangled = []
        for shard in self.plan.corrupt_ckpt_shards:
            for path in self._payload_paths(directory, shard):
                _flip_bytes(path, self.plan.seed ^ shard)
                mangled.append(path)
        for shard in self.plan.truncate_ckpt_shards:
            for path in self._payload_paths(directory, shard):
                _truncate_half(path)
                mangled.append(path)
        return mangled

    @staticmethod
    def _payload_paths(directory: str, shard: int) -> List[str]:
        base = os.path.join(directory, f"shard_{shard:04d}")
        if not os.path.isdir(base):
            return []
        return [os.path.join(base, d, "arrays.npz")
                for d in sorted(os.listdir(base)) if d.startswith("step_")
                and os.path.isfile(os.path.join(base, d, "arrays.npz"))]


def _flip_bytes(path: str, seed: int, stride: int = 97) -> None:
    """Bit-flips every ``stride``-th byte of the file body (deterministic
    offset from ``seed``) — enough to break the stored checksums without
    necessarily breaking the container format."""
    with open(path, "r+b") as f:
        data = bytearray(f.read())
        if not data:
            return
        start = random.Random(seed).randrange(min(stride, len(data)))
        for i in range(start, len(data), stride):
            data[i] ^= 0xFF
        f.seek(0)
        f.write(data)


def _truncate_half(path: str) -> None:
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.truncate(size // 2)


__all__ = [
    "FaultError",
    "ShardFault",
    "WaveTimeout",
    "WaveFailedError",
    "ReplicaFault",
    "ReplicaCrashed",
    "ReplicaStalled",
    "FaultEvent",
    "FaultPlan",
    "FaultInjector",
]
