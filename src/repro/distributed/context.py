"""Trace-time activation-sharding context.

GSPMD propagates parameter shardings well, but drops the batch axis at scan
boundaries (saved-for-backward residual stacks come out replicated —
observed: 210 GB/chip for a 1B model). The standard fix is explicit
``with_sharding_constraint`` on the canonical activation shapes; model code
stays mesh-agnostic by calling :func:`constrain`, which is a no-op unless
the launcher opened an :func:`activation_sharding` context around tracing.

Every constraint checks divisibility and silently degrades to replication on
that axis otherwise (e.g. batch=1 long_500k cells, 36-head attention).
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass
class ActivationSharding:
    mesh: Mesh
    dp: Tuple[str, ...] = ("data",)
    tp: str = "model"
    sp: bool = False      # Megatron sequence parallelism: shard the sequence
                          # dim of block-boundary activations over the model
                          # axis (the saved-for-backward stacks shrink 1/tp)


_CTX: Optional[ActivationSharding] = None


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, dp: Tuple[str, ...] = ("data",),
                        tp: str = "model", sp: bool = False):
    global _CTX
    old = _CTX
    _CTX = ActivationSharding(mesh, dp, tp, sp)
    try:
        yield _CTX
    finally:
        _CTX = old


def current() -> Optional[ActivationSharding]:
    return _CTX


def _axis_size(mesh: Mesh, axes) -> int:
    if isinstance(axes, tuple):
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axes]


def constrain(x, kind: str):
    """kind: 'btd' (hidden states), 'logits' (…, vocab), 'bt' (per-token),
    'bh' (attention internals [B, H, …]: heads on the model axis when
    divisible — None dims in an explicit constraint mean *replicated*, so
    attention tensors need the head axis spelled out)."""
    ctx = _CTX
    if ctx is None:
        return x
    dp = ctx.dp if len(ctx.dp) > 1 else ctx.dp[0]
    dp_size = _axis_size(ctx.mesh, dp)
    tp_size = ctx.mesh.shape[ctx.tp]
    spec = [None] * x.ndim
    if x.ndim and x.shape[0] % dp_size == 0 and x.shape[0] > 0:
        spec[0] = dp
    if kind == "logits" and x.shape[-1] % tp_size == 0:
        spec[-1] = ctx.tp
    if kind == "bh" and x.ndim >= 2 and x.shape[1] % tp_size == 0:
        spec[1] = ctx.tp
    if (kind == "btd" and ctx.sp and x.ndim == 3
            and x.shape[1] % tp_size == 0):
        spec[1] = ctx.tp
    if kind == "state4" and x.ndim == 4 and x.shape[-1] % tp_size == 0:
        spec[-1] = ctx.tp
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, P(*spec)))
