"""Pallas TPU kernel: causal GQA flash attention (+ sliding window).

The LM-side compute hot-spot: prefill attention at 32k context is the one
place the assigned architectures are quadratic. Standard online-softmax
blocked attention (Rabe–Staats / FlashAttention), restructured for the MXU:

* grid = (batch, q_heads, q_blocks, kv_blocks), kv innermost (sequential);
* q/out tiles ``(bq, D)`` and kv tiles ``(bk, D)`` sized so bq = bk = 128
  keeps every matmul MXU-shaped (128×D·D×128);
* GQA is expressed in the k/v BlockSpec index maps (q-head h reads kv-head
  h // group) — no repeated KV materialization, which is the point of GQA;
* running max/denominator kept in VMEM scratch across kv blocks;
* causal + sliding-window masks applied from absolute positions; fully-masked
  kv tiles short-circuit (``pl.when``) so the sliding-window case does
  O(S·W) work, not O(S²) — this is what makes gemma3/danube long-context
  prefill sub-quadratic.

Validated against ``ref.attention_ref`` over (B, Hq, Hkv, S, D, window,
causal, dtype) sweeps in interpret mode.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref,
    acc_ref, m_ref, l_ref,
    *,
    scale: float,
    causal: bool,
    window: Optional[int],
    q_offset: int,
    block_q: int,
    block_k: int,
    soft_cap: Optional[float],
):
    iq, ik = pl.program_id(2), pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos = q_offset + iq * block_q + jnp.arange(block_q)          # [bq]
    k_pos = ik * block_k + jnp.arange(block_k)                     # [bk]

    # Tile-level skip: a kv tile is dead if entirely in the causal future or
    # entirely behind the sliding window.
    live = True
    if causal:
        live = (ik * block_k) <= (q_offset + iq * block_q + block_q - 1)
    if window is not None:
        live = live & ((ik * block_k + block_k - 1) > (q_offset + iq * block_q - window))

    @pl.when(live)
    def _tile():
        q = q_ref[0, 0].astype(jnp.float32) * scale                # [bq, D]
        k = k_ref[0, 0].astype(jnp.float32)                        # [bk, D]
        v = v_ref[0, 0].astype(jnp.float32)                        # [bk, D]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )                                                          # [bq, bk]
        if soft_cap is not None:
            s = soft_cap * jnp.tanh(s / soft_cap)
        mask = jnp.ones((block_q, block_k), dtype=bool)
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            mask &= k_pos[None, :] > (q_pos[:, None] - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                                        # [bq, 1]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)                            # [bq, 1]
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)                     # [bq, 1]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "q_offset", "block_q", "block_k", "soft_cap",
        "interpret",
    ),
)
def flash_attention(
    q: jnp.ndarray,                    # [B, Hq, Sq, D]
    k: jnp.ndarray,                    # [B, Hkv, Skv, D]
    v: jnp.ndarray,                    # [B, Hkv, Skv, D]
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    soft_cap: Optional[float] = None,
    interpret: bool = True,
) -> jnp.ndarray:
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    if Hq % Hkv:
        raise ValueError(f"Hq={Hq} not a multiple of Hkv={Hkv}")
    if Sq % block_q or Skv % block_k:
        raise ValueError(
            f"Sq={Sq}, Skv={Skv} must be multiples of blocks ({block_q},{block_k})"
        )
    group = Hq // Hkv
    grid = (B, Hq, Sq // block_q, Skv // block_k)
    scale = 1.0 / (D ** 0.5)
    kernel = functools.partial(
        _flash_kernel,
        scale=scale, causal=causal, window=window, q_offset=q_offset,
        block_q=block_q, block_k=block_k, soft_cap=soft_cap,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, i, j, g=group: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
