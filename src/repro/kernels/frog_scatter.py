"""Pallas TPU kernel: frog-count histogram (the apply() tally).

``counts[v] = #{f : dest[f] == v}`` — the scatter-add at the heart of both
the walker oracle (tallying stopped frogs) and the engine's frontier build.
Scatter is hostile to TPUs (no HBM atomics), so we restructure it as a
**compare-and-reduce over a 2-D grid**: vertex blocks × frog blocks, each
tile materializing a one-hot match matrix and reducing over the frog axis.
The frog axis is the innermost (sequential) grid dimension, accumulating into
the output tile that stays resident in VMEM — the classic TPU histogram
pattern (work O(N·n/BV·BF⁻¹·…) = O(N · num_vertex_blocks), worth it because
N ≪ E and the match matrix hits the VPU at full width).

Validated against ``ref.frog_count_ref`` over shapes and index skews.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_VERTEX_BLOCK = 512
DEFAULT_FROG_BLOCK = 1024


def _frog_scatter_kernel(dest_ref, counts_ref, *, vertex_block: int):
    jf = pl.program_id(1)

    @pl.when(jf == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    iv = pl.program_id(0)
    v0 = iv * vertex_block
    dest = dest_ref[...]                                        # [BF]
    local = dest - v0                                           # [BF]
    onehot = local[:, None] == jnp.arange(vertex_block)[None, :]  # [BF, BV]
    counts_ref[...] += onehot.sum(axis=0).astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("n", "vertex_block", "frog_block", "interpret")
)
def frog_count(
    dest: jnp.ndarray,          # int32[N] — destination vertex per frog
    n: int,                     # number of vertices (padded multiple of vertex_block)
    vertex_block: int = DEFAULT_VERTEX_BLOCK,
    frog_block: int = DEFAULT_FROG_BLOCK,
    interpret: bool = True,
) -> jnp.ndarray:
    (N,) = dest.shape
    if n % vertex_block != 0:
        raise ValueError(f"n={n} must be a multiple of vertex_block={vertex_block}")
    if N % frog_block != 0:
        raise ValueError(f"N={N} must be a multiple of frog_block={frog_block}")
    grid = (n // vertex_block, N // frog_block)
    kernel = functools.partial(_frog_scatter_kernel, vertex_block=vertex_block)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((frog_block,), lambda iv, jf: (jf,))],
        out_specs=pl.BlockSpec((vertex_block,), lambda iv, jf: (iv,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=interpret,
    )(dest)
