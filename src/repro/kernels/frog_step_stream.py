"""Pallas TPU kernel: HBM-streaming walker superstep (sorted-frog pipeline).

The resident ``frog_step.py`` kernel keeps the *entire* graph block
(``row_ptr``/``col_idx``/``deg``) in VMEM, which caps shard size at a few MB
of CSR — far below the paper's Twitter-scale shards. This kernel lifts that:
the graph lives in HBM as **uniform per-vertex-block slabs** (:class:`
BlockedCSR`) and only the slab of the vertex block currently being processed
is brought into VMEM, driven by a scalar-prefetched schedule:

  1. (XLA prologue, ``ops.frog_step(impl="stream")``) frogs are argsorted by
     vertex and laid out so each ``frog_block`` belongs to exactly one
     ``vertex_block`` (per-block segments padded to a ``frog_block``
     multiple with inert frogs);
  2. the grid iterates over sorted frog blocks; the scalar-prefetched
     ``blk_vid[b]`` array drives the BlockSpec index maps, so the Pallas
     pipeline DMAs exactly the CSR slab (local row offsets, degrees, edge
     destinations) of the vertex block that frog block needs — and because
     sorted frog blocks visit vertex blocks in nondecreasing order, the
     pipeline's revisit elision means **each graph slab streams HBM → VMEM
     at most once per superstep**, double-buffered against compute;
  3. the per-block death tally is a **sort-compacted segment sum** (prefix
     sum over the die flags + one ``searchsorted`` of the block's bin edges
     into the already-sorted positions) instead of the resident kernel's
     O(frog_block · vertex_block) one-hot tile;
  4. the counts tile for vertex block ``v`` stays VMEM-resident across the
     consecutive frog blocks that map to it and is flushed when the grid
     moves on (never revisited — the sort guarantees contiguity).

VMEM working set per grid step: ``4 · (3·BV + E_blk + 5·BF)`` bytes (three
BV-slabs + edge slab + pos/die/bits/next/prefix frog tiles) — bounded by the
block shapes, **independent of n and nnz**; HBM holds the full
``4 · (2·n_pad + num_vb · E_blk + 5·P_pad)`` working set. The resident
kernel needs ``4 · (2n + nnz)`` bytes of VMEM for the graph alone.

Random bits default to the caller (``jax.random`` outside), keeping the
kernel deterministic and byte-for-byte testable against
``ref.frog_step_ref`` (the ops wrapper unsorts the outputs) — the
interpret-mode determinism contract. On real TPU pass
``use_device_rng=True`` (the bits operand becomes an ``int32[1]`` seed):
the slot draw then comes from the in-kernel ``pltpu.prng_random_bits``
seeded per frog block, and the HBM bits stream disappears.

Dangling guard: ``d_out == 0`` ⇒ the frog stays put (the self-loop
convention, see graph/csr.py:uniform_successor — asserted identical across
implementations by tests/test_stream_step.py).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_VERTEX_BLOCK = 512
DEFAULT_FROG_BLOCK = 1024


@dataclasses.dataclass(frozen=True)
class BlockedCSR:
    """CSR re-laid-out as uniform per-vertex-block slabs (the DMA unit).

    Attributes:
      vertex_block: BV — vertices per slab.
      row_off: int32[num_vb, BV] — offset of each vertex's edges *within its
        block's edge slab* (``row_ptr[v] - row_ptr[v0]``).
      deg:     int32[num_vb, BV] — out-degrees (0 for pad vertices ≥ n).
      col:     int32[num_vb, E_blk] — edge destinations (global vertex ids),
        each block's edges packed at the front, tail untouched garbage that
        no in-range ``row_off + slot`` ever reads.

    ``E_blk`` (slab width) is the max per-block nnz — static, so every slab
    DMA has the same shape and the Pallas pipeline can double-buffer it.
    """

    vertex_block: int
    row_off: jnp.ndarray
    deg: jnp.ndarray
    col: jnp.ndarray

    @property
    def num_blocks(self) -> int:
        return int(self.row_off.shape[0])

    @property
    def n_pad(self) -> int:
        return self.num_blocks * self.vertex_block

    @property
    def e_blk(self) -> int:
        return int(self.col.shape[1])


def max_block_nnz(row_ptr, n: int, vertex_block: int) -> int:
    """Max per-vertex-block edge count — the natural slab width for
    :func:`block_csr` (exposed so multi-shard builders can force one
    uniform width across shards)."""
    rp = np.asarray(row_ptr, dtype=np.int64)
    bv = min(vertex_block, max(8, n))
    num_vb = -(-n // bv)
    block_nnz = rp[np.minimum(np.arange(1, num_vb + 1) * bv, n)] - rp[
        np.minimum(np.arange(num_vb) * bv, n)]
    return int(max(1, block_nnz.max()))


def round_e_blk(natural: int) -> int:
    """Slab-width alignment rule (8-lane multiples) — the single definition
    shared by :func:`block_csr`'s default and the engine's cross-shard
    forced width."""
    return max(8, int(np.ceil(natural / 8) * 8))


def block_csr(
    row_ptr, col_idx, deg, n: int,
    vertex_block: int = DEFAULT_VERTEX_BLOCK,
    e_blk: int | None = None,
) -> BlockedCSR:
    """Builds the uniform-slab layout from CSR arrays (host-side, O(nnz)).

    The inputs must be concrete (the layout's slab width is a static shape);
    callers inside traced code pass a prebuilt ``BlockedCSR`` to
    ``ops.frog_step`` instead. ``e_blk`` forces a slab width (≥ the natural
    :func:`max_block_nnz`) — how the engine keeps one width across shards.
    """
    rp = np.asarray(row_ptr, dtype=np.int64)
    col = np.asarray(col_idx, dtype=np.int32)
    dg = np.asarray(deg, dtype=np.int32)
    bv = min(vertex_block, max(8, n))
    num_vb = -(-n // bv)
    n_pad = num_vb * bv
    natural = max_block_nnz(row_ptr, n, vertex_block)
    if e_blk is None:
        e_blk = round_e_blk(natural)
    elif e_blk < natural:
        raise ValueError(f"e_blk={e_blk} < max per-block nnz {natural}")
    row_off = np.zeros((num_vb, bv), dtype=np.int32)
    deg_b = np.zeros((num_vb, bv), dtype=np.int32)
    col_b = np.zeros((num_vb, e_blk), dtype=np.int32)
    for i in range(num_vb):
        v0, v1 = i * bv, min((i + 1) * bv, n)
        lo, hi = int(rp[v0]), int(rp[v1])
        row_off[i, : v1 - v0] = rp[v0:v1] - lo
        deg_b[i, : v1 - v0] = dg[v0:v1]
        col_b[i, : hi - lo] = col[lo:hi]
    return BlockedCSR(
        vertex_block=bv,
        row_off=jnp.asarray(row_off),
        deg=jnp.asarray(deg_b),
        col=jnp.asarray(col_b),
    )


def _stream_kernel(
    vid_ref,                      # scalar prefetch: int32[num_fb]
    pos_ref, die_ref, bits_ref,   # int32[BF] — sorted/padded frog tiles
    row_off_ref, deg_ref, col_ref,  # (1, BV), (1, BV), (1, E_blk) slabs
    counts_ref, next_ref,         # int32[BV], int32[BF]
    *, vertex_block: int, use_device_rng: bool,
):
    b = pl.program_id(0)
    vid = vid_ref[b]
    # First frog block of this vertex block → fresh counts tile. (The tile
    # stays resident across the consecutive blocks with the same vid and is
    # flushed exactly once when the grid moves on — sorted order guarantees
    # a vid never comes back.)
    first = jnp.logical_or(b == 0, vid != vid_ref[jnp.maximum(b - 1, 0)])

    @pl.when(first)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    pos = pos_ref[...]                                          # [BF] global
    die = die_ref[...]                                          # [BF] 0/1
    v0 = vid * vertex_block
    local = pos - v0                                            # in [0, BV)
    # --- scatter(): draw slot, gather successor from the streamed slab ---
    d = jnp.take(deg_ref[0], local, axis=0)
    if use_device_rng:
        # Each frog block is visited exactly once (the grid IS the sorted
        # frog-block sequence), so one per-block seed suffices; the large
        # odd multiplier keeps consecutive caller seeds (superstep indices)
        # off each other's block streams.
        pltpu.prng_seed(bits_ref[0] * 1000003 + b)
        raw = pltpu.bitcast(pltpu.prng_random_bits(pos.shape), jnp.uint32)
        bits = (raw >> 1).astype(jnp.int32)
    else:
        bits = bits_ref[...]
    slot = bits % jnp.maximum(d, 1)
    edge = jnp.take(row_off_ref[0], local, axis=0) + slot
    nxt = jnp.take(col_ref[0], edge, axis=0)
    next_ref[...] = jnp.where(d > 0, nxt, pos).astype(jnp.int32)
    # --- apply() tally: sort-compacted segment sum over the sorted tile ---
    # pos is sorted within the block, so per-bin death counts are prefix-sum
    # differences at searchsorted bin edges: O(BF + BV·log BF) work instead
    # of the resident kernel's O(BF·BV) one-hot tile.
    prefix = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(die.astype(jnp.int32))])
    edges = v0 + jnp.arange(vertex_block + 1, dtype=jnp.int32)
    bounds = jnp.searchsorted(pos, edges, side="left").astype(jnp.int32)
    counts_ref[...] += (
        jnp.take(prefix, bounds[1:], axis=0)
        - jnp.take(prefix, bounds[:-1], axis=0)
    )


@functools.partial(
    jax.jit,
    static_argnames=("num_fb", "vertex_block", "frog_block", "interpret",
                     "use_device_rng"),
)
def frog_step_stream_sorted(
    pos_p: jnp.ndarray,       # int32[P_pad] — block-sorted, padded positions
    die_p: jnp.ndarray,       # int32[P_pad] — 0 on padding slots
    bits_p: jnp.ndarray,      # int32[P_pad]; int32[1] seed in device-rng mode
    blk_vid: jnp.ndarray,     # int32[num_fb] — vertex block per frog block
    row_off: jnp.ndarray,     # int32[num_vb, BV]
    deg: jnp.ndarray,         # int32[num_vb, BV]
    col: jnp.ndarray,         # int32[num_vb, E_blk]
    num_fb: int,
    vertex_block: int = DEFAULT_VERTEX_BLOCK,
    frog_block: int = DEFAULT_FROG_BLOCK,
    interpret: bool = True,
    use_device_rng: bool = False,
):
    """Streamed superstep over pre-sorted frogs.

    Returns ``(next int32[P_pad], counts int32[n_pad])`` in the *sorted*
    frog order; ``ops.frog_step`` owns the sort/unsort and the zeroing of
    never-visited count blocks. ``blk_vid`` must be nondecreasing.
    """
    num_vb = row_off.shape[0]
    e_blk = col.shape[1]
    bits_spec = (pl.BlockSpec((1,), lambda b, vid: (0,)) if use_device_rng
                 else pl.BlockSpec((frog_block,), lambda b, vid: (b,)))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(num_fb,),
        in_specs=[
            pl.BlockSpec((frog_block,), lambda b, vid: (b,)),       # pos
            pl.BlockSpec((frog_block,), lambda b, vid: (b,)),       # die
            bits_spec,                                              # bits | seed
            pl.BlockSpec((1, vertex_block), lambda b, vid: (vid[b], 0)),
            pl.BlockSpec((1, vertex_block), lambda b, vid: (vid[b], 0)),
            pl.BlockSpec((1, e_blk), lambda b, vid: (vid[b], 0)),
        ],
        out_specs=(
            pl.BlockSpec((vertex_block,), lambda b, vid: (vid[b],)),
            pl.BlockSpec((frog_block,), lambda b, vid: (b,)),
        ),
    )
    kernel = functools.partial(_stream_kernel, vertex_block=vertex_block,
                               use_device_rng=use_device_rng)
    counts, nxt = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=(
            jax.ShapeDtypeStruct((num_vb * vertex_block,), jnp.int32),
            jax.ShapeDtypeStruct((pos_p.shape[0],), jnp.int32),
        ),
        interpret=interpret,
    )(blk_vid, pos_p, die_p, bits_p, row_off, deg, col)
    return nxt, counts
