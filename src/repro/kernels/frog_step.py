"""Pallas TPU kernel: fused plain walker superstep (apply + scatter).

One superstep of the p_s = 1 walk is four XLA ops with an HBM round-trip
between each: gather ``deg[pos]``, draw a slot, gather ``col_idx[row_ptr[pos]
+ slot]``, scatter-add the deaths.  This kernel fuses them into a single
VMEM-resident pass:

  per (vertex-block, frog-block) tile:
    deg/row_ptr/col_idx stay resident in VMEM (the whole graph block — this
    kernel targets CPU-bench-sized shards; the engine's per-shard CSR blocks
    are exactly that),
    gather degree → slot = bits % deg → gather successor → one-hot-reduce
    the died frogs into the counts tile (the frog axis is the innermost
    sequential grid dimension, so the counts tile never leaves VMEM).

Random bits default to the caller (``jax.random`` outside) — the kernel is
deterministic and byte-for-byte testable against ``ref.frog_step_ref``, the
interpret-mode determinism contract. On real TPU pass
``use_device_rng=True`` (the bits operand becomes an ``int32[1]`` seed) and
the slot draw comes from the in-kernel ``pltpu.prng_random_bits`` —
deleting the HBM bits stream without touching the step semantics.

Dangling guard: ``d_out == 0`` ⇒ the frog stays put (the self-loop
convention, see graph/csr.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_VERTEX_BLOCK = 512
DEFAULT_FROG_BLOCK = 1024


def _frog_step_kernel(
    pos_ref, die_ref, bits_ref, row_ptr_ref, col_idx_ref, deg_ref,
    counts_ref, next_ref, *, vertex_block: int, use_device_rng: bool,
):
    iv, jf = pl.program_id(0), pl.program_id(1)

    @pl.when(jf == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    pos = pos_ref[...]                                          # [BF]
    die = die_ref[...]                                          # [BF] 0/1
    # --- scatter(): draw slot, gather successor (graph VMEM-resident) ---
    deg = jnp.take(deg_ref[...], pos, axis=0)                   # [BF]
    if use_device_rng:
        # A frog block is revisited once per vertex block and next_ref is
        # rewritten each time; seeding on (seed, iv, jf) makes every visit
        # an independent uniform draw, so the surviving (last-iv) write is
        # still exactly one uniform slot per frog. The large odd multiplier
        # keeps consecutive caller seeds (superstep indices) off each
        # other's tile streams.
        pltpu.prng_seed(
            bits_ref[0] * 1000003 + iv * pl.num_programs(1) + jf)
        raw = pltpu.bitcast(pltpu.prng_random_bits(pos.shape), jnp.uint32)
        bits = (raw >> 1).astype(jnp.int32)
    else:
        bits = bits_ref[...]
    slot = bits % jnp.maximum(deg, 1)
    edge = jnp.take(row_ptr_ref[...], pos, axis=0) + slot
    nxt = jnp.take(col_idx_ref[...], edge, axis=0)
    nxt = jnp.where(deg > 0, nxt, pos)                          # dangling guard
    next_ref[...] = nxt.astype(jnp.int32)
    # --- apply() tally: died frogs accumulate into the resident tile ---
    v0 = iv * vertex_block
    local = jnp.where(die > 0, pos - v0, -1)
    onehot = local[:, None] == jnp.arange(vertex_block)[None, :]  # [BF, BV]
    counts_ref[...] += onehot.sum(axis=0).astype(jnp.int32)


@functools.partial(
    jax.jit,
    static_argnames=("n_pad", "vertex_block", "frog_block", "interpret",
                     "use_device_rng"),
)
def frog_step(
    pos: jnp.ndarray,        # int32[N] — current vertex per frog
    die: jnp.ndarray,        # int32[N] — 1 where the frog dies this step
    bits: jnp.ndarray,       # int32[N] — slot bits; int32[1] seed in device-rng mode
    row_ptr: jnp.ndarray,    # int32[n + 1]
    col_idx: jnp.ndarray,    # int32[nnz]
    deg: jnp.ndarray,        # int32[n]
    n_pad: int,              # counts bins, multiple of vertex_block
    vertex_block: int = DEFAULT_VERTEX_BLOCK,
    frog_block: int = DEFAULT_FROG_BLOCK,
    interpret: bool = True,
    use_device_rng: bool = False,
):
    """Returns ``(next_pos int32[N], death_counts int32[n_pad])``."""
    (N,) = pos.shape
    if n_pad % vertex_block != 0:
        raise ValueError(f"n_pad={n_pad} not a multiple of {vertex_block}")
    if N % frog_block != 0:
        raise ValueError(f"N={N} not a multiple of {frog_block}")
    n1 = row_ptr.shape[0]
    nnz = col_idx.shape[0]
    nv = deg.shape[0]
    grid = (n_pad // vertex_block, N // frog_block)
    kernel = functools.partial(_frog_step_kernel, vertex_block=vertex_block,
                               use_device_rng=use_device_rng)
    whole = lambda shape: pl.BlockSpec(shape, lambda iv, jf: (0,) * len(shape))
    bits_spec = (pl.BlockSpec((1,), lambda iv, jf: (0,)) if use_device_rng
                 else pl.BlockSpec((frog_block,), lambda iv, jf: (jf,)))
    counts, nxt = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((frog_block,), lambda iv, jf: (jf,)),   # pos
            pl.BlockSpec((frog_block,), lambda iv, jf: (jf,)),   # die
            bits_spec,                                           # bits | seed
            whole((n1,)),                                        # row_ptr
            whole((nnz,)),                                       # col_idx
            whole((nv,)),                                        # deg
        ],
        out_specs=(
            pl.BlockSpec((vertex_block,), lambda iv, jf: (iv,)),
            pl.BlockSpec((frog_block,), lambda iv, jf: (jf,)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((n_pad,), jnp.int32),
            jax.ShapeDtypeStruct((N,), jnp.int32),
        ),
        interpret=interpret,
    )(pos, die, bits, row_ptr, col_idx, deg)
    return nxt, counts
