"""Pallas TPU kernel: hybrid-ELL sparse matrix–vector product.

This is the compute hot-spot of the GraphLab-PR baseline (power iteration
x ← Qx touches every edge, every iteration) and of the engine's count-vector
superstep. The graph's regular part is stored as an ELL slab
(``idx/weight: [rows, K]``, DESIGN.md §2); power-law hub rows spill to a COO
tail applied by the ops wrapper.

TPU mapping
-----------
* The dense vector ``x`` is pinned **whole in VMEM** (one BlockSpec covering
  the array): PageRank vectors are f32[n]; a 4M-vertex shard is 16 MB — the
  per-shard vertex range is sized so x fits (launch/mesh.py picks shard
  counts accordingly). This is the TPU-native replacement for the GPU
  "texture-cache gather" SpMV: HBM→VMEM once per superstep, then K·rows
  VMEM-random-access gathers, which the VPU does at register speed.
* The slab is processed in ``(ROW_BLOCK, K)`` tiles; K is padded to a
  multiple of 8 (f32 sublane) and ROW_BLOCK to 128 (lanes) so the
  gather+multiply+row-sum vectorizes cleanly.
* Weights encode validity (weight == 0 on padded lanes), so no mask tile.

Validated in interpret mode against ``ref.spmv_ref`` (tests/test_kernels.py
sweeps rows, K, dtypes, degree skews).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_ROW_BLOCK = 128


def _spmv_kernel(x_ref, idx_ref, w_ref, y_ref):
    """One (ROW_BLOCK, K) tile: y = Σ_k w[:, k] · x[idx[:, k]]."""
    x = x_ref[...]                                    # [n_pad] — whole vector in VMEM
    idx = idx_ref[...]                                # [BR, K]
    w = w_ref[...]                                    # [BR, K]
    gathered = jnp.take(x, idx.reshape(-1), axis=0).reshape(idx.shape)
    y_ref[...] = (gathered.astype(jnp.float32) * w.astype(jnp.float32)).sum(
        axis=1
    ).astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("row_block", "interpret"))
def spmv_ell_slab(
    idx: jnp.ndarray,        # int32[rows, K]
    weight: jnp.ndarray,     # f32[rows, K]
    x: jnp.ndarray,          # f32[n_pad]
    row_block: int = DEFAULT_ROW_BLOCK,
    interpret: bool = True,
) -> jnp.ndarray:
    rows, K = idx.shape
    if rows % row_block != 0:
        raise ValueError(f"rows={rows} must be a multiple of row_block={row_block}")
    grid = (rows // row_block,)
    return pl.pallas_call(
        _spmv_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(x.shape, lambda i: (0,)),               # x: whole vector
            pl.BlockSpec((row_block, K), lambda i: (i, 0)),      # idx tile
            pl.BlockSpec((row_block, K), lambda i: (i, 0)),      # weight tile
        ],
        out_specs=pl.BlockSpec((row_block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((rows,), x.dtype),
        interpret=interpret,
    )(x, idx, weight)
