"""Pallas TPU kernels: fused walk-segment gather-and-tally (query stitch).

The online query engine (``repro/query``) composes precomputed length-L walk
segments: one stitch round replaces L walker supersteps with a single gather
from the dense endpoint slab ``endpoints[n, R]`` — ``next = endpoints[pos,
slot]`` for a uniform segment slot — and walks whose step budget is exhausted
are tallied into the per-vertex counter. Written as separate XLA ops that is
a gather, a modulo, and a scatter-add with an HBM round-trip between each;
these kernels fuse them into one VMEM-resident pass, structurally the twin
of ``frog_step.py``.

Two variants share the tile schedule:

* :func:`stitch_step` — the **global** kernel: the whole flat slab is
  resident (bench-/single-device-sized slabs, same budget assumption as
  ``frog_step``'s graph block).
* :func:`stitch_step_local` — the **local-index** kernel for sharded
  serving: the resident slab is one shard's ``[shard_size, R]`` block and a
  ``base`` vertex offset rebases the gather. Walks the shard does not own
  (``pos ∉ [base, base + shard_size)``) contribute ``0`` to ``next`` and
  nothing to the tally, so per-shard outputs compose across shards by a
  plain ``psum`` (mesh) or host-side sum (single device): each walk is
  owned by exactly one shard. Per-device slab VMEM drops from ``4nR`` to
  ``4nR/S`` — the Twitter-scale serving answer.

Random bits default to the caller (``jax.random`` outside the kernel), so
the kernels are deterministic and byte-for-byte testable against the
``ref.py`` oracles — the interpret-mode determinism contract. On real TPU
pass ``use_device_rng=True`` (third operand becomes a seed) and the slot
draw comes from the in-kernel ``pltpu.prng_random_bits``, eliminating the
HBM bits stream without touching the stitch semantics.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_VERTEX_BLOCK = 512
DEFAULT_WALK_BLOCK = 1024


def _slot_bits(bits_ref, jw: int, shape, use_device_rng: bool):
    """Uniform nonnegative int32 bits for the slot draw.

    Caller mode reads the precomputed bits tile; device mode seeds the
    per-core PRNG on (seed, walk-block) — the gather runs once per walk
    block (``iv == 0``), so one draw per block keeps the walk's slot
    consistent across the whole grid. The seed is spread by a large odd
    multiplier so consecutive caller seeds (round indices) never share a
    block's stream.
    """
    if not use_device_rng:
        return bits_ref[...]
    pltpu.prng_seed(bits_ref[0] * 1000003 + jw)
    raw = pltpu.bitcast(pltpu.prng_random_bits(shape), jnp.uint32)
    return (raw >> 1).astype(jnp.int32)


def _stitch_kernel(
    pos_ref, stop_ref, bits_ref, endpoints_ref,
    counts_ref, next_ref, *, vertex_block: int, R: int, use_device_rng: bool,
):
    iv, jw = pl.program_id(0), pl.program_id(1)

    @pl.when(jw == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    pos = pos_ref[...]                                          # [BW]
    stop = stop_ref[...]                                        # [BW] 0/1

    # --- stitch: draw a segment slot, gather its endpoint (slab resident).
    # Only the tally below depends on the vertex-block index; the gather is
    # done once per walk block (its tile is first visited at iv == 0 and the
    # written block round-trips through HBM across later iv revisits, the
    # same read-modify-write contract the counts accumulation relies on).
    @pl.when(iv == 0)
    def _gather():
        slot = _slot_bits(bits_ref, jw, pos.shape, use_device_rng) % R
        nxt = jnp.take(endpoints_ref[...], pos * R + slot, axis=0)
        next_ref[...] = nxt.astype(jnp.int32)
    # --- tally: stopped walks accumulate into the resident counts tile ---
    v0 = iv * vertex_block
    local = jnp.where(stop > 0, pos - v0, -1)
    onehot = local[:, None] == jnp.arange(vertex_block)[None, :]  # [BW, BV]
    counts_ref[...] += onehot.sum(axis=0).astype(jnp.int32)


@functools.partial(
    jax.jit,
    static_argnames=("R", "n_pad", "vertex_block", "walk_block", "interpret",
                     "use_device_rng"),
)
def stitch_step(
    pos: jnp.ndarray,        # int32[W] — current vertex per walk
    stop: jnp.ndarray,       # int32[W] — 1 where the walk halts this round
    bits: jnp.ndarray,       # int32[W] — slot bits; int32[1] seed in device-rng mode
    endpoints: jnp.ndarray,  # int32[n · R] — flat walk-segment endpoint slab
    R: int,                  # segments per vertex
    n_pad: int,              # counts bins, multiple of vertex_block
    vertex_block: int = DEFAULT_VERTEX_BLOCK,
    walk_block: int = DEFAULT_WALK_BLOCK,
    interpret: bool = True,
    use_device_rng: bool = False,
):
    """Returns ``(next_pos int32[W], stop_counts int32[n_pad])``."""
    (W,) = pos.shape
    if n_pad % vertex_block != 0:
        raise ValueError(f"n_pad={n_pad} not a multiple of {vertex_block}")
    if W % walk_block != 0:
        raise ValueError(f"W={W} not a multiple of {walk_block}")
    nR = endpoints.shape[0]
    grid = (n_pad // vertex_block, W // walk_block)
    kernel = functools.partial(
        _stitch_kernel, vertex_block=vertex_block, R=R,
        use_device_rng=use_device_rng)
    bits_spec = (pl.BlockSpec((1,), lambda iv, jw: (0,)) if use_device_rng
                 else pl.BlockSpec((walk_block,), lambda iv, jw: (jw,)))
    counts, nxt = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((walk_block,), lambda iv, jw: (jw,)),   # pos
            pl.BlockSpec((walk_block,), lambda iv, jw: (jw,)),   # stop
            bits_spec,                                           # bits | seed
            pl.BlockSpec((nR,), lambda iv, jw: (0,)),            # endpoints
        ],
        out_specs=(
            pl.BlockSpec((vertex_block,), lambda iv, jw: (iv,)),
            pl.BlockSpec((walk_block,), lambda iv, jw: (jw,)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((n_pad,), jnp.int32),
            jax.ShapeDtypeStruct((W,), jnp.int32),
        ),
        interpret=interpret,
    )(pos, stop, bits, endpoints)
    return nxt, counts


def _stitch_gather_kernel(
    pos_ref, bits_ref, endpoints_ref, next_ref, *, R: int,
    use_device_rng: bool,
):
    jw = pl.program_id(0)
    pos = pos_ref[...]
    slot = _slot_bits(bits_ref, jw, pos.shape, use_device_rng) % R
    nxt = jnp.take(endpoints_ref[...], pos * R + slot, axis=0)
    next_ref[...] = nxt.astype(jnp.int32)


@functools.partial(
    jax.jit,
    static_argnames=("R", "walk_block", "interpret", "use_device_rng"),
)
def stitch_gather(
    pos: jnp.ndarray,        # int32[W] — current vertex per walk
    bits: jnp.ndarray,       # int32[W] — slot bits; int32[1] seed in device-rng mode
    endpoints: jnp.ndarray,  # int32[n · R] — flat walk-segment endpoint slab
    R: int,
    walk_block: int = DEFAULT_WALK_BLOCK,
    interpret: bool = True,
    use_device_rng: bool = False,
):
    """Gather-only stitch round → ``next_pos int32[W]``.

    The tally-free twin of :func:`stitch_step` for callers that defer the
    histogram to one final pass over the wave's end positions (the
    scheduler's fused ``lax.scan`` wave): no per-round counts output means
    a lean scan carry and a 1-D grid (walk blocks only). The slot draw is
    identical to :func:`stitch_step`'s (same ``_slot_bits`` per walk
    block), so the gathered positions are byte-identical.
    """
    (W,) = pos.shape
    if W % walk_block != 0:
        raise ValueError(f"W={W} not a multiple of {walk_block}")
    nR = endpoints.shape[0]
    grid = (W // walk_block,)
    kernel = functools.partial(
        _stitch_gather_kernel, R=R, use_device_rng=use_device_rng)
    bits_spec = (pl.BlockSpec((1,), lambda jw: (0,)) if use_device_rng
                 else pl.BlockSpec((walk_block,), lambda jw: (jw,)))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((walk_block,), lambda jw: (jw,)),        # pos
            bits_spec,                                            # bits | seed
            pl.BlockSpec((nR,), lambda jw: (0,)),                 # endpoints
        ],
        out_specs=pl.BlockSpec((walk_block,), lambda jw: (jw,)),
        out_shape=jax.ShapeDtypeStruct((W,), jnp.int32),
        interpret=interpret,
    )(pos, bits, endpoints)


def _stitch_local_kernel(
    pos_ref, stop_ref, bits_ref, base_ref, block_ref,
    counts_ref, next_ref, *, vertex_block: int, R: int, shard_size: int,
    use_device_rng: bool,
):
    iv, jw = pl.program_id(0), pl.program_id(1)

    @pl.when(jw == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    pos = pos_ref[...]                                          # [BW] global
    stop = stop_ref[...]                                        # [BW] 0/1
    local = pos - base_ref[0]                                   # shard-local
    owned = (local >= 0) & (local < shard_size)

    # --- stitch: gather from this shard's slab block only; walks owned by
    # other shards contribute the psum/host-sum identity 0.
    @pl.when(iv == 0)
    def _gather():
        slot = _slot_bits(bits_ref, jw, pos.shape, use_device_rng) % R
        li = jnp.clip(local, 0, shard_size - 1)
        nxt = jnp.take(block_ref[...], li * R + slot, axis=0)
        next_ref[...] = jnp.where(owned, nxt, 0).astype(jnp.int32)
    # --- tally: owned stopped walks into the shard-local counts tile ---
    v0 = iv * vertex_block
    lb = jnp.where((stop > 0) & owned, local - v0, -1)
    onehot = lb[:, None] == jnp.arange(vertex_block)[None, :]   # [BW, BV]
    counts_ref[...] += onehot.sum(axis=0).astype(jnp.int32)


def _stitch_gather_local_kernel(
    pos_ref, bits_ref, base_ref, block_ref, next_ref, *, R: int,
    shard_size: int, use_device_rng: bool,
):
    jw = pl.program_id(0)
    pos = pos_ref[...]
    local = pos - base_ref[0]
    owned = (local >= 0) & (local < shard_size)
    slot = _slot_bits(bits_ref, jw, pos.shape, use_device_rng) % R
    li = jnp.clip(local, 0, shard_size - 1)
    nxt = jnp.take(block_ref[...], li * R + slot, axis=0)
    next_ref[...] = jnp.where(owned, nxt, 0).astype(jnp.int32)


@functools.partial(
    jax.jit,
    static_argnames=("R", "shard_size", "walk_block", "interpret",
                     "use_device_rng"),
)
def stitch_gather_local(
    pos: jnp.ndarray,        # int32[W] — current *global* vertex per walk
    bits: jnp.ndarray,       # int32[W] — slot bits; int32[1] seed in device-rng mode
    base: jnp.ndarray,       # int32[1] — first global vertex this shard owns
    block: jnp.ndarray,      # int32[shard_size · R] — this shard's flat slab block
    R: int,
    shard_size: int,
    walk_block: int = DEFAULT_WALK_BLOCK,
    interpret: bool = True,
    use_device_rng: bool = False,
):
    """Gather-only per-shard stitch round → ``next_contrib int32[W]``.

    The tally-free twin of :func:`stitch_step_local` (see
    :func:`stitch_gather`): owned walks gather from the local block, the
    rest contribute the additive identity 0, and the per-round tally is
    simply not computed — the wave histograms once over final positions.
    """
    (W,) = pos.shape
    if W % walk_block != 0:
        raise ValueError(f"W={W} not a multiple of {walk_block}")
    szR = block.shape[0]
    grid = (W // walk_block,)
    kernel = functools.partial(
        _stitch_gather_local_kernel, R=R, shard_size=shard_size,
        use_device_rng=use_device_rng)
    bits_spec = (pl.BlockSpec((1,), lambda jw: (0,)) if use_device_rng
                 else pl.BlockSpec((walk_block,), lambda jw: (jw,)))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((walk_block,), lambda jw: (jw,)),        # pos
            bits_spec,                                            # bits | seed
            pl.BlockSpec((1,), lambda jw: (0,)),                  # base
            pl.BlockSpec((szR,), lambda jw: (0,)),                # slab block
        ],
        out_specs=pl.BlockSpec((walk_block,), lambda jw: (jw,)),
        out_shape=jax.ShapeDtypeStruct((W,), jnp.int32),
        interpret=interpret,
    )(pos, bits, base, block)


@functools.partial(
    jax.jit,
    static_argnames=("R", "shard_size", "sz_pad", "vertex_block",
                     "walk_block", "interpret", "use_device_rng"),
)
def stitch_step_local(
    pos: jnp.ndarray,        # int32[W] — current *global* vertex per walk
    stop: jnp.ndarray,       # int32[W] — 1 where the walk halts this round
    bits: jnp.ndarray,       # int32[W] — slot bits; int32[1] seed in device-rng mode
    base: jnp.ndarray,       # int32[1] — first global vertex this shard owns
    block: jnp.ndarray,      # int32[shard_size · R] — this shard's flat slab block
    R: int,
    shard_size: int,
    sz_pad: int,             # local counts bins, multiple of vertex_block
    vertex_block: int = DEFAULT_VERTEX_BLOCK,
    walk_block: int = DEFAULT_WALK_BLOCK,
    interpret: bool = True,
    use_device_rng: bool = False,
):
    """Per-shard stitch round against a local slab block.

    Returns ``(next_contrib int32[W], stop_counts int32[sz_pad])`` where
    ``next_contrib`` is ``endpoints[pos, slot]`` for owned walks and ``0``
    otherwise, and the tally covers only vertices in
    ``[base, base + shard_size)`` rebased to local bins — both compose
    across shards by summation.
    """
    (W,) = pos.shape
    if sz_pad % vertex_block != 0:
        raise ValueError(f"sz_pad={sz_pad} not a multiple of {vertex_block}")
    if W % walk_block != 0:
        raise ValueError(f"W={W} not a multiple of {walk_block}")
    szR = block.shape[0]
    grid = (sz_pad // vertex_block, W // walk_block)
    kernel = functools.partial(
        _stitch_local_kernel, vertex_block=vertex_block, R=R,
        shard_size=shard_size, use_device_rng=use_device_rng)
    bits_spec = (pl.BlockSpec((1,), lambda iv, jw: (0,)) if use_device_rng
                 else pl.BlockSpec((walk_block,), lambda iv, jw: (jw,)))
    counts, nxt = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((walk_block,), lambda iv, jw: (jw,)),   # pos
            pl.BlockSpec((walk_block,), lambda iv, jw: (jw,)),   # stop
            bits_spec,                                           # bits | seed
            pl.BlockSpec((1,), lambda iv, jw: (0,)),             # base
            pl.BlockSpec((szR,), lambda iv, jw: (0,)),           # slab block
        ],
        out_specs=(
            pl.BlockSpec((vertex_block,), lambda iv, jw: (iv,)),
            pl.BlockSpec((walk_block,), lambda iv, jw: (jw,)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((sz_pad,), jnp.int32),
            jax.ShapeDtypeStruct((W,), jnp.int32),
        ),
        interpret=interpret,
    )(pos, stop, bits, base, block)
    return nxt, counts
