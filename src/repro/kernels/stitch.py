"""Pallas TPU kernel: fused walk-segment gather-and-tally (query stitch).

The online query engine (``repro/query``) composes precomputed length-L walk
segments: one stitch round replaces L walker supersteps with a single gather
from the dense endpoint slab ``endpoints[n, R]`` — ``next = endpoints[pos,
slot]`` for a uniform segment slot — and walks whose step budget is exhausted
are tallied into the per-vertex counter. Written as separate XLA ops that is
a gather, a modulo, and a scatter-add with an HBM round-trip between each;
this kernel fuses them into one VMEM-resident pass, structurally the twin of
``frog_step.py``:

  per (vertex-block, walk-block) tile:
    the flat endpoint slab stays resident in VMEM (bench-/shard-sized
    slabs, same budget assumption as frog_step's graph block),
    slot = bits % R → gather endpoints[pos · R + slot] → one-hot-reduce the
    stopped walks into the counts tile (walk axis is the innermost
    sequential grid dimension, so the counts tile never leaves VMEM).

Random bits come from the caller (``jax.random`` outside the kernel), so the
kernel is deterministic and byte-for-byte testable against
``ref.stitch_step_ref``; on real TPU the bits input can be swapped for
``pltpu.prng_random_bits`` without touching the stitch semantics.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_VERTEX_BLOCK = 512
DEFAULT_WALK_BLOCK = 1024


def _stitch_kernel(
    pos_ref, stop_ref, bits_ref, endpoints_ref,
    counts_ref, next_ref, *, vertex_block: int, R: int,
):
    iv, jw = pl.program_id(0), pl.program_id(1)

    @pl.when(jw == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    pos = pos_ref[...]                                          # [BW]
    stop = stop_ref[...]                                        # [BW] 0/1

    # --- stitch: draw a segment slot, gather its endpoint (slab resident).
    # Only the tally below depends on the vertex-block index; the gather is
    # done once per walk block (its tile is first visited at iv == 0 and the
    # written block round-trips through HBM across later iv revisits, the
    # same read-modify-write contract the counts accumulation relies on).
    @pl.when(iv == 0)
    def _gather():
        slot = bits_ref[...] % R
        nxt = jnp.take(endpoints_ref[...], pos * R + slot, axis=0)
        next_ref[...] = nxt.astype(jnp.int32)
    # --- tally: stopped walks accumulate into the resident counts tile ---
    v0 = iv * vertex_block
    local = jnp.where(stop > 0, pos - v0, -1)
    onehot = local[:, None] == jnp.arange(vertex_block)[None, :]  # [BW, BV]
    counts_ref[...] += onehot.sum(axis=0).astype(jnp.int32)


@functools.partial(
    jax.jit,
    static_argnames=("R", "n_pad", "vertex_block", "walk_block", "interpret"),
)
def stitch_step(
    pos: jnp.ndarray,        # int32[W] — current vertex per walk
    stop: jnp.ndarray,       # int32[W] — 1 where the walk halts this round
    bits: jnp.ndarray,       # int32[W] — uniform random bits for the slot draw
    endpoints: jnp.ndarray,  # int32[n · R] — flat walk-segment endpoint slab
    R: int,                  # segments per vertex
    n_pad: int,              # counts bins, multiple of vertex_block
    vertex_block: int = DEFAULT_VERTEX_BLOCK,
    walk_block: int = DEFAULT_WALK_BLOCK,
    interpret: bool = True,
):
    """Returns ``(next_pos int32[W], stop_counts int32[n_pad])``."""
    (W,) = pos.shape
    if n_pad % vertex_block != 0:
        raise ValueError(f"n_pad={n_pad} not a multiple of {vertex_block}")
    if W % walk_block != 0:
        raise ValueError(f"W={W} not a multiple of {walk_block}")
    nR = endpoints.shape[0]
    grid = (n_pad // vertex_block, W // walk_block)
    kernel = functools.partial(
        _stitch_kernel, vertex_block=vertex_block, R=R)
    counts, nxt = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((walk_block,), lambda iv, jw: (jw,)),   # pos
            pl.BlockSpec((walk_block,), lambda iv, jw: (jw,)),   # stop
            pl.BlockSpec((walk_block,), lambda iv, jw: (jw,)),   # bits
            pl.BlockSpec((nR,), lambda iv, jw: (0,)),            # endpoints
        ],
        out_specs=(
            pl.BlockSpec((vertex_block,), lambda iv, jw: (iv,)),
            pl.BlockSpec((walk_block,), lambda iv, jw: (jw,)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((n_pad,), jnp.int32),
            jax.ShapeDtypeStruct((W,), jnp.int32),
        ),
        interpret=interpret,
    )(pos, stop, bits, endpoints)
    return nxt, counts
