"""Jitted public wrappers over the Pallas kernels.

These handle padding, hybrid spill application, and backend dispatch
(``impl="pallas"`` → interpret-mode kernel on CPU / compiled kernel on TPU,
``impl="ref"`` → pure-jnp oracle). Model code and the engine call these, so
swapping implementations is a config flag, not a code change.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.partition import EllGraph
from repro.kernels import ref as kref
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.frog_scatter import frog_count as _frog_count
from repro.kernels.frog_step import frog_step as _frog_step
from repro.kernels.frog_step_stream import (BlockedCSR, block_csr,
                                            frog_step_stream_sorted)
from repro.kernels.spmv_ell import spmv_ell_slab
from repro.kernels.stitch import stitch_gather as _stitch_gather
from repro.kernels.stitch import stitch_gather_local as _stitch_gather_local
from repro.kernels.stitch import stitch_step as _stitch_step
from repro.kernels.stitch import stitch_step_local as _stitch_step_local

# VMEM the resident frog_step kernel may spend on its graph block before
# impl="auto" switches to the HBM-streaming kernel (half a 16 MB core,
# leaving room for the frog tiles and double buffers).
STREAM_VMEM_BUDGET = 8 * 1024 * 1024


def resident_graph_bytes(n: int, nnz: int) -> int:
    """VMEM bytes the resident ``frog_step`` kernel pins for the graph
    (row_ptr + col_idx + deg, int32)."""
    return 4 * ((n + 1) + nnz + n)


def _rng_mode(rng: str, interpret: bool, seed):
    """Resolves the kernel RNG mode → ``(use_device_rng, seed_arr | None)``.

    ``rng="device"`` swaps the caller-supplied bits stream for the
    in-kernel ``pltpu.prng_random_bits`` draw (the bits operand becomes a
    scalar seed). That primitive only lowers on real TPU — interpret mode
    keeps the seeded-bits path as the determinism contract, so requesting
    both is a configuration error, not a silent fallback. The seed is
    mandatory and must be **fresh per call** (e.g. fold in the superstep /
    stitch-round index): the kernels are deterministic in it, so reusing a
    seed replays the identical bit stream and correlates every draw.
    """
    if rng == "caller":
        return False, None
    if rng != "device":
        raise ValueError(f"unknown rng mode {rng!r}")
    if interpret:
        raise ValueError(
            'rng="device" draws slot bits with pltpu.prng_random_bits, '
            "which lowers only on TPU hardware; interpret mode keeps the "
            'caller-supplied bits path (rng="caller") for byte-for-byte '
            "determinism tests")
    if seed is None:
        raise ValueError(
            'rng="device" needs an explicit per-call seed= (fold in the '
            "step index; a reused seed replays the same bit stream and "
            "biases iterated walks)")
    return True, jnp.asarray([seed], jnp.int32)


def _pad_to(x: jnp.ndarray, m: int, axis: int = 0, value=0):
    size = x.shape[axis]
    pad = (-size) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def spmv(ell: EllGraph, x: jnp.ndarray, impl: str = "pallas",
         interpret: bool = True, row_block: int = 128) -> jnp.ndarray:
    """Hybrid-ELL SpMV: y = P @ x (slab kernel + COO spill tail).

    ``x`` must have length ≥ max referenced vertex id; output has
    ``ell.n_rows`` entries (callers slice to the true n).
    """
    idx = _pad_to(ell.idx, row_block)
    w = _pad_to(ell.weight, row_block)
    if impl == "pallas":
        y = spmv_ell_slab(idx, w, x, row_block=row_block, interpret=interpret)
        y = y[: ell.n_rows]
    elif impl == "ref":
        y = kref.spmv_ref(ell.idx, ell.weight, x)
    else:
        raise ValueError(f"unknown impl {impl!r}")
    if ell.spill_nnz:
        y = y + kref.spill_ref(ell.spill_src, ell.spill_dst, ell.spill_w, x,
                               ell.n_rows)
    return y


def frog_count(dest: jnp.ndarray, n: int, impl: str = "pallas",
               interpret: bool = True, vertex_block: int = 512,
               frog_block: int = 1024,
               assume_sorted: bool = False) -> jnp.ndarray:
    """Histogram of frog destinations into n vertex bins (int32).

    * ``pallas`` — compare-and-reduce tile kernel (O(N · n/vertex_block)
      one-hot work; wins when n is small and the VPU eats the tiles).
    * ``sort``   — sort + searchsorted segment counts (O((N+n) log N); the
      scalable path when n is large). With ``assume_sorted=True`` the sort
      is skipped — callers that already hold sorted destinations (e.g. the
      streamed superstep's block-sorted frogs) pay only the O(n log N)
      searchsorted pass.
    * ``ref``    — XLA scatter-add oracle.
    * ``auto``   — picks by the work model: one-hot tile work
      ``N · ⌈n/vertex_block⌉`` vs sort work ``(N+n) · ⌈log₂N⌉`` (always
      ``sort`` when the input is already sorted).
    """
    if impl == "auto":
        N = dest.shape[0]
        onehot_work = N * -(-n // vertex_block)
        sort_work = (N + n) * max(1, int(np.ceil(np.log2(max(N, 2)))))
        impl = ("sort" if assume_sorted or onehot_work > sort_work
                else "pallas")
    if impl == "ref":
        return kref.frog_count_ref(dest, n)
    if impl == "sort":
        return kref.frog_count_sort(dest, n, assume_sorted=assume_sorted)
    if impl != "pallas":
        raise ValueError(f"unknown impl {impl!r}")
    vertex_block = min(vertex_block, n)
    n_pad = ((n + vertex_block - 1) // vertex_block) * vertex_block
    # Padded frogs land on bin n_pad-1? No: route them to an existing bin and
    # subtract. Simpler: pad with vertex id `n_pad` mapped into a discard bin.
    N = dest.shape[0]
    frog_block = min(frog_block, max(8, N))
    dest_p = _pad_to(dest, frog_block, value=-1)  # -1 never matches a bin
    counts = _frog_count(dest_p, n_pad, vertex_block=vertex_block,
                         frog_block=frog_block, interpret=interpret)
    return counts[:n]


def _frog_step_stream(
    pos, die, bits, blocked: BlockedCSR, n: int, frog_block: int,
    interpret: bool, seed_arr: Optional[jnp.ndarray] = None,
):
    """Stream-path prologue/epilogue: sort frogs by vertex block, pad each
    block's segment to a ``frog_block`` multiple with inert frogs, run the
    scalar-prefetch streamed kernel, unsort. With ``seed_arr`` set the
    kernel draws its own bits (device RNG) and no bits stream is sorted."""
    N = pos.shape[0]
    bv, num_vb = blocked.vertex_block, blocked.num_blocks
    fb = min(frog_block, max(8, N))
    order = jnp.argsort(pos)            # by vertex ⇒ by vertex block
    pos_s, die_s = pos[order], die[order]
    bits_s = None if seed_arr is not None else bits[order]
    # Per-block frog counts from the sorted positions (the sort is reused by
    # the in-kernel segment-sum tally — no second histogram pass).
    starts = jnp.searchsorted(
        pos_s, jnp.arange(num_vb + 1, dtype=pos.dtype) * bv, side="left"
    ).astype(jnp.int32)
    cnt = starts[1:] - starts[:-1]
    pad_cnt = ((cnt + fb - 1) // fb) * fb
    pad_off = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(pad_cnt)])
    # Static worst case: at most min(num_vb, N) blocks can be nonempty
    # (each needs ≥ 1 frog) and only nonempty blocks get padded, by at most
    # fb − 1 slots each — keeps the padded arrays ∝ N, not num_vb, in the
    # sparse-frog regime.
    p_pad = int(np.ceil((N + min(num_vb, N) * (fb - 1)) / fb) * fb)
    blk_s = (pos_s // bv).astype(jnp.int32)
    dst = pad_off[blk_s] + jnp.arange(N, dtype=jnp.int32) - starts[blk_s]
    # Slot → owning vertex block (trailing unused slots ride with the last
    # block). Inert slots sit on their block's last vertex — keeps every
    # tile sorted and in-block — and never die, so they tally nothing and
    # their next position is discarded by the unsort below.
    slot_vid = jnp.clip(
        jnp.searchsorted(pad_off, jnp.arange(p_pad, dtype=jnp.int32),
                         side="right").astype(jnp.int32) - 1,
        0, num_vb - 1)
    pos_p = ((slot_vid + 1) * bv - 1).at[dst].set(pos_s)
    die_p = jnp.zeros((p_pad,), jnp.int32).at[dst].set(die_s)
    bits_p = (seed_arr if seed_arr is not None
              else jnp.zeros((p_pad,), jnp.int32).at[dst].set(bits_s))
    blk_vid = slot_vid[::fb]
    nxt_p, counts = frog_step_stream_sorted(
        pos_p, die_p, bits_p, blk_vid,
        blocked.row_off, blocked.deg, blocked.col,
        num_fb=p_pad // fb, vertex_block=bv, frog_block=fb,
        interpret=interpret, use_device_rng=seed_arr is not None,
    )
    # Count blocks the grid never visited hold uninitialized memory.
    counts = jnp.where((cnt > 0)[:, None],
                       counts.reshape(num_vb, bv), 0).reshape(-1)
    nxt = jnp.zeros((N,), jnp.int32).at[order].set(nxt_p[dst])
    return nxt, counts[:n]


def frog_step(
    pos: jnp.ndarray,
    die: jnp.ndarray,
    bits: Optional[jnp.ndarray],
    row_ptr: jnp.ndarray,
    col_idx: jnp.ndarray,
    deg: jnp.ndarray,
    n: int,
    impl: str = "pallas",
    interpret: bool = True,
    vertex_block: int = 512,
    frog_block: int = 1024,
    blocked: Optional[BlockedCSR] = None,
    vmem_budget: int = STREAM_VMEM_BUDGET,
    rng: str = "caller",
    seed: Optional[int] = None,
):
    """Fused plain walker superstep → ``(next_pos[N], death_counts[n])``.

    * ``pallas`` — the VMEM-resident fused kernel (interpret mode on CPU);
      assumes the whole graph block fits VMEM.
    * ``stream`` — the HBM-streaming kernel: frogs sorted by vertex block,
      per-block CSR slabs DMA'd through VMEM once per superstep, tally by
      sort-compacted segment sum. Needs a :class:`BlockedCSR` — pass
      ``blocked=`` when the graph arrays are traced; otherwise it is built
      (and folded into the trace) from the concrete arrays.
    * ``ref``    — pure-jnp oracle.
    * ``auto``   — ``pallas`` while ``resident_graph_bytes(n, nnz)`` fits
      ``vmem_budget``, else ``stream`` (falling back to ``pallas`` when no
      ``blocked`` layout is available from traced arrays).

    ``rng="device"`` (compiled TPU only) draws the slot bits in-kernel with
    ``pltpu.prng_random_bits`` seeded from ``seed`` — ``bits`` may then be
    ``None``; ``rng="caller"`` (default) keeps the deterministic
    caller-supplied bits path.

    Handles all padding here so callers pass natural shapes.
    """
    die = die.astype(jnp.int32)
    use_device_rng, seed_arr = _rng_mode(rng, interpret, seed)
    if not use_device_rng:
        bits = jnp.abs(bits).astype(jnp.int32)
    if impl == "auto":
        fits = resident_graph_bytes(n, col_idx.shape[0]) <= vmem_budget
        traced = blocked is None and isinstance(row_ptr, jax.core.Tracer)
        impl = "pallas" if (fits or traced) else "stream"
    if impl == "ref":
        if use_device_rng:
            raise ValueError('rng="device" has no jnp oracle (impl="ref")')
        return kref.frog_step_ref(pos, die, bits, row_ptr, col_idx, deg, n)
    if impl == "stream":
        if blocked is None:
            if isinstance(row_ptr, jax.core.Tracer):
                raise ValueError(
                    "impl='stream' needs a prebuilt BlockedCSR (blocked=) "
                    "when the graph arrays are traced — the slab width is a "
                    "static shape (see kernels/frog_step_stream.block_csr)")
            blocked = block_csr(row_ptr, col_idx, deg, n,
                                vertex_block=vertex_block)
        return _frog_step_stream(pos, die, bits, blocked, n,
                                 frog_block=frog_block, interpret=interpret,
                                 seed_arr=seed_arr)
    if impl != "pallas":
        raise ValueError(f"unknown impl {impl!r}")
    N = pos.shape[0]
    vertex_block = min(vertex_block, max(8, n))
    n_pad = ((n + vertex_block - 1) // vertex_block) * vertex_block
    frog_block = min(frog_block, max(8, N))
    # padded frogs: parked on vertex 0, not dying, slot bits 0 — their next
    # position is discarded by the slice below and they tally nothing.
    pos_p = _pad_to(pos, frog_block)
    die_p = _pad_to(die, frog_block)
    bits_p = seed_arr if use_device_rng else _pad_to(bits, frog_block)
    nxt, counts = _frog_step(
        pos_p, die_p, bits_p, row_ptr, col_idx, deg, n_pad,
        vertex_block=vertex_block, frog_block=frog_block,
        interpret=interpret, use_device_rng=use_device_rng,
    )
    return nxt[:N], counts[:n]


def stitch_step(
    pos: jnp.ndarray,
    stop: jnp.ndarray,
    bits: Optional[jnp.ndarray],
    endpoints: jnp.ndarray,  # int32[n, R] — walk-segment endpoint slab
    n: int,
    impl: str = "pallas",
    interpret: bool = True,
    vertex_block: int = 512,
    walk_block: int = 1024,
    rng: str = "caller",
    seed: Optional[int] = None,
    tally: bool = True,
):
    """Fused query stitch round → ``(next_pos[W], stop_counts[n])``.

    One round replaces ``segment_len`` walker supersteps: gather a uniformly
    chosen precomputed segment endpoint per walk and tally the walks whose
    budget ran out. ``pallas`` runs the VMEM-resident fused kernel
    (interpret mode on CPU); ``ref`` is the pure-jnp oracle.
    ``rng="device"`` (compiled TPU only) draws the slot bits in-kernel from
    ``seed`` instead of the caller's ``bits`` stream. Padding is handled
    here so callers pass natural shapes.

    ``tally=False`` runs the gather-only variant and returns
    ``(next_pos[W], None)`` — for callers that defer the histogram to one
    pass over the wave's final positions (the scheduler's fused
    ``lax.scan`` wave, where a per-round counts output would just fatten
    the scan carry to be thrown away). ``next_pos`` is byte-identical to
    the tallying kernel's.
    """
    stop = stop.astype(jnp.int32)
    use_device_rng, seed_arr = _rng_mode(rng, interpret, seed)
    if not use_device_rng:
        bits = jnp.abs(bits).astype(jnp.int32)
    if impl == "ref":
        if use_device_rng:
            raise ValueError('rng="device" has no jnp oracle (impl="ref")')
        if not tally:
            R = endpoints.shape[1]
            return endpoints[pos, bits % R].astype(jnp.int32), None
        return kref.stitch_step_ref(pos, stop, bits, endpoints, n)
    if impl != "pallas":
        raise ValueError(f"unknown impl {impl!r}")
    W = pos.shape[0]
    R = endpoints.shape[1]
    if not tally:
        wb = min(walk_block, max(8, W))
        pos_p = _pad_to(pos, wb)
        bits_p = seed_arr if use_device_rng else _pad_to(bits, wb)
        nxt = _stitch_gather(pos_p, bits_p, endpoints.reshape(-1), R,
                             walk_block=wb, interpret=interpret,
                             use_device_rng=use_device_rng)
        return nxt[:W], None
    vertex_block = min(vertex_block, max(8, n))
    n_pad = ((n + vertex_block - 1) // vertex_block) * vertex_block
    walk_block = min(walk_block, max(8, W))
    # padded walks: parked on vertex 0, not stopping, slot bits 0 — their
    # next position is discarded by the slice below and they tally nothing.
    pos_p = _pad_to(pos, walk_block)
    stop_p = _pad_to(stop, walk_block)
    bits_p = seed_arr if use_device_rng else _pad_to(bits, walk_block)
    nxt, counts = _stitch_step(
        pos_p, stop_p, bits_p, endpoints.reshape(-1), R, n_pad,
        vertex_block=vertex_block, walk_block=walk_block,
        interpret=interpret, use_device_rng=use_device_rng,
    )
    return nxt[:W], counts[:n]


def stitch_step_local(
    pos: jnp.ndarray,
    stop: jnp.ndarray,
    bits: Optional[jnp.ndarray],
    block: jnp.ndarray,      # int32[shard_size, R] — one shard's slab block
    base,                    # int — first global vertex this shard owns
    impl: str = "pallas",
    interpret: bool = True,
    vertex_block: int = 512,
    walk_block: int = 1024,
    rng: str = "caller",
    seed: Optional[int] = None,
    tally: bool = True,
):
    """Per-shard stitch round against a local ``[shard_size, R]`` slab block.

    Returns ``(next_contrib[W], stop_counts[shard_size])``: owned walks
    (``pos ∈ [base, base + shard_size)``) gather their next endpoint from
    the local block and are tallied into shard-local bins; all other walks
    contribute 0 — so summing the outputs over shards (``psum`` on a mesh,
    host sum on one device) reproduces :func:`stitch_step` exactly, while
    every device holds only ``4·n·R/S`` bytes of slab.

    ``tally=False`` → ``(next_contrib[W], None)``, the gather-only variant
    (see :func:`stitch_step`): byte-identical contributions, no per-round
    counts — the wave histograms once over its final positions.
    """
    stop = stop.astype(jnp.int32)
    use_device_rng, seed_arr = _rng_mode(rng, interpret, seed)
    if not use_device_rng:
        bits = jnp.abs(bits).astype(jnp.int32)
    base_arr = jnp.asarray(base, jnp.int32).reshape((1,))
    if impl == "ref":
        if use_device_rng:
            raise ValueError('rng="device" has no jnp oracle (impl="ref")')
        if not tally:
            sz, R = block.shape
            local = pos - base_arr[0]
            owned = (local >= 0) & (local < sz)
            li = jnp.clip(local, 0, sz - 1)
            nxt = jnp.where(owned, block[li, bits % R], 0)
            return nxt.astype(jnp.int32), None
        return kref.stitch_step_local_ref(pos, stop, bits, block, base_arr)
    if impl != "pallas":
        raise ValueError(f"unknown impl {impl!r}")
    W = pos.shape[0]
    sz, R = block.shape
    if not tally:
        wb = min(walk_block, max(8, W))
        pos_p = _pad_to(pos, wb)
        bits_p = seed_arr if use_device_rng else _pad_to(bits, wb)
        nxt = _stitch_gather_local(pos_p, bits_p, base_arr,
                                   block.reshape(-1), R, sz, walk_block=wb,
                                   interpret=interpret,
                                   use_device_rng=use_device_rng)
        return nxt[:W], None
    vertex_block = min(vertex_block, max(8, sz))
    sz_pad = ((sz + vertex_block - 1) // vertex_block) * vertex_block
    walk_block = min(walk_block, max(8, W))
    pos_p = _pad_to(pos, walk_block)
    stop_p = _pad_to(stop, walk_block)
    bits_p = seed_arr if use_device_rng else _pad_to(bits, walk_block)
    nxt, counts = _stitch_step_local(
        pos_p, stop_p, bits_p, base_arr, block.reshape(-1), R, sz, sz_pad,
        vertex_block=vertex_block, walk_block=walk_block,
        interpret=interpret, use_device_rng=use_device_rng,
    )
    return nxt[:W], counts[:sz]


def attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    soft_cap: Optional[float] = None,
    impl: str = "jnp_flash",
    interpret: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    chunk: int = 512,
) -> jnp.ndarray:
    """GQA attention, dispatching between three implementations.

    * ``jnp_flash`` — chunked online-softmax in pure jnp (memory-bounded,
      XLA-compilable anywhere). Default: what the models lower in dry-runs.
    * ``pallas``    — the flash TPU kernel (target hardware implementation;
      interpret mode on CPU).
    * ``ref``       — O(S²)-memory oracle, tests only.
    """
    if impl == "ref":
        return kref.attention_ref(q, k, v, causal=causal, window=window,
                                  q_offset=q_offset, logit_soft_cap=soft_cap)
    if impl == "jnp_flash":
        return kref.attention_chunked(q, k, v, causal=causal, window=window,
                                      q_offset=q_offset, logit_soft_cap=soft_cap,
                                      chunk=chunk)
    Sq, Skv = q.shape[2], k.shape[2]
    bq, bk = min(block_q, Sq), min(block_k, Skv)
    qp = _pad_to(q, bq, axis=2)
    kp = _pad_to(k, bk, axis=2)
    vp = _pad_to(v, bk, axis=2)
    out = _flash(qp, kp, vp, causal=causal, window=window, q_offset=q_offset,
                 block_q=bq, block_k=bk, soft_cap=soft_cap, interpret=interpret)
    return out[:, :, :Sq]
