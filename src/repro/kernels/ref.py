"""Pure-jnp oracles for every Pallas kernel in this package.

Each kernel's test sweeps shapes/dtypes and asserts allclose against these.
They are also the fallback implementation used on non-TPU backends (the
512-device CPU dry-run compiles these; the Pallas kernels are the TPU-target
implementations, validated in interpret mode).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def spmv_ref(idx: jnp.ndarray, weight: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """y[i] = Σ_k weight[i, k] · x[idx[i, k]]   (hybrid-ELL slab part).

    Invalid lanes are encoded by weight == 0 (idx may be garbage but always
    in-range), so no mask argument is needed.
    """
    gathered = jnp.take(x, idx, axis=0)          # [rows, K]
    return (gathered * weight).sum(axis=1).astype(x.dtype)


def spill_ref(
    spill_src: jnp.ndarray,
    spill_dst: jnp.ndarray,
    spill_w: jnp.ndarray,
    x: jnp.ndarray,
    n: int,
) -> jnp.ndarray:
    """COO tail of the hybrid SpMV: y[dst] += w · x[src]."""
    if spill_src.shape[0] == 0:
        return jnp.zeros((n,), dtype=x.dtype)
    return jax.ops.segment_sum(
        x[spill_src] * spill_w.astype(x.dtype), spill_dst, num_segments=n
    )


def frog_count_ref(dest: jnp.ndarray, n: int, weights: Optional[jnp.ndarray] = None
                   ) -> jnp.ndarray:
    """counts[v] = Σ_f weights[f] · 1{dest[f] == v}. int32 when weights=None.

    Entries outside [0, n) (padding sentinels like -1) are ignored — the
    same contract as the sort and pallas implementations (a raw scatter
    would wrap -1 to n-1 under JAX negative indexing)."""
    dest = jnp.where((dest >= 0) & (dest < n), dest, n)
    if weights is None:
        return jnp.zeros((n + 1,), jnp.int32).at[dest].add(1)[:n]
    return jnp.zeros((n + 1,), weights.dtype).at[dest].add(weights)[:n]


def frog_count_sort(dest: jnp.ndarray, n: int,
                    assume_sorted: bool = False) -> jnp.ndarray:
    """Sort-based histogram: counts[v] = #{f : dest[f] == v}.

    O((N + n) log N) with no scatter and no [N, n/BV] one-hot tiles — the
    TPU-friendly replacement for the compare-and-reduce histogram when n is
    large relative to the vertex block.  Entries outside [0, n) (padding
    sentinels like -1) are ignored.  ``assume_sorted=True`` skips the sort
    (the caller already paid for it — e.g. the streamed superstep's
    block-sorted frogs), leaving only the O(n log N) searchsorted pass.
    """
    s = dest if assume_sorted else jnp.sort(dest)
    bounds = jnp.searchsorted(
        s, jnp.arange(n + 1, dtype=dest.dtype), side="left"
    )
    return (bounds[1:] - bounds[:-1]).astype(jnp.int32)


def frog_step_ref(
    pos: jnp.ndarray,        # int32[N]
    die: jnp.ndarray,        # int32[N] — 1 where the frog dies this step
    bits: jnp.ndarray,       # int32[N] — uniform bits for the slot draw
    row_ptr: jnp.ndarray,    # int32[n + 1]
    col_idx: jnp.ndarray,    # int32[nnz]
    deg: jnp.ndarray,        # int32[n]
    n: int,
):
    """Oracle for the fused walker step: (next_pos, death_counts).

    next = col_idx[row_ptr[pos] + bits % deg[pos]] (stay put when d_out = 0);
    counts tallies the died frogs at their current vertex.
    """
    d = deg[pos]
    slot = bits % jnp.maximum(d, 1)
    nxt = jnp.where(d > 0, col_idx[row_ptr[pos] + slot], pos)
    counts = jnp.zeros((n,), jnp.int32).at[pos].add(die.astype(jnp.int32))
    return nxt.astype(jnp.int32), counts


def stitch_step_ref(
    pos: jnp.ndarray,        # int32[W]
    stop: jnp.ndarray,       # int32[W] — 1 where the walk halts this round
    bits: jnp.ndarray,       # int32[W] — uniform bits for the segment slot
    endpoints: jnp.ndarray,  # int32[n, R] — walk-segment endpoint slab
    n: int,
):
    """Oracle for the fused stitch round: (next_pos, stop_counts).

    next = endpoints[pos, bits % R]; counts tallies the halting walks at
    their current vertex.
    """
    R = endpoints.shape[1]
    nxt = endpoints[pos, bits % R]
    counts = jnp.zeros((n,), jnp.int32).at[pos].add(stop.astype(jnp.int32))
    return nxt.astype(jnp.int32), counts


def stitch_step_local_ref(
    pos: jnp.ndarray,        # int32[W] — global vertex per walk
    stop: jnp.ndarray,       # int32[W] — 1 where the walk halts this round
    bits: jnp.ndarray,       # int32[W] — uniform bits for the segment slot
    block: jnp.ndarray,      # int32[shard_size, R] — one shard's slab block
    base: jnp.ndarray,       # int32[] / int32[1] — first vertex this shard owns
):
    """Oracle for the per-shard local-index stitch round.

    Owned walks (``pos ∈ [base, base + shard_size)``) gather from the local
    block; the rest contribute 0 — outputs sum across shards to the global
    :func:`stitch_step_ref` result (each walk has exactly one owner).
    Returns ``(next_contrib int32[W], stop_counts int32[shard_size])``.
    """
    sz, R = block.shape
    base = jnp.asarray(base, jnp.int32).reshape(())
    local = pos - base
    owned = (local >= 0) & (local < sz)
    li = jnp.clip(local, 0, sz - 1)
    nxt = jnp.where(owned, block[li, bits % R], 0)
    counts = jnp.zeros((sz + 1,), jnp.int32).at[
        jnp.where(owned, li, sz)
    ].add(stop.astype(jnp.int32))[:sz]
    return nxt.astype(jnp.int32), counts


def attention_ref(
    q: jnp.ndarray,                    # [B, Hq, Sq, D]
    k: jnp.ndarray,                    # [B, Hkv, Skv, D]
    v: jnp.ndarray,                    # [B, Hkv, Skv, D]
    causal: bool = True,
    window: Optional[int] = None,      # sliding-window size (None = full)
    q_offset: int = 0,                 # absolute position of q[…, 0, :] (decode)
    logit_soft_cap: Optional[float] = None,
) -> jnp.ndarray:
    """GQA scaled-dot-product attention oracle (f32 accumulation)."""
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    group = Hq // Hkv
    kx = jnp.repeat(k, group, axis=1)
    vx = jnp.repeat(v, group, axis=1)
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
    logits = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), kx.astype(jnp.float32)
    ) * scale
    if logit_soft_cap is not None:
        logits = logit_soft_cap * jnp.tanh(logits / logit_soft_cap)
    qpos = q_offset + jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    # fully-masked rows (can happen with tiny windows) → zeros, not NaN
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vx.astype(jnp.float32))
    return out.astype(q.dtype)


def attention_chunked(
    q: jnp.ndarray,                    # [B, Hq, Sq, D]
    k: jnp.ndarray,                    # [B, Hkv, Skv, D]
    v: jnp.ndarray,                    # [B, Hkv, Skv, D]
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    logit_soft_cap: Optional[float] = None,
    chunk: int = 512,
) -> jnp.ndarray:
    """Memory-bounded attention: lax.scan over query chunks, f32 online math.

    Peak live logits are [B, Hq, chunk, Skv] instead of [B, Hq, Sq, Skv] —
    this is the XLA-compilable path the 32k-prefill dry-runs lower (the
    Pallas flash kernel is the TPU-target twin of this computation). With a
    sliding ``window``, each chunk slices only the K/V band it can see
    (⌈(window+chunk)/chunk⌉ chunks), so SWA work is O(S·window), not O(S²) —
    what makes 500k-token contexts feasible for danube/gemma3.
    """
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    if Sq % chunk:
        # pad then strip (padding attends but is discarded)
        pad = chunk - Sq % chunk
        qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        out = attention_chunked(qp, k, v, causal, window, q_offset,
                                logit_soft_cap, chunk)
        return out[:, :, :Sq]
    group = Hq // Hkv
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
    n_chunks = Sq // chunk
    qc = q.reshape(B, Hq, n_chunks, chunk, D).transpose(2, 0, 1, 3, 4)

    banded = window is not None and causal
    if banded:
        # K/V band per chunk: positions [c*chunk + q_offset - window + 1,
        # c*chunk + q_offset + chunk). Width rounded to chunk multiple.
        band = ((window + chunk + chunk - 1) // chunk) * chunk
        band = min(band, Skv)

    def body(_, args):
        from repro.distributed.context import constrain

        ci, qi = args
        qi = constrain(qi, "bh")    # keep batch+heads sharded in the chunk scan
        q0 = ci * chunk + q_offset                       # absolute q start
        if banded:
            start = jnp.clip(q0 - window + 1, 0, Skv - band)
            kc = jax.lax.dynamic_slice(k, (0, 0, start, 0), (B, Hkv, band, D))
            vc = jax.lax.dynamic_slice(v, (0, 0, start, 0), (B, Hkv, band, D))
            kpos = start + jnp.arange(band)[None, :]
        else:
            kc, vc = k, v
            kpos = jnp.arange(Skv)[None, :]
        kx = jnp.repeat(kc, group, axis=1)
        vx = jnp.repeat(vc, group, axis=1)
        logits = jnp.einsum(
            "bhqd,bhkd->bhqk", qi.astype(jnp.float32), kx.astype(jnp.float32)
        ) * scale
        if logit_soft_cap is not None:
            logits = logit_soft_cap * jnp.tanh(logits / logit_soft_cap)
        qpos = q0 + jnp.arange(chunk)[:, None]
        mask = jnp.ones((chunk, kpos.shape[1]), dtype=bool)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        logits = constrain(
            jnp.where(mask[None, None], logits, -jnp.inf), "bh")
        probs = jax.nn.softmax(logits, axis=-1)
        probs = constrain(jnp.where(jnp.isnan(probs), 0.0, probs), "bh")
        o = jnp.einsum("bhqk,bhkd->bhqd", probs, vx.astype(jnp.float32))
        return None, constrain(o.astype(q.dtype), "bh")

    _, outs = jax.lax.scan(body, None, (jnp.arange(n_chunks), qc))
    return outs.transpose(1, 2, 0, 3, 4).reshape(B, Hq, Sq, D)


def decode_attention_ref(
    q: jnp.ndarray,                    # [B, Hq, 1, D]
    k_cache: jnp.ndarray,              # [B, Hkv, S, D]
    v_cache: jnp.ndarray,              # [B, Hkv, S, D]
    length: jnp.ndarray,               # int32[] — valid cache prefix
    window: Optional[int] = None,
    logit_soft_cap: Optional[float] = None,
) -> jnp.ndarray:
    """Single-token decode attention oracle (full-cache, length-masked)."""
    B, Hq, _, D = q.shape
    _, Hkv, S, _ = k_cache.shape
    group = Hq // Hkv
    kx = jnp.repeat(k_cache, group, axis=1)
    vx = jnp.repeat(v_cache, group, axis=1)
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
    logits = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), kx.astype(jnp.float32)
    ) * scale
    if logit_soft_cap is not None:
        logits = logit_soft_cap * jnp.tanh(logits / logit_soft_cap)
    pos = jnp.arange(S)[None, None, None, :]
    mask = pos < length
    if window is not None:
        mask &= pos >= length - window
    logits = jnp.where(mask, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vx.astype(jnp.float32))
    return out.astype(q.dtype)
