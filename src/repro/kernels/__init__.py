"""Pallas TPU kernels for the perf-critical compute layers.

* ``spmv_ell``        — hybrid-ELL SpMV (power-iteration / engine hot loop)
* ``frog_scatter``    — frog-count histogram (scatter-add, TPU-restructured)
* ``frog_step``       — fused plain walker superstep (gather deg → draw slot
                        → gather successor → tally deaths, one VMEM pass)
* ``flash_attention`` — causal GQA flash attention (+ sliding window)

Each has a jitted wrapper in ``ops.py`` and a pure-jnp oracle in ``ref.py``;
tests sweep shapes/dtypes and assert allclose in interpret mode. Pallas is
the TPU *target*: on this CPU container kernels execute via interpret=True.
See README.md for the step-cost model and dispatch flags.
"""
from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.frog_scatter import frog_count
from repro.kernels.frog_step import frog_step
from repro.kernels.spmv_ell import spmv_ell_slab

__all__ = ["ops", "ref", "flash_attention", "frog_count", "frog_step",
           "spmv_ell_slab"]
