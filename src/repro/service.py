"""Unified service facade: one front door for batch, index, and serving.

FrogWild is an *anytime* estimator — every extra wave of walks tightens the
Theorem-1 bound — but the repo historically exposed it through four
divergent entry points (``frogwild_run``, ``distributed_frogwild``,
``build_walk_index{,_sharded}``, ``QueryScheduler.submit/run``) with three
overlapping config dataclasses. This module is the redesigned surface:

* :class:`FrogWildService` — ``open(graph_or_path, config)`` owns graph
  ingestion (a :class:`~repro.graph.csr.CSRGraph` or a ``save_graph``
  ``.npz`` path), :class:`~repro.distributed.runtime.ShardRuntime`
  acquisition, and the walk-index lifecycle (build / load / reuse through
  ``checkpoint/`` when ``RuntimeConfig.serving.checkpoint_dir`` is set).
  ``pagerank(eps, delta)`` is the batch estimator, dispatching the
  single-device walker oracle or the mesh engine automatically; ``topk``
  and ``ppr`` return :class:`QueryHandle` futures served by the
  continuous-batching scheduler (admission, EDF allocation, and downgrade
  semantics unchanged underneath).

* :class:`QueryHandle` — a future with ``poll()`` / ``partial()`` /
  ``result()`` / ``cancel()``. Each ``partial()`` snapshot carries the ε
  Theorem 1 certifies for the walks tallied *so far* — monotonically
  tightening wave over wave (FAST-PPR's per-query confidence, PowerWalk's
  index-then-serve decomposition) — and with ``early_stop`` (the default)
  the query completes as soon as the requested ``(ε, δ)`` bound is met,
  even if its walk budget is not drained.

* :func:`batch_pagerank` / :func:`build_index` — the canonical module-level
  dispatchers the legacy entry points now delegate through (they emit
  ``DeprecationWarning`` and return byte-identical results).

Config is the layered :class:`~repro.config.RuntimeConfig` (kernel +
runtime + serving sub-configs — see ``repro/config.py``); the legacy
dataclasses are accepted everywhere a shim needs them.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import List, Optional, Union

import jax

from repro.checkpoint import CheckpointCorruptError
from repro.config import (EngineConfig, FrogWildConfig, KernelConfig,
                          RuntimeConfig, ServingConfig, ShardConfig,
                          WalkIndexConfig)
from repro.core.frogwild import (FrogWildResult, _as_tuple,
                                 _frogwild_walks)
from repro.distributed.faults import FaultInjector, WaveFailedError
from repro.distributed.runtime import ShardRuntime
from repro.engine import gas as _gas
from repro.graph.csr import CSRGraph, load_graph
from repro.query import index as _qindex
from repro.query.engine import plan_query
from repro.query.index import ShardedWalkIndex, WalkIndex
from repro.query.scheduler import (QueryPartial, QueryRequest, QueryResult,
                                   QueryScheduler, SchedulerStats)

__all__ = [
    "FrogWildService",
    "JoinedQueryHandle",
    "QueryHandle",
    "QueryPartial",
    "RuntimeConfig",
    "KernelConfig",
    "ShardConfig",
    "ServingConfig",
    "batch_pagerank",
    "build_index",
]


# ---------------------------------------------------------------------------
# canonical module-level dispatchers (the legacy shims delegate through these)
# ---------------------------------------------------------------------------


def _as_runtime_config(config) -> RuntimeConfig:
    if isinstance(config, RuntimeConfig):
        return config
    if isinstance(config, FrogWildConfig):
        return RuntimeConfig.from_frogwild(config)
    if isinstance(config, EngineConfig):
        return RuntimeConfig.from_engine(config)
    if isinstance(config, WalkIndexConfig):
        return RuntimeConfig.from_walk_index(config)
    raise TypeError(f"unsupported config type {type(config).__name__}")


def batch_pagerank(
    graph: Union[CSRGraph, "_gas.DistributedGraph"],
    config: Union[RuntimeConfig, FrogWildConfig, EngineConfig],
    *,
    key: Optional[jax.Array] = None,
    seed: Optional[int] = None,
    mesh=None,
):
    """One batch FrogWild run — the single dispatch point under both the
    service's :meth:`FrogWildService.pagerank` and the legacy
    ``frogwild_run`` / ``distributed_frogwild`` shims.

    A mesh (or a prebuilt :class:`~repro.engine.gas.DistributedGraph`)
    routes to the distributed engine (seeded by ``seed``); otherwise the
    single-device walker oracle runs with ``key`` (or ``PRNGKey(seed)``).
    """
    if isinstance(graph, _gas.DistributedGraph):
        if mesh is None:
            raise ValueError("a DistributedGraph run needs mesh=")
        cfg = (config.engine() if isinstance(config, RuntimeConfig)
               else config)
        return _gas._distributed_frogwild(graph, cfg, mesh,
                                          seed=0 if seed is None else seed)
    if mesh is not None:
        rc = _as_runtime_config(config)
        rt = ShardRuntime.for_mesh(mesh, rc.runtime.axis_name)
        dg = _gas.build_distributed_graph(
            graph, rt.num_shards, vertex_block=rc.runtime.vertex_block)
        return _gas._distributed_frogwild(dg, rc.engine(), mesh,
                                          seed=0 if seed is None else seed)
    cfg = config.frogwild() if isinstance(config, RuntimeConfig) else config
    if key is None:
        key = jax.random.PRNGKey(0 if seed is None else seed)
    return _frogwild_walks(graph, cfg, key)


def build_index(
    graph: CSRGraph,
    config: Union[RuntimeConfig, WalkIndexConfig],
    *,
    key: Optional[jax.Array] = None,
    mesh=None,
    directory: Optional[str] = None,
    axis_name: str = "vertex",
    step: int = 0,
    reassemble: bool = True,
) -> Union[WalkIndex, ShardedWalkIndex]:
    """One walk-index build — the single dispatch point under the service's
    index lifecycle and the legacy ``build_walk_index{,_sharded}`` shims.

    With a mesh the build runs as one ``shard_map`` (each device
    materializes only its slab block); otherwise the host shard loop. With
    ``directory`` the result is persisted through ``checkpoint/``.
    """
    cfg = (config.walk_index() if isinstance(config, RuntimeConfig)
           else config)
    if mesh is not None:
        return _qindex._build_walk_index_sharded(
            graph, cfg, mesh, directory=directory, key=key,
            axis_name=axis_name, step=step, reassemble=reassemble)
    idx = _qindex._build_walk_index(graph, cfg, key)
    if directory is not None:
        _qindex.save_walk_index(directory, idx, step=step)
    return idx


# ---------------------------------------------------------------------------
# the async query surface
# ---------------------------------------------------------------------------


class QueryHandle:
    """Future for one submitted query, with anytime (ε, δ) refinement.

    * ``poll()``    — advance the service by at most one wave; True when done.
    * ``partial()`` — snapshot of the current estimate; its
      ``epsilon_bound`` (the ε certified for the walks tallied so far)
      tightens monotonically wave over wave.
    * ``result()``  — drive waves until this query completes.
    * ``cancel()``  — drop it from the queue / its slot.

    Handles are cooperative: any handle's ``poll()`` / ``result()``
    advances the shared scheduler, so all in-flight queries make progress
    together (continuous batching).

    A handle pins the scheduler — and therefore the graph epoch and slab
    — it was admitted on: a mutation commit (``apply_mutations``) swaps
    the service's current scheduler for the new epoch's, but this handle
    keeps finishing on its own, byte-identical to a run where no mutation
    ever happened (the two-epoch serving contract).
    """

    def __init__(self, service: "FrogWildService", request: QueryRequest,
                 decision, scheduler: Optional[QueryScheduler] = None):
        self._service = service
        self._sched = (scheduler if scheduler is not None
                       else service.scheduler)
        self.request = request
        self.decision = decision

    @property
    def rid(self) -> int:
        return self.request.rid

    @property
    def admitted(self) -> bool:
        return bool(self.decision.admitted)

    def status(self) -> str:
        """``rejected`` | ``queued`` | ``active`` | ``finished`` |
        ``cancelled``."""
        if not self.admitted:
            return "rejected"
        if self._service.closed:
            # close() cancels in-flight work; a handle outliving its
            # service reports that instead of resurrecting a scheduler.
            return "cancelled"
        return self._sched.query_state(self.rid)

    def done(self) -> bool:
        return self.status() in ("finished", "cancelled", "rejected")

    def poll(self) -> bool:
        """Advances the service by one wave unless already done."""
        if not self.done():
            self._service.step()
        return self.done()

    def partial(self) -> QueryPartial:
        """Current anytime snapshot (no waves are driven)."""
        st = self.status()
        if st in ("rejected", "cancelled"):
            raise RuntimeError(
                f"query {self.rid} is {st}"
                + (f": {self.decision.reason}" if st == "rejected" else ""))
        return self._sched.partial(self.rid)

    def result(self, max_waves: Optional[int] = None) -> QueryResult:
        """Drives waves until this query finishes and returns its result."""
        if not self.admitted:
            raise RuntimeError(
                f"query {self.rid} rejected at admission: "
                f"{self.decision.reason}")
        waves = 0
        while True:
            st = self.status()
            if st == "finished":
                return self._sched.result_for(self.rid)
            if st == "cancelled":
                raise RuntimeError(f"query {self.rid} was cancelled")
            if st == "rejected":
                # shard loss can shrink capacity after admission: the
                # re-admission pass moves infeasible queued work here.
                reason = next(
                    (d.reason for d in self._sched.rejected
                     if d.rid == self.rid), "")
                raise RuntimeError(
                    f"query {self.rid} rejected after admission: {reason}")
            if max_waves is not None and waves >= max_waves:
                raise TimeoutError(
                    f"query {self.rid} still {st} after {waves} waves")
            if not self._service.step():
                raise RuntimeError(
                    f"scheduler idle but query {self.rid} is {st}")
            waves += 1

    def cancel(self) -> bool:
        """Drops the query; False when it already finished (or never ran)."""
        if not self.admitted or self._service.closed:
            return False
        return self._sched.cancel(self.rid)

    def join(self, epsilon: Optional[float] = None,
             delta: Optional[float] = None) -> "JoinedQueryHandle":
        """Attaches a duplicate request to this live handle (in-flight
        dedup — the gateway's join hook).

        Valid only when this handle's target **dominates** the joiner's —
        ``self.ε ≤ ε`` and ``self.δ ≤ δ`` — because then Theorem 1
        guarantees the walks already being executed certify the joiner's
        weaker bound no later than this handle's own. The joined handle
        executes zero walks of its own: it is fed this handle's monotone
        ``partial()`` snapshots and completes the wave *its* (ε, δ) is
        certified — at the latest, the wave this handle finishes.
        """
        eps = self.request.epsilon if epsilon is None else epsilon
        dlt = self.request.delta if delta is None else delta
        if self.request.epsilon > eps or self.request.delta > dlt:
            raise ValueError(
                f"cannot join query {self.rid}: its target "
                f"(ε={self.request.epsilon}, δ={self.request.delta}) does "
                f"not dominate the joiner's (ε={eps}, δ={dlt}) — submit a "
                f"fresh query instead")
        if not self.admitted:
            raise RuntimeError(
                f"cannot join rejected query {self.rid}: "
                f"{self.decision.reason}")
        return JoinedQueryHandle(self, eps, dlt)


class JoinedQueryHandle:
    """A duplicate request riding a live :class:`QueryHandle`.

    Created by :meth:`QueryHandle.join` — the parent's (ε, δ) target must
    dominate this one's. No walks are executed on its behalf: ``poll()`` /
    ``result()`` drive the parent's service, ``partial()`` is the parent's
    snapshot, and the join settles the wave its own (ε, δ) is certified by
    the walks tallied so far. With a target identical to the parent's, the
    settled result *is* the parent's :class:`~repro.query.scheduler.
    QueryResult` object — byte-identical, provenance included.
    """

    def __init__(self, parent: QueryHandle, epsilon: float, delta: float):
        self.parent = parent
        self.epsilon = epsilon
        self.delta = delta
        self._result: Optional[QueryResult] = None
        self._t_join = time.perf_counter()

    @property
    def rid(self) -> int:
        return self.parent.rid

    @property
    def admitted(self) -> bool:
        return self.parent.admitted

    def done(self) -> bool:
        """True when settled — or **terminal**: a parent that was
        cancelled or late-rejected mid-wave can never certify this join,
        so the joiner reports done instead of polling forever (its
        ``result()`` then raises the classified error)."""
        if self._result is not None or self._settle():
            return True
        return self.parent.status() in ("cancelled", "rejected")

    def poll(self) -> bool:
        """Advances the parent's service by one wave unless already done."""
        if not self.done():
            self.parent._service.step()
        return self.done()

    def partial(self) -> QueryPartial:
        """The parent's anytime snapshot (shared tallies)."""
        return self.parent.partial()

    def _settle(self) -> bool:
        """Settles the joined result once certifiable; False until then."""
        parent = self.parent
        st = parent.status()
        if st == "finished":
            # the parent's certificate was issued at (ε_p ≤ ε, δ_p ≤ δ), so
            # it dominates the joiner's target: hand back the parent's
            # result object itself — byte-identical by construction.
            self._result = parent._sched.result_for(parent.rid)
            return True
        if st != "active":
            return False             # queued: no walks yet; cancelled /
                                     # rejected: surfaced by result()
        if (self.epsilon, self.delta) == (parent.request.epsilon,
                                          parent.request.delta):
            return False             # identical target: settle with parent
        sched = parent._sched
        p = sched.partial(self.rid)
        if not p.walks_done:
            return False
        bound = sched.anytime_bound(parent.decision.plan.num_steps,
                                    parent.request.k, self.delta,
                                    p.walks_done)
        if bound > self.epsilon:
            return False
        # the weaker bound is certified mid-flight: freeze this wave's
        # snapshot as the joined result while the parent keeps refining.
        self._result = QueryResult(
            rid=p.rid, kind=p.kind, vertices=p.vertices, scores=p.scores,
            num_walks=p.walks_done,
            num_steps=parent.decision.plan.num_steps, waves=p.waves,
            latency_s=time.perf_counter() - self._t_join,
            epsilon_bound=bound, early_stopped=True, degraded=p.degraded,
            shards_lost=p.shards_lost, walks_lost=p.walks_lost,
            epoch=sched.epoch)
        return True

    def result(self, max_waves: Optional[int] = None) -> QueryResult:
        """Drives waves until this join's (ε, δ) is certified.

        A parent cancelled / late-rejected before certification surfaces
        as a classified :class:`~repro.distributed.faults.WaveFailedError`
        (the gateway's failover migrates joiners *before* cancelling a
        parent, so through the tier this only fires when the caller
        cancels a parent that still has joiners riding it).
        """
        waves = 0
        while True:
            if self.done():
                if self._result is None:
                    st = self.parent.status()
                    raise WaveFailedError(
                        f"joined query {self.rid}: parent handle is {st} "
                        f"before this join's (ε={self.epsilon}, "
                        f"δ={self.delta}) was certified — resubmit")
                return self._result
            st = self.parent.status()
            if max_waves is not None and waves >= max_waves:
                raise TimeoutError(
                    f"joined query {self.rid} still {st} after "
                    f"{waves} waves")
            if not self.parent._service.step():
                raise RuntimeError(
                    f"scheduler idle but joined query {self.rid} is {st}")
            waves += 1


# ---------------------------------------------------------------------------
# the facade
# ---------------------------------------------------------------------------


class FrogWildService:
    """The one front door: batch PageRank, walk-index lifecycle, and async
    top-k / PPR serving over a single graph.

    Build one with :meth:`open`; everything else (runtime acquisition,
    index build-or-load, scheduler construction) is lazy and owned by the
    service.
    """

    def __init__(self, graph: CSRGraph, config: RuntimeConfig, *,
                 mesh=None, index=None):
        self.graph = graph
        self.config = config
        self._mesh = mesh
        if mesh is not None:
            self.runtime = ShardRuntime.for_mesh(mesh,
                                                 config.runtime.axis_name)
        elif config.runtime.num_shards > 1:
            self.runtime = ShardRuntime.acquire(config.runtime.num_shards,
                                                config.runtime.axis_name)
        else:
            self.runtime = None
        self._index = index
        self._scheduler: Optional[QueryScheduler] = None
        # retired epochs' schedulers, kept alive until their last pinned
        # query settles (two-epoch serving — see commit_epoch / step).
        self._retiring: List[QueryScheduler] = []
        self._dg = None                  # cached DistributedGraph
        self._dg_key = None
        self._next_rid = 0
        self._closed = False
        # one injector per service: the scheduler consults it per
        # (wave, attempt), and the index loader lets it mangle on-disk
        # checkpoint payloads before the first read (crash-injection).
        self._injector = (FaultInjector(config.faults)
                          if config.faults is not None else None)

    # --- lifecycle -------------------------------------------------------

    @classmethod
    def open(
        cls,
        graph_or_path: Union[CSRGraph, str, os.PathLike],
        config: Optional[RuntimeConfig] = None,
        *,
        mesh=None,
        index: Union[WalkIndex, ShardedWalkIndex, None] = None,
    ) -> "FrogWildService":
        """Opens a service over a graph (or a ``save_graph`` ``.npz`` path).

        ``mesh`` routes batch runs through the distributed engine and (when
        its shard count matches ``config.runtime.num_shards``) sharded
        serving through one ``shard_map``; ``index`` short-circuits the
        index lifecycle with a prebuilt slab.
        """
        if config is None:
            config = RuntimeConfig()
        elif not isinstance(config, RuntimeConfig):
            config = _as_runtime_config(config)
        if isinstance(graph_or_path, (str, os.PathLike)):
            graph = load_graph(os.fspath(graph_or_path))
        elif isinstance(graph_or_path, CSRGraph):
            graph = graph_or_path
        else:
            raise TypeError(
                f"graph_or_path must be a CSRGraph or a path, got "
                f"{type(graph_or_path).__name__}")
        return cls(graph, config, mesh=mesh, index=index)

    @property
    def closed(self) -> bool:
        """True once :meth:`close` ran; a closed service refuses new work."""
        return self._closed

    def close(self) -> None:
        """Tears the service down — idempotent and safe under pool teardown.

        Replicas in a :class:`~repro.gateway.ReplicaPool` share the graph
        and walk-index arrays but each own their scheduler, so close only
        touches per-service state: queued and in-flight queries are
        cancelled (their :class:`QueryHandle`\\ s report ``cancelled``
        afterwards, never an exception), the scheduler / index / graph
        caches are dropped, and every later call — including another
        ``close()`` — is a no-op. Submitting new work on a closed service
        raises ``RuntimeError``.
        """
        if self._closed:
            return
        for sched in [self._scheduler] + self._retiring:
            if sched is not None:
                for rid in ([e.req.rid for e in sched.queue]
                            + [a.req.rid for a in sched.active.values()]):
                    sched.cancel(rid)
        self._retiring = []
        self._scheduler = None
        self._index = None
        self._dg = None
        self._dg_key = None
        self._closed = True

    def _check_open(self) -> None:
        if self._closed:
            raise RuntimeError(
                "FrogWildService is closed — open a new service (or a new "
                "gateway replica) to submit more work")

    def __enter__(self) -> "FrogWildService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # --- walk-index lifecycle -------------------------------------------

    def ensure_index(self) -> Union[WalkIndex, ShardedWalkIndex]:
        """Build / load / reuse the walk index (idempotent).

        With ``serving.checkpoint_dir`` set, an existing on-disk index is
        loaded (and validated against the configured geometry); otherwise
        the index is built — as one ``shard_map`` when the service owns a
        multi-device mesh matching ``runtime.num_shards``, else via the
        host shard loop — and persisted to the checkpoint dir if given.
        The slab is served sharded (never reassembled) whenever
        ``runtime.num_shards > 1``.
        """
        self._check_open()
        if self._index is None:
            self._index = self._load_or_build_index()
        S = self.config.runtime.num_shards
        if S > 1:
            # runtime.num_shards declares the serving layout: a dense slab
            # (built, loaded, or passed in) is range-partitioned here, and
            # a sharded one laid out for a different shard count (e.g. a
            # checkpoint from a differently-configured run) is re-split —
            # never silently served at the checkpoint's layout.
            if isinstance(self._index, WalkIndex):
                self._index = _qindex.shard_walk_index(self._index, S)
            elif self._index.num_shards != S:
                self._index = _qindex.shard_walk_index(
                    self._index.reassemble(), S)
        return self._index

    def _load_or_build_index(self) -> Union[WalkIndex, ShardedWalkIndex]:
        icfg = self.config.walk_index()
        S = self.config.runtime.num_shards
        directory = self.config.serving.checkpoint_dir
        if directory is not None:
            if self._injector is not None:
                # crash-injection hook: mangle on-disk payloads *before*
                # the first read so the repair path below is what serves.
                self._injector.mangle_checkpoints(directory)
            try:
                # self-healing load: corrupt / torn / missing shards of a
                # per-shard layout are quarantined and rebuilt in place
                # with the original build's key stream.
                idx = _qindex.load_or_repair_walk_index(
                    directory, self.graph, icfg, reassemble=(S <= 1))
            except FileNotFoundError:
                idx = None
            except CheckpointCorruptError:
                # monolithic (dense) layout: no sub-unit to repair —
                # rebuild the whole index below (the atomic save replaces
                # the corrupt step dir).
                idx = None
            if idx is not None:
                if (idx.segments_per_vertex != icfg.segments_per_vertex
                        or idx.segment_len != icfg.segment_len):
                    raise ValueError(
                        f"walk index under {directory!r} has (R, L) = "
                        f"({idx.segments_per_vertex}, {idx.segment_len}) "
                        f"but the config wants "
                        f"({icfg.segments_per_vertex}, {icfg.segment_len});"
                        f" rebuild or point checkpoint_dir elsewhere")
                if int(getattr(idx, "graph_epoch", 0)) != int(
                        getattr(self.graph, "epoch", 0)):
                    raise ValueError(
                        f"walk index under {directory!r} was built at "
                        f"graph epoch {idx.graph_epoch} but the service "
                        f"graph is at epoch "
                        f"{int(getattr(self.graph, 'epoch', 0))} — a stale "
                        f"slab would serve wrong answers silently; refresh "
                        f"it (repro.dynamic.refresh_walk_index / "
                        f"load_epoch_index) or rebuild")
                return idx
        if (S > 1 and self.runtime is not None and self.runtime.is_mesh
                and self.runtime.num_shards == S):
            return build_index(
                self.graph, icfg, mesh=self.runtime.mesh,
                axis_name=self.config.runtime.axis_name,
                directory=directory, reassemble=False)
        return build_index(self.graph, icfg, directory=directory)

    # --- batch -----------------------------------------------------------

    def pagerank(
        self,
        epsilon: Optional[float] = None,
        delta: float = 0.1,
        k: int = 10,
        *,
        key: Optional[jax.Array] = None,
        seed: Optional[int] = None,
        config: Optional[RuntimeConfig] = None,
    ):
        """One batch FrogWild estimate of the full PageRank vector.

        With ``epsilon`` given, Theorem 1 is inverted into ``(t, N)`` for a
        ``μ_k`` guarantee at confidence ``1 − delta`` (plans at p_s = 1);
        otherwise the config's ``num_frogs`` / ``num_steps`` run as-is.
        Dispatch is automatic: a service opened with a mesh runs the
        distributed engine (returns :class:`~repro.engine.gas.
        EngineResult`), else the single-device walker oracle (returns
        :class:`~repro.core.frogwild.FrogWildResult`).
        """
        self._check_open()
        rc = config if config is not None else self.config
        if epsilon is not None:
            plan = plan_query(k, epsilon, delta, p_T=rc.p_T,
                              max_steps=rc.serving.max_steps)
            rc = dataclasses.replace(rc, num_frogs=plan.num_walks,
                                     num_steps=plan.num_steps)
        if self._mesh is not None:
            return batch_pagerank(
                self._dgraph(rc), rc.engine(), mesh=self._mesh,
                seed=rc.runtime.seed if seed is None else seed)
        cfg = rc.frogwild()
        if key is None:
            key = jax.random.PRNGKey(rc.runtime.seed if seed is None
                                     else seed)
        run = jax.jit(
            lambda kk: _as_tuple(_frogwild_walks(self.graph, cfg, kk)))
        counts, pi_hat = run(key)
        return FrogWildResult(counts=counts, pi_hat=pi_hat,
                              num_frogs=cfg.num_frogs)

    def _dgraph(self, rc: RuntimeConfig) -> "_gas.DistributedGraph":
        """Per-shard CSR blocks for the engine path (cached per shape)."""
        shape = (self.runtime.num_shards, rc.runtime.vertex_block)
        if self._dg is None or self._dg_key != shape:
            self._dg = _gas.build_distributed_graph(
                self.graph, shape[0], vertex_block=shape[1])
            self._dg_key = shape
        return self._dg

    # --- serving ---------------------------------------------------------

    @property
    def scheduler(self) -> QueryScheduler:
        """The (lazily built) continuous-batching scheduler."""
        self._check_open()
        if self._scheduler is None:
            index = self.ensure_index()
            scfg = self.config.serving
            runtime = None
            if (isinstance(index, ShardedWalkIndex)
                    and self.runtime is not None
                    and self.runtime.num_shards == index.num_shards):
                runtime = self.runtime
            self._scheduler = QueryScheduler(
                self.graph, index,
                max_walks=scfg.max_walks, max_queries=scfg.max_queries,
                max_steps=scfg.max_steps, p_T=self.config.p_T,
                impl=self.config.kernel.stitch_impl,
                tally_impl=self.config.kernel.tally_impl,
                seed=self.config.runtime.seed, runtime=runtime,
                wave_time_estimate_s=scfg.wave_time_estimate_s,
                fault_injector=self._injector,
                wave_timeout_s=scfg.wave_timeout_s,
                max_retries=scfg.max_retries,
                backoff_base_s=scfg.backoff_base_s,
                backoff_max_s=scfg.backoff_max_s,
                sharded_dispatch=scfg.sharded_dispatch,
                donate_wave_buffers=scfg.donate_wave_buffers,
                walk_buckets=scfg.walk_buckets,
                query_buckets=scfg.query_buckets,
                aot_warmup=scfg.aot_warmup)
        return self._scheduler

    @property
    def lost_shards(self) -> frozenset:
        """Shards evicted from serving so far (empty before any fault)."""
        if self._scheduler is None:
            return frozenset()
        return frozenset(self._scheduler.lost_shards)

    def serving_stats(self) -> Optional[SchedulerStats]:
        """The scheduler's admission-accounting snapshot — ``None`` until
        the first query forces the scheduler into existence (a replica
        that has never served is, by definition, unloaded). The gateway's
        replica router keys on ``backlog_walks``."""
        if self._closed or self._scheduler is None:
            return None
        return self._scheduler.stats()

    @property
    def fault_log(self) -> list:
        """The wave supervisor's fault provenance log (chronological
        :class:`~repro.distributed.faults.FaultEvent` entries)."""
        if self._scheduler is None:
            return []
        return list(self._scheduler.fault_log)

    def topk(
        self,
        k: int = 10,
        epsilon: float = 0.3,
        delta: float = 0.1,
        *,
        num_walks: Optional[int] = None,
        slo_s: Optional[float] = None,
        allow_downgrade: bool = False,
        early_stop: bool = True,
    ) -> QueryHandle:
        """Submits a global top-k query; returns its :class:`QueryHandle`.

        ``num_walks`` overrides the Theorem-1 walk budget (a larger budget
        plus ``early_stop`` gives pure anytime behaviour: the query runs
        until the requested ε is certified, then stops). ``slo_s`` engages
        deadline-aware admission exactly as before.
        """
        return self._submit_request(
            kind="topk", k=k, source=0, epsilon=epsilon, delta=delta,
            num_walks=num_walks, slo_s=slo_s,
            allow_downgrade=allow_downgrade, early_stop=early_stop)

    def ppr(
        self,
        source: int,
        k: int = 10,
        epsilon: float = 0.3,
        delta: float = 0.1,
        *,
        num_walks: Optional[int] = None,
        slo_s: Optional[float] = None,
        allow_downgrade: bool = False,
        early_stop: bool = True,
    ) -> QueryHandle:
        """Submits a personalized-PageRank query pinned at ``source``."""
        return self._submit_request(
            kind="ppr", k=k, source=source, epsilon=epsilon, delta=delta,
            num_walks=num_walks, slo_s=slo_s,
            allow_downgrade=allow_downgrade, early_stop=early_stop)

    def _submit_request(self, **kw) -> QueryHandle:
        req = QueryRequest(rid=self._next_rid, **kw)
        self._next_rid += 1
        sched = self.scheduler
        decision = sched._submit(req)
        # the handle pins the scheduler (and so the epoch/slab) it was
        # admitted on — an epoch commit never disturbs in-flight queries.
        return QueryHandle(self, req, decision, scheduler=sched)

    def resubmit(self, req: QueryRequest) -> QueryHandle:
        """Submits a fresh copy of ``req`` (new rid, new latency clock) —
        the gateway's failover hook: a query whose replica died mid-flight
        is replayed on a healthy replica with the *same plan parameters*.
        On a cold (or freshly restarted) replica the scheduler's key
        stream starts at wave 0, so the replayed answer is byte-identical
        to a fault-free run on a cold replica (asserted in the bench
        smoke)."""
        return self._submit_request(
            kind=req.kind, k=req.k, source=req.source, epsilon=req.epsilon,
            delta=req.delta, num_walks=req.num_walks, slo_s=req.slo_s,
            allow_downgrade=req.allow_downgrade, early_stop=req.early_stop)

    def step(self) -> bool:
        """Runs one device wave; False when nothing is in flight.

        Drives the current epoch's scheduler first, then any retiring
        epochs still carrying pinned queries; a retiring scheduler whose
        last pinned query has settled is released here (its handles keep
        their own references for ``result_for``).
        """
        progressed = self.scheduler.step_wave()
        for sched in list(self._retiring):
            if sched.queue or sched.active:
                progressed = sched.step_wave() or progressed
            if not sched.queue and not sched.active:
                self._retiring.remove(sched)
        return progressed

    def drain(self) -> List[QueryResult]:
        """Drives waves until queue + slots are empty; returns all results
        finished so far (in finish order)."""
        while self._retiring and self.step():
            pass
        return self.scheduler._drain()

    # --- dynamic graphs (epoch lifecycle) ---------------------------------

    @property
    def graph_epoch(self) -> int:
        """The mutation epoch new admissions land on."""
        return int(getattr(self.graph, "epoch", 0))

    @property
    def retiring_epochs(self) -> List[int]:
        """Epochs still draining pinned queries (oldest first)."""
        return [s.epoch for s in self._retiring]

    def commit_epoch(self, graph: CSRGraph, index) -> int:
        """Swaps serving to ``(graph, index)`` at the next epoch.

        The current scheduler — if it still carries queued or active
        queries — moves to the retiring list and keeps draining through
        :meth:`step`; its handles finish byte-identically to a
        never-mutated run (each scheduler owns its key stream, seeded
        identically). New admissions land on the new epoch immediately.
        Returns the committed epoch.
        """
        self._check_open()
        if graph.n != self.graph.n:
            raise ValueError(
                f"epoch commit cannot change the vertex count "
                f"({self.graph.n} → {graph.n})")
        if int(getattr(index, "graph_epoch", 0)) != int(graph.epoch):
            raise ValueError(
                f"slab epoch {getattr(index, 'graph_epoch', 0)} does not "
                f"match graph epoch {graph.epoch} — refusing a mismatched "
                f"commit")
        icfg = self.config.walk_index()
        if (index.segments_per_vertex != icfg.segments_per_vertex
                or index.segment_len != icfg.segment_len):
            raise ValueError(
                f"slab geometry (R, L) = ({index.segments_per_vertex}, "
                f"{index.segment_len}) does not match the service config "
                f"({icfg.segments_per_vertex}, {icfg.segment_len})")
        old = self._scheduler
        if old is not None and (old.queue or old.active):
            self._retiring.append(old)
        self._scheduler = None
        self.graph = graph
        self._index = index
        self._dg = None
        self._dg_key = None
        return int(graph.epoch)

    def apply_mutations(self, batch, *, chunk: int = 1024):
        """Applies one mutation batch end-to-end: compact the CSR at
        ``epoch + 1``, incrementally refresh exactly the invalidated walk
        segments, persist the new slab under its epoch directory (when a
        checkpoint dir is configured), and commit the two-epoch swap.
        Returns the :class:`repro.dynamic.RefreshReport`.
        """
        from repro.dynamic import (apply_mutations as _apply,
                                   refresh_walk_index, save_epoch_index)

        self._check_open()
        index = self.ensure_index()
        new_graph, changed = _apply(self.graph, batch)
        new_index, report = refresh_walk_index(
            index, new_graph, changed,
            step_impl=self.config.walk_index().step_impl, chunk=chunk)
        directory = self.config.serving.checkpoint_dir
        if directory is not None:
            save_epoch_index(directory, new_index)
        self.commit_epoch(new_graph, new_index)
        return report
