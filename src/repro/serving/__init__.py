"""Serving substrate: prefill, decode (serve_step), request scheduler."""
from repro.serving.prefill import prefill
from repro.serving.decode import sample_token, serve_step
from repro.serving.scheduler import BatchScheduler, Request

__all__ = ["prefill", "serve_step", "sample_token", "BatchScheduler", "Request"]
