"""Minimal continuous-batching request scheduler (host-side).

Fixed-slot batching: ``max_batch`` sequence slots, each either free or
running one request. New requests prefill into a free slot; finished
sequences (EOS or budget) free theirs. The device program (serve_step) is a
fixed shape — scheduling is pure host logic, so this composes with the
sharded decode path unchanged. This is the serving loop used by
examples/serve_lm.py.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.transformer import init_decode_state
from repro.serving.decode import serve_step
from repro.serving.prefill import prefill


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int = 32
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class BatchScheduler:
    """Single-host reference implementation (per-slot prefill)."""

    def __init__(self, params, cfg: ModelConfig, max_batch: int = 4,
                 max_len: int = 512, eos_id: int = 1):
        self.params = params
        self.cfg = cfg
        self.max_batch = max_batch
        self.max_len = max_len
        self.eos_id = eos_id
        self.queue: List[Request] = []
        self.finished: List[Request] = []

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def run(self) -> List[Request]:
        """Drains the queue in batches of ``max_batch`` (simple generational
        batching: one generation wave per batch)."""
        while self.queue:
            wave = [self.queue.pop(0) for _ in
                    range(min(self.max_batch, len(self.queue)))]
            self._run_wave(wave)
            self.finished.extend(wave)
        return self.finished

    def _run_wave(self, wave: List[Request]) -> None:
        B = len(wave)
        maxp = max(len(r.prompt) for r in wave)
        toks = np.full((B, maxp), self.eos_id, np.int32)
        for i, r in enumerate(wave):
            toks[i, -len(r.prompt):] = r.prompt        # left-pad
        logits, state = prefill(self.params, self.cfg, jnp.asarray(toks),
                                self.max_len)
        cur = jnp.argmax(logits, -1).astype(jnp.int32)
        budget = max(r.max_new_tokens for r in wave)
        done = np.zeros(B, bool)
        key = jax.random.PRNGKey(0)
        for step in range(budget):
            for i, r in enumerate(wave):
                if not done[i]:
                    r.output.append(int(cur[i]))
                    if int(cur[i]) == self.eos_id or len(r.output) >= r.max_new_tokens:
                        done[i] = True
            if done.all():
                break
            cur, state = serve_step(self.params, state, cur, self.cfg,
                                    key=jax.random.fold_in(key, step))
        for r in wave:
            r.done = True
