"""Prefill: process the prompt, return last-token logits + a filled cache.

Implemented as token-by-token decode over a scan (cache-filling), which is
exact for every family (attention rings, SSM states, shared blocks) and
reuses the single decode_step program. A fused full-sequence prefill
(forward + bulk cache write) is the natural perf upgrade recorded in
EXPERIMENTS.md §Perf; the dry-run's ``prefill_32k`` cells lower the fused
full-sequence forward (forward_train), which is the compute-equivalent
program.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.transformer import DecodeState, decode_step, init_decode_state


def prefill(
    params,
    cfg: ModelConfig,
    tokens: jnp.ndarray,            # int32[B, S_prompt]
    max_len: int,
    encoder_frames: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, DecodeState]:
    """Returns (logits for the last prompt token [B, V], filled state)."""
    B, S = tokens.shape
    state = init_decode_state(params, cfg, B, max_len,
                              encoder_frames=encoder_frames)

    def step(st, tok):
        logits, st = decode_step(params, st, tok, cfg)
        return st, logits

    state, logits_all = jax.lax.scan(step, state, tokens.T)
    return logits_all[-1], state
