"""serve_step: the program the decode dry-run cells lower.

One new token for every sequence in the batch, against a KV cache /
SSM state of the configured context length. Sampling is greedy /
temperature / top-k, all in-graph.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.transformer import DecodeState, decode_step


def sample_token(
    logits: jnp.ndarray,            # [B, V]
    key: jax.Array,
    temperature: float = 0.0,
    top_k: int = 0,
) -> jnp.ndarray:
    if temperature <= 0.0:
        return logits.argmax(-1).astype(jnp.int32)
    lf = logits.astype(jnp.float32) / temperature
    if top_k > 0:
        vals, _ = jax.lax.top_k(lf, top_k)
        kth = vals[..., -1:]
        lf = jnp.where(lf < kth, -jnp.inf, lf)
    return jax.random.categorical(key, lf).astype(jnp.int32)


def serve_step(
    params,
    state: DecodeState,
    tokens: jnp.ndarray,            # int32[B] — last generated tokens
    cfg: ModelConfig,
    key: Optional[jax.Array] = None,
    temperature: float = 0.0,
    top_k: int = 0,
) -> Tuple[jnp.ndarray, DecodeState]:
    """Decode one token per sequence. Returns (next_tokens [B], new state)."""
    logits, state = decode_step(params, state, tokens, cfg)
    if key is None:
        key = jax.random.PRNGKey(0)
    nxt = sample_token(logits, key, temperature=temperature, top_k=top_k)
    return nxt, state
