"""Offline walk-segment index (the PowerWalk precompute, FrogWild flavour).

For every vertex ``v`` the index stores ``R`` independent endpoints of plain
(p_s = 1, no-death) random walks of exactly ``L`` steps started at ``v`` —
a dense ``int32[n, R]`` slab. Each stored endpoint is an exact sample of
the L-step transition kernel ``P^L(· | v)``, so the online engine can
replace L walker supersteps with one gather from row ``v``. Sizing note:
pick ``R ≥ t/L`` (stitches per walk) — the engine's slot rotation then
guarantees a walk never rereads a cell and its composed marginal is exact;
cell sharing across walks only adds variance (tests/test_query.py checks
the distribution statistically).

Build is sharded via ``graph/partition.py``: one fixed-shape jitted program
walks ``shard_size · R`` frogs for ``L`` steps, invoked once per range shard
(the shard loop is the host-side analogue of the engine's vertex sharding —
peak device memory is one shard's walk batch, not ``n · R``). The inner step
is a batched variant of the walker superstep and can run through the fused
Pallas kernels (``step_impl="pallas"`` for the VMEM-resident kernel,
``"stream"`` for the HBM-streaming sorted-frog kernel, ``"auto"`` to pick by
VMEM budget).

Two build drivers share that step:

* :func:`build_walk_index` — the host shard loop (single device);
* :func:`build_walk_index_sharded` — the same per-shard program as one
  ``shard_map`` over the engine's ``"vertex"`` mesh axis: every device
  materializes only its own ``[shard_size, R]`` slab block (the full slab is
  ``4nR`` bytes — the Twitter-scale memory hog), and per-shard blocks are
  persisted independently.

Persistence goes through ``checkpoint/`` (atomic step directories), so index
builds inherit the crash-safety and GC story of model checkpoints. A
sharded build writes one checkpoint dir per shard
(``<dir>/shard_<s>/step_<k>/`` via :func:`save_walk_index_shard`);
:func:`load_walk_index` detects the sharded layout and reassembles the
slab, so readers are agnostic to how the index was built.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.graph.csr import CSRGraph, uniform_successor
from repro.graph.partition import partition_graph


@dataclasses.dataclass(frozen=True)
class WalkIndexConfig:
    segments_per_vertex: int = 16     # R — endpoints stored per vertex
    segment_len: int = 4              # L — steps per precomputed segment
    num_shards: int = 8               # build sharding (graph/partition.py)
    step_impl: str = "xla"            # xla | pallas | stream | auto | ref
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class WalkIndex:
    """Dense per-vertex walk-segment endpoints.

    Attributes:
      endpoints:   int32[n, R] — ``endpoints[v, r] ~ P^L(· | v)`` i.i.d.
      segment_len: L, the number of steps each stored segment advanced.
      seed:        build seed (provenance; queries use their own keys).
    """

    endpoints: jnp.ndarray
    segment_len: int
    seed: int

    @property
    def n(self) -> int:
        return int(self.endpoints.shape[0])

    @property
    def segments_per_vertex(self) -> int:
        return int(self.endpoints.shape[1])


def _segment_step(row_ptr, col_idx, deg, n, step_impl, pos, key):
    """One no-death plain walker move for a batch of segment walks.

    The segment walk is the p_T = 0, p_s = 1 corner of the walker
    superstep: with ``step_impl != "xla"`` it routes through the fused
    Pallas kernels (resident or HBM-streaming — the death tally is all
    zeros and discarded).
    """
    bits = jax.random.randint(key, pos.shape, 0, 1 << 30, jnp.int32)
    if step_impl == "xla":
        return uniform_successor(row_ptr, col_idx, deg, pos, bits)
    from repro.kernels import ops

    nxt, _ = ops.frog_step(
        pos, jnp.zeros_like(pos), bits, row_ptr, col_idx, deg, n,
        impl=step_impl,
    )
    return nxt


@dataclasses.dataclass(frozen=True)
class _ShardWalker:
    """One fixed-shape compiled program reused for every shard's build."""

    row_ptr: jnp.ndarray
    col_idx: jnp.ndarray
    deg: jnp.ndarray
    n: int
    shard_size: int
    cfg: WalkIndexConfig

    def __call__(self, lo: jnp.ndarray, key: jax.Array) -> jnp.ndarray:
        R, L = self.cfg.segments_per_vertex, self.cfg.segment_len
        pos0 = lo + jnp.repeat(
            jnp.arange(self.shard_size, dtype=jnp.int32), R,
            total_repeat_length=self.shard_size * R,
        )

        def step(pos, k):
            nxt = _segment_step(self.row_ptr, self.col_idx, self.deg,
                                self.n, self.cfg.step_impl, pos, k)
            return nxt, None

        pos, _ = jax.lax.scan(step, pos0, jax.random.split(key, L))
        return pos.reshape(self.shard_size, R)


def build_walk_index(
    g: CSRGraph, cfg: WalkIndexConfig, key: Optional[jax.Array] = None
) -> WalkIndex:
    """Builds the ``int32[n, R]`` endpoint slab, one range shard at a time."""
    if cfg.segment_len < 1:
        raise ValueError(f"segment_len must be ≥ 1, got {cfg.segment_len}")
    if key is None:
        key = jax.random.PRNGKey(cfg.seed)
    gp, part = partition_graph(g, cfg.num_shards)
    walker = _ShardWalker(
        row_ptr=gp.row_ptr, col_idx=gp.col_idx, deg=gp.out_deg, n=gp.n,
        shard_size=part.shard_size, cfg=cfg,
    )
    run = jax.jit(walker.__call__)
    blocks = []
    for s in range(cfg.num_shards):
        lo, _ = part.bounds(s)
        blocks.append(np.asarray(run(jnp.int32(lo), jax.random.fold_in(key, s))))
    endpoints = np.concatenate(blocks, axis=0)[: g.n]
    return WalkIndex(
        endpoints=jnp.asarray(endpoints, dtype=jnp.int32),
        segment_len=cfg.segment_len,
        seed=cfg.seed,
    )


def build_walk_index_sharded(
    g: CSRGraph,
    cfg: WalkIndexConfig,
    mesh,
    directory: Optional[str] = None,
    key: Optional[jax.Array] = None,
    axis_name: str = "vertex",
    step: int = 0,
) -> WalkIndex:
    """Builds the slab as **one** ``shard_map`` program over ``mesh``.

    Each device walks its own range shard's ``shard_size · R`` segment
    frogs and materializes only its ``[shard_size, R]`` slab block
    (``out_specs=P(axis_name)`` — device memory holds ``4nR/S`` bytes of
    slab, the engine-mesh answer to the ROADMAP's "distributed index build
    + sharded slab" follow-up). The graph CSR is closed over (replicated);
    per-shard randomness is ``fold_in(key, shard)``, so a shard's block is
    reproducible independent of mesh shape.

    With ``directory`` set, every shard's block is persisted as its own
    atomic checkpoint (``save_walk_index_shard``) before the function
    returns; ``load_walk_index`` reassembles them.
    """
    if cfg.segment_len < 1:
        raise ValueError(f"segment_len must be ≥ 1, got {cfg.segment_len}")
    if key is None:
        key = jax.random.PRNGKey(cfg.seed)
    from jax.sharding import PartitionSpec as P

    S = mesh.devices.size
    gp, part = partition_graph(g, S)
    sz = part.shard_size
    R, L = cfg.segments_per_vertex, cfg.segment_len
    row_ptr, col_idx, deg = gp.row_ptr, gp.col_idx, gp.out_deg

    def body(key_data):
        me = jax.lax.axis_index(axis_name)
        k = jax.random.fold_in(
            jax.random.wrap_key_data(key_data, impl="threefry2x32"), me)
        pos0 = me * sz + jnp.repeat(
            jnp.arange(sz, dtype=jnp.int32), R, total_repeat_length=sz * R)

        def walk(pos, kk):
            return _segment_step(row_ptr, col_idx, deg, gp.n,
                                 cfg.step_impl, pos, kk), None

        pos, _ = jax.lax.scan(walk, pos0, jax.random.split(k, L))
        return pos.reshape(1, sz, R)

    # check_vma=False: jax has no replication rule for pallas_call, and the
    # fused step backends lower through one (the body is trivially
    # per-shard — nothing cross-device to check).
    fn = jax.jit(jax.shard_map(
        body, mesh=mesh, in_specs=(P(),), out_specs=P(axis_name),
        check_vma=False))
    blocks = np.asarray(fn(jax.random.key_data(key)))        # [S, sz, R]
    if directory is not None:
        for s in range(S):
            save_walk_index_shard(
                directory, s, S, g.n, blocks[s], cfg.segment_len, cfg.seed,
                step=step)
    return WalkIndex(
        endpoints=jnp.asarray(blocks.reshape(S * sz, R)[: g.n],
                              dtype=jnp.int32),
        segment_len=cfg.segment_len,
        seed=cfg.seed,
    )


# --- persistence (checkpoint/ atomic step directories) ----------------------


def _index_tree(index: WalkIndex) -> dict:
    return {
        "endpoints": index.endpoints,
        "segment_len": jnp.int32(index.segment_len),
        "seed": jnp.int32(index.seed),
    }


def _shard_dir(directory: str, shard: int) -> str:
    return os.path.join(directory, f"shard_{shard:04d}")


def save_walk_index_shard(
    directory: str,
    shard: int,
    num_shards: int,
    n: int,
    block: np.ndarray,            # int32[shard_size, R] — this shard's slab
    segment_len: int,
    seed: int,
    step: int = 0,
) -> str:
    """Atomic save of one shard's slab block under
    ``<directory>/shard_<s>/step_<k>/`` — each shard is an independent
    checkpoint dir, so a sharded build can persist (and crash/retry) one
    shard at a time without ever exposing a torn slab."""
    block = jnp.asarray(block, dtype=jnp.int32)
    return save_checkpoint(_shard_dir(directory, shard), step, {
        "endpoints": block,
        "segment_len": jnp.int32(segment_len),
        "seed": jnp.int32(seed),
        "shard": jnp.int32(shard),
        "num_shards": jnp.int32(num_shards),
        "n": jnp.int32(n),
        "segments_per_vertex": jnp.int32(block.shape[1]),
    })


def save_walk_index(directory: str, index: WalkIndex, step: int = 0) -> str:
    """Atomic save under ``<directory>/step_<k>/`` (checkpoint layout)."""
    return save_checkpoint(directory, step, _index_tree(index))


def _load_checkpoint_tree(directory: str, step: int) -> dict:
    # Reconstruct the restore template from the checkpoint's own metadata —
    # the index is self-describing, callers need not know (n, R) up front.
    with open(os.path.join(directory, f"step_{step:08d}", "tree.json")) as f:
        meta = json.load(f)
    like = {
        path: np.zeros(shape, dtype=np.dtype(dtype))
        for path, shape, dtype in zip(
            meta["paths"], meta["shapes"], meta["dtypes"])
    }
    return restore_checkpoint(directory, step, like)


def load_walk_index(directory: str, step: Optional[int] = None) -> WalkIndex:
    """Restores the latest (or given) index build from ``directory``.

    Handles both layouts: a monolithic ``save_walk_index`` checkpoint, and
    the per-shard layout written by a sharded build
    (``<directory>/shard_<s>/step_<k>/``), whose blocks are validated
    (all shards present, consistent metadata) and reassembled into the
    dense slab.
    """
    shard_dirs = sorted(
        d for d in (os.listdir(directory) if os.path.isdir(directory) else [])
        if d.startswith("shard_"))
    if not shard_dirs:
        if step is None:
            step = latest_step(directory)
            if step is None:
                raise FileNotFoundError(f"no walk index under {directory!r}")
        tree = _load_checkpoint_tree(directory, step)
        return WalkIndex(
            endpoints=tree["endpoints"],
            segment_len=int(tree["segment_len"]),
            seed=int(tree["seed"]),
        )

    blocks, meta = {}, None
    for d in shard_dirs:
        sdir = os.path.join(directory, d)
        s_step = latest_step(sdir) if step is None else step
        if s_step is None:
            raise FileNotFoundError(f"no checkpoint under {sdir!r}")
        tree = _load_checkpoint_tree(sdir, s_step)
        cur = (int(tree["num_shards"]), int(tree["n"]),
               int(tree["segment_len"]), int(tree["seed"]),
               int(tree["segments_per_vertex"]))
        if meta is None:
            meta = cur
        elif cur != meta:
            raise ValueError(
                f"inconsistent shard metadata under {directory!r}: "
                f"{cur} vs {meta}")
        blocks[int(tree["shard"])] = np.asarray(tree["endpoints"])
    num_shards, n, segment_len, seed, _ = meta
    missing = sorted(set(range(num_shards)) - set(blocks))
    if missing:
        raise FileNotFoundError(
            f"walk index under {directory!r} is missing shards {missing}")
    endpoints = np.concatenate(
        [blocks[s] for s in range(num_shards)], axis=0)[:n]
    return WalkIndex(
        endpoints=jnp.asarray(endpoints, dtype=jnp.int32),
        segment_len=segment_len,
        seed=seed,
    )
