"""Offline walk-segment index (the PowerWalk precompute, FrogWild flavour).

For every vertex ``v`` the index stores ``R`` independent endpoints of plain
(p_s = 1, no-death) random walks of exactly ``L`` steps started at ``v`` —
a dense ``int32[n, R]`` slab. Each stored endpoint is an exact sample of
the L-step transition kernel ``P^L(· | v)``, so the online engine can
replace L walker supersteps with one gather from row ``v``. Sizing note:
pick ``R ≥ t/L`` (stitches per walk) — the engine's slot rotation then
guarantees a walk never rereads a cell and its composed marginal is exact;
cell sharing across walks only adds variance (tests/test_query.py checks
the distribution statistically).

Build is sharded via ``graph/partition.py``: one fixed-shape jitted program
walks ``shard_size · R`` frogs for ``L`` steps, invoked once per range shard
(the shard loop is the host-side analogue of the engine's vertex sharding —
peak device memory is one shard's walk batch, not ``n · R``). The inner step
is a batched variant of the walker superstep and can run through the fused
Pallas ``frog_step`` kernel (``step_impl="pallas"``).

Persistence goes through ``checkpoint/`` (atomic step directories), so index
builds inherit the crash-safety and GC story of model checkpoints.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.graph.csr import CSRGraph, uniform_successor
from repro.graph.partition import partition_graph


@dataclasses.dataclass(frozen=True)
class WalkIndexConfig:
    segments_per_vertex: int = 16     # R — endpoints stored per vertex
    segment_len: int = 4              # L — steps per precomputed segment
    num_shards: int = 8               # build sharding (graph/partition.py)
    step_impl: str = "xla"            # xla | pallas | ref — walk-step backend
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class WalkIndex:
    """Dense per-vertex walk-segment endpoints.

    Attributes:
      endpoints:   int32[n, R] — ``endpoints[v, r] ~ P^L(· | v)`` i.i.d.
      segment_len: L, the number of steps each stored segment advanced.
      seed:        build seed (provenance; queries use their own keys).
    """

    endpoints: jnp.ndarray
    segment_len: int
    seed: int

    @property
    def n(self) -> int:
        return int(self.endpoints.shape[0])

    @property
    def segments_per_vertex(self) -> int:
        return int(self.endpoints.shape[1])


@dataclasses.dataclass(frozen=True)
class _ShardWalker:
    """One fixed-shape compiled program reused for every shard's build."""

    row_ptr: jnp.ndarray
    col_idx: jnp.ndarray
    deg: jnp.ndarray
    n: int
    shard_size: int
    cfg: WalkIndexConfig

    def __call__(self, lo: jnp.ndarray, key: jax.Array) -> jnp.ndarray:
        R, L = self.cfg.segments_per_vertex, self.cfg.segment_len
        pos0 = lo + jnp.repeat(
            jnp.arange(self.shard_size, dtype=jnp.int32), R,
            total_repeat_length=self.shard_size * R,
        )

        def step(pos, k):
            bits = jax.random.randint(k, pos.shape, 0, 1 << 30, jnp.int32)
            if self.cfg.step_impl == "xla":
                nxt = uniform_successor(
                    self.row_ptr, self.col_idx, self.deg, pos, bits)
            else:
                from repro.kernels import ops

                # batched frog step with no deaths: the death tally is all
                # zeros and discarded — the segment walk is the p_T = 0,
                # p_s = 1 corner of the walker superstep.
                nxt, _ = ops.frog_step(
                    pos, jnp.zeros_like(pos), bits,
                    self.row_ptr, self.col_idx, self.deg, self.n,
                    impl=self.cfg.step_impl,
                )
            return nxt, None

        pos, _ = jax.lax.scan(step, pos0, jax.random.split(key, L))
        return pos.reshape(self.shard_size, R)


def build_walk_index(
    g: CSRGraph, cfg: WalkIndexConfig, key: Optional[jax.Array] = None
) -> WalkIndex:
    """Builds the ``int32[n, R]`` endpoint slab, one range shard at a time."""
    if cfg.segment_len < 1:
        raise ValueError(f"segment_len must be ≥ 1, got {cfg.segment_len}")
    if key is None:
        key = jax.random.PRNGKey(cfg.seed)
    gp, part = partition_graph(g, cfg.num_shards)
    walker = _ShardWalker(
        row_ptr=gp.row_ptr, col_idx=gp.col_idx, deg=gp.out_deg, n=gp.n,
        shard_size=part.shard_size, cfg=cfg,
    )
    run = jax.jit(walker.__call__)
    blocks = []
    for s in range(cfg.num_shards):
        lo, _ = part.bounds(s)
        blocks.append(np.asarray(run(jnp.int32(lo), jax.random.fold_in(key, s))))
    endpoints = np.concatenate(blocks, axis=0)[: g.n]
    return WalkIndex(
        endpoints=jnp.asarray(endpoints, dtype=jnp.int32),
        segment_len=cfg.segment_len,
        seed=cfg.seed,
    )


# --- persistence (checkpoint/ atomic step directories) ----------------------


def _index_tree(index: WalkIndex) -> dict:
    return {
        "endpoints": index.endpoints,
        "segment_len": jnp.int32(index.segment_len),
        "seed": jnp.int32(index.seed),
    }


def save_walk_index(directory: str, index: WalkIndex, step: int = 0) -> str:
    """Atomic save under ``<directory>/step_<k>/`` (checkpoint layout)."""
    return save_checkpoint(directory, step, _index_tree(index))


def load_walk_index(directory: str, step: Optional[int] = None) -> WalkIndex:
    """Restores the latest (or given) index build from ``directory``."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no walk index under {directory!r}")
    # Reconstruct the restore template from the checkpoint's own metadata —
    # the index is self-describing, callers need not know (n, R) up front.
    with open(os.path.join(directory, f"step_{step:08d}", "tree.json")) as f:
        meta = json.load(f)
    like = {
        path: np.zeros(shape, dtype=np.dtype(dtype))
        for path, shape, dtype in zip(
            meta["paths"], meta["shapes"], meta["dtypes"])
    }
    tree = restore_checkpoint(directory, step, like)
    return WalkIndex(
        endpoints=tree["endpoints"],
        segment_len=int(tree["segment_len"]),
        seed=int(tree["seed"]),
    )
