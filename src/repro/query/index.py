"""Offline walk-segment index (the PowerWalk precompute, FrogWild flavour).

For every vertex ``v`` the index stores ``R`` independent endpoints of plain
(p_s = 1, no-death) random walks of exactly ``L`` steps started at ``v`` —
a dense ``int32[n, R]`` slab. Each stored endpoint is an exact sample of
the L-step transition kernel ``P^L(· | v)``, so the online engine can
replace L walker supersteps with one gather from row ``v``. Sizing note:
pick ``R ≥ t/L`` (stitches per walk) — the engine's slot rotation then
guarantees a walk never rereads a cell and its composed marginal is exact;
cell sharing across walks only adds variance (tests/test_query.py checks
the distribution statistically).

The full slab is ``4·n·R`` bytes — the Twitter-scale memory hog — so the
index exists in two forms:

* :class:`WalkIndex` — the dense slab (single-device serving, small n);
* :class:`ShardedWalkIndex` — the slab as ``num_shards`` range-partitioned
  ``[shard_size, R]`` blocks that are **never concatenated on a device**:
  the sharded :class:`~repro.query.scheduler.QueryScheduler` wave gathers
  each walk's next segment from the block of the shard that owns its
  current vertex, so peak per-device slab memory is ``4·n·R/S`` bytes.

Build is sharded via ``graph/partition.py``; the per-shard step program is
shared between two drivers built on the one shard-execution layer
(``distributed/runtime.py``):

* :func:`build_walk_index` — the host shard loop (single device);
* :func:`build_walk_index_sharded` — the same per-shard program as one
  ``shard_map`` over the runtime's ``"vertex"`` mesh axis: every device
  materializes only its own ``[shard_size, R]`` slab block, and per-shard
  blocks are persisted independently.

The inner step is a batched variant of the walker superstep and can run
through the fused Pallas kernels (``step_impl="pallas"`` for the
VMEM-resident kernel, ``"stream"`` for the HBM-streaming sorted-frog
kernel, ``"auto"`` to pick by VMEM budget).

Persistence goes through ``checkpoint/`` atomic step directories, so index
builds inherit the crash-safety and GC story of model checkpoints. A
sharded build writes one checkpoint dir per shard (``<dir>/shard_<s>/
step_<k>/``, the runtime's per-shard round-trip); :func:`load_walk_index`
detects the sharded layout and either reassembles the slab
(``reassemble=True``, the legacy reader) or hands the per-shard blocks
straight to the serving layer (``reassemble=False`` →
:class:`ShardedWalkIndex` — no device ever sees the full slab).
"""
from __future__ import annotations

import collections
import dataclasses
import os
from typing import Dict, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import (CheckpointCorruptError, latest_step,
                              save_checkpoint)
from repro.config import WalkIndexConfig, warn_deprecated
from repro.distributed.runtime import (ShardRuntime, list_shard_dirs,
                                       load_checkpoint_tree,
                                       load_shard_checkpoints,
                                       quarantine_shard_dir,
                                       save_shard_checkpoint, shard_dir)
from repro.graph.csr import CSRGraph, uniform_successor
from repro.graph.partition import partition_graph

# WalkIndexConfig is defined in repro/config.py (the layered-config module —
# single definition per flag) and re-exported here for back-compat.


@dataclasses.dataclass(frozen=True)
class WalkIndex:
    """Dense per-vertex walk-segment endpoints.

    Attributes:
      endpoints:   int32[n, R] — ``endpoints[v, r] ~ P^L(· | v)`` i.i.d.
      segment_len: L, the number of steps each stored segment advanced.
      seed:        build seed (provenance; queries use their own keys).
    """

    endpoints: jnp.ndarray
    segment_len: int
    seed: int

    @property
    def n(self) -> int:
        return int(self.endpoints.shape[0])

    @property
    def segments_per_vertex(self) -> int:
        return int(self.endpoints.shape[1])


@dataclasses.dataclass(frozen=True)
class ShardedWalkIndex:
    """The walk-index slab as range-partitioned per-shard blocks.

    ``blocks[s]`` holds the ``[shard_size, R]`` endpoints of vertices
    ``[s · shard_size, (s+1) · shard_size)`` (host memory; the sharded
    scheduler places block ``s`` on device ``s`` of the serving mesh, or
    feeds blocks one at a time on a single device — the full slab is never
    concatenated on any device).

    Attributes:
      blocks:      int32[S, shard_size, R] — host-side stacked blocks.
      n:           true vertex count (``S · shard_size ≥ n``; padded rows
                   are never gathered — walk positions are graph vertices).
      segment_len: L, steps per precomputed segment.
      seed:        build seed (provenance).
    """

    blocks: np.ndarray
    n: int
    segment_len: int
    seed: int

    @property
    def num_shards(self) -> int:
        return int(self.blocks.shape[0])

    @property
    def shard_size(self) -> int:
        return int(self.blocks.shape[1])

    @property
    def segments_per_vertex(self) -> int:
        return int(self.blocks.shape[2])

    def reassemble(self) -> WalkIndex:
        """Dense slab (tests / the legacy gathered serving path) — this is
        exactly the concatenation the sharded scheduler avoids."""
        S, sz, R = self.blocks.shape
        return WalkIndex(
            endpoints=jnp.asarray(
                self.blocks.reshape(S * sz, R)[: self.n], jnp.int32),
            segment_len=self.segment_len,
            seed=self.seed,
        )


def shard_walk_index(index: WalkIndex, num_shards: int) -> ShardedWalkIndex:
    """Range-partitions a dense index into serving blocks.

    Rows are padded to a ``num_shards`` multiple; padded rows are zero and
    unreachable (walk positions are always real graph vertices < n).
    """
    n, R = index.endpoints.shape
    sz = -(-n // num_shards)
    ep = np.zeros((num_shards * sz, R), np.int32)
    ep[:n] = np.asarray(index.endpoints)
    return ShardedWalkIndex(
        blocks=ep.reshape(num_shards, sz, R), n=n,
        segment_len=index.segment_len, seed=index.seed,
    )


def _segment_step(row_ptr, col_idx, deg, n, step_impl, pos, key):
    """One no-death plain walker move for a batch of segment walks.

    The segment walk is the p_T = 0, p_s = 1 corner of the walker
    superstep: with ``step_impl != "xla"`` it routes through the fused
    Pallas kernels (resident or HBM-streaming — the death tally is all
    zeros and discarded).
    """
    bits = jax.random.randint(key, pos.shape, 0, 1 << 30, jnp.int32)
    if step_impl == "xla":
        return uniform_successor(row_ptr, col_idx, deg, pos, bits)
    from repro.kernels import ops

    nxt, _ = ops.frog_step(
        pos, jnp.zeros_like(pos), bits, row_ptr, col_idx, deg, n,
        impl=step_impl,
    )
    return nxt


@dataclasses.dataclass(frozen=True)
class _ShardWalker:
    """One fixed-shape compiled program reused for every shard's build."""

    row_ptr: jnp.ndarray
    col_idx: jnp.ndarray
    deg: jnp.ndarray
    n: int
    shard_size: int
    cfg: WalkIndexConfig

    def __call__(self, lo: jnp.ndarray, key: jax.Array) -> jnp.ndarray:
        R, L = self.cfg.segments_per_vertex, self.cfg.segment_len
        pos0 = lo + jnp.repeat(
            jnp.arange(self.shard_size, dtype=jnp.int32), R,
            total_repeat_length=self.shard_size * R,
        )

        def step(pos, k):
            nxt = _segment_step(self.row_ptr, self.col_idx, self.deg,
                                self.n, self.cfg.step_impl, pos, k)
            return nxt, None

        pos, _ = jax.lax.scan(step, pos0, jax.random.split(key, L))
        return pos.reshape(self.shard_size, R)


def build_walk_index(
    g: CSRGraph, cfg: WalkIndexConfig, key: Optional[jax.Array] = None
) -> WalkIndex:
    """Deprecated entry point — use :meth:`repro.service.FrogWildService.
    ensure_index` (or :func:`repro.service.build_index`). Delegates through
    the service so the slab is byte-identical to the facade's."""
    warn_deprecated("build_walk_index", "FrogWildService.ensure_index")
    from repro import service

    return service.build_index(g, cfg, key=key)


def _build_walk_index(
    g: CSRGraph, cfg: WalkIndexConfig, key: Optional[jax.Array] = None
) -> WalkIndex:
    """Builds the ``int32[n, R]`` endpoint slab, one range shard at a time
    (the runtime's single-device host-loop dispatch)."""
    if cfg.segment_len < 1:
        raise ValueError(f"segment_len must be ≥ 1, got {cfg.segment_len}")
    if key is None:
        key = jax.random.PRNGKey(cfg.seed)
    gp, part = partition_graph(g, cfg.num_shards)
    walker = _ShardWalker(
        row_ptr=gp.row_ptr, col_idx=gp.col_idx, deg=gp.out_deg, n=gp.n,
        shard_size=part.shard_size, cfg=cfg,
    )
    run = jax.jit(walker.__call__)
    rt = ShardRuntime(num_shards=cfg.num_shards, mesh=None)
    blocks = rt.map_shards(
        lambda s: np.asarray(
            run(jnp.int32(part.bounds(s)[0]), jax.random.fold_in(key, s))))
    endpoints = np.concatenate(blocks, axis=0)[: g.n]
    return WalkIndex(
        endpoints=jnp.asarray(endpoints, dtype=jnp.int32),
        segment_len=cfg.segment_len,
        seed=cfg.seed,
    )


def build_walk_index_sharded(
    g: CSRGraph,
    cfg: WalkIndexConfig,
    mesh,
    directory: Optional[str] = None,
    key: Optional[jax.Array] = None,
    axis_name: str = "vertex",
    step: int = 0,
    reassemble: bool = True,
) -> Union[WalkIndex, ShardedWalkIndex]:
    """Deprecated entry point — use :meth:`repro.service.FrogWildService.
    ensure_index` (or :func:`repro.service.build_index` with ``mesh=``).
    Delegates through the service so the slab is byte-identical."""
    warn_deprecated("build_walk_index_sharded", "FrogWildService.ensure_index")
    from repro import service

    return service.build_index(g, cfg, mesh=mesh, directory=directory,
                               key=key, axis_name=axis_name, step=step,
                               reassemble=reassemble)


def _build_walk_index_sharded(
    g: CSRGraph,
    cfg: WalkIndexConfig,
    mesh,
    directory: Optional[str] = None,
    key: Optional[jax.Array] = None,
    axis_name: str = "vertex",
    step: int = 0,
    reassemble: bool = True,
) -> Union[WalkIndex, ShardedWalkIndex]:
    """Builds the slab as **one** ``shard_map`` program over ``mesh``.

    Each device walks its own range shard's ``shard_size · R`` segment
    frogs and materializes only its ``[shard_size, R]`` slab block
    (``out_specs=P(axis_name)`` — device memory holds ``4nR/S`` bytes of
    slab). The graph CSR is closed over (replicated); per-shard randomness
    is ``fold_in(key, shard)`` via the runtime's :meth:`ShardRuntime.
    shard_key`, so a shard's block is reproducible independent of mesh
    shape.

    With ``directory`` set, every shard's block is persisted as its own
    atomic checkpoint (``save_walk_index_shard``) before the function
    returns. ``reassemble=False`` returns the :class:`ShardedWalkIndex`
    blocks directly (the sharded-serving input); the default reassembles
    the dense :class:`WalkIndex` for legacy readers.
    """
    if cfg.segment_len < 1:
        raise ValueError(f"segment_len must be ≥ 1, got {cfg.segment_len}")
    if key is None:
        key = jax.random.PRNGKey(cfg.seed)
    rt = ShardRuntime.for_mesh(mesh, axis_name)
    S = rt.num_shards
    gp, part = partition_graph(g, S)
    sz = part.shard_size
    R, L = cfg.segments_per_vertex, cfg.segment_len
    row_ptr, col_idx, deg = gp.row_ptr, gp.col_idx, gp.out_deg

    def body(key_data):
        k = ShardRuntime.shard_key(key_data, axis_name)
        me = jax.lax.axis_index(axis_name)
        pos0 = me * sz + jnp.repeat(
            jnp.arange(sz, dtype=jnp.int32), R, total_repeat_length=sz * R)

        def walk(pos, kk):
            return _segment_step(row_ptr, col_idx, deg, gp.n,
                                 cfg.step_impl, pos, kk), None

        pos, _ = jax.lax.scan(walk, pos0, jax.random.split(k, L))
        return pos.reshape(1, sz, R)

    # check_vma=False: jax has no replication rule for pallas_call, and the
    # fused step backends lower through one (the body is trivially
    # per-shard — nothing cross-device to check).
    fn = rt.sharded_call(body, num_sharded=0, num_replicated=1,
                         check_vma=False)
    blocks = np.asarray(fn(ShardRuntime.key_data(key)))      # [S, sz, R]
    if directory is not None:
        for s in range(S):
            save_walk_index_shard(
                directory, s, S, g.n, blocks[s], cfg.segment_len, cfg.seed,
                step=step)
    sharded = ShardedWalkIndex(blocks=blocks, n=g.n,
                               segment_len=cfg.segment_len, seed=cfg.seed)
    return sharded.reassemble() if reassemble else sharded


# --- persistence (checkpoint/ atomic step directories) ----------------------


def _index_tree(index: WalkIndex) -> dict:
    return {
        "endpoints": index.endpoints,
        "segment_len": jnp.int32(index.segment_len),
        "seed": jnp.int32(index.seed),
    }


def save_walk_index_shard(
    directory: str,
    shard: int,
    num_shards: int,
    n: int,
    block: np.ndarray,            # int32[shard_size, R] — this shard's slab
    segment_len: int,
    seed: int,
    step: int = 0,
) -> str:
    """Atomic save of one shard's slab block through the runtime's
    per-shard checkpoint layout (``<directory>/shard_<s>/step_<k>/``) —
    each shard is an independent checkpoint dir, so a sharded build can
    persist (and crash/retry) one shard at a time without ever exposing a
    torn slab."""
    block = jnp.asarray(block, dtype=jnp.int32)
    return save_shard_checkpoint(directory, shard, {
        "endpoints": block,
        "segment_len": jnp.int32(segment_len),
        "seed": jnp.int32(seed),
        "shard": jnp.int32(shard),
        "num_shards": jnp.int32(num_shards),
        "n": jnp.int32(n),
        "segments_per_vertex": jnp.int32(block.shape[1]),
    }, step=step)


def save_walk_index(directory: str, index: WalkIndex, step: int = 0) -> str:
    """Atomic save under ``<directory>/step_<k>/`` (checkpoint layout)."""
    return save_checkpoint(directory, step, _index_tree(index))


def load_walk_index(
    directory: str, step: Optional[int] = None, reassemble: bool = True
) -> Union[WalkIndex, ShardedWalkIndex]:
    """Restores the latest (or given) index build from ``directory``.

    Handles both layouts: a monolithic ``save_walk_index`` checkpoint, and
    the per-shard layout written by a sharded build (``<directory>/
    shard_<s>/step_<k>/``), whose blocks are validated (all shards
    present, consistent metadata). ``reassemble=True`` concatenates them
    into the dense slab (legacy readers); ``reassemble=False`` hands the
    per-shard blocks to the caller as a :class:`ShardedWalkIndex` — the
    sharded scheduler's input, with no full-slab concatenation (a
    monolithic checkpoint is returned as a single-shard index).
    """
    shard_dirs = list_shard_dirs(directory)
    if not shard_dirs:
        if step is None:
            step = latest_step(directory)
            if step is None:
                raise FileNotFoundError(f"no walk index under {directory!r}")
        tree = load_checkpoint_tree(directory, step)
        index = WalkIndex(
            endpoints=jnp.asarray(tree["endpoints"], jnp.int32),
            segment_len=int(tree["segment_len"]),
            seed=int(tree["seed"]),
        )
        return index if reassemble else shard_walk_index(index, 1)

    trees = load_shard_checkpoints(directory, step, on_error="collect")
    good, bad = _split_shard_trees(directory, trees)
    meta = _shard_meta_consensus(directory, good, bad)
    if bad:
        R, L = (meta.R, meta.L) if meta is not None else ("?", "?")
        detail = "; ".join(f"{shard_dir(directory, s)}: {e}"
                           for s, e in sorted(bad.items()))
        raise CheckpointCorruptError(
            f"walk index under {directory!r} has corrupt or partial shard "
            f"checkpoints (expected int32[shard_size, R={R}] blocks of "
            f"L={L}-step segments): {detail} — quarantine and rebuild "
            f"them (load_or_repair_walk_index does both)")
    missing = sorted(set(range(meta.num_shards)) - set(good))
    if missing:
        raise FileNotFoundError(
            f"walk index under {directory!r} is missing shards {missing} "
            f"(expected {meta.num_shards} shard dirs of "
            f"int32[shard_size, R={meta.R}] blocks, L={meta.L})")
    return _assemble_sharded(good, meta, reassemble)


_ShardMeta = collections.namedtuple(
    "_ShardMeta", ["num_shards", "n", "L", "seed", "R"])


def _split_shard_trees(directory, trees):
    """Separates healthy shard trees from failed loads; a tree whose
    payload shape contradicts its own metadata counts as corrupt."""
    good: Dict[int, dict] = {}
    bad: Dict[int, Exception] = {}
    for s, tree in trees.items():
        if isinstance(tree, Exception):
            bad[s] = tree
            continue
        try:
            R = int(tree["segments_per_vertex"])
            ep = np.asarray(tree["endpoints"])
            if ep.ndim != 2 or ep.shape[1] != R:
                raise CheckpointCorruptError(
                    f"shard block has shape {ep.shape}, metadata says "
                    f"R={R}")
            good[s] = tree
        except (KeyError, CheckpointCorruptError) as e:
            bad[s] = e if isinstance(e, CheckpointCorruptError) else (
                CheckpointCorruptError(
                    f"shard checkpoint is missing leaf {e}"))
    return good, bad


def _shard_meta_consensus(directory, good, bad):
    """Majority metadata across healthy shards; dissenting shards are
    reclassified as corrupt (moved to ``bad``). None when no healthy
    shard survives."""
    metas = {
        s: _ShardMeta(int(t["num_shards"]), int(t["n"]),
                      int(t["segment_len"]), int(t["seed"]),
                      int(t["segments_per_vertex"]))
        for s, t in good.items()
    }
    if not metas:
        return None
    consensus, _ = collections.Counter(metas.values()).most_common(1)[0]
    for s, m in metas.items():
        if m != consensus:
            bad[s] = CheckpointCorruptError(
                f"shard metadata {tuple(m)} disagrees with the "
                f"{tuple(consensus)} consensus under {directory!r}")
            del good[s]
    return consensus


def _assemble_sharded(good, meta, reassemble):
    sharded = ShardedWalkIndex(
        blocks=np.stack([np.asarray(good[s]["endpoints"])
                         for s in range(meta.num_shards)]).astype(np.int32),
        n=meta.n, segment_len=meta.L, seed=meta.seed,
    )
    return sharded.reassemble() if reassemble else sharded


def rebuild_shard_blocks(
    g: CSRGraph, cfg: WalkIndexConfig, shards: List[int]
) -> Dict[int, np.ndarray]:
    """Rebuilds just the named shards' slab blocks with the build's exact
    key stream (``fold_in(PRNGKey(cfg.seed), shard)`` over the
    ``partition_graph(g, cfg.num_shards)`` ranges) — byte-identical to the
    blocks the original host-loop *or* ``shard_map`` build produced, so a
    quarantined shard can be regenerated without touching the others."""
    gp, part = partition_graph(g, cfg.num_shards)
    walker = _ShardWalker(
        row_ptr=gp.row_ptr, col_idx=gp.col_idx, deg=gp.out_deg, n=gp.n,
        shard_size=part.shard_size, cfg=cfg,
    )
    run = jax.jit(walker.__call__)
    key = jax.random.PRNGKey(cfg.seed)
    return {
        s: np.asarray(run(jnp.int32(part.bounds(s)[0]),
                          jax.random.fold_in(key, s)))
        for s in shards
    }


def load_or_repair_walk_index(
    directory: str,
    g: CSRGraph,
    cfg: WalkIndexConfig,
    step: Optional[int] = None,
    reassemble: bool = True,
) -> Union[WalkIndex, ShardedWalkIndex]:
    """Like :func:`load_walk_index`, but self-healing for the per-shard
    layout: a corrupt, torn, or missing shard checkpoint is quarantined
    (``quarantine.shard_<s>`` — kept for forensics, invisible to loaders)
    and its slab block rebuilt via :func:`rebuild_shard_blocks` with the
    original build's key stream, then persisted and served. Only the
    broken shards are rebuilt; healthy blocks are never re-walked.

    The monolithic (dense) layout has no sub-unit to repair — corruption
    there propagates as :class:`~repro.checkpoint.CheckpointCorruptError`
    and the caller rebuilds the whole index.
    """
    if not list_shard_dirs(directory):
        return load_walk_index(directory, step, reassemble)

    trees = load_shard_checkpoints(directory, step, on_error="collect")
    good, bad = _split_shard_trees(directory, trees)
    meta = _shard_meta_consensus(directory, good, bad)
    if meta is None:
        # every shard is broken: fall back to the caller's config geometry
        meta = _ShardMeta(cfg.num_shards, g.n, cfg.segment_len, cfg.seed,
                          cfg.segments_per_vertex)
    if meta.n != g.n:
        raise ValueError(
            f"walk index under {directory!r} was built for n={meta.n} but "
            f"the service graph has n={g.n}; refusing to repair across "
            f"graphs — point checkpoint_dir elsewhere or rebuild")
    missing = sorted(set(range(meta.num_shards)) - set(good))
    broken = sorted(set(bad) | set(missing))
    if not broken:
        return _assemble_sharded(good, meta, reassemble)

    build_cfg = dataclasses.replace(
        cfg, num_shards=meta.num_shards, segments_per_vertex=meta.R,
        segment_len=meta.L, seed=meta.seed)
    rebuilt = rebuild_shard_blocks(g, build_cfg, broken)
    healthy_step = step
    if healthy_step is None:
        steps = [latest_step(shard_dir(directory, s)) for s in good]
        healthy_step = next((s for s in steps if s is not None), 0)
    for s in broken:
        if os.path.isdir(shard_dir(directory, s)):
            quarantine_shard_dir(directory, s)
        save_walk_index_shard(
            directory, s, meta.num_shards, g.n, rebuilt[s], meta.L,
            meta.seed, step=healthy_step)
        good[s] = {"endpoints": rebuilt[s]}
    return _assemble_sharded(good, meta, reassemble)
