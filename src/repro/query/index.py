"""Offline walk-segment index (the PowerWalk precompute, FrogWild flavour).

For every vertex ``v`` the index stores ``R`` independent endpoints of plain
(p_s = 1, no-death) random walks of exactly ``L`` steps started at ``v`` —
a dense ``int32[n, R]`` slab. Each stored endpoint is an exact sample of
the L-step transition kernel ``P^L(· | v)``, so the online engine can
replace L walker supersteps with one gather from row ``v``. Sizing note:
pick ``R ≥ t/L`` (stitches per walk) — the engine's slot rotation then
guarantees a walk never rereads a cell and its composed marginal is exact;
cell sharing across walks only adds variance (tests/test_query.py checks
the distribution statistically).

The full slab is ``4·n·R`` bytes — the Twitter-scale memory hog — so the
index exists in two forms:

* :class:`WalkIndex` — the dense slab (single-device serving, small n);
* :class:`ShardedWalkIndex` — the slab as ``num_shards`` range-partitioned
  ``[shard_size, R]`` blocks that are **never concatenated on a device**:
  the sharded :class:`~repro.query.scheduler.QueryScheduler` wave gathers
  each walk's next segment from the block of the shard that owns its
  current vertex, so peak per-device slab memory is ``4·n·R/S`` bytes.

Build is sharded via ``graph/partition.py``; the per-shard step program is
shared between two drivers built on the one shard-execution layer
(``distributed/runtime.py``):

* :func:`build_walk_index` — the host shard loop (single device);
* :func:`build_walk_index_sharded` — the same per-shard program as one
  ``shard_map`` over the runtime's ``"vertex"`` mesh axis: every device
  materializes only its own ``[shard_size, R]`` slab block, and per-shard
  blocks are persisted independently.

The inner step is a batched variant of the walker superstep and can run
through the fused Pallas kernels (``step_impl="pallas"`` for the
VMEM-resident kernel, ``"stream"`` for the HBM-streaming sorted-frog
kernel, ``"auto"`` to pick by VMEM budget).

**Per-vertex key streams (dynamic-graph contract).** A segment's
randomness is derived per *(vertex, step)* — ``fold_in(fold_in(key, v),
l)`` drawing ``R`` slot bits at shape ``(R,)`` — never per batch shape,
so a row's endpoints are byte-identical whether walked in a full-shard
build, a ``shard_map`` build, or an arbitrary row/slot subset. This is
what lets ``repro.dynamic.refresh_walk_index`` rebuild exactly the
invalidated segments of a mutated graph and still produce a slab
byte-identical to a from-scratch build at the new epoch. The build scan
additionally records, per segment, a bitmask over ``32·_MASK_WORDS``
vertex-id blocks of every vertex whose out-edge the segment consumed —
stored as ``visited_blocks`` (uint32[n, R, W]) — so staleness under a
mutation batch is one vectorized bitwise check, not a re-walk.

Persistence goes through ``checkpoint/`` atomic step directories, so index
builds inherit the crash-safety and GC story of model checkpoints. A
sharded build writes one checkpoint dir per shard (``<dir>/shard_<s>/
step_<k>/``, the runtime's per-shard round-trip); :func:`load_walk_index`
detects the sharded layout and either reassembles the slab
(``reassemble=True``, the legacy reader) or hands the per-shard blocks
straight to the serving layer (``reassemble=False`` →
:class:`ShardedWalkIndex` — no device ever sees the full slab).
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import os
from typing import Dict, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import (CheckpointCorruptError, latest_step,
                              save_checkpoint)
from repro.config import WalkIndexConfig, warn_deprecated
from repro.distributed.runtime import (ShardRuntime, list_shard_dirs,
                                       load_checkpoint_tree,
                                       load_shard_checkpoints,
                                       quarantine_shard_dir,
                                       save_shard_checkpoint, shard_dir)
from repro.graph.csr import CSRGraph, uniform_successor
from repro.graph.partition import partition_graph

# WalkIndexConfig is defined in repro/config.py (the layered-config module —
# single definition per flag) and re-exported here for back-compat.

# Per-segment visited-block bitmask geometry: ``32 · _MASK_WORDS`` vertex-id
# blocks of ``segment_mask_block_size(n)`` consecutive ids each. For
# n ≤ 256 the blocks are single vertices (invalidation is exact); larger
# graphs trade one conservative bit per
# ``ceil(n / 256)`` ids for a fixed 32-byte-per-segment footprint.
_MASK_WORDS = 8


def segment_mask_block_size(n: int) -> int:
    """Vertex ids per visited-block bit for an n-vertex graph (the one
    formula shared by the index build and ``repro.dynamic`` invalidation —
    they must agree or staleness checks would be unsound)."""
    return max(1, -(-n // (32 * _MASK_WORDS)))


@dataclasses.dataclass(frozen=True)
class WalkIndex:
    """Dense per-vertex walk-segment endpoints.

    Attributes:
      endpoints:   int32[n, R] — ``endpoints[v, r] ~ P^L(· | v)`` i.i.d.
      segment_len: L, the number of steps each stored segment advanced.
      seed:        build seed (provenance; queries use their own keys).
      visited_blocks: uint32[n, R, _MASK_WORDS] — per-segment bitmask of
                   the vertex-id blocks whose out-edges the segment
                   consumed (the dynamic-graph invalidation input; None
                   on indexes loaded from pre-epoch checkpoints).
      graph_epoch: mutation epoch of the graph this slab was walked on.
      mutation_offset: that graph's mutation-log offset (manifest cross-
                   check against ``CSRGraph.mutation_offset``).
    """

    endpoints: jnp.ndarray
    segment_len: int
    seed: int
    visited_blocks: Optional[np.ndarray] = None
    graph_epoch: int = 0
    mutation_offset: int = 0

    @property
    def n(self) -> int:
        return int(self.endpoints.shape[0])

    @property
    def segments_per_vertex(self) -> int:
        return int(self.endpoints.shape[1])


@dataclasses.dataclass(frozen=True)
class ShardedWalkIndex:
    """The walk-index slab as range-partitioned per-shard blocks.

    ``blocks[s]`` holds the ``[shard_size, R]`` endpoints of vertices
    ``[s · shard_size, (s+1) · shard_size)`` (host memory; the sharded
    scheduler places block ``s`` on device ``s`` of the serving mesh, or
    feeds blocks one at a time on a single device — the full slab is never
    concatenated on any device).

    Attributes:
      blocks:      int32[S, shard_size, R] — host-side stacked blocks.
      n:           true vertex count (``S · shard_size ≥ n``; padded rows
                   are never gathered — walk positions are graph vertices).
      segment_len: L, steps per precomputed segment.
      seed:        build seed (provenance).
      visited_blocks: uint32[S, shard_size, R, _MASK_WORDS] per-segment
                   visited-block bitmasks (None for pre-epoch checkpoints).
      graph_epoch / mutation_offset: epoch provenance of the graph this
                   slab was walked on (see :class:`WalkIndex`).
    """

    blocks: np.ndarray
    n: int
    segment_len: int
    seed: int
    visited_blocks: Optional[np.ndarray] = None
    graph_epoch: int = 0
    mutation_offset: int = 0

    @property
    def num_shards(self) -> int:
        return int(self.blocks.shape[0])

    @property
    def shard_size(self) -> int:
        return int(self.blocks.shape[1])

    @property
    def segments_per_vertex(self) -> int:
        return int(self.blocks.shape[2])

    def reassemble(self) -> WalkIndex:
        """Dense slab (tests / the legacy gathered serving path) — this is
        exactly the concatenation the sharded scheduler avoids."""
        S, sz, R = self.blocks.shape
        vb = self.visited_blocks
        if vb is not None:
            vb = np.asarray(vb).reshape(S * sz, R, _MASK_WORDS)[: self.n]
        return WalkIndex(
            endpoints=jnp.asarray(
                self.blocks.reshape(S * sz, R)[: self.n], jnp.int32),
            segment_len=self.segment_len,
            seed=self.seed,
            visited_blocks=vb,
            graph_epoch=self.graph_epoch,
            mutation_offset=self.mutation_offset,
        )


def shard_walk_index(index: WalkIndex, num_shards: int) -> ShardedWalkIndex:
    """Range-partitions a dense index into serving blocks.

    Rows are padded to a ``num_shards`` multiple; padded rows are zero and
    unreachable (walk positions are always real graph vertices < n).
    """
    n, R = index.endpoints.shape
    sz = -(-n // num_shards)
    ep = np.zeros((num_shards * sz, R), np.int32)
    ep[:n] = np.asarray(index.endpoints)
    vb = None
    if index.visited_blocks is not None:
        vb = np.zeros((num_shards * sz, R, _MASK_WORDS), np.uint32)
        vb[:n] = np.asarray(index.visited_blocks)
        vb = vb.reshape(num_shards, sz, R, _MASK_WORDS)
    return ShardedWalkIndex(
        blocks=ep.reshape(num_shards, sz, R), n=n,
        segment_len=index.segment_len, seed=index.seed,
        visited_blocks=vb, graph_epoch=index.graph_epoch,
        mutation_offset=index.mutation_offset,
    )


def _segment_step(row_ptr, col_idx, deg, n, step_impl, pos, bits):
    """One no-death plain walker move for a batch of segment walks.

    The segment walk is the p_T = 0, p_s = 1 corner of the walker
    superstep: with ``step_impl != "xla"`` it routes through the fused
    Pallas kernels (resident or HBM-streaming — the death tally is all
    zeros and discarded). ``bits`` are the callers' per-walker slot draws
    (per-vertex key streams — see the module docstring).
    """
    if step_impl == "xla":
        return uniform_successor(row_ptr, col_idx, deg, pos, bits)
    from repro.kernels import ops

    nxt, _ = ops.frog_step(
        pos, jnp.zeros_like(pos), bits, row_ptr, col_idx, deg, n,
        impl=step_impl,
    )
    return nxt


def _block_one_hot(pos, block_size, num_words):
    """uint32[len(pos), num_words] — the visited-block bit of each walker's
    current vertex (out-of-range blocks, i.e. graph-padding rows, contribute
    no bit)."""
    blk = (pos // block_size).astype(jnp.uint32)
    word = blk >> 5
    bit = (blk & jnp.uint32(31))[:, None]
    eq = jnp.arange(num_words, dtype=jnp.uint32)[None, :] == word[:, None]
    return eq.astype(jnp.uint32) << bit


def _segment_walk_rows(row_ptr, col_idx, deg, n, step_impl, R, L,
                       block_size, vertices, key):
    """The one segment-walk program under every build and refresh path.

    Walks the L-step segments of ``vertices`` — all ``R`` slots per row.
    Randomness is per ``(vertex, step)``:
    ``fold_in(fold_in(key, v), l)`` drawing the row's ``R`` slot bits at
    shape ``(R,)``, so a row's stream is independent of the batch it is
    walked in — full-shard builds, ``shard_map`` builds, and arbitrary
    stale-row subsets all produce byte-identical cells.

    Returns ``(endpoints[C, R], visited_masks[C, R, W])``. The mask ORs the
    block bit of the *intermediate* vertices only (``p_1..p_{L-1}``): the
    start's out-edge consumption is covered exactly — per vertex, not per
    block — by the invalidator's source rule, so recording its block here
    would only drag every block-mate of a mutated vertex stale, and the
    endpoint consumes no edge at all.
    """
    C = vertices.shape[0]
    row_keys = jax.vmap(lambda v: jax.random.fold_in(key, v))(vertices)
    pos0 = jnp.repeat(vertices.astype(jnp.int32), R,
                      total_repeat_length=C * R)
    mask0 = jnp.zeros((pos0.shape[0], _MASK_WORDS), jnp.uint32)

    def step(carry, l):
        pos, mask = carry
        ks = jax.vmap(lambda kk: jax.random.fold_in(kk, l))(row_keys)
        bits = jax.vmap(
            lambda kk: jax.random.randint(kk, (R,), 0, 1 << 30, jnp.int32)
        )(ks)
        nxt = _segment_step(row_ptr, col_idx, deg, n, step_impl, pos,
                            bits.reshape(-1))
        oh = _block_one_hot(nxt, block_size, _MASK_WORDS)
        mask = jnp.where(l < L - 1, mask | oh, mask)
        return (nxt, mask), None

    (pos, mask), _ = jax.lax.scan(step, (pos0, mask0),
                                  jnp.arange(L, dtype=jnp.int32))
    return pos.reshape(C, R), mask.reshape(C, R, _MASK_WORDS)


@functools.lru_cache(maxsize=None)
def _row_walk_program(n, step_impl, R, L, block_size):
    """The process-wide compiled row walker for one geometry.

    Graph buffers are *traced operands*, not closure constants, so every
    build, shard repair, and incremental refresh at the same geometry
    shares one compile — a mutated graph at a new epoch re-dispatches the
    cached program instead of re-tracing (only a changed ``col_idx``
    length, i.e. a net edge-count change, costs a new trace). Wrapping
    this in another ``jax.jit`` at a call site would inline and re-trace
    it per wrapper; call it directly.
    """

    def run(row_ptr, col_idx, deg, vertices, key):
        return _segment_walk_rows(row_ptr, col_idx, deg, n, step_impl,
                                  R, L, block_size, vertices, key)

    return jax.jit(run)


@dataclasses.dataclass(frozen=True)
class _ShardWalker:
    """Per-shard front-end over the cached :func:`_row_walk_program`.

    ``block_size`` is ``segment_mask_block_size`` of the *real* vertex
    count (``n`` here is the padded graph's, used only for kernel bounds);
    padded rows' walks stay on their self-loops ≥ real n and fall outside
    the mask range, contributing no bits. Call it directly — the row
    program inside is already jitted and shared process-wide; wrapping the
    call in ``jax.jit`` again would re-trace it per wrapper.
    """

    row_ptr: jnp.ndarray
    col_idx: jnp.ndarray
    deg: jnp.ndarray
    n: int
    shard_size: int
    cfg: WalkIndexConfig
    block_size: int

    def __call__(self, lo: jnp.ndarray, key: jax.Array):
        vs = lo + jnp.arange(self.shard_size, dtype=jnp.int32)
        run = _row_walk_program(
            self.n, self.cfg.step_impl, self.cfg.segments_per_vertex,
            self.cfg.segment_len, self.block_size)
        return run(self.row_ptr, self.col_idx, self.deg, vs, key)


def build_walk_index(
    g: CSRGraph, cfg: WalkIndexConfig, key: Optional[jax.Array] = None
) -> WalkIndex:
    """Deprecated entry point — use :meth:`repro.service.FrogWildService.
    ensure_index` (or :func:`repro.service.build_index`). Delegates through
    the service so the slab is byte-identical to the facade's."""
    warn_deprecated("build_walk_index", "FrogWildService.ensure_index")
    from repro import service

    return service.build_index(g, cfg, key=key)


def _build_walk_index(
    g: CSRGraph, cfg: WalkIndexConfig, key: Optional[jax.Array] = None
) -> WalkIndex:
    """Builds the ``int32[n, R]`` endpoint slab, one range shard at a time
    (the runtime's single-device host-loop dispatch)."""
    if cfg.segment_len < 1:
        raise ValueError(f"segment_len must be ≥ 1, got {cfg.segment_len}")
    if key is None:
        key = jax.random.PRNGKey(cfg.seed)
    gp, part = partition_graph(g, cfg.num_shards)
    walker = _ShardWalker(
        row_ptr=gp.row_ptr, col_idx=gp.col_idx, deg=gp.out_deg, n=gp.n,
        shard_size=part.shard_size, cfg=cfg,
        block_size=segment_mask_block_size(g.n),
    )
    rt = ShardRuntime(num_shards=cfg.num_shards, mesh=None)
    # per-vertex key streams: every shard gets the same base key — the
    # vertex id folded inside the walk program is the only stream selector.
    pairs = rt.map_shards(
        lambda s: jax.tree_util.tree_map(
            np.asarray, walker(jnp.int32(part.bounds(s)[0]), key)))
    endpoints = np.concatenate([p[0] for p in pairs], axis=0)[: g.n]
    masks = np.concatenate([p[1] for p in pairs], axis=0)[: g.n]
    return WalkIndex(
        endpoints=jnp.asarray(endpoints, dtype=jnp.int32),
        segment_len=cfg.segment_len,
        seed=cfg.seed,
        visited_blocks=masks.astype(np.uint32),
        graph_epoch=int(getattr(g, "epoch", 0)),
        mutation_offset=int(getattr(g, "mutation_offset", 0)),
    )


def build_walk_index_sharded(
    g: CSRGraph,
    cfg: WalkIndexConfig,
    mesh,
    directory: Optional[str] = None,
    key: Optional[jax.Array] = None,
    axis_name: str = "vertex",
    step: int = 0,
    reassemble: bool = True,
) -> Union[WalkIndex, ShardedWalkIndex]:
    """Deprecated entry point — use :meth:`repro.service.FrogWildService.
    ensure_index` (or :func:`repro.service.build_index` with ``mesh=``).
    Delegates through the service so the slab is byte-identical."""
    warn_deprecated("build_walk_index_sharded", "FrogWildService.ensure_index")
    from repro import service

    return service.build_index(g, cfg, mesh=mesh, directory=directory,
                               key=key, axis_name=axis_name, step=step,
                               reassemble=reassemble)


def _build_walk_index_sharded(
    g: CSRGraph,
    cfg: WalkIndexConfig,
    mesh,
    directory: Optional[str] = None,
    key: Optional[jax.Array] = None,
    axis_name: str = "vertex",
    step: int = 0,
    reassemble: bool = True,
) -> Union[WalkIndex, ShardedWalkIndex]:
    """Builds the slab as **one** ``shard_map`` program over ``mesh``.

    Each device walks its own range shard's ``shard_size · R`` segment
    frogs and materializes only its ``[shard_size, R]`` slab block
    (``out_specs=P(axis_name)`` — device memory holds ``4nR/S`` bytes of
    slab). The graph CSR is closed over (replicated); randomness is the
    per-vertex key stream (``fold_in(key, v)`` inside the shared walk
    program — see the module docstring), so a shard's block is
    byte-identical to the host loop's and to any row-subset rebuild,
    independent of mesh shape.

    With ``directory`` set, every shard's block is persisted as its own
    atomic checkpoint (``save_walk_index_shard``) before the function
    returns. ``reassemble=False`` returns the :class:`ShardedWalkIndex`
    blocks directly (the sharded-serving input); the default reassembles
    the dense :class:`WalkIndex` for legacy readers.
    """
    if cfg.segment_len < 1:
        raise ValueError(f"segment_len must be ≥ 1, got {cfg.segment_len}")
    if key is None:
        key = jax.random.PRNGKey(cfg.seed)
    rt = ShardRuntime.for_mesh(mesh, axis_name)
    S = rt.num_shards
    gp, part = partition_graph(g, S)
    sz = part.shard_size
    R, L = cfg.segments_per_vertex, cfg.segment_len
    bs = segment_mask_block_size(g.n)
    row_ptr, col_idx, deg = gp.row_ptr, gp.col_idx, gp.out_deg

    def body(key_data):
        k = jax.random.wrap_key_data(key_data, impl="threefry2x32")
        me = jax.lax.axis_index(axis_name)
        vs = me * sz + jnp.arange(sz, dtype=jnp.int32)
        ep, mk = _segment_walk_rows(row_ptr, col_idx, deg, gp.n,
                                    cfg.step_impl, R, L, bs, vs, k)
        return ep.reshape(1, sz, R), mk.reshape(1, sz, R, _MASK_WORDS)

    # check_vma=False: jax has no replication rule for pallas_call, and the
    # fused step backends lower through one (the body is trivially
    # per-shard — nothing cross-device to check).
    fn = rt.sharded_call(body, num_sharded=0, num_replicated=1,
                         num_outputs=2, check_vma=False)
    ep, mk = fn(ShardRuntime.key_data(key))
    blocks = np.asarray(ep)                       # [S, sz, R]
    masks = np.asarray(mk).astype(np.uint32)      # [S, sz, R, W]
    g_epoch = int(getattr(g, "epoch", 0))
    g_offset = int(getattr(g, "mutation_offset", 0))
    if directory is not None:
        for s in range(S):
            save_walk_index_shard(
                directory, s, S, g.n, blocks[s], cfg.segment_len, cfg.seed,
                step=step, visited_blocks=masks[s], graph_epoch=g_epoch,
                mutation_offset=g_offset)
    sharded = ShardedWalkIndex(blocks=blocks, n=g.n,
                               segment_len=cfg.segment_len, seed=cfg.seed,
                               visited_blocks=masks, graph_epoch=g_epoch,
                               mutation_offset=g_offset)
    return sharded.reassemble() if reassemble else sharded


# --- persistence (checkpoint/ atomic step directories) ----------------------


def _index_tree(index: WalkIndex) -> dict:
    tree = {
        "endpoints": index.endpoints,
        "segment_len": jnp.int32(index.segment_len),
        "seed": jnp.int32(index.seed),
        "graph_epoch": jnp.int32(index.graph_epoch),
        "mutation_offset": jnp.int32(index.mutation_offset),
    }
    if index.visited_blocks is not None:
        tree["visited_blocks"] = jnp.asarray(index.visited_blocks,
                                             jnp.uint32)
    return tree


def save_walk_index_shard(
    directory: str,
    shard: int,
    num_shards: int,
    n: int,
    block: np.ndarray,            # int32[shard_size, R] — this shard's slab
    segment_len: int,
    seed: int,
    step: int = 0,
    *,
    visited_blocks: Optional[np.ndarray] = None,
    graph_epoch: int = 0,
    mutation_offset: int = 0,
) -> str:
    """Atomic save of one shard's slab block through the runtime's
    per-shard checkpoint layout (``<directory>/shard_<s>/step_<k>/``) —
    each shard is an independent checkpoint dir, so a sharded build can
    persist (and crash/retry) one shard at a time without ever exposing a
    torn slab. ``graph_epoch`` / ``mutation_offset`` stamp the manifest
    with the source graph's mutation provenance; ``visited_blocks`` rides
    along when the build recorded per-segment masks."""
    block = jnp.asarray(block, dtype=jnp.int32)
    tree = {
        "endpoints": block,
        "segment_len": jnp.int32(segment_len),
        "seed": jnp.int32(seed),
        "shard": jnp.int32(shard),
        "num_shards": jnp.int32(num_shards),
        "n": jnp.int32(n),
        "segments_per_vertex": jnp.int32(block.shape[1]),
        "graph_epoch": jnp.int32(graph_epoch),
        "mutation_offset": jnp.int32(mutation_offset),
    }
    if visited_blocks is not None:
        tree["visited_blocks"] = jnp.asarray(visited_blocks, jnp.uint32)
    return save_shard_checkpoint(directory, shard, tree, step=step)


def save_walk_index(directory: str, index: WalkIndex, step: int = 0) -> str:
    """Atomic save under ``<directory>/step_<k>/`` (checkpoint layout)."""
    return save_checkpoint(directory, step, _index_tree(index))


def load_walk_index(
    directory: str, step: Optional[int] = None, reassemble: bool = True
) -> Union[WalkIndex, ShardedWalkIndex]:
    """Restores the latest (or given) index build from ``directory``.

    Handles both layouts: a monolithic ``save_walk_index`` checkpoint, and
    the per-shard layout written by a sharded build (``<directory>/
    shard_<s>/step_<k>/``), whose blocks are validated (all shards
    present, consistent metadata). ``reassemble=True`` concatenates them
    into the dense slab (legacy readers); ``reassemble=False`` hands the
    per-shard blocks to the caller as a :class:`ShardedWalkIndex` — the
    sharded scheduler's input, with no full-slab concatenation (a
    monolithic checkpoint is returned as a single-shard index).
    """
    shard_dirs = list_shard_dirs(directory)
    if not shard_dirs:
        if step is None:
            step = latest_step(directory)
            if step is None:
                raise FileNotFoundError(f"no walk index under {directory!r}")
        tree = load_checkpoint_tree(directory, step)
        vb = tree.get("visited_blocks")
        index = WalkIndex(
            endpoints=jnp.asarray(tree["endpoints"], jnp.int32),
            segment_len=int(tree["segment_len"]),
            seed=int(tree["seed"]),
            visited_blocks=(None if vb is None
                            else np.asarray(vb, np.uint32)),
            graph_epoch=int(tree.get("graph_epoch", 0)),
            mutation_offset=int(tree.get("mutation_offset", 0)),
        )
        return index if reassemble else shard_walk_index(index, 1)

    trees = load_shard_checkpoints(directory, step, on_error="collect")
    good, bad = _split_shard_trees(directory, trees)
    meta = _shard_meta_consensus(directory, good, bad)
    if bad:
        R, L = (meta.R, meta.L) if meta is not None else ("?", "?")
        detail = "; ".join(f"{shard_dir(directory, s)}: {e}"
                           for s, e in sorted(bad.items()))
        raise CheckpointCorruptError(
            f"walk index under {directory!r} has corrupt or partial shard "
            f"checkpoints (expected int32[shard_size, R={R}] blocks of "
            f"L={L}-step segments): {detail} — quarantine and rebuild "
            f"them (load_or_repair_walk_index does both)")
    missing = sorted(set(range(meta.num_shards)) - set(good))
    if missing:
        raise FileNotFoundError(
            f"walk index under {directory!r} is missing shards {missing} "
            f"(expected {meta.num_shards} shard dirs of "
            f"int32[shard_size, R={meta.R}] blocks, L={meta.L})")
    return _assemble_sharded(good, meta, reassemble)


_ShardMeta = collections.namedtuple(
    "_ShardMeta",
    ["num_shards", "n", "L", "seed", "R", "graph_epoch", "mutation_offset"])


def _split_shard_trees(directory, trees):
    """Separates healthy shard trees from failed loads; a tree whose
    payload shape contradicts its own metadata counts as corrupt."""
    good: Dict[int, dict] = {}
    bad: Dict[int, Exception] = {}
    for s, tree in trees.items():
        if isinstance(tree, Exception):
            bad[s] = tree
            continue
        try:
            R = int(tree["segments_per_vertex"])
            ep = np.asarray(tree["endpoints"])
            if ep.ndim != 2 or ep.shape[1] != R:
                raise CheckpointCorruptError(
                    f"shard block has shape {ep.shape}, metadata says "
                    f"R={R}")
            good[s] = tree
        except (KeyError, CheckpointCorruptError) as e:
            bad[s] = e if isinstance(e, CheckpointCorruptError) else (
                CheckpointCorruptError(
                    f"shard checkpoint is missing leaf {e}"))
    return good, bad


def _shard_meta_consensus(directory, good, bad):
    """Majority metadata across healthy shards; dissenting shards are
    reclassified as corrupt (moved to ``bad``). None when no healthy
    shard survives."""
    metas = {
        s: _ShardMeta(int(t["num_shards"]), int(t["n"]),
                      int(t["segment_len"]), int(t["seed"]),
                      int(t["segments_per_vertex"]),
                      int(t.get("graph_epoch", 0)),
                      int(t.get("mutation_offset", 0)))
        for s, t in good.items()
    }
    if not metas:
        return None
    consensus, _ = collections.Counter(metas.values()).most_common(1)[0]
    for s, m in metas.items():
        if m != consensus:
            bad[s] = CheckpointCorruptError(
                f"shard metadata {tuple(m)} disagrees with the "
                f"{tuple(consensus)} consensus under {directory!r}")
            del good[s]
    return consensus


def _assemble_sharded(good, meta, reassemble):
    vb = None
    if all("visited_blocks" in good[s] for s in range(meta.num_shards)):
        vb = np.stack([np.asarray(good[s]["visited_blocks"])
                       for s in range(meta.num_shards)]).astype(np.uint32)
    sharded = ShardedWalkIndex(
        blocks=np.stack([np.asarray(good[s]["endpoints"])
                         for s in range(meta.num_shards)]).astype(np.int32),
        n=meta.n, segment_len=meta.L, seed=meta.seed,
        visited_blocks=vb, graph_epoch=meta.graph_epoch,
        mutation_offset=meta.mutation_offset,
    )
    return sharded.reassemble() if reassemble else sharded


def rebuild_shard_blocks(
    g: CSRGraph, cfg: WalkIndexConfig, shards: List[int]
) -> Dict[int, tuple]:
    """Rebuilds just the named shards' slab blocks with the build's exact
    per-vertex key stream (``fold_in(PRNGKey(cfg.seed), v)`` over the
    ``partition_graph(g, cfg.num_shards)`` ranges) — byte-identical to the
    blocks the original host-loop *or* ``shard_map`` build produced, so a
    quarantined shard can be regenerated without touching the others.
    Returns ``{shard: (endpoints int32[sz, R], visited uint32[sz, R, W])}``.
    """
    gp, part = partition_graph(g, cfg.num_shards)
    walker = _ShardWalker(
        row_ptr=gp.row_ptr, col_idx=gp.col_idx, deg=gp.out_deg, n=gp.n,
        shard_size=part.shard_size, cfg=cfg,
        block_size=segment_mask_block_size(g.n),
    )
    key = jax.random.PRNGKey(cfg.seed)
    out = {}
    for s in shards:
        ep, mk = walker(jnp.int32(part.bounds(s)[0]), key)
        out[s] = (np.asarray(ep), np.asarray(mk).astype(np.uint32))
    return out


def load_or_repair_walk_index(
    directory: str,
    g: CSRGraph,
    cfg: WalkIndexConfig,
    step: Optional[int] = None,
    reassemble: bool = True,
) -> Union[WalkIndex, ShardedWalkIndex]:
    """Like :func:`load_walk_index`, but self-healing for the per-shard
    layout: a corrupt, torn, or missing shard checkpoint is quarantined
    (``quarantine.shard_<s>`` — kept for forensics, invisible to loaders)
    and its slab block rebuilt via :func:`rebuild_shard_blocks` with the
    original build's key stream, then persisted and served. Only the
    broken shards are rebuilt; healthy blocks are never re-walked.

    The monolithic (dense) layout has no sub-unit to repair — corruption
    there propagates as :class:`~repro.checkpoint.CheckpointCorruptError`
    and the caller rebuilds the whole index.
    """
    if not list_shard_dirs(directory):
        return load_walk_index(directory, step, reassemble)

    trees = load_shard_checkpoints(directory, step, on_error="collect")
    good, bad = _split_shard_trees(directory, trees)
    meta = _shard_meta_consensus(directory, good, bad)
    if meta is None:
        # every shard is broken: fall back to the caller's config geometry
        meta = _ShardMeta(cfg.num_shards, g.n, cfg.segment_len, cfg.seed,
                          cfg.segments_per_vertex,
                          int(getattr(g, "epoch", 0)),
                          int(getattr(g, "mutation_offset", 0)))
    if meta.n != g.n:
        raise ValueError(
            f"walk index under {directory!r} was built for n={meta.n} but "
            f"the service graph has n={g.n}; refusing to repair across "
            f"graphs — point checkpoint_dir elsewhere or rebuild")
    if meta.graph_epoch != int(getattr(g, "epoch", 0)):
        raise ValueError(
            f"walk index under {directory!r} was built at graph epoch "
            f"{meta.graph_epoch} but the service graph is at epoch "
            f"{int(getattr(g, 'epoch', 0))}; a repair would mix epochs — "
            f"refresh the slab (repro.dynamic.refresh_walk_index) or "
            f"rebuild at the current epoch")
    missing = sorted(set(range(meta.num_shards)) - set(good))
    broken = sorted(set(bad) | set(missing))
    if not broken:
        return _assemble_sharded(good, meta, reassemble)

    build_cfg = dataclasses.replace(
        cfg, num_shards=meta.num_shards, segments_per_vertex=meta.R,
        segment_len=meta.L, seed=meta.seed)
    rebuilt = rebuild_shard_blocks(g, build_cfg, broken)
    healthy_step = step
    if healthy_step is None:
        steps = [latest_step(shard_dir(directory, s)) for s in good]
        healthy_step = next((s for s in steps if s is not None), 0)
    for s in broken:
        if os.path.isdir(shard_dir(directory, s)):
            quarantine_shard_dir(directory, s)
        ep, mk = rebuilt[s]
        save_walk_index_shard(
            directory, s, meta.num_shards, g.n, ep, meta.L,
            meta.seed, step=healthy_step, visited_blocks=mk,
            graph_epoch=meta.graph_epoch,
            mutation_offset=meta.mutation_offset)
        good[s] = {"endpoints": ep, "visited_blocks": mk}
    return _assemble_sharded(good, meta, reassemble)
