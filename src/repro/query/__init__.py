"""Walk-index query engine: FrogWild as an online serving primitive.

The public front door is :class:`repro.service.FrogWildService` — its
``topk`` / ``ppr`` methods return anytime :class:`~repro.service.
QueryHandle` futures served by this subsystem. The modules here are the
engine room (PowerWalk-style precompute + FAST-PPR-style per-query
confidence), executing on the shard runtime layer
(``distributed/runtime.py``):

* ``index.py``     — offline walk-segment index: for every vertex, ``R``
                     precomputed length-``L`` plain-walk endpoints — a
                     dense ``int32[n, R]`` slab (``WalkIndex``) or, at
                     scale, range-partitioned ``[shard_size, R]`` blocks
                     that are never concatenated on a device
                     (``ShardedWalkIndex``; built per-shard via the
                     runtime, persisted as per-shard atomic checkpoints,
                     ``load_walk_index(reassemble=False)``).
* ``engine.py``    — online stitching: a query walk of Geometric(p_T) total
                     length is composed from ``⌊τ/L⌋`` index segments plus
                     ``τ mod L`` direct steps; Theorem-1 bounds invert into
                     per-query ``(ε, δ)`` → walk-count/step plans, clamped
                     to the index's reuse-free stitch budget with the hit
                     recorded in ``epsilon_bound``.
* ``scheduler.py`` — host-side continuous batching with deadline-aware
                     admission: many concurrent top-k / personalized-
                     PageRank queries share one fixed-shape device program
                     (fixed walk slots × fixed query slots). Dense index →
                     gathered wave; sharded index → one ``shard_map`` whose
                     devices each hold a single slab block (or the
                     identical per-shard program as a host loop on one
                     device). ``submit()`` takes an optional SLO; queries
                     whose ``(t, N)`` plan cannot fit the remaining wave
                     budget are rejected or downgraded, and allocation is
                     earliest-deadline-first within each wave.
"""
from repro.query.index import (
    ShardedWalkIndex,
    WalkIndex,
    WalkIndexConfig,
    build_walk_index,
    build_walk_index_sharded,
    load_or_repair_walk_index,
    load_walk_index,
    rebuild_shard_blocks,
    save_walk_index,
    save_walk_index_shard,
    shard_walk_index,
)
from repro.query.engine import (
    QueryPlan,
    plan_query,
    query_counts,
    sample_walk_lengths,
    walk_wave,
)
from repro.query.scheduler import (
    AdmissionDecision,
    QueryPartial,
    QueryRequest,
    QueryResult,
    QueryScheduler,
    RejectReason,
    SchedulerStats,
)

__all__ = [
    "ShardedWalkIndex",
    "WalkIndex",
    "WalkIndexConfig",
    "build_walk_index",
    "build_walk_index_sharded",
    "load_or_repair_walk_index",
    "load_walk_index",
    "rebuild_shard_blocks",
    "save_walk_index",
    "save_walk_index_shard",
    "shard_walk_index",
    "QueryPlan",
    "plan_query",
    "query_counts",
    "sample_walk_lengths",
    "walk_wave",
    "AdmissionDecision",
    "QueryPartial",
    "QueryRequest",
    "QueryResult",
    "QueryScheduler",
    "RejectReason",
    "SchedulerStats",
]
