"""Walk-index query engine: FrogWild as an online serving primitive.

The batch reproduction answers one offline top-k question per
``frogwild_run``. This subsystem turns the same random-walk machinery into a
*query* primitive (PowerWalk-style):

* ``index.py``     — offline walk-segment index: for every vertex, ``R``
                     precomputed length-``L`` plain-walk endpoints stored as
                     a dense ``int32[n, R]`` slab (built shard-by-shard via
                     ``graph/partition.py``, persisted through
                     ``checkpoint/``).
* ``engine.py``    — online stitching: a query walk of Geometric(p_T) total
                     length is composed from ``⌊τ/L⌋`` index segments plus
                     ``τ mod L`` direct steps; Theorem-1 bounds invert into
                     per-query ``(ε, δ)`` → walk-count/step plans.
* ``scheduler.py`` — host-side continuous batching: many concurrent top-k /
                     personalized-PageRank queries share one fixed-shape
                     device program (fixed walk slots × fixed query slots,
                     the ``serving/scheduler.py`` design).
"""
from repro.query.index import (
    WalkIndex,
    WalkIndexConfig,
    build_walk_index,
    build_walk_index_sharded,
    load_walk_index,
    save_walk_index,
    save_walk_index_shard,
)
from repro.query.engine import (
    QueryPlan,
    plan_query,
    query_counts,
    sample_walk_lengths,
    walk_wave,
)
from repro.query.scheduler import QueryRequest, QueryResult, QueryScheduler

__all__ = [
    "WalkIndex",
    "WalkIndexConfig",
    "build_walk_index",
    "build_walk_index_sharded",
    "load_walk_index",
    "save_walk_index",
    "save_walk_index_shard",
    "QueryPlan",
    "plan_query",
    "query_counts",
    "sample_walk_lengths",
    "walk_wave",
    "QueryRequest",
    "QueryResult",
    "QueryScheduler",
]
