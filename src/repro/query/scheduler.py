"""Host-side continuous-batching query scheduler (fixed-slot design) with
sharded-slab serving and deadline-aware admission.

The device program is one fixed shape — ``max_walks`` walk slots ×
``max_queries`` query slots — and scheduling is pure host logic, exactly the
``serving/scheduler.py`` contract. Each wave:

  admit     queued queries claim free query slots, earliest deadline first;
  allocate  walk slots are split fairly among active queries (equal shares),
            with shares and leftovers handed out in earliest-deadline-first
            order — continuous batching, not generational: a query spanning
            several waves keeps its slot while finished queries free theirs
            mid-flight;
  execute   one wave program advances all walks (residual steps + index
            stitching, ``query/engine.py``) and histograms endpoints into
            per-query-slot bins;
  retire    queries whose walk budget completed finalize top-k from their
            accumulated counters and release the slot.

**Execution dispatch** (the ``distributed/runtime.py`` layer): with a dense
:class:`~repro.query.index.WalkIndex` the wave is the single-device gathered
program (whole slab resident). With a :class:`~repro.query.index.
ShardedWalkIndex` the slab is *never reassembled*: on a mesh the wave runs
as one ``shard_map`` over the runtime's ``"vertex"`` axis — device ``s``
holds only its ``[shard_size, R]`` slab block, each stitch round routes
every walk to the shard owning its current vertex by endpoint range
(masked local gather), per-shard partial results are reduced with ``psum``,
and the tally lands in shard-local bins (``out_specs=P(axis)``). On a
single device the identical per-shard program runs as the runtime's host
loop, one block resident at a time. All three paths draw from the same key
stream, so with the same slab content they produce byte-identical answers
(tests assert it).

**Admission** is deadline-aware: ``QueryRequest.slo_s`` declares a latency
SLO, and ``submit()`` checks the Theorem-1 ``(t, N)`` plan against the
remaining wave budget (measured wave time × waves needed at full machine
allocation — the FAST-PPR-style per-query budget). An infeasible query is
rejected up front, or — with ``allow_downgrade`` — its walk count is
clamped to what fits and the weakened guarantee is *recorded* in
``QueryPlan.epsilon_bound`` (never a silent miss). Plans are also clamped
to the index's reuse-free stitch budget (``plan_query(segments_per_vertex,
segment_len)``), so an undersized index degrades to an honest, recorded
``epsilon_bound`` instead of a silent statistical bias.

Different queries in one wave may have different planned truncations ``t``
(per-walk ``t_cap``) and different kinds (global top-k draws uniform starts,
personalized PageRank pins the start vertex) — the program shape never
changes, so XLA compiles exactly once per scheduler.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.runtime import ShardRuntime
from repro.graph.csr import CSRGraph
from repro.kernels import ops
from repro.query.engine import (QueryPlan, _plain_steps, plan_query,
                                sample_walk_lengths)
from repro.query.index import ShardedWalkIndex, WalkIndex


@dataclasses.dataclass
class QueryRequest:
    rid: int
    kind: str = "topk"               # "topk" | "ppr"
    k: int = 10
    source: int = 0                  # PPR start vertex (ignored for topk)
    epsilon: float = 0.3
    delta: float = 0.1
    num_walks: Optional[int] = None  # override the (ε, δ) plan's walk count
    slo_s: Optional[float] = None    # latency SLO (deadline = submit + slo_s)
    allow_downgrade: bool = False    # shrink the plan to fit the SLO budget
    t_submit: Optional[float] = None # stamped by QueryScheduler.submit()


@dataclasses.dataclass(frozen=True)
class AdmissionDecision:
    """What the admission controller did with a ``submit()``.

    ``admitted=False`` means the request was dropped at the door (its
    Theorem-1 plan cannot fit the remaining wave budget before the
    deadline); ``downgraded=True`` means it was admitted with a clamped
    walk count whose weaker guarantee is recorded in
    ``plan.epsilon_bound``.
    """

    rid: int
    admitted: bool
    reason: str = ""
    downgraded: bool = False
    plan: Optional[QueryPlan] = None
    num_walks: int = 0


@dataclasses.dataclass
class QueryResult:
    rid: int
    kind: str
    vertices: np.ndarray             # int64[k] — estimated top-k
    scores: np.ndarray               # f64[k]  — π̂ / PPR estimates
    num_walks: int
    num_steps: int
    waves: int                       # device waves this query spanned
    latency_s: float
    epsilon_bound: float = 0.0       # the ε Theorem 1 certifies for (t, N)
    downgraded: bool = False         # admission shrank the plan to fit SLO
    met_slo: Optional[bool] = None   # None when no SLO was requested


@dataclasses.dataclass
class _Queued:
    req: QueryRequest
    plan: QueryPlan
    walks: int
    deadline: float                  # math.inf when no SLO
    downgraded: bool


@dataclasses.dataclass
class _Active:
    req: QueryRequest
    plan: QueryPlan
    remaining: int
    total_walks: int
    counts: np.ndarray               # int64[n] accumulator
    waves: int
    t_submit: float
    deadline: float
    downgraded: bool


class QueryScheduler:
    def __init__(
        self,
        g: CSRGraph,
        index: Union[WalkIndex, ShardedWalkIndex],
        max_walks: int = 8192,
        max_queries: int = 8,
        max_steps: int = 32,
        p_T: float = 0.15,
        impl: str = "xla",
        tally_impl: str = "ref",
        seed: int = 0,
        runtime: Optional[ShardRuntime] = None,
        wave_time_estimate_s: Optional[float] = None,
    ):
        self.g = g
        self.index = index
        self.max_walks = max_walks
        self.max_queries = max_queries
        self.max_steps = max_steps
        self.p_T = p_T
        self.impl = impl
        self.tally_impl = tally_impl
        self.queue: List[_Queued] = []
        self.active: Dict[int, _Active] = {}
        self.finished: List[QueryResult] = []
        self.rejected: List[AdmissionDecision] = []
        self._key = jax.random.PRNGKey(seed)
        self._wave_time = wave_time_estimate_s   # EMA of measured wave s
        self._waves_run = 0
        if isinstance(index, ShardedWalkIndex):
            self.runtime = (runtime if runtime is not None
                            else ShardRuntime.acquire(index.num_shards))
            if self.runtime.num_shards != index.num_shards:
                raise ValueError(
                    f"runtime has {self.runtime.num_shards} shards, index "
                    f"has {index.num_shards}")
            if self.runtime.is_mesh:
                self._wave = self._build_mesh_wave()
            else:
                self._wave = self._build_loop_wave()
        else:
            self.runtime = runtime
            self._wave = self._build_gathered_wave()

    # --- device programs (each compiled once) ----------------------------

    @property
    def _q_max(self) -> int:
        return self.max_steps // self.index.segment_len

    def _wave_prep(self, start, uniform, t_cap, key):
        """Shared wave prologue: starts, lengths, residual steps, slot
        offsets — one definition so the gathered, mesh, and host-loop waves
        consume the *same* key stream and agree byte-for-byte."""
        g, W = self.g, self.max_walks
        L = self.index.segment_len
        k_start, k_tau, k_walk = jax.random.split(key, 3)
        pos0 = jnp.where(
            uniform,
            jax.random.randint(k_start, (W,), 0, g.n, dtype=jnp.int32),
            start,
        )
        tau = sample_walk_lengths(k_tau, W, self.p_T, t_cap)
        k_res, k_slot = jax.random.split(k_walk)
        q = tau // L
        pos = _plain_steps(g.row_ptr, g.col_idx, g.out_deg, pos0, tau % L,
                           k_res, L)
        s0 = jax.random.randint(k_slot, pos.shape, 0, 1 << 30, jnp.int32)
        return pos, q, s0

    def _build_gathered_wave(self):
        """Single-device wave against the dense slab.

        Structurally the one-shard case of the sharded waves: the same
        :meth:`_wave_prep` prologue and :meth:`_stitch_rounds` loop, with
        the whole slab as the (only) shard's block — which is what makes
        the byte-identical gathered-vs-sharded contract hold by
        construction rather than by parallel-edit discipline.
        """
        index = self.index
        n, Q = self.g.n, self.max_queries
        R, impl = index.segments_per_vertex, self.impl
        endpoints_flat = index.endpoints.reshape(-1)

        def wave(start, uniform, qid, t_cap, key):
            pos, q, s0 = self._wave_prep(start, uniform, t_cap, key)

            def round_fn(pos, j):
                if impl == "xla":
                    return jnp.take(endpoints_flat,
                                    pos * R + (s0 + j) % R, axis=0)
                # fused stitch kernel; its per-round tally is discarded —
                # the wave tallies once over final positions below.
                nxt, _ = ops.stitch_step(
                    pos, (q == j).astype(jnp.int32), s0 + j,
                    index.endpoints, n, impl=impl)
                return nxt

            pos = self._stitch_rounds(pos, q, round_fn)
            # one histogram for the whole wave: vertex id offset by the
            # walk's query slot; row Q is the idle-slot discard bin.
            # ``tally_impl``: "ref" (XLA scatter-add — fastest on CPU) or
            # "sort" (segment counts — the TPU-friendly scatter-free path).
            counts = ops.frog_count(pos + qid * n, (Q + 1) * n,
                                    impl=self.tally_impl)
            return counts.reshape(Q + 1, n)[:Q]

        fn = jax.jit(wave)
        return lambda *args: np.asarray(fn(*args))

    def _shard_round(self, block_flat, base, pos, q, s0, j):
        """One stitch round against one shard's slab block: owned walks
        gather their next endpoint, everyone else contributes the additive
        identity — results sum across shards (psum / host sum)."""
        R = self.index.segments_per_vertex
        sz = self.index.shard_size
        if self.impl == "xla":
            slot = (s0 + j) % R
            local = pos - base
            mine = (local >= 0) & (local < sz)
            li = jnp.clip(local, 0, sz - 1)
            nxt = jnp.take(block_flat, li * R + slot, axis=0)
            return jnp.where(mine & (j < q), nxt, 0)
        # fused local-index stitch kernel ("pallas" | "ref"): same masked
        # gather + shard-local tally in one pass; the per-round tally is
        # discarded here (the wave tallies once over final positions).
        nxt, _ = ops.stitch_step_local(
            pos, (q == j).astype(jnp.int32), s0 + j,
            block_flat.reshape(sz, R), base, impl=self.impl)
        return jnp.where(j < q, nxt, 0)

    def _shard_tally(self, pos, qid, base):
        """Shard-local per-query-slot histogram: walks whose final vertex
        this shard owns land in its ``[Q, shard_size]`` bins; the rest
        (other shards' walks + idle slots via ``qid == Q``) are discarded."""
        Q = self.max_queries
        sz = self.index.shard_size
        local = pos - base
        mine = (local >= 0) & (local < sz)
        bins = jnp.where(mine, qid * sz + jnp.clip(local, 0, sz - 1),
                         (Q + 1) * sz)
        counts = ops.frog_count(bins, (Q + 1) * sz + 1, impl=self.tally_impl)
        return counts[: (Q + 1) * sz].reshape(Q + 1, sz)[:Q]

    def _stitch_rounds(self, pos, q, round_fn):
        """Applies ``q_max`` stitch rounds where ``round_fn(pos, j)`` sums
        per-shard contributions; stopped walks (``j ≥ q``) keep their
        position. Shared by the mesh and host-loop waves."""
        for j in range(self._q_max):
            nxt = round_fn(pos, j)
            pos = jnp.where(j < q, nxt, pos)
        return pos

    def _build_mesh_wave(self):
        """Sharded wave: one ``shard_map`` over the runtime's vertex axis.

        Device ``s`` holds only slab block ``s`` (``in_specs=P(axis)``) and
        its ``[Q, shard_size]`` tally rows (``out_specs=P(axis)``); walk
        state is replicated and advanced identically on every device, with
        the per-round gather contribution reduced by ``psum``.
        """
        rt, index = self.runtime, self.index
        Q = self.max_queries
        sz = index.shard_size
        ax = rt.axis_name

        def body(blocks, start, uniform, qid, t_cap, key_data):
            block_flat = blocks[0].reshape(-1)
            base = jax.lax.axis_index(ax) * sz
            key = jax.random.wrap_key_data(key_data, impl="threefry2x32")
            pos, q, s0 = self._wave_prep(start, uniform, t_cap, key)

            def round_fn(pos, j):
                contrib = self._shard_round(block_flat, base, pos, q, s0, j)
                # every walk is owned by exactly one shard; stopped walks
                # contribute 0 everywhere and are restored by the caller.
                return jax.lax.psum(contrib, ax)

            pos = self._stitch_rounds(pos, q, round_fn)
            return self._shard_tally(pos, qid, base)[None]

        # check_vma=False: the fused stitch backends lower through
        # pallas_call (no replication rule), and the body mixes replicated
        # walk state with per-shard slab blocks by construction.
        fn = rt.sharded_call(body, num_sharded=1, num_replicated=5,
                             check_vma=False)
        # kept as an attribute so tests can assert the per-device placement
        # (each device holds exactly one [shard_size, R] block — 4nR/S
        # bytes of slab, never the whole thing).
        self._placed_blocks = blocks = rt.place_sharded(
            jnp.asarray(self.index.blocks))

        def wave(start, uniform, qid, t_cap, key):
            out = np.asarray(fn(blocks, start, uniform, qid, t_cap,
                                ShardRuntime.key_data(key)))  # [S, Q, sz]
            return out.transpose(1, 0, 2).reshape(Q, -1)[:, : self.g.n]

        return wave

    def _build_loop_wave(self):
        """Sharded wave on a single device: the runtime's host-loop
        dispatch of the identical per-shard program — one ``[shard_size,
        R]`` block resident per call, cross-shard sums on the host."""
        rt, index = self.runtime, self.index
        Q = self.max_queries
        sz = index.shard_size

        prep = jax.jit(lambda start, uniform, t_cap, key:
                       self._wave_prep(start, uniform, t_cap, key))
        round_s = jax.jit(self._shard_round)
        tally_s = jax.jit(self._shard_tally)
        blocks = [jnp.asarray(index.blocks[s].reshape(-1))
                  for s in range(rt.num_shards)]

        def wave(start, uniform, qid, t_cap, key):
            pos, q, s0 = prep(start, uniform, t_cap, key)

            def round_fn(pos, j):
                contribs = rt.map_shards(
                    lambda s: round_s(blocks[s], jnp.int32(s * sz),
                                      pos, q, s0, jnp.int32(j)))
                return sum(contribs)

            pos = self._stitch_rounds(pos, q, round_fn)
            out = np.stack(rt.map_shards(
                lambda s: np.asarray(tally_s(pos, qid, jnp.int32(s * sz)))))
            return out.transpose(1, 0, 2).reshape(Q, -1)[:, : self.g.n]

        return wave

    # --- admission (deadline-aware) --------------------------------------

    def submit(self, req: QueryRequest) -> AdmissionDecision:
        """Validates, plans, and admission-checks a request.

        Returns the :class:`AdmissionDecision`; rejected requests are
        recorded in ``self.rejected`` and never enter the queue. The
        latency clock starts here, so queue wait counts toward both
        ``latency_s`` and the SLO.
        """
        if req.num_walks is not None and req.num_walks <= 0:
            raise ValueError(
                f"request {req.rid}: num_walks must be positive, got "
                f"{req.num_walks}")
        if req.kind == "ppr" and not (0 <= req.source < self.g.n):
            raise ValueError(
                f"request {req.rid}: ppr source {req.source} outside "
                f"[0, {self.g.n})")
        if req.kind not in ("topk", "ppr"):
            raise ValueError(f"request {req.rid}: unknown kind {req.kind!r}")
        if req.slo_s is not None and req.slo_s <= 0:
            raise ValueError(
                f"request {req.rid}: slo_s must be positive, got {req.slo_s}")
        if req.t_submit is None:
            req.t_submit = time.perf_counter()

        # the plan is clamped to the index's reuse-free stitch budget — an
        # undersized index yields a recorded epsilon_bound, not a bias.
        plan = plan_query(
            req.k, req.epsilon, req.delta, p_T=self.p_T,
            max_steps=self.max_steps,
            segments_per_vertex=self.index.segments_per_vertex,
            segment_len=self.index.segment_len)
        walks = req.num_walks if req.num_walks is not None else plan.num_walks
        downgraded = False

        if req.slo_s is not None and self._wave_time is not None:
            # Remaining wave budget under the SLO, assuming best-case (full
            # machine) allocation — an optimistic bound, so a rejection
            # here is certain to be correct.
            feasible = int(req.slo_s / self._wave_time)
            needed = -(-walks // self.max_walks)
            if feasible < 1:
                return self._reject(
                    req, plan,
                    f"SLO {req.slo_s:.3g}s is shorter than one wave "
                    f"(≈{self._wave_time:.3g}s)")
            if needed > feasible:
                if not req.allow_downgrade:
                    return self._reject(
                        req, plan,
                        f"plan needs {needed} waves, only {feasible} fit "
                        f"the {req.slo_s:.3g}s SLO")
                walks = feasible * self.max_walks
                plan = plan_query(
                    req.k, req.epsilon, req.delta, p_T=self.p_T,
                    max_walks=walks, max_steps=self.max_steps,
                    segments_per_vertex=self.index.segments_per_vertex,
                    segment_len=self.index.segment_len)
                walks = min(walks, plan.num_walks if req.num_walks is None
                            else req.num_walks)
                walks = min(walks, feasible * self.max_walks)
                downgraded = True

        deadline = (math.inf if req.slo_s is None
                    else req.t_submit + req.slo_s)
        self.queue.append(_Queued(req=req, plan=plan, walks=walks,
                                  deadline=deadline, downgraded=downgraded))
        return AdmissionDecision(rid=req.rid, admitted=True,
                                 downgraded=downgraded, plan=plan,
                                 num_walks=walks)

    def _reject(self, req: QueryRequest, plan: QueryPlan,
                reason: str) -> AdmissionDecision:
        decision = AdmissionDecision(rid=req.rid, admitted=False,
                                     reason=reason, plan=plan)
        self.rejected.append(decision)
        return decision

    # --- host scheduling --------------------------------------------------

    def _admit(self) -> None:
        """Queued queries claim free slots, earliest deadline first."""
        free = [s for s in range(self.max_queries) if s not in self.active]
        self.queue.sort(key=lambda e: (e.deadline, e.req.t_submit))
        while self.queue and free:
            e = self.queue.pop(0)
            self.active[free.pop(0)] = _Active(
                req=e.req, plan=e.plan, remaining=e.walks,
                total_walks=e.walks, counts=np.zeros(self.g.n, np.int64),
                waves=0, t_submit=e.req.t_submit, deadline=e.deadline,
                downgraded=e.downgraded,
            )

    def _edf_order(self) -> List[int]:
        return sorted(self.active,
                      key=lambda s: (self.active[s].deadline, s))

    def _allocate(self) -> Dict[int, int]:
        """Walk-slot split: equal shares, handed out (and topped up from
        the leftovers) in earliest-deadline-first order — a tight-deadline
        query drains its budget first without starving the rest below
        their fair share."""
        slots = {}
        budget = self.max_walks
        order = self._edf_order()
        share = max(1, budget // max(1, len(order)))
        for s in order:
            take = min(self.active[s].remaining, share, budget)
            slots[s] = take
            budget -= take
        for s in order:                      # leftovers, EDF-greedy
            if budget == 0:
                break
            extra = min(self.active[s].remaining - slots[s], budget)
            slots[s] += extra
            budget -= extra
        return {s: w for s, w in slots.items() if w > 0}

    def step_wave(self) -> bool:
        """Runs one device wave; returns False when nothing is in flight."""
        self._admit()
        if not self.active:
            return False
        alloc = self._allocate()
        W, Q = self.max_walks, self.max_queries
        start = np.zeros(W, np.int32)
        uniform = np.zeros(W, bool)
        qid = np.full(W, Q, np.int32)        # default: discard bin
        t_cap = np.zeros(W, np.int32)
        cursor = 0
        for s, w in alloc.items():
            a = self.active[s]
            sl = slice(cursor, cursor + w)
            qid[sl] = s
            t_cap[sl] = a.plan.num_steps
            if a.req.kind == "ppr":
                start[sl] = a.req.source
            else:
                uniform[sl] = True
            cursor += w

        self._key, k_wave = jax.random.split(self._key)
        t0 = time.perf_counter()
        counts = self._wave(
            jnp.asarray(start), jnp.asarray(uniform), jnp.asarray(qid),
            jnp.asarray(t_cap), k_wave)
        now = time.perf_counter()
        # EMA of measured wave time — feeds the admission budget check. The
        # scheduler's very first wave includes jit compilation (seconds vs
        # steady-state ms) and would poison the estimate into rejecting
        # feasible SLOs, so it is never folded in.
        self._waves_run += 1
        if self._waves_run > 1:
            dt = now - t0
            self._wave_time = (dt if self._wave_time is None
                               else 0.5 * self._wave_time + 0.5 * dt)

        for s, w in alloc.items():
            a = self.active[s]
            a.counts += counts[s]
            a.remaining -= w
            a.waves += 1
            if a.remaining == 0:
                self.finished.append(self._finalize(a, now))
                del self.active[s]
        return True

    def _finalize(self, a: _Active, now: float) -> QueryResult:
        scores = a.counts / float(a.total_walks)
        k = min(a.req.k, self.g.n)
        top = np.argsort(-scores, kind="stable")[:k]
        latency = now - a.t_submit
        return QueryResult(
            rid=a.req.rid, kind=a.req.kind, vertices=top,
            scores=scores[top], num_walks=a.total_walks,
            num_steps=a.plan.num_steps, waves=a.waves,
            latency_s=latency,
            epsilon_bound=a.plan.epsilon_bound,
            downgraded=a.downgraded,
            met_slo=(None if a.req.slo_s is None
                     else bool(latency <= a.req.slo_s)),
        )

    def run(self) -> List[QueryResult]:
        """Drains queue + in-flight queries; returns results in finish order."""
        while self.step_wave():
            pass
        return self.finished
