"""Host-side continuous-batching query scheduler (fixed-slot design).

The device program is one fixed shape — ``max_walks`` walk slots ×
``max_queries`` query slots — and scheduling is pure host logic, exactly the
``serving/scheduler.py`` contract. Each wave:

  admit     queued queries claim free query slots;
  allocate  walk slots are split fairly among active queries (equal shares,
            leftovers greedily), so a million-walk query cannot starve a
            cheap PPR probe — continuous batching, not generational: a query
            spanning several waves keeps its slot while finished queries
            free theirs mid-flight;
  execute   one jitted wave program advances all walks (residual steps +
            index stitching, ``query/engine.py``) and histograms endpoints
            into per-query-slot bins with a single sort-based
            ``frog_count`` over ``(Q + 1) · n`` bins (row Q discards idle
            slots);
  retire    queries whose walk budget completed finalize top-k from their
            accumulated counters and release the slot.

Different queries in one wave may have different planned truncations ``t``
(per-walk ``t_cap``) and different kinds (global top-k draws uniform starts,
personalized PageRank pins the start vertex) — the program shape never
changes, so XLA compiles exactly once per scheduler.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.csr import CSRGraph
from repro.kernels import ops
from repro.query.engine import (check_segment_budget, plan_query,
                                sample_walk_lengths, walk_wave)
from repro.query.index import WalkIndex


@dataclasses.dataclass
class QueryRequest:
    rid: int
    kind: str = "topk"               # "topk" | "ppr"
    k: int = 10
    source: int = 0                  # PPR start vertex (ignored for topk)
    epsilon: float = 0.3
    delta: float = 0.1
    num_walks: Optional[int] = None  # override the (ε, δ) plan's walk count
    t_submit: Optional[float] = None # stamped by QueryScheduler.submit()


@dataclasses.dataclass
class QueryResult:
    rid: int
    kind: str
    vertices: np.ndarray             # int64[k] — estimated top-k
    scores: np.ndarray               # f64[k]  — π̂ / PPR estimates
    num_walks: int
    num_steps: int
    waves: int                       # device waves this query spanned
    latency_s: float


@dataclasses.dataclass
class _Active:
    req: QueryRequest
    num_steps: int
    remaining: int
    total_walks: int
    counts: np.ndarray               # int64[n] accumulator
    waves: int
    t_submit: float


class QueryScheduler:
    def __init__(
        self,
        g: CSRGraph,
        index: WalkIndex,
        max_walks: int = 8192,
        max_queries: int = 8,
        max_steps: int = 32,
        p_T: float = 0.15,
        impl: str = "xla",
        tally_impl: str = "ref",
        seed: int = 0,
    ):
        self.g = g
        self.index = index
        self.max_walks = max_walks
        self.max_queries = max_queries
        self.max_steps = max_steps
        self.p_T = p_T
        self.impl = impl
        self.tally_impl = tally_impl
        check_segment_budget(index.segments_per_vertex,
                             max_steps // index.segment_len)
        self.queue: List[QueryRequest] = []
        self.active: Dict[int, _Active] = {}
        self.finished: List[QueryResult] = []
        self._key = jax.random.PRNGKey(seed)
        self._wave_fn = self._build_wave_fn()

    # --- device program (compiled once) ---------------------------------

    def _build_wave_fn(self):
        g, index = self.g, self.index
        n, W, Q = g.n, self.max_walks, self.max_queries
        L = index.segment_len
        q_max = self.max_steps // L
        p_T, impl = self.p_T, self.impl
        row_ptr, col_idx, deg = g.row_ptr, g.col_idx, g.out_deg
        endpoints = index.endpoints

        def wave(start, uniform, qid, t_cap, key):
            k_start, k_tau, k_walk = jax.random.split(key, 3)
            pos0 = jnp.where(
                uniform,
                jax.random.randint(k_start, (W,), 0, n, dtype=jnp.int32),
                start,
            )
            tau = sample_walk_lengths(k_tau, W, p_T, t_cap)
            pos, _ = walk_wave(
                row_ptr, col_idx, deg, endpoints, pos0, tau, k_walk,
                L, q_max, impl=impl,
            )
            # one histogram for the whole wave: vertex id offset by the
            # walk's query slot; row Q is the idle-slot discard bin.
            # ``tally_impl``: "ref" (XLA scatter-add — fastest on CPU) or
            # "sort" (segment counts — the TPU-friendly scatter-free path).
            counts = ops.frog_count(pos + qid * n, (Q + 1) * n,
                                    impl=self.tally_impl)
            return counts.reshape(Q + 1, n)[:Q]

        return jax.jit(wave)

    # --- host scheduling --------------------------------------------------

    def submit(self, req: QueryRequest) -> None:
        if req.num_walks is not None and req.num_walks <= 0:
            raise ValueError(
                f"request {req.rid}: num_walks must be positive, got "
                f"{req.num_walks}")
        if req.kind == "ppr" and not (0 <= req.source < self.g.n):
            raise ValueError(
                f"request {req.rid}: ppr source {req.source} outside "
                f"[0, {self.g.n})")
        if req.kind not in ("topk", "ppr"):
            raise ValueError(f"request {req.rid}: unknown kind {req.kind!r}")
        # latency clock starts here, so queue wait counts toward latency_s
        if req.t_submit is None:
            req.t_submit = time.perf_counter()
        self.queue.append(req)

    def _admit(self) -> None:
        free = [s for s in range(self.max_queries) if s not in self.active]
        while self.queue and free:
            req = self.queue.pop(0)
            plan = plan_query(req.k, req.epsilon, req.delta, p_T=self.p_T,
                              max_steps=self.max_steps)
            walks = req.num_walks if req.num_walks is not None else plan.num_walks
            self.active[free.pop(0)] = _Active(
                req=req, num_steps=plan.num_steps, remaining=walks,
                total_walks=walks, counts=np.zeros(self.g.n, np.int64),
                waves=0, t_submit=req.t_submit,
            )

    def _allocate(self) -> Dict[int, int]:
        """Fair-share walk-slot split: {query slot: walks this wave}."""
        slots = {}
        budget = self.max_walks
        order = sorted(self.active)
        share = max(1, budget // max(1, len(order)))
        for s in order:
            take = min(self.active[s].remaining, share, budget)
            slots[s] = take
            budget -= take
        for s in order:                      # leftovers, greedy
            if budget == 0:
                break
            extra = min(self.active[s].remaining - slots[s], budget)
            slots[s] += extra
            budget -= extra
        return {s: w for s, w in slots.items() if w > 0}

    def step_wave(self) -> bool:
        """Runs one device wave; returns False when nothing is in flight."""
        self._admit()
        if not self.active:
            return False
        alloc = self._allocate()
        W, Q = self.max_walks, self.max_queries
        start = np.zeros(W, np.int32)
        uniform = np.zeros(W, bool)
        qid = np.full(W, Q, np.int32)        # default: discard bin
        t_cap = np.zeros(W, np.int32)
        cursor = 0
        for s, w in alloc.items():
            a = self.active[s]
            sl = slice(cursor, cursor + w)
            qid[sl] = s
            t_cap[sl] = a.num_steps
            if a.req.kind == "ppr":
                start[sl] = a.req.source
            else:
                uniform[sl] = True
            cursor += w

        self._key, k_wave = jax.random.split(self._key)
        counts = np.asarray(self._wave_fn(
            jnp.asarray(start), jnp.asarray(uniform), jnp.asarray(qid),
            jnp.asarray(t_cap), k_wave))

        now = time.perf_counter()
        for s, w in alloc.items():
            a = self.active[s]
            a.counts += counts[s]
            a.remaining -= w
            a.waves += 1
            if a.remaining == 0:
                self.finished.append(self._finalize(a, now))
                del self.active[s]
        return True

    def _finalize(self, a: _Active, now: float) -> QueryResult:
        scores = a.counts / float(a.total_walks)
        k = min(a.req.k, self.g.n)
        top = np.argsort(-scores, kind="stable")[:k]
        return QueryResult(
            rid=a.req.rid, kind=a.req.kind, vertices=top,
            scores=scores[top], num_walks=a.total_walks,
            num_steps=a.num_steps, waves=a.waves,
            latency_s=now - a.t_submit,
        )

    def run(self) -> List[QueryResult]:
        """Drains queue + in-flight queries; returns results in finish order."""
        while self.step_wave():
            pass
        return self.finished
