"""Host-side continuous-batching query scheduler (fixed-slot design) with
sharded-slab serving and deadline-aware admission.

The device program is one fixed shape — ``max_walks`` walk slots ×
``max_queries`` query slots — and scheduling is pure host logic, exactly the
``serving/scheduler.py`` contract. Each wave:

  admit     queued queries claim free query slots, earliest deadline first;
  allocate  walk slots are split fairly among active queries (equal shares),
            with shares and leftovers handed out in earliest-deadline-first
            order — continuous batching, not generational: a query spanning
            several waves keeps its slot while finished queries free theirs
            mid-flight;
  execute   one wave program advances all walks (residual steps + index
            stitching, ``query/engine.py``) and histograms endpoints into
            per-query-slot bins;
  retire    queries whose walk budget completed finalize top-k from their
            accumulated counters and release the slot.

**Execution dispatch** (the ``distributed/runtime.py`` layer): with a dense
:class:`~repro.query.index.WalkIndex` the wave is the single-device gathered
program (whole slab resident). With a :class:`~repro.query.index.
ShardedWalkIndex` the slab is *never reassembled*: on a mesh the wave runs
as one ``shard_map`` over the runtime's ``"vertex"`` axis — device ``s``
holds only its ``[shard_size, R]`` slab block, each stitch round routes
every walk to the shard owning its current vertex by endpoint range
(masked local gather), per-shard partial results are reduced with ``psum``,
and the tally lands in shard-local bins (``out_specs=P(axis)``). On a
single device the identical per-shard program runs as the runtime's host
loop, one block resident at a time. All three paths draw from the same key
stream, so with the same slab content they produce byte-identical answers
(tests assert it).

**Admission** is deadline- and queue-depth-aware: ``QueryRequest.slo_s``
declares a latency SLO, and ``submit()`` checks the Theorem-1 ``(t, N)``
plan against the remaining wave budget (measured wave time × waves at full
machine throughput — the FAST-PPR-style per-query budget), charged for the
already-admitted walk demand that outranks the request under EDF
(earlier-or-equal deadlines; no-SLO work is never charged). An infeasible
query is
rejected up front, or — with ``allow_downgrade`` — its walk count is
clamped to what fits and the weakened guarantee is *recorded* in
``QueryPlan.epsilon_bound`` (never a silent miss). Plans are also clamped
to the index's reuse-free stitch budget (``plan_query(segments_per_vertex,
segment_len)``), so an undersized index degrades to an honest, recorded
``epsilon_bound`` instead of a silent statistical bias.

Different queries in one wave may have different planned truncations ``t``
(per-walk ``t_cap``) and different kinds (global top-k draws uniform starts,
personalized PageRank pins the start vertex) — the program shape never
changes, so XLA compiles exactly once per scheduler.

**Anytime serving** (PR 5): per-query tallies track the walks *executed*
so far, and :meth:`QueryScheduler.partial` exposes the estimate together
with the ε Theorem 1 certifies for those walks — monotone non-increasing
wave over wave. A request with ``early_stop`` finishes as soon as that
bound reaches its requested ``epsilon``, even with walk budget left. The
public way to drive all of this is the :class:`repro.service.QueryHandle`
future (``submit()`` / ``run()`` here are deprecation shims kept for the
legacy callers).

**Degradation contract** (PR 6). FrogWild tolerates missing contributions
by design — partial synchronization drops mirror updates and Theorem 1
prices the loss — and the wave supervisor extends that lens to serving
faults:

* a **transient** fault or a wave exceeding ``wave_timeout_s`` is retried
  (bounded by ``max_retries``, exponential backoff + jitter) from the
  *same* wave key, so a successful retry is byte-identical to an unfaulted
  wave; a mesh dispatch that keeps failing fails over once to the
  host-loop dispatch of the identical per-shard program (byte-identical
  answers — failover is principled, not best-effort);
* a **permanent** shard fault evicts the shard: subsequent stitch rounds
  mask its endpoint range (a walk needing a gather from — or a final tally
  in — a lost range is dropped), per-query scores renormalize by the walks
  that actually completed, and ``epsilon_bound`` widens to exactly the ε
  Theorem 1 certifies for those surviving walks (the early-stopping
  accounting applied to loss instead of budget). Results carry
  ``degraded`` / ``shards_lost`` / ``walks_lost`` provenance, queued SLO
  work is re-admitted against the shrunken capacity, and with zero faults
  the masked programs are bit-for-bit the unfaulted ones;
* retried / stalled / degraded waves never feed the admission wave-time
  EMA (and clean outliers are clamped), so one bad wave cannot poison
  ``wave_time_estimate_s`` into spurious SLO rejections.

Injection (:class:`~repro.distributed.faults.FaultPlan`) drives all of the
above deterministically in-process; ``WaveFailedError`` is the only way a
wave surfaces an error, and it leaves no partial tallies behind.
"""
from __future__ import annotations

import dataclasses
import enum
import math
import random
import time
from typing import Dict, List, Optional, Set, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import warn_deprecated
from repro.core import theory
from repro.distributed.faults import (FaultEvent, FaultInjector, ShardFault,
                                      WaveFailedError, WaveTimeout)
from repro.distributed.runtime import ShardRuntime, record_wave_trace
from repro.graph.csr import CSRGraph
from repro.kernels import ops
from repro.query.engine import (QueryPlan, WaveSpec, build_wave_program,
                                plan_query, wave_prep)
from repro.query.index import ShardedWalkIndex, WalkIndex

# A "clean" wave more than this factor above the EMA is clamped before the
# fold — one GC pause or page-fault storm must not trip SLO rejections.
_EMA_OUTLIER_CLAMP = 4.0


def _topk_stable(scores: np.ndarray, k: int) -> np.ndarray:
    """First ``k`` indices of ``np.argsort(-scores, kind="stable")`` without
    sorting all ``n`` scores.

    This is the per-``poll()`` hot path: every anytime :meth:`partial`
    snapshot ranks the accumulated stop counts, and the full n-element
    argsort was the serving-handle overhead. Two strategies:

    * **sparse** — serving count vectors have support bounded by the
      walks executed (≪ n in the paper's regime), so when every nonzero
      entry is positive and the support is small, a stable sort of just
      the support reproduces the full sort's head; entries outside the
      support are exact zeros, whose tie order under the full stable
      argsort is ascending index — the pad.
    * **dense** — ``np.partition`` finds the k-th largest in O(n); the
      candidate set ``scores >= kth`` is a superset of the stable top-k
      (it includes every boundary tie), and a stable descending sort of
      just the candidates reproduces the full sort's relative order.
    """
    n = scores.shape[0]
    if k >= n:
        return np.argsort(-scores, kind="stable")[:k]
    nz = np.flatnonzero(scores)
    if nz.size <= n >> 2 and (nz.size == 0 or scores[nz].min() > 0):
        top = nz[np.argsort(-scores[nz], kind="stable")][:k]
        if top.size == k:
            return top
        pad = np.setdiff1d(np.arange(min(n, k + nz.size)),
                           nz)[:k - top.size]
        return np.concatenate([top, pad])
    kth = np.partition(scores, n - k)[n - k]
    cand = np.flatnonzero(scores >= kth)
    return cand[np.argsort(-scores[cand], kind="stable")][:k]


@dataclasses.dataclass
class QueryRequest:
    rid: int
    kind: str = "topk"               # "topk" | "ppr"
    k: int = 10
    source: int = 0                  # PPR start vertex (ignored for topk)
    epsilon: float = 0.3
    delta: float = 0.1
    num_walks: Optional[int] = None  # override the (ε, δ) plan's walk count
    slo_s: Optional[float] = None    # latency SLO (deadline = submit + slo_s)
    allow_downgrade: bool = False    # shrink the plan to fit the SLO budget
    early_stop: bool = False         # finish once the anytime Theorem-1
                                     # bound reaches epsilon (QueryHandle mode)
    t_submit: Optional[float] = None # stamped by QueryScheduler.submit()


class RejectReason(str, enum.Enum):
    """Why admission refused a request — structured, so a routing layer
    (the gateway) can branch on it without string-matching ``reason``.

    * ``NONE``           — not rejected (the decision admitted the request).
    * ``INFEASIBLE_SLO`` — the SLO is shorter than a single wave: no walk
      budget could ever fit it, retrying elsewhere with the same SLO is
      pointless.
    * ``CAPACITY``       — the Theorem-1 plan (plus the EDF-charged
      backlog) needs more waves than the SLO leaves; another, less loaded
      replica may well admit it.
    * ``SHARD_LOSS``     — a post-admission re-check after shard eviction
      shrank capacity; the replica is degraded and a healthy replica
      should be preferred.
    """

    NONE = "none"
    INFEASIBLE_SLO = "infeasible_slo"
    CAPACITY = "capacity"
    SHARD_LOSS = "shard_loss"


@dataclasses.dataclass(frozen=True)
class AdmissionDecision:
    """What the admission controller did with a ``submit()``.

    ``admitted=False`` means the request was dropped at the door (its
    Theorem-1 plan cannot fit the remaining wave budget before the
    deadline) with the *kind* of refusal in ``reason_code`` (a
    :class:`RejectReason`) and the human-readable detail in ``reason``;
    ``downgraded=True`` means it was admitted with a clamped walk count
    whose weaker guarantee is recorded in ``plan.epsilon_bound``.
    """

    rid: int
    admitted: bool
    reason: str = ""
    reason_code: RejectReason = RejectReason.NONE
    downgraded: bool = False
    plan: Optional[QueryPlan] = None
    num_walks: int = 0


@dataclasses.dataclass
class QueryResult:
    rid: int
    kind: str
    vertices: np.ndarray             # int64[k] — estimated top-k
    scores: np.ndarray               # f64[k]  — π̂ / PPR estimates
    num_walks: int                   # walks actually executed (≤ budget)
    num_steps: int
    waves: int                       # device waves this query spanned
    latency_s: float
    epsilon_bound: float = 0.0       # the ε Theorem 1 certifies for (t, N)
    downgraded: bool = False         # admission shrank the plan to fit SLO
    met_slo: Optional[bool] = None   # None when no SLO was requested
    early_stopped: bool = False      # anytime bound met before the budget
    degraded: bool = False           # some walks died on evicted shards
    shards_lost: Tuple[int, ...] = ()  # shards evicted while this query ran
    walks_lost: int = 0              # allocated walks that never tallied
    epoch: int = 0                   # graph epoch this query was served on


@dataclasses.dataclass(frozen=True)
class QueryPartial:
    """Anytime snapshot of an in-flight (or finished) query.

    ``epsilon_bound`` is the ε Theorem 1 certifies for the walks tallied
    *so far* (``math.inf`` before the first wave lands); it tightens
    monotonically as waves accumulate — the anytime property the
    :class:`repro.service.QueryHandle` future exposes.
    """

    rid: int
    kind: str
    k: int
    vertices: np.ndarray             # int64[≤k] — current top-k estimate
    scores: np.ndarray               # f64[≤k]
    walks_done: int
    waves: int
    epsilon_bound: float
    done: bool
    degraded: bool = False
    shards_lost: Tuple[int, ...] = ()
    walks_lost: int = 0


@dataclasses.dataclass(frozen=True)
class SchedulerStats:
    """One structured snapshot of the scheduler's serving/admission state.

    ``backlog_walks`` is the scheduler's own admission accounting — the
    queued plus in-flight walk demand a new no-SLO request would be
    EDF-charged behind (every outstanding deadline outranks ∞). The
    gateway's replica router keys on it; everything else feeds the
    metrics/health layer.
    """

    queued: int                      # requests waiting for a query slot
    active: int                      # requests occupying a slot
    finished: int                    # results retired so far
    rejected: int                    # admission refusals so far
    cancelled: int
    backlog_walks: int               # queued + in-flight walk demand
    waves_run: int
    walks_executed: int              # walks whose tallies landed
    wave_time_ema_s: Optional[float]
    wave_occupancy: float            # allocated walk slots / capacity
    lost_shards: Tuple[int, ...]
    max_walks: int
    max_queries: int
    # heartbeat (PR 8): when the last wave retired and how long it took —
    # the pool supervisor's stall-detection + health-scoring inputs.
    t_last_wave: Optional[float] = None   # time.monotonic() of last wave
    last_wave_s: Optional[float] = None   # wall time of that wave
    epoch: int = 0                   # graph epoch this scheduler serves


@dataclasses.dataclass
class _Queued:
    req: QueryRequest
    plan: QueryPlan
    walks: int
    deadline: float                  # math.inf when no SLO
    downgraded: bool


@dataclasses.dataclass
class _Active:
    req: QueryRequest
    plan: QueryPlan
    remaining: int
    total_walks: int
    counts: np.ndarray               # int64[n] accumulator
    waves: int
    t_submit: float
    deadline: float
    downgraded: bool
    executed: int = 0                # walks whose tallies have landed
    lost: int = 0                    # allocated walks that died on a lost shard
    shards_lost: Tuple[int, ...] = ()  # evicted shards seen by this query


class QueryScheduler:
    def __init__(
        self,
        g: CSRGraph,
        index: Union[WalkIndex, ShardedWalkIndex],
        max_walks: int = 8192,
        max_queries: int = 8,
        max_steps: int = 32,
        p_T: float = 0.15,
        impl: str = "xla",
        tally_impl: str = "ref",
        seed: int = 0,
        runtime: Optional[ShardRuntime] = None,
        wave_time_estimate_s: Optional[float] = None,
        fault_injector: Optional[FaultInjector] = None,
        wave_timeout_s: Optional[float] = None,
        max_retries: int = 2,
        backoff_base_s: float = 0.02,
        backoff_max_s: float = 0.5,
        sharded_dispatch: str = "fused",
        donate_wave_buffers: bool = True,
        walk_buckets: Optional[Tuple[int, ...]] = None,
        query_buckets: Optional[Tuple[int, ...]] = None,
        aot_warmup: bool = False,
    ):
        if sharded_dispatch not in ("fused", "loop"):
            raise ValueError(
                f"sharded_dispatch must be 'fused' or 'loop', got "
                f"{sharded_dispatch!r}")
        self.g = g
        self.index = index
        # the epoch this scheduler serves, pinned at construction: a
        # mutation commit builds a *new* scheduler for e+1 and retires
        # this one once its pinned queries settle (two-epoch serving).
        self.epoch = int(getattr(g, "epoch", 0))
        self.max_walks = max_walks
        self.max_queries = max_queries
        self.max_steps = max_steps
        self.p_T = p_T
        self.impl = impl
        self.tally_impl = tally_impl
        self.donate_wave_buffers = donate_wave_buffers
        # AOT wave-program ladder: waves run at the smallest bucket shape
        # ≥ the allocation, so the set of compiled programs is fixed up
        # front (hyadmin-style per-batch-size wrappers) — a shifting query
        # mix re-buckets instead of retracing. The top bucket is always
        # the full (max_walks, max_queries) shape.
        self._walk_ladder = self._normalize_buckets(
            walk_buckets, max_walks, "walk_buckets",
            floor=max(1, max_walks // 8))
        self._query_ladder = self._normalize_buckets(
            query_buckets, max_queries, "query_buckets", floor=1)
        self._wave_fns: Dict[Tuple[int, int], object] = {}
        self.queue: List[_Queued] = []
        self.active: Dict[int, _Active] = {}
        self.finished: List[QueryResult] = []
        self.rejected: List[AdmissionDecision] = []
        self.cancelled: List[int] = []
        self._key = jax.random.PRNGKey(seed)
        self._wave_time = wave_time_estimate_s   # EMA of measured wave s
        self._waves_run = 0
        self._walks_allocated = 0    # walk slots handed out across all waves
        self._walks_executed = 0     # walks whose tallies actually landed
        self._t_last_wave: Optional[float] = None   # heartbeat stamp
        self._last_wave_s: Optional[float] = None   # last wave wall time
        # --- fault-tolerance state (PR 6) ---
        self._injector = fault_injector
        self.wave_timeout_s = wave_timeout_s
        self.max_retries = max_retries
        self.backoff_base_s = backoff_base_s
        self.backoff_max_s = backoff_max_s
        self.lost_shards: Set[int] = set()
        self.fault_log: List[FaultEvent] = []
        self._backoff_rng = random.Random(seed)
        self._failed_over = False
        # bool[S] eviction mask, a replicated wave operand: all-False (the
        # zero-fault case) leaves every masked program bit-identical to the
        # unmasked one. Dense slabs get a 1-wide mask that never flips.
        self._lost = np.zeros(
            index.num_shards if isinstance(index, ShardedWalkIndex) else 1,
            bool)
        self._placed_blocks = None
        if isinstance(index, ShardedWalkIndex):
            self.runtime = (runtime if runtime is not None
                            else ShardRuntime.acquire(index.num_shards))
            if self.runtime.num_shards != index.num_shards:
                raise ValueError(
                    f"runtime has {self.runtime.num_shards} shards, index "
                    f"has {index.num_shards}")
            self._S = index.num_shards
            self._sz = index.shard_size
            # stacked blocks flattened = the row-padded dense slab. Walk
            # positions are graph vertices < n ≤ S·sz, so the fused wave's
            # gathers never touch the padding rows — which is what makes
            # one program byte-identical to the per-shard host loop.
            self._slab_flat = jnp.asarray(
                index.blocks.reshape(self._S * self._sz, -1)).reshape(-1)
            if self.runtime.is_mesh:
                self.dispatch = "mesh"
                # kept as an attribute so tests can assert the per-device
                # placement (each device holds exactly one [shard_size, R]
                # block — 4nR/S bytes of slab, never the whole thing).
                self._placed_blocks = self.runtime.place_sharded(
                    jnp.asarray(index.blocks))
            else:
                self.dispatch = sharded_dispatch   # "fused" | legacy "loop"
        else:
            self.runtime = runtime
            self.dispatch = "gathered"   # the fused program at S=1
            self._S, self._sz = 1, g.n
            self._slab_flat = jnp.asarray(index.endpoints).reshape(-1)
        if aot_warmup:
            self.warm_ladder()

    # --- device programs (one per ladder bucket, compiled AOT or lazily) --

    @property
    def _q_max(self) -> int:
        return self.max_steps // self.index.segment_len

    @staticmethod
    def _normalize_buckets(buckets: Optional[Tuple[int, ...]], cap: int,
                           name: str, floor: int) -> Tuple[int, ...]:
        """Validates a user ladder (or derives the default: ``cap`` and its
        halvings down to ``floor``). The full shape ``cap`` is always a
        member — the top bucket must fit a fully-allocated wave."""
        if buckets is None:
            out = {cap}
            b = cap
            while b // 2 >= floor:
                b //= 2
                out.add(b)
            return tuple(sorted(out))
        ladder = sorted(set(int(b) for b in buckets))
        if not ladder or ladder[0] < 1 or ladder[-1] > cap:
            raise ValueError(
                f"{name} must be within [1, {cap}], got {buckets!r}")
        if ladder[-1] != cap:
            ladder.append(cap)
        return tuple(ladder)

    @staticmethod
    def _bucket(ladder: Tuple[int, ...], demand: int) -> int:
        """Smallest ladder bucket ≥ demand (the ladder top bounds demand)."""
        for b in ladder:
            if b >= demand:
                return b
        return ladder[-1]

    def _spec(self, W_b: int, Q_b: int) -> WaveSpec:
        return WaveSpec(
            n=self.g.n, R=self.index.segments_per_vertex,
            L=self.index.segment_len, q_max=self._q_max,
            S=self._S, sz=self._sz, W=W_b, Q=Q_b, p_T=self.p_T,
            impl=self.impl, tally_impl=self.tally_impl,
            donate=self.donate_wave_buffers)

    def _wave_for(self, W_b: int, Q_b: int):
        """The wave callable for one ladder bucket, built on first use and
        cached — ``wave(start, uniform, qid, t_cap, key, lost) ->
        int32[Q_b, n]`` with every operand at bucket shape."""
        fn = self._wave_fns.get((W_b, Q_b))
        if fn is None:
            if self.dispatch == "mesh":
                fn = self._build_mesh_wave(W_b, Q_b)
            elif self.dispatch == "loop":
                fn = self._build_loop_wave(W_b, Q_b)
            else:
                fn = self._build_fused_wave(W_b, Q_b)
            self._wave_fns[(W_b, Q_b)] = fn
        return fn

    def warm_ladder(self) -> int:
        """AOT-compiles the whole ladder: one dummy wave per (walk-bucket,
        query-bucket) pair, so serving never traces mid-wave — an
        admission-driven change of query mix re-buckets into a warm
        executable. Scheduler state (key stream, EMA, counters) is
        untouched. Returns the number of programs warmed."""
        key = jax.random.PRNGKey(0)   # shapes drive compilation, not bits
        count = 0
        for W_b in self._walk_ladder:
            for Q_b in self._query_ladder:
                wave = self._wave_for(W_b, Q_b)
                wave(jnp.zeros(W_b, jnp.int32), jnp.zeros(W_b, bool),
                     jnp.full(W_b, Q_b, jnp.int32),
                     jnp.zeros(W_b, jnp.int32), key,
                     jnp.asarray(self._lost))
                count += 1
        return count

    def _build_fused_wave(self, W_b: int, Q_b: int):
        """The fused single-dispatch wave (gathered and sharded host-side
        serving): prologue + ``lax.scan`` over stitch rounds + one
        histogram, compiled once per :class:`WaveSpec` in the process-wide
        :meth:`ShardRuntime.wave_cache` — replicas over the same slab
        geometry share the executable (slab and graph arrays are
        operands, not closures)."""
        prog = ShardRuntime.wave_cache().get_or_build(
            self._spec(W_b, Q_b), build_wave_program)
        g = self.g

        def wave(start, uniform, qid, t_cap, key, lost):
            return np.asarray(prog(
                self._slab_flat, g.row_ptr, g.col_idx, g.out_deg,
                start, uniform, qid, t_cap,
                ShardRuntime.key_data(key), lost))

        return wave

    def _shard_round(self, block_flat, base, pos, q, s0, j):
        """One stitch round against one shard's slab block: owned walks
        gather their next endpoint, everyone else contributes the additive
        identity — results sum across shards (psum / host sum). Fully
        traced-``j`` compatible, so it runs under the mesh wave's
        ``lax.scan`` as well as the legacy unrolled host loop."""
        R = self.index.segments_per_vertex
        sz = self.index.shard_size
        if self.impl == "xla":
            slot = (s0 + j) % R
            local = pos - base
            mine = (local >= 0) & (local < sz)
            li = jnp.clip(local, 0, sz - 1)
            nxt = jnp.take(block_flat, li * R + slot, axis=0)
            return jnp.where(mine & (j < q), nxt, 0)
        # gather-only local-index stitch kernel ("pallas" | "ref"): the
        # wave tallies once over final positions, so the per-round tally
        # is not computed at all (tally=False).
        nxt, _ = ops.stitch_step_local(
            pos, (q == j).astype(jnp.int32), s0 + j,
            block_flat.reshape(sz, R), base, impl=self.impl, tally=False)
        return jnp.where(j < q, nxt, 0)

    def _shard_tally(self, pos, qid, base, Q):
        """Shard-local per-query-slot histogram: walks whose final vertex
        this shard owns land in its ``[Q, shard_size]`` bins; the rest
        (other shards' walks + idle slots via ``qid == Q``) are discarded.
        ``Q`` is the wave's *query-slot bucket* (row count), not
        ``max_queries`` — ladder waves tally at bucket shape."""
        sz = self.index.shard_size
        local = pos - base
        mine = (local >= 0) & (local < sz)
        bins = jnp.where(mine, qid * sz + jnp.clip(local, 0, sz - 1),
                         (Q + 1) * sz)
        counts = ops.frog_count(bins, (Q + 1) * sz + 1, impl=self.tally_impl)
        return counts[: (Q + 1) * sz].reshape(Q + 1, sz)[:Q]

    def _stitch_rounds(self, pos, q, round_fn, lost_of=None):
        """Applies ``q_max`` stitch rounds where ``round_fn(pos, j)`` sums
        per-shard contributions; stopped walks (``j ≥ q``) keep their
        position. This is the legacy *unrolled* round loop, kept under the
        ``sharded_dispatch="loop"`` path as the reference the fused
        ``lax.scan`` waves are byte-compared against.

        ``lost_of(pos) -> bool[W]`` marks walks sitting in an evicted
        shard's endpoint range. A walk that still needs a gather from a
        lost range (``j < q``) — or whose *final* vertex lands in one —
        dies: ``alive`` goes False and its position freezes, so the tally
        can route it to the discard bin. With no evictions the mask is
        all-False and the emitted program is bit-identical to the unmasked
        one. Returns ``(pos, alive)`` (``alive is None`` without a mask).
        """
        alive = None
        if lost_of is not None:
            alive = jnp.ones(pos.shape, bool)
        for j in range(self._q_max):
            if lost_of is not None:
                alive = alive & ~(lost_of(pos) & (j < q))
            nxt = round_fn(pos, j)
            adv = (j < q) if alive is None else ((j < q) & alive)
            pos = jnp.where(adv, nxt, pos)
        if lost_of is not None:
            alive = alive & ~lost_of(pos)
        return pos, alive

    def _build_mesh_wave(self, W_b: int, Q_b: int):
        """Sharded wave: one ``shard_map`` over the runtime's vertex axis.

        Device ``s`` holds only slab block ``s`` (``in_specs=P(axis)``) and
        its ``[Q, shard_size]`` tally rows (``out_specs=P(axis)``); walk
        state is replicated and advanced identically on every device, with
        the per-round gather contribution reduced by ``psum`` inside one
        ``lax.scan`` over the stitch rounds — one dispatch per wave, same
        as the fused single-device program. Walk-state operands are
        donated. Mesh programs close over the (unhashable) mesh, so they
        cache per-scheduler in ``_wave_fns``, not in the process-wide
        ladder cache.
        """
        rt, index, g = self.runtime, self.index, self.g
        Q = Q_b
        S = rt.num_shards
        sz = index.shard_size
        ax = rt.axis_name
        spec = self._spec(W_b, Q_b)

        def body(blocks, start, uniform, qid, t_cap, key_data, lost):
            record_wave_trace(spec)
            block_flat = blocks[0].reshape(-1)
            base = jax.lax.axis_index(ax) * sz
            key = jax.random.wrap_key_data(key_data, impl="threefry2x32")
            pos, q, s0 = wave_prep(
                g.row_ptr, g.col_idx, g.out_deg, start, uniform, t_cap,
                key, n=g.n, L=index.segment_len, p_T=self.p_T)
            alive = jnp.ones(pos.shape, bool)

            def round_fn(carry, j):
                pos, alive = carry
                # an evicted shard's range is masked identically on every
                # device (``lost`` is replicated) — the mesh simulates
                # loss; real device loss fails over to the fused path.
                alive = alive & ~(lost[jnp.clip(pos // sz, 0, S - 1)]
                                  & (j < q))
                contrib = self._shard_round(block_flat, base, pos, q, s0, j)
                # every walk is owned by exactly one shard; stopped walks
                # contribute 0 everywhere and keep their position.
                nxt = jax.lax.psum(contrib, ax)
                pos = jnp.where((j < q) & alive, nxt, pos)
                return (pos, alive), None

            if self._q_max > 0:
                (pos, alive), _ = jax.lax.scan(
                    round_fn, (pos, alive),
                    jnp.arange(self._q_max, dtype=jnp.int32))
            alive = alive & ~lost[jnp.clip(pos // sz, 0, S - 1)]
            qid_eff = jnp.where(alive, qid, Q)   # dead walks → discard bin
            return self._shard_tally(pos, qid_eff, base, Q)[None]

        # check_vma=False: the fused stitch backends lower through
        # pallas_call (no replication rule), and the body mixes replicated
        # walk state with per-shard slab blocks by construction. Donation
        # skips the blocks (operand 0, reused every wave) and key_data.
        donate = (1, 2, 3, 4, 6) if self.donate_wave_buffers else ()
        fn = rt.sharded_call(body, num_sharded=1, num_replicated=6,
                             check_vma=False, donate_argnums=donate)
        blocks = self._placed_blocks

        def wave(start, uniform, qid, t_cap, key, lost):
            out = np.asarray(fn(blocks, start, uniform, qid, t_cap,
                                ShardRuntime.key_data(key),
                                lost))              # [S, Q, sz]
            return out.transpose(1, 0, 2).reshape(Q, -1)[:, : self.g.n]

        return wave

    def _build_loop_wave(self, W_b: int, Q_b: int):
        """Legacy sharded wave on a single device: the host-loop dispatch
        of the per-shard program — S × q_max separate device calls per
        wave, cross-shard sums on the host. Superseded as the default by
        the fused single-dispatch program (``sharded_dispatch="fused"``);
        kept selectable because it is the structural reference the fused
        wave is byte-compared against in tests and the bench smoke."""
        rt, index, g = self.runtime, self.index, self.g
        Q = Q_b
        S = rt.num_shards
        sz = index.shard_size

        def _prep(start, uniform, t_cap, key):
            return wave_prep(g.row_ptr, g.col_idx, g.out_deg, start,
                             uniform, t_cap, key, n=g.n,
                             L=index.segment_len, p_T=self.p_T)

        prep = jax.jit(_prep)
        round_s = jax.jit(self._shard_round)
        tally_s = jax.jit(self._shard_tally, static_argnums=3)
        blocks = [jnp.asarray(index.blocks[s].reshape(-1))
                  for s in range(rt.num_shards)]

        def wave(start, uniform, qid, t_cap, key, lost):
            pos, q, s0 = prep(start, uniform, t_cap, key)
            lost_host = np.asarray(lost)

            def round_fn(pos, j):
                # an evicted shard's block is genuinely never touched on
                # this path: walks needing it are dead (masked below), so
                # skipping its contribution changes no surviving value.
                contribs = [
                    round_s(blocks[s], jnp.int32(s * sz),
                            pos, q, s0, jnp.int32(j))
                    for s in range(S) if not lost_host[s]]
                return sum(contribs)

            lost_of = lambda p: lost[jnp.clip(p // sz, 0, S - 1)]
            pos, alive = self._stitch_rounds(pos, q, round_fn, lost_of)
            qid_eff = jnp.where(alive, qid, Q)   # dead walks → discard bin
            out = np.stack([
                np.zeros((Q, sz), np.int32) if lost_host[s]
                else np.asarray(tally_s(pos, qid_eff, jnp.int32(s * sz), Q))
                for s in range(S)])
            return out.transpose(1, 0, 2).reshape(Q, -1)[:, : self.g.n]

        return wave

    # --- admission (deadline-aware) --------------------------------------

    def submit(self, req: QueryRequest) -> AdmissionDecision:
        """Deprecated entry point — use :meth:`repro.service.
        FrogWildService.topk` / :meth:`~repro.service.FrogWildService.ppr`,
        whose :class:`~repro.service.QueryHandle` futures delegate here."""
        warn_deprecated("QueryScheduler.submit", "FrogWildService.topk/ppr")
        return self._submit(req)

    def _submit(self, req: QueryRequest) -> AdmissionDecision:
        """Validates, plans, and admission-checks a request.

        Returns the :class:`AdmissionDecision`; rejected requests are
        recorded in ``self.rejected`` and never enter the queue. The
        latency clock starts here, so queue wait counts toward both
        ``latency_s`` and the SLO.
        """
        if req.num_walks is not None and req.num_walks <= 0:
            raise ValueError(
                f"request {req.rid}: num_walks must be positive, got "
                f"{req.num_walks}")
        if req.kind == "ppr" and not (0 <= req.source < self.g.n):
            raise ValueError(
                f"request {req.rid}: ppr source {req.source} outside "
                f"[0, {self.g.n})")
        if req.kind not in ("topk", "ppr"):
            raise ValueError(f"request {req.rid}: unknown kind {req.kind!r}")
        if req.slo_s is not None and req.slo_s <= 0:
            raise ValueError(
                f"request {req.rid}: slo_s must be positive, got {req.slo_s}")
        if req.t_submit is None:
            req.t_submit = time.perf_counter()

        # the plan is clamped to the index's reuse-free stitch budget — an
        # undersized index yields a recorded epsilon_bound, not a bias.
        plan = plan_query(
            req.k, req.epsilon, req.delta, p_T=self.p_T,
            max_steps=self.max_steps,
            segments_per_vertex=self.index.segments_per_vertex,
            segment_len=self.index.segment_len)
        walks = req.num_walks if req.num_walks is not None else plan.num_walks
        downgraded = False

        if req.slo_s is not None and self._wave_time is not None:
            # Remaining wave budget under the SLO at full-machine
            # throughput (max_walks walks per wave) — charged for *queue
            # depth*: already-admitted walk demand whose deadline is at or
            # before this request's outranks it under EDF and drains from
            # the same wave budget first (no-SLO work, deadline = ∞, is
            # never charged — EDF orders it behind every deadline). This
            # is an estimate, not a certainty: fair-share allocation still
            # guarantees every active query its per-wave share, so a
            # charged query can finish sooner than the model says — the
            # estimate deliberately errs toward protecting the SLOs
            # already admitted.
            deadline_new = req.t_submit + req.slo_s
            backlog = (sum(e.walks for e in self.queue
                           if e.deadline <= deadline_new)
                       + sum(a.remaining for a in self.active.values()
                             if a.deadline <= deadline_new))
            feasible = int(req.slo_s / self._wave_time)
            eff = self._effective_walks()
            needed = -(-(walks + backlog) // eff)
            if feasible < 1:
                return self._reject(
                    req, plan,
                    f"SLO {req.slo_s:.3g}s is shorter than one wave "
                    f"(≈{self._wave_time:.3g}s)",
                    RejectReason.INFEASIBLE_SLO)
            if needed > feasible:
                budget = feasible * eff - backlog
                if not req.allow_downgrade or budget < 1:
                    return self._reject(
                        req, plan,
                        f"plan needs {needed} waves ({backlog} walks "
                        f"queued ahead at earlier deadlines), only "
                        f"{feasible} fit the {req.slo_s:.3g}s SLO",
                        RejectReason.CAPACITY)
                plan = plan_query(
                    req.k, req.epsilon, req.delta, p_T=self.p_T,
                    max_walks=budget, max_steps=self.max_steps,
                    segments_per_vertex=self.index.segments_per_vertex,
                    segment_len=self.index.segment_len)
                walks = min(budget, plan.num_walks if req.num_walks is None
                            else req.num_walks)
                downgraded = True

        deadline = (math.inf if req.slo_s is None
                    else req.t_submit + req.slo_s)
        self.queue.append(_Queued(req=req, plan=plan, walks=walks,
                                  deadline=deadline, downgraded=downgraded))
        return AdmissionDecision(rid=req.rid, admitted=True,
                                 downgraded=downgraded, plan=plan,
                                 num_walks=walks)

    def _reject(self, req: QueryRequest, plan: QueryPlan, reason: str,
                code: RejectReason) -> AdmissionDecision:
        decision = AdmissionDecision(rid=req.rid, admitted=False,
                                     reason=reason, reason_code=code,
                                     plan=plan)
        self.rejected.append(decision)
        return decision

    # --- host scheduling --------------------------------------------------

    def _admit(self) -> None:
        """Queued queries claim free slots, earliest deadline first."""
        free = [s for s in range(self.max_queries) if s not in self.active]
        self.queue.sort(key=lambda e: (e.deadline, e.req.t_submit))
        while self.queue and free:
            e = self.queue.pop(0)
            self.active[free.pop(0)] = _Active(
                req=e.req, plan=e.plan, remaining=e.walks,
                total_walks=e.walks, counts=np.zeros(self.g.n, np.int64),
                waves=0, t_submit=e.req.t_submit, deadline=e.deadline,
                downgraded=e.downgraded,
            )

    def _edf_order(self) -> List[int]:
        return sorted(self.active,
                      key=lambda s: (self.active[s].deadline, s))

    def _allocate(self) -> Dict[int, int]:
        """Walk-slot split: equal shares, handed out (and topped up from
        the leftovers) in earliest-deadline-first order — a tight-deadline
        query drains its budget first without starving the rest below
        their fair share."""
        slots = {}
        budget = self.max_walks
        order = self._edf_order()
        share = max(1, budget // max(1, len(order)))
        for s in order:
            take = min(self.active[s].remaining, share, budget)
            slots[s] = take
            budget -= take
        for s in order:                      # leftovers, EDF-greedy
            if budget == 0:
                break
            extra = min(self.active[s].remaining - slots[s], budget)
            slots[s] += extra
            budget -= extra
        return {s: w for s, w in slots.items() if w > 0}

    def step_wave(self) -> bool:
        """Runs one device wave; returns False when nothing is in flight.

        The wave runs at the smallest ladder bucket that fits the
        allocation — walk slots padded to ``W_b``, query slots *compacted*
        (EDF allocation order) into ``[0, Q_b)`` rows and scattered back to
        their slots on the host. Bucket choice is a pure function of
        host-side scheduler state, so every dispatch path and replica picks
        the same bucket — the cross-path byte-identity contract holds
        bucket by bucket.
        """
        self._admit()
        if not self.active:
            return False
        alloc = self._allocate()
        W_b = self._bucket(self._walk_ladder, sum(alloc.values()))
        Q_b = self._bucket(self._query_ladder, len(alloc))
        start = np.zeros(W_b, np.int32)
        uniform = np.zeros(W_b, bool)
        qid = np.full(W_b, Q_b, np.int32)    # default: discard bin
        t_cap = np.zeros(W_b, np.int32)
        cursor = 0
        # ``alloc`` preserves EDF order, so compact row ci is deterministic
        # from (deadlines, slots) alone — identical across dispatch paths.
        for ci, (s, w) in enumerate(alloc.items()):
            a = self.active[s]
            sl = slice(cursor, cursor + w)
            qid[sl] = ci
            t_cap[sl] = a.plan.num_steps
            if a.req.kind == "ppr":
                start[sl] = a.req.source
            else:
                uniform[sl] = True
            cursor += w

        self._key, k_wave = jax.random.split(self._key)
        counts, clean, dt = self._run_wave(start, uniform, qid, t_cap,
                                           k_wave, W_b, Q_b)
        now = time.perf_counter()
        self._walks_allocated += sum(alloc.values())
        # EMA of measured wave time — feeds the admission budget check. The
        # scheduler's very first wave includes jit compilation (seconds vs
        # steady-state ms) and would poison the estimate into rejecting
        # feasible SLOs, so it is never folded in. Faulted / stalled /
        # retried waves are skipped too (their wall time measures the fault,
        # not the machine), and a clean outlier is clamped to a bounded
        # multiple of the current estimate.
        self._waves_run += 1
        self._t_last_wave = time.monotonic()
        self._last_wave_s = dt
        if self._waves_run > 1 and clean:
            if self._wave_time is not None:
                dt = min(dt, _EMA_OUTLIER_CLAMP * self._wave_time)
            self._wave_time = (dt if self._wave_time is None
                               else 0.5 * self._wave_time + 0.5 * dt)

        for ci, (s, w) in enumerate(alloc.items()):
            if s not in self.active:         # evicted mid-wave? impossible
                continue                     # today, but stay defensive
            a = self.active[s]
            row = counts[ci]                 # compact row → query slot
            # every surviving walk lands in exactly one tally bin, so the
            # slot's landed count is the row sum — lost walks need no extra
            # program output.
            landed = int(row.sum())
            a.counts += row
            a.remaining -= w
            a.executed += landed
            self._walks_executed += landed
            a.waves += 1
            if landed < w:
                a.lost += w - landed
                a.shards_lost = tuple(sorted(self.lost_shards))
            early = (a.remaining > 0 and a.req.early_stop
                     and self.anytime_bound(a.plan.num_steps, a.req.k,
                                             a.req.delta, a.executed)
                     <= a.req.epsilon)
            if a.remaining == 0 or early:
                self.finished.append(self._finalize(a, now, early=early))
                del self.active[s]
        return True

    # --- wave supervision (fault tolerance) -------------------------------

    def _run_wave(self, start, uniform, qid, t_cap, k_wave, W_b, Q_b):
        """Runs one wave under supervision: injector hooks fire first, the
        dispatch is retried (same key — a successful retry is byte-identical)
        on transient faults / timeouts with exponential backoff, permanent
        shard faults evict the shard and re-run degraded, and a mesh that
        keeps failing fails over once to the fused single-device dispatch.
        Exhausting every option raises :class:`WaveFailedError` with
        nothing tallied.

        The wave callable is re-fetched per attempt (``_wave_for(W_b,
        Q_b)``) — a failover mid-retry picks up the new dispatch path for
        the *same* bucket — and every attempt converts the host operands
        to fresh device buffers, so donation (the executable consumes its
        inputs) can never poison a retry.

        Returns ``(counts, clean, dt)`` — ``clean`` is False for any wave
        that saw a fault, stall, retry, or eviction (the EMA skips those).
        """
        wave_no = self._waves_run
        attempt = 0
        clean = True
        if self._injector is not None:
            for shard in self._injector.shard_losses_at(wave_no):
                clean = False
                self._evict_shard(shard, wave_no)
        while True:
            t0 = time.perf_counter()
            try:
                if self._injector is not None:
                    stall = self._injector.stall_s(wave_no)
                    if stall:
                        clean = False
                        time.sleep(stall)
                    kind = self._injector.fail_attempt(wave_no, attempt)
                    if kind == "timeout":
                        raise WaveTimeout(
                            f"injected hang (wave {wave_no}, attempt "
                            f"{attempt})")
                    if kind == "transient":
                        raise ShardFault(
                            f"injected transient fault (wave {wave_no}, "
                            f"attempt {attempt})", transient=True)
                wave = self._wave_for(W_b, Q_b)
                counts = wave(
                    jnp.asarray(start), jnp.asarray(uniform),
                    jnp.asarray(qid), jnp.asarray(t_cap), k_wave,
                    jnp.asarray(self._lost))
                dt = time.perf_counter() - t0
                if self.wave_timeout_s is not None and dt > self.wave_timeout_s:
                    raise WaveTimeout(
                        f"wave {wave_no} took {dt:.3g}s > wave_timeout_s="
                        f"{self.wave_timeout_s:.3g}s — result discarded")
                return counts, clean, dt
            except ShardFault as e:
                clean = False
                if not e.transient:
                    if e.shard is None:
                        raise WaveFailedError(
                            f"wave {wave_no}: permanent fault named no "
                            f"shard to evict: {e}") from e
                    self._evict_shard(e.shard, wave_no)
                    continue        # degraded re-run, not a retry
                attempt = self._count_retry(wave_no, attempt, e)
            except WaveTimeout as e:
                clean = False
                attempt = self._count_retry(wave_no, attempt, e)

    def _count_retry(self, wave_no: int, attempt: int,
                     err: Exception) -> int:
        """Charges one retry; past ``max_retries`` tries the mesh→host-loop
        failover (attempt counter resets — a fresh dispatch path earns a
        fresh budget), then gives up with :class:`WaveFailedError`."""
        attempt += 1
        self.fault_log.append(FaultEvent(
            kind="retry", wave=wave_no, attempt=attempt, detail=str(err)))
        if attempt > self.max_retries:
            if self._failover_to_loop(wave_no, str(err)):
                return 0
            raise WaveFailedError(
                f"wave {wave_no} failed after {attempt} attempts "
                f"(max_retries={self.max_retries}, no failover path left): "
                f"{err}") from err
        time.sleep(self._backoff_s(attempt))
        return attempt

    def _backoff_s(self, attempt: int) -> float:
        """Exponential backoff with ×[0.5, 1.5) seeded jitter."""
        base = min(self.backoff_max_s,
                   self.backoff_base_s * (2 ** (attempt - 1)))
        return base * (0.5 + self._backoff_rng.random())

    def _evict_shard(self, shard: int, wave_no: int) -> None:
        """Permanently removes a shard from serving: flips its eviction
        mask bit (subsequent waves drop walks touching its range) and
        re-runs admission for queued SLO work against the shrunken
        capacity. Evicting the last shard is unservable and raises."""
        if not isinstance(self.index, ShardedWalkIndex):
            raise WaveFailedError(
                f"shard {shard} reported lost but the slab is dense — "
                f"gathered serving has no shard granularity to degrade to; "
                f"rebuild the index")
        S = self.index.num_shards
        if not (0 <= shard < S):
            raise ValueError(f"lost shard {shard} outside [0, {S})")
        if shard in self.lost_shards:
            return
        if len(self.lost_shards) + 1 >= S:
            raise WaveFailedError(
                f"shard {shard} lost but shards "
                f"{sorted(self.lost_shards)} are already evicted — no "
                f"shard left to serve from; rebuild the index")
        self.lost_shards.add(shard)
        self._lost[shard] = True
        self.fault_log.append(FaultEvent(
            kind="shard_loss", wave=wave_no, shard=shard))
        self._readmit_queued(wave_no)

    def _failover_to_loop(self, wave_no: int, reason: str) -> bool:
        """Mesh→single-device failover: rebuilds the wave as the fused
        single-dispatch program over the stacked slab — byte-identical
        answers (the PR-4 contract, now via the fused path). One shot: a
        single-device dispatch has nothing further to fail over to."""
        if (self._failed_over
                or not isinstance(self.index, ShardedWalkIndex)
                or self.runtime is None or not self.runtime.is_mesh):
            return False
        self._failed_over = True
        self.runtime = ShardRuntime(num_shards=self.runtime.num_shards,
                                    axis_name=self.runtime.axis_name,
                                    mesh=None)
        self.dispatch = "fused"
        self._wave_fns.clear()      # drop the mesh programs
        self._placed_blocks = None
        self.fault_log.append(FaultEvent(
            kind="failover", wave=wave_no,
            detail=f"mesh dispatch abandoned for single-device fused "
                   f"dispatch: {reason}"))
        return True

    def _effective_walks(self) -> int:
        """Walks the admission model charges per wave: losing shards kills
        the walks that land in their ranges, so full-machine throughput
        shrinks by the surviving-shard fraction (first-order — endpoint
        mass is roughly balanced across range shards)."""
        if isinstance(self.index, ShardedWalkIndex) and self.lost_shards:
            S = self.index.num_shards
            return max(1, int(self.max_walks * (S - len(self.lost_shards))
                              / S))
        return self.max_walks

    def _readmit_queued(self, wave_no: int) -> None:
        """Re-runs admission for queued SLO work after capacity shrank.

        Every queued deadline entry is re-checked (EDF order) against the
        post-eviction effective throughput: still-feasible work stays,
        downgradable work is re-clamped, and the rest moves to
        ``rejected`` — an honest late rejection instead of a silent SLO
        miss discovered at the deadline. No-SLO work is untouched."""
        if self._wave_time is None or not self.queue:
            return
        now = time.perf_counter()
        eff = self._effective_walks()
        keep: List[_Queued] = []
        for e in sorted(self.queue,
                        key=lambda e: (e.deadline, e.req.t_submit)):
            if e.deadline == math.inf:
                keep.append(e)
                continue
            feasible = int((e.deadline - now) / self._wave_time)
            backlog = (sum(q.walks for q in keep
                           if q.deadline <= e.deadline)
                       + sum(a.remaining for a in self.active.values()
                             if a.deadline <= e.deadline))
            needed = -(-(e.walks + backlog) // eff)
            if feasible >= needed:
                keep.append(e)
                continue
            budget = feasible * eff - backlog
            if e.req.allow_downgrade and budget >= 1:
                e.walks = min(e.walks, budget)
                e.downgraded = True
                keep.append(e)
                self.fault_log.append(FaultEvent(
                    kind="readmit", wave=wave_no,
                    detail=f"rid={e.req.rid} downgraded to {e.walks} walks"))
            else:
                self.rejected.append(AdmissionDecision(
                    rid=e.req.rid, admitted=False,
                    reason=(f"re-admission after shard loss (shards "
                            f"{sorted(self.lost_shards)} evicted): plan "
                            f"needs {needed} waves, {feasible} fit the "
                            f"SLO at degraded throughput"),
                    reason_code=RejectReason.SHARD_LOSS,
                    plan=e.plan))
                self.fault_log.append(FaultEvent(
                    kind="readmit", wave=wave_no,
                    detail=f"rid={e.req.rid} rejected"))
        self.queue = keep

    # --- introspection (gateway routing + metrics) ------------------------

    def stats(self) -> SchedulerStats:
        """Structured snapshot of serving/admission state (no waves driven).

        ``backlog_walks`` is exactly the demand ``_submit`` would charge a
        new no-SLO request with under EDF (every outstanding deadline
        outranks ∞): queued walk counts plus the remaining budgets of every
        active slot. The gateway's replica router picks the replica where
        this is smallest.
        """
        backlog = (sum(e.walks for e in self.queue)
                   + sum(a.remaining for a in self.active.values()))
        capacity = self._waves_run * self.max_walks
        return SchedulerStats(
            queued=len(self.queue),
            active=len(self.active),
            finished=len(self.finished),
            rejected=len(self.rejected),
            cancelled=len(self.cancelled),
            backlog_walks=backlog,
            waves_run=self._waves_run,
            walks_executed=self._walks_executed,
            wave_time_ema_s=self._wave_time,
            wave_occupancy=(self._walks_allocated / capacity
                            if capacity else 0.0),
            lost_shards=tuple(sorted(self.lost_shards)),
            max_walks=self.max_walks,
            max_queries=self.max_queries,
            t_last_wave=self._t_last_wave,
            last_wave_s=self._last_wave_s,
            epoch=self.epoch,
        )

    # --- anytime (ε, δ) refinement ---------------------------------------

    def anytime_bound(self, num_steps: int, k: int, delta: float,
                       executed: int) -> float:
        """The ε Theorem 1 certifies for the walks tallied so far (p_s = 1
        serving walks, p_cap = 0). Monotone non-increasing in ``executed``
        — every extra wave tightens it; ``inf`` before the first wave."""
        if executed < 1:
            return math.inf
        return theory.epsilon_bound(self.p_T, num_steps, k, delta,
                                    executed, 1.0, 0.0)

    def _finalize(self, a: _Active, now: float,
                  early: bool = False) -> QueryResult:
        # scores renormalize by the walks that actually completed — lost
        # walks shrink the denominator rather than biasing the estimate
        # (max() only guards the all-walks-lost corner: counts are all
        # zero there and the bound below is already inf).
        # rank the integer counts (same order as the renormalized scores
        # — a positive scalar divide preserves ranks and ties exactly)
        # and divide only the selected head.
        k = min(a.req.k, self.g.n)
        top = _topk_stable(a.counts, k)
        scores_top = a.counts[top] / float(max(1, a.executed))
        latency = now - a.t_submit
        # Early-stopped (anytime) queries carry the bound their executed
        # walks actually certify; budget-drained queries keep the plan's
        # recorded bound (incl. any admission downgrade). A degraded query
        # — walks died on evicted shards — widens to exactly the ε
        # Theorem 1 certifies at N = executed: the lost-walk fraction
        # enters through the sampling term, never silently.
        degraded = a.lost > 0
        bound = (self.anytime_bound(a.plan.num_steps, a.req.k, a.req.delta,
                                     a.executed)
                 if (a.req.early_stop or degraded)
                 else a.plan.epsilon_bound)
        return QueryResult(
            rid=a.req.rid, kind=a.req.kind, vertices=top,
            scores=scores_top, num_walks=a.executed,
            num_steps=a.plan.num_steps, waves=a.waves,
            latency_s=latency,
            epsilon_bound=bound,
            downgraded=a.downgraded,
            met_slo=(None if a.req.slo_s is None
                     else bool(latency <= a.req.slo_s)),
            early_stopped=early,
            degraded=degraded,
            shards_lost=a.shards_lost,
            walks_lost=a.lost,
            epoch=self.epoch,
        )

    # --- anytime introspection (the QueryHandle surface) ------------------

    def query_state(self, rid: int) -> str:
        """``queued`` | ``active`` | ``finished`` | ``rejected`` |
        ``cancelled`` | ``unknown``."""
        if any(r.rid == rid for r in self.finished):
            return "finished"
        if any(a.req.rid == rid for a in self.active.values()):
            return "active"
        if any(e.req.rid == rid for e in self.queue):
            return "queued"
        if rid in self.cancelled:
            return "cancelled"
        if any(d.rid == rid for d in self.rejected):
            return "rejected"
        return "unknown"

    def result_for(self, rid: int) -> QueryResult:
        for r in self.finished:
            if r.rid == rid:
                return r
        raise KeyError(f"query {rid} has no finished result "
                       f"(state: {self.query_state(rid)})")

    def partial(self, rid: int) -> QueryPartial:
        """Anytime snapshot: the current top-k estimate plus the ε the
        tallied walks certify so far (``inf`` before the first wave)."""
        for r in self.finished:
            if r.rid == rid:
                return QueryPartial(
                    rid=rid, kind=r.kind, k=len(r.vertices),
                    vertices=r.vertices, scores=r.scores,
                    walks_done=r.num_walks, waves=r.waves,
                    epsilon_bound=r.epsilon_bound, done=True,
                    degraded=r.degraded, shards_lost=r.shards_lost,
                    walks_lost=r.walks_lost)
        for a in self.active.values():
            if a.req.rid != rid:
                continue
            k = min(a.req.k, self.g.n)
            if a.executed:
                top = _topk_stable(a.counts, k)
                vertices = top
                top_scores = a.counts[top] / float(a.executed)
            else:
                vertices = np.zeros(0, np.int64)
                top_scores = np.zeros(0, np.float64)
            return QueryPartial(
                rid=rid, kind=a.req.kind, k=k, vertices=vertices,
                scores=top_scores, walks_done=a.executed, waves=a.waves,
                epsilon_bound=self.anytime_bound(
                    a.plan.num_steps, a.req.k, a.req.delta, a.executed),
                done=False,
                degraded=a.lost > 0, shards_lost=a.shards_lost,
                walks_lost=a.lost)
        for e in self.queue:
            if e.req.rid == rid:
                return QueryPartial(
                    rid=rid, kind=e.req.kind, k=min(e.req.k, self.g.n),
                    vertices=np.zeros(0, np.int64),
                    scores=np.zeros(0, np.float64),
                    walks_done=0, waves=0, epsilon_bound=math.inf,
                    done=False)
        raise KeyError(f"no in-flight query {rid} "
                       f"(state: {self.query_state(rid)})")

    def cancel(self, rid: int) -> bool:
        """Drops a queued or in-flight query (its tallies are discarded).
        Returns False when there is nothing left to cancel."""
        for i, e in enumerate(self.queue):
            if e.req.rid == rid:
                del self.queue[i]
                self.cancelled.append(rid)
                return True
        for s, a in list(self.active.items()):
            if a.req.rid == rid:
                del self.active[s]
                self.cancelled.append(rid)
                return True
        return False

    def run(self) -> List[QueryResult]:
        """Deprecated entry point — use :meth:`repro.service.
        FrogWildService.drain` (or drive :class:`~repro.service.QueryHandle`
        futures via ``poll()`` / ``result()``)."""
        warn_deprecated("QueryScheduler.run", "FrogWildService.drain")
        return self._drain()

    def _drain(self) -> List[QueryResult]:
        """Drains queue + in-flight queries; returns results in finish order."""
        while self.step_wave():
            pass
        return self.finished
