"""Host-side continuous-batching query scheduler (fixed-slot design) with
sharded-slab serving and deadline-aware admission.

The device program is one fixed shape — ``max_walks`` walk slots ×
``max_queries`` query slots — and scheduling is pure host logic, exactly the
``serving/scheduler.py`` contract. Each wave:

  admit     queued queries claim free query slots, earliest deadline first;
  allocate  walk slots are split fairly among active queries (equal shares),
            with shares and leftovers handed out in earliest-deadline-first
            order — continuous batching, not generational: a query spanning
            several waves keeps its slot while finished queries free theirs
            mid-flight;
  execute   one wave program advances all walks (residual steps + index
            stitching, ``query/engine.py``) and histograms endpoints into
            per-query-slot bins;
  retire    queries whose walk budget completed finalize top-k from their
            accumulated counters and release the slot.

**Execution dispatch** (the ``distributed/runtime.py`` layer): with a dense
:class:`~repro.query.index.WalkIndex` the wave is the single-device gathered
program (whole slab resident). With a :class:`~repro.query.index.
ShardedWalkIndex` the slab is *never reassembled*: on a mesh the wave runs
as one ``shard_map`` over the runtime's ``"vertex"`` axis — device ``s``
holds only its ``[shard_size, R]`` slab block, each stitch round routes
every walk to the shard owning its current vertex by endpoint range
(masked local gather), per-shard partial results are reduced with ``psum``,
and the tally lands in shard-local bins (``out_specs=P(axis)``). On a
single device the identical per-shard program runs as the runtime's host
loop, one block resident at a time. All three paths draw from the same key
stream, so with the same slab content they produce byte-identical answers
(tests assert it).

**Admission** is deadline- and queue-depth-aware: ``QueryRequest.slo_s``
declares a latency SLO, and ``submit()`` checks the Theorem-1 ``(t, N)``
plan against the remaining wave budget (measured wave time × waves at full
machine throughput — the FAST-PPR-style per-query budget), charged for the
already-admitted walk demand that outranks the request under EDF
(earlier-or-equal deadlines; no-SLO work is never charged). An infeasible
query is
rejected up front, or — with ``allow_downgrade`` — its walk count is
clamped to what fits and the weakened guarantee is *recorded* in
``QueryPlan.epsilon_bound`` (never a silent miss). Plans are also clamped
to the index's reuse-free stitch budget (``plan_query(segments_per_vertex,
segment_len)``), so an undersized index degrades to an honest, recorded
``epsilon_bound`` instead of a silent statistical bias.

Different queries in one wave may have different planned truncations ``t``
(per-walk ``t_cap``) and different kinds (global top-k draws uniform starts,
personalized PageRank pins the start vertex) — the program shape never
changes, so XLA compiles exactly once per scheduler.

**Anytime serving** (PR 5): per-query tallies track the walks *executed*
so far, and :meth:`QueryScheduler.partial` exposes the estimate together
with the ε Theorem 1 certifies for those walks — monotone non-increasing
wave over wave. A request with ``early_stop`` finishes as soon as that
bound reaches its requested ``epsilon``, even with walk budget left. The
public way to drive all of this is the :class:`repro.service.QueryHandle`
future (``submit()`` / ``run()`` here are deprecation shims kept for the
legacy callers).
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import warn_deprecated
from repro.core import theory
from repro.distributed.runtime import ShardRuntime
from repro.graph.csr import CSRGraph
from repro.kernels import ops
from repro.query.engine import (QueryPlan, _plain_steps, plan_query,
                                sample_walk_lengths)
from repro.query.index import ShardedWalkIndex, WalkIndex


@dataclasses.dataclass
class QueryRequest:
    rid: int
    kind: str = "topk"               # "topk" | "ppr"
    k: int = 10
    source: int = 0                  # PPR start vertex (ignored for topk)
    epsilon: float = 0.3
    delta: float = 0.1
    num_walks: Optional[int] = None  # override the (ε, δ) plan's walk count
    slo_s: Optional[float] = None    # latency SLO (deadline = submit + slo_s)
    allow_downgrade: bool = False    # shrink the plan to fit the SLO budget
    early_stop: bool = False         # finish once the anytime Theorem-1
                                     # bound reaches epsilon (QueryHandle mode)
    t_submit: Optional[float] = None # stamped by QueryScheduler.submit()


@dataclasses.dataclass(frozen=True)
class AdmissionDecision:
    """What the admission controller did with a ``submit()``.

    ``admitted=False`` means the request was dropped at the door (its
    Theorem-1 plan cannot fit the remaining wave budget before the
    deadline); ``downgraded=True`` means it was admitted with a clamped
    walk count whose weaker guarantee is recorded in
    ``plan.epsilon_bound``.
    """

    rid: int
    admitted: bool
    reason: str = ""
    downgraded: bool = False
    plan: Optional[QueryPlan] = None
    num_walks: int = 0


@dataclasses.dataclass
class QueryResult:
    rid: int
    kind: str
    vertices: np.ndarray             # int64[k] — estimated top-k
    scores: np.ndarray               # f64[k]  — π̂ / PPR estimates
    num_walks: int                   # walks actually executed (≤ budget)
    num_steps: int
    waves: int                       # device waves this query spanned
    latency_s: float
    epsilon_bound: float = 0.0       # the ε Theorem 1 certifies for (t, N)
    downgraded: bool = False         # admission shrank the plan to fit SLO
    met_slo: Optional[bool] = None   # None when no SLO was requested
    early_stopped: bool = False      # anytime bound met before the budget


@dataclasses.dataclass(frozen=True)
class QueryPartial:
    """Anytime snapshot of an in-flight (or finished) query.

    ``epsilon_bound`` is the ε Theorem 1 certifies for the walks tallied
    *so far* (``math.inf`` before the first wave lands); it tightens
    monotonically as waves accumulate — the anytime property the
    :class:`repro.service.QueryHandle` future exposes.
    """

    rid: int
    kind: str
    k: int
    vertices: np.ndarray             # int64[≤k] — current top-k estimate
    scores: np.ndarray               # f64[≤k]
    walks_done: int
    waves: int
    epsilon_bound: float
    done: bool


@dataclasses.dataclass
class _Queued:
    req: QueryRequest
    plan: QueryPlan
    walks: int
    deadline: float                  # math.inf when no SLO
    downgraded: bool


@dataclasses.dataclass
class _Active:
    req: QueryRequest
    plan: QueryPlan
    remaining: int
    total_walks: int
    counts: np.ndarray               # int64[n] accumulator
    waves: int
    t_submit: float
    deadline: float
    downgraded: bool
    executed: int = 0                # walks whose tallies have landed


class QueryScheduler:
    def __init__(
        self,
        g: CSRGraph,
        index: Union[WalkIndex, ShardedWalkIndex],
        max_walks: int = 8192,
        max_queries: int = 8,
        max_steps: int = 32,
        p_T: float = 0.15,
        impl: str = "xla",
        tally_impl: str = "ref",
        seed: int = 0,
        runtime: Optional[ShardRuntime] = None,
        wave_time_estimate_s: Optional[float] = None,
    ):
        self.g = g
        self.index = index
        self.max_walks = max_walks
        self.max_queries = max_queries
        self.max_steps = max_steps
        self.p_T = p_T
        self.impl = impl
        self.tally_impl = tally_impl
        self.queue: List[_Queued] = []
        self.active: Dict[int, _Active] = {}
        self.finished: List[QueryResult] = []
        self.rejected: List[AdmissionDecision] = []
        self.cancelled: List[int] = []
        self._key = jax.random.PRNGKey(seed)
        self._wave_time = wave_time_estimate_s   # EMA of measured wave s
        self._waves_run = 0
        if isinstance(index, ShardedWalkIndex):
            self.runtime = (runtime if runtime is not None
                            else ShardRuntime.acquire(index.num_shards))
            if self.runtime.num_shards != index.num_shards:
                raise ValueError(
                    f"runtime has {self.runtime.num_shards} shards, index "
                    f"has {index.num_shards}")
            if self.runtime.is_mesh:
                self._wave = self._build_mesh_wave()
            else:
                self._wave = self._build_loop_wave()
        else:
            self.runtime = runtime
            self._wave = self._build_gathered_wave()

    # --- device programs (each compiled once) ----------------------------

    @property
    def _q_max(self) -> int:
        return self.max_steps // self.index.segment_len

    def _wave_prep(self, start, uniform, t_cap, key):
        """Shared wave prologue: starts, lengths, residual steps, slot
        offsets — one definition so the gathered, mesh, and host-loop waves
        consume the *same* key stream and agree byte-for-byte."""
        g, W = self.g, self.max_walks
        L = self.index.segment_len
        k_start, k_tau, k_walk = jax.random.split(key, 3)
        pos0 = jnp.where(
            uniform,
            jax.random.randint(k_start, (W,), 0, g.n, dtype=jnp.int32),
            start,
        )
        tau = sample_walk_lengths(k_tau, W, self.p_T, t_cap)
        k_res, k_slot = jax.random.split(k_walk)
        q = tau // L
        pos = _plain_steps(g.row_ptr, g.col_idx, g.out_deg, pos0, tau % L,
                           k_res, L)
        s0 = jax.random.randint(k_slot, pos.shape, 0, 1 << 30, jnp.int32)
        return pos, q, s0

    def _build_gathered_wave(self):
        """Single-device wave against the dense slab.

        Structurally the one-shard case of the sharded waves: the same
        :meth:`_wave_prep` prologue and :meth:`_stitch_rounds` loop, with
        the whole slab as the (only) shard's block — which is what makes
        the byte-identical gathered-vs-sharded contract hold by
        construction rather than by parallel-edit discipline.
        """
        index = self.index
        n, Q = self.g.n, self.max_queries
        R, impl = index.segments_per_vertex, self.impl
        endpoints_flat = index.endpoints.reshape(-1)

        def wave(start, uniform, qid, t_cap, key):
            pos, q, s0 = self._wave_prep(start, uniform, t_cap, key)

            def round_fn(pos, j):
                if impl == "xla":
                    return jnp.take(endpoints_flat,
                                    pos * R + (s0 + j) % R, axis=0)
                # fused stitch kernel; its per-round tally is discarded —
                # the wave tallies once over final positions below.
                nxt, _ = ops.stitch_step(
                    pos, (q == j).astype(jnp.int32), s0 + j,
                    index.endpoints, n, impl=impl)
                return nxt

            pos = self._stitch_rounds(pos, q, round_fn)
            # one histogram for the whole wave: vertex id offset by the
            # walk's query slot; row Q is the idle-slot discard bin.
            # ``tally_impl``: "ref" (XLA scatter-add — fastest on CPU) or
            # "sort" (segment counts — the TPU-friendly scatter-free path).
            counts = ops.frog_count(pos + qid * n, (Q + 1) * n,
                                    impl=self.tally_impl)
            return counts.reshape(Q + 1, n)[:Q]

        fn = jax.jit(wave)
        return lambda *args: np.asarray(fn(*args))

    def _shard_round(self, block_flat, base, pos, q, s0, j):
        """One stitch round against one shard's slab block: owned walks
        gather their next endpoint, everyone else contributes the additive
        identity — results sum across shards (psum / host sum)."""
        R = self.index.segments_per_vertex
        sz = self.index.shard_size
        if self.impl == "xla":
            slot = (s0 + j) % R
            local = pos - base
            mine = (local >= 0) & (local < sz)
            li = jnp.clip(local, 0, sz - 1)
            nxt = jnp.take(block_flat, li * R + slot, axis=0)
            return jnp.where(mine & (j < q), nxt, 0)
        # fused local-index stitch kernel ("pallas" | "ref"): same masked
        # gather + shard-local tally in one pass; the per-round tally is
        # discarded here (the wave tallies once over final positions).
        nxt, _ = ops.stitch_step_local(
            pos, (q == j).astype(jnp.int32), s0 + j,
            block_flat.reshape(sz, R), base, impl=self.impl)
        return jnp.where(j < q, nxt, 0)

    def _shard_tally(self, pos, qid, base):
        """Shard-local per-query-slot histogram: walks whose final vertex
        this shard owns land in its ``[Q, shard_size]`` bins; the rest
        (other shards' walks + idle slots via ``qid == Q``) are discarded."""
        Q = self.max_queries
        sz = self.index.shard_size
        local = pos - base
        mine = (local >= 0) & (local < sz)
        bins = jnp.where(mine, qid * sz + jnp.clip(local, 0, sz - 1),
                         (Q + 1) * sz)
        counts = ops.frog_count(bins, (Q + 1) * sz + 1, impl=self.tally_impl)
        return counts[: (Q + 1) * sz].reshape(Q + 1, sz)[:Q]

    def _stitch_rounds(self, pos, q, round_fn):
        """Applies ``q_max`` stitch rounds where ``round_fn(pos, j)`` sums
        per-shard contributions; stopped walks (``j ≥ q``) keep their
        position. Shared by the mesh and host-loop waves."""
        for j in range(self._q_max):
            nxt = round_fn(pos, j)
            pos = jnp.where(j < q, nxt, pos)
        return pos

    def _build_mesh_wave(self):
        """Sharded wave: one ``shard_map`` over the runtime's vertex axis.

        Device ``s`` holds only slab block ``s`` (``in_specs=P(axis)``) and
        its ``[Q, shard_size]`` tally rows (``out_specs=P(axis)``); walk
        state is replicated and advanced identically on every device, with
        the per-round gather contribution reduced by ``psum``.
        """
        rt, index = self.runtime, self.index
        Q = self.max_queries
        sz = index.shard_size
        ax = rt.axis_name

        def body(blocks, start, uniform, qid, t_cap, key_data):
            block_flat = blocks[0].reshape(-1)
            base = jax.lax.axis_index(ax) * sz
            key = jax.random.wrap_key_data(key_data, impl="threefry2x32")
            pos, q, s0 = self._wave_prep(start, uniform, t_cap, key)

            def round_fn(pos, j):
                contrib = self._shard_round(block_flat, base, pos, q, s0, j)
                # every walk is owned by exactly one shard; stopped walks
                # contribute 0 everywhere and are restored by the caller.
                return jax.lax.psum(contrib, ax)

            pos = self._stitch_rounds(pos, q, round_fn)
            return self._shard_tally(pos, qid, base)[None]

        # check_vma=False: the fused stitch backends lower through
        # pallas_call (no replication rule), and the body mixes replicated
        # walk state with per-shard slab blocks by construction.
        fn = rt.sharded_call(body, num_sharded=1, num_replicated=5,
                             check_vma=False)
        # kept as an attribute so tests can assert the per-device placement
        # (each device holds exactly one [shard_size, R] block — 4nR/S
        # bytes of slab, never the whole thing).
        self._placed_blocks = blocks = rt.place_sharded(
            jnp.asarray(self.index.blocks))

        def wave(start, uniform, qid, t_cap, key):
            out = np.asarray(fn(blocks, start, uniform, qid, t_cap,
                                ShardRuntime.key_data(key)))  # [S, Q, sz]
            return out.transpose(1, 0, 2).reshape(Q, -1)[:, : self.g.n]

        return wave

    def _build_loop_wave(self):
        """Sharded wave on a single device: the runtime's host-loop
        dispatch of the identical per-shard program — one ``[shard_size,
        R]`` block resident per call, cross-shard sums on the host."""
        rt, index = self.runtime, self.index
        Q = self.max_queries
        sz = index.shard_size

        prep = jax.jit(lambda start, uniform, t_cap, key:
                       self._wave_prep(start, uniform, t_cap, key))
        round_s = jax.jit(self._shard_round)
        tally_s = jax.jit(self._shard_tally)
        blocks = [jnp.asarray(index.blocks[s].reshape(-1))
                  for s in range(rt.num_shards)]

        def wave(start, uniform, qid, t_cap, key):
            pos, q, s0 = prep(start, uniform, t_cap, key)

            def round_fn(pos, j):
                contribs = rt.map_shards(
                    lambda s: round_s(blocks[s], jnp.int32(s * sz),
                                      pos, q, s0, jnp.int32(j)))
                return sum(contribs)

            pos = self._stitch_rounds(pos, q, round_fn)
            out = np.stack(rt.map_shards(
                lambda s: np.asarray(tally_s(pos, qid, jnp.int32(s * sz)))))
            return out.transpose(1, 0, 2).reshape(Q, -1)[:, : self.g.n]

        return wave

    # --- admission (deadline-aware) --------------------------------------

    def submit(self, req: QueryRequest) -> AdmissionDecision:
        """Deprecated entry point — use :meth:`repro.service.
        FrogWildService.topk` / :meth:`~repro.service.FrogWildService.ppr`,
        whose :class:`~repro.service.QueryHandle` futures delegate here."""
        warn_deprecated("QueryScheduler.submit", "FrogWildService.topk/ppr")
        return self._submit(req)

    def _submit(self, req: QueryRequest) -> AdmissionDecision:
        """Validates, plans, and admission-checks a request.

        Returns the :class:`AdmissionDecision`; rejected requests are
        recorded in ``self.rejected`` and never enter the queue. The
        latency clock starts here, so queue wait counts toward both
        ``latency_s`` and the SLO.
        """
        if req.num_walks is not None and req.num_walks <= 0:
            raise ValueError(
                f"request {req.rid}: num_walks must be positive, got "
                f"{req.num_walks}")
        if req.kind == "ppr" and not (0 <= req.source < self.g.n):
            raise ValueError(
                f"request {req.rid}: ppr source {req.source} outside "
                f"[0, {self.g.n})")
        if req.kind not in ("topk", "ppr"):
            raise ValueError(f"request {req.rid}: unknown kind {req.kind!r}")
        if req.slo_s is not None and req.slo_s <= 0:
            raise ValueError(
                f"request {req.rid}: slo_s must be positive, got {req.slo_s}")
        if req.t_submit is None:
            req.t_submit = time.perf_counter()

        # the plan is clamped to the index's reuse-free stitch budget — an
        # undersized index yields a recorded epsilon_bound, not a bias.
        plan = plan_query(
            req.k, req.epsilon, req.delta, p_T=self.p_T,
            max_steps=self.max_steps,
            segments_per_vertex=self.index.segments_per_vertex,
            segment_len=self.index.segment_len)
        walks = req.num_walks if req.num_walks is not None else plan.num_walks
        downgraded = False

        if req.slo_s is not None and self._wave_time is not None:
            # Remaining wave budget under the SLO at full-machine
            # throughput (max_walks walks per wave) — charged for *queue
            # depth*: already-admitted walk demand whose deadline is at or
            # before this request's outranks it under EDF and drains from
            # the same wave budget first (no-SLO work, deadline = ∞, is
            # never charged — EDF orders it behind every deadline). This
            # is an estimate, not a certainty: fair-share allocation still
            # guarantees every active query its per-wave share, so a
            # charged query can finish sooner than the model says — the
            # estimate deliberately errs toward protecting the SLOs
            # already admitted.
            deadline_new = req.t_submit + req.slo_s
            backlog = (sum(e.walks for e in self.queue
                           if e.deadline <= deadline_new)
                       + sum(a.remaining for a in self.active.values()
                             if a.deadline <= deadline_new))
            feasible = int(req.slo_s / self._wave_time)
            needed = -(-(walks + backlog) // self.max_walks)
            if feasible < 1:
                return self._reject(
                    req, plan,
                    f"SLO {req.slo_s:.3g}s is shorter than one wave "
                    f"(≈{self._wave_time:.3g}s)")
            if needed > feasible:
                budget = feasible * self.max_walks - backlog
                if not req.allow_downgrade or budget < 1:
                    return self._reject(
                        req, plan,
                        f"plan needs {needed} waves ({backlog} walks "
                        f"queued ahead at earlier deadlines), only "
                        f"{feasible} fit the {req.slo_s:.3g}s SLO")
                plan = plan_query(
                    req.k, req.epsilon, req.delta, p_T=self.p_T,
                    max_walks=budget, max_steps=self.max_steps,
                    segments_per_vertex=self.index.segments_per_vertex,
                    segment_len=self.index.segment_len)
                walks = min(budget, plan.num_walks if req.num_walks is None
                            else req.num_walks)
                downgraded = True

        deadline = (math.inf if req.slo_s is None
                    else req.t_submit + req.slo_s)
        self.queue.append(_Queued(req=req, plan=plan, walks=walks,
                                  deadline=deadline, downgraded=downgraded))
        return AdmissionDecision(rid=req.rid, admitted=True,
                                 downgraded=downgraded, plan=plan,
                                 num_walks=walks)

    def _reject(self, req: QueryRequest, plan: QueryPlan,
                reason: str) -> AdmissionDecision:
        decision = AdmissionDecision(rid=req.rid, admitted=False,
                                     reason=reason, plan=plan)
        self.rejected.append(decision)
        return decision

    # --- host scheduling --------------------------------------------------

    def _admit(self) -> None:
        """Queued queries claim free slots, earliest deadline first."""
        free = [s for s in range(self.max_queries) if s not in self.active]
        self.queue.sort(key=lambda e: (e.deadline, e.req.t_submit))
        while self.queue and free:
            e = self.queue.pop(0)
            self.active[free.pop(0)] = _Active(
                req=e.req, plan=e.plan, remaining=e.walks,
                total_walks=e.walks, counts=np.zeros(self.g.n, np.int64),
                waves=0, t_submit=e.req.t_submit, deadline=e.deadline,
                downgraded=e.downgraded,
            )

    def _edf_order(self) -> List[int]:
        return sorted(self.active,
                      key=lambda s: (self.active[s].deadline, s))

    def _allocate(self) -> Dict[int, int]:
        """Walk-slot split: equal shares, handed out (and topped up from
        the leftovers) in earliest-deadline-first order — a tight-deadline
        query drains its budget first without starving the rest below
        their fair share."""
        slots = {}
        budget = self.max_walks
        order = self._edf_order()
        share = max(1, budget // max(1, len(order)))
        for s in order:
            take = min(self.active[s].remaining, share, budget)
            slots[s] = take
            budget -= take
        for s in order:                      # leftovers, EDF-greedy
            if budget == 0:
                break
            extra = min(self.active[s].remaining - slots[s], budget)
            slots[s] += extra
            budget -= extra
        return {s: w for s, w in slots.items() if w > 0}

    def step_wave(self) -> bool:
        """Runs one device wave; returns False when nothing is in flight."""
        self._admit()
        if not self.active:
            return False
        alloc = self._allocate()
        W, Q = self.max_walks, self.max_queries
        start = np.zeros(W, np.int32)
        uniform = np.zeros(W, bool)
        qid = np.full(W, Q, np.int32)        # default: discard bin
        t_cap = np.zeros(W, np.int32)
        cursor = 0
        for s, w in alloc.items():
            a = self.active[s]
            sl = slice(cursor, cursor + w)
            qid[sl] = s
            t_cap[sl] = a.plan.num_steps
            if a.req.kind == "ppr":
                start[sl] = a.req.source
            else:
                uniform[sl] = True
            cursor += w

        self._key, k_wave = jax.random.split(self._key)
        t0 = time.perf_counter()
        counts = self._wave(
            jnp.asarray(start), jnp.asarray(uniform), jnp.asarray(qid),
            jnp.asarray(t_cap), k_wave)
        now = time.perf_counter()
        # EMA of measured wave time — feeds the admission budget check. The
        # scheduler's very first wave includes jit compilation (seconds vs
        # steady-state ms) and would poison the estimate into rejecting
        # feasible SLOs, so it is never folded in.
        self._waves_run += 1
        if self._waves_run > 1:
            dt = now - t0
            self._wave_time = (dt if self._wave_time is None
                               else 0.5 * self._wave_time + 0.5 * dt)

        for s, w in alloc.items():
            a = self.active[s]
            a.counts += counts[s]
            a.remaining -= w
            a.executed += w
            a.waves += 1
            early = (a.remaining > 0 and a.req.early_stop
                     and self._anytime_bound(a.plan.num_steps, a.req.k,
                                             a.req.delta, a.executed)
                     <= a.req.epsilon)
            if a.remaining == 0 or early:
                self.finished.append(self._finalize(a, now, early=early))
                del self.active[s]
        return True

    # --- anytime (ε, δ) refinement ---------------------------------------

    def _anytime_bound(self, num_steps: int, k: int, delta: float,
                       executed: int) -> float:
        """The ε Theorem 1 certifies for the walks tallied so far (p_s = 1
        serving walks, p_cap = 0). Monotone non-increasing in ``executed``
        — every extra wave tightens it; ``inf`` before the first wave."""
        if executed < 1:
            return math.inf
        return theory.epsilon_bound(self.p_T, num_steps, k, delta,
                                    executed, 1.0, 0.0)

    def _finalize(self, a: _Active, now: float,
                  early: bool = False) -> QueryResult:
        scores = a.counts / float(a.executed)
        k = min(a.req.k, self.g.n)
        top = np.argsort(-scores, kind="stable")[:k]
        latency = now - a.t_submit
        # Early-stopped (anytime) queries carry the bound their executed
        # walks actually certify; budget-drained queries keep the plan's
        # recorded bound (incl. any admission downgrade).
        bound = (self._anytime_bound(a.plan.num_steps, a.req.k, a.req.delta,
                                     a.executed)
                 if a.req.early_stop else a.plan.epsilon_bound)
        return QueryResult(
            rid=a.req.rid, kind=a.req.kind, vertices=top,
            scores=scores[top], num_walks=a.executed,
            num_steps=a.plan.num_steps, waves=a.waves,
            latency_s=latency,
            epsilon_bound=bound,
            downgraded=a.downgraded,
            met_slo=(None if a.req.slo_s is None
                     else bool(latency <= a.req.slo_s)),
            early_stopped=early,
        )

    # --- anytime introspection (the QueryHandle surface) ------------------

    def query_state(self, rid: int) -> str:
        """``queued`` | ``active`` | ``finished`` | ``rejected`` |
        ``cancelled`` | ``unknown``."""
        if any(r.rid == rid for r in self.finished):
            return "finished"
        if any(a.req.rid == rid for a in self.active.values()):
            return "active"
        if any(e.req.rid == rid for e in self.queue):
            return "queued"
        if rid in self.cancelled:
            return "cancelled"
        if any(d.rid == rid for d in self.rejected):
            return "rejected"
        return "unknown"

    def result_for(self, rid: int) -> QueryResult:
        for r in self.finished:
            if r.rid == rid:
                return r
        raise KeyError(f"query {rid} has no finished result "
                       f"(state: {self.query_state(rid)})")

    def partial(self, rid: int) -> QueryPartial:
        """Anytime snapshot: the current top-k estimate plus the ε the
        tallied walks certify so far (``inf`` before the first wave)."""
        for r in self.finished:
            if r.rid == rid:
                return QueryPartial(
                    rid=rid, kind=r.kind, k=len(r.vertices),
                    vertices=r.vertices, scores=r.scores,
                    walks_done=r.num_walks, waves=r.waves,
                    epsilon_bound=r.epsilon_bound, done=True)
        for a in self.active.values():
            if a.req.rid != rid:
                continue
            k = min(a.req.k, self.g.n)
            if a.executed:
                scores = a.counts / float(a.executed)
                top = np.argsort(-scores, kind="stable")[:k]
                vertices, top_scores = top, scores[top]
            else:
                vertices = np.zeros(0, np.int64)
                top_scores = np.zeros(0, np.float64)
            return QueryPartial(
                rid=rid, kind=a.req.kind, k=k, vertices=vertices,
                scores=top_scores, walks_done=a.executed, waves=a.waves,
                epsilon_bound=self._anytime_bound(
                    a.plan.num_steps, a.req.k, a.req.delta, a.executed),
                done=False)
        for e in self.queue:
            if e.req.rid == rid:
                return QueryPartial(
                    rid=rid, kind=e.req.kind, k=min(e.req.k, self.g.n),
                    vertices=np.zeros(0, np.int64),
                    scores=np.zeros(0, np.float64),
                    walks_done=0, waves=0, epsilon_bound=math.inf,
                    done=False)
        raise KeyError(f"no in-flight query {rid} "
                       f"(state: {self.query_state(rid)})")

    def cancel(self, rid: int) -> bool:
        """Drops a queued or in-flight query (its tallies are discarded).
        Returns False when there is nothing left to cancel."""
        for i, e in enumerate(self.queue):
            if e.req.rid == rid:
                del self.queue[i]
                self.cancelled.append(rid)
                return True
        for s, a in list(self.active.items()):
            if a.req.rid == rid:
                del self.active[s]
                self.cancelled.append(rid)
                return True
        return False

    def run(self) -> List[QueryResult]:
        """Deprecated entry point — use :meth:`repro.service.
        FrogWildService.drain` (or drive :class:`~repro.service.QueryHandle`
        futures via ``poll()`` / ``result()``)."""
        warn_deprecated("QueryScheduler.run", "FrogWildService.drain")
        return self._drain()

    def _drain(self) -> List[QueryResult]:
        """Drains queue + in-flight queries; returns results in finish order."""
        while self.step_wave():
            pass
        return self.finished
