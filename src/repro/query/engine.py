"""Online query engine: stitch precomputed walk segments into query answers.

A FrogWild walk truncated at ``t`` steps takes ``τ = min(G, t)`` moves with
``P(G = m) = p_T (1 − p_T)^m`` (the Geometric death clock of Process 15, so
``τ`` moves are followed by the tally). The engine samples ``τ`` per walk up
front and composes the τ-step walk from the index:

    τ = q · L + r,   q = τ // L,  r = τ mod L
    → ``r`` direct walker steps, then ``q`` segment stitches (each stitch
      gathers one uniformly-chosen precomputed endpoint of the walk's
      current vertex — an exact sample of ``P^L``).

The composed endpoint is distributed exactly as a τ-step walk
(tests/test_query.py, chi-square + TV against the direct walk) as long as a
walk never rereads a slab cell: round ``j`` reads slot ``(s0 + j) mod R``
(per-walk random offset ``s0``), so cells can only repeat after R stitches —
pick ``R ≥ t/L`` and every gather is a fresh ``P^L`` sample. Sharing cells
*across* walks correlates them (inflating estimator variance FAST-PPR-style
by ≈ ``1 + q̄/R``) but never biases a walk's own marginal.

Per-query planning inverts Theorem 1 at ``p_s = 1`` (index segments are
fully-synced walks): the mixing term bounds ``t``, the ``1/N`` sampling term
bounds the walk count, each at ``ε/2`` — so the served estimate carries the
same ``(ε, δ)`` guarantee as an offline run with those parameters.

Geometry of the work: a query of ``N`` walks costs ``N·(r̄ + τ̄/L)`` gathers
instead of the restart baseline's ``N·τ̄`` CSR draws — the stitch divides
the per-walk step count by ``L`` (benchmarks/bench_query.py measures the
end-to-end queries/sec win).
"""
from __future__ import annotations

import dataclasses
import math
import warnings
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import theory
from repro.distributed.runtime import record_wave_trace
from repro.graph.csr import CSRGraph, uniform_successor
from repro.kernels import ops
from repro.query.index import WalkIndex

# Donating the walk-state operands lets XLA write wave outputs into the
# dead input buffers; when a buffer's shape/layout doesn't match any output
# jax emits a UserWarning per compile. That mismatch is expected here (the
# tally output is [Q+1, n], the donated operands are [W]) and harmless —
# silence exactly that message, nothing else.
warnings.filterwarnings(
    "ignore", message="Some donated buffers were not usable")


@dataclasses.dataclass(frozen=True)
class QueryPlan:
    """Device-program shape for one query, derived from ``(ε, δ)``.

    ``num_steps`` is the walk truncation ``t`` (mixing term ≤ ε/2) and
    ``num_walks`` the sample count ``N`` (sampling term ≤ ε/2), so Theorem 1
    gives ``μ_k(π̂) > μ_k(π) − ε`` w.p. ≥ 1 − δ — *unless* the caller's
    ``max_steps`` / ``max_walks`` caps truncated the inversion, in which
    case ``epsilon_bound`` (the ε Theorem 1 actually certifies for this
    (t, N)) exceeds the requested ``epsilon``; check it when the guarantee
    matters.
    """

    num_walks: int
    num_steps: int
    epsilon: float               # requested
    delta: float
    k: int
    epsilon_bound: float = 0.0   # achieved (== requested iff no cap bound)

    def num_rounds(self, segment_len: int) -> int:
        """Stitch rounds needed: ``⌊t/L⌋`` (the residual covers ``t mod L``)."""
        return self.num_steps // segment_len


def plan_query(
    k: int,
    epsilon: float,
    delta: float = 0.1,
    p_T: float = 0.15,
    max_walks: Optional[int] = None,
    max_steps: int = 64,
    segments_per_vertex: Optional[int] = None,
    segment_len: Optional[int] = None,
) -> QueryPlan:
    """Inverts Theorem 1 into ``(t, N)`` at ``p_s = 1``.

    mixing_term(p_T, t) ≤ ε/2  ⇔  (1−p_T)^{t+1} ≤ (ε/2)² p_T
    sampling_term = √(k/(δN)) ≤ ε/2  ⇔  N ≥ 4k/(δ ε²)

    With the serving index's ``(segments_per_vertex, segment_len)`` =
    ``(R, L)`` given, ``t`` is additionally clamped to the reuse-free stitch
    budget ``⌊t/L⌋ ≤ R`` (i.e. ``t ≤ R·L + L − 1``): beyond it a walk can
    reread a slab cell and the stitched marginal is biased (see
    :func:`check_segment_budget`), so the plan trades the silent bias for
    an honest, *recorded* truncation — ``epsilon_bound`` then exceeds the
    requested ``epsilon`` exactly as for any other binding cap.
    """
    if not (0.0 < epsilon):
        raise ValueError(f"epsilon must be positive, got {epsilon}")
    if (segments_per_vertex is None) != (segment_len is None):
        raise ValueError(
            "segments_per_vertex and segment_len come as a pair (the "
            "index's (R, L)); got only one of them")
    target = (epsilon / 2.0) ** 2 * p_T
    if target >= 1.0:
        t = 1
    else:
        t = max(1, math.ceil(math.log(target) / math.log(1.0 - p_T) - 1.0))
    t = min(t, max_steps)
    if segments_per_vertex is not None:
        t = min(t, segments_per_vertex * segment_len + segment_len - 1)
    n_walks = max(1, math.ceil(4.0 * k / (delta * epsilon**2)))
    if max_walks is not None:
        n_walks = min(n_walks, max_walks)
    achieved = theory.epsilon_bound(p_T, t, k, delta, n_walks, 1.0, 0.0)
    return QueryPlan(num_walks=n_walks, num_steps=t, epsilon=epsilon,
                     delta=delta, k=k, epsilon_bound=achieved)


def check_segment_budget(segments_per_vertex: int, num_rounds: int) -> None:
    """Warns when the index cannot cover the stitch budget reuse-free.

    The slot rotation only guarantees a walk never rereads a slab cell while
    its stitch count stays ≤ R; with ``num_rounds > R`` a walk that revisits
    a vertex R rounds later rereads a cell and deterministically repeats the
    hop — a small statistical bias. Serving still works, but the exactness
    claim doesn't hold; rebuild the index with R ≥ t/L to restore it.

    Planned queries never get here: :func:`plan_query` given the index's
    ``(R, L)`` clamps ``t`` to the reuse-free budget up front and records
    the truncation in ``epsilon_bound`` — this warning is the safety net
    for hand-built plans / direct ``walk_wave`` callers.
    """
    if num_rounds > segments_per_vertex:
        warnings.warn(
            f"walk index has R={segments_per_vertex} segments/vertex but the "
            f"query plan needs up to {num_rounds} stitch rounds: walks may "
            f"reread segments and the stitched distribution is no longer "
            f"exact. Rebuild with segments_per_vertex ≥ {num_rounds}.",
            stacklevel=3,
        )


def sample_walk_lengths(
    key: jax.Array, num_walks: int, p_T: float, max_steps
) -> jnp.ndarray:
    """``τ ~ min(Geometric(p_T), max_steps)`` per walk (number of moves).

    ``max_steps`` may be a scalar or an int32[W] per-walk truncation (the
    scheduler packs queries with different planned ``t`` into one wave).
    """
    u = jnp.maximum(jax.random.uniform(key, (num_walks,)), 1e-12)
    m = jnp.floor(jnp.log(u) / math.log(1.0 - p_T)).astype(jnp.int32)
    return jnp.clip(m, 0, max_steps).astype(jnp.int32)


def _plain_steps(
    row_ptr: jnp.ndarray,
    col_idx: jnp.ndarray,
    deg: jnp.ndarray,
    pos: jnp.ndarray,
    active_until: jnp.ndarray,   # int32[W] — walk takes steps s < active_until
    key: jax.Array,
    num_steps: int,
) -> jnp.ndarray:
    """``active_until[w]`` masked plain walker steps (the stitch residual)."""
    if num_steps == 0:
        return pos

    def step(carry, k):
        pos, s = carry
        bits = jax.random.randint(k, pos.shape, 0, 1 << 30, jnp.int32)
        nxt = uniform_successor(row_ptr, col_idx, deg, pos, bits)
        pos = jnp.where(s < active_until, nxt, pos)
        return (pos, s + 1), None

    (pos, _), _ = jax.lax.scan(
        step, (pos, jnp.int32(0)), jax.random.split(key, num_steps))
    return pos


@dataclasses.dataclass(frozen=True)
class WaveSpec:
    """Static geometry of one compiled scheduler wave program — the AOT
    ladder cache key (:class:`repro.distributed.runtime.WaveProgramCache`).

    ``(W, Q)`` are the *bucket* shapes (walk slots / query slots the
    operands are padded to), ``(S, sz)`` the shard granularity of the
    eviction mask (``S=1, sz=n`` for a dense slab — the mask never flips),
    and ``q_max`` the static stitch-round budget the ``lax.scan`` runs.
    Everything that changes the traced Python body is in here; arrays
    (slab, graph, walk state) are operands, so two schedulers with equal
    specs share one executable.
    """

    n: int               # graph vertices (tally bins per query row)
    R: int               # segments per vertex
    L: int               # segment length
    q_max: int           # stitch rounds (lax.scan length)
    S: int               # shards (eviction-mask entries)
    sz: int              # shard size (n for dense)
    W: int               # walk-slot bucket
    Q: int               # query-slot bucket
    p_T: float           # geometric stop probability (baked into lengths)
    impl: str            # stitch backend: xla | pallas | ref
    tally_impl: str      # histogram backend: ref | sort | pallas | auto
    donate: bool         # donate walk-state operands to the executable


def wave_prep(
    row_ptr: jnp.ndarray,
    col_idx: jnp.ndarray,
    deg: jnp.ndarray,
    start: jnp.ndarray,          # int32[W] — pinned start vertex (PPR)
    uniform: jnp.ndarray,        # bool[W]  — True → uniform random start
    t_cap: jnp.ndarray,          # int32[W] — per-walk truncation cap
    key: jax.Array,
    *,
    n: int,
    L: int,
    p_T: float,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Shared wave prologue: starts, lengths, residual steps, slot offsets.

    One definition so every dispatch path (fused, mesh, legacy host loop)
    consumes the *same* key stream — the byte-identity contract across
    paths reduces to "same prologue, same rounds".
    Returns ``(pos int32[W], q int32[W], s0 int32[W])``.
    """
    W = start.shape[0]
    k_start, k_tau, k_walk = jax.random.split(key, 3)
    pos0 = jnp.where(
        uniform,
        jax.random.randint(k_start, (W,), 0, n, dtype=jnp.int32),
        start,
    )
    tau = sample_walk_lengths(k_tau, W, p_T, t_cap)
    k_res, k_slot = jax.random.split(k_walk)
    q = tau // L
    pos = _plain_steps(row_ptr, col_idx, deg, pos0, tau % L, k_res, L)
    s0 = jax.random.randint(k_slot, pos.shape, 0, 1 << 30, jnp.int32)
    return pos, q, s0


def build_wave_program(spec: WaveSpec):
    """One fused, jitted wave program for ``spec``: prologue + ``lax.scan``
    over stitch rounds + one final histogram — a single device dispatch
    where the legacy sharded host loop paid ``S × q_max`` of them.

    Signature of the returned program::

        wave(slab_flat, row_ptr, col_idx, deg,
             start, uniform, qid, t_cap, key_data, lost) -> int32[Q, n]

    ``slab_flat`` is the flat endpoint slab — the dense ``[n, R]`` slab, or
    the sharded index's stacked blocks ``[S·sz, R]`` flattened (row-padded;
    walk positions are graph vertices < n ≤ S·sz, so padding rows are never
    gathered). Because every walk is owned by exactly one shard and the
    other shards contribute the additive identity, gathering from the
    stacked slab is *bit-identical* to the per-shard masked-gather-and-sum
    the host loop runs — which is what lets one program serve both the
    gathered and the sharded single-device paths.

    ``lost`` is the bool[S] eviction mask: a walk that still needs a gather
    while sitting in a lost shard's endpoint range — or whose final vertex
    lands in one — dies (position frozen, routed to the ``Q`` discard row).
    All-False masks leave the program bit-identical to an unmasked one.

    With ``spec.donate`` the walk-state operands (start / uniform / qid /
    t_cap / lost) are donated — they are dead after the prologue, so XLA
    may reuse their buffers instead of round-tripping fresh allocations
    every wave. ``key_data`` is never donated (callers re-derive it from a
    live key across fault-supervision retries).
    """
    n, R, L, Q, S, sz = spec.n, spec.R, spec.L, spec.Q, spec.S, spec.sz

    def wave(slab_flat, row_ptr, col_idx, deg,
             start, uniform, qid, t_cap, key_data, lost):
        record_wave_trace(spec)   # executes while tracing, not per call
        key = jax.random.wrap_key_data(key_data, impl="threefry2x32")
        pos, q, s0 = wave_prep(row_ptr, col_idx, deg, start, uniform,
                               t_cap, key, n=n, L=L, p_T=spec.p_T)
        alive = jnp.ones(pos.shape, bool)

        def round_fn(carry, j):
            pos, alive = carry
            alive = alive & ~(lost[jnp.clip(pos // sz, 0, S - 1)] & (j < q))
            if spec.impl == "xla":
                nxt = jnp.take(slab_flat, pos * R + (s0 + j) % R, axis=0)
            else:
                # gather-only stitch kernel: the per-round tally is not
                # computed at all (the wave histograms once, below).
                nxt, _ = ops.stitch_step(
                    pos, (q == j).astype(jnp.int32), s0 + j,
                    slab_flat.reshape(-1, R), n, impl=spec.impl,
                    tally=False)
            pos = jnp.where((j < q) & alive, nxt, pos)
            return (pos, alive), None

        if spec.q_max > 0:
            (pos, alive), _ = jax.lax.scan(
                round_fn, (pos, alive),
                jnp.arange(spec.q_max, dtype=jnp.int32))
        alive = alive & ~lost[jnp.clip(pos // sz, 0, S - 1)]
        qid_eff = jnp.where(alive, qid, Q)   # dead walks → discard bin
        counts = ops.frog_count(pos + qid_eff * n, (Q + 1) * n,
                                impl=spec.tally_impl)
        return counts.reshape(Q + 1, n)[:Q]

    donate = (4, 5, 6, 7, 9) if spec.donate else ()
    return jax.jit(wave, donate_argnums=donate)


def walk_wave(
    row_ptr: jnp.ndarray,
    col_idx: jnp.ndarray,
    deg: jnp.ndarray,
    endpoints: jnp.ndarray,      # int32[n, R] — index slab
    pos0: jnp.ndarray,           # int32[W] — per-walk start vertex
    tau: jnp.ndarray,            # int32[W] — per-walk total moves (≤ L·q_max + L−1)
    key: jax.Array,
    segment_len: int,
    num_rounds: int,             # q_max — static stitch-round budget
    impl: str = "xla",           # xla | pallas | ref — stitch backend
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Advances ``W`` walks by ``τ`` moves each via residual + stitching.

    Returns ``(final_pos int32[W], stop_counts int32[n])``. ``stop_counts``
    comes from the fused gather-and-tally kernel (``impl != "xla"``): round
    ``j`` tallies walks with ``q == j`` while gathering the next segment for
    walks with ``q > j``. With ``impl == "xla"`` the tally is deferred to
    one final histogram over ``final_pos`` — the two are identical because a
    stopped walk's position never changes (tests assert count equality).
    """
    L = segment_len
    n = deg.shape[0]
    R = endpoints.shape[1]
    k_res, k_slot = jax.random.split(key)
    q = tau // L
    r = tau % L
    # residual first: r < L direct steps (order of composition is free —
    # any r + q·L decomposition yields the same τ-step marginal).
    pos = _plain_steps(row_ptr, col_idx, deg, pos0, r, k_res, L)

    # Anti-reuse slot rotation: round j reads slot (s0 + j) mod R. A walk
    # that revisits a vertex therefore never rereads a slab cell while its
    # stitch count stays ≤ R, so every gather is a *fresh* P^L sample and
    # the composed marginal is exact (rereading a cell would deterministically
    # repeat the hop — a measurable bias, see tests). s0 is uniform per walk,
    # so each individual read is still a uniform slot.
    s0 = jax.random.randint(k_slot, pos.shape, 0, 1 << 30, jnp.int32)

    if impl == "xla":
        def round_(carry, j):
            pos, = carry
            nxt = jnp.take(endpoints.reshape(-1),
                           pos * R + (s0 + j) % R, axis=0)
            pos = jnp.where(j < q, nxt, pos)
            return (pos,), None

        if num_rounds > 0:
            (pos,), _ = jax.lax.scan(
                round_, (pos,), jnp.arange(num_rounds, dtype=jnp.int32))
        counts = ops.frog_count(pos, n, impl="ref")
        return pos, counts

    # fused gather-and-tally path: num_rounds + 1 kernel rounds, the last
    # only tallies walks that used the full stitch budget.
    counts = jnp.zeros((n,), jnp.int32)
    for j in range(num_rounds + 1):
        nxt, c = ops.stitch_step(
            pos, (q == j).astype(jnp.int32), s0 + j, endpoints, n, impl=impl)
        counts = counts + c
        pos = jnp.where(j < q, nxt, pos)
    return pos, counts


def query_counts(
    g: CSRGraph,
    index: WalkIndex,
    plan: QueryPlan,
    key: jax.Array,
    source: Optional[int] = None,
    p_T: float = 0.15,
    impl: str = "xla",
) -> jnp.ndarray:
    """Single-query convenience: the stop-counter histogram ``int32[n]``.

    ``source=None`` → global top-k start distribution (uniform over
    vertices, the FrogWild estimator); ``source=v`` → personalized PageRank
    from ``v`` (walk endpoints of Geometric(p_T)-length walks from ``v`` are
    PPR(v) samples with damping 1 − p_T). ``π̂ = counts / num_walks``.
    """
    W = plan.num_walks
    check_segment_budget(index.segments_per_vertex,
                         plan.num_rounds(index.segment_len))
    k_start, k_tau, k_walk = jax.random.split(key, 3)
    if source is None:
        pos0 = jax.random.randint(k_start, (W,), 0, g.n, dtype=jnp.int32)
    else:
        if not 0 <= source < g.n:
            # XLA gathers clamp out-of-range indices, which would silently
            # answer for vertex 0 / n-1 instead of the caller's vertex.
            raise ValueError(f"ppr source {source} outside [0, {g.n})")
        pos0 = jnp.full((W,), source, dtype=jnp.int32)
    tau = sample_walk_lengths(k_tau, W, p_T, plan.num_steps)
    _, counts = walk_wave(
        g.row_ptr, g.col_idx, g.out_deg, index.endpoints,
        pos0, tau, k_walk, index.segment_len,
        plan.num_rounds(index.segment_len), impl=impl,
    )
    return counts
