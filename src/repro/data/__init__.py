"""Data pipeline: deterministic synthetic LM stream + graph workloads."""
from repro.data.tokens import SyntheticTokens

__all__ = ["SyntheticTokens"]
