"""Deterministic synthetic LM token stream.

Step-indexed PRNG (threefry fold-in of the step number) means the pipeline
is **stateless-resumable**: after a restart from checkpoint step k, batch k+1
is bit-identical — no shard iterators to persist. Per-host sharding slices
the global batch by process index (single-process here, but the arithmetic
is the multi-host one).

The stream is a learnable mixture (repeated n-grams + structural tokens),
not uniform noise, so smoke-training runs show real loss decrease.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SyntheticTokens:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_patterns: int = 64          # learnable n-gram pool size
    process_index: int = 0
    process_count: int = 1

    @property
    def local_batch(self) -> int:
        assert self.global_batch % self.process_count == 0
        return self.global_batch // self.process_count

    def _pattern_table(self) -> jnp.ndarray:
        key = jax.random.PRNGKey(self.seed)
        return jax.random.randint(
            key, (self.num_patterns, 8), 2, self.vocab_size, dtype=jnp.int32)

    def batch(self, step: int) -> Dict[str, jnp.ndarray]:
        """Returns {"tokens": [B, S], "labels": [B, S]} for this host."""
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed ^ 0x5EED), step)
        key = jax.random.fold_in(key, self.process_index)
        B, S = self.local_batch, self.seq_len
        table = self._pattern_table()
        n_slots = (S + 1 + 7) // 8
        pat = jax.random.randint(key, (B, n_slots), 0, self.num_patterns,
                                 dtype=jnp.int32)
        seq = table[pat].reshape(B, n_slots * 8)[:, : S + 1]
        return {"tokens": seq[:, :-1], "labels": seq[:, 1:]}
