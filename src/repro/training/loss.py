"""LM loss: causal cross-entropy with f32 logits, z-loss and masking."""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.context import constrain


def lm_loss(
    logits: jnp.ndarray,               # [B, S, V] (any float dtype)
    labels: jnp.ndarray,               # int32[B, S]
    mask: Optional[jnp.ndarray] = None,  # f32/bool[B, S]; None = all valid
    z_loss: float = 1e-4,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Next-token cross entropy (labels are already shifted by the data
    pipeline). Returns (scalar loss, metrics)."""
    lf = constrain(logits.astype(jnp.float32), "logits")
    lse = constrain(jax.nn.logsumexp(lf, axis=-1), "bt")         # [B, S]
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    zl = jnp.square(lse)
    if mask is None:
        mask = jnp.ones(labels.shape, jnp.float32)
    mask = mask.astype(jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = (nll * mask).sum() / denom
    total = loss + z_loss * (zl * mask).sum() / denom
    acc = ((lf.argmax(-1) == labels) * mask).sum() / denom
    return total, {"ce_loss": loss, "accuracy": acc,
                   "tokens": mask.sum()}
