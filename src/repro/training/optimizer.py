"""AdamW, from scratch (no optax in this container).

Mixed precision: parameters are stored in ``param_dtype`` (f32 master);
moments in f32. The optimizer-state tree mirrors the param tree, so the
FSDP/TP PartitionSpecs from distributed/sharding.py apply leaf-for-leaf —
optimizer state is sharded exactly like parameter storage (ZeRO-1/3 for
free under GSPMD).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def adamw_init(params) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def lr_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup → cosine decay to min_lr_ratio·lr."""
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * frac


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(
    grads, opt_state: Dict[str, Any], params, cfg: AdamWConfig
) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    """One AdamW step with global-norm clipping. Returns
    (params, opt_state, metrics)."""
    step = opt_state["step"]
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** (step.astype(jnp.float32) + 1.0)
    bc2 = 1.0 - b2 ** (step.astype(jnp.float32) + 1.0)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m_new = b1 * m + (1.0 - b1) * g
        v_new = b2 * v + (1.0 - b2) * g * g
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:                      # decoupled decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step + 1}, metrics
