"""FrogWild-style partial synchronization for data-parallel gradients.

This is the paper's contribution exported to LM training (DESIGN.md §3).
Two granularities:

* ``shard``  — each data shard's gradient enters the all-reduce with
  probability p_s, rescaled 1/p_s (unbiased — the exact analogue of the
  Binomial scatter marginal). Uses ``core.partial_sync.partial_psum`` inside
  a manual-over-data shard_map.
* ``layer``  — per step, each top-level parameter block wins the sync
  lottery with probability p_s *consistently across shards* (replicated
  coin). Losing blocks skip their all-reduce entirely that step and the
  local gradient accumulates in an error-feedback residual — this is the
  variant whose *wire bytes actually shrink* even under dense collectives,
  because the psum op is simply not executed for unsynced blocks.

Like the engine, correctness degrades gracefully in p_s and the same
Theorem-1-style variance pricing applies (the gradient estimate stays
unbiased in "shard" mode; "layer" mode's residuals telescope).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.partial_sync import partial_psum


@dataclasses.dataclass(frozen=True)
class PartialSyncConfig:
    p_s: float = 1.0
    granularity: str = "shard"      # shard | layer
    mode: str = "unbiased"          # unbiased | error_feedback (shard gran.)


def sync_grads_shard(
    grads, axis_name, p_s: float, key: jax.Array, mode: str = "unbiased",
    residual=None,
):
    """Per-shard lottery all-reduce (call inside shard_map over data axes)."""
    n = jax.lax.psum(jnp.ones(()), axis_name)
    if mode == "unbiased":
        out = partial_psum(grads, axis_name, p_s, key, mode="unbiased")
        return jax.tree.map(lambda g: g / n, out), residual
    out, residual = partial_psum(grads, axis_name, p_s, key,
                                 mode="error_feedback", residual=residual)
    return jax.tree.map(lambda g: g / n, out), residual


def sync_grads_layer(
    grads, axis_name, p_s: float, key: jax.Array, residual=None,
) -> Tuple[Any, Any]:
    """Layer-lottery all-reduce with error feedback.

    The coin is *replicated* (not folded with the shard index), so every
    shard agrees on which blocks sync — collectives stay congruent. Unsynced
    blocks keep g_local + residual for the next round.
    """
    n = jax.lax.psum(jnp.ones(()), axis_name)
    leaves, treedef = jax.tree.flatten(grads)
    if residual is None:
        res_leaves = [jnp.zeros_like(g) for g in leaves]
    else:
        res_leaves = treedef.flatten_up_to(residual)
    out_leaves, new_res = [], []
    for i, (g, r) in enumerate(zip(leaves, res_leaves)):
        coin = jax.random.bernoulli(jax.random.fold_in(key, i), p_s)
        msg = g + r
        # cond so the psum is genuinely skipped when the block loses —
        # this is where the wire bytes go away.
        synced = jax.lax.cond(
            coin,
            lambda m: jax.lax.psum(m, axis_name) / n,
            lambda m: jnp.zeros_like(m),
            msg,
        )
        out_leaves.append(synced)
        new_res.append(jnp.where(coin, jnp.zeros_like(msg), msg))
    return treedef.unflatten(out_leaves), treedef.unflatten(new_res)
