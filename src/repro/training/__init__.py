"""Training substrate: optimizer, loss, gradient sync, train step factory."""
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update
from repro.training.loss import lm_loss
from repro.training.train_step import TrainStepConfig, make_train_step
from repro.training.grad_sync import PartialSyncConfig

__all__ = [
    "AdamWConfig",
    "adamw_init",
    "adamw_update",
    "lm_loss",
    "TrainStepConfig",
    "make_train_step",
    "PartialSyncConfig",
]
