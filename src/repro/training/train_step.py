"""Train-step factory: baseline GSPMD mode and FrogWild partial-sync mode.

* ``mode="gspmd"``   — single jit; batch sharded over data axes, params TP
  (+FSDP) sharded; XLA inserts the gradient all-reduce. This is the
  reference data-flow every dry-run cell lowers.
* ``mode="partial_sync"`` — the paper's technique on the DP boundary:
  shard_map manual over the data axes (model axis stays auto/GSPMD), local
  backward, then the p_s-lottery gradient synchronization from grad_sync.py.
  Carries an error-feedback residual in the train state.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.transformer import forward_train
from repro.training.grad_sync import (
    PartialSyncConfig,
    sync_grads_layer,
    sync_grads_shard,
)
from repro.training.loss import lm_loss
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update


@dataclasses.dataclass(frozen=True)
class TrainStepConfig:
    opt: AdamWConfig = AdamWConfig()
    remat: bool = True
    moe_aux_weight: float = 0.01
    mode: str = "gspmd"                     # gspmd | partial_sync
    partial_sync: PartialSyncConfig = PartialSyncConfig()
    accum_steps: int = 1                    # microbatches per optimizer step


def _loss_fn(params, batch, cfg: ModelConfig, tcfg: TrainStepConfig):
    logits, aux = forward_train(params, batch, cfg, remat=tcfg.remat)
    if cfg.family == "vlm":
        logits = logits[:, cfg.num_prefix_embeddings:]
    loss, metrics = lm_loss(logits, batch["labels"])
    if "moe_aux_loss" in aux:
        loss = loss + tcfg.moe_aux_weight * aux["moe_aux_loss"]
        metrics["moe_aux_loss"] = aux["moe_aux_loss"]
    return loss, metrics


def make_train_step(cfg: ModelConfig, tcfg: TrainStepConfig,
                    mesh: Optional[Mesh] = None,
                    data_axes: Tuple[str, ...] = ("data",)):
    """Returns ``step(train_state, batch, key) -> (train_state, metrics)``.

    train_state = {"params", "opt", ["residual"]}. Not jitted here — the
    launcher jits with in/out shardings (dry-run) or plainly (tests).
    """
    if tcfg.mode == "gspmd":
        def step(state, batch, key):
            params, opt_state = state["params"], state["opt"]
            A = tcfg.accum_steps
            if A <= 1:
                (loss, metrics), grads = jax.value_and_grad(
                    _loss_fn, has_aux=True)(params, batch, cfg, tcfg)
            else:
                # microbatching: sequential scan over batch slices with f32
                # gradient accumulation — activation transients scale 1/A
                # while params/optimizer memory is unchanged. Standard at
                # 64k-tokens-per-chip batch shapes.
                mb = jax.tree.map(
                    lambda a: a.reshape(A, a.shape[0] // A, *a.shape[1:]),
                    batch)

                def micro(carry, mslice):
                    g_acc, l_acc = carry
                    (loss, metrics), grads = jax.value_and_grad(
                        _loss_fn, has_aux=True)(params, mslice, cfg, tcfg)
                    g_acc = jax.tree.map(
                        lambda a, g: a + g.astype(jnp.float32), g_acc, grads)
                    return (g_acc, l_acc + loss), metrics

                g0 = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (grads, loss), metrics = jax.lax.scan(
                    micro, (g0, jnp.zeros((), jnp.float32)), mb)
                grads = jax.tree.map(lambda g: g / A, grads)
                loss = loss / A
                metrics = jax.tree.map(lambda m: m.mean(), metrics)
            params, opt_state, om = adamw_update(grads, opt_state, params,
                                                 tcfg.opt)
            metrics = dict(metrics, loss=loss, **om)
            return {"params": params, "opt": opt_state}, metrics

        return step

    if tcfg.mode != "partial_sync":
        raise ValueError(tcfg.mode)
    if mesh is None:
        raise ValueError("partial_sync mode needs the mesh")
    ps = tcfg.partial_sync
    axis = data_axes if len(data_axes) > 1 else data_axes[0]

    def shard_body(params, opt_state, residual, batch, key):
        (loss, metrics), grads = jax.value_and_grad(
            _loss_fn, has_aux=True)(params, batch, cfg, tcfg)
        me = jax.lax.axis_index(axis) if not isinstance(axis, tuple) else (
            jax.lax.axis_index(axis[0]))
        shard_key = key                      # folded inside partial_psum
        if ps.granularity == "shard":
            grads, residual = sync_grads_shard(
                grads, axis, ps.p_s, shard_key, mode=ps.mode,
                residual=residual)
        else:
            grads, residual = sync_grads_layer(
                grads, axis, ps.p_s, shard_key, residual=residual)
        params, opt_state, om = adamw_update(grads, opt_state, params,
                                             tcfg.opt)
        n = jax.lax.psum(jnp.ones(()), axis)
        metrics = {k: jax.lax.psum(v, axis) / n for k, v in metrics.items()}
        metrics = dict(metrics, loss=jax.lax.psum(loss, axis) / n, **om)
        return params, opt_state, residual, metrics

    manual = set(data_axes)
    batch_spec = P(axis)

    def step(state, batch, key):
        params, opt_state = state["params"], state["opt"]
        residual = state.get("residual")
        if residual is None:
            residual = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                    params)
        in_batch_specs = jax.tree.map(lambda _: batch_spec, batch)
        fn = jax.shard_map(
            shard_body,
            mesh=mesh,
            in_specs=(P(), P(), P(), in_batch_specs, P()),
            out_specs=(P(), P(), P(), P()),
            axis_names=manual,
            check_vma=False,
        )
        params, opt_state, residual, metrics = fn(
            params, opt_state, residual, batch, key)
        return {"params": params, "opt": opt_state, "residual": residual}, metrics

    return step


def init_train_state(cfg: ModelConfig, key: jax.Array,
                     tcfg: Optional[TrainStepConfig] = None) -> Dict[str, Any]:
    from repro.models.transformer import init_params

    params = init_params(cfg, key)
    state = {"params": params, "opt": adamw_init(params)}
    if tcfg is not None and tcfg.mode == "partial_sync":
        state["residual"] = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return state
