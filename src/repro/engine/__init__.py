"""Distributed GAS engine: the GraphLab-PowerGraph role, on a JAX mesh.

``gas.py`` runs FrogWild! supersteps over a 1-D "vertex" mesh axis with the
paper's randomized partial synchronization; ``baseline.py`` is the
distributed GraphLab-PR power iteration it is compared against;
``netcost.py`` is the wire-byte cost model (what GraphLab's network counters
measured).
"""
from repro.engine.gas import (
    DistributedGraph,
    EngineConfig,
    EngineResult,
    build_distributed_graph,
    distributed_frogwild,
)
from repro.engine.baseline import distributed_power_iteration
from repro.engine.netcost import (
    frogwild_bytes_model,
    pagerank_bytes_model,
)

__all__ = [
    "DistributedGraph",
    "EngineConfig",
    "EngineResult",
    "build_distributed_graph",
    "distributed_frogwild",
    "distributed_power_iteration",
    "frogwild_bytes_model",
    "pagerank_bytes_model",
]
