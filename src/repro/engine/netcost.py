"""Wire-byte cost models — what GraphLab's network counters measured.

The paper's headline systems numbers (Fig. 1c, Fig. 8, Fig. 4 circle areas)
are bytes on the wire. XLA's dense collectives always move the full buffer,
so the *semantic* savings of partial synchronization are accounted here the
way a sparse transport (GraphLab's, or a ragged all-to-all) would see them:

* FrogWild: per superstep, each open (shard→shard) channel costs a header
  plus 4 bytes per frog in it; closed channels cost nothing. The engine
  reports measured per-step sent-frog and open-channel counts.
* GraphLab-PR: every iteration synchronizes every replica of every vertex —
  in our range-sharded formulation, an all-gather of the f32 rank vector
  (each shard receives n − n/S values, ×4 bytes), plus the same on the
  apply-side accumulate (reduce). This is the O(E)-ish dense traffic the
  paper contrasts against.

These models are validated against the *compiled* collective bytes parsed
from dry-run HLO in EXPERIMENTS.md §Dry-run (dense upper bound) and used for
the Fig-1c/Fig-8 reproductions.
"""
from __future__ import annotations

import dataclasses

import numpy as np

SYNC_MSG_BYTES = 64            # one (vertex, mirror) sync: program + data
FROG_PAYLOAD_BYTES = 4         # one int32 vertex id per frog (no identity)
RANK_BYTES = 4                 # f32 PageRank value


@dataclasses.dataclass(frozen=True)
class BytesReport:
    total: float
    per_step: np.ndarray

    def __str__(self) -> str:
        return f"{self.total / 1e6:.3f} MB total ({len(self.per_step)} steps)"


def frogwild_bytes_measured(
    sent_per_step: np.ndarray, sync_msgs_per_step: np.ndarray
) -> BytesReport:
    """Bytes from engine-measured statistics (the paper's Fig-8 counter).

    Dominant term: (active vertex, mirror) sync messages — each costs the
    vertex-program/data envelope, and p_s throttles exactly these. Frog
    payloads ride along at 4 bytes each.
    """
    per_step = (
        sent_per_step.astype(np.float64) * FROG_PAYLOAD_BYTES
        + sync_msgs_per_step.astype(np.float64) * SYNC_MSG_BYTES
    )
    return BytesReport(total=float(per_step.sum()), per_step=per_step)


def frogwild_bytes_model(
    N: int, t: int, p_T: float, p_s: float, S: int, avg_mirrors: float = 4.0
) -> BytesReport:
    """Analytic expectation. Alive frogs decay as (1−p_T)^τ. Active vertices
    ≈ alive frogs (sub-linear collisions at N ≪ n); each syncs an expected
    p_s · avg_mirrors channels. avg_mirrors = E[# distinct destination shards
    per vertex] (graph-dependent, ≤ min(S, avg out-degree))."""
    per_step = []
    for tau in range(t):
        alive = N * (1.0 - p_T) ** (tau + 1)
        syncs = alive * p_s * avg_mirrors
        per_step.append(alive * FROG_PAYLOAD_BYTES + syncs * SYNC_MSG_BYTES)
    arr = np.asarray(per_step)
    return BytesReport(total=float(arr.sum()), per_step=arr)


def pagerank_bytes_model(n: int, num_iters: int, S: int) -> BytesReport:
    """Dense rank synchronization: all-gather (recv (S−1)·n/S values per
    shard, S shards) per iteration — 2× for the gather+apply round trip."""
    per_iter = 2.0 * (S - 1) * n * RANK_BYTES
    arr = np.full(num_iters, per_iter)
    return BytesReport(total=float(arr.sum()), per_step=arr)
