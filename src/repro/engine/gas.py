"""Distributed FrogWild! over a JAX mesh — the PowerGraph role.

Vertices are range-sharded over a 1-D ``"vertex"`` mesh axis; each shard owns
the CSR row-block of its vertices' out-edges. One superstep =

  init     frogs arrive from the previous exchange (fixed-capacity buffers);
  apply    each frog dies w.p. p_T and is tallied in the owner's counter;
  sync     each (vertex, destination-shard) channel opens w.p. p_s
           (the paper's randomized mirror synchronization — Definition 8's
           erasure model at exactly the granularity of the GraphLab patch);
  scatter  survivors redraw uniformly among edges on *open* channels
           ("blocking walk", Process 19; Example 10 repair guarantees one
           open edge), are bucketed per destination shard, and exchanged
           with a single all-to-all.

The all-to-all buffers are **fixed-capacity per channel** (like MoE token
dispatch): static shapes for XLA, a measured overflow counter instead of
dynamic resizing. Frogs have no identity (paper §3.3's first optimization) —
the payload is just destination vertex ids, and the cost model in netcost.py
counts only open channels, matching what GraphLab's sparse transport would
put on the wire.

The *same* shard program is used for execution (``distributed_frogwild``)
and for the large-scale dry-run (``frogwild_dryrun_lowered`` — ShapeDtype-
Structs only, no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.config import EngineConfig, warn_deprecated
from repro.core.blocking import (channel_enum_draw, coin_uniform,
                                 rejection_is_profitable)
from repro.distributed.runtime import ShardRuntime
from repro.graph.csr import CSRGraph
from repro.graph.partition import partition_graph
from repro.kernels.frog_step_stream import BlockedCSR

# EngineConfig is defined in repro/config.py (the layered-config module —
# single definition per flag) and re-exported here for back-compat.


@dataclasses.dataclass(frozen=True)
class DistributedGraph:
    """Stacked per-shard CSR blocks (leading axis = shard, sharded on mesh).

    For dry-runs this carries only the *shapes* (arrays are None).
    """

    num_shards: int
    shard_size: int                   # vertices per shard (padded)
    n: int                            # original vertex count
    nnz_max: int                      # padded edges per shard
    row_ptr: jnp.ndarray | None = None      # int32[S, shard_size + 1]
    col_idx: jnp.ndarray | None = None      # int32[S, nnz_max] (global dest)
    deg: jnp.ndarray | None = None          # int32[S, shard_size]
    edge_src: jnp.ndarray | None = None     # int32[S, nnz_max] (local source)
    edge_dst_shard: jnp.ndarray | None = None  # int32[S, nnz_max]
    chan_cnt: jnp.ndarray | None = None     # int32[S, shard_size, num_shards]
    col_sorted: jnp.ndarray | None = None   # int32[S, nnz_max] (channel-sorted)
    # Streamed-step slab layout (kernels/frog_step_stream.BlockedCSR per
    # shard, uniform static shapes across shards). Present only when
    # build_distributed_graph was given a vertex_block; the fused
    # step_impl="stream" path requires it.
    vertex_block: int = 0                   # BV (0 = no blocked layout)
    nnz_blk_max: int = 0                    # E_blk
    blk_row_off: jnp.ndarray | None = None  # int32[S, num_vb, BV]
    blk_deg: jnp.ndarray | None = None      # int32[S, num_vb, BV]
    blk_col: jnp.ndarray | None = None      # int32[S, num_vb, E_blk]
    # chan_cnt[s, v, d] — #out-edges of vertex v (on shard s) into shard d:
    # the "mirror" structure (has_edge_to ≡ chan_cnt > 0). A (v, d) sync
    # message is owed only when v is active AND the channel opened — the
    # quantity p_s throttles in GraphLab. col_sorted is each vertex's CSR
    # segment reordered by destination shard — the exact channel-enumeration
    # draw indexes into it via chan_cnt's prefix offsets.

    @property
    def n_padded(self) -> int:
        return self.num_shards * self.shard_size

    @property
    def has_blocked(self) -> bool:
        return self.vertex_block > 0

    @property
    def num_vertex_blocks(self) -> int:
        return (-(-self.shard_size // self.vertex_block)
                if self.has_blocked else 0)

    def array_specs(self):
        S, sz, nnz = self.num_shards, self.shard_size, self.nnz_max
        specs = [
            jax.ShapeDtypeStruct((S, sz + 1), jnp.int32),
            jax.ShapeDtypeStruct((S, nnz), jnp.int32),
            jax.ShapeDtypeStruct((S, sz), jnp.int32),
            jax.ShapeDtypeStruct((S, nnz), jnp.int32),
            jax.ShapeDtypeStruct((S, nnz), jnp.int32),
            jax.ShapeDtypeStruct((S, sz, S), jnp.int32),
            jax.ShapeDtypeStruct((S, nnz), jnp.int32),
        ]
        if self.has_blocked:
            nvb, bv, eb = self.num_vertex_blocks, self.vertex_block, self.nnz_blk_max
            specs += [
                jax.ShapeDtypeStruct((S, nvb, bv), jnp.int32),
                jax.ShapeDtypeStruct((S, nvb, bv), jnp.int32),
                jax.ShapeDtypeStruct((S, nvb, eb), jnp.int32),
            ]
        return tuple(specs)

    def arrays(self):
        base = (self.row_ptr, self.col_idx, self.deg, self.edge_src,
                self.edge_dst_shard, self.chan_cnt, self.col_sorted)
        if self.has_blocked:
            return base + (self.blk_row_off, self.blk_deg, self.blk_col)
        return base


@dataclasses.dataclass
class EngineResult:
    counts: jnp.ndarray                 # int32[n] — stop tallies (global)
    pi_hat: jnp.ndarray                 # f32[n]
    sent_per_step: np.ndarray           # int64[t] — frogs exchanged each step
    open_channels_per_step: np.ndarray  # int64[t] — (shard→shard) pairs used
    sync_msgs_per_step: np.ndarray      # int64[t] — (active vertex, mirror)
    overflow: int                       # frogs dropped by capacity (want 0)
    config: EngineConfig


def build_distributed_graph(
    g: CSRGraph, num_shards: int, vertex_block: int | None = None
) -> DistributedGraph:
    """Splits CSR rows into per-shard blocks with uniform padded shapes.

    With ``vertex_block`` set, each shard's row block is additionally laid
    out as uniform per-vertex-block slabs (the streamed ``frog_step``
    kernel's DMA unit) — required for ``EngineConfig.step_impl`` of
    ``"stream"``/``"auto"``.
    """
    gp, part = partition_graph(g, num_shards)
    if int(np.asarray(g.out_deg).min()) < 1:
        # Both step paths index col_idx[row_ptr[v] + slot] unguarded — a
        # deg-0 vertex would read a neighbour's edge (xla draw) or leak a
        # local id as a global destination (fused kernels). build_csr's
        # dangling repair is a precondition, so enforce it here.
        raise ValueError(
            "engine graphs need d_out ≥ 1 everywhere; repair dangling "
            "vertices first (graph/csr.py:build_csr dangling= policy)")
    gn = gp.to_numpy()
    S, sz = num_shards, part.shard_size
    nnz_per = [int(gn.row_ptr[(s + 1) * sz] - gn.row_ptr[s * sz]) for s in range(S)]
    nnz_max = max(8, int(np.ceil(max(nnz_per) / 8) * 8))

    # Per-edge source / destination-shard / channel layout come from the
    # graph's memoized derived arrays (computed once per CSRGraph, shared
    # with the walker oracle) — each shard block just slices and re-bases.
    es_global = np.asarray(gp.edge_src)
    eds_global = np.asarray(gp.edge_dst_shard(num_shards))
    cs_global, cnt_global, _ = (np.asarray(a)
                                for a in gp.channel_layout(num_shards))
    row_ptr = np.zeros((S, sz + 1), dtype=np.int32)
    col_idx = np.zeros((S, nnz_max), dtype=np.int32)
    deg = np.zeros((S, sz), dtype=np.int32)
    edge_src = np.zeros((S, nnz_max), dtype=np.int32)
    edge_dst_shard = np.zeros((S, nnz_max), dtype=np.int32)
    col_sorted = np.zeros((S, nnz_max), dtype=np.int32)
    for s in range(S):
        lo = int(gn.row_ptr[s * sz])
        hi = int(gn.row_ptr[(s + 1) * sz])
        row_ptr[s] = gn.row_ptr[s * sz : (s + 1) * sz + 1] - lo
        col_idx[s, : hi - lo] = gn.col_idx[lo:hi]
        deg[s] = gn.out_deg[s * sz : (s + 1) * sz]
        edge_src[s, : hi - lo] = es_global[lo:hi] - s * sz
        edge_dst_shard[s, : hi - lo] = eds_global[lo:hi]
        col_sorted[s, : hi - lo] = cs_global[lo:hi]
    chan_cnt = cnt_global.reshape(S, sz, S).astype(np.int32)

    blocked = {}
    if vertex_block is not None:
        from repro.kernels.frog_step_stream import (block_csr, max_block_nnz,
                                                    round_e_blk)

        # One slab layout per shard via the kernel's own builder, with a
        # uniform slab width forced across shards (the shard body's
        # BlockedCSR must have one static E_blk).
        e_blk = round_e_blk(max(max_block_nnz(row_ptr[s], sz, vertex_block)
                                for s in range(S)))
        per_shard = [
            block_csr(row_ptr[s], col_idx[s], deg[s], sz,
                      vertex_block=vertex_block, e_blk=e_blk)
            for s in range(S)
        ]
        blocked = dict(
            vertex_block=per_shard[0].vertex_block, nnz_blk_max=e_blk,
            blk_row_off=jnp.stack([b.row_off for b in per_shard]),
            blk_deg=jnp.stack([b.deg for b in per_shard]),
            blk_col=jnp.stack([b.col for b in per_shard]),
        )

    return DistributedGraph(
        num_shards=S, shard_size=sz, n=g.n, nnz_max=nnz_max,
        row_ptr=jnp.asarray(row_ptr),
        col_idx=jnp.asarray(col_idx),
        deg=jnp.asarray(deg),
        edge_src=jnp.asarray(edge_src),
        edge_dst_shard=jnp.asarray(edge_dst_shard),
        chan_cnt=jnp.asarray(chan_cnt),
        col_sorted=jnp.asarray(col_sorted),
        **blocked,
    )


def channel_capacity(cfg: EngineConfig, S: int) -> int:
    """Expected frogs per (shard → shard) channel is N/S²; the blocking walk
    concentrates them into the open p_s fraction, hence the 1/p_s term."""
    expected = cfg.num_frogs / (S * S * max(cfg.p_s, 1e-3))
    cap = int(np.ceil(cfg.capacity_factor * max(expected, 1.0)))
    return max(8, int(np.ceil(cap / 8) * 8))


def _pack_by_shard(
    dest: jnp.ndarray, S: int, shard_size: int, cap: int
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Buckets frogs (global dest ids, -1 = empty) into a (S, cap) buffer.

    Sort-based packing: stable argsort by destination shard, rank-in-group by
    index arithmetic, capacity overflow dropped (and counted). This is the
    same fixed-capacity dispatch pattern as MoE token routing.
    """
    B = dest.shape[0]
    valid = dest >= 0
    ds = jnp.where(valid, dest // shard_size, S)      # trash bucket S
    order = jnp.argsort(ds)                           # stable — groups shards
    ds_s = ds[order]
    dv_s = dest[order]
    first = jnp.searchsorted(ds_s, jnp.arange(S), side="left")
    rank = jnp.arange(B, dtype=jnp.int32) - first[jnp.clip(ds_s, 0, S - 1)].astype(jnp.int32)
    ok = (ds_s < S) & (rank < cap)
    row = jnp.where(ok, ds_s, S)                      # OOB rows drop
    col = jnp.where(ok, rank, 0)
    buf = jnp.full((S, cap), -1, dtype=jnp.int32)
    buf = buf.at[row, col].set(dv_s, mode="drop")
    n_sent = ok.sum()
    return buf, n_sent, valid.sum() - n_sent


def _blocking_draw_cumsum(
    pos_local: jnp.ndarray,       # int32[B] local vertex (garbage if dead)
    row_ptr: jnp.ndarray,         # int32[shard_size + 1]
    col_idx: jnp.ndarray,         # int32[nnz_max]
    deg: jnp.ndarray,             # int32[shard_size]
    edge_src: jnp.ndarray,        # int32[nnz_max]
    edge_dst_shard: jnp.ndarray,  # int32[nnz_max]
    coins: jnp.ndarray,           # bool[shard_size, S] — open sync channels
    key: jax.Array,
) -> jnp.ndarray:
    """O(nnz) reference scatter draw (per-edge mask + cumsum + searchsorted)."""
    B = pos_local.shape[0]
    shard_size = deg.shape[0]
    nnz_max = col_idx.shape[0]
    k_force, k_draw = jax.random.split(key)

    real_edge = jnp.arange(nnz_max, dtype=jnp.int32) < row_ptr[-1]
    kept = coins[edge_src, edge_dst_shard] & real_edge
    csum = jnp.cumsum(kept.astype(jnp.int32))
    kb = jnp.concatenate([jnp.zeros((1,), jnp.int32), csum])
    kv = kb[row_ptr[pos_local + 1]] - kb[row_ptr[pos_local]]
    # Example 10 repair: one uniformly-chosen edge per fully-blocked vertex.
    forced_slot = (
        jax.random.randint(k_force, (shard_size,), 0, 1 << 30, jnp.int32)
        % jnp.maximum(deg, 1)
    )
    forced_edge = row_ptr[:-1] + forced_slot
    u = jax.random.randint(k_draw, (B,), 0, 1 << 30, jnp.int32)
    u = u % jnp.maximum(kv, 1)
    target = kb[row_ptr[pos_local]] + u + 1
    edge = jnp.searchsorted(csum, target, side="left").astype(jnp.int32)
    edge = jnp.where(kv > 0, edge, forced_edge[pos_local])
    return col_idx[edge]


def _blocking_draw(
    pos_local: jnp.ndarray,       # int32[B] local vertex (garbage if dead)
    row_ptr: jnp.ndarray,         # int32[shard_size + 1]
    col_idx: jnp.ndarray,         # int32[nnz_max]
    deg: jnp.ndarray,             # int32[shard_size]
    edge_src: jnp.ndarray,        # int32[nnz_max]
    edge_dst_shard: jnp.ndarray,  # int32[nnz_max]
    chan_cnt: jnp.ndarray,        # int32[shard_size, S]
    chan_off: jnp.ndarray,        # int32[shard_size, S]
    col_sorted: jnp.ndarray,      # int32[nnz_max] (channel-sorted dests)
    coins: jnp.ndarray | None,    # bool[shard_size, S] — open sync channels
    p_s: float,
    key: jax.Array,
    draw: str = "rejection",
    alive: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """One scatter draw per frog among edges on open channels (Process 19).

    The default ``rejection`` path never touches per-edge state: each frog
    enumerates its ≤ S (vertex, mirror) channels against the superstep's
    coin grid (the same grid the sync accounting charges, so the draw and
    the wire cost always agree on which channels opened) and samples a kept
    edge exactly — O(B · S) instead of O(nnz_max), skew-safe
    (core/blocking.py:channel_enum_draw).
    """
    B = pos_local.shape[0]
    if p_s >= 1.0:
        u = jax.random.randint(key, (B,), 0, 1 << 30, jnp.int32)
        slot = u % jnp.maximum(deg[pos_local], 1)
        return col_idx[row_ptr[pos_local] + slot]
    if draw == "cumsum":
        return _blocking_draw_cumsum(
            pos_local, row_ptr, col_idx, deg, edge_src, edge_dst_shard,
            coins, key,
        )
    if draw != "rejection":
        raise ValueError(f"unknown draw impl {draw!r}")
    edge = channel_enum_draw(
        key, pos_local, row_ptr[pos_local], deg[pos_local],
        chan_cnt[pos_local], chan_off[pos_local], coins[pos_local],
        skip=None if alive is None else ~alive,
    )
    return col_sorted[edge]


def make_shard_body(dg: DistributedGraph, cfg: EngineConfig):
    """The per-shard superstep program (shared by run and dry-run paths).

    Takes stacked blocks ([1, ...] per shard) + a raw uint32 PRNG key; returns
    (counts[1, shard_size], stats[1, t, 3]).
    """
    S, sz, n = dg.num_shards, dg.shard_size, dg.n
    ax = cfg.axis_name
    cap = channel_capacity(cfg, S)
    B = S * cap
    t = cfg.num_steps
    f0 = cfg.num_frogs // S
    if f0 > B:
        raise ValueError(f"buffer too small: {f0} initial frogs > B={B}")
    draw_mode = cfg.draw
    if draw_mode == "auto":
        draw_mode = ("rejection"
                     if rejection_is_profitable(B, dg.nnz_max, cfg.p_s,
                                                num_channels=S)
                     else "cumsum")
    # Fused plain-step path: at p_s = 1 the shard-local tally + move route
    # through ops.frog_step (resident or HBM-streaming kernel).
    use_fused = cfg.p_s >= 1.0 and cfg.step_impl != "xla"
    if cfg.step_impl != "xla" and cfg.p_s < 1.0:
        raise ValueError(
            f"step_impl={cfg.step_impl!r} fuses the plain (p_s = 1) step; "
            f"the blocking walk at p_s={cfg.p_s} uses the draw paths")
    if cfg.step_impl in ("stream", "auto") and not dg.has_blocked:
        # Inside shard_map the graph arrays are traced, so without the
        # prebuilt slabs "auto" could only ever fall back to the resident
        # kernel — silently recreating the VMEM cap it exists to lift.
        raise ValueError(
            f"step_impl={cfg.step_impl!r} needs the blocked slab layout — "
            "build the graph with build_distributed_graph(g, S, "
            "vertex_block=...)")

    def shard_body(row_ptr, col_idx, deg, edge_src, edge_dst_shard,
                   chan_cnt, col_sorted, *rest):
        *blk, key_data = rest
        row_ptr, col_idx = row_ptr[0], col_idx[0]
        deg, edge_src, edge_dst_shard = deg[0], edge_src[0], edge_dst_shard[0]
        chan_cnt, col_sorted = chan_cnt[0], col_sorted[0]
        blocked = (BlockedCSR(vertex_block=dg.vertex_block,
                              row_off=blk[0][0], deg=blk[1][0], col=blk[2][0])
                   if blk else None)
        has_edge_to = chan_cnt > 0
        chan_off = jnp.cumsum(chan_cnt, axis=-1) - chan_cnt
        me = jax.lax.axis_index(ax)
        base = me * sz
        n_local = jnp.clip(n - base, 1, sz)

        key = jax.random.wrap_key_data(key_data, impl="threefry2x32")
        k = jax.random.fold_in(key, me)
        k_init, k_run = jax.random.split(k)
        pos0 = base + (
            jax.random.randint(k_init, (B,), 0, 1 << 30, jnp.int32) % n_local
        )
        frogs0 = jnp.where(jnp.arange(B) < f0, pos0, -1)
        counts0 = jax.lax.pcast(
            jnp.zeros((sz + 1,), jnp.int32), (ax,), to="varying"
        )                                               # last bin = trash

        def step(carry, step_key):
            frogs, counts = carry
            valid = frogs >= 0
            v_local = jnp.clip(frogs - base, 0, sz - 1)
            k_die, k_coin, k_draw = jax.random.split(step_key, 3)
            # apply(): deaths tallied where they happen.
            die = jax.random.bernoulli(k_die, cfg.p_T, (B,)) & valid
            if use_fused:
                from repro.kernels import ops

                # One fused kernel pass tallies the deaths *and* draws the
                # successors (col_idx carries global dest ids, so nxt is
                # already a global destination; build_distributed_graph
                # rejects deg-0 vertices — build_csr repairs real ones and
                # partition padding self-loops the rest — so the kernels'
                # local dangling guard can never fire here).
                bits = jax.random.randint(k_draw, (B,), 0, 1 << 30,
                                          jnp.int32)
                nxt, death_counts = ops.frog_step(
                    v_local, die.astype(jnp.int32), bits,
                    row_ptr, col_idx, deg, sz,
                    impl=cfg.step_impl, blocked=blocked,
                )
                counts = counts.at[:-1].add(death_counts)
            else:
                counts = counts.at[jnp.where(die, v_local, sz)].add(1)
            alive = valid & ~die
            # <sync>: one coin per (vertex, mirror shard) — the p_s patch.
            # The coin is a pure hash of (k_coin, v·S + d): this grid (used
            # only for sync accounting + the cumsum reference draw) and the
            # rejection draw's pointwise acceptance checks see identical
            # coins without sharing any materialized state.
            if cfg.p_s < 1.0:
                chan_grid = (
                    jnp.arange(sz, dtype=jnp.int32)[:, None] * S
                    + jnp.arange(S, dtype=jnp.int32)[None, :]
                )
                coins = coin_uniform(k_coin, chan_grid) < cfg.p_s
            else:
                coins = jnp.ones((sz, S), dtype=bool)
            # GraphLab-faithful sync accounting: a message is owed for every
            # (active vertex, existing mirror) pair whose channel opened.
            occ = jnp.zeros((sz + 1,), jnp.int32).at[
                jnp.where(alive, v_local, sz)
            ].add(1)
            active = occ[:sz] > 0
            sync_msgs = (active[:, None] & coins & has_edge_to).sum()
            if use_fused:
                dest = nxt
            else:
                dest = _blocking_draw(
                    v_local, row_ptr, col_idx, deg, edge_src, edge_dst_shard,
                    chan_cnt, chan_off, col_sorted, coins, cfg.p_s, k_draw,
                    draw=draw_mode, alive=alive,
                )
            dest = jnp.where(alive, dest, -1)
            buf, n_sent, ovf = _pack_by_shard(dest, S, sz, cap)
            open_ch = (buf >= 0).any(axis=1).sum()
            recv = jax.lax.all_to_all(
                buf[:, None], ax, split_axis=0, concat_axis=0, tiled=False
            )[:, 0]
            frogs = recv.reshape(B)
            stats = jnp.stack([n_sent.astype(jnp.int32),
                               open_ch.astype(jnp.int32),
                               ovf.astype(jnp.int32),
                               sync_msgs.astype(jnp.int32)])
            return (frogs, counts), stats

        step_keys = jax.random.split(k_run, t)
        (frogs, counts), stats = jax.lax.scan(step, (frogs0, counts0), step_keys)
        # cut-off at t: survivors halt and are tallied (Process 15).
        valid = frogs >= 0
        v_local = jnp.clip(frogs - base, 0, sz - 1)
        counts = counts.at[jnp.where(valid, v_local, sz)].add(1)
        return counts[None, :sz], stats[None]

    return shard_body


def _sharded_fn(dg: DistributedGraph, cfg: EngineConfig, mesh: Mesh):
    rt = ShardRuntime.for_mesh(mesh, cfg.axis_name)
    # jax has no replication rule for pallas_call: the fused step backends
    # need the varying-manual-axes check off (the body is per-shard; the
    # only cross-device op is the all_to_all exchange).
    return rt.shard_map_fn(
        make_shard_body(dg, cfg),
        num_sharded=len(dg.array_specs()), num_replicated=1, num_outputs=2,
        check_vma=cfg.step_impl == "xla",
    )


def distributed_frogwild(
    dg: DistributedGraph, cfg: EngineConfig, mesh: Mesh, seed: int = 0
) -> EngineResult:
    """Deprecated entry point — use :meth:`repro.service.FrogWildService.
    pagerank` with a mesh (or :func:`repro.service.batch_pagerank`).
    Delegates through the service so the answer is byte-identical."""
    warn_deprecated("distributed_frogwild", "FrogWildService.pagerank")
    from repro import service

    return service.batch_pagerank(dg, cfg, mesh=mesh, seed=seed)


def _distributed_frogwild(
    dg: DistributedGraph, cfg: EngineConfig, mesh: Mesh, seed: int = 0
) -> EngineResult:
    """Runs the full FrogWild! process under ``mesh`` and returns π̂ + stats."""
    rt = ShardRuntime.for_mesh(mesh, cfg.axis_name)
    if rt.num_shards != dg.num_shards:
        raise ValueError(
            f"mesh has {rt.num_shards} devices, graph has {dg.num_shards} shards"
        )
    fn = jax.jit(_sharded_fn(dg, cfg, mesh))
    key_data = ShardRuntime.key_data(jax.random.PRNGKey(seed))
    counts, stats = fn(*dg.arrays(), key_data)
    counts = counts.reshape(-1)[: dg.n]
    stats = np.asarray(stats)                         # [S, t, 4]
    total = (cfg.num_frogs // dg.num_shards) * dg.num_shards
    return EngineResult(
        counts=counts,
        pi_hat=counts.astype(jnp.float32) / total,
        sent_per_step=stats[:, :, 0].sum(axis=0).astype(np.int64),
        open_channels_per_step=stats[:, :, 1].sum(axis=0).astype(np.int64),
        sync_msgs_per_step=stats[:, :, 3].sum(axis=0).astype(np.int64),
        overflow=int(stats[:, :, 2].sum()),
        config=cfg,
    )


def frogwild_dryrun_lowered(dg: DistributedGraph, cfg: EngineConfig, mesh: Mesh):
    """Lowers the identical shard program from ShapeDtypeStructs only —
    the multi-pod dry-run entry point (no graph data, no allocation)."""
    rt = ShardRuntime.for_mesh(mesh, cfg.axis_name)
    sh, rep = rt.sharding(), rt.sharding(replicated=True)
    fn = _sharded_fn(dg, cfg, mesh)
    specs = dg.array_specs() + (jax.ShapeDtypeStruct((2,), jnp.uint32),)
    return jax.jit(
        fn, in_shardings=(sh,) * len(dg.array_specs()) + (rep,)
    ).lower(*specs)
