"""Distributed GraphLab-PR baseline: power iteration on the vertex mesh.

Pull-form PageRank over range-sharded vertices. Each iteration must read the
rank of every predecessor, which under vertex replication is exactly the
all-mirror synchronization GraphLab performs — on a TPU mesh it is an
**all-gather of the full rank vector** (O(n) bytes per shard per iteration).
That dense synchronization is the cost FrogWild's sparse, partially-
synchronized frog exchange avoids; the two collective footprints are
contrasted in EXPERIMENTS.md §Roofline.

Like the engine, the same program serves execution and dry-run.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.graph.csr import CSRGraph
from repro.graph.partition import partition_graph


@dataclasses.dataclass(frozen=True)
class PullGraph:
    """Per-shard in-edge COO blocks (pull orientation), stacked on shard axis.

    ``src`` holds *global* predecessor ids, ``dst`` local successor ids,
    ``w = 1/d_out(src)``; padded entries have w = 0.
    """

    num_shards: int
    shard_size: int
    n: int
    nnz_max: int
    src: jnp.ndarray | None = None    # int32[S, nnz_max]
    dst: jnp.ndarray | None = None    # int32[S, nnz_max]
    w: jnp.ndarray | None = None      # f32[S, nnz_max]

    def array_specs(self):
        S, nnz = self.num_shards, self.nnz_max
        return (
            jax.ShapeDtypeStruct((S, nnz), jnp.int32),
            jax.ShapeDtypeStruct((S, nnz), jnp.int32),
            jax.ShapeDtypeStruct((S, nnz), jnp.float32),
        )


def build_pull_graph(g: CSRGraph, num_shards: int) -> PullGraph:
    gp, part = partition_graph(g, num_shards)
    gn = gp.to_numpy()
    S, sz = num_shards, part.shard_size
    deg = gn.out_deg.astype(np.int64)
    src_all = np.repeat(np.arange(gp.n, dtype=np.int64), deg)
    dst_all = gn.col_idx.astype(np.int64)
    w_all = (1.0 / deg[src_all]).astype(np.float32)
    owner = dst_all // sz

    nnz_per = np.bincount(owner, minlength=S)
    nnz_max = max(8, int(np.ceil(nnz_per.max() / 8) * 8))
    src = np.zeros((S, nnz_max), dtype=np.int32)
    dst = np.zeros((S, nnz_max), dtype=np.int32)
    w = np.zeros((S, nnz_max), dtype=np.float32)
    for s in range(S):
        sel = owner == s
        m = int(sel.sum())
        src[s, :m] = src_all[sel]
        dst[s, :m] = dst_all[sel] - s * sz
        w[s, :m] = w_all[sel]
    return PullGraph(
        num_shards=S, shard_size=sz, n=g.n, nnz_max=nnz_max,
        src=jnp.asarray(src), dst=jnp.asarray(dst), w=jnp.asarray(w),
    )


def _pr_sharded_fn(pg: PullGraph, num_iters: int, p_T: float, mesh: Mesh,
                   axis_name: str = "vertex"):
    S, sz, n = pg.num_shards, pg.shard_size, pg.n

    def shard_body(src, dst, w):
        src, dst, w = src[0], dst[0], w[0]

        def step(x_local, _):
            # The dense mirror synchronization: every shard needs every
            # predecessor's rank → all-gather the full vector (O(n) bytes).
            x_full = jax.lax.all_gather(x_local, axis_name, tiled=True)
            contrib = x_full[src] * w
            px = jax.ops.segment_sum(contrib, dst, num_segments=sz)
            x_new = (1.0 - p_T) * px + p_T / n
            return x_new, None

        x0 = jnp.full((sz,), 1.0 / n, dtype=jnp.float32)
        x0 = jax.lax.pcast(x0, (axis_name,), to="varying")
        x, _ = jax.lax.scan(step, x0, None, length=num_iters)
        return x[None]

    return jax.shard_map(
        shard_body, mesh=mesh,
        in_specs=(P(axis_name),) * 3,
        out_specs=P(axis_name),
    )


def distributed_power_iteration(
    pg: PullGraph, mesh: Mesh, num_iters: int = 50, p_T: float = 0.15
) -> jnp.ndarray:
    """Returns the PageRank vector computed on the mesh (padding stripped)."""
    fn = jax.jit(_pr_sharded_fn(pg, num_iters, p_T, mesh))
    x = fn(pg.src, pg.dst, pg.w)
    return x.reshape(-1)[: pg.n]


def pagerank_dryrun_lowered(pg: PullGraph, mesh: Mesh, num_iters: int = 2,
                            p_T: float = 0.15, axis_name: str = "vertex"):
    """Dry-run lowering of the baseline (ShapeDtypeStructs, no allocation)."""
    sh = NamedSharding(mesh, P(axis_name))
    fn = _pr_sharded_fn(pg, num_iters, p_T, mesh, axis_name)
    return jax.jit(fn, in_shardings=(sh,) * 3).lower(*pg.array_specs())
