"""Config registry. The **public surface is the graph workload family**
(``frogwild_graphs.py`` — the paper's datasets): ``GRAPHS`` /
:func:`get_graph_config` are what ``repro.configs`` exports.

The LLM architecture × input-shape machinery below (``_ARCH_MODULES``,
``ARCHS``, ``get_config``, ``input_specs``, …) is a template leftover kept
*out* of the public surface (``__all__``): it still backs the model-stack
smoke tests and the ``launch/`` dry-run tooling, which import it from this
module explicitly, but it is not part of the FrogWild service API and is
pinned out of it by ``tests/test_api_surface.py``.

Shape semantics for the LLM registry (assignment brief):
  * train_4k     — train_step   (tokens+labels, seq 4096, global batch 256)
  * prefill_32k  — serve prefill (forward, seq 32768, batch 32)
  * decode_32k   — serve_step    (ONE new token, KV cache of 32768, batch 128)
  * long_500k    — serve_step    (one token, 524288 cache, batch 1) —
                   sub-quadratic archs only (``ModelConfig.subquadratic``).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.frogwild_graphs import (GraphConfig, LIVEJOURNAL_BENCH,
                                           LIVEJOURNAL_FULL, TWITTER_BENCH,
                                           TWITTER_FULL)
from repro.models.config import ModelConfig

__all__ = [
    "GraphConfig",
    "GRAPHS",
    "get_graph_config",
]

# --- the registered config family: the paper's graph workloads --------------

GRAPHS: Dict[str, GraphConfig] = {
    cfg.name: cfg
    for cfg in (LIVEJOURNAL_BENCH, TWITTER_BENCH,
                LIVEJOURNAL_FULL, TWITTER_FULL)
}


def get_graph_config(name: str) -> GraphConfig:
    if name not in GRAPHS:
        raise KeyError(f"unknown graph {name!r}; known: {sorted(GRAPHS)}")
    return GRAPHS[name]


# --- LLM template machinery (internal; NOT exported) ------------------------

_ARCH_MODULES = {
    "h2o-danube-3-4b": "repro.configs.h2o_danube3_4b",
    "starcoder2-7b": "repro.configs.starcoder2_7b",
    "gemma3-4b": "repro.configs.gemma3_4b",
    "llama3.2-1b": "repro.configs.llama32_1b",
    "llava-next-mistral-7b": "repro.configs.llava_next_mistral_7b",
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "phi3.5-moe-42b-a6.6b": "repro.configs.phi35_moe",
    "whisper-medium": "repro.configs.whisper_medium",
    "rwkv6-3b": "repro.configs.rwkv6_3b",
    "zamba2-1.2b": "repro.configs.zamba2_1b",
}
ARCHS = tuple(_ARCH_MODULES)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(_ARCH_MODULES[arch]).CONFIG


def shape_applicable(cfg: ModelConfig, shape: str) -> Tuple[bool, str]:
    """(applicable?, reason-if-not). DESIGN.md §4 records the skips."""
    spec = SHAPES[shape]
    if spec.name == "long_500k" and not cfg.subquadratic:
        return False, ("pure full-attention arch — 500k decode needs "
                       "sub-quadratic attention (skip per brief)")
    if cfg.family == "encdec" and spec.name == "long_500k":
        return False, "enc-dec ASR: 30s audio yields no 500k decode context"
    return True, ""


def _token_specs(cfg: ModelConfig, B: int, S: int, with_labels: bool
                 ) -> Dict[str, jax.ShapeDtypeStruct]:
    i32 = jnp.int32
    S_text = S - cfg.num_prefix_embeddings if cfg.family == "vlm" else S
    out: Dict[str, Any] = {
        "tokens": jax.ShapeDtypeStruct((B, S_text), i32)
    }
    if with_labels:
        out["labels"] = jax.ShapeDtypeStruct((B, S_text), i32)
    if cfg.family == "vlm":
        out["prefix_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.num_prefix_embeddings, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        out["encoder_frames"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_seq, cfg.d_model), jnp.float32)
    return out


def input_specs(
    arch_or_cfg, shape: str
) -> Tuple[str, Dict[str, Any]]:
    """Returns (kind, specs). ``specs`` for train/prefill is the batch dict;
    for decode it is {"tokens": [B] i32, "state": DecodeState-shaped specs,
    "params": param specs} (the cache is an input to serve_step)."""
    cfg = (arch_or_cfg if isinstance(arch_or_cfg, ModelConfig)
           else get_config(arch_or_cfg))
    spec = SHAPES[shape]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        raise ValueError(f"{cfg.name} × {shape} not applicable: {why}")
    B, S = spec.global_batch, spec.seq_len

    if spec.kind in ("train", "prefill"):
        return spec.kind, _token_specs(cfg, B, S, with_labels=spec.kind == "train")

    # decode: one token in, cache of length S as carried state.
    from repro.models.transformer import init_decode_state, init_params

    params_specs = jax.eval_shape(
        lambda k: init_params(cfg, k), jax.ShapeDtypeStruct((2,), jnp.uint32))
    enc = (jax.ShapeDtypeStruct((B, cfg.encoder_seq, cfg.d_model), jnp.float32)
           if cfg.family == "encdec" else None)
    state_specs = jax.eval_shape(
        lambda p, e: init_decode_state(p, cfg, B, S, encoder_frames=e),
        params_specs, enc,
    )
    return "decode", {
        "tokens": jax.ShapeDtypeStruct((B,), jnp.int32),
        "state": state_specs,
    }


def param_specs(cfg: ModelConfig):
    from repro.models.transformer import init_params

    return jax.eval_shape(
        lambda k: init_params(cfg, k), jax.ShapeDtypeStruct((2,), jnp.uint32))


# ----------------------------------------------------------------------------
# reduced configs for CPU smoke tests
# ----------------------------------------------------------------------------

def reduced_config(cfg: ModelConfig) -> ModelConfig:
    """Same family/wiring, toy width: one forward/train step runs on CPU."""
    kw: Dict[str, Any] = dict(
        name=cfg.name + "-smoke",
        family=cfg.family,
        num_layers=min(cfg.num_layers, 4),
        d_model=128,
        num_heads=4,
        num_kv_heads=max(1, min(cfg.num_kv_heads, 4) // max(1, cfg.num_heads // 4)),
        d_ff=256,
        vocab_size=512,
        head_dim=32,
        rope_theta=cfg.rope_theta,
        tie_embeddings=cfg.tie_embeddings,
        dtype="float32",
    )
    # keep the kv:q ratio flavour
    if cfg.num_kv_heads == cfg.num_heads:
        kw["num_kv_heads"] = 4
    else:
        kw["num_kv_heads"] = 2
    if cfg.sliding_window is not None:
        kw["sliding_window"] = 8
    if cfg.global_every is not None:
        kw["global_every"] = 2
        kw["num_layers"] = 4
    if cfg.family == "moe":
        kw.update(num_experts=8, num_experts_per_tok=min(
            cfg.num_experts_per_tok, 2), d_ff=64, moe_capacity_factor=2.0)
    if cfg.family in ("ssm", "hybrid"):
        kw.update(ssm_head_dim=32, ssm_state=16)
        kw["num_kv_heads"] = 4
    if cfg.family == "hybrid":
        kw.update(shared_attn_every=2, num_layers=4)
    if cfg.family == "encdec":
        kw.update(encoder_layers=2, encoder_seq=16, num_layers=2)
        kw["num_kv_heads"] = 4
    if cfg.family == "vlm":
        kw.update(num_prefix_embeddings=4)
    return ModelConfig(**kw)
