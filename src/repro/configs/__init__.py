"""Assigned-architecture configs (exact shapes from the public sources in the
brief) + input-shape registry + reduced smoke configs."""
from repro.configs.registry import (
    ARCHS,
    SHAPES,
    get_config,
    input_specs,
    reduced_config,
    shape_applicable,
)

__all__ = [
    "ARCHS",
    "SHAPES",
    "get_config",
    "input_specs",
    "reduced_config",
    "shape_applicable",
]
