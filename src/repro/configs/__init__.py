"""Registered config family: the paper's graph workloads.

``repro.configs`` exports only the FrogWild graph configs
(``frogwild_graphs.py`` — LiveJournal / Twitter bench + full-scale specs).
The LLM architecture registry that previously lived on this surface is a
template leftover; the model-stack smoke tests and ``launch/`` tooling that
still need it import it from ``repro.configs.registry`` explicitly.
"""
from repro.configs.frogwild_graphs import (GraphConfig, LIVEJOURNAL_BENCH,
                                           LIVEJOURNAL_FULL, TWITTER_BENCH,
                                           TWITTER_FULL)
from repro.configs.registry import GRAPHS, get_graph_config

__all__ = [
    "GraphConfig",
    "GRAPHS",
    "get_graph_config",
    "LIVEJOURNAL_BENCH",
    "LIVEJOURNAL_FULL",
    "TWITTER_BENCH",
    "TWITTER_FULL",
]
