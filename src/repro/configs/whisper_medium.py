"""whisper-medium — encoder-decoder ASR; conv frontend is a stub
(input_specs supplies precomputed 1500-frame embeddings).
[arXiv:2212.04356; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    num_layers=24,                # decoder layers
    encoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=52224,   # 51865 padded to 256·204 for TP divisibility
    head_dim=64,
    encoder_seq=1500,             # 30 s of audio at 50 Hz after conv stub
    mlp_gated=False,
    act="gelu",
)
