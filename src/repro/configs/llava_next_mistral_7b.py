"""llava-next-mistral-7b — VLM: mistral-7b backbone + anyres patch frontend
(stub: precomputed patch embeddings). [hf:llava-hf/llava-v1.6-mistral-7b-hf;
unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    head_dim=128,
    rope_theta=1_000_000.0,
    num_prefix_embeddings=2880,   # anyres tiling: ~5 tiles × 576 patches
)
