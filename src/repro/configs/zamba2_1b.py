"""zamba2-1.2b — Mamba2 backbone + weight-shared attention blocks.
[arXiv:2411.15242; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,                    # shared transformer block FFN
    vocab_size=32000,
    head_dim=64,
    ssm_state=64,
    ssm_head_dim=64,
    shared_attn_every=6,
)
