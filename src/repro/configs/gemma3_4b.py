"""gemma3-4b — dense, 5:1 local:global attention, 128k context, 262k vocab.
[hf:google/gemma-3-1b-pt; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b",
    family="dense",
    num_layers=34,
    d_model=2560,
    num_heads=8,
    num_kv_heads=4,
    d_ff=10240,
    vocab_size=262144,
    head_dim=256,                 # gemma uses head_dim ≠ d_model/num_heads
    sliding_window=1024,          # local layers
    global_every=6,               # every 6th layer is global (5:1)
    rope_theta=1_000_000.0,
)
