"""Graph workload configs for the paper's own experiments (the datasets are
offline-synthesized at the paper's scales; see graph/generators.py)."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class GraphConfig:
    name: str
    n: int                       # vertices
    avg_out_deg: float
    theta: float = 2.2           # PageRank power-law exponent (paper §2.3)
    seed: int = 0


# Benchmark-scale stand-ins (CPU-runnable) for the paper's datasets.
LIVEJOURNAL_BENCH = GraphConfig("livejournal-bench", n=65_536, avg_out_deg=14.4)
TWITTER_BENCH = GraphConfig("twitter-bench", n=262_144, avg_out_deg=16.0)

# Full-scale specs used ONLY for dry-run lowering (no data materialized).
LIVEJOURNAL_FULL = GraphConfig("livejournal", n=4_847_571, avg_out_deg=14.2)
TWITTER_FULL = GraphConfig("twitter", n=41_652_230, avg_out_deg=35.3)
