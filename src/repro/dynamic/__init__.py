"""Dynamic graphs: streaming edge mutations, epoch-versioned slabs, and
incremental walk-index refresh.

The frozen-graph walk index (``repro.query.index``) meets mutating graphs
through three pieces:

* :mod:`repro.dynamic.mutations` — batched edge inserts/deletes compacted
  into a brand-new CSR per epoch (``CSRGraph.epoch``/``mutation_offset``
  are the provenance every graph and slab manifest carries);
* :mod:`repro.dynamic.refresh` — per-segment invalidation from the
  build-time ``visited_blocks`` trajectory masks (intermediate hops
  only; the start's consumption is covered exactly by the per-vertex
  source rule), plus an incremental re-walk of the stale rows, writing
  back exactly the stale cells, through the builders' process-cached
  row program — graph buffers are jit operands, so successive epochs
  re-dispatch instead of re-tracing;
* the serving tiers (``FrogWildService.apply_mutations`` /
  ``Gateway.apply_mutations``) — the two-epoch commit that swaps slabs
  without stopping admission.

**The staleness/epoch contract.**

1. *Epochs are immutable snapshots.* Applying a :class:`MutationBatch`
   never modifies an existing ``CSRGraph`` or slab; it produces new
   objects at ``epoch + 1``. A slab is valid for exactly one graph epoch
   (``WalkIndex.graph_epoch``), and loaders refuse mismatched pairs.
2. *Invalidation is sound, possibly conservative.* A segment not marked
   stale is **byte-identical** under the new graph: its random bits
   depend only on ``(seed, vertex, step)``, and every vertex whose
   out-edges it consumed kept its successor list verbatim (order
   included). Block granularity (``segment_mask_block_size``) can only
   over-invalidate, never under-invalidate.
3. *Refresh equals rebuild.* ``refresh_walk_index`` walks only the rows
   holding stale segments (writing back only the stale cells) yet
   returns a slab byte-identical — endpoints and masks — to a
   from-scratch build at the new epoch (tier-1 gates this).
4. *Serving never stops.* In-flight queries pin the epoch (scheduler +
   slab) they were admitted on and finish byte-identically to a run where
   no mutation ever happened; new admissions land on the committed
   ``e + 1``; the old epoch's scheduler is released when its last pinned
   query settles.
"""
from repro.dynamic.mutations import (MutationBatch, MutationLog,
                                     apply_mutations)
from repro.dynamic.refresh import (RefreshReport, dirty_block_mask,
                                   epoch_dir, invalidate_segments,
                                   list_epochs, load_epoch_index,
                                   refresh_walk_index, save_epoch_index)

__all__ = [
    "MutationBatch",
    "MutationLog",
    "RefreshReport",
    "apply_mutations",
    "dirty_block_mask",
    "epoch_dir",
    "invalidate_segments",
    "list_epochs",
    "load_epoch_index",
    "refresh_walk_index",
    "save_epoch_index",
]
