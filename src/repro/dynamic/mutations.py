"""Batched edge mutations on :class:`~repro.graph.csr.CSRGraph`.

A :class:`MutationBatch` is one atomic set of edge inserts and deletes.
Applying it compacts the deltas into a brand-new CSR at ``epoch + 1`` —
the old graph object is immutable and keeps serving its pinned queries
(the two-epoch contract, see the package docstring).

**Successor-order preservation is load-bearing.** The walk sampler picks
``col_idx[row_ptr[v] + bits % d_out(v)]``, so the *order* of a vertex's
successor list is part of the sampling function: reordering an untouched
vertex's list would silently change its segments' bytes and break the
invalidation soundness argument. :func:`apply_mutations` therefore edits
per-vertex successor lists in place — deletes remove the first matching
occurrence, inserts append at the end — and every untouched vertex's
list is carried over verbatim.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List, Tuple

import jax.numpy as jnp
import numpy as np

from repro.graph.csr import CSRGraph


def _edge_arrays(edges: Iterable[Tuple[int, int]]):
    pairs = list(edges)
    if not pairs:
        return (np.zeros(0, np.int64), np.zeros(0, np.int64))
    a = np.asarray(pairs, dtype=np.int64).reshape(-1, 2)
    return a[:, 0].copy(), a[:, 1].copy()


@dataclasses.dataclass(frozen=True)
class MutationBatch:
    """One atomic batch of edge inserts/deletes (the epoch increment unit).

    Attributes:
      insert_src / insert_dst: int64[k_i] — edges to add (duplicates are
        legal: multi-edges mean proportionally higher transition mass).
      delete_src / delete_dst: int64[k_d] — edges to remove; each delete
        consumes the *first* remaining occurrence of ``(src, dst)`` in
        ``src``'s successor list. Deleting an absent edge raises.
    """

    insert_src: np.ndarray
    insert_dst: np.ndarray
    delete_src: np.ndarray
    delete_dst: np.ndarray

    @classmethod
    def edges(cls, insert: Iterable[Tuple[int, int]] = (),
              delete: Iterable[Tuple[int, int]] = ()) -> "MutationBatch":
        isrc, idst = _edge_arrays(insert)
        dsrc, ddst = _edge_arrays(delete)
        return cls(insert_src=isrc, insert_dst=idst,
                   delete_src=dsrc, delete_dst=ddst)

    @property
    def size(self) -> int:
        """Total mutations in the batch (the mutation-log offset delta)."""
        return int(self.insert_src.size + self.delete_src.size)


def apply_mutations(
    g: CSRGraph, batch: MutationBatch, dangling: str = "hash"
) -> Tuple[CSRGraph, np.ndarray]:
    """Compacts ``batch`` into a new CSR at ``g.epoch + 1``.

    Returns ``(new_graph, changed)`` where ``changed`` is the sorted array
    of vertices whose successor list differs from the old graph's — the
    exact input :func:`~repro.dynamic.refresh.invalidate_segments` needs.
    A vertex left with zero out-edges gets the same dangling repair
    :func:`~repro.graph.csr.build_csr` would apply (policy ``dangling``),
    keeping the "every vertex has d_out > 0" invariant across epochs; the
    repaired vertex counts as changed.

    Raises ``ValueError`` on out-of-range endpoints or deletes of absent
    edges — mutation streams must be loud about disagreeing with the graph
    they think they are mutating.
    """
    n = g.n
    for name, arr in (("insert_src", batch.insert_src),
                      ("insert_dst", batch.insert_dst),
                      ("delete_src", batch.delete_src),
                      ("delete_dst", batch.delete_dst)):
        if arr.size and (arr.min() < 0 or arr.max() >= n):
            raise ValueError(f"{name} has endpoints outside [0, {n})")

    rp = np.asarray(g.row_ptr).astype(np.int64)
    col = np.asarray(g.col_idx).astype(np.int64)

    touched = np.union1d(batch.insert_src, batch.delete_src).astype(np.int64)
    segs = {int(v): list(col[rp[v]:rp[v + 1]]) for v in touched}

    for s, d in zip(batch.delete_src, batch.delete_dst):
        try:
            segs[int(s)].remove(int(d))
        except ValueError:
            raise ValueError(
                f"delete of absent edge ({int(s)}, {int(d)}) — the "
                f"mutation stream disagrees with epoch {g.epoch}'s graph")
    for s, d in zip(batch.insert_src, batch.insert_dst):
        segs[int(s)].append(int(d))

    changed: List[int] = []
    for v, lst in segs.items():
        old = col[rp[v]:rp[v + 1]]
        if len(lst) != old.size or not np.array_equal(np.asarray(lst, np.int64), old):
            changed.append(v)
        if not lst:                       # dangling repair (build_csr policy)
            if dangling == "hash":
                t = (v * 2654435761 + 12345) % n
                if t == v:
                    t = (t + 1) % n
            elif dangling == "self_loop":
                t = v
            else:
                raise ValueError(f"unknown dangling policy {dangling!r}")
            lst.append(int(t))

    # Rebuild col_idx by splicing edited per-vertex lists between the
    # untouched contiguous runs — O(nnz) copies, no per-vertex Python loop
    # over the n untouched vertices.
    tv = np.sort(touched)
    parts: List[np.ndarray] = []
    prev = 0
    for v in tv:
        parts.append(col[rp[prev]:rp[v]])
        parts.append(np.asarray(segs[int(v)], dtype=np.int64))
        prev = int(v) + 1
    parts.append(col[rp[prev]:rp[n]])
    col_new = np.concatenate(parts) if parts else col.copy()

    deg_new = (rp[1:] - rp[:-1]).copy()
    for v in tv:
        deg_new[v] = len(segs[int(v)])
    rp_new = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(deg_new, out=rp_new[1:])

    new_g = CSRGraph(
        n=n,
        row_ptr=jnp.asarray(rp_new, dtype=jnp.int32),
        col_idx=jnp.asarray(col_new, dtype=jnp.int32),
        out_deg=jnp.asarray(deg_new, dtype=jnp.int32),
        epoch=g.epoch + 1,
        mutation_offset=g.mutation_offset + batch.size,
    )
    return new_g, np.asarray(sorted(changed), dtype=np.int64)


@dataclasses.dataclass
class MutationLog:
    """An append-only stream of mutation batches with offset bookkeeping.

    ``base_epoch`` / ``base_offset`` anchor the log to the graph snapshot
    it extends; ``epoch`` / ``offset`` are where a full replay lands —
    exactly the provenance :func:`~repro.graph.csr.save_graph` manifests
    and walk-index checkpoints carry, so a (graph, slab, log) triple can
    be cross-checked on load.
    """

    base_epoch: int = 0
    base_offset: int = 0
    batches: List[MutationBatch] = dataclasses.field(default_factory=list)

    def append(self, batch: MutationBatch) -> int:
        """Appends one batch; returns the epoch a replay-through lands on."""
        self.batches.append(batch)
        return self.epoch

    @property
    def epoch(self) -> int:
        return self.base_epoch + len(self.batches)

    @property
    def offset(self) -> int:
        return self.base_offset + sum(b.size for b in self.batches)

    def replay(self, g: CSRGraph) -> Tuple[CSRGraph, np.ndarray]:
        """Applies every batch after ``g``'s epoch, in order.

        ``g.epoch`` selects where in the log to resume (a graph already at
        ``base_epoch + k`` skips the first ``k`` batches). Returns the
        final graph and the union of changed vertices across the replayed
        batches.
        """
        if not (self.base_epoch <= g.epoch <= self.epoch):
            raise ValueError(
                f"graph epoch {g.epoch} outside log range "
                f"[{self.base_epoch}, {self.epoch}]")
        changed = np.zeros(0, dtype=np.int64)
        for batch in self.batches[g.epoch - self.base_epoch:]:
            g, ch = apply_mutations(g, batch)
            changed = np.union1d(changed, ch)
        return g, changed
