"""Segment invalidation and incremental walk-index refresh.

The invalidation rule (sound by construction, see the package docstring):
a segment ``(v, r)`` is stale iff

* ``v``'s own successor list changed (the segment's first hop samples it),
  or
* the segment's recorded trajectory *passed through* a vertex-id block
  containing a changed vertex — one bitwise AND of the segment's
  ``visited_blocks`` mask against the batch's dirty-block mask, not a
  re-walk. The mask records the intermediate hops only (the start's
  consumption is the first rule, exact per vertex; the endpoint consumes
  no edge), so a mutation dirties block-mates of trajectories, never of
  mere start positions. Blocks make the check conservative (a block-mate's
  change can flag an innocent segment) but never unsound: a segment whose
  consumed vertices all kept their successor lists verbatim replays
  byte-identically under the new graph, because its random bits depend
  only on ``(seed, v, step)`` — never on the graph or the batch shape.

:func:`refresh_walk_index` then re-walks the rows holding stale segments
through the builders' own cached row program and writes back exactly the
invalidated cells, producing a slab byte-identical to a from-scratch build
at the new epoch.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.csr import CSRGraph
from repro.query.index import (_MASK_WORDS, ShardedWalkIndex, WalkIndex,
                               _row_walk_program, load_walk_index,
                               save_walk_index, save_walk_index_shard,
                               segment_mask_block_size, shard_walk_index)


@dataclasses.dataclass(frozen=True)
class RefreshReport:
    """What one incremental refresh did (the bench/gate observable).

    ``segments_rebuilt == stale_segments`` always — the refresh writes the
    invalidated cells and nothing else (it *walks* the ``stale_rows``
    distinct vertices holding them, all R slots per row, because the
    per-row ``(R,)`` bit draw costs the same as one slot's); ``stale_rows``
    counts distinct vertices with ≥ 1 stale segment (the "rebuilt ≤
    invalidated rows" acceptance gate compares against this).
    """

    epoch: int
    n: int
    changed_vertices: int
    stale_rows: int
    stale_segments: int
    segments_rebuilt: int
    total_segments: int


def dirty_block_mask(changed: np.ndarray, n: int) -> np.ndarray:
    """uint32[_MASK_WORDS] — the visited-block bits covering ``changed``."""
    dirty = np.zeros(_MASK_WORDS, dtype=np.uint32)
    if changed.size:
        blk = (np.asarray(changed, np.int64)
               // segment_mask_block_size(n)).astype(np.int64)
        np.bitwise_or.at(dirty, blk >> 5,
                         np.uint32(1) << (blk & 31).astype(np.uint32))
    return dirty


def invalidate_segments(
    index: Union[WalkIndex, ShardedWalkIndex], changed: np.ndarray
) -> np.ndarray:
    """bool[n, R] — True where segment ``(v, r)`` must be re-walked.

    Requires the index to carry ``visited_blocks`` (every slab built since
    epochs exist does); an index loaded from a pre-epoch checkpoint has no
    trajectory record and cannot be incrementally invalidated.
    """
    vb = index.visited_blocks
    if vb is None:
        raise ValueError(
            "index has no visited_blocks (built before per-segment "
            "trajectory masks existed) — incremental invalidation is "
            "impossible; rebuild the slab from scratch")
    n = index.n
    vb = np.asarray(vb, np.uint32)
    if vb.ndim == 4:                       # sharded [S, sz, R, W] → [n, R, W]
        S, sz, R, W = vb.shape
        vb = vb.reshape(S * sz, R, W)[:n]
    changed = np.asarray(changed, dtype=np.int64)
    if changed.size and (changed.min() < 0 or changed.max() >= n):
        raise ValueError(f"changed vertices outside [0, {n})")
    dirty = dirty_block_mask(changed, n)
    # only a handful of mask words are ever dirty (a batch touches few
    # blocks); testing those words alone beats AND-ing the full [n, R, W]
    # cube by ~20× at serving sizes.
    stale = np.zeros(vb.shape[:2], dtype=bool)
    for word in np.nonzero(dirty)[0]:
        stale |= (vb[:, :, word] & dirty[word]) != 0
    stale[changed] = True                  # source-list-changed rule
    return stale


def _dense_views(index):
    """(endpoints[n, R] copy, masks[n, R, W] copy, R) from either form."""
    n = index.n
    if isinstance(index, ShardedWalkIndex):
        S, sz, R = index.blocks.shape
        ep = np.asarray(index.blocks).reshape(S * sz, R)[:n].copy()
        vb = np.asarray(index.visited_blocks).reshape(
            S * sz, R, _MASK_WORDS)[:n].copy()
    else:
        ep = np.asarray(index.endpoints).copy()
        vb = np.asarray(index.visited_blocks).copy()
        R = ep.shape[1]
    return ep, vb, R


def refresh_walk_index(
    index: Union[WalkIndex, ShardedWalkIndex],
    new_graph: CSRGraph,
    changed: np.ndarray,
    *,
    step_impl: str = "xla",
    chunk: int = 4096,
):
    """Re-walks exactly the invalidated segments on ``new_graph``.

    Returns ``(new_index, report)`` where ``new_index`` has the same
    container type (and shard count) as ``index``, is stamped with
    ``new_graph``'s epoch/offset, and is **byte-identical to a
    from-scratch build at the new epoch** — endpoints and visited masks
    both (the per-vertex key-stream contract; tier-1 gates this).

    The *distinct stale rows* are walked through the builders'
    process-cached row program (:func:`_row_walk_program` — the graph's
    buffers are operands, so successive epochs re-dispatch instead of
    re-tracing unless the edge count changed), and only the invalidated
    cells are written back: a row's ``(R,)`` bit draw costs the same
    whether one slot or all R are kept, so walking whole rows is strictly
    cheaper than per-pair dispatch while the "rebuilds only invalidated
    segments" guarantee stays literal at the slab. Dispatch shapes form a
    bounded ladder — full ``chunk``-sized blocks plus one power-of-two
    tail — so steady-state refreshes of any stale-set size never re-trace;
    the tail is padded by *repeating stale rows*, never by touching a
    clean one, and duplicate writes are idempotent.
    """
    if new_graph.n != index.n:
        raise ValueError(
            f"graph n={new_graph.n} vs index n={index.n}: refresh cannot "
            f"change the vertex count")
    if new_graph.epoch <= index.graph_epoch:
        raise ValueError(
            f"graph epoch {new_graph.epoch} is not ahead of the slab's "
            f"{index.graph_epoch} — nothing to refresh (or the pair is "
            f"mismatched)")
    stale = invalidate_segments(index, changed)
    ep, vb, R = _dense_views(index)
    n, L = index.n, index.segment_len
    total = int(stale.sum())

    rows = np.flatnonzero(stale.any(axis=1))
    if total:
        run = _row_walk_program(n, step_impl, R, L,
                                segment_mask_block_size(n))
        key = jax.random.PRNGKey(index.seed)

        def walk_chunk(sel):
            e, m = run(new_graph.row_ptr, new_graph.col_idx,
                       new_graph.out_deg, jnp.asarray(sel, jnp.int32), key)
            ci, ri = np.nonzero(stale[sel])    # write only invalidated cells
            ep[sel[ci], ri] = np.asarray(e)[ci, ri]
            vb[sel[ci], ri] = np.asarray(m, dtype=np.uint32)[ci, ri]

        sz = rows.size
        tail = sz % chunk
        for lo in range(0, sz - tail, chunk):
            walk_chunk(rows[lo:lo + chunk])
        if tail:
            C = 1 << (tail - 1).bit_length()   # pow-2 shape ≥ tail
            walk_chunk(rows[(sz - tail + np.arange(C)) % sz])

    dense = WalkIndex(
        endpoints=jnp.asarray(ep, jnp.int32),
        segment_len=L, seed=index.seed,
        visited_blocks=np.asarray(vb, dtype=np.uint32),
        graph_epoch=new_graph.epoch,
        mutation_offset=new_graph.mutation_offset,
    )
    out = (shard_walk_index(dense, index.num_shards)
           if isinstance(index, ShardedWalkIndex) else dense)
    report = RefreshReport(
        epoch=new_graph.epoch, n=n,
        changed_vertices=int(np.asarray(changed).size),
        stale_rows=int(rows.size),
        stale_segments=total, segments_rebuilt=total,
        total_segments=int(n * R),
    )
    return out, report


# --- epoch'd checkpoint directories ------------------------------------------


def epoch_dir(directory: str, epoch: int) -> str:
    """``<directory>/epoch_<e>`` — one walk-index checkpoint layout per
    epoch, invisible to the base layout's shard/step scanners (they only
    match ``shard_*`` / ``step_*`` names), so epochs coexist with a
    pre-epoch checkpoint in the same tree."""
    return os.path.join(directory, f"epoch_{epoch:06d}")


def save_epoch_index(
    directory: str,
    index: Union[WalkIndex, ShardedWalkIndex],
    step: int = 0,
) -> str:
    """Persists ``index`` under its own epoch directory, reusing the
    crc/atomic-rename checkpoint machinery (dense → one step dir; sharded
    → one atomic dir per shard)."""
    d = epoch_dir(directory, index.graph_epoch)
    if isinstance(index, ShardedWalkIndex):
        S = index.num_shards
        for s in range(S):
            save_walk_index_shard(
                d, s, S, index.n, index.blocks[s], index.segment_len,
                index.seed, step=step,
                visited_blocks=(None if index.visited_blocks is None
                                else index.visited_blocks[s]),
                graph_epoch=index.graph_epoch,
                mutation_offset=index.mutation_offset)
    else:
        save_walk_index(d, index, step=step)
    return d


def load_epoch_index(
    directory: str,
    epoch: int,
    step: Optional[int] = None,
    reassemble: bool = True,
) -> Union[WalkIndex, ShardedWalkIndex]:
    """Loads the slab saved for ``epoch`` and verifies the manifest agrees
    — a directory whose contents claim a different epoch fails loudly
    (torn copy / manual tampering) instead of serving the wrong epoch."""
    idx = load_walk_index(epoch_dir(directory, epoch), step=step,
                          reassemble=reassemble)
    if idx.graph_epoch != epoch:
        raise ValueError(
            f"{epoch_dir(directory, epoch)!r} claims graph_epoch="
            f"{idx.graph_epoch}, expected {epoch} — refusing to serve a "
            f"mislabelled slab")
    return idx


def list_epochs(directory: str):
    """Sorted epochs with a saved slab under ``directory``."""
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("epoch_") and os.path.isdir(
                os.path.join(directory, name)):
            try:
                out.append(int(name[len("epoch_"):]))
            except ValueError:
                continue
    return sorted(out)
