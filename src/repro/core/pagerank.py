"""Exact PageRank via power iteration — the GraphLab-PR baseline.

``power_iteration`` is the continuous-water process the paper quantizes:
x ← (1 − p_T)·P·x + p_T/n. Each iteration touches every edge (O(E) work and,
distributed, O(E-cut) communication) — this is precisely the cost FrogWild
avoids. We use it (a) as ground truth π for accuracy metrics, (b) as the
reduced-iterations baseline (paper runs GraphLab PR for 1–2 iterations), and
(c) as the workload for the Pallas SpMV kernel.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.graph.csr import CSRGraph, transition_edges


@functools.partial(jax.jit, static_argnames=("n", "num_iters"))
def _power_iter_coo(
    src: jnp.ndarray,
    dst: jnp.ndarray,
    w: jnp.ndarray,
    n: int,
    num_iters: int,
    p_T: float,
) -> jnp.ndarray:
    def step(x, _):
        contrib = x[src] * w
        px = jax.ops.segment_sum(contrib, dst, num_segments=n)
        x_new = (1.0 - p_T) * px + p_T / n
        return x_new, None

    x0 = jnp.full((n,), 1.0 / n, dtype=jnp.float32)
    x, _ = jax.lax.scan(step, x0, None, length=num_iters)
    return x


def power_iteration(
    g: CSRGraph,
    num_iters: int = 50,
    p_T: float = 0.15,
    spmv: str = "coo",
) -> jnp.ndarray:
    """PageRank by power iteration.

    Args:
      g: the graph.
      num_iters: iterations. 50 ≈ machine-precision convergence at p_T=0.15
        (|λ2| ≤ 1 − p_T ⇒ error ≤ 0.85^50 ≈ 3e-4 of initial).
      p_T: teleport probability (paper uses 0.15 throughout).
      spmv: "coo" (segment-sum, CPU-fast) or "ell" (Pallas kernel path).
    """
    if spmv == "coo":
        src, dst, w = transition_edges(g)
        return _power_iter_coo(src, dst, w, g.n, num_iters, p_T)
    elif spmv == "ell":
        from repro.graph.partition import to_ell
        from repro.kernels import spmv_ops

        ell = to_ell(g, K=32)
        x = jnp.full((g.n,), 1.0 / n_round(g.n), dtype=jnp.float32)

        def step(x, _):
            px = spmv_ops.spmv(ell, x, interpret=True)[: g.n]
            return (1.0 - p_T) * px + p_T / g.n, None

        x, _ = jax.lax.scan(step, x, None, length=num_iters)
        return x
    raise ValueError(f"unknown spmv impl {spmv!r}")


def n_round(n: int, m: int = 8) -> int:
    return ((n + m - 1) // m) * m


def reduced_iteration_baseline(
    g: CSRGraph, num_iters: int, p_T: float = 0.15
) -> jnp.ndarray:
    """The paper's GraphLab-PR comparison point: run PR for 1–2 iterations
    only ("a good top-k approximation, much faster than convergence")."""
    return power_iteration(g, num_iters=num_iters, p_T=p_T)


def pagerank_residual(g: CSRGraph, x: jnp.ndarray, p_T: float = 0.15) -> jnp.ndarray:
    """‖Qx − x‖₁ — fixed-point residual, used by convergence tests."""
    src, dst, w = transition_edges(g)
    px = jax.ops.segment_sum(x[src] * w, dst, num_segments=g.n)
    qx = (1.0 - p_T) * px + p_T / g.n
    return jnp.abs(qx - x).sum()
