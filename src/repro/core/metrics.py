"""Top-k accuracy metrics (paper Definition 2 and §2.1.1).

* ``mass_captured``: μ_k(v) = π(argmax_{|S|=k} v(S)) — the true PageRank mass
  of the k vertices the estimate ranks highest. Maximized by π itself.
* ``exact_identification``: |top_k(v) ∩ top_k(π)| / k.
"""
from __future__ import annotations

import jax.numpy as jnp


def topk_set(v: jnp.ndarray, k: int) -> jnp.ndarray:
    """Indices of the k largest entries of v (ties broken by lower index,
    matching jax.lax.top_k semantics)."""
    _, idx = jnp.lax.top_k(v, k) if hasattr(jnp, "lax") else (None, None)
    return idx


def mass_captured(estimate: jnp.ndarray, pi: jnp.ndarray, k: int) -> jnp.ndarray:
    """μ_k(estimate) per paper Definition 2."""
    import jax

    _, idx = jax.lax.top_k(estimate, k)
    return pi[idx].sum()


def normalized_mass_captured(estimate: jnp.ndarray, pi: jnp.ndarray, k: int) -> jnp.ndarray:
    """μ_k(estimate) / μ_k(π) ∈ [0, 1] — the paper's plotted accuracy."""
    import jax

    _, idx_opt = jax.lax.top_k(pi, k)
    opt = pi[idx_opt].sum()
    return mass_captured(estimate, pi, k) / opt


def exact_identification(estimate: jnp.ndarray, pi: jnp.ndarray, k: int) -> jnp.ndarray:
    """Fraction of the true top-k list recovered (paper Fig. 2b)."""
    import jax

    _, a = jax.lax.top_k(estimate, k)
    _, b = jax.lax.top_k(pi, k)
    hits = (a[:, None] == b[None, :]).any(axis=1)
    return hits.mean()
