"""Core of the reproduction: the FrogWild! algorithm, its estimator, exact
PageRank baselines, accuracy metrics, analytic bounds and the generalized
partial-synchronization primitive."""
from repro.core.frogwild import FrogWildConfig, FrogWildResult, frogwild, frogwild_run
from repro.core.metrics import (
    exact_identification,
    mass_captured,
    normalized_mass_captured,
)
from repro.core.pagerank import power_iteration, reduced_iteration_baseline
from repro.core.partial_sync import (
    partial_all_to_all,
    partial_channel_mask,
    partial_psum,
)
from repro.core.sparsify import sparsify_uniform
from repro.core import theory

__all__ = [
    "FrogWildConfig",
    "FrogWildResult",
    "frogwild",
    "frogwild_run",
    "exact_identification",
    "mass_captured",
    "normalized_mass_captured",
    "power_iteration",
    "reduced_iteration_baseline",
    "partial_all_to_all",
    "partial_channel_mask",
    "partial_psum",
    "sparsify_uniform",
    "theory",
]
