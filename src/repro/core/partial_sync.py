"""Partial synchronization as a first-class, mesh-generic primitive.

This is the paper's `p_s` knob (randomized mirror synchronization in
PowerGraph) lifted to JAX collectives (DESIGN.md §3). All functions are meant
to be called **inside shard_map** with a named mesh axis.

Modes
-----
* ``unbiased``        — each shard's contribution enters the collective with
  probability p_s, scaled by 1/p_s. E[partial_psum(x)] = psum(x). This is the
  exact analogue of the paper's Binomial(K, 1/(d·p_s)) scatter marginal.
* ``error_feedback``  — contributions are masked *without* rescaling and the
  unsent part accumulates in a local residual that is added next round
  (gradient-compression-style). Biased per-step, but the bias telescopes:
  after T rounds the total synced mass equals the total produced mass minus
  one residual. Used for DP gradient sync where per-step unbiasedness matters
  less than variance.

Straggler note: dropping a shard's contribution for one round is
*mathematically identical* to that shard being a straggler whose message is
not waited for — Theorem 1 prices this in, which is why partial sync doubles
as straggler mitigation (README §fault-tolerance).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def _shard_coin(key: jax.Array, p_s: float, axis_name: str) -> jax.Array:
    """One Bernoulli(p_s) coin per shard along ``axis_name``; independent
    across shards (key folded with the shard index) and across calls."""
    idx = jax.lax.axis_index(axis_name)
    return jax.random.bernoulli(jax.random.fold_in(key, idx), p_s)


def partial_psum(
    x,
    axis_name: str,
    p_s: float,
    key: jax.Array,
    mode: str = "unbiased",
    residual=None,
):
    """Randomly-synchronized all-reduce over ``axis_name``.

    Args:
      x: pytree of arrays (per-shard contribution).
      p_s: synchronization probability. 1.0 short-circuits to plain psum.
      key: PRNG key, identical on all shards (folded per shard internally).
      mode: "unbiased" | "error_feedback".
      residual: pytree like x (required for error_feedback), carried state.

    Returns:
      unbiased:        psum of masked-and-rescaled contributions.
      error_feedback:  (psum of masked contributions, new_residual).
    """
    if p_s >= 1.0:
        out = jax.lax.psum(x, axis_name)
        return out if mode == "unbiased" else (out, residual)

    coin = _shard_coin(key, p_s, axis_name)
    if mode == "unbiased":
        scale = coin.astype(jnp.float32) / p_s
        masked = jax.tree.map(lambda a: a * scale.astype(a.dtype), x)
        return jax.lax.psum(masked, axis_name)
    elif mode == "error_feedback":
        if residual is None:
            residual = jax.tree.map(jnp.zeros_like, x)
        msg = jax.tree.map(lambda a, r: a + r, x, residual)
        sent = jax.tree.map(lambda m: m * coin.astype(m.dtype), msg)
        new_residual = jax.tree.map(lambda m, s: m - s, msg, sent)
        # No rescaling: the residual mechanism already conserves mass —
        # over T rounds Σ out = T·psum(x) − final residual. Rescaling by
        # n/n_synced would double-compensate (≈1/p_s long-run bias).
        out = jax.lax.psum(sent, axis_name)
        return out, new_residual
    raise ValueError(f"unknown mode {mode!r}")


def partial_channel_mask(
    key: jax.Array,
    p_s: float,
    axis_name: str,
    num_shards: int,
    force_one: bool = True,
) -> jax.Array:
    """bool[num_shards] — per-destination-channel coins for this shard.

    This is the engine's mirror-sync granularity: entry d says whether this
    shard's messages to shard d are synchronized this superstep. With
    ``force_one`` (Example 10, "at least one out-edge per node") one uniform
    channel is forced open when all coins come up tails, so no shard is ever
    fully cut off.
    """
    me = jax.lax.axis_index(axis_name)
    k = jax.random.fold_in(key, me)
    k_coin, k_force = jax.random.split(k)
    coins = jax.random.bernoulli(k_coin, p_s, shape=(num_shards,))
    if p_s >= 1.0:
        return jnp.ones((num_shards,), dtype=bool)
    if force_one:
        forced = jax.random.randint(k_force, (), 0, num_shards)
        all_closed = ~coins.any()
        coins = coins | (all_closed & (jnp.arange(num_shards) == forced))
    return coins


def partial_all_to_all(
    x: jnp.ndarray,
    axis_name: str,
    p_s: float,
    key: jax.Array,
    num_shards: int,
    compensate: bool = True,
) -> Tuple[jnp.ndarray, jax.Array]:
    """Channel-masked all-to-all along leading axis (length ``num_shards``).

    Each (sender → receiver) channel is open with probability p_s; closed
    channels transmit zeros (which XLA still moves, but the engine's cost
    model and a real sparse transport count only open channels — see
    engine/netcost.py). Open payloads are scaled 1/p_s when ``compensate``.

    Returns (received block-stack, open-channel mask used).
    """
    coins = partial_channel_mask(key, p_s, axis_name, num_shards)
    scale = (coins.astype(x.dtype) / (p_s if compensate else 1.0)) if p_s < 1.0 else (
        coins.astype(x.dtype)
    )
    shaped = scale.reshape((num_shards,) + (1,) * (x.ndim - 1))
    masked = x * shaped
    out = jax.lax.all_to_all(
        masked[:, None], axis_name, split_axis=0, concat_axis=0, tiled=False
    )[:, 0]
    return out, coins
