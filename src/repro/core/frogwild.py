"""FrogWild! walker-centric oracle (paper §2.2, Appendix A).

This is the *semantic reference* for the whole system: N discrete walkers
("frogs") start uniformly at random, take at most ``t`` steps following the
original transition matrix P, die with probability ``p_T`` at each apply()
(⇒ Geometric(p_T) lifespans truncated at t — Process 15, provably identical
in distribution to walking the Google matrix Q, Lemma 16), and are tallied
where they stop. The estimator π̂ = c/N (Definition 5).

Partial synchronization is modelled by **edge erasures** (Definition 8):
at every step a random subset of edges is disabled and frogs redraw uniformly
among surviving out-edges of their vertex (the "blocking walk", Process 19).
Three erasure models are implemented:

* ``none``           — p_s = 1, the plain process.
* ``independent``    — Example 9: every edge erased i.i.d. w.p. 1 − p_s.
                       With "at least one out-edge per node" repair
                       (Example 10) so walkers are never lost.
* ``channel``        — edges grouped by destination shard; one coin per
                       (vertex, destination-shard) pair. This is exactly what
                       the distributed engine does (and what the paper's
                       GraphLab patch does per mirror machine); Theorem 1's
                       analysis covers it through Definition 8.

Two interchangeable blocking-walk draws (``cfg.draw``):

* ``rejection`` — per-frog rejection sampling with pointwise keyed-hash
                  coins: O(N · 1/p_s) work per superstep, independent of
                  nnz (see core/blocking.py).
* ``cumsum``    — the direct per-edge mask + cumsum + searchsorted draw:
                  O(nnz) per superstep. Kept as the distributional
                  reference the rejection path is tested against.
* ``auto``      — (default) rejection exactly when its probe budget
                  undercuts the per-edge pass (the paper's N ≪ E regime).

The plain (p_s = 1) step can additionally run through the fused Pallas
``frog_step`` kernels (``cfg.step_impl``: ``xla`` | ``pallas`` | ``stream``
| ``auto`` | ``ref`` — ``stream`` is the HBM-streaming sorted-frog kernel
whose VMEM footprint is bounded by block shapes, not graph size;
``auto`` picks between the resident and streamed kernels by VMEM budget).

Everything is pure JAX (lax.scan over steps) and runs on CPU.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.config import FrogWildConfig, warn_deprecated
from repro.core.blocking import (channel_enum_draw, coin_uniform,
                                 rejection_blocking_draw,
                                 rejection_is_profitable)
from repro.graph.csr import CSRGraph, uniform_successor

# FrogWildConfig is defined in repro/config.py (the layered-config module —
# single definition per flag) and re-exported here for back-compat.


@dataclasses.dataclass
class FrogWildResult:
    counts: jnp.ndarray               # int32[n] — c(i), frogs stopped at i
    pi_hat: jnp.ndarray               # f32[n]   — counts / N (Definition 5)
    num_frogs: int


def _kept_mask(
    key: jax.Array,
    g: CSRGraph,
    dst_shard: jnp.ndarray,
    cfg: FrogWildConfig,
) -> jnp.ndarray:
    """Per-edge keep mask for one superstep (cumsum reference path only)."""
    if cfg.erasure == "independent":
        return jax.random.bernoulli(key, cfg.p_s, shape=g.col_idx.shape)
    elif cfg.erasure == "channel":
        # One coin per (source vertex, destination shard): all edges of v
        # going to shard s share the coin — the engine/mirror granularity.
        coins = jax.random.bernoulli(
            key, cfg.p_s, shape=(g.n, cfg.num_shards)
        )
        return coins[g.edge_src, dst_shard]
    raise ValueError(f"unknown erasure model {cfg.erasure!r}")


def draw_next_cumsum(
    g: CSRGraph, cfg: FrogWildConfig, key: jax.Array, pos: jnp.ndarray
) -> jnp.ndarray:
    """One blocking-walk scatter draw, O(nnz) reference implementation."""
    n = g.n
    row_ptr, col_idx, deg = g.row_ptr, g.col_idx, g.out_deg
    dst_shard = g.edge_dst_shard(cfg.num_shards)
    N = pos.shape[0]
    k_mask, k_force, k_draw = jax.random.split(key, 3)
    kept = _kept_mask(k_mask, g, dst_shard, cfg)
    csum = jnp.cumsum(kept.astype(jnp.int32))            # inclusive
    kept_before = jnp.concatenate([jnp.zeros((1,), jnp.int32), csum])
    # surviving out-degree per frog's vertex
    kv = kept_before[row_ptr[pos + 1]] - kept_before[row_ptr[pos]]
    # Example 10 repair: one forced edge per vertex when all erased.
    forced_slot = (
        jax.random.randint(k_force, (n,), 0, 1 << 30, jnp.int32)
        % jnp.maximum(deg, 1)
    )
    forced_edge = row_ptr[jnp.arange(n)] + forced_slot
    # rank among kept edges of the frog's vertex
    u = jax.random.randint(k_draw, (N,), 0, 1 << 30, jnp.int32)
    u = u % jnp.maximum(kv, 1)
    target = kept_before[row_ptr[pos]] + u + 1           # 1-indexed rank
    edge = jnp.searchsorted(csum, target, side="left").astype(jnp.int32)
    edge = jnp.where(kv > 0, edge, forced_edge[pos])
    nxt = col_idx[edge]
    return jnp.where(deg[pos] > 0, nxt, pos)


def draw_next_rejection(
    g: CSRGraph, cfg: FrogWildConfig, key: jax.Array, pos: jnp.ndarray
) -> jnp.ndarray:
    """One blocking-walk scatter draw in O(N) probes, independent of nnz.

    Independent model → edge rejection sampling (O(N · 1/p_s) probes);
    channel model → exact channel enumeration (O(N · S) probes) — rejection
    is not skew-safe at channel granularity (see core/blocking.py).
    """
    if cfg.erasure == "independent":
        chan_of = lambda v, e: e                       # one coin per edge
        edge = rejection_blocking_draw(
            key, pos, g.row_ptr, g.out_deg, cfg.p_s, chan_of
        )
        return jnp.where(g.out_deg[pos] > 0, g.col_idx[edge], pos)
    elif cfg.erasure == "channel":
        S = cfg.num_shards
        col_sorted, chan_cnt, chan_off = g.channel_layout(S)
        k_coin, k_draw = jax.random.split(key)
        chan_ids = pos[:, None] * S + jnp.arange(S, dtype=jnp.int32)[None, :]
        coins_open = coin_uniform(k_coin, chan_ids) < cfg.p_s
        edge = channel_enum_draw(
            k_draw, pos, g.row_ptr[pos], g.out_deg[pos],
            chan_cnt[pos], chan_off[pos], coins_open,
        )
        return jnp.where(g.out_deg[pos] > 0, col_sorted[edge], pos)
    raise ValueError(f"unknown erasure model {cfg.erasure!r}")


def draw_next(
    g: CSRGraph, cfg: FrogWildConfig, key: jax.Array, pos: jnp.ndarray
) -> jnp.ndarray:
    """One scatter draw under ``cfg`` (dispatches on ``cfg.draw``).

    ``auto`` picks rejection exactly when its probe budget undercuts the
    O(nnz) per-edge pass (the paper's N ≪ E regime); both impls remain
    forcible and are distribution-equivalent (tests/test_blocking_draw.py).
    Module-level so tests and benchmarks can exercise a single superstep's
    draw in isolation.
    """
    draw = cfg.draw
    if draw == "auto":
        nc = cfg.num_shards if cfg.erasure == "channel" else None
        draw = ("rejection"
                if rejection_is_profitable(pos.shape[0], g.nnz, cfg.p_s, nc)
                else "cumsum")
    if draw == "cumsum":
        return draw_next_cumsum(g, cfg, key, pos)
    elif draw == "rejection":
        return draw_next_rejection(g, cfg, key, pos)
    raise ValueError(f"unknown draw impl {cfg.draw!r}")


def frogwild_run(
    g: CSRGraph,
    cfg: FrogWildConfig,
    key: jax.Array,
) -> FrogWildResult:
    """Deprecated entry point — use :meth:`repro.service.FrogWildService.
    pagerank` (or :func:`repro.service.batch_pagerank`). Delegates through
    the service so the answer is byte-identical to the facade's."""
    warn_deprecated("frogwild_run", "FrogWildService.pagerank")
    from repro import service

    return service.batch_pagerank(g, cfg, key=key)


def _frogwild_walks(
    g: CSRGraph,
    cfg: FrogWildConfig,
    key: jax.Array,
) -> FrogWildResult:
    """Runs the FrogWild! process and returns the stop-counter estimator."""
    n = g.n
    N, t = cfg.num_frogs, cfg.num_steps
    row_ptr = g.row_ptr
    col_idx = g.col_idx
    deg = g.out_deg
    use_erasure = cfg.erasure != "none" and cfg.p_s < 1.0
    use_fused = (not use_erasure) and cfg.step_impl != "xla"

    k_init, k_loop = jax.random.split(key)
    pos0 = jax.random.randint(k_init, (N,), 0, n, dtype=jnp.int32)
    alive0 = jnp.ones((N,), dtype=bool)
    counts0 = jnp.zeros((n,), dtype=jnp.int32)

    def plain_move(kmove: jax.Array, pos: jnp.ndarray) -> jnp.ndarray:
        bits = jax.random.randint(kmove, (N,), 0, 1 << 30, dtype=jnp.int32)
        return uniform_successor(row_ptr, col_idx, deg, pos, bits)

    def step(carry, step_key):
        pos, alive, counts = carry
        k_die, k_move = jax.random.split(step_key)
        # apply(): each arriving frog dies w.p. p_T and is tallied here.
        die = jax.random.bernoulli(k_die, cfg.p_T, shape=(N,)) & alive
        if use_fused:
            from repro.kernels import ops

            slot_bits = jax.random.randint(k_move, (N,), 0, 1 << 30, jnp.int32)
            nxt, death_counts = ops.frog_step(
                pos, die, slot_bits, row_ptr, col_idx, deg, n,
                impl=cfg.step_impl,
            )
            counts = counts + death_counts
        else:
            counts = counts.at[pos].add(die.astype(jnp.int32))
            # scatter(): survivors traverse one (non-erased) out-edge.
            nxt = (draw_next(g, cfg, k_move, pos) if use_erasure
                   else plain_move(k_move, pos))
        alive = alive & ~die
        pos = jnp.where(alive, nxt, pos)
        return (pos, alive, counts), None

    keys = jax.random.split(k_loop, t)
    (pos, alive, counts), _ = jax.lax.scan(step, (pos0, alive0, counts0), keys)
    # cut-off at t: all surviving frogs halt and are tallied (Process 15).
    counts = counts.at[pos].add(alive.astype(jnp.int32))
    pi_hat = counts.astype(jnp.float32) / N
    return FrogWildResult(counts=counts, pi_hat=pi_hat, num_frogs=N)


# jitted entry point (static graph arrays close over the trace)
def frogwild(
    g: CSRGraph, cfg: FrogWildConfig, seed: int = 0
) -> FrogWildResult:
    key = jax.random.PRNGKey(seed)
    run = jax.jit(lambda k: _as_tuple(_frogwild_walks(g, cfg, k)))
    counts, pi_hat = run(key)
    return FrogWildResult(counts=counts, pi_hat=pi_hat, num_frogs=cfg.num_frogs)


def _as_tuple(r: FrogWildResult):
    return r.counts, r.pi_hat
