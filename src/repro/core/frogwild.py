"""FrogWild! walker-centric oracle (paper §2.2, Appendix A).

This is the *semantic reference* for the whole system: N discrete walkers
("frogs") start uniformly at random, take at most ``t`` steps following the
original transition matrix P, die with probability ``p_T`` at each apply()
(⇒ Geometric(p_T) lifespans truncated at t — Process 15, provably identical
in distribution to walking the Google matrix Q, Lemma 16), and are tallied
where they stop. The estimator π̂ = c/N (Definition 5).

Partial synchronization is modelled by **edge erasures** (Definition 8):
at every step a random subset of edges is disabled and frogs redraw uniformly
among surviving out-edges of their vertex (the "blocking walk", Process 19).
Three erasure models are implemented:

* ``none``           — p_s = 1, the plain process.
* ``independent``    — Example 9: every edge erased i.i.d. w.p. 1 − p_s.
                       With "at least one out-edge per node" repair
                       (Example 10) so walkers are never lost.
* ``channel``        — edges grouped by destination shard; one coin per
                       (vertex, destination-shard) pair. This is exactly what
                       the distributed engine does (and what the paper's
                       GraphLab patch does per mirror machine); Theorem 1's
                       analysis covers it through Definition 8.

Everything is pure JAX (lax.scan over steps) and runs on CPU.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.graph.csr import CSRGraph


@dataclasses.dataclass(frozen=True)
class FrogWildConfig:
    num_frogs: int = 100_000          # N  (paper uses 800K on 41.6M-vertex Twitter)
    num_steps: int = 4                # t  (paper: good results with 3–4 iterations)
    p_T: float = 0.15                 # teleport/death probability
    p_s: float = 1.0                  # synchronization probability
    erasure: str = "none"             # none | independent | channel
    num_shards: int = 16              # channel model: destination shards


@dataclasses.dataclass
class FrogWildResult:
    counts: jnp.ndarray               # int32[n] — c(i), frogs stopped at i
    pi_hat: jnp.ndarray               # f32[n]   — counts / N (Definition 5)
    num_frogs: int


def _kept_mask(
    key: jax.Array,
    g: CSRGraph,
    dst_shard: jnp.ndarray,
    cfg: FrogWildConfig,
) -> jnp.ndarray:
    """Per-edge keep mask for one superstep under the configured model."""
    if cfg.erasure == "independent":
        return jax.random.bernoulli(key, cfg.p_s, shape=g.col_idx.shape)
    elif cfg.erasure == "channel":
        # One coin per (source vertex, destination shard): all edges of v
        # going to shard s share the coin — the engine/mirror granularity.
        coins = jax.random.bernoulli(
            key, cfg.p_s, shape=(g.n, cfg.num_shards)
        )
        src = _edge_src(g)
        return coins[src, dst_shard]
    raise ValueError(f"unknown erasure model {cfg.erasure!r}")


def _edge_src(g: CSRGraph) -> jnp.ndarray:
    """int32[nnz] source vertex of each edge (computed once per graph)."""
    # repeat is cheap relative to the walk; avoid caching device arrays.
    return jnp.repeat(
        jnp.arange(g.n, dtype=jnp.int32), g.out_deg, total_repeat_length=g.nnz
    )


def frogwild_run(
    g: CSRGraph,
    cfg: FrogWildConfig,
    key: jax.Array,
) -> FrogWildResult:
    """Runs the FrogWild! process and returns the stop-counter estimator."""
    n, nnz = g.n, g.nnz
    N, t = cfg.num_frogs, cfg.num_steps
    row_ptr = g.row_ptr
    col_idx = g.col_idx
    deg = g.out_deg
    use_erasure = cfg.erasure != "none" and cfg.p_s < 1.0
    if use_erasure:
        src = _edge_src(g)
        dst_shard = (col_idx.astype(jnp.int32) //
                     max(1, -(-n // cfg.num_shards)))  # ceil-div shard size
    else:
        src = dst_shard = None

    k_init, k_loop = jax.random.split(key)
    pos0 = jax.random.randint(k_init, (N,), 0, n, dtype=jnp.int32)
    alive0 = jnp.ones((N,), dtype=bool)
    counts0 = jnp.zeros((n,), dtype=jnp.int32)

    def plain_move(kmove: jax.Array, pos: jnp.ndarray) -> jnp.ndarray:
        slot = jax.random.randint(kmove, (N,), 0, 1 << 30, dtype=jnp.int32)
        slot = slot % deg[pos]
        return col_idx[row_ptr[pos] + slot]

    def erasure_move(kmove: jax.Array, pos: jnp.ndarray) -> jnp.ndarray:
        k_mask, k_force, k_draw = jax.random.split(kmove, 3)
        kept = _kept_mask(k_mask, g, dst_shard, cfg)
        csum = jnp.cumsum(kept.astype(jnp.int32))            # inclusive
        kept_before = jnp.concatenate([jnp.zeros((1,), jnp.int32), csum])
        # surviving out-degree per frog's vertex
        kv = kept_before[row_ptr[pos + 1]] - kept_before[row_ptr[pos]]
        # Example 10 repair: one forced edge per vertex when all erased.
        forced_slot = jax.random.randint(k_force, (n,), 0, 1 << 30, jnp.int32) % deg
        forced_edge = row_ptr[jnp.arange(n)] + forced_slot
        # rank among kept edges of the frog's vertex
        u = jax.random.randint(k_draw, (N,), 0, 1 << 30, jnp.int32)
        u = u % jnp.maximum(kv, 1)
        target = kept_before[row_ptr[pos]] + u + 1           # 1-indexed rank
        edge = jnp.searchsorted(csum, target, side="left").astype(jnp.int32)
        edge = jnp.where(kv > 0, edge, forced_edge[pos])
        return col_idx[edge]

    def step(carry, step_key):
        pos, alive, counts = carry
        k_die, k_move = jax.random.split(step_key)
        # apply(): each arriving frog dies w.p. p_T and is tallied here.
        die = jax.random.bernoulli(k_die, cfg.p_T, shape=(N,)) & alive
        counts = counts.at[pos].add(die.astype(jnp.int32))
        alive = alive & ~die
        # scatter(): survivors traverse one (non-erased) out-edge.
        nxt = erasure_move(k_move, pos) if use_erasure else plain_move(k_move, pos)
        pos = jnp.where(alive, nxt, pos)
        return (pos, alive, counts), None

    keys = jax.random.split(k_loop, t)
    (pos, alive, counts), _ = jax.lax.scan(step, (pos0, alive0, counts0), keys)
    # cut-off at t: all surviving frogs halt and are tallied (Process 15).
    counts = counts.at[pos].add(alive.astype(jnp.int32))
    pi_hat = counts.astype(jnp.float32) / N
    return FrogWildResult(counts=counts, pi_hat=pi_hat, num_frogs=N)


# jitted entry point (static graph arrays close over the trace)
def frogwild(
    g: CSRGraph, cfg: FrogWildConfig, seed: int = 0
) -> FrogWildResult:
    key = jax.random.PRNGKey(seed)
    run = jax.jit(lambda k: _as_tuple(frogwild_run(g, cfg, k)))
    counts, pi_hat = run(key)
    return FrogWildResult(counts=counts, pi_hat=pi_hat, num_frogs=cfg.num_frogs)


def _as_tuple(r: FrogWildResult):
    return r.counts, r.pi_hat
