"""Rejection-sampled blocking-walk draw — Process 19 in O(N · 1/p_s).

The blocking walk (paper Definition 8 / Process 19) moves each frog uniformly
among the out-edges of its vertex that survived this superstep's erasure.  The
direct implementation materializes a per-edge keep mask, cumsums it, and
searchsorts a rank — O(nnz) work **per superstep**, the every-edge-every-
iteration cost profile FrogWild exists to avoid.

This module implements the same draw with **per-frog probes**, two variants:

``rejection_blocking_draw`` — for the *independent* model (one i.i.d. coin
per edge):

  1. draw a uniform out-edge slot of the frog's vertex,
  2. accept iff that edge's erasure coin is open,
  3. retry up to a bounded number of rounds,
  4. fall back to the Example-10 forced edge (a per-vertex uniform
     replacement edge) if every round rejected.

Conditioned on the coin realization, an accepted probe is uniform over the
kept edges — exactly the blocking-walk draw.  The bounded retry leaves a
residual that lands on the forced edge instead.  With i.i.d. per-edge coins
the acceptance rate kv/deg concentrates at p_s (the probability of
kv/deg ≪ p_s decays exponentially in both deg and the retry count), so
``num_rounds ≈ ln(1/ε)/p_s`` keeps the residual below any statistical
tolerance; for a fully-blocked vertex the fallback *is* the reference
behaviour.  Expected work is O(N / p_s) probes total, independent of nnz.

``channel_enum_draw`` — for the *channel* model (one coin per (vertex,
destination-shard)).  Rejection is NOT sound here: channel-count skew (a hub
with almost all edges on one closed channel) drives the acceptance rate
kv/deg arbitrarily far below p_s with constant probability, so any fixed
retry budget misroutes such vertices through the forced edge.  Instead the
draw enumerates the ≤ S channel coins pointwise, samples a channel with
probability ∝ edges-on-open-channels (static per-graph counts), then a
uniform edge within the channel — exact for any skew, O(N · S) work,
loop-free, still nnz-free.

Coins are never materialized: a coin is a pure function of
``(channel id, step key)`` evaluated pointwise — O(1) per *probe*, never
O(edges) or O(channels).  The caller picks the channel granularity:

  * independent model — channel id = edge index (one coin per edge);
  * channel model     — channel id = vertex · S + destination shard (one coin
    per (vertex, mirror) pair: the engine/GraphLab granularity).

Because the coin is a deterministic hash of the channel id, every probe of
the same channel in the same superstep sees the same coin — the consistency
the blocking walk requires across frogs, retry rounds, and the engine's
sync-message accounting grid.

Two coin hashes are provided (``coin_uniform(..., impl=)``):

* ``"hash"``    — (default) two-round splitmix32 mix keyed by the step key's
                  raw words.  Pure vectorized integer ops: this is what keeps
                  a probe ~10× cheaper than a per-edge ``bernoulli`` lane, so
                  the whole point of the rejection draw survives contact with
                  real wall clocks.  Statistical quality is enforced by
                  tests (uniformity, key decorrelation, and distribution
                  equivalence of the full draw against the cumsum reference).
* ``"fold_in"`` — one ``jax.random.fold_in`` (threefry) per element; the
                  reference construction the fast hash is validated against.
                  ~50× slower on CPU (vmapped scalar fold-ins), so it is the
                  cross-check, not the hot path.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

ROUNDS_PER_CHUNK = 32      # probes drawn per while_loop iteration (vectorized)
UNROLL_PROBES = 1 << 21    # ≤ this many total probes ⇒ loop-free single shot

_M1 = np.uint32(0x7FEB352D)
_M2 = np.uint32(0x846CA68B)
_GOLDEN = np.uint32(0x9E3779B9)


def num_rounds_for(p_s: float, eps: float = 1e-4) -> int:
    """Retry budget so the non-accept residual (1-p_s)^K is below ``eps``."""
    return int(np.clip(np.ceil(np.log(1.0 / eps) / max(p_s, 1e-3)), 8, 256))


def rejection_is_profitable(
    B: int, nnz: int, p_s: float, num_channels: Optional[int] = None
) -> bool:
    """``draw="auto"`` policy: the probe-based draw wins when its worst-case
    probe budget undercuts the per-edge pass by a comfortable constant
    (measured crossover on the bench graphs sits near probes ≈ nnz/3).
    ``num_channels`` set ⇒ the channel-enumeration draw (B · S probes);
    unset ⇒ edge rejection (B · num_rounds probes)."""
    probes = B * (num_channels if num_channels else num_rounds_for(p_s))
    return probes * 3 <= nnz


def _key_words(key: jax.Array):
    """The key's two raw uint32 words (typed or legacy uint32[2] keys)."""
    if jnp.issubdtype(key.dtype, jax.dtypes.prng_key):
        data = jax.random.key_data(key)
    else:
        data = key
    return data[0].astype(jnp.uint32), data[1].astype(jnp.uint32)


def _splitmix(x: jnp.ndarray) -> jnp.ndarray:
    """splitmix32 finalizer — full-avalanche 32-bit mix."""
    x = x ^ (x >> 16)
    x = x * _M1
    x = x ^ (x >> 15)
    x = x * _M2
    x = x ^ (x >> 16)
    return x


def hash_bits(key: jax.Array, idx: jnp.ndarray) -> jnp.ndarray:
    """uint32 hash per (key, idx): two chained splitmix32 rounds, one key
    word injected per round. Vectorized integer ops only."""
    k0, k1 = _key_words(key)
    x = idx.astype(jnp.uint32) * _GOLDEN + k0
    x = _splitmix(x) ^ k1
    return _splitmix(x)


def coin_uniform(
    key: jax.Array, idx: jnp.ndarray, impl: str = "hash"
) -> jnp.ndarray:
    """Deterministic uniform [0, 1) per (key, idx) — the erasure coin."""
    if impl == "hash":
        bits = hash_bits(key, idx)
    elif impl == "fold_in":
        flat = idx.reshape(-1)
        data = jax.vmap(
            lambda i: jax.random.key_data(jax.random.fold_in(key, i))
        )(flat)                                           # uint32[M, 2]
        bits = data[:, 1].reshape(idx.shape)
    else:
        raise ValueError(f"unknown coin impl {impl!r}")
    return (bits >> jnp.uint32(8)).astype(jnp.float32) * (1.0 / (1 << 24))


def forced_edge_for(
    key: jax.Array,
    pos: jnp.ndarray,          # int32[B] vertex per frog
    row_ptr_at: jnp.ndarray,   # int32[B] row_ptr[pos]
    deg_at: jnp.ndarray,       # int32[B] out_deg[pos]
) -> jnp.ndarray:
    """Example-10 repair edge, evaluated per frog but keyed per *vertex*:
    every frog on the same fully-blocked vertex is forced onto the same
    uniformly-chosen edge (the paper's per-vertex replacement edge)."""
    degs = jnp.maximum(deg_at, 1)
    u = coin_uniform(key, pos)
    slot = jnp.minimum((u * degs.astype(jnp.float32)).astype(jnp.int32),
                       degs - 1)
    return row_ptr_at + slot


def channel_enum_draw(
    key: jax.Array,
    pos: jnp.ndarray,                   # int32[B] vertex per frog
    row_ptr_at: jnp.ndarray,            # int32[B] row_ptr[pos]
    deg_at: jnp.ndarray,                # int32[B] out_deg[pos]
    chan_cnt_at: jnp.ndarray,           # int32[B, S] edges of pos into shard d
    chan_off_at: jnp.ndarray,           # int32[B, S] channel offsets of pos
    coins_open: jnp.ndarray,            # bool [B, S] — this superstep's coins
    skip: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """EXACT blocking draw for the channel model, O(B · S), loop-free.

    The channel model has at most S coins per vertex, so instead of
    rejection-probing edges (whose acceptance rate is kv/deg and can be
    driven arbitrarily low by channel-count skew — e.g. a hub with 99 edges
    on a closed channel and 1 on an open one), enumerate the channels:
    sample a channel with probability ∝ edges-on-open-channel, then a
    uniform edge within it.  Conditioned on the coins this is uniform over
    kept edges with no retry residual; kv = 0 takes the Example-10 forced
    edge exactly as the reference does.

    Returns an index into the **channel-sorted** edge array
    (``CSRGraph.channel_layout``'s ``col_sorted``), not ``col_idx``.
    """
    B = pos.shape[0]
    k_draw, k_force = jax.random.split(key)
    w = jnp.where(coins_open, chan_cnt_at, 0)             # [B, S]
    csum = jnp.cumsum(w, axis=1)
    kv = csum[:, -1]
    r = (
        (hash_bits(k_draw, jnp.arange(B, dtype=jnp.int32)) >> jnp.uint32(1))
        .astype(jnp.int32) % jnp.maximum(kv, 1)
    )
    chan = (csum > r[:, None]).argmax(axis=1)             # weighted channel
    before = jnp.take_along_axis(csum - w, chan[:, None], axis=1)[:, 0]
    j = r - before                                        # uniform in channel
    edge = (
        row_ptr_at
        + jnp.take_along_axis(chan_off_at, chan[:, None], axis=1)[:, 0]
        + j
    )
    forced = forced_edge_for(k_force, pos, row_ptr_at, deg_at)
    ok = (kv > 0) & (deg_at > 0)
    if skip is not None:
        ok = ok & ~skip
    return jnp.where(ok, edge, forced)


def rejection_blocking_draw(
    key: jax.Array,
    pos: jnp.ndarray,                   # int32[B] vertex per frog
    row_ptr: jnp.ndarray,               # int32[n(+pad) + 1]
    deg: jnp.ndarray,                   # int32[n(+pad)]
    p_s: float,
    chan_of: Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray],
    num_rounds: Optional[int] = None,
    skip: Optional[jnp.ndarray] = None,  # bool[B] — frogs to leave untouched
    coin_key: Optional[jax.Array] = None,
) -> jnp.ndarray:
    """Draws one surviving out-EDGE index per frog (caller gathers col_idx).

    ``chan_of(v, e)`` maps (frog vertex, candidate edge index) to the erasure
    channel id whose coin gates the edge.  Frogs with ``skip`` set (dead /
    padding) and zero-out-degree vertices get their forced edge immediately;
    callers mask the result anyway.

    Work: O(B) per chunk of ROUNDS_PER_CHUNK probes; the while_loop body is
    pure integer hashing (no jax.random calls), and the loop exits as soon as
    every frog accepted — expected total O(B / p_s), capped at
    ``num_rounds``.

    ``coin_key`` overrides the internally-derived channel-coin key — the
    engine passes its superstep coin key here so the draw's acceptance checks
    and its sync-message accounting grid evaluate the *same* coins.
    """
    B = pos.shape[0]
    if num_rounds is None:
        num_rounds = num_rounds_for(p_s)
    k_slot, k_coin, k_force = jax.random.split(key, 3)
    if coin_key is not None:
        k_coin = coin_key

    deg_at = deg[pos]
    degs = jnp.maximum(deg_at, 1)
    base = row_ptr[pos]
    forced = forced_edge_for(k_force, pos, base, deg_at)

    done0 = deg_at <= 0
    if skip is not None:
        done0 = done0 | skip

    def probes(c, R):
        """[R, B] candidate edges + acceptance for chunk c of R rounds."""
        probe_id = (
            jnp.arange(R * B, dtype=jnp.int32).reshape(R, B) + c * (R * B)
        )
        slot_bits = hash_bits(k_slot, probe_id)
        slot = (slot_bits >> jnp.uint32(1)).astype(jnp.int32) % degs[None, :]
        e = base[None, :] + slot
        u = coin_uniform(k_coin, chan_of(jnp.broadcast_to(pos, e.shape), e))
        return e, u < p_s

    def first_hit(e, acc, edge, done):
        hit = acc.any(axis=0)
        first = jnp.argmax(acc, axis=0)
        cand = jnp.take_along_axis(e, first[None, :], axis=0)[0]
        return jnp.where(~done & hit, cand, edge), done | hit

    if num_rounds * B <= UNROLL_PROBES:
        # small batch: all rounds in one loop-free vectorized shot (the
        # sequential while_loop's per-iteration dispatch would dominate).
        e, acc = probes(0, num_rounds)
        edge, _ = first_hit(e, acc, forced, done0)
        return edge

    R = ROUNDS_PER_CHUNK
    n_chunks = -(-num_rounds // R)

    def cond(state):
        _, done, c = state
        return (c < n_chunks) & ~done.all()

    def chunk(state):
        edge, done, c = state
        e, acc = probes(c, R)
        edge, done = first_hit(e, acc, edge, done)
        return edge, done, c + 1

    edge, _, _ = jax.lax.while_loop(cond, chunk, (forced, done0, 0))
    return edge
