"""Uniform graph sparsification baseline (paper §2.4, Figure 5).

The natural heuristic FrogWild is compared against: independently delete each
edge with probability ``r = 1 − q``, then run a couple of power iterations on
the sparsified graph. (The paper notes no known sparsifier preserves
PageRank; this uniform one is the cheap strawman and FrogWild beats it on
time at comparable accuracy.)
"""
from __future__ import annotations

import numpy as np

from repro.graph.csr import CSRGraph, build_csr


def sparsify_uniform(g: CSRGraph, keep_prob: float, seed: int = 0) -> CSRGraph:
    """Keeps each edge i.i.d. with probability ``keep_prob`` (q in Fig. 5).

    Vertices that lose all out-edges are repaired by ``build_csr``'s dangling
    fix (mirrors GraphLab needing d_out > 0).
    """
    if not (0.0 < keep_prob <= 1.0):
        raise ValueError("keep_prob must be in (0, 1]")
    gn = g.to_numpy()
    rng = np.random.default_rng(seed)
    keep = rng.random(g.nnz) < keep_prob
    deg = gn.out_deg.astype(np.int64)
    src = np.repeat(np.arange(g.n, dtype=np.int64), deg)
    return build_csr(g.n, src[keep], gn.col_idx[keep].astype(np.int64))
