"""Analytic bounds from the paper (Theorems 1 & 2, Proposition 7, Remark 6).

These are used by ``benchmarks/bench_theory.py`` to check the main theorem
empirically, and by ``examples/quickstart.py`` to pick N and t for a target
accuracy (Remark 6 scaling).
"""
from __future__ import annotations

import math


def mixing_term(p_T: float, t: int) -> float:
    """First term of (4): sqrt((1 − p_T)^{t+1} / p_T) — truncation penalty."""
    return math.sqrt((1.0 - p_T) ** (t + 1) / p_T)


def sampling_term(k: int, delta: float, N: int, p_s: float, p_cap: float) -> float:
    """Second term of (4): sqrt(k/δ · [1/N + (1 − p_s²)·p_∩(t)])."""
    return math.sqrt((k / delta) * (1.0 / N + (1.0 - p_s**2) * p_cap))


def epsilon_bound(
    p_T: float, t: int, k: int, delta: float, N: int, p_s: float, p_cap: float
) -> float:
    """Theorem 1: with probability ≥ 1 − δ,  μ_k(π̂) > μ_k(π) − ε with this ε."""
    return mixing_term(p_T, t) + sampling_term(k, delta, N, p_s, p_cap)


def p_cap_bound(n: int, t: int, pi_inf: float, p_T: float) -> float:
    """Theorem 2: p_∩(t) ≤ 1/n + t·‖π‖∞/p_T for uniformly-started walks."""
    return 1.0 / n + t * pi_inf / p_T


def pi_inf_powerlaw_bound(n: int, gamma: float = 0.5) -> float:
    """Proposition 7 instance: ‖π‖∞ ≤ n^{-γ} w.h.p. for θ ≈ 2.2 power laws."""
    return n ** (-gamma)


def suggested_steps(mu_k: float, p_T: float = 0.15) -> int:
    """Remark 6: t = O(log 1/μ_k(π)). Constant chosen so the mixing term is
    below μ_k/4."""
    target = (mu_k / 4.0) ** 2 * p_T
    t = math.log(target) / math.log(1.0 - p_T) - 1.0
    return max(1, math.ceil(t))


def suggested_frogs(k: int, mu_k: float, delta: float = 0.1) -> int:
    """Remark 6: N = O(k / μ_k(π)²), constant so the 1/N part of the sampling
    term is below μ_k/4 at confidence δ."""
    return max(1, math.ceil(16.0 * k / (delta * mu_k**2)))
