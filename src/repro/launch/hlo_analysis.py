"""Post-optimization HLO cost walker.

XLA's built-in ``compiled.cost_analysis()`` does **not** multiply while-loop
bodies by their trip counts (verified empirically — a 10-step scan reports
1-step FLOPs), which makes it useless for scan-over-layers programs. This
walker re-derives the three roofline inputs from ``compiled.as_text()``:

  * ``flops``            — 2·prod(result)·prod(contracted) per ``dot`` op,
                           multiplied through the while-loop call graph using
                           the ``known_trip_count`` backend configs;
  * ``memory_bytes``     — Σ (operand + result bytes) over non-trivial ops
                           (fusions, dots, copies, slices, collectives).
                           Post-fusion HLO makes this a reasonable HBM-traffic
                           proxy (upper bound: ignores VMEM residency);
  * ``collective_bytes`` — wire bytes per device with ring-algorithm factors:
                           all-gather (g−1)/g·result, all-reduce 2(g−1)/g,
                           reduce-scatter (g−1)·result, all-to-all (g−1)/g,
                           collective-permute 1×.

All numbers are **per device** (the module is the SPMD per-device program).
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\((.*?)\)\s*->")
_TRIP_RE = re.compile(r'known_trip_count[\\"={:]+n[\\"]*:[\\"]*(\d+)')
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_OLD_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")

_SKIP_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of (possibly tuple) HLO type text."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> Tuple[List[int], str]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return [], ""
    dt, dims = m.groups()
    return ([int(d) for d in dims.split(",")] if dims else []), dt


@dataclasses.dataclass
class _Op:
    name: str
    type_str: str
    opcode: str
    rest: str                    # operands + attributes


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    memory_bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_breakdown: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    collective_counts: Dict[str, int] = dataclasses.field(
        default_factory=dict)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def _parse_op_line(line: str) -> Optional[_Op]:
    """Parses '%name = TYPE opcode(rest'. TYPE may be a tuple containing
    parens, layouts and /*index=k*/ comments (which contain '=' — a plain
    regex mis-splits there, silently dropping e.g. while ops with big tuple
    carries and all their FLOPs)."""
    s = line.strip()
    if s.startswith("ROOT "):
        s = s[5:]
    if not s.startswith("%"):
        return None
    eq = s.find(" = ")
    if eq < 0:
        return None
    name = s[1:eq]
    rest = s[eq + 3:]
    if rest.startswith("("):
        depth = 0
        end = 0
        for i, c in enumerate(rest):
            if c == "(":
                depth += 1
            elif c == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        type_str = rest[: end + 1]
        tail = rest[end + 1:].lstrip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        type_str = rest[:sp]
        tail = rest[sp + 1:]
    par = tail.find("(")
    if par < 0:
        return None
    opcode = tail[:par].strip()
    if not opcode or " " in opcode:
        return None
    return _Op(name, type_str, opcode, tail[par + 1:])


def _parse_computations(text: str) -> Dict[str, List[_Op]]:
    comps: Dict[str, List[_Op]] = {}
    cur: Optional[str] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_RE.match(line)
            if m and line.rstrip().endswith("{"):
                cur = m.group(1)
                comps[cur] = []
        else:
            if line.startswith("}"):
                cur = None
                continue
            op = _parse_op_line(line)
            if op is not None:
                comps[cur].append(op)
    return comps


def _operand_names(rest: str) -> List[str]:
    # operands are %names before the closing paren of the op call
    depth, out, i = 1, [], 0
    token = ""
    while i < len(rest) and depth > 0:
        c = rest[i]
        if c == "(":
            depth += 1
        elif c == ")":
            depth -= 1
        if depth >= 1 and c == "%":
            j = i + 1
            while j < len(rest) and (rest[j].isalnum() or rest[j] in "._-"):
                j += 1
            out.append(rest[i + 1 : j])
            i = j
            continue
        i += 1
    return out


def _group_size(rest: str, num_partitions: int) -> int:
    m = _GROUPS_RE.search(rest)
    if m:
        return int(m.group(2))
    m = _GROUPS_OLD_RE.search(rest)
    if m:
        return len(m.group(1).split(","))
    return num_partitions


def analyze_hlo(text: str) -> HloCost:
    comps = _parse_computations(text)
    num_partitions = 1
    mnp = re.search(r"num_partitions=(\d+)", text)
    if mnp:
        num_partitions = int(mnp.group(1))

    # symbol tables: op name -> type string (per computation)
    symtab: Dict[str, Dict[str, str]] = {
        c: {op.name: op.type_str for op in ops} for c, ops in comps.items()
    }
    # computation parameters also appear as ops (parameter(k)) — included.

    # ---- call-graph multipliers ----
    mult: Dict[str, float] = {}

    entry = None
    # entry is the last computation in scheduled modules; find via ENTRY tag
    em = re.search(r"ENTRY\s+%?([\w.\-]+)", text)
    if em:
        entry = em.group(1)
    else:  # fallback: computation with most ops
        entry = max(comps, key=lambda c: len(comps[c]))

    def visit(cname: str, m: float):
        mult[cname] = mult.get(cname, 0.0) + m
        for op in comps.get(cname, []):
            callees: List[Tuple[str, float]] = []
            if op.opcode == "while":
                tm = _TRIP_RE.search(op.rest)
                trips = float(tm.group(1)) if tm else 1.0
                bm = re.search(r"body=%?([\w.\-]+)", op.rest)
                cm = re.search(r"condition=%?([\w.\-]+)", op.rest)
                if bm:
                    callees.append((bm.group(1), trips))
                if cm:
                    callees.append((cm.group(1), trips))
            elif op.opcode in ("fusion", "call", "map", "reduce",
                               "reduce-window", "scatter", "sort", "select-and-scatter"):
                for cm_ in re.finditer(r"(?:calls|to_apply)=%?([\w.\-]+)",
                                       op.rest):
                    callees.append((cm_.group(1), 1.0))
            elif op.opcode == "conditional":
                for cm_ in re.finditer(r"branch_computations=\{([^}]*)\}",
                                       op.rest):
                    for b in cm_.group(1).split(","):
                        callees.append((b.strip().lstrip("%"), 1.0))
                for key in ("true_computation", "false_computation"):
                    km = re.search(rf"{key}=%?([\w.\-]+)", op.rest)
                    if km:
                        callees.append((km.group(1), 1.0))
            for callee, k in callees:
                if callee in comps:
                    visit(callee, m * k)

    visit(entry, 1.0)

    cost = HloCost()
    for cname, ops in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        st = symtab[cname]
        for op in ops:
            if op.opcode in _SKIP_OPS:
                continue
            rbytes = _shape_bytes(op.type_str)
            obytes = sum(
                _shape_bytes(st.get(o, "")) for o in _operand_names(op.rest))
            if op.opcode not in ("while", "conditional", "call"):
                cost.memory_bytes += m * (rbytes + obytes)
            if op.opcode == "dot":
                dims, _ = _shape_dims(op.type_str)
                out_elems = 1
                for d in dims:
                    out_elems *= d
                opnames = _operand_names(op.rest)
                lhs_dims, _ = _shape_dims(st.get(opnames[0], "")) if opnames \
                    else ([], "")
                cm_ = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
                contracted = 1
                if cm_ and cm_.group(1):
                    for ci in cm_.group(1).split(","):
                        ci = int(ci)
                        if ci < len(lhs_dims):
                            contracted *= lhs_dims[ci]
                cost.flops += m * 2.0 * out_elems * contracted
            if op.opcode in _COLLECTIVES:
                g = _group_size(op.rest, num_partitions)
                if op.opcode == "all-reduce":
                    wire = 2.0 * rbytes * (g - 1) / g
                elif op.opcode == "all-gather":
                    wire = rbytes * (g - 1) / g
                elif op.opcode == "reduce-scatter":
                    wire = rbytes * (g - 1)
                elif op.opcode == "all-to-all":
                    wire = rbytes * (g - 1) / g
                else:  # collective-permute
                    wire = float(rbytes)
                cost.collective_bytes += m * wire
                cost.collective_breakdown[op.opcode] = (
                    cost.collective_breakdown.get(op.opcode, 0.0) + m * wire)
                cost.collective_counts[op.opcode] = (
                    cost.collective_counts.get(op.opcode, 0) + 1)
    return cost
