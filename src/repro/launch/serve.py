"""Serving launcher: batched generation with the fixed-slot scheduler.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
      --requests 6 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs.registry import get_config, reduced_config
from repro.models.transformer import init_params
from repro.serving import BatchScheduler, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced_config(cfg)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    sched = BatchScheduler(params, cfg, max_batch=args.max_batch,
                           max_len=256)
    rng = jax.random.PRNGKey(args.seed + 1)
    for r in range(args.requests):
        k = jax.random.fold_in(rng, r)
        n = 3 + r % 5
        prompt = [int(t) for t in
                  jax.random.randint(k, (n,), 2, cfg.vocab_size)]
        sched.submit(Request(rid=r, prompt=prompt,
                             max_new_tokens=args.max_new))
    t0 = time.time()
    done = sched.run()
    dt = time.time() - t0
    total = sum(len(r.output) for r in done)
    for r in done:
        print(f"[serve] req {r.rid}: {len(r.output)} tokens → {r.output[:8]}…")
    print(f"[serve] {len(done)} requests, {total} tokens in {dt:.1f}s "
          f"({total / max(dt, 1e-9):.1f} tok/s)")


if __name__ == "__main__":
    main()
