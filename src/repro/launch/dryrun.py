import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines: jax locks device count on first init. The
# 512 placeholder host devices exist ONLY for dry-run lowering/compilation —
# smoke tests and benchmarks never import this module and see 1 device.

"""Multi-pod dry-run: lower + compile every (architecture × shape × mesh)
cell from ShapeDtypeStructs, print memory/cost analyses, and derive the
roofline terms (launch/roofline.py) from the compiled HLO.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun
  PYTHONPATH=src python -m repro.launch.dryrun --engine            # paper's own workload
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.registry import (ARCHS, SHAPES, get_config, input_specs,
                                    param_specs, shape_applicable)
from repro.distributed.sharding import (
    MeshAxes,
    batch_pspec,
    decode_state_pspecs,
    param_pspecs,
)
from repro.launch.mesh import make_production_mesh, make_vertex_mesh
from repro.launch.roofline import build_roofline
from repro.models.config import ModelConfig
from repro.models.transformer import decode_step, forward_train
from repro.training.optimizer import adamw_init
from repro.training.train_step import TrainStepConfig, make_train_step


def _named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _bf16_specs(specs):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, jnp.bfloat16 if jnp.issubdtype(s.dtype, jnp.floating)
            else s.dtype),
        specs)


def lower_cell(arch: str, shape: str, mesh: Mesh, mesh_name: str,
               overrides: Optional[dict] = None):
    """Returns (lowered, kind, cfg, extras) for one dry-run cell.

    ``overrides``: dataclasses.replace kwargs on the ModelConfig — the §Perf
    hillclimb harness lowers variants through the identical path.
    """
    import dataclasses as _dc2

    from repro.distributed.context import activation_sharding

    cfg = get_config(arch)
    if overrides:
        cfg = _dc2.replace(cfg, **overrides)
    kind, specs = input_specs(cfg, shape)
    ax_train = MeshAxes.for_mesh(mesh, fsdp=True)
    ax_serve = MeshAxes.for_mesh(mesh, fsdp=False)
    key_spec = jax.ShapeDtypeStruct((2,), jnp.uint32)
    p_specs = param_specs(cfg)
    # sequence parallelism on for train (saved-activation stacks must fit);
    # serving paths have no saved stacks — plain constraints suffice.
    act_ctx = activation_sharding(mesh, dp=ax_train.data, tp=ax_train.model,
                                  sp=(kind == "train"))

    if kind == "train":
        accum = 8 if cfg.param_count > 5e9 else 4
        tcfg = TrainStepConfig(remat=True, accum_steps=accum)
        step = make_train_step(cfg, tcfg)
        opt_specs = jax.eval_shape(adamw_init, p_specs)
        state_specs = {"params": p_specs, "opt": opt_specs}
        pspec_tree = param_pspecs(cfg, mesh, p_specs, ax_train)
        state_pspecs = {
            "params": pspec_tree,
            "opt": {"m": pspec_tree, "v": pspec_tree, "step": P()},
        }
        batch_pspecs = batch_pspec(cfg, mesh, specs, ax_train)
        with act_ctx:
            lowered = jax.jit(
                step,
                in_shardings=(_named(mesh, state_pspecs),
                              _named(mesh, batch_pspecs),
                              NamedSharding(mesh, P())),
                out_shardings=(_named(mesh, state_pspecs), None),
                donate_argnums=(0,),
            ).lower(state_specs, specs, key_spec)
        return lowered, kind, cfg

    if kind == "prefill":
        import dataclasses as _dc

        # dispatch chunking only helps backward-pass transients; for the
        # forward-only serving path the chunk scan's stacked copies cost more
        # than they save.
        cfg = _dc.replace(cfg, moe_dispatch_chunks=1)

        def fwd(params, batch):
            logits, _ = forward_train(params, batch, cfg, remat=False)
            return logits[:, -1].astype(jnp.float32)   # serving: last token

        serve_params = _bf16_specs(p_specs)
        pspec_tree = param_pspecs(cfg, mesh, serve_params, ax_serve)
        batch_pspecs = batch_pspec(cfg, mesh, specs, ax_serve)
        with act_ctx:
            lowered = jax.jit(
                fwd,
                in_shardings=(_named(mesh, pspec_tree),
                              _named(mesh, batch_pspecs)),
            ).lower(serve_params, specs)
        return lowered, kind, cfg

    # decode — serve_step: one token against the configured cache.
    def step_fn(params, state, tokens):
        logits, new_state = decode_step(params, state, tokens, cfg)
        return logits.astype(jnp.float32), new_state

    serve_params = _bf16_specs(p_specs)
    # decode-state specs come from init_decode_state and already carry the
    # serving dtypes (bf16 KV caches, f32 SSM recurrence states) — no cast.
    state_specs = specs["state"]
    pspec_tree = param_pspecs(cfg, mesh, serve_params, ax_serve)
    state_pspecs = decode_state_pspecs(cfg, mesh, state_specs, ax_serve)
    tok_pspec = batch_pspec(cfg, mesh, specs["tokens"], ax_serve)
    with act_ctx:
        lowered = jax.jit(
            step_fn,
            in_shardings=(_named(mesh, pspec_tree),
                          _named(mesh, state_pspecs),
                          NamedSharding(mesh, tok_pspec)),
            out_shardings=(None, _named(mesh, state_pspecs)),
            donate_argnums=(1,),
        ).lower(serve_params, state_specs, specs["tokens"])
    return lowered, kind, cfg


HBM_PER_CHIP = 16 * 1024**3          # v5e


def run_cell(arch: str, shape: str, mesh_name: str,
             save_dir: Optional[str] = None,
             keep_hlo: bool = False,
             overrides: Optional[dict] = None,
             tag: str = "") -> Dict[str, Any]:
    cfg = get_config(arch)
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        res = {"arch": arch, "shape": shape, "mesh": mesh_name,
               "skipped": why}
        _save(res, save_dir)
        return res

    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    chips = mesh.devices.size
    t0 = time.time()
    try:
        lowered, kind, cfg = lower_cell(arch, shape, mesh, mesh_name,
                                        overrides=overrides)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        mem_d = {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
        }
        live = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
        mem_d["live_bytes_per_device"] = live
        mem_d["fits_hbm"] = bool(live <= HBM_PER_CHIP)
        xla_cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        spec = SHAPES[shape]
        roof = build_roofline(
            arch, shape, mesh_name, chips, hlo, cfg, kind,
            spec.seq_len, spec.global_batch, memory_analysis=mem_d)
        res = {
            "arch": arch, "shape": shape, "mesh": mesh_name, "chips": chips,
            "kind": kind, "ok": True, "tag": tag,
            "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
            "memory": mem_d,
            "xla_flops_per_device_unscanned": float(xla_cost.get("flops", 0)),
            "roofline": roof.as_dict(),
        }
        print(f"[dryrun] {arch} × {shape} [{mesh_name}] OK "
              f"live={live/1e9:.2f}GB/chip fits={mem_d['fits_hbm']} "
              f"lower={t_lower:.0f}s compile={t_compile:.0f}s")
        print("         " + roof.summary())
        if keep_hlo and save_dir:
            with open(os.path.join(
                    save_dir, f"{arch}_{shape}_{mesh_name}.hlo.txt"),
                    "w") as f:
                f.write(hlo)
    except Exception as e:  # noqa: BLE001 — dry-run must report every cell
        res = {"arch": arch, "shape": shape, "mesh": mesh_name,
               "ok": False, "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-2000:]}
        print(f"[dryrun] {arch} × {shape} [{mesh_name}] FAILED: "
              f"{type(e).__name__}: {str(e)[:200]}")
    _save(res, save_dir)
    return res


def _save(res: Dict[str, Any], save_dir: Optional[str]):
    if not save_dir:
        return
    os.makedirs(save_dir, exist_ok=True)
    tag = ("_" + res["tag"]) if res.get("tag") else ""
    name = f"{res['arch']}_{res['shape']}_{res['mesh']}{tag}.json".replace("/", "-")
    with open(os.path.join(save_dir, name), "w") as f:
        json.dump(res, f, indent=1, default=str)


def run_engine_cells(mesh_name: str, save_dir: Optional[str] = None):
    """The paper's own workload at production scale: FrogWild + GraphLab-PR
    baseline on a Twitter-scale graph spec, on the vertex mesh."""
    from repro.configs.frogwild_graphs import TWITTER_FULL
    from repro.engine.baseline import PullGraph, pagerank_dryrun_lowered
    from repro.engine.gas import (DistributedGraph, EngineConfig,
                                  frogwild_dryrun_lowered)

    mesh = make_vertex_mesh(multi_pod=(mesh_name == "multi"))
    S = mesh.devices.size
    n = TWITTER_FULL.n
    sz = -(-n // S)
    sz = ((sz + 7) // 8) * 8
    nnz_per = int(TWITTER_FULL.avg_out_deg * sz * 2)       # 2× skew headroom
    nnz_per = ((nnz_per + 7) // 8) * 8
    results = []

    dg = DistributedGraph(num_shards=S, shard_size=sz, n=n, nnz_max=nnz_per)
    ecfg = EngineConfig(num_frogs=800_000, num_steps=4, p_s=0.7)
    for name, low_fn in (
        ("frogwild", lambda: frogwild_dryrun_lowered(dg, ecfg, mesh)),
        ("graphlab-pr", lambda: pagerank_dryrun_lowered(
            PullGraph(num_shards=S, shard_size=sz, n=n, nnz_max=nnz_per),
            mesh, num_iters=2)),
    ):
        t0 = time.time()
        try:
            lowered = low_fn()
            compiled = lowered.compile()
            mem = compiled.memory_analysis()
            live = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                    + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
            from repro.launch.hlo_analysis import analyze_hlo
            cost = analyze_hlo(compiled.as_text())
            res = {
                "arch": name, "shape": "twitter-full", "mesh": mesh_name,
                "chips": S, "ok": True, "kind": "engine",
                "compile_s": round(time.time() - t0, 1),
                "memory": {"live_bytes_per_device": live,
                           "fits_hbm": bool(live <= HBM_PER_CHIP)},
                "hlo_cost": cost.as_dict(),
            }
            print(f"[dryrun] engine {name} [{mesh_name}] OK "
                  f"live={live/1e9:.3f}GB/chip "
                  f"coll={cost.collective_bytes/1e6:.1f}MB/dev")
        except Exception as e:  # noqa: BLE001
            res = {"arch": name, "shape": "twitter-full", "mesh": mesh_name,
                   "ok": False, "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-2000:]}
            print(f"[dryrun] engine {name} [{mesh_name}] FAILED: {e}")
        _save(res, save_dir)
        results.append(res)
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id or 'all'")
    ap.add_argument("--shape", default=None, help="shape id or 'all'")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true",
                    help="every (arch × shape) cell")
    ap.add_argument("--engine", action="store_true",
                    help="the paper's graph-engine cells")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--keep-hlo", action="store_true")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.engine:
        for m in meshes:
            run_engine_cells(m, args.out)
        return

    archs = list(ARCHS) if (args.all or args.arch == "all") else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape == "all") else [args.shape]
    if not archs[0] or not shapes[0]:
        ap.error("need --arch and --shape, or --all")
    n_ok = n_fail = n_skip = 0
    for m in meshes:
        for a in archs:
            for s in shapes:
                r = run_cell(a, s, m, args.out, keep_hlo=args.keep_hlo)
                if "skipped" in r:
                    n_skip += 1
                elif r.get("ok"):
                    n_ok += 1
                else:
                    n_fail += 1
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, {n_fail} failed")


if __name__ == "__main__":
    main()
