"""Training launcher (CPU-runnable at reduced scale; mesh-ready at full).

Runs real optimization steps with the synthetic token pipeline, async
checkpointing every ``--ckpt-every`` steps, and crash-resume (restores the
latest checkpoint if present — kill it mid-run and relaunch to see).

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke \
      --steps 50 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import Checkpointer, latest_step, restore_checkpoint
from repro.configs.registry import get_config, reduced_config
from repro.data import SyntheticTokens
from repro.training import AdamWConfig, PartialSyncConfig, TrainStepConfig
from repro.training.train_step import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--partial-sync", type=float, default=1.0,
                    help="p_s for FrogWild-style gradient sync (<1 enables)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced_config(cfg)
    tcfg = TrainStepConfig(
        opt=AdamWConfig(lr=args.lr, warmup_steps=10, total_steps=args.steps,
                        weight_decay=0.0),
        remat=True,
        mode="partial_sync" if args.partial_sync < 1.0 else "gspmd",
        partial_sync=PartialSyncConfig(p_s=args.partial_sync,
                                       granularity="layer"),
    )
    mesh = None
    data_axes = ("data",)
    if tcfg.mode == "partial_sync":
        n = jax.device_count()
        mesh = jax.make_mesh((n,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))

    key = jax.random.PRNGKey(args.seed)
    state = init_train_state(cfg, key, tcfg)
    start = 0
    ckpt = None
    if args.ckpt_dir:
        ckpt = Checkpointer(args.ckpt_dir)
        last = latest_step(args.ckpt_dir)
        if last is not None:
            state = restore_checkpoint(args.ckpt_dir, last, state)
            start = last
            print(f"[train] resumed from step {last}")

    data = SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=args.seq,
                           global_batch=args.batch, seed=args.seed)
    step_fn = jax.jit(make_train_step(cfg, tcfg, mesh=mesh,
                                      data_axes=data_axes))
    t0 = time.time()
    for i in range(start, args.steps):
        batch = data.batch(i)
        state, metrics = step_fn(state, batch, jax.random.fold_in(key, i))
        if i % 10 == 0 or i == args.steps - 1:
            print(f"[train] step {i:5d} loss={float(metrics['loss']):.4f} "
                  f"acc={float(metrics['accuracy']):.3f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"({time.time() - t0:.1f}s)")
        if ckpt and (i + 1) % args.ckpt_every == 0:
            ckpt.save_async(i + 1, state)
    if ckpt:
        ckpt.wait()
    print("[train] done")


if __name__ == "__main__":
    main()
