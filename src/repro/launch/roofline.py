"""Roofline terms from a compiled dry-run artifact (TPU v5e targets).

  compute   = FLOPs_per_device / 197 TFLOP/s (bf16)
  memory    = HBM-ish bytes_per_device / 819 GB/s
  collective= wire bytes_per_device / 50 GB/s ICI

FLOPs / bytes come from the exact HLO walker (hlo_analysis.py — XLA's own
cost_analysis drops while-loop trip counts). MODEL_FLOPS uses the 6·N·D
(train) / 2·N·D (inference) convention with N = active params.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.launch.hlo_analysis import HloCost, analyze_hlo

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    memory_bytes_per_device: float
    collective_bytes_per_device: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops_per_device: float
    useful_flops_ratio: float
    step_time_s: float                     # max of the three terms
    hw_util: float                         # model_flops/(step_time·peak)
    collective_breakdown: Dict[str, float]
    memory_analysis: Optional[dict] = None

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    def summary(self) -> str:
        return (f"{self.arch} × {self.shape} [{self.mesh}]  "
                f"compute={self.compute_s*1e3:.2f}ms "
                f"memory={self.memory_s*1e3:.2f}ms "
                f"collective={self.collective_s*1e3:.2f}ms "
                f"→ {self.dominant}-bound, "
                f"useful={self.useful_flops_ratio:.2f}, "
                f"MFU*={self.hw_util:.3f}")


def model_flops(cfg, kind: str, seq_len: int, global_batch: int) -> float:
    """6·N·D train / 2·N·D inference (D = tokens this step, global)."""
    n = cfg.active_param_count
    if kind == "train":
        tokens = seq_len * global_batch
        return 6.0 * n * tokens
    if kind == "prefill":
        tokens = seq_len * global_batch
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * global_batch


def build_roofline(
    arch: str,
    shape: str,
    mesh_name: str,
    chips: int,
    hlo_text: str,
    cfg,
    kind: str,
    seq_len: int,
    global_batch: int,
    memory_analysis: Optional[dict] = None,
) -> Roofline:
    cost = analyze_hlo(hlo_text)
    compute_s = cost.flops / PEAK_FLOPS
    memory_s = cost.memory_bytes / HBM_BW
    coll_s = cost.collective_bytes / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, kind, seq_len, global_batch) / chips
    step = max(compute_s, memory_s, coll_s)
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_device=cost.flops,
        memory_bytes_per_device=cost.memory_bytes,
        collective_bytes_per_device=cost.collective_bytes,
        compute_s=compute_s, memory_s=memory_s, collective_s=coll_s,
        dominant=dominant,
        model_flops_per_device=mf,
        useful_flops_ratio=(mf / cost.flops) if cost.flops else 0.0,
        step_time_s=step,
        hw_util=(mf / (step * PEAK_FLOPS)) if step > 0 else 0.0,
        collective_breakdown=cost.collective_breakdown,
        memory_analysis=memory_analysis,
    )
