"""Production meshes.

Functions, not module-level constants — importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def _make(shape, axes):
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """The LM mesh: 16×16 chips per pod; ``pod`` axis for the 2-pod config."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make(shape, axes)


def make_vertex_mesh(*, multi_pod: bool = False):
    """The graph-engine mesh: all chips flattened on one ``vertex`` axis
    (vertex range-sharding has no 2-D structure to exploit)."""
    n = 512 if multi_pod else 256
    return _make((n,), ("vertex",))


def make_test_mesh(n_data: int = 2, n_model: int = 2):
    return _make((n_data, n_model), ("data", "model"))
