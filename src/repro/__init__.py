"""FrogWild! reproduction package — served through one facade.

The public surface is the **service API** (``repro/service.py``)::

    from repro import FrogWildService, RuntimeConfig

    svc = FrogWildService.open(graph_or_path, RuntimeConfig())
    res = svc.pagerank(epsilon=0.3, delta=0.1)      # batch (auto dispatch)
    h = svc.topk(k=10, epsilon=0.3)                 # async QueryHandle
    while not h.poll():
        print(h.partial().epsilon_bound)            # anytime: tightens
    print(h.result().vertices)

``FrogWildService.open`` owns graph ingestion, shard-runtime acquisition,
and the walk-index lifecycle (build / load / reuse through ``checkpoint/``);
``topk`` / ``ppr`` return :class:`~repro.service.QueryHandle` futures whose
``partial()`` snapshots carry a monotonically tightening Theorem-1
``epsilon_bound`` and which complete early once the requested (ε, δ) target
is met. Configuration is the layered :class:`~repro.config.RuntimeConfig`
(kernel + runtime + serving sub-configs, one definition per flag).

Above the facade sits the **serving gateway** (``repro/gateway/``): a
replica pool over one shared graph/walk-index, an (ε, δ)-aware result
cache with in-flight dedup (dominance contract: a cached certificate
(ε′, δ′) serves a request (ε, δ) iff ε′ ≤ ε and δ′ ≤ δ), and a metrics /
health layer with a stdlib HTTP front-end — ``Gateway.open(graph,
replicas=2)``.

The historical entry points (``frogwild_run``, ``distributed_frogwild``,
``build_walk_index{,_sharded}``, ``QueryScheduler.submit/run``) remain as
deprecation shims that delegate through the service and return
byte-identical results.

Importing ``repro`` (any submodule) installs the jax version-compat shims —
the codebase targets the jax ≥ 0.5 public API (``jax.shard_map``,
``jax.sharding.AxisType``, ``make_mesh(axis_types=)``) and
``distributed/compat.py`` back-fills those names on older containers.
"""
from repro.distributed.compat import install as _install_jax_compat

_install_jax_compat()

from repro.config import (KernelConfig, RuntimeConfig, ServingConfig,
                          ShardConfig)
from repro.gateway import Gateway
from repro.service import FrogWildService, QueryHandle

__all__ = [
    "FrogWildService",
    "Gateway",
    "QueryHandle",
    "RuntimeConfig",
    "KernelConfig",
    "ShardConfig",
    "ServingConfig",
]
