"""FrogWild! reproduction package.

Importing ``repro`` (any submodule) installs the jax version-compat shims —
the codebase targets the jax ≥ 0.5 public API (``jax.shard_map``,
``jax.sharding.AxisType``, ``make_mesh(axis_types=)``) and
``distributed/compat.py`` back-fills those names on older containers.
"""
from repro.distributed.compat import install as _install_jax_compat

_install_jax_compat()
