"""Single configuration dataclass covering every assigned architecture family."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"            # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int = 2
    d_model: int = 128
    num_heads: int = 4
    num_kv_heads: int = 4
    d_ff: int = 512
    vocab_size: int = 1024
    head_dim: Optional[int] = None   # default: d_model // num_heads

    # --- attention pattern ---
    sliding_window: Optional[int] = None   # SWA width (danube, gemma3 locals)
    global_every: Optional[int] = None     # gemma3: every Nth layer is global
    rope_theta: float = 10_000.0
    logit_soft_cap: Optional[float] = None

    # --- MoE ---
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_capacity_factor: float = 1.25
    moe_dispatch_chunks: int = 8       # batch sub-chunks per dispatch scan

    # --- SSM (rwkv6 / mamba2) ---
    ssm_state: int = 64
    ssm_heads: Optional[int] = None        # default d_model // ssm_head_dim
    ssm_head_dim: int = 64
    conv_width: int = 4                    # mamba2 depthwise conv

    # --- hybrid (zamba2) ---
    shared_attn_every: int = 0             # shared attention block period

    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 1500                # precomputed frame embeddings

    # --- frontend stubs ---
    num_prefix_embeddings: int = 0         # VLM: precomputed patch embeds

    # --- numerics / misc ---
    act: str = "silu"
    mlp_gated: bool = True                 # False: classic 2-matrix MLP
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"                # compute dtype
    param_dtype: str = "float32"           # storage dtype
    attn_impl: str = "jnp_flash"           # jnp_flash | pallas | ref | cp_kv
    attn_chunk: int = 512                  # q-chunk for jnp_flash
    attn_bf16_probs: bool = False          # §Perf: bf16 softmax probs
    ssm_state_sharding: bool = True        # §Perf: shard recurrence state (V3)
    kv_cache_dtype: str = "compute"        # "compute" (=dtype) | "int8"

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.ssm_heads is None:
            object.__setattr__(
                self, "ssm_heads", max(1, self.d_model // self.ssm_head_dim)
            )

    # ---- derived properties ----
    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def has_decoder(self) -> bool:
        return True   # every assigned arch has an autoregressive decoder

    @property
    def subquadratic(self) -> bool:
        """Can this arch decode at 500k context? (DESIGN.md §4)."""
        if self.family in ("ssm", "hybrid"):
            return True
        if self.sliding_window is not None:
            return True          # SWA (danube) / local-global (gemma3)
        return False

    def layer_is_global(self, i: int) -> bool:
        """gemma3-style local:global pattern; True ⇒ full attention."""
        if self.global_every is None:
            return self.sliding_window is None
        return (i + 1) % self.global_every == 0

    @property
    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks), for rooflines.

        Matches the implemented modules (tests assert against actual trees).
        """
        d, f, V, L = self.d_model, self.d_ff, self.vocab_size, self.num_layers
        hd, Hq, Hkv = self.head_dim, self.num_heads, self.num_kv_heads
        emb = V * d * (1 if self.tie_embeddings else 2)
        attn = d * hd * (Hq + 2 * Hkv) + Hq * hd * d
        n_mlp_mats = 3 if self.mlp_gated else 2
        mlp = n_mlp_mats * d * f
        if self.family == "moe":
            mlp = self.num_experts * 3 * d * f + d * self.num_experts
        if self.family == "ssm":
            # rwkv6: 5 d×d time-mix mats + decay LoRA + 2-matrix channel mix
            per_layer = 5 * d * d + d * 64 + 64 * d + 2 * d * f
        elif self.family == "hybrid":
            # mamba2: in_proj (z,x → 2·2d) + out_proj (2d) ≈ 6d² + small
            per_layer = 6 * d * d + 2 * d * self.ssm_state + d * 2
        else:
            per_layer = attn + mlp
        total = emb + L * per_layer
        if self.family == "encdec":
            total += self.encoder_layers * (attn + mlp) + L * attn  # cross-attn
        if self.family == "hybrid" and self.shared_attn_every:
            total += attn + 3 * d * f          # one shared attn+MLP block
        if self.family == "vlm":
            total += d * d                     # vision projector stub
        return int(total)

    @property
    def active_param_count(self) -> int:
        """Active parameters per token (MoE: top-k experts only)."""
        if self.family != "moe":
            return self.param_count
        d, f, L = self.d_model, self.d_ff, self.num_layers
        dense_mlp = self.num_experts_per_tok * 3 * d * f
        full_mlp = self.num_experts * 3 * d * f
        return int(self.param_count - L * (full_mlp - dense_mlp))
