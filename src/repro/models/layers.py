"""Shared building blocks: norms, rotary embeddings, linear/embedding init."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def pdtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


# ----------------------------------------------------------------------------
# initializers
# ----------------------------------------------------------------------------

def dense_init(key, shape, dtype, fan_in: Optional[int] = None):
    fan_in = fan_in if fan_in is not None else shape[0]
    std = fan_in ** -0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            ).astype(dtype)


# ----------------------------------------------------------------------------
# RMSNorm
# ----------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rmsnorm(params: dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = (xf * xf).mean(axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


# ----------------------------------------------------------------------------
# Rotary position embeddings (supports offset for decode)
# ----------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(
    x: jnp.ndarray,                # [B, H, S, D]
    positions: jnp.ndarray,        # int32[S] or int32[B, S]
    theta: float = 10_000.0,
) -> jnp.ndarray:
    B, H, S, D = x.shape
    freqs = rope_freqs(D, theta)                     # [D/2]
    if positions.ndim == 1:
        ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]  # [S, D/2]
        ang = ang[None, None]                                          # [1,1,S,D/2]
    else:
        ang = positions.astype(jnp.float32)[:, None, :, None] * freqs  # [B,1,S,D/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------------
# Embedding / unembedding
# ----------------------------------------------------------------------------

def embedding_init(key, cfg: ModelConfig) -> dict:
    p = {"embedding": embed_init(key, (cfg.vocab_size, cfg.d_model),
                                 pdtype_of(cfg))}
    return p


def embed_tokens(params: dict, tokens: jnp.ndarray, cfg: ModelConfig
                 ) -> jnp.ndarray:
    emb = params["embedding"].astype(dtype_of(cfg))
    x = jnp.take(emb, tokens, axis=0)
    return x * jnp.asarray(cfg.d_model ** 0.5, dtype=x.dtype)


def unembed(params: dict, x: jnp.ndarray, cfg: ModelConfig,
            head: Optional[dict] = None) -> jnp.ndarray:
    """Project to vocab logits (tied or separate head)."""
    if cfg.tie_embeddings or head is None:
        w = params["embedding"].astype(dtype_of(cfg))       # [V, d]
        return jnp.einsum("...d,vd->...v", x, w)
    w = head["kernel"].astype(dtype_of(cfg))                # [d, V]
    return jnp.einsum("...d,dv->...v", x, w)


def lm_head_init(key, cfg: ModelConfig) -> Optional[dict]:
    if cfg.tie_embeddings:
        return None
    return {"kernel": dense_init(key, (cfg.d_model, cfg.vocab_size),
                                 pdtype_of(cfg))}
