"""Mixture-of-Experts block: top-k router + sort-based capacity dispatch.

Dispatch is the same fixed-capacity bucketing pattern as the engine's frog
exchange (gas.py `_pack_by_shard`): argsort token-slots by expert, rank-in-
group by index arithmetic, capacity overflow dropped. No [T, E, C] one-hot
tensors are ever materialized — the dispatch buffer is [E, C, d] and experts
are applied with a single batched einsum, sharded expert-parallel
(P("model", None, None)) by the sharding rules.

Partial synchronization hook (DESIGN.md §3): with ``p_s < 1`` the router's
expert set is stochastically masked per step — the FrogWild channel lottery
applied to EP dispatch; dropped experts' tokens fall through to their
next-best routed expert, and router probabilities are renormalized (the
analogue of the blocking-walk redraw).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.context import constrain
from repro.models.config import ModelConfig
from repro.models.layers import dense_init, dtype_of, pdtype_of

_ACTS = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}


def moe_init(key, cfg: ModelConfig) -> dict:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    kr, kg, ku, kd = jax.random.split(key, 4)
    pd = pdtype_of(cfg)
    return {
        "router": dense_init(kr, (d, E), pd),
        "w_gate": dense_init(kg, (E, d, f), pd, fan_in=d),
        "w_up": dense_init(ku, (E, d, f), pd, fan_in=d),
        "w_down": dense_init(kd, (E, f, d), pd, fan_in=f),
    }


def capacity(cfg: ModelConfig, num_tokens: int) -> int:
    import math

    c = num_tokens * cfg.num_experts_per_tok / cfg.num_experts
    c = math.ceil(c * cfg.moe_capacity_factor)
    return max(8, ((c + 7) // 8) * 8)


def moe_forward(
    params: dict,
    x: jnp.ndarray,                    # [B, S, d]
    cfg: ModelConfig,
    expert_mask: Optional[jnp.ndarray] = None,   # bool[E] — partial-sync lottery
) -> Tuple[jnp.ndarray, dict]:
    """Returns (output, aux) where aux carries the load-balancing loss.

    GShard-style **grouped dispatch**: each sequence is its own routing group
    (capacity per group = S·k/E·factor), and the sort/bucket runs vmapped
    over the batch dim. Groups align with the data-sharded batch axis, so
    under GSPMD the dispatch is entirely batch-local — no global sort, no
    token all-gather; only the expert einsums (E sharded on the model axis)
    move tokens, which is the EP all-to-all proper.
    """
    B0, S0, d = x.shape
    # GShard-style routing groups: long sequences are split into ≤4096-token
    # groups (capacity enforced per group) so dispatch gathers stay bounded
    # at 32k+ prefill.
    gs = S0
    while gs > 4096 and gs % 2 == 0:
        gs //= 2
    B, S = B0 * (S0 // gs), gs
    x = x.reshape(B, S, d)
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    dt = dtype_of(cfg)
    C = capacity(cfg, S)

    logits = jnp.einsum("bsd,de->bse", x, params["router"].astype(dt))
    logits = logits.astype(jnp.float32)
    if expert_mask is not None:
        logits = jnp.where(expert_mask[None, None, :], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)                       # [B, S, E]
    top_p, top_e = jax.lax.top_k(probs, k)                        # [B, S, k]
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # ---- load-balancing auxiliary loss (Switch-style, global) ----
    me = probs.mean(axis=(0, 1))                                  # [E]
    ce = jax.nn.one_hot(top_e[..., 0], E).mean(axis=(0, 1))       # [E]
    aux_loss = E * jnp.sum(me * ce)

    def dispatch_group(xg, eg, wg):
        """One group: xg [S, d], eg/wg [S, k] → (buf [E,C,d], meta)."""
        e_flat = eg.reshape(-1)                                   # [S*k]
        w_flat = wg.reshape(-1).astype(dt)
        t_flat = jnp.arange(S * k, dtype=jnp.int32) // k
        order = jnp.argsort(e_flat)                               # stable
        e_s, t_s, w_s = e_flat[order], t_flat[order], w_flat[order]
        first = jnp.searchsorted(e_s, jnp.arange(E), side="left")
        rank = jnp.arange(S * k, dtype=jnp.int32) - first[
            jnp.clip(e_s, 0, E - 1)].astype(jnp.int32)
        ok = rank < C
        row = jnp.where(ok, e_s, E)                               # OOB drops
        col = jnp.where(ok, rank, 0)
        buf = jnp.zeros((E, C, d), dt).at[row, col].set(
            xg[t_s], mode="drop")
        return buf, (row, col, t_s, w_s, ok)

    def combine_group(ob, m):
        row, col, t_s, w_s, ok = m
        vals = ob[row, col] * w_s[:, None]                        # [S*k, d]
        vals = jnp.where(ok[:, None], vals, 0)
        y = jnp.zeros((S, d), dt).at[t_s].add(vals)
        return y, (~ok).sum()

    act = _ACTS[cfg.act]

    def chunk_fn(_, inp):
        """Dispatch + experts + combine for one batch sub-chunk. The chunk
        scan (checkpointed) bounds the [S·k, d]-sized gather/scatter
        transients — with all groups vmapped at once they dominate HBM."""
        xg, eg, wg = inp
        buf, meta = jax.vmap(dispatch_group)(xg, eg, wg)          # [Bc,E,C,d]
        buf = constrain(buf, "bh")    # batch over data, experts over model
        g = jnp.einsum("becd,edf->becf", buf, params["w_gate"].astype(dt))
        u = jnp.einsum("becd,edf->becf", buf, params["w_up"].astype(dt))
        h = constrain(act(g) * u, "bh")
        out_buf = constrain(
            jnp.einsum("becf,efd->becd", h, params["w_down"].astype(dt)),
            "bh")
        y, dropped = jax.vmap(combine_group)(out_buf, meta)
        return None, (y, dropped.sum())

    # chunk count: bound transients while keeping the per-chunk batch a
    # multiple of 32 (so data-axis sharding of the chunk survives on meshes
    # up to dp=32); degenerate cases fall back to one pass.
    n_chunks = min(cfg.moe_dispatch_chunks, max(1, B // 32))
    if B % n_chunks != 0:
        n_chunks = 1
    Bc = B // n_chunks
    xs = (x.reshape(n_chunks, Bc, S, d),
          top_e.reshape(n_chunks, Bc, S, k),
          top_p.reshape(n_chunks, Bc, S, k))
    if n_chunks > 1:
        _, (y, dropped) = jax.lax.scan(jax.checkpoint(chunk_fn), None, xs)
        dropped = dropped.sum()
    else:
        _, (y, dropped) = chunk_fn(None, jax.tree.map(lambda a: a[0], xs))
    return y.reshape(B0, S0, d), {"aux_loss": aux_loss, "dropped": dropped}
