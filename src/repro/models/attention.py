"""GQA attention block: train/prefill (chunked flash) and decode (KV cache).

Decode supports two cache layouts:
* ``full``  — cache length = max context (standard full attention);
* ``ring``  — cache length = sliding window; positions wrap modulo the
  window (danube / gemma3-local layers). This is what makes 500k-token
  decode O(window) in memory for SWA layers.

The split-KV (sequence-sharded cache) distributed decode lives in
``repro/serving/decode.py``; this module is layout-agnostic single-logical-
device math that GSPMD shards via constraint specs.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.models.config import ModelConfig
from repro.models.layers import apply_rope, dense_init, dtype_of, pdtype_of


def attention_init(key, cfg: ModelConfig) -> dict:
    d, hd = cfg.d_model, cfg.head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    pd = pdtype_of(cfg)
    return {
        "wq": dense_init(kq, (d, cfg.num_heads * hd), pd),
        "wk": dense_init(kk, (d, cfg.num_kv_heads * hd), pd),
        "wv": dense_init(kv, (d, cfg.num_kv_heads * hd), pd),
        "wo": dense_init(ko, (cfg.num_heads * hd, d), pd, fan_in=cfg.num_heads * hd),
    }


def _project_q(params: dict, x: jnp.ndarray, cfg: ModelConfig):
    B, S, _ = x.shape
    dt = dtype_of(cfg)
    q = jnp.einsum("bsd,dh->bsh", x, params["wq"].astype(dt))
    return q.reshape(B, S, cfg.num_heads, cfg.head_dim).transpose(0, 2, 1, 3)


def project_kv(params: dict, src: jnp.ndarray, cfg: ModelConfig):
    """K/V projection from ``src`` (self: src = x; cross: encoder states)."""
    B, S, _ = src.shape
    dt = dtype_of(cfg)
    k = jnp.einsum("bsd,dh->bsh", src, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dh->bsh", src, params["wv"].astype(dt))
    k = k.reshape(B, S, cfg.num_kv_heads, cfg.head_dim).transpose(0, 2, 1, 3)
    v = v.reshape(B, S, cfg.num_kv_heads, cfg.head_dim).transpose(0, 2, 1, 3)
    return k, v


def attention_forward(
    params: dict,
    x: jnp.ndarray,                 # [B, S, d]
    cfg: ModelConfig,
    is_global: bool = True,
    causal: bool = True,
    positions: Optional[jnp.ndarray] = None,
    kv_source: Optional[jnp.ndarray] = None,    # cross-attn: encoder states
    use_rope: bool = True,
) -> jnp.ndarray:
    """Full-sequence attention (training / prefill / encoder / cross)."""
    B, S, _ = x.shape
    q = _project_q(params, x, cfg)
    k, v = project_kv(params, kv_source if kv_source is not None else x, cfg)
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)
    if kv_source is None and use_rope:          # self-attention gets RoPE
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    window = None if is_global else cfg.sliding_window
    if cfg.attn_impl == "cp_kv" and kv_source is None:
        out = cp_kv_attention(q, k, v, cfg, causal=causal, window=window)
    else:
        out = kops.attention(
            q, k, v, causal=causal, window=window,
            soft_cap=cfg.logit_soft_cap, impl=cfg.attn_impl,
            chunk=cfg.attn_chunk,
        )
    out = out.transpose(0, 2, 1, 3).reshape(B, S, cfg.num_heads * cfg.head_dim)
    return jnp.einsum("bsh,hd->bsd", out, params["wo"].astype(dtype_of(cfg)))


def cp_kv_attention(q, k, v, cfg: ModelConfig, causal: bool = True,
                    window=None) -> jnp.ndarray:
    """§Perf: context parallelism over the KV sequence (ring-attention lite).

    For archs whose head counts don't divide the TP degree (starcoder2's 36,
    gemma3's 8), head-parallel attention is unavailable and the baseline
    replicates attention work across the model axis. Here each model shard
    holds a 1/tp slice of K/V; for every q chunk all shards compute a
    partial online softmax over their slice and combine with pmax/psum —
    attention FLOPs and logit HBM traffic drop 1/tp at the cost of one
    small (B,H,chunk,D) psum per chunk. Falls back to jnp_flash when no
    sharding context is active (CPU tests).
    """
    import functools

    from jax.sharding import PartitionSpec as P

    from repro.distributed import context as dctx

    ctx = dctx.current()
    B, Hq, S, D = q.shape
    Skv = k.shape[2]
    if ctx is None or Skv % ctx.mesh.shape[ctx.tp] or cfg.attn_chunk > S:
        return kops.attention(q, k, v, causal=causal, window=window,
                              soft_cap=cfg.logit_soft_cap, impl="jnp_flash",
                              chunk=cfg.attn_chunk)
    ntp = ctx.mesh.shape[ctx.tp]
    dp = ctx.dp if len(ctx.dp) > 1 else ctx.dp[0]
    dp_size = 1
    for a in (ctx.dp if isinstance(ctx.dp, tuple) else (ctx.dp,)):
        dp_size *= ctx.mesh.shape[a]
    bspec = dp if B % dp_size == 0 else None
    starts = jnp.arange(ntp, dtype=jnp.int32)
    chunk = cfg.attn_chunk
    nq = S // chunk
    probs_dt = jnp.bfloat16 if cfg.attn_bf16_probs else jnp.float32

    def body(qc, kl, vl, starts, ci):
        # qc [B,H,chunk,D] replicated over model; kl/vl local KV slice.
        Sl = kl.shape[2]
        start = starts[0] * Sl
        group = Hq // kl.shape[1]
        kx = jnp.repeat(kl, group, axis=1).astype(jnp.float32)
        vx = jnp.repeat(vl, group, axis=1).astype(probs_dt)
        scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
        s = jnp.einsum("bhqd,bhkd->bhqk", qc.astype(jnp.float32), kx) * scale
        if cfg.logit_soft_cap is not None:
            s = cfg.logit_soft_cap * jnp.tanh(s / cfg.logit_soft_cap)
        qpos = ci * chunk + jnp.arange(chunk)[:, None]
        kpos = (start + jnp.arange(Sl))[None, :]
        mask = jnp.ones((chunk, Sl), bool)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask[None, None], s, -jnp.inf)
        m_i = jnp.maximum(s.max(-1, keepdims=True), -1e30)
        p = jnp.where(mask[None, None], jnp.exp(s - m_i), 0.0)
        l_i = p.sum(-1, keepdims=True)
        o_i = jnp.einsum("bhqk,bhkd->bhqd", p.astype(probs_dt), vx)
        m = jax.lax.pmax(m_i, ctx.tp)
        corr = jnp.exp(m_i - m)
        l = jax.lax.psum(l_i * corr, ctx.tp)
        o = jax.lax.psum(o_i.astype(jnp.float32) * corr, ctx.tp)
        return (o / jnp.maximum(l, 1e-30)).astype(qc.dtype)

    fn = jax.shard_map(
        body, mesh=ctx.mesh,
        in_specs=(P(bspec), P(bspec, None, ctx.tp, None),
                  P(bspec, None, ctx.tp, None), P(ctx.tp), P()),
        out_specs=P(bspec),
        axis_names=set(ctx.mesh.axis_names),
        check_vma=False,
    )

    qc = q.reshape(B, Hq, nq, chunk, D).transpose(2, 0, 1, 3, 4)

    def scan_body(_, args):
        ci, qi = args
        return None, fn(qi, k, v, starts, ci)

    _, outs = jax.lax.scan(scan_body, None, (jnp.arange(nq), qc))
    return outs.transpose(1, 2, 0, 3, 4).reshape(B, Hq, S, D)


# ----------------------------------------------------------------------------
# KV-cache decode
# ----------------------------------------------------------------------------

def cache_is_ring(cfg: ModelConfig, is_global: bool) -> bool:
    """Static layout decision: windowed layers use a ring cache."""
    return not (is_global or cfg.sliding_window is None)


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  is_global: bool) -> dict:
    """Cache arrays for one layer. Ring layout when the layer is windowed
    (layout itself is static — see ``cache_is_ring``).

    ``kv_cache_dtype="int8"`` stores per-(position, head) symmetric-quantized
    K/V (scales alongside) — halves cache HBM vs bf16, the production lever
    that fits phi3.5-42B × decode_32k on a single pod.
    """
    length = max_len if not cache_is_ring(cfg, is_global) else min(
        max_len, cfg.sliding_window
    )
    shape = (batch, cfg.num_kv_heads, length, cfg.head_dim)
    if cfg.kv_cache_dtype == "int8":
        sshape = shape[:-1] + (1,)
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(sshape, jnp.float32),
                "v_scale": jnp.zeros(sshape, jnp.float32)}
    dt = dtype_of(cfg)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def _quantize_kv(x: jnp.ndarray):
    """Symmetric per-(batch, head, position) int8 quantization."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def _dequantize_kv(q: jnp.ndarray, scale: jnp.ndarray, dt):
    return (q.astype(jnp.float32) * scale).astype(dt)


def _splitkv_body(q, k_new, v_new, kc, vc, ks, vs, pos, starts, *,
                  ring: bool, L: int, window, soft_cap, axis: str,
                  quantized: bool):
    """Per-model-shard decode attention over a sequence-sharded cache.

    The owner shard writes the new K/V locally (no cross-shard gather — the
    thing GSPMD cannot do for a dynamic-update-slice on a sharded dim) and
    every shard computes a partial online-softmax over its cache slice; the
    partials combine with one tiny pmax/psum. This is flash-decoding mapped
    onto the mesh, and works for ANY head count.

    ``starts`` is a P(axis)-sharded iota (each shard sees its own [1] slice)
    — the partial-manual-safe replacement for axis_index, whose partition-id
    lowering the SPMD partitioner refuses in mixed auto/manual modules.
    """
    B, Hq, _, D = q.shape
    Hkv = kc.shape[1]
    Sl = kc.shape[2]
    start = starts[0] * Sl
    slot_g = (pos % L) if ring else pos
    slot = jnp.clip(slot_g - start, 0, Sl - 1)
    in_range = (slot_g >= start) & (slot_g < start + Sl)

    def upd(buf, new):
        u = jax.lax.dynamic_update_slice(buf, new, (0, 0, slot, 0))
        return jnp.where(in_range, u, buf)

    if quantized:
        k8, ksc = _quantize_kv(k_new)
        v8, vsc = _quantize_kv(v_new)
        kc, vc = upd(kc, k8), upd(vc, v8)
        ks, vs = upd(ks, ksc), upd(vs, vsc)
        k_f = kc.astype(jnp.float32) * ks
        v_f = vc.astype(jnp.float32) * vs
    else:
        kc, vc = upd(kc, k_new), upd(vc, v_new)
        k_f, v_f = kc, vc

    group = Hq // Hkv
    kx = jnp.repeat(k_f, group, axis=1).astype(jnp.float32)
    vx = jnp.repeat(v_f, group, axis=1).astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(jnp.asarray(D, jnp.float32))
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kx) * scale
    if soft_cap is not None:
        s = soft_cap * jnp.tanh(s / soft_cap)
    gidx = start + jnp.arange(Sl)[None, None, None, :]
    if ring:
        valid = gidx < jnp.minimum(pos + 1, L)
    else:
        valid = gidx <= pos
        if window is not None:
            valid = valid & (gidx > pos - window)
    s = jnp.where(valid, s, -jnp.inf)
    m_i = s.max(axis=-1, keepdims=True)                      # [B,H,1,1]
    m_i = jnp.maximum(m_i, -1e30)                            # empty shard
    p = jnp.exp(s - m_i)
    p = jnp.where(valid, p, 0.0)
    l_i = p.sum(axis=-1, keepdims=True)
    o_i = jnp.einsum("bhqk,bhkd->bhqd", p, vx)
    m = jax.lax.pmax(m_i, axis)
    corr = jnp.exp(m_i - m)                                  # [B,H,1,1]
    l = jax.lax.psum(l_i * corr, axis)
    o = jax.lax.psum(o_i * corr, axis)
    out = o / jnp.maximum(l, 1e-30)
    return out.astype(q.dtype), kc, vc, ks, vs


def decode_attention(
    params: dict,
    x: jnp.ndarray,                 # [B, 1, d]
    cache: dict,
    pos: jnp.ndarray,               # int32[] — absolute position of this token
    cfg: ModelConfig,
    is_global: bool = True,
    use_rope: bool = True,
) -> Tuple[jnp.ndarray, dict]:
    """One decode step: update cache at ``pos``, attend to the valid prefix.

    Under an activation-sharding context with a divisible cache length, the
    split-KV shard_map path runs (sequence-sharded cache, flash-decoding
    combine); otherwise the single-logical-device path.
    """
    from repro.distributed import context as dctx

    B = x.shape[0]
    q = _project_q(params, x, cfg)
    k_new, v_new = project_kv(params, x, cfg)
    if use_rope:
        q = apply_rope(q, jnp.full((1,), pos, jnp.int32), cfg.rope_theta)
        k_new = apply_rope(k_new, jnp.full((1,), pos, jnp.int32), cfg.rope_theta)

    ring = cache_is_ring(cfg, is_global)          # static
    L = cache["k"].shape[2]
    window = None if (is_global or ring) else cfg.sliding_window

    ctx = dctx.current()
    use_splitkv = (ctx is not None
                   and L % ctx.mesh.shape[ctx.tp] == 0
                   and L >= ctx.mesh.shape[ctx.tp])
    if use_splitkv:
        import functools

        from jax.sharding import PartitionSpec as P

        quantized = cfg.kv_cache_dtype == "int8"
        body = functools.partial(
            _splitkv_body, ring=ring, L=L, window=window,
            soft_cap=cfg.logit_soft_cap, axis=ctx.tp, quantized=quantized)
        ntp = ctx.mesh.shape[ctx.tp]
        starts = jnp.arange(ntp, dtype=jnp.int32)
        # FULLY-manual shard_map (every mesh axis named): the SPMD
        # partitioner never sees this region, so its partition-id refusal
        # in mixed auto/manual modules cannot trigger. Batch shards over
        # the data axes when divisible; heads stay local.
        dp = ctx.dp if len(ctx.dp) > 1 else ctx.dp[0]
        dp_size = 1
        for a in (ctx.dp if isinstance(ctx.dp, tuple) else (ctx.dp,)):
            dp_size *= ctx.mesh.shape[a]
        bspec = dp if (q.shape[0] % dp_size == 0) else None
        cspec = P(bspec, None, ctx.tp, None)
        if quantized:
            ks_in, vs_in = cache["k_scale"], cache["v_scale"]
        else:  # dummy tiny placeholders keep one body signature
            ks_in = jnp.zeros((1, 1, ntp, 1), jnp.float32)
            vs_in = jnp.zeros((1, 1, ntp, 1), jnp.float32)
        sspec = cspec if quantized else P(None, None, ctx.tp, None)
        fn = jax.shard_map(
            body, mesh=ctx.mesh,
            in_specs=(P(bspec), P(bspec), P(bspec), cspec, cspec,
                      sspec, sspec, P(), P(ctx.tp)),
            out_specs=(P(bspec), cspec, cspec, sspec, sspec),
            axis_names=set(ctx.mesh.axis_names),
            check_vma=False,
        )
        out, k_cache, v_cache, ks_out, vs_out = fn(
            q, k_new, v_new, cache["k"], cache["v"], ks_in, vs_in,
            pos, starts)
        new_cache = {"k": k_cache, "v": v_cache}
        if quantized:
            new_cache["k_scale"] = ks_out
            new_cache["v_scale"] = vs_out
    else:
        slot = (pos % L) if ring else pos
        quantized = cfg.kv_cache_dtype == "int8"
        if quantized:
            k8, ksc = _quantize_kv(k_new)
            v8, vsc = _quantize_kv(v_new)
            k_cache = jax.lax.dynamic_update_slice(
                cache["k"], k8, (0, 0, slot, 0))
            v_cache = jax.lax.dynamic_update_slice(
                cache["v"], v8, (0, 0, slot, 0))
            ks = jax.lax.dynamic_update_slice(
                cache["k_scale"], ksc, (0, 0, slot, 0))
            vs = jax.lax.dynamic_update_slice(
                cache["v_scale"], vsc, (0, 0, slot, 0))
            dt = dtype_of(cfg)
            k_att = _dequantize_kv(k_cache, ks, dt)
            v_att = _dequantize_kv(v_cache, vs, dt)
            new_cache = {"k": k_cache, "v": v_cache,
                         "k_scale": ks, "v_scale": vs}
        else:
            k_cache = jax.lax.dynamic_update_slice(
                cache["k"], k_new, (0, 0, slot, 0))
            v_cache = jax.lax.dynamic_update_slice(
                cache["v"], v_new, (0, 0, slot, 0))
            k_att, v_att = k_cache, v_cache
            new_cache = {"k": k_cache, "v": v_cache}
        if ring:
            # Ring cache holds the last ≤L positions in wrapped order. RoPE
            # was applied at absolute positions when written and softmax is
            # order-invariant, so wrapped slot order does not perturb scores.
            length = jnp.minimum(pos + 1, L)
            out = kref.decode_attention_ref(
                q, k_att, v_att, length,
                window=None, logit_soft_cap=cfg.logit_soft_cap)
        else:
            out = kref.decode_attention_ref(
                q, k_att, v_att, pos + 1,
                window=window, logit_soft_cap=cfg.logit_soft_cap)
    out = out.transpose(0, 2, 1, 3).reshape(B, 1, cfg.num_heads * cfg.head_dim)
    y = jnp.einsum("bsh,hd->bsd", out, params["wo"].astype(dtype_of(cfg)))
    return y, new_cache
