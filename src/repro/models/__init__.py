"""Composable LM model zoo (the assigned-architecture substrate).

Pure-functional JAX models: params are nested dicts of arrays, apply
functions are pure, sharding is injected via PartitionSpec trees built in
``repro.distributed.sharding``. Families: dense (GQA/SWA/local-global),
MoE (top-k, EP), RWKV6, Mamba2 (+Zamba2 hybrid), Whisper enc-dec, LLaVA
(stub vision frontend).
"""
from repro.models.config import ModelConfig
from repro.models.transformer import (
    init_params,
    forward_train,
    init_decode_state,
    decode_step,
)

__all__ = [
    "ModelConfig",
    "init_params",
    "forward_train",
    "init_decode_state",
    "decode_step",
]
