"""Gated MLP (SwiGLU / GeGLU) block."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_init, dtype_of, pdtype_of

_ACTS = {
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "relu": jax.nn.relu,
}


def mlp_init(key, cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d = cfg.d_model
    f = d_ff if d_ff is not None else cfg.d_ff
    kg, ku, kd = jax.random.split(key, 3)
    pd = pdtype_of(cfg)
    p = {
        "w_up": dense_init(ku, (d, f), pd),
        "w_down": dense_init(kd, (f, d), pd, fan_in=f),
    }
    if cfg.mlp_gated:
        p["w_gate"] = dense_init(kg, (d, f), pd)
    return p


def mlp_forward(params: dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    dt = dtype_of(cfg)
    act = _ACTS[cfg.act]
    u = jnp.einsum("...d,df->...f", x, params["w_up"].astype(dt))
    if cfg.mlp_gated:
        g = jnp.einsum("...d,df->...f", x, params["w_gate"].astype(dt))
        h = act(g) * u
    else:
        h = act(u)
    return jnp.einsum("...f,fd->...d", h, params["w_down"].astype(dt))
