"""RWKV-6 ("Finch") block — attention-free linear recurrence with
data-dependent decay (arXiv:2404.05892).

Faithful core mechanics kept:
  * token-shift mixing (μ-interpolation with the previous token),
  * per-channel **data-dependent decay** w_t = exp(−exp(w0 + LoRA(x_t)))
    — the defining Finch feature,
  * per-head state S ∈ R^{D×D} recurrence  S_t = diag(w_t)·S_{t−1} + k_t v_tᵀ,
    readout o_t = r_tᵀ(S_{t−1} + diag(u)·k_t v_tᵀ),
  * grouped output norm + silu(g) gating, squared-ReLU channel mix.

Training runs a lax.scan over time (O(T) state memory); decode carries
(x_prev, S) — constant-size state, which is why rwkv6 is the cheapest
``long_500k`` architecture in the fleet.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.distributed.context import constrain
from repro.models.config import ModelConfig
from repro.models.layers import dense_init, dtype_of, pdtype_of
from repro.models.scan_utils import chunked_scan


LORA_RANK = 64


def rwkv_time_mix_init(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    H, D = cfg.ssm_heads, cfg.ssm_head_dim
    assert H * D == d, (H, D, d)
    ks = jax.random.split(key, 10)
    pd = pdtype_of(cfg)
    return {
        "mu_r": jnp.full((d,), 0.5, pd),
        "mu_k": jnp.full((d,), 0.5, pd),
        "mu_v": jnp.full((d,), 0.5, pd),
        "mu_w": jnp.full((d,), 0.5, pd),
        "mu_g": jnp.full((d,), 0.5, pd),
        "w_r": dense_init(ks[0], (d, d), pd),
        "w_k": dense_init(ks[1], (d, d), pd),
        "w_v": dense_init(ks[2], (d, d), pd),
        "w_g": dense_init(ks[3], (d, d), pd),
        "w_o": dense_init(ks[4], (d, d), pd),
        # data-dependent decay: w0 + tanh(x A) B  (low-rank)
        "w0": jnp.full((d,), -6.0, pd),
        "w_lora_a": dense_init(ks[5], (d, LORA_RANK), pd),
        "w_lora_b": dense_init(ks[6], (LORA_RANK, d), pd, fan_in=LORA_RANK),
        "u": (jax.random.normal(ks[7], (d,), jnp.float32) * 0.1).astype(pd),
        "ln_scale": jnp.ones((d,), pd),
    }


def _decay(params: dict, xw: jnp.ndarray, dt) -> jnp.ndarray:
    """w_t ∈ (0, 1): exp(−exp(w0 + tanh(x·A)·B)) — data-dependent decay."""
    a = jnp.tanh(jnp.einsum("...d,dr->...r", xw, params["w_lora_a"].astype(dt)))
    lora = jnp.einsum("...r,rd->...d", a, params["w_lora_b"].astype(dt))
    raw = params["w0"].astype(jnp.float32) + lora.astype(jnp.float32)
    return jnp.exp(-jnp.exp(raw))


def _group_norm(x: jnp.ndarray, scale: jnp.ndarray, H: int, eps: float
                ) -> jnp.ndarray:
    """Per-head (group) normalization of the readout."""
    B, d = x.shape
    xh = x.reshape(B, H, d // H).astype(jnp.float32)
    mu = xh.mean(-1, keepdims=True)
    var = xh.var(-1, keepdims=True)
    y = (xh - mu) * jax.lax.rsqrt(var + eps)
    return (y.reshape(B, d) * scale.astype(jnp.float32)).astype(x.dtype)


def rwkv_time_mix(
    params: dict,
    x: jnp.ndarray,                  # [B, S, d]
    cfg: ModelConfig,
    state: Tuple[jnp.ndarray, jnp.ndarray] | None = None,
) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Returns (out [B,S,d], (x_last, S_last)). ``state``: carried at decode.

    §Perf note (EXPERIMENTS.md): the r/k/v/g/decay projections are hoisted
    OUT of the time recurrence into full-sequence matmuls — token-shift
    inputs are known for all t up front — so each weight matrix is read from
    HBM once per call instead of once per timestep. The recurrence streams
    only precomputed per-step vectors plus the state. (The original
    hypothesis — that the state itself dominated HBM — was refuted: per-step
    weight re-reads were ~75% of the memory term.)
    """
    B, S, d = x.shape
    H, D = cfg.ssm_heads, cfg.ssm_head_dim
    dt = dtype_of(cfg)
    if state is None:
        x_prev0 = jnp.zeros((B, d), dt)
        S0 = jnp.zeros((B, H, D, D), jnp.float32)
    else:
        x_prev0, S0 = state

    shifted = jnp.concatenate([x_prev0[:, None], x[:, :-1]], axis=1)

    def mix(mu):
        m = params[mu].astype(dt)
        return x * m + shifted * (1.0 - m)

    # full-sequence projections (one HBM weight read per call)
    r = jnp.einsum("bsd,de->bse", mix("mu_r"), params["w_r"].astype(dt))
    k = jnp.einsum("bsd,de->bse", mix("mu_k"), params["w_k"].astype(dt))
    v = jnp.einsum("bsd,de->bse", mix("mu_v"), params["w_v"].astype(dt))
    g = jnp.einsum("bsd,de->bse", mix("mu_g"), params["w_g"].astype(dt))
    w = _decay(params, mix("mu_w"), dt)                           # [B,S,d] f32

    rh = r.reshape(B, S, H, D).astype(jnp.float32).transpose(1, 0, 2, 3)
    kh = k.reshape(B, S, H, D).astype(jnp.float32).transpose(1, 0, 2, 3)
    vh = v.reshape(B, S, H, D).astype(jnp.float32).transpose(1, 0, 2, 3)
    wh = w.reshape(B, S, H, D).transpose(1, 0, 2, 3)
    uh = params["u"].astype(jnp.float32).reshape(H, D)

    def step(Sst, inp):
        rt, kt, vt, wt = inp                                      # [B,H,D]
        kv = jnp.einsum("bhi,bhj->bhij", kt, vt)                  # k ⊗ v
        if cfg.ssm_state_sharding:
            # §Perf V1: shard the state value-dim over the model axis —
            # per-step ops contract the key dim, so this stays local.
            kv = constrain(kv, "state4")
        o = jnp.einsum("bhi,bhij->bhj", rt, Sst + uh[None, :, :, None] * kv)
        S_new = wt[..., None] * Sst + kv
        if cfg.ssm_state_sharding:
            S_new = constrain(S_new, "state4")
        return S_new, o

    S_last, outs = chunked_scan(step, S0, (rh, kh, vh, wh), chunk=256)
    o_seq = outs.transpose(1, 0, 2, 3).reshape(B, S, d).astype(dt)
    out = jax.vmap(
        lambda a: _group_norm(a, params["ln_scale"], H, cfg.norm_eps),
        in_axes=1, out_axes=1)(o_seq)
    out = out * jax.nn.silu(g)
    out = jnp.einsum("bsd,de->bse", out, params["w_o"].astype(dt))
    return out, (x[:, -1], S_last)


def rwkv_channel_mix_init(key, cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    k1, k2 = jax.random.split(key)
    pd = pdtype_of(cfg)
    return {
        "mu": jnp.full((d,), 0.5, pd),
        "w_in": dense_init(k1, (d, f), pd),
        "w_out": dense_init(k2, (f, d), pd, fan_in=f),
    }


def rwkv_channel_mix(
    params: dict,
    x: jnp.ndarray,                 # [B, S, d]
    cfg: ModelConfig,
    x_prev: jnp.ndarray | None = None,   # [B, d] decode carry
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    B, S, d = x.shape
    dt = dtype_of(cfg)
    if x_prev is None:
        x_prev = jnp.zeros((B, d), dt)
    shifted = jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)
    m = params["mu"].astype(dt)
    xm = x * m + shifted * (1.0 - m)
    h = jnp.einsum("bsd,df->bsf", xm, params["w_in"].astype(dt))
    h = jnp.square(jax.nn.relu(h))                   # squared ReLU (RWKV)
    y = jnp.einsum("bsf,fd->bsd", h, params["w_out"].astype(dt))
    return y, x[:, -1]
