"""Model assembly for every architecture family.

Training/prefill forward passes use **scan-over-layers** with stacked block
parameters (one traced block, L-fold loop) — this is what keeps the
512-device dry-run HLO small enough to compile for 7B/42B configs — plus
optional remat. Heterogeneous layer patterns (gemma3's 5:1 local:global) stay
inside the scan via ``lax.cond`` on a per-layer flag, so the block stays
homogeneous for XLA.

Decode takes the opposite trade: a Python loop over layers (per-layer
compute is tiny, and caches are heterogeneous — ring buffers for windowed
layers, full buffers for global ones, SSM states for mamba/rwkv).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import mamba2 as m2
from repro.models import moe as moe_mod
from repro.models import rwkv6 as rwkv
from repro.models.attention import (
    attention_forward,
    cache_is_ring,
    decode_attention,
    init_kv_cache,
    project_kv,
)
from repro.models.config import ModelConfig
from repro.models.layers import (
    dense_init,
    dtype_of,
    embed_tokens,
    embedding_init,
    lm_head_init,
    pdtype_of,
    rmsnorm,
    rmsnorm_init,
    unembed,
)
from repro.models.mlp import mlp_forward, mlp_init
from repro.distributed.context import constrain


# ----------------------------------------------------------------------------
# per-family block init
# ----------------------------------------------------------------------------

def _dense_block_init(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": rmsnorm_init(cfg.d_model, pdtype_of(cfg)),
        "attn": attn_mod.attention_init(k1, cfg),
        "ln2": rmsnorm_init(cfg.d_model, pdtype_of(cfg)),
        "mlp": mlp_init(k2, cfg),
    }


def _moe_block_init(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": rmsnorm_init(cfg.d_model, pdtype_of(cfg)),
        "attn": attn_mod.attention_init(k1, cfg),
        "ln2": rmsnorm_init(cfg.d_model, pdtype_of(cfg)),
        "moe": moe_mod.moe_init(k2, cfg),
    }


def _rwkv_block_init(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": rmsnorm_init(cfg.d_model, pdtype_of(cfg)),
        "time_mix": rwkv.rwkv_time_mix_init(k1, cfg),
        "ln2": rmsnorm_init(cfg.d_model, pdtype_of(cfg)),
        "channel_mix": rwkv.rwkv_channel_mix_init(k2, cfg),
    }


def _mamba_block_init(key, cfg: ModelConfig) -> dict:
    return {
        "ln": rmsnorm_init(cfg.d_model, pdtype_of(cfg)),
        "mamba": m2.mamba2_init(key, cfg),
    }


def _encdec_block_init(key, cfg: ModelConfig, cross: bool) -> dict:
    ks = jax.random.split(key, 3)
    p = {
        "ln1": rmsnorm_init(cfg.d_model, pdtype_of(cfg)),
        "attn": attn_mod.attention_init(ks[0], cfg),
        "ln2": rmsnorm_init(cfg.d_model, pdtype_of(cfg)),
        "mlp": mlp_init(ks[1], cfg),
    }
    if cross:
        p["ln_cross"] = rmsnorm_init(cfg.d_model, pdtype_of(cfg))
        p["cross_attn"] = attn_mod.attention_init(ks[2], cfg)
    return p


def _stacked(init_fn, key, L: int):
    keys = jax.random.split(key, L)
    return jax.vmap(init_fn)(keys)


# ----------------------------------------------------------------------------
# init
# ----------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key: jax.Array) -> Dict[str, Any]:
    k_emb, k_blocks, k_head, k_extra = jax.random.split(key, 4)
    params: Dict[str, Any] = {
        "embed": embedding_init(k_emb, cfg),
        "final_norm": rmsnorm_init(cfg.d_model, pdtype_of(cfg)),
    }
    head = lm_head_init(k_head, cfg)
    if head is not None:
        params["head"] = head

    fam = cfg.family
    if fam in ("dense", "vlm"):
        params["blocks"] = _stacked(
            lambda k: _dense_block_init(k, cfg), k_blocks, cfg.num_layers)
        if fam == "vlm":
            params["vision_proj"] = {
                "kernel": dense_init(k_extra, (cfg.d_model, cfg.d_model),
                                     pdtype_of(cfg))
            }
    elif fam == "moe":
        params["blocks"] = _stacked(
            lambda k: _moe_block_init(k, cfg), k_blocks, cfg.num_layers)
    elif fam == "ssm":
        params["blocks"] = _stacked(
            lambda k: _rwkv_block_init(k, cfg), k_blocks, cfg.num_layers)
    elif fam == "hybrid":
        params["blocks"] = _stacked(
            lambda k: _mamba_block_init(k, cfg), k_blocks, cfg.num_layers)
        ka, km = jax.random.split(k_extra)
        # zamba2's weight-shared transformer block (attention + MLP), applied
        # at every shared_attn_every-th depth with the same parameters.
        params["shared_attn"] = {
            "ln": rmsnorm_init(cfg.d_model, pdtype_of(cfg)),
            "attn": attn_mod.attention_init(ka, cfg),
            "ln2": rmsnorm_init(cfg.d_model, pdtype_of(cfg)),
            "mlp": mlp_init(km, cfg),
        }
    elif fam == "encdec":
        ke, kd = jax.random.split(k_blocks)
        params["enc_blocks"] = _stacked(
            lambda k: _encdec_block_init(k, cfg, cross=False),
            ke, cfg.encoder_layers)
        params["dec_blocks"] = _stacked(
            lambda k: _encdec_block_init(k, cfg, cross=True),
            kd, cfg.num_layers)
        params["enc_final_norm"] = rmsnorm_init(cfg.d_model, pdtype_of(cfg))
    else:
        raise ValueError(f"unknown family {fam!r}")
    return params


# ----------------------------------------------------------------------------
# helpers
# ----------------------------------------------------------------------------

def _sinusoidal(S: int, d: int, dtype) -> jnp.ndarray:
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, 2.0 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def _sinusoidal_at(pos: jnp.ndarray, d: int, dtype) -> jnp.ndarray:
    """Sinusoidal embedding for one (dynamic) position."""
    dim = jnp.arange(d // 2, dtype=jnp.float32)
    ang = pos.astype(jnp.float32) / jnp.power(10000.0, 2.0 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def _is_global_flags(cfg: ModelConfig) -> jnp.ndarray:
    return jnp.asarray(
        [cfg.layer_is_global(i) for i in range(cfg.num_layers)], dtype=bool
    )


def _maybe_remat(fn, remat: bool, family: str = "dense"):
    if not remat:
        return fn
    if family in ("ssm", "hybrid"):
        # recurrent blocks: save nothing — the per-step projections that the
        # dots policy would keep are O(S·B·d) *per step* and dwarf HBM;
        # recomputing them inside the chunked time scan is the memory-sane
        # trade for linear-RNN training.
        return jax.checkpoint(fn)
    return jax.checkpoint(
        fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    )


# ----------------------------------------------------------------------------
# training / prefill forward
# ----------------------------------------------------------------------------

def forward_train(
    params: Dict[str, Any],
    batch: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
    remat: bool = False,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Full-sequence forward. Returns (logits [B,S,V], aux losses).

    batch keys: "tokens" [B,S]; VLM adds "prefix_embeds" [B,P,d]; whisper
    adds "encoder_frames" [B,T_enc,d] (stub frontend output).
    """
    fam = cfg.family
    dt = dtype_of(cfg)
    x = embed_tokens(params["embed"], batch["tokens"], cfg)
    x = constrain(x, "btd")
    aux: Dict[str, jnp.ndarray] = {}

    if fam == "vlm":
        prefix = jnp.einsum(
            "bpd,de->bpe", batch["prefix_embeds"].astype(dt),
            params["vision_proj"]["kernel"].astype(dt))
        x = jnp.concatenate([prefix, x], axis=1)

    if fam in ("dense", "vlm"):
        flags = _is_global_flags(cfg)

        def block(x, scanned):
            bp, is_glob = scanned
            h = rmsnorm(bp["ln1"], x, cfg.norm_eps)
            if cfg.global_every is None:
                a = attention_forward(bp["attn"], h, cfg,
                                      is_global=cfg.sliding_window is None)
            else:
                a = jax.lax.cond(
                    is_glob,
                    lambda hh: attention_forward(bp["attn"], hh, cfg,
                                                 is_global=True),
                    lambda hh: attention_forward(bp["attn"], hh, cfg,
                                                 is_global=False),
                    h,
                )
            x = x + a
            h2 = rmsnorm(bp["ln2"], x, cfg.norm_eps)
            x = x + mlp_forward(bp["mlp"], h2, cfg)
            return constrain(x, "btd"), None

        x, _ = jax.lax.scan(_maybe_remat(block, remat), x,
                            (params["blocks"], flags))

    elif fam == "moe":
        moe_ck = jax.checkpoint(
            lambda mp, h: moe_mod.moe_forward(mp, h, cfg))

        def block(x, bp):
            h = rmsnorm(bp["ln1"], x, cfg.norm_eps)
            x = x + attention_forward(bp["attn"], h, cfg, is_global=True)
            h2 = rmsnorm(bp["ln2"], x, cfg.norm_eps)
            # nested checkpoint: the dispatch gathers ([S·k, d] per group)
            # are recomputed in their own segment during backward instead of
            # coexisting with the attention residuals.
            y, moe_aux = moe_ck(bp["moe"], h2)
            return constrain(x + y, "btd"), moe_aux["aux_loss"]

        x, aux_losses = jax.lax.scan(_maybe_remat(block, remat), x,
                                     params["blocks"])
        aux["moe_aux_loss"] = aux_losses.mean()

    elif fam == "ssm":
        def block(x, bp):
            h = rmsnorm(bp["ln1"], x, cfg.norm_eps)
            y, _ = rwkv.rwkv_time_mix(bp["time_mix"], h, cfg)
            x = x + y
            h2 = rmsnorm(bp["ln2"], x, cfg.norm_eps)
            y2, _ = rwkv.rwkv_channel_mix(bp["channel_mix"], h2, cfg)
            return constrain(x + y2, "btd"), None

        x, _ = jax.lax.scan(_maybe_remat(block, remat, "ssm"), x,
                            params["blocks"])

    elif fam == "hybrid":
        x = _hybrid_forward_train(params, x, cfg, remat)

    elif fam == "encdec":
        enc = _encoder_forward(params, batch["encoder_frames"], cfg, remat)

        def block(x, bp):
            h = rmsnorm(bp["ln1"], x, cfg.norm_eps)
            x = x + attention_forward(bp["attn"], h, cfg, use_rope=False)
            hc = rmsnorm(bp["ln_cross"], x, cfg.norm_eps)
            x = x + attention_forward(bp["cross_attn"], hc, cfg,
                                      causal=False, kv_source=enc)
            h2 = rmsnorm(bp["ln2"], x, cfg.norm_eps)
            return constrain(x + mlp_forward(bp["mlp"], h2, cfg), "btd"), None

        S = x.shape[1]
        x = x + _sinusoidal(S, cfg.d_model, dt)[None]
        x, _ = jax.lax.scan(_maybe_remat(block, remat), x, params["dec_blocks"])
    else:
        raise ValueError(fam)

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["embed"], x, cfg, params.get("head"))
    return constrain(logits, "logits"), aux


def _encoder_forward(params, frames: jnp.ndarray, cfg: ModelConfig,
                     remat: bool) -> jnp.ndarray:
    """Whisper encoder over precomputed frame embeddings (conv stub)."""
    dt = dtype_of(cfg)
    x = frames.astype(dt)
    x = x + _sinusoidal(x.shape[1], cfg.d_model, dt)[None]

    def block(x, bp):
        h = rmsnorm(bp["ln1"], x, cfg.norm_eps)
        x = x + attention_forward(bp["attn"], h, cfg, causal=False,
                                  use_rope=False)
        h2 = rmsnorm(bp["ln2"], x, cfg.norm_eps)
        return constrain(x + mlp_forward(bp["mlp"], h2, cfg), "btd"), None

    x, _ = jax.lax.scan(_maybe_remat(block, remat), x, params["enc_blocks"])
    return rmsnorm(params["enc_final_norm"], x, cfg.norm_eps)


def _hybrid_forward_train(params, x: jnp.ndarray, cfg: ModelConfig,
                          remat: bool) -> jnp.ndarray:
    """Zamba2: scan groups of mamba2 blocks, shared attention in between.

    The shared attention block (single weight set) is applied after every
    ``shared_attn_every`` mamba layers — weight sharing is the zamba2 trick
    that keeps the attention parameter count tiny.
    """
    L = cfg.num_layers
    period = cfg.shared_attn_every or L

    def mamba_block(x, bp):
        h = rmsnorm(bp["ln"], x, cfg.norm_eps)
        y, _ = m2.mamba2_forward(bp["mamba"], h, cfg)
        return constrain(x + y, "btd"), None

    def shared_block(x):
        sp = params["shared_attn"]
        h = rmsnorm(sp["ln"], x, cfg.norm_eps)
        x = x + attention_forward(sp["attn"], h, cfg, is_global=True)
        h2 = rmsnorm(sp["ln2"], x, cfg.norm_eps)
        return constrain(x + mlp_forward(sp["mlp"], h2, cfg), "btd")

    # Structure the depth loop as a scan over (period-sized mamba group +
    # one shared-block application): scan's sequential backward keeps only
    # ONE group's recompute residuals live at a time (a python loop lets the
    # scheduler keep every site's transients alive simultaneously).
    n_groups = L // period
    tail = L - n_groups * period

    def group_fn(x, gp):
        x, _ = jax.lax.scan(_maybe_remat(mamba_block, remat, "hybrid"), x, gp)
        return shared_block(x), None

    if n_groups:
        grouped = jax.tree.map(
            lambda a: a[: n_groups * period].reshape(
                n_groups, period, *a.shape[1:]),
            params["blocks"])
        x, _ = jax.lax.scan(_maybe_remat(group_fn, remat, "dense"), x, grouped)
    if tail:
        tail_p = jax.tree.map(lambda a: a[n_groups * period:], params["blocks"])
        x, _ = jax.lax.scan(_maybe_remat(mamba_block, remat, "hybrid"), x,
                            tail_p)
    return x


# ----------------------------------------------------------------------------
# decode (KV-cache / SSM-state serving path)
# ----------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DecodeState:
    pos: jnp.ndarray                      # int32[] — next position to write
    layers: list                          # per-layer cache / SSM state
    cross: Optional[list] = None          # whisper: per-layer (k, v) from enc
    shared: Optional[list] = None         # zamba2: per-site shared-attn cache


def init_decode_state(
    params: Dict[str, Any],
    cfg: ModelConfig,
    batch: int,
    max_len: int,
    encoder_frames: Optional[jnp.ndarray] = None,
) -> DecodeState:
    fam = cfg.family
    dt = dtype_of(cfg)
    layers: list = []
    cross = None
    shared = None
    if fam in ("dense", "vlm", "moe", "encdec"):
        for i in range(cfg.num_layers):
            is_glob = cfg.layer_is_global(i) if fam != "encdec" else True
            layers.append(init_kv_cache(cfg, batch, max_len, is_glob))
    elif fam == "ssm":
        d = cfg.d_model
        H, D = cfg.ssm_heads, cfg.ssm_head_dim
        for _ in range(cfg.num_layers):
            layers.append({
                "x_prev_tm": jnp.zeros((batch, d), dt),
                "S": jnp.zeros((batch, H, D, D), jnp.float32),
                "x_prev_cm": jnp.zeros((batch, d), dt),
            })
    elif fam == "hybrid":
        d_inner, H, D, n = m2._dims(cfg)
        W = cfg.conv_width
        for _ in range(cfg.num_layers):
            layers.append({
                "conv_buf": jnp.zeros((batch, W - 1, d_inner), dt),
                "h": jnp.zeros((batch, H, D, n), jnp.float32),
            })
        # one KV cache per application site of the weight-shared block —
        # weights are shared, attention histories are not.
        period = cfg.shared_attn_every or cfg.num_layers
        n_sites = cfg.num_layers // period
        shared = [init_kv_cache(cfg, batch, max_len, True)
                  for _ in range(n_sites)]
    if fam == "encdec":
        if encoder_frames is None:
            raise ValueError("whisper decode needs encoder_frames")
        enc = _encoder_forward(params, encoder_frames, cfg, remat=False)
        cross = []
        for i in range(cfg.num_layers):
            bp = jax.tree.map(lambda a: a[i], params["dec_blocks"])
            cross.append(project_kv(bp["cross_attn"], enc, cfg))
    return DecodeState(pos=jnp.zeros((), jnp.int32), layers=layers,
                       cross=cross, shared=shared)


def decode_step(
    params: Dict[str, Any],
    state: DecodeState,
    tokens: jnp.ndarray,                  # int32[B] — current input token
    cfg: ModelConfig,
) -> Tuple[jnp.ndarray, DecodeState]:
    """One autoregressive step. Returns (logits [B, V], new state)."""
    fam = cfg.family
    pos = state.pos
    x = embed_tokens(params["embed"], tokens[:, None], cfg)    # [B, 1, d]
    if fam == "encdec":
        x = x + _sinusoidal_at(pos, cfg.d_model, x.dtype)[None, None]
    new_layers = []
    shared = state.shared

    for i in range(cfg.num_layers):
        bp = jax.tree.map(lambda a: a[i], params["blocks"]) if fam != "encdec" \
            else jax.tree.map(lambda a: a[i], params["dec_blocks"])
        lc = state.layers[i]
        if fam in ("dense", "vlm"):
            is_glob = cfg.layer_is_global(i)
            h = rmsnorm(bp["ln1"], x, cfg.norm_eps)
            a, lc = decode_attention(bp["attn"], h, lc, pos, cfg,
                                     is_global=is_glob)
            x = x + a
            h2 = rmsnorm(bp["ln2"], x, cfg.norm_eps)
            x = x + mlp_forward(bp["mlp"], h2, cfg)
        elif fam == "moe":
            h = rmsnorm(bp["ln1"], x, cfg.norm_eps)
            a, lc = decode_attention(bp["attn"], h, lc, pos, cfg)
            x = x + a
            h2 = rmsnorm(bp["ln2"], x, cfg.norm_eps)
            y, _ = moe_mod.moe_forward(bp["moe"], h2, cfg)
            x = x + y
        elif fam == "ssm":
            h = rmsnorm(bp["ln1"], x, cfg.norm_eps)
            y, (x_tm, S) = rwkv.rwkv_time_mix(
                bp["time_mix"], h, cfg, state=(lc["x_prev_tm"], lc["S"]))
            x = x + y
            h2 = rmsnorm(bp["ln2"], x, cfg.norm_eps)
            y2, x_cm = rwkv.rwkv_channel_mix(
                bp["channel_mix"], h2, cfg, x_prev=lc["x_prev_cm"])
            x = x + y2
            lc = {"x_prev_tm": x_tm, "S": S, "x_prev_cm": x_cm}
        elif fam == "hybrid":
            h = rmsnorm(bp["ln"], x, cfg.norm_eps)
            y, (cb, hst) = m2.mamba2_forward(
                bp["mamba"], h, cfg, state=(lc["conv_buf"], lc["h"]))
            x = x + y
            lc = {"conv_buf": cb, "h": hst}
            period = cfg.shared_attn_every or cfg.num_layers
            if (i + 1) % period == 0:
                site = (i + 1) // period - 1
                sp = params["shared_attn"]
                hs = rmsnorm(sp["ln"], x, cfg.norm_eps)
                a, site_cache = decode_attention(sp["attn"], hs, shared[site],
                                                 pos, cfg)
                shared = shared[:site] + [site_cache] + shared[site + 1:]
                x = x + a
                hs2 = rmsnorm(sp["ln2"], x, cfg.norm_eps)
                x = x + mlp_forward(sp["mlp"], hs2, cfg)
        elif fam == "encdec":
            h = rmsnorm(bp["ln1"], x, cfg.norm_eps)
            a, lc = decode_attention(bp["attn"], h, lc, pos, cfg,
                                     use_rope=False)
            x = x + a
            hc = rmsnorm(bp["ln_cross"], x, cfg.norm_eps)
            kc, vc = state.cross[i]
            x = x + _cross_decode(bp["cross_attn"], hc, kc, vc, cfg)
            h2 = rmsnorm(bp["ln2"], x, cfg.norm_eps)
            x = x + mlp_forward(bp["mlp"], h2, cfg)
        new_layers.append(lc)

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["embed"], x[:, 0], cfg, params.get("head"))
    new_state = DecodeState(pos=pos + 1, layers=new_layers,
                            cross=state.cross, shared=shared)
    return logits, new_state


def _cross_decode(params, x, k, v, cfg: ModelConfig) -> jnp.ndarray:
    """Cross-attention for a single decode token (cached encoder K/V)."""
    from repro.kernels import ref as kref

    B = x.shape[0]
    q = attn_mod._project_q(params, x, cfg)
    out = kref.attention_ref(q, k, v, causal=False,
                             logit_soft_cap=cfg.logit_soft_cap)
    out = out.transpose(0, 2, 1, 3).reshape(B, 1, cfg.num_heads * cfg.head_dim)
    return jnp.einsum("bsh,hd->bsd", out,
                      params["wo"].astype(dtype_of(cfg)))
