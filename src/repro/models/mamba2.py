"""Mamba-2 (SSD) block — selective state-space layer (zamba2 backbone).

Kept faithful: per-head scalar decay A (the Mamba-2 simplification),
input-dependent Δ (softplus), B/C projections shared across heads within a
group, causal depthwise conv on the SSM input path, gated (silu z) output
with RMS norm, and a skip D·x term. State: h ∈ R^{heads × head_dim × n}.

  h_t = exp(Δ_t·a) · h_{t−1} + Δ_t · (x_t ⊗ B_t)
  y_t = h_t · C_t + D ⊙ x_t

Training scans over time; decode carries (conv_buf, h) — constant state, so
zamba2 decodes 500k contexts with O(1) SSM memory (plus the shared-attention
cache handled in transformer.py).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.distributed.context import constrain
from repro.models.config import ModelConfig
from repro.models.layers import dense_init, dtype_of, pdtype_of
from repro.models.scan_utils import chunked_scan


def _dims(cfg: ModelConfig):
    d_inner = 2 * cfg.d_model
    H = d_inner // cfg.ssm_head_dim
    return d_inner, H, cfg.ssm_head_dim, cfg.ssm_state


def mamba2_init(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    d_inner, H, D, n = _dims(cfg)
    ks = jax.random.split(key, 6)
    pd = pdtype_of(cfg)
    return {
        # fused input projection → [z, x, B, C, dt]
        "w_in_z": dense_init(ks[0], (d, d_inner), pd),
        "w_in_x": dense_init(ks[1], (d, d_inner), pd),
        "w_in_B": dense_init(ks[2], (d, n), pd),
        "w_in_C": dense_init(ks[3], (d, n), pd),
        "w_in_dt": dense_init(ks[4], (d, H), pd),
        "dt_bias": jnp.zeros((H,), pd),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(pd),
        "D": jnp.ones((H,), pd),
        "conv_w": (jax.random.normal(ks[5], (cfg.conv_width, d_inner),
                                     jnp.float32) * 0.1).astype(pd),
        "norm_scale": jnp.ones((d_inner,), pd),
        "w_out": dense_init(jax.random.fold_in(key, 7), (d_inner, d), pd,
                            fan_in=d_inner),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, buf: jnp.ndarray | None
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv along time. x: [B,S,C]; w: [W,C]; buf: [B,W-1,C]."""
    B, S, C = x.shape
    W = w.shape[0]
    if buf is None:
        buf = jnp.zeros((B, W - 1, C), x.dtype)
    xp = jnp.concatenate([buf, x], axis=1)              # [B, S+W-1, C]
    out = jnp.zeros_like(x)
    for i in range(W):
        out = out + xp[:, i : i + S] * w[i][None, None]
    return out, xp[:, -(W - 1):]


def _gated_rmsnorm(x: jnp.ndarray, z: jnp.ndarray, scale: jnp.ndarray,
                   eps: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = (xf * xf).mean(-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
            ).astype(x.dtype)


def mamba2_forward(
    params: dict,
    x: jnp.ndarray,                 # [B, S, d]
    cfg: ModelConfig,
    state: Tuple[jnp.ndarray, jnp.ndarray] | None = None,
) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Returns (out, (conv_buf, h)) — state carried at decode."""
    B, S, d = x.shape
    d_inner, H, D, n = _dims(cfg)
    dt = dtype_of(cfg)

    z = jnp.einsum("bsd,de->bse", x, params["w_in_z"].astype(dt))
    xc = jnp.einsum("bsd,de->bse", x, params["w_in_x"].astype(dt))
    Bv = jnp.einsum("bsd,dn->bsn", x, params["w_in_B"].astype(dt))
    Cv = jnp.einsum("bsd,dn->bsn", x, params["w_in_C"].astype(dt))
    delta = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", x, params["w_in_dt"].astype(dt)).astype(jnp.float32)
        + params["dt_bias"].astype(jnp.float32)
    )                                                    # [B, S, H]

    conv_buf0 = None if state is None else state[0]
    xc, conv_buf = _causal_conv(xc, params["conv_w"].astype(dt), conv_buf0)
    xc = jax.nn.silu(xc)

    a = -jnp.exp(params["A_log"].astype(jnp.float32))    # [H] (negative)
    h0 = (jnp.zeros((B, H, D, n), jnp.float32) if state is None else state[1])

    xh = xc.reshape(B, S, H, D).astype(jnp.float32)
    Bf = Bv.astype(jnp.float32)
    Cf = Cv.astype(jnp.float32)

    def step(h, inp):
        xt, Bt, Ct, dlt = inp                            # [B,H,D],[B,n],[B,n],[B,H]
        decay = jnp.exp(dlt * a[None, :])                # [B, H]
        dBx = jnp.einsum("bhd,bn,bh->bhdn", xt, Bt, dlt)
        # state sharded over (data, model): heads split across the model
        # axis — the SSM analogue of head-parallel attention.
        h_new = constrain(decay[..., None, None] * h + dBx, "bh")
        y = jnp.einsum("bhdn,bn->bhd", h_new, Ct)
        return h_new, y

    inputs = (xh.transpose(1, 0, 2, 3), Bf.transpose(1, 0, 2),
              Cf.transpose(1, 0, 2), delta.transpose(1, 0, 2))
    h_last, ys = chunked_scan(step, h0, inputs, chunk=64)
    y = ys.transpose(1, 0, 2, 3)                         # [B, S, H, D]
    y = y + params["D"].astype(jnp.float32)[None, None, :, None] * xh
    y = y.reshape(B, S, d_inner).astype(dt)
    y = _gated_rmsnorm(y, z, params["norm_scale"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, params["w_out"].astype(dt))
    return out, (conv_buf, h_last)
