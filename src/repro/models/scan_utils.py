"""Time-chunked scan with checkpointing — linear-RNN training memory fix.

``lax.scan`` autodiff saves the carry at every step; for SSM states
(rwkv6: [B,H,64,64] ≈ 10 MB/step, mamba2: [B,H,64,n] ≈ 67 MB/step at the
dry-run batch) a 4096-step sequence would stash 43–274 GB per device.
``chunked_scan`` nests two scans — outer over S/chunk segments (AD saves
only segment-boundary states), inner over the chunk under ``jax.checkpoint``
(recomputed during backward) — the classic BPTT-with-checkpointing trade:
memory  O(S/chunk + chunk)  instead of O(S), at ~2× step compute.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Tuple

import jax


def chunked_scan(
    step: Callable[[Any, Any], Tuple[Any, Any]],
    carry0: Any,
    xs: Any,                      # pytree, leaves time-major [S, ...]
    chunk: int = 256,
) -> Tuple[Any, Any]:
    """Drop-in for ``lax.scan(step, carry0, xs)`` with O(√S)-ish AD memory."""
    leaves = jax.tree.leaves(xs)
    S = leaves[0].shape[0]
    if S <= chunk or S % chunk != 0:
        return jax.lax.scan(step, carry0, xs)
    n = S // chunk
    xs_c = jax.tree.map(lambda a: a.reshape(n, chunk, *a.shape[1:]), xs)

    @jax.checkpoint
    def segment(carry, xc):
        return jax.lax.scan(step, carry, xc)

    carry, ys_c = jax.lax.scan(segment, carry0, xs_c)
    ys = jax.tree.map(lambda a: a.reshape(S, *a.shape[2:]), ys_c)
    return carry, ys
