"""Minimal stdlib HTTP front-end over a :class:`~repro.gateway.gateway.
Gateway` — enough surface to curl the tier, not a web framework.

Routes (all GET, all JSON):

* ``/pagerank?epsilon=&delta=&k=``        — batch top-k of the full vector
* ``/topk?k=&epsilon=&delta=&slo_s=&timeout_s=`` — async global top-k,
  driven to completion before responding (the HTTP surface is
  synchronous; the async path is the Python API)
* ``/ppr?source=&k=&epsilon=&delta=``     — personalized PageRank
* ``/healthz``                            — 200 iff the tier is routable
* ``/metrics``                            — :meth:`Gateway.stats` snapshot

Status mapping — every failure is structured, never a hang:

* **429** — replica admission refused; body carries the scheduler's
  ``reason_code`` (``infeasible_slo`` | ``capacity`` | ``shard_loss``).
* **503 + Retry-After** — the gateway shed the request
  (:class:`~repro.gateway.gateway.GatewayOverloadError`: breakers all
  open, backlog past the shed threshold, or draining); ``reason_code``
  names which.
* **504** — the request's ``timeout_s`` deadline (default 30 s) expired
  before the (ε, δ) certificate was earned; ``reason_code="deadline"``.
* **400** bad parameters; **404** unknown path; **500** anything else,
  surfaced with its exception type.

Concurrency (PR 8): there is **no per-process query lock**. Submits are
serialized by the gateway's own brief host-state lock, and wave driving
is serialized per replica inside the supervised pool — so a stalled or
crashed replica cannot block ``/healthz``, ``/metrics``, or queries
routed to healthy replicas; its own requests fail over or return 504.
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional, Tuple
from urllib.parse import parse_qs, urlparse

import numpy as np

from repro.gateway.gateway import Gateway, GatewayOverloadError

__all__ = ["GatewayHTTPServer", "serve_http"]

# wall-time budget for driving one HTTP request to certification; callers
# override per request with ?timeout_s=.
_DEFAULT_TIMEOUT_S = 30.0


def _result_payload(handle_or_result, source: str) -> dict:
    r = handle_or_result
    return {
        "kind": r.kind,
        "vertices": np.asarray(r.vertices).tolist(),
        "scores": np.asarray(r.scores).tolist(),
        "epsilon_bound": float(r.epsilon_bound),
        "num_walks": int(r.num_walks),
        "waves": int(r.waves),
        "latency_s": float(r.latency_s),
        "degraded": bool(r.degraded),
        "source": source,
    }


class _Handler(BaseHTTPRequestHandler):
    gateway: Gateway = None          # injected by GatewayHTTPServer

    def log_message(self, fmt, *args):   # noqa: D102 — silence stderr spam
        pass

    def _send(self, code: int, payload: dict, headers=()) -> None:
        body = json.dumps(payload).encode()
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        for name, value in headers:
            self.send_header(name, value)
        self.end_headers()
        self.wfile.write(body)

    def _param(self, qs, name, cast, default):
        raw = qs.get(name)
        if raw is None:
            if default is None:
                raise ValueError(f"missing required parameter {name!r}")
            return default
        return cast(raw[0])

    def do_GET(self):                # noqa: N802 — http.server contract
        url = urlparse(self.path)
        qs = parse_qs(url.query)
        try:
            self._route(url.path, qs)
        except ValueError as e:
            self._send(400, {"error": str(e)})
        except GatewayOverloadError as e:
            # structured backpressure, not failure: the tier is telling
            # the client when to come back.
            self._send(503, {"error": str(e),
                             "reason_code": e.reason,
                             "retry_after_s": e.retry_after_s},
                       headers=[("Retry-After",
                                 str(max(1, int(round(e.retry_after_s)))))])
        except TimeoutError as e:
            self._send(504, {"error": str(e), "reason_code": "deadline"})
        except Exception as e:      # surfaced, not swallowed: curl sees it
            self._send(500, {"error": f"{type(e).__name__}: {e}"})

    def _route(self, path: str, qs) -> None:
        gw = self.gateway
        if path == "/healthz":       # lock-free: must answer even when a
            ok = gw.healthy()        # replica is stalled mid-wave
            self._send(200 if ok else 503,
                       {"healthy": ok,
                        "replicas": len(gw.pool),
                        "routable": gw.pool.routable(),
                        "lost_shards": sorted(
                            s for r in gw.pool.replicas
                            for s in r.lost_shards)})
            return
        if path == "/metrics":
            self._send(200, gw.stats())
            return
        k = self._param(qs, "k", int, 10)
        epsilon = self._param(qs, "epsilon", float, 0.3)
        delta = self._param(qs, "delta", float, 0.1)
        if path == "/pagerank":
            hits_before = gw.metrics.cache_hits
            res = gw.pagerank(epsilon=epsilon, delta=delta, k=k)
            src = "cache" if gw.metrics.cache_hits > hits_before else "live"
            self._send(200, _result_payload(res, src))
            return
        if path in ("/topk", "/ppr"):
            slo_s = self._param(qs, "slo_s", float, 0.0) or None
            timeout_s = self._param(qs, "timeout_s", float,
                                    _DEFAULT_TIMEOUT_S)
            if path == "/ppr":
                source = self._param(qs, "source", int, None)
                h = gw.ppr(source, k=k, epsilon=epsilon, delta=delta,
                           slo_s=slo_s)
            else:
                h = gw.topk(k=k, epsilon=epsilon, delta=delta, slo_s=slo_s)
            if not h.admitted:
                d = h.decision
                self._send(429, {
                    "error": "rejected at admission",
                    "reason": d.reason,
                    "reason_code": d.reason_code.value,
                })
                return
            self._send(200, _result_payload(h.result(timeout_s=timeout_s),
                                            h.source))
            return
        self._send(404, {"error": f"no route {path!r}",
                         "routes": ["/pagerank", "/topk", "/ppr",
                                    "/healthz", "/metrics"]})


class GatewayHTTPServer:
    """Owns the listening socket + serving thread for one gateway.

    ``port=0`` (the default) binds an ephemeral port — read it back from
    :attr:`port` / :attr:`url`. ``close()`` stops the thread; the gateway
    itself is NOT closed (the caller owns it).
    """

    def __init__(self, gateway: Gateway, host: str = "127.0.0.1",
                 port: int = 0):
        self.gateway = gateway
        handler = type("BoundHandler", (_Handler,), {"gateway": gateway})
        self._httpd = ThreadingHTTPServer((host, port), handler)
        self._thread: Optional[threading.Thread] = None

    @property
    def address(self) -> Tuple[str, int]:
        return self._httpd.server_address[:2]

    @property
    def port(self) -> int:
        return self._httpd.server_address[1]

    @property
    def url(self) -> str:
        host, port = self.address
        return f"http://{host}:{port}"

    def start(self) -> "GatewayHTTPServer":
        if self._thread is None:
            self._thread = threading.Thread(
                target=self._httpd.serve_forever, kwargs={"poll_interval": 0.05},
                name="frogwild-gateway-http", daemon=True)
            self._thread.start()
        return self

    def close(self) -> None:
        if self._thread is not None:
            self._httpd.shutdown()
            self._thread.join(timeout=5.0)
            self._thread = None
        self._httpd.server_close()

    def __enter__(self) -> "GatewayHTTPServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()


def serve_http(gateway: Gateway, host: str = "127.0.0.1",
               port: int = 0) -> GatewayHTTPServer:
    """Starts (and returns) an HTTP front-end bound to ``gateway``."""
    return GatewayHTTPServer(gateway, host, port).start()
