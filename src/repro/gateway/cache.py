"""(ε, δ)-aware result cache: Theorem 1 makes reuse principled.

A generic result cache can only serve *exact* repeats; FrogWild's
certificates make sharing sound across users asking for different
accuracies. Every finished query carries the ε Theorem 1 certifies for the
walks it executed, at the δ it was requested at. That pair is a
**certificate** ``(ε′, δ′)``, and the dominance contract is:

    a cached answer certified at (ε′, δ′) serves a request for (ε, δ)
    iff ε′ ≤ ε and δ′ ≤ δ — the cached guarantee is at least as strong
    in both coordinates, so the new user gets what they asked for free.

Keys are ``(query kind, k, target/source vertex, graph epoch)``; a key
holds the *Pareto frontier* of certificates seen so far (two certificates
can be incomparable — tighter ε at looser δ — so one slot would silently
throw away reusable guarantees). Degraded answers — walks died on evicted
shards — are **never** cached: their bound is honest for the moment the
fault happened, but serving them after recovery would pin the outage into
the cache. Bumping the graph epoch (dynamic-graph refresh) orphans every
older key without a scan.
"""
from __future__ import annotations

import collections
import dataclasses
import math
from typing import Dict, List, Optional, Tuple

from repro.query.scheduler import QueryResult

__all__ = ["CacheEntry", "Certificate", "ResultCache"]

CacheKey = Tuple[str, int, int, int]     # (kind, k, source, epoch)


@dataclasses.dataclass(frozen=True)
class Certificate:
    """An (ε′, δ′) guarantee attached to a cached answer."""

    epsilon: float
    delta: float

    def dominates(self, epsilon: float, delta: float) -> bool:
        """True iff this certificate satisfies a request for (ε, δ)."""
        return self.epsilon <= epsilon and self.delta <= delta


@dataclasses.dataclass(frozen=True)
class CacheEntry:
    cert: Certificate
    result: QueryResult


class ResultCache:
    """LRU over query keys, Pareto frontier of certificates per key."""

    def __init__(self, capacity: int = 256):
        if capacity < 1:
            raise ValueError(f"capacity must be ≥ 1, got {capacity}")
        self.capacity = capacity
        self._entries: "collections.OrderedDict[CacheKey, List[CacheEntry]]" = (
            collections.OrderedDict())
        self.hits = 0                # requests served from the cache
        self.dominated_hits = 0      # … of those, by a strictly stronger cert
        self.misses = 0
        self.insertions = 0
        self.rejected_inserts = 0    # degraded / uncertified answers refused
        self.epoch_evictions = 0     # certificates dropped by epoch bumps

    @staticmethod
    def key(kind: str, k: int, source: int, epoch: int) -> CacheKey:
        """Canonical cache key. Global queries (top-k, pagerank) have no
        source vertex — it is normalized away so a caller-supplied dummy
        can't split their cache lines."""
        src = int(source) if kind == "ppr" else -1
        return (kind, int(k), src, int(epoch))

    def lookup(self, key: CacheKey, epsilon: float,
               delta: float) -> Optional[CacheEntry]:
        """The first cached certificate dominating (ε, δ), else None."""
        entries = self._entries.get(key)
        if entries:
            for e in entries:
                if e.cert.dominates(epsilon, delta):
                    self._entries.move_to_end(key)
                    self.hits += 1
                    if e.cert.epsilon < epsilon or e.cert.delta < delta:
                        self.dominated_hits += 1
                    return e
        self.misses += 1
        return None

    def insert(self, key: CacheKey, result: QueryResult,
               delta: float, min_epoch: Optional[int] = None) -> bool:
        """Caches a certified answer under ``key``; returns False when the
        answer is uncacheable (degraded, no finite certificate, or — with
        ``min_epoch`` — certified under a graph epoch older than the
        gateway's current one) or an already-cached certificate dominates
        it.

        ``min_epoch`` is the bump-epoch race guard: a query started on
        epoch ``e`` whose certificate lands after the gateway moved to
        ``e+1`` must never enter the cache (its key could collide with a
        fresh epoch-``e`` lookup only through ``drop_epochs_before``
        ordering bugs, and even inert stale entries burn capacity).
        Refused stale inserts count in ``rejected_inserts``.
        """
        if min_epoch is not None and key[3] < min_epoch:
            self.rejected_inserts += 1
            return False
        if (result.degraded or result.epsilon_bound <= 0.0
                or not math.isfinite(result.epsilon_bound)):
            self.rejected_inserts += 1
            return False
        cert = Certificate(float(result.epsilon_bound), float(delta))
        entries = self._entries.get(key, [])
        if any(e.cert.dominates(cert.epsilon, cert.delta) for e in entries):
            return False
        entries = [e for e in entries
                   if not cert.dominates(e.cert.epsilon, e.cert.delta)]
        entries.append(CacheEntry(cert=cert, result=result))
        self._entries[key] = entries
        self._entries.move_to_end(key)
        self.insertions += 1
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
        return True

    def drop_epochs_before(self, epoch: int) -> int:
        """Evicts every key from an older graph epoch (they can never hit
        again once the gateway's epoch moved on); returns the count, also
        accumulated in ``epoch_evictions`` (surfaced via ``stats()``)."""
        stale = [k for k in self._entries if k[3] < epoch]
        for k in stale:
            del self._entries[k]
        self.epoch_evictions += len(stale)
        return len(stale)

    def clear(self) -> None:
        self._entries.clear()

    def __len__(self) -> int:
        return len(self._entries)

    def stats(self) -> Dict[str, float]:
        looked = self.hits + self.misses
        return {
            "entries": len(self._entries),
            "capacity": self.capacity,
            "hits": self.hits,
            "dominated_hits": self.dominated_hits,
            "misses": self.misses,
            "insertions": self.insertions,
            "rejected_inserts": self.rejected_inserts,
            "epoch_evictions": self.epoch_evictions,
            "hit_rate": (self.hits / looked) if looked else 0.0,
        }
