"""Replica pool: N ``FrogWildService`` replicas over ONE shared graph and
walk index.

The expensive state — the CSR graph and the ``int32[n, R]`` walk-index
slab (or its per-shard blocks) — is built or loaded exactly once and the
*same arrays* are handed to every replica, so an N-replica pool costs N
schedulers (host state + one compiled wave program each), not N slabs.
Replicas are seeded identically, which keeps the cold-replica contract
from the rest of the stack: the first query on any fresh replica is
byte-identical to the first query on a fresh standalone service with the
same config.

Routing is queue-depth-aware: :meth:`ReplicaPool.route` picks the replica
with the smallest EDF-charged backlog as reported by its scheduler's own
admission accounting (:meth:`~repro.query.scheduler.QueryScheduler.stats`
``backlog_walks`` — the demand a new request would be charged behind),
breaking ties toward the replica that has run the fewest waves.
"""
from __future__ import annotations

import os
from typing import List, Optional, Union

from repro.config import RuntimeConfig
from repro.graph.csr import CSRGraph
from repro.service import FrogWildService

__all__ = ["ReplicaPool"]


class ReplicaPool:
    def __init__(
        self,
        graph_or_path: Union[CSRGraph, str, os.PathLike],
        config: Optional[RuntimeConfig] = None,
        *,
        num_replicas: int = 2,
        mesh=None,
    ):
        if num_replicas < 1:
            raise ValueError(f"num_replicas must be ≥ 1, got {num_replicas}")
        primary = FrogWildService.open(graph_or_path, config, mesh=mesh)
        # one build/load; every replica serves the same slab arrays (and,
        # for a sharded layout, the same per-shard blocks) — no N-fold
        # duplication, asserted in tests via object identity.
        index = primary.ensure_index()
        self.replicas: List[FrogWildService] = [primary]
        for _ in range(num_replicas - 1):
            self.replicas.append(FrogWildService.open(
                primary.graph, primary.config, mesh=mesh, index=index))
        self._closed = False

    def __len__(self) -> int:
        return len(self.replicas)

    @property
    def closed(self) -> bool:
        return self._closed

    @property
    def graph(self) -> CSRGraph:
        return self.replicas[0].graph

    @property
    def config(self) -> RuntimeConfig:
        return self.replicas[0].config

    def route(self) -> int:
        """Index of the replica a new request should land on.

        Orders by (EDF-charged backlog walks, waves run, replica index):
        the backlog is the scheduler's own admission charge — queued plus
        in-flight walk demand — so routing and admission agree about what
        "loaded" means. A replica whose scheduler does not exist yet is
        unloaded by definition (depth 0, zero waves).
        """
        if self._closed:
            raise RuntimeError("ReplicaPool is closed")

        def load(i: int):
            st = self.replicas[i].serving_stats()
            if st is None:
                return (0, 0, i)
            return (st.backlog_walks, st.waves_run, i)

        return min(range(len(self.replicas)), key=load)

    def total_waves_run(self) -> int:
        """Waves executed across the pool — the cache tests' "zero new
        walks" witness (a dominated hit must not move this)."""
        return sum(st.waves_run for st in
                   (r.serving_stats() for r in self.replicas)
                   if st is not None)

    def close(self) -> None:
        """Closes every replica (idempotent — replica close is too)."""
        if self._closed:
            return
        for r in self.replicas:
            r.close()
        self._closed = True

    def __enter__(self) -> "ReplicaPool":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
