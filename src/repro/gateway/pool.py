"""Supervised replica pool: N ``FrogWildService`` replicas over ONE shared
graph and walk index, with per-replica health, circuit breakers, and
deterministic restart.

The expensive state — the CSR graph and the ``int32[n, R]`` walk-index
slab (or its per-shard blocks) — is built or loaded exactly once and the
*same arrays* are handed to every replica, so an N-replica pool costs N
schedulers (host state + one compiled wave program each), not N slabs.
Replicas are seeded identically, which keeps the cold-replica contract
from the rest of the stack: the first query on any fresh replica is
byte-identical to the first query on a fresh standalone service with the
same config — and that is also what makes **restart deterministic**: a
crashed replica is re-opened as a new service over the *same* slab
(object identity re-asserted, zero index rebuild) whose key stream
starts at wave 0 like any cold replica's.

Supervision (PR 8). The pool is the fault boundary between the gateway
and its replicas:

* **Wave driving** goes through :meth:`step_replica`, never
  ``service.step()`` directly: the pool consults the replica-level fault
  injector (``replica_crash`` / ``replica_stall`` / ``replica_slow``
  from the shared :class:`~repro.distributed.faults.FaultPlan`), holds a
  per-replica lock (two HTTP threads driving the same scheduler would
  corrupt host state; different replicas drive concurrently), measures
  wall time against the **heartbeat deadline**, and folds clean waves
  into a per-replica wave-time EMA.
* **Breaker states** per replica — ``closed`` (routable), ``open``
  (quarantined out of :meth:`route`), ``half_open`` (cooldown elapsed;
  routable as a probe — first clean wave closes the breaker, first fault
  re-opens it). A crash or missed heartbeat opens the breaker
  immediately; repeated :class:`~repro.distributed.faults.
  WaveFailedError` opens it after ``breaker_failure_threshold``
  consecutive failures.
* **Health score** in [0, 1] per replica (:meth:`health_score`):
  0 when open/crashed, 0.5 while half-open, else
  ``max(0.1, 1 − 0.25·consecutive_failures) · min(1, median_ema/own_ema)``
  — a straggler (own EMA above the pool median) scores below its peers
  even before any fault fires, which is what the gateway's hedging keys
  on.
* **Restart** (:meth:`restart_replica`): a crashed replica's slot gets a
  fresh ``FrogWildService`` opened over the same graph / config / mesh /
  shared index — ``ensure_index() is`` the pool's slab, asserted — with
  the breaker left ``open`` until the cooldown elapses (the restarted
  replica re-enters rotation through the half-open probe like any other
  recovered replica).

Routing (:meth:`route`) is queue-depth-aware over **routable** replicas
only: smallest EDF-charged ``backlog_walks`` from each scheduler's own
admission accounting, ties toward fewest waves run. With every breaker
open, :meth:`route` raises :class:`NoReplicaAvailable` — the gateway
turns that into load shedding, never a hang.
"""
from __future__ import annotations

import os
import threading
import time
from typing import Dict, List, Optional, Union

from repro.config import RuntimeConfig
from repro.distributed.faults import (FaultEvent, FaultInjector,
                                      ReplicaCrashed, ReplicaStalled)
from repro.graph.csr import CSRGraph
from repro.service import FrogWildService

__all__ = ["NoReplicaAvailable", "ReplicaPool", "ReplicaState"]


class NoReplicaAvailable(RuntimeError):
    """Every replica's breaker is open (or the pool is closed) — there is
    nowhere to route. The gateway maps this to structured load shedding
    (HTTP 503 + Retry-After), never a blocked caller."""

    def __init__(self, message: str, retry_after_s: float = 1.0):
        super().__init__(message)
        self.retry_after_s = retry_after_s


class ReplicaState:
    """Mutable supervision record for one replica slot."""

    def __init__(self):
        self.breaker = "closed"          # closed | open | half_open
        self.consecutive_failures = 0
        self.opened_at: Optional[float] = None
        self.last_fault = ""             # why the breaker last opened
        self.wave_time_ema_s: Optional[float] = None
        self.waves_driven = 0            # pool drives (fault addressing)
        self.restarts = 0
        self.crashed = False             # service closed, awaiting restart


class ReplicaPool:
    def __init__(
        self,
        graph_or_path: Union[CSRGraph, str, os.PathLike],
        config: Optional[RuntimeConfig] = None,
        *,
        num_replicas: int = 2,
        mesh=None,
        heartbeat_timeout_s: Optional[float] = None,
        breaker_failure_threshold: int = 3,
        breaker_cooldown_s: float = 30.0,
    ):
        if num_replicas < 1:
            raise ValueError(f"num_replicas must be ≥ 1, got {num_replicas}")
        primary = FrogWildService.open(graph_or_path, config, mesh=mesh)
        # one build/load; every replica serves the same slab arrays (and,
        # for a sharded layout, the same per-shard blocks) — no N-fold
        # duplication, asserted in tests via object identity.
        self._index = index = primary.ensure_index()
        self._graph = primary.graph
        self._mesh = mesh
        self.replicas: List[FrogWildService] = [primary]
        for _ in range(num_replicas - 1):
            self.replicas.append(FrogWildService.open(
                primary.graph, primary.config, mesh=mesh, index=index))
        self._closed = False
        # --- supervision (PR 8) ---
        self.heartbeat_timeout_s = heartbeat_timeout_s
        self.breaker_failure_threshold = breaker_failure_threshold
        self.breaker_cooldown_s = breaker_cooldown_s
        self.states: List[ReplicaState] = [ReplicaState()
                                           for _ in range(num_replicas)]
        self.fault_log: List[FaultEvent] = []
        # replica-level faults come from the SAME FaultPlan as the
        # scheduler-level ones, but through the pool's own injector — the
        # per-service injectors never see pool-wave indices.
        cfg = primary.config
        self._injector = (FaultInjector(cfg.faults)
                          if cfg.faults is not None else None)
        # one step lock per replica: waves on one scheduler serialize,
        # different replicas (and /healthz, /metrics) never contend.
        self._step_locks = [threading.Lock() for _ in range(num_replicas)]
        self._state_lock = threading.RLock()

    def __len__(self) -> int:
        return len(self.replicas)

    @property
    def closed(self) -> bool:
        return self._closed

    @property
    def graph(self) -> CSRGraph:
        return self._graph

    @property
    def config(self) -> RuntimeConfig:
        return self.replicas[0].config

    @property
    def index(self):
        """The ONE shared walk-index slab every replica serves from."""
        return self._index

    def commit_epoch(self, graph: CSRGraph, index) -> int:
        """Commits a new (graph, slab) epoch to every live replica.

        Replica 0 commits first and its ``ensure_index()`` result — the
        slab normalized to the serving layout (re-sharded at most once) —
        is what every other replica receives, so all replicas keep sharing
        ONE set of slab arrays and :meth:`restart_replica`'s object-
        identity assertion stays true across epochs. In-flight queries on
        any replica keep draining on their pinned old-epoch schedulers.
        """
        self._check_open()
        with self._state_lock:
            epoch = self.replicas[0].commit_epoch(graph, index)
            shared = self.replicas[0].ensure_index()
            for r in self.replicas[1:]:
                if not r.closed:
                    r.commit_epoch(graph, shared)
            self._index = shared
            self._graph = graph
            return epoch

    # --- supervised wave driving -----------------------------------------

    def step_replica(self, ridx: int) -> bool:
        """Drives one wave on replica ``ridx`` under supervision.

        The pool-boundary contract: injected replica faults fire here
        (crash → service closed + :class:`ReplicaCrashed`; stall past the
        heartbeat deadline → :class:`ReplicaStalled`; slow → added
        latency, no exception), the wave's wall time is checked against
        ``heartbeat_timeout_s`` and folded into the replica's EMA, and
        breaker bookkeeping happens on both success and failure. Returns
        the scheduler's "did anything run" bool.
        """
        self._check_open()
        st = self.states[ridx]
        if st.crashed:
            raise ReplicaCrashed(
                f"replica {ridx} is crashed (restart pending)", ridx)
        wave_no = st.waves_driven
        st.waves_driven += 1
        stall_s = slow_s = 0.0
        if self._injector is not None:
            if self._injector.replica_crash_at(ridx, wave_no):
                self._on_crash(ridx, f"injected crash at pool wave {wave_no}")
                raise ReplicaCrashed(
                    f"replica {ridx} crashed at pool wave {wave_no}", ridx)
            stall_s = self._injector.replica_stall_s(ridx, wave_no)
            slow_s = self._injector.replica_slow_s(ridx)
        t0 = time.monotonic()
        hb = self.heartbeat_timeout_s
        if stall_s or slow_s:
            # simulate the stall/straggler before the wave body; a stall
            # already past the deadline means the wave never returns in
            # time — don't run it (a real stalled worker produced nothing).
            if hb is not None and stall_s + slow_s > hb:
                time.sleep(min(stall_s + slow_s, hb))
                self._on_stall(ridx, time.monotonic() - t0)
                raise ReplicaStalled(
                    f"replica {ridx} missed its heartbeat deadline "
                    f"({stall_s + slow_s:.3g}s stall > {hb:.3g}s)", ridx)
            time.sleep(stall_s + slow_s)
        with self._step_locks[ridx]:
            progressed = self.replicas[ridx].step()
        dt = time.monotonic() - t0
        # the wall-time heartbeat only arms once an EMA exists — the first
        # timed waves include jit compilation, which must never read as a
        # stall (injected stalls above fire regardless; they are
        # deterministic and machine-independent).
        if hb is not None and dt > hb and st.wave_time_ema_s is not None:
            self._on_stall(ridx, dt)
            raise ReplicaStalled(
                f"replica {ridx} wave took {dt:.3g}s > heartbeat deadline "
                f"{hb:.3g}s", ridx)
        # one-shot stalls are faults, not throughput, and stay out of the
        # EMA; persistent slowness IS the machine — it belongs in it (the
        # straggler term of the health score keys on exactly that).
        self._on_success(ridx, dt, clean=stall_s == 0.0)
        return progressed

    def record_failure(self, ridx: int, reason: str) -> None:
        """Charges a wave-level failure (e.g. ``WaveFailedError`` out of
        the scheduler) against the replica's breaker: past
        ``breaker_failure_threshold`` consecutive failures it opens."""
        with self._state_lock:
            st = self.states[ridx]
            st.consecutive_failures += 1
            if (st.breaker == "half_open"
                    or st.consecutive_failures
                    >= self.breaker_failure_threshold):
                self._open_breaker(ridx, reason)

    def _on_success(self, ridx: int, dt: float, clean: bool) -> None:
        with self._state_lock:
            st = self.states[ridx]
            st.consecutive_failures = 0
            if st.breaker == "half_open":
                st.breaker = "closed"       # probe succeeded
                st.opened_at = None
                self.fault_log.append(FaultEvent(
                    "breaker_close", st.waves_driven,
                    detail=f"replica={ridx} probe wave clean"))
            # EMA over clean waves only (injected latency measures the
            # fault, not the machine); the first wave includes jit
            # compilation and is skipped like the scheduler's own EMA.
            if clean and st.waves_driven > 1:
                st.wave_time_ema_s = (
                    dt if st.wave_time_ema_s is None
                    else 0.5 * st.wave_time_ema_s + 0.5 * dt)

    def _on_crash(self, ridx: int, reason: str) -> None:
        with self._state_lock:
            st = self.states[ridx]
            st.crashed = True
            # the crashed service is closed so its in-flight handles
            # settle as "cancelled" (never a hang) while the gateway
            # migrates them to a healthy replica.
            self.replicas[ridx].close()
            self._open_breaker(ridx, reason)

    def _on_stall(self, ridx: int, dt: float) -> None:
        with self._state_lock:
            self.states[ridx].consecutive_failures += 1
            self._open_breaker(
                ridx, f"heartbeat missed ({dt:.3g}s wave)")

    def _open_breaker(self, ridx: int, reason: str) -> None:
        st = self.states[ridx]
        if st.breaker != "open":
            st.breaker = "open"
            st.opened_at = time.monotonic()
            self.fault_log.append(FaultEvent(
                "breaker_open", st.waves_driven,
                detail=f"replica={ridx}: {reason}"))
        st.last_fault = reason

    def restart_replica(self, ridx: int) -> FrogWildService:
        """Deterministically restarts replica ``ridx``: a fresh
        ``FrogWildService`` over the *same* graph / config / mesh and the
        *same* shared slab — object identity asserted, zero index
        rebuild. The breaker stays ``open`` until the cooldown elapses,
        so the restarted replica re-enters rotation through the standard
        half-open probe."""
        with self._state_lock:
            old = self.replicas[ridx]
            if not old.closed:
                old.close()
            fresh = FrogWildService.open(self.graph, self.config,
                                         mesh=self._mesh, index=self._index)
            assert fresh.ensure_index() is self._index, (
                "restarted replica must share the pool's slab")
            self.replicas[ridx] = fresh
            st = self.states[ridx]
            st.crashed = False
            st.restarts += 1
            st.waves_driven = 0          # cold again: key stream at wave 0
            st.wave_time_ema_s = None
            self.fault_log.append(FaultEvent(
                "replica_restart", 0,
                detail=f"replica={ridx} restart #{st.restarts} over the "
                       f"shared slab"))
            return fresh

    # --- breaker / health introspection ----------------------------------

    def _tick_breakers(self) -> None:
        """Moves cooled-down open breakers to half-open (probe-ready)."""
        now = time.monotonic()
        for i, st in enumerate(self.states):
            if (st.breaker == "open" and not st.crashed
                    and st.opened_at is not None
                    and now - st.opened_at >= self.breaker_cooldown_s):
                st.breaker = "half_open"
                self.fault_log.append(FaultEvent(
                    "breaker_half_open", st.waves_driven,
                    detail=f"replica={i} cooldown elapsed"))

    def breaker_state(self, ridx: int) -> str:
        """``closed`` | ``open`` | ``half_open`` (cooldowns applied)."""
        with self._state_lock:
            self._tick_breakers()
            return self.states[ridx].breaker

    def routable(self) -> List[int]:
        """Replica indices :meth:`route` may currently pick: closed
        breakers plus half-open probes. Half-open replicas stay routable
        alongside healthy peers — otherwise a recovered replica would
        never receive the probe wave that closes its breaker — and one
        failure in the probe re-opens immediately
        (:meth:`record_failure`)."""
        with self._state_lock:
            self._tick_breakers()
            return [i for i, st in enumerate(self.states)
                    if st.breaker in ("closed", "half_open")
                    and not st.crashed]

    def health_score(self, ridx: int) -> float:
        """Replica health in [0, 1] — the breaker's drive signal.

        0.0 open/crashed; 0.5 half-open; else a closed replica starts at
        1.0, loses 0.25 per consecutive wave failure (floor 0.1), and is
        scaled by ``min(1, median_ema / own_ema)`` so a straggler scores
        below its peers before any fault ever fires.
        """
        with self._state_lock:
            self._tick_breakers()
            st = self.states[ridx]
            if st.crashed or st.breaker == "open":
                return 0.0
            if st.breaker == "half_open":
                return 0.5
            score = max(0.1, 1.0 - 0.25 * st.consecutive_failures)
            emas = sorted(s.wave_time_ema_s for s in self.states
                          if s.wave_time_ema_s is not None)
            if emas and st.wave_time_ema_s:
                median = emas[len(emas) // 2]
                score *= min(1.0, median / st.wave_time_ema_s)
            return score

    def route(self) -> int:
        """Index of the replica a new request should land on.

        Orders the **routable** replicas (open breakers are quarantined
        out) by (EDF-charged backlog walks, waves run, replica index):
        the backlog is the scheduler's own admission charge — queued plus
        in-flight walk demand — so routing and admission agree about what
        "loaded" means. A replica whose scheduler does not exist yet is
        unloaded by definition (depth 0, zero waves). With nothing
        routable, raises :class:`NoReplicaAvailable` with the remaining
        breaker cooldown as the suggested retry-after.
        """
        if self._closed:
            raise RuntimeError("ReplicaPool is closed")
        candidates = self.routable()
        if not candidates:
            now = time.monotonic()
            waits = [self.breaker_cooldown_s - (now - st.opened_at)
                     for st in self.states if st.opened_at is not None]
            retry = max(0.05, min(waits) if waits else 1.0)
            raise NoReplicaAvailable(
                f"all {len(self.replicas)} replicas quarantined "
                f"(breakers open) — retry in {retry:.2g}s",
                retry_after_s=retry)

        def load(i: int):
            st = self.replicas[i].serving_stats()
            if st is None:
                return (0, 0, i)
            return (st.backlog_walks, st.waves_run, i)

        return min(candidates, key=load)

    def total_waves_run(self) -> int:
        """Waves executed across the pool — the cache tests' "zero new
        walks" witness (a dominated hit must not move this)."""
        return sum(st.waves_run for st in
                   (r.serving_stats() for r in self.replicas)
                   if st is not None)

    def close(self) -> None:
        """Closes every replica (idempotent — replica close is too)."""
        if self._closed:
            return
        for r in self.replicas:
            r.close()
        self._closed = True

    def _check_open(self) -> None:
        if self._closed:
            raise RuntimeError("ReplicaPool is closed")

    def __enter__(self) -> "ReplicaPool":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
