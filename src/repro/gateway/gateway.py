"""The gateway facade: cache → in-flight join → replica routing.

One :class:`Gateway` fronts a :class:`~repro.gateway.pool.ReplicaPool`
behind a single submit path shared by ``topk`` / ``ppr`` / ``pagerank``:

1. **Result cache** — if a cached certificate dominates the request
   (ε′ ≤ ε, δ′ ≤ δ), the answer is served immediately with zero walks
   executed, byte-identical to the originally certified answer.
2. **In-flight dedup** — if an identical key is already being computed and
   its target dominates the request, the request joins the live
   :class:`~repro.service.QueryHandle` (via :meth:`~repro.service.
   QueryHandle.join`): it is fed monotone ``partial()`` snapshots and
   completes the wave the weaker of the two bounds certifies.
3. **Replica routing** — otherwise the request lands on the replica with
   the lowest EDF-charged queue depth; its completed (undegraded) result
   is inserted into the cache for everyone after.

Every request returns a :class:`GatewayHandle` whose ``source`` records
which path served it (``"cache"`` | ``"joined"`` | ``"live"``).
"""
from __future__ import annotations

import os
import time
from typing import Dict, Optional, Union

import numpy as np

from repro.config import RuntimeConfig
from repro.gateway.cache import CacheKey, ResultCache
from repro.gateway.metrics import GatewayMetrics
from repro.gateway.pool import ReplicaPool
from repro.graph.csr import CSRGraph
from repro.query.engine import plan_query
from repro.query.scheduler import QueryPartial, QueryResult
from repro.service import JoinedQueryHandle, QueryHandle

__all__ = ["Gateway", "GatewayHandle"]


class GatewayHandle:
    """Uniform future for a gateway request, whatever path served it.

    ``source`` is ``"cache"`` (settled at submit, zero walks), ``"joined"``
    (riding another user's in-flight query), or ``"live"`` (a fresh query
    on ``replica``). The interface mirrors :class:`~repro.service.
    QueryHandle`: ``done()`` / ``poll()`` / ``partial()`` / ``result()``.
    """

    def __init__(self, gateway: "Gateway", source: str,
                 replica: Optional[int], *, key: CacheKey,
                 epsilon: float, delta: float,
                 inner: Union[QueryHandle, JoinedQueryHandle, None] = None,
                 result: Optional[QueryResult] = None):
        self._gateway = gateway
        self.source = source
        self.replica = replica
        self.key = key
        self.epsilon = epsilon
        self.delta = delta
        self._inner = inner
        self._result: Optional[QueryResult] = None
        self._t0 = time.perf_counter()
        if result is not None:           # cache hit: settled at birth
            self._result = result
            gateway._record_done(self, result, latency_s=0.0)

    @property
    def admitted(self) -> bool:
        return self._result is not None or self._inner.admitted

    @property
    def decision(self):
        """The replica's AdmissionDecision (None off the live path)."""
        return (self._inner.decision
                if isinstance(self._inner, QueryHandle) else None)

    def done(self) -> bool:
        return self._result is not None or self._maybe_settle()

    def poll(self) -> bool:
        """Advances the serving replica by at most one wave."""
        if self._result is None:
            self._inner.poll()
        return self.done()

    def partial(self) -> QueryPartial:
        """Anytime snapshot (for a settled handle, the final state)."""
        if self._result is not None:
            r = self._result
            return QueryPartial(
                rid=r.rid, kind=r.kind, k=len(r.vertices),
                vertices=r.vertices, scores=r.scores,
                walks_done=r.num_walks, waves=r.waves,
                epsilon_bound=r.epsilon_bound, done=True,
                degraded=r.degraded, shards_lost=r.shards_lost,
                walks_lost=r.walks_lost)
        return self._inner.partial()

    def result(self, max_waves: Optional[int] = None) -> QueryResult:
        if self._result is None:
            self._settle(self._inner.result(max_waves))
        return self._result

    def _maybe_settle(self) -> bool:
        """Settles without driving waves when the inner future finished.

        Rejected / cancelled queries are terminal (True) but never settle a
        result — ``result()`` surfaces the inner handle's error instead.
        """
        inner = self._inner
        if isinstance(inner, QueryHandle):
            st = inner.status() if inner.admitted else "rejected"
            if st == "finished":
                self._settle(inner.result(max_waves=0))
                return True
            return st in ("rejected", "cancelled")
        if inner.done():
            self._settle(inner.result(max_waves=0))
            return True
        return False

    def _settle(self, result: QueryResult) -> None:
        if self._result is None:
            self._result = result
            self._gateway._record_done(
                self, result, latency_s=time.perf_counter() - self._t0)


class Gateway:
    """Serving tier over a replica pool with an (ε, δ)-aware cache.

    Build one with :meth:`open`; submit with :meth:`topk` / :meth:`ppr`
    (async :class:`GatewayHandle`) or :meth:`pagerank` (synchronous batch);
    observe with :meth:`stats`; mount the stdlib HTTP front-end with
    :func:`~repro.gateway.http.serve_http`.
    """

    def __init__(self, pool: ReplicaPool, cache: Optional[ResultCache],
                 metrics: Optional[GatewayMetrics] = None):
        self.pool = pool
        self.cache = cache
        self.metrics = metrics if metrics is not None else GatewayMetrics()
        self.epoch = 0
        self._inflight: Dict[CacheKey, GatewayHandle] = {}
        self._closed = False

    @classmethod
    def open(
        cls,
        graph_or_path: Union[CSRGraph, str, os.PathLike],
        config: Optional[RuntimeConfig] = None,
        *,
        replicas: int = 2,
        cache: bool = True,
        cache_capacity: int = 256,
        mesh=None,
    ) -> "Gateway":
        """Opens a gateway: one shared graph/index, ``replicas`` services,
        and (unless ``cache=False``) the dominance-checked result cache."""
        pool = ReplicaPool(graph_or_path, config, num_replicas=replicas,
                           mesh=mesh)
        return cls(pool, ResultCache(cache_capacity) if cache else None)

    # --- lifecycle -------------------------------------------------------

    @property
    def closed(self) -> bool:
        return self._closed

    def close(self) -> None:
        """Closes the pool and drops gateway state (idempotent)."""
        if self._closed:
            return
        self._inflight.clear()
        if self.cache is not None:
            self.cache.clear()
        self.pool.close()
        self._closed = True

    def __enter__(self) -> "Gateway":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def bump_epoch(self) -> int:
        """Advances the graph epoch: every cached certificate and in-flight
        join key from older epochs stops matching (the dynamic-graph
        refresh hook — ROADMAP item 4 pins the epoch at query start)."""
        self.epoch += 1
        self._inflight.clear()
        if self.cache is not None:
            self.cache.drop_epochs_before(self.epoch)
        return self.epoch

    def _check_open(self) -> None:
        if self._closed:
            raise RuntimeError("Gateway is closed")

    # --- the async query surface -----------------------------------------

    def topk(self, k: int = 10, epsilon: float = 0.3, delta: float = 0.1,
             *, slo_s: Optional[float] = None,
             allow_downgrade: bool = False) -> GatewayHandle:
        """Global top-k through the tier (cache → join → replica)."""
        return self._submit("topk", k, 0, epsilon, delta, slo_s=slo_s,
                            allow_downgrade=allow_downgrade)

    def ppr(self, source: int, k: int = 10, epsilon: float = 0.3,
            delta: float = 0.1, *, slo_s: Optional[float] = None,
            allow_downgrade: bool = False) -> GatewayHandle:
        """Personalized PageRank through the tier."""
        return self._submit("ppr", k, source, epsilon, delta, slo_s=slo_s,
                            allow_downgrade=allow_downgrade)

    def _submit(self, kind: str, k: int, source: int, epsilon: float,
                delta: float, *, slo_s: Optional[float],
                allow_downgrade: bool) -> GatewayHandle:
        self._check_open()
        self.metrics.requests += 1
        key = ResultCache.key(kind, k, source, self.epoch)

        # 1. cache: a dominating certificate answers for free.
        if self.cache is not None:
            entry = self.cache.lookup(key, epsilon, delta)
            if entry is not None:
                self.metrics.cache_hits += 1
                return GatewayHandle(self, "cache", None, key=key,
                                     epsilon=epsilon, delta=delta,
                                     result=entry.result)

        # 2. in-flight dedup: ride a live duplicate whose target dominates.
        live = self._inflight.get(key)
        if live is not None:
            if live.done():              # finished since last touched —
                live = None              # its settle cached it already;
                self._inflight.pop(key, None)  # fall through to re-lookup
                if self.cache is not None:
                    entry = self.cache.lookup(key, epsilon, delta)
                    if entry is not None:
                        self.metrics.cache_hits += 1
                        return GatewayHandle(self, "cache", None, key=key,
                                             epsilon=epsilon, delta=delta,
                                             result=entry.result)
            elif live.epsilon <= epsilon and live.delta <= delta:
                self.metrics.joins += 1
                joined = live._inner.join(epsilon, delta)
                return GatewayHandle(self, "joined", live.replica, key=key,
                                     epsilon=epsilon, delta=delta,
                                     inner=joined)

        # 3. route to the least-loaded replica.
        ridx = self.pool.route()
        svc = self.pool.replicas[ridx]
        if kind == "ppr":
            qh = svc.ppr(source, k=k, epsilon=epsilon, delta=delta,
                         slo_s=slo_s, allow_downgrade=allow_downgrade)
        else:
            qh = svc.topk(k=k, epsilon=epsilon, delta=delta, slo_s=slo_s,
                          allow_downgrade=allow_downgrade)
        self.metrics.record_admission(qh.decision)
        handle = GatewayHandle(self, "live", ridx, key=key,
                               epsilon=epsilon, delta=delta, inner=qh)
        if qh.admitted:
            self.metrics.live += 1
            prev = self._inflight.get(key)
            # register for joins; a strictly stronger target displaces a
            # weaker registrant (it can serve strictly more duplicates).
            if (prev is None or prev.done()
                    or (epsilon <= prev.epsilon and delta <= prev.delta)):
                self._inflight[key] = handle
        return handle

    # --- batch -----------------------------------------------------------

    def pagerank(self, epsilon: float = 0.3, delta: float = 0.1,
                 k: int = 10) -> QueryResult:
        """Batch full-vector PageRank, reduced to its top-k and cached.

        The Theorem-1 plan meets the requested (ε, δ) by construction, so
        the certificate is the plan's recorded ``epsilon_bound`` (which
        also honestly widens when a cap binds the plan).
        """
        self._check_open()
        self.metrics.requests += 1
        key = ResultCache.key("pagerank", k, 0, self.epoch)
        if self.cache is not None:
            entry = self.cache.lookup(key, epsilon, delta)
            if entry is not None:
                self.metrics.cache_hits += 1
                self.metrics.record_completion(0.0)
                return entry.result
        ridx = self.pool.route()
        svc = self.pool.replicas[ridx]
        plan = plan_query(k, epsilon, delta, p_T=svc.config.p_T,
                          max_steps=svc.config.serving.max_steps)
        t0 = time.perf_counter()
        res = svc.pagerank(epsilon=epsilon, delta=delta, k=k)
        pi = np.asarray(res.pi_hat)
        top = np.argsort(-pi, kind="stable")[:min(k, pi.shape[0])]
        qr = QueryResult(
            rid=-1, kind="pagerank", vertices=top, scores=pi[top],
            num_walks=int(getattr(res, "num_frogs", plan.num_walks)),
            num_steps=plan.num_steps, waves=0,
            latency_s=time.perf_counter() - t0,
            epsilon_bound=plan.epsilon_bound)
        self.metrics.live += 1
        self.metrics.record_completion(qr.latency_s)
        if self.cache is not None:
            self.cache.insert(key, qr, delta)
        return qr

    # --- completion hook --------------------------------------------------

    def _record_done(self, handle: GatewayHandle, result: QueryResult,
                     latency_s: float) -> None:
        self.metrics.record_completion(latency_s)
        if handle.source != "live":
            return
        if self._inflight.get(handle.key) is handle:
            del self._inflight[handle.key]
        if self.cache is not None and not self._closed:
            # degraded answers are refused inside insert(); the
            # certificate's δ is the δ the bound was certified at.
            self.cache.insert(handle.key, result, handle.delta)

    # --- drive + observe --------------------------------------------------

    def step(self) -> bool:
        """One wave across the pool: advances every replica with in-flight
        work; False when the whole tier is idle."""
        self._check_open()
        progressed = False
        for r in self.pool.replicas:
            if r.serving_stats() is not None:
                progressed |= r.step()
        return progressed

    def healthy(self) -> bool:
        """Liveness: open, and no replica lost a serving shard."""
        return (not self._closed and not self.pool.closed
                and all(not r.lost_shards for r in self.pool.replicas))

    def stats(self) -> Dict[str, object]:
        """One structured snapshot of the whole tier (what ``/metrics``
        serves): gateway counters + per-replica scheduler stats + cache."""
        snap = self.metrics.snapshot()
        snap["epoch"] = self.epoch
        snap["inflight_keys"] = len(self._inflight)
        snap["closed"] = self._closed
        snap["cache"] = (self.cache.stats() if self.cache is not None
                         else None)
        replicas = []
        for i, r in enumerate(self.pool.replicas):
            st = r.serving_stats()
            replicas.append({
                "replica": i,
                "queue_depth_walks": 0 if st is None else st.backlog_walks,
                "queued": 0 if st is None else st.queued,
                "active": 0 if st is None else st.active,
                "finished": 0 if st is None else st.finished,
                "rejected": 0 if st is None else st.rejected,
                "waves_run": 0 if st is None else st.waves_run,
                "walks_executed": 0 if st is None else st.walks_executed,
                "wave_occupancy": (0.0 if st is None
                                   else round(st.wave_occupancy, 4)),
                "wave_time_ema_s": None if st is None else st.wave_time_ema_s,
                "lost_shards": [] if st is None else list(st.lost_shards),
            })
        snap["replicas"] = replicas
        return snap
