"""The gateway facade: cache → in-flight join → supervised replica routing.

One :class:`Gateway` fronts a :class:`~repro.gateway.pool.ReplicaPool`
behind a single submit path shared by ``topk`` / ``ppr`` / ``pagerank``:

1. **Result cache** — if a cached certificate dominates the request
   (ε′ ≤ ε, δ′ ≤ δ), the answer is served immediately with zero walks
   executed, byte-identical to the originally certified answer.
2. **In-flight dedup** — if an identical key is already being computed and
   its target dominates the request, the request joins the live
   :class:`~repro.service.QueryHandle` (via :meth:`~repro.service.
   QueryHandle.join`): it is fed monotone ``partial()`` snapshots and
   completes the wave the weaker of the two bounds certifies.
3. **Replica routing** — otherwise the request lands on the *routable*
   replica (breakers closed, or half-open probes) with the lowest
   EDF-charged queue depth; its completed (undegraded) result is inserted
   into the cache for everyone after.

Every request returns a :class:`GatewayHandle` whose ``source`` records
which path served it (``"cache"`` | ``"joined"`` | ``"live"``).

Fault tolerance (PR 8). All wave driving goes through the pool's
supervised :meth:`~repro.gateway.pool.ReplicaPool.step_replica`, and the
gateway reacts to what it reports:

* **Failover** — a replica that crashes or misses its heartbeat under a
  live query gets that query *replayed* on a healthy replica via
  :meth:`~repro.service.FrogWildService.resubmit` (same plan parameters,
  fresh rid). Joined handles migrate with their parent — re-joined onto
  the replacement, still zero walks of their own — or, when there is
  nowhere left to route, settle with a classified
  :class:`~repro.distributed.faults.WaveFailedError`; never a hang.
  Because every replica is seeded identically and a freshly (re)started
  replica's key stream begins at wave 0, a failover that lands on a cold
  replica returns an answer **byte-identical** to the fault-free run
  (asserted in the tier-1 bench smoke).
* **Hedging** — with ``hedge_after_s`` set, a live query whose wall time
  exceeds ``max(hedge_after_s, 4·p99)`` fires one duplicate submission on
  a different routable replica. First certified answer wins, the loser is
  cancelled, and the dominance cache sees exactly one insert (the settle
  path is idempotent).
* **Load shedding** — :meth:`topk`/:meth:`ppr`/:meth:`pagerank` raise
  :class:`GatewayOverloadError` (carrying ``retry_after_s``) instead of
  queueing when every breaker is open, when the routable backlog exceeds
  the shed threshold, or while draining. The HTTP layer maps this to
  ``503`` + ``Retry-After``.
* **Drain** — :meth:`drain` stops admitting, drives every in-flight
  handle to completion (fault handling included), then closes the tier.
"""
from __future__ import annotations

import os
import threading
import time
from typing import Dict, List, Optional, Tuple, Union

import numpy as np

from repro.config import RuntimeConfig
from repro.distributed.faults import ReplicaFault, WaveFailedError
from repro.gateway.cache import CacheKey, ResultCache
from repro.gateway.metrics import GatewayMetrics
from repro.gateway.pool import NoReplicaAvailable, ReplicaPool
from repro.graph.csr import CSRGraph
from repro.query.engine import plan_query
from repro.query.scheduler import QueryPartial, QueryResult
from repro.service import JoinedQueryHandle, QueryHandle

__all__ = ["Gateway", "GatewayHandle", "GatewayOverloadError"]


class GatewayOverloadError(RuntimeError):
    """The tier refused to admit this request — structured backpressure,
    not a failure: retry after ``retry_after_s``. ``reason`` is one of
    ``overload`` (routable backlog past the shed threshold),
    ``no_replica`` (every breaker open), or ``draining``."""

    def __init__(self, message: str, retry_after_s: float,
                 reason: str = "overload"):
        super().__init__(message)
        self.retry_after_s = retry_after_s
        self.reason = reason


class GatewayHandle:
    """Uniform future for a gateway request, whatever path served it.

    ``source`` is ``"cache"`` (settled at submit, zero walks), ``"joined"``
    (riding another user's in-flight query), or ``"live"`` (a fresh query
    on ``replica``). The interface mirrors :class:`~repro.service.
    QueryHandle`: ``done()`` / ``poll()`` / ``partial()`` / ``result()`` —
    but waves are driven through the gateway's supervised path, so a
    handle transparently survives its replica dying (``replica`` then
    points at the replacement and ``failovers`` counts the migrations).
    """

    def __init__(self, gateway: "Gateway", source: str,
                 replica: Optional[int], *, key: CacheKey,
                 epsilon: float, delta: float,
                 inner: Union[QueryHandle, JoinedQueryHandle, None] = None,
                 result: Optional[QueryResult] = None):
        self._gateway = gateway
        self.source = source
        self.replica = replica
        self.key = key
        self.epsilon = epsilon
        self.delta = delta
        self._inner = inner
        self._result: Optional[QueryResult] = None
        self._t0 = time.perf_counter()
        self.failovers = 0
        self._parent: Optional["GatewayHandle"] = None   # set on joins
        self._joiners: List["GatewayHandle"] = []        # set on parents
        self._hedge: Optional[Tuple[int, QueryHandle]] = None
        self._hedge_won = False
        if result is not None:           # cache hit: settled at birth
            self._result = result
            gateway._record_done(self, result, latency_s=0.0)

    @property
    def admitted(self) -> bool:
        return self._result is not None or self._inner.admitted

    @property
    def decision(self):
        """The replica's AdmissionDecision (None off the live path)."""
        return (self._inner.decision
                if isinstance(self._inner, QueryHandle) else None)

    def done(self) -> bool:
        return self._result is not None or self._maybe_settle()

    def poll(self) -> bool:
        """Advances the serving replica by at most one wave (supervised:
        a dead replica triggers failover here, not an exception)."""
        if self._result is None:
            self._gateway._drive(self, step=True)
        return self.done()

    def partial(self) -> QueryPartial:
        """Anytime snapshot (for a settled handle, the final state)."""
        if self._result is not None:
            r = self._result
            return QueryPartial(
                rid=r.rid, kind=r.kind, k=len(r.vertices),
                vertices=r.vertices, scores=r.scores,
                walks_done=r.num_walks, waves=r.waves,
                epsilon_bound=r.epsilon_bound, done=True,
                degraded=r.degraded, shards_lost=r.shards_lost,
                walks_lost=r.walks_lost)
        return self._inner.partial()

    def result(self, max_waves: Optional[int] = None,
               timeout_s: Optional[float] = None) -> QueryResult:
        """Drives supervised waves until this request settles.

        ``max_waves`` bounds the number of waves driven; ``timeout_s``
        bounds wall time — both raise ``TimeoutError`` (the HTTP layer
        maps the latter to 504). A request that can never settle (replica
        dead with nowhere to fail over, parent cancelled under a join)
        raises a classified error instead of hanging.
        """
        deadline = (None if timeout_s is None
                    else time.perf_counter() + timeout_s)
        waves = 0
        while self._result is None:
            if self.done():
                break                    # terminal without a result
            if max_waves is not None and waves >= max_waves:
                raise TimeoutError(
                    f"gateway request on key {self.key} not settled after "
                    f"{waves} waves")
            if deadline is not None and time.perf_counter() > deadline:
                self._gateway.metrics.timeouts += 1
                raise TimeoutError(
                    f"gateway request on key {self.key} not settled within "
                    f"{timeout_s:g}s")
            self._gateway._drive(self, step=True)
            waves += 1
        if self._result is None:
            # terminal (rejected / cancelled with no failover possible):
            # surface the inner handle's classified error.
            self._inner.result(max_waves=0)
            raise RuntimeError(          # pragma: no cover — result raises
                f"request on key {self.key} terminal without a result")
        return self._result

    def _maybe_settle(self) -> bool:
        """Settles without driving waves when the inner future finished.

        Rejected / cancelled queries are terminal (True) but never settle
        a result — ``result()`` surfaces the inner handle's error instead.
        A handle whose replica *died* (rather than being cancelled by its
        caller) is not terminal: the gateway migrates it on the next
        drive, so this reports not-done and lets failover run.
        """
        inner = self._inner
        gw = self._gateway
        if isinstance(inner, QueryHandle):
            st = inner.status() if inner.admitted else "rejected"
            if st == "finished":
                self._settle(inner.result(max_waves=0))
                return True
            if st == "cancelled" and gw._failover_eligible(self):
                return False             # migrates on the next drive
            return st in ("rejected", "cancelled")
        if inner.done():
            if inner._result is not None:
                self._settle(inner.result(max_waves=0))
                return True
            if gw._failover_eligible(self):
                return False             # parent died: migrate, not settle
            return True                  # cancelled parent: classified error
        return False

    def _settle(self, result: QueryResult) -> None:
        if self._result is None:
            self._result = result
            self._gateway._record_done(
                self, result, latency_s=time.perf_counter() - self._t0)


class Gateway:
    """Serving tier over a supervised replica pool with an (ε, δ)-aware
    cache.

    Build one with :meth:`open`; submit with :meth:`topk` / :meth:`ppr`
    (async :class:`GatewayHandle`) or :meth:`pagerank` (synchronous batch);
    observe with :meth:`stats`; shut down with :meth:`drain` (graceful) or
    :meth:`close` (immediate); mount the stdlib HTTP front-end with
    :func:`~repro.gateway.http.serve_http`.
    """

    def __init__(self, pool: ReplicaPool, cache: Optional[ResultCache],
                 metrics: Optional[GatewayMetrics] = None, *,
                 hedge_after_s: Optional[float] = None,
                 shed_backlog_walks: Optional[int] = None):
        self.pool = pool
        self.cache = cache
        self.metrics = metrics if metrics is not None else GatewayMetrics()
        # cache/join keys carry the graph's mutation epoch: a gateway
        # opened over an already-mutated graph starts there, and
        # apply_mutations() keeps the two in lock-step.
        self.epoch = int(getattr(pool.graph, "epoch", 0))
        self.hedge_after_s = hedge_after_s
        # shed when the total backlog across routable replicas exceeds
        # this many walks (default: 8 full waves per replica — deep enough
        # that EDF admission, not the gateway, is the normal gate).
        if shed_backlog_walks is None:
            shed_backlog_walks = (8 * pool.config.serving.max_walks
                                  * len(pool))
        self.shed_backlog_walks = shed_backlog_walks
        self._inflight: Dict[CacheKey, GatewayHandle] = {}
        self._pending: List[GatewayHandle] = []   # unsettled live handles
        self._lock = threading.RLock()            # host-state mutations only
        self._draining = False
        self._closed = False

    @classmethod
    def open(
        cls,
        graph_or_path: Union[CSRGraph, str, os.PathLike],
        config: Optional[RuntimeConfig] = None,
        *,
        replicas: int = 2,
        cache: bool = True,
        cache_capacity: int = 256,
        mesh=None,
        hedge_after_s: Optional[float] = None,
        shed_backlog_walks: Optional[int] = None,
        heartbeat_timeout_s: Optional[float] = None,
        breaker_failure_threshold: int = 3,
        breaker_cooldown_s: float = 30.0,
    ) -> "Gateway":
        """Opens a gateway: one shared graph/index, ``replicas`` supervised
        services, and (unless ``cache=False``) the dominance-checked result
        cache. ``heartbeat_timeout_s`` / ``breaker_*`` configure the pool's
        supervisor; ``hedge_after_s`` enables hedged retries (None = off);
        ``shed_backlog_walks`` sets the overload shed threshold."""
        pool = ReplicaPool(graph_or_path, config, num_replicas=replicas,
                           mesh=mesh,
                           heartbeat_timeout_s=heartbeat_timeout_s,
                           breaker_failure_threshold=breaker_failure_threshold,
                           breaker_cooldown_s=breaker_cooldown_s)
        return cls(pool, ResultCache(cache_capacity) if cache else None,
                   hedge_after_s=hedge_after_s,
                   shed_backlog_walks=shed_backlog_walks)

    # --- lifecycle -------------------------------------------------------

    @property
    def closed(self) -> bool:
        return self._closed

    @property
    def draining(self) -> bool:
        return self._draining

    def close(self) -> None:
        """Closes the pool and drops gateway state (idempotent)."""
        if self._closed:
            return
        self._inflight.clear()
        self._pending.clear()
        if self.cache is not None:
            self.cache.clear()
        self.pool.close()
        self._closed = True

    def drain(self) -> List[QueryResult]:
        """Graceful shutdown: stop admitting, finish in-flight, close.

        New submits raise :class:`GatewayOverloadError` (``reason=
        "draining"``) the moment this is called; every outstanding live
        handle is then driven to completion through the supervised path
        (failover included — a replica dying mid-drain still settles its
        queries elsewhere), joined handles settle with their parents, and
        finally the pool is closed. Returns the results settled during the
        drain, in completion order. Idempotent with :meth:`close`.
        """
        if self._closed:
            return []
        with self._lock:
            self._draining = True
            pending = list(self._pending)
        results: List[QueryResult] = []
        for h in pending:
            if h._result is None:
                try:
                    h.result()
                except (WaveFailedError, RuntimeError, TimeoutError):
                    # classified terminal (rejected / cancelled / nowhere
                    # to fail over) — the caller's handle already says so;
                    # drain's job is just to not leave work running.
                    pass
            if h._result is not None:
                results.append(h._result)
        self.close()
        return results

    def __enter__(self) -> "Gateway":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def bump_epoch(self) -> int:
        """Advances the graph epoch: every cached certificate and in-flight
        join key from older epochs stops matching (the dynamic-graph
        refresh hook — ROADMAP item 4 pins the epoch at query start).
        Queries already in flight keep running, but their certificates are
        refused at insert time (``min_epoch`` guard in the cache) — a
        stale-epoch answer can never land after the epoch moved on.
        Orphaned certificates are counted in ``metrics.epoch_orphaned``."""
        with self._lock:
            self.epoch += 1
            self._inflight.clear()
            if self.cache is not None:
                self.metrics.epoch_orphaned += (
                    self.cache.drop_epochs_before(self.epoch))
            return self.epoch

    def apply_mutations(self, batch, *, chunk: int = 1024):
        """One mutation batch through the whole tier: compact the CSR at
        the next epoch, incrementally refresh exactly the invalidated walk
        segments, persist the slab under its epoch directory (when a
        checkpoint dir is configured), commit the two-epoch swap on every
        replica, and bump the gateway epoch so stale cached certificates
        are orphaned (counted in ``metrics.epoch_orphaned``). In-flight
        queries finish on their pinned old-epoch slabs, byte-identical to
        a never-mutated run. Returns the :class:`repro.dynamic.
        RefreshReport`.
        """
        from repro.dynamic import (apply_mutations as _apply,
                                   refresh_walk_index, save_epoch_index)

        self._check_open()
        new_graph, changed = _apply(self.pool.graph, batch)
        new_index, report = refresh_walk_index(
            self.pool.index, new_graph, changed,
            step_impl=self.pool.config.walk_index().step_impl, chunk=chunk)
        directory = self.pool.config.serving.checkpoint_dir
        if directory is not None:
            save_epoch_index(directory, new_index)
        self.pool.commit_epoch(new_graph, new_index)
        self.bump_epoch()
        return report

    def _check_open(self) -> None:
        if self._closed:
            raise RuntimeError("Gateway is closed")

    # --- the async query surface -----------------------------------------

    def topk(self, k: int = 10, epsilon: float = 0.3, delta: float = 0.1,
             *, slo_s: Optional[float] = None,
             allow_downgrade: bool = False) -> GatewayHandle:
        """Global top-k through the tier (cache → join → replica)."""
        return self._submit("topk", k, 0, epsilon, delta, slo_s=slo_s,
                            allow_downgrade=allow_downgrade)

    def ppr(self, source: int, k: int = 10, epsilon: float = 0.3,
            delta: float = 0.1, *, slo_s: Optional[float] = None,
            allow_downgrade: bool = False) -> GatewayHandle:
        """Personalized PageRank through the tier."""
        return self._submit("ppr", k, source, epsilon, delta, slo_s=slo_s,
                            allow_downgrade=allow_downgrade)

    def _submit(self, kind: str, k: int, source: int, epsilon: float,
                delta: float, *, slo_s: Optional[float],
                allow_downgrade: bool) -> GatewayHandle:
        self._check_open()
        with self._lock:
            self.metrics.requests += 1
            if self._draining:
                self.metrics.sheds += 1
                raise GatewayOverloadError(
                    "gateway is draining — not admitting new work",
                    retry_after_s=5.0, reason="draining")
            key = ResultCache.key(kind, k, source, self.epoch)

            # 1. cache: a dominating certificate answers for free.
            if self.cache is not None:
                entry = self.cache.lookup(key, epsilon, delta)
                if entry is not None:
                    self.metrics.cache_hits += 1
                    return GatewayHandle(self, "cache", None, key=key,
                                         epsilon=epsilon, delta=delta,
                                         result=entry.result)

            # 2. in-flight dedup: ride a live duplicate that dominates.
            live = self._inflight.get(key)
            if live is not None:
                if live.done():          # finished since last touched —
                    live = None          # its settle cached it already;
                    self._inflight.pop(key, None)  # fall through, re-lookup
                    if self.cache is not None:
                        entry = self.cache.lookup(key, epsilon, delta)
                        if entry is not None:
                            self.metrics.cache_hits += 1
                            return GatewayHandle(
                                self, "cache", None, key=key,
                                epsilon=epsilon, delta=delta,
                                result=entry.result)
                elif live.epsilon <= epsilon and live.delta <= delta:
                    self.metrics.joins += 1
                    joined = live._inner.join(epsilon, delta)
                    handle = GatewayHandle(self, "joined", live.replica,
                                           key=key, epsilon=epsilon,
                                           delta=delta, inner=joined)
                    handle._parent = live
                    live._joiners.append(handle)
                    return handle

            # 3. route to the least-loaded *routable* replica — or shed.
            ridx = self._route_or_shed()
            svc = self.pool.replicas[ridx]
            if kind == "ppr":
                qh = svc.ppr(source, k=k, epsilon=epsilon, delta=delta,
                             slo_s=slo_s, allow_downgrade=allow_downgrade)
            else:
                qh = svc.topk(k=k, epsilon=epsilon, delta=delta, slo_s=slo_s,
                              allow_downgrade=allow_downgrade)
            self.metrics.record_admission(qh.decision)
            handle = GatewayHandle(self, "live", ridx, key=key,
                                   epsilon=epsilon, delta=delta, inner=qh)
            if qh.admitted:
                self.metrics.live += 1
                self._pending.append(handle)
                prev = self._inflight.get(key)
                # register for joins; a strictly stronger target displaces
                # a weaker registrant (it serves strictly more duplicates).
                if (prev is None or prev.done()
                        or (epsilon <= prev.epsilon and delta <= prev.delta)):
                    self._inflight[key] = handle
            return handle

    def _route_or_shed(self) -> int:
        """Routes, translating supervision state into structured
        backpressure: every breaker open → ``no_replica`` shed; routable
        backlog past the threshold → ``overload`` shed with a Retry-After
        derived from how long that backlog takes to drain at the pool's
        observed wave rate."""
        try:
            ridx = self.pool.route()
        except NoReplicaAvailable as e:
            self.metrics.sheds += 1
            raise GatewayOverloadError(str(e), e.retry_after_s,
                                       reason="no_replica") from e
        backlog = 0
        for i in self.pool.routable():
            st = self.pool.replicas[i].serving_stats()
            if st is not None:
                backlog += st.backlog_walks
        if backlog >= self.shed_backlog_walks:
            self.metrics.sheds += 1
            retry = self._retry_after_s(backlog)
            raise GatewayOverloadError(
                f"routable backlog {backlog} walks ≥ shed threshold "
                f"{self.shed_backlog_walks} — retry in {retry:.2g}s",
                retry_after_s=retry, reason="overload")
        return ridx

    def _retry_after_s(self, backlog_walks: int) -> float:
        """Time for the current backlog to drain at the observed wave
        rate — the honest Retry-After. Falls back to 1s before any wave
        has been timed."""
        emas = [st.wave_time_ema_s for st in
                (r.serving_stats() for r in self.pool.replicas)
                if st is not None and st.wave_time_ema_s]
        if not emas:
            return 1.0
        per_wave = sum(emas) / len(emas)
        waves = backlog_walks / max(1, self.pool.config.serving.max_walks)
        return max(0.05, min(60.0, waves * per_wave))

    # --- supervised driving: failover + hedging ---------------------------

    def _failover_eligible(self, handle: GatewayHandle) -> bool:
        """A handle migrates (rather than settling terminal) iff its
        serving replica actually died — crashed or closed under it — the
        gateway is still open, and its failover budget (one attempt per
        replica) is not exhausted. A query its *caller* cancelled is not
        eligible: that cancellation is an answer, not a fault."""
        if self._closed or self.pool.closed or handle.replica is None:
            return False
        root = handle._parent if handle._parent is not None else handle
        if root.failovers >= len(self.pool):
            return False
        st = self.pool.states[handle.replica]
        return st.crashed or self.pool.replicas[handle.replica].closed

    def _failover(self, handle: GatewayHandle, reason: str) -> None:
        """Migrates a query off a dead replica: replay on a healthy one
        (same plan parameters — byte-identical on a cold replica), then
        re-join every unsettled joiner onto the replacement. With nowhere
        to route, raises a classified :class:`WaveFailedError` so callers
        get a resubmittable error, never a hang."""
        with self._lock:
            parent = handle._parent if handle._parent is not None else handle
            if parent._result is not None:
                parent = handle          # orphaned joiner: go live itself
            if parent._hedge is not None:
                # a hedge is already replaying this exact plan on a healthy
                # replica: promote it to primary instead of submitting a
                # third copy. The hedge "won" by outliving the primary.
                hridx, hqh = parent._hedge
                parent._hedge = None
                parent._inner = hqh
                parent.replica = hridx
                parent.failovers += 1
                self.metrics.failovers += 1
                self.metrics.hedges_won += 1
                for j in parent._joiners:
                    if j._result is None:
                        j._inner = hqh.join(j.epsilon, j.delta)
                        j.replica = hridx
                return
            try:
                ridx = self.pool.route()
            except NoReplicaAvailable as e:
                raise WaveFailedError(
                    f"failover impossible for key {handle.key}: {e} "
                    f"(original fault: {reason})") from e
            svc = self.pool.replicas[ridx]
            self.metrics.failovers += 1
            parent.failovers += 1
            if parent.source == "joined":
                # orphaned joiner whose parent settled before the replica
                # died: promote it to a live query at its own target.
                req = parent._inner.parent.request
                if req.kind == "ppr":
                    new_qh = svc.ppr(req.source, k=req.k,
                                     epsilon=parent.epsilon,
                                     delta=parent.delta, slo_s=req.slo_s,
                                     allow_downgrade=req.allow_downgrade)
                else:
                    new_qh = svc.topk(k=req.k, epsilon=parent.epsilon,
                                      delta=parent.delta, slo_s=req.slo_s,
                                      allow_downgrade=req.allow_downgrade)
                parent.source = "live"
                self._pending.append(parent)
            else:
                new_qh = svc.resubmit(parent._inner.request)
            parent._inner = new_qh
            parent.replica = ridx
            parent._hedge = None         # a hedge raced the dead primary
            for j in parent._joiners:    # joiners migrate with the parent
                if j._result is None:
                    j._inner = new_qh.join(j.epsilon, j.delta)
                    j.replica = ridx

    def _drive(self, handle: GatewayHandle, step: bool = True) -> bool:
        """One supervised wave on behalf of ``handle``: runs hedge logic,
        steps the serving replica through the pool supervisor, and turns
        replica faults into failover. Returns ``handle.done()``."""
        if handle._result is not None:
            return True
        if handle.done():                # settles, or flags dead replica
            return True
        root = handle._parent if handle._parent is not None else handle
        if root._result is None and self._hedge_step(root):
            pass                         # hedge certified: root settled
        elif step:
            try:
                progressed = self.pool.step_replica(handle.replica)
            except ReplicaFault as e:
                self._failover(handle, str(e))
                progressed = True        # migration is progress
            except WaveFailedError as e:
                # the wave supervisor exhausted retries on this replica:
                # charge its breaker; the query itself migrates only if
                # the replica actually died, else the error is terminal.
                self.pool.record_failure(handle.replica, str(e))
                raise
            else:
                self._maybe_hedge(root)
            if not progressed and not handle.done():
                raise RuntimeError(
                    f"replica {handle.replica} idle but request on key "
                    f"{handle.key} is not done")
        return handle.done()

    def _hedge_threshold_s(self) -> Optional[float]:
        """Hedge when a query's wall time exceeds ``max(hedge_after_s,
        4·p99)`` — the floor keeps cold starts from hedging on compile
        time; the p99 term adapts to the workload once the latency window
        has data. None disables hedging."""
        if self.hedge_after_s is None:
            return None
        _, p99 = self.metrics.latency_percentiles()
        if p99 is None:
            return self.hedge_after_s
        return max(self.hedge_after_s, 4.0 * p99)

    def _maybe_hedge(self, root: GatewayHandle) -> None:
        if (root._hedge is not None or root.source != "live"
                or root._result is not None):
            return
        threshold = self._hedge_threshold_s()
        if threshold is None:
            return
        if time.perf_counter() - root._t0 < threshold:
            return
        others = [i for i in self.pool.routable() if i != root.replica]
        if not others:
            return
        with self._lock:
            if root._hedge is not None or root._result is not None:
                return
            hridx = min(others, key=lambda i: (
                (lambda st: (0, 0) if st is None
                 else (st.backlog_walks, st.waves_run))(
                    self.pool.replicas[i].serving_stats())))
            hqh = self.pool.replicas[hridx].resubmit(root._inner.request)
            if hqh.admitted:
                root._hedge = (hridx, hqh)
                self.metrics.hedges_fired += 1

    def _hedge_step(self, root: GatewayHandle) -> bool:
        """Advances an active hedge one wave; True iff the hedge certified
        first and settled ``root`` (and its joiners — directly, since the
        winner's certificate dominates every joiner's target)."""
        if root._hedge is None:
            return False
        hridx, hqh = root._hedge
        try:
            self.pool.step_replica(hridx)
        except (ReplicaFault, WaveFailedError):
            root._hedge = None           # the hedge died; primary goes on
            return False
        if hqh.status() != "finished":
            return False
        result = hqh.result(max_waves=0)
        with self._lock:
            if root._result is not None:
                return False             # primary won the race after all
            root._hedge_won = True
            self.metrics.hedges_won += 1
            root._settle(result)         # exactly one cache insert
            for j in root._joiners:
                if j._result is None:
                    j._settle(result)
        # the loser is cancelled — its walks stop charging the replica.
        if isinstance(root._inner, QueryHandle):
            root._inner.cancel()
        return True

    # --- batch -----------------------------------------------------------

    def pagerank(self, epsilon: float = 0.3, delta: float = 0.1,
                 k: int = 10) -> QueryResult:
        """Batch full-vector PageRank, reduced to its top-k and cached.

        The Theorem-1 plan meets the requested (ε, δ) by construction, so
        the certificate is the plan's recorded ``epsilon_bound`` (which
        also honestly widens when a cap binds the plan).
        """
        self._check_open()
        with self._lock:
            self.metrics.requests += 1
            if self._draining:
                self.metrics.sheds += 1
                raise GatewayOverloadError(
                    "gateway is draining — not admitting new work",
                    retry_after_s=5.0, reason="draining")
            epoch = self.epoch
            key = ResultCache.key("pagerank", k, 0, epoch)
            if self.cache is not None:
                entry = self.cache.lookup(key, epsilon, delta)
                if entry is not None:
                    self.metrics.cache_hits += 1
                    self.metrics.record_completion(0.0)
                    return entry.result
            ridx = self._route_or_shed()
        svc = self.pool.replicas[ridx]
        plan = plan_query(k, epsilon, delta, p_T=svc.config.p_T,
                          max_steps=svc.config.serving.max_steps)
        t0 = time.perf_counter()
        res = svc.pagerank(epsilon=epsilon, delta=delta, k=k)
        pi = np.asarray(res.pi_hat)
        top = np.argsort(-pi, kind="stable")[:min(k, pi.shape[0])]
        qr = QueryResult(
            rid=-1, kind="pagerank", vertices=top, scores=pi[top],
            num_walks=int(getattr(res, "num_frogs", plan.num_walks)),
            num_steps=plan.num_steps, waves=0,
            latency_s=time.perf_counter() - t0,
            epsilon_bound=plan.epsilon_bound)
        with self._lock:
            self.metrics.live += 1
            self.metrics.record_completion(qr.latency_s)
            if self.cache is not None:
                self.cache.insert(key, qr, delta, min_epoch=self.epoch)
        return qr

    # --- completion hook --------------------------------------------------

    def _record_done(self, handle: GatewayHandle, result: QueryResult,
                     latency_s: float) -> None:
        with self._lock:
            self.metrics.record_completion(latency_s)
            if handle in self._pending:
                self._pending.remove(handle)
            if handle.source != "live":
                return
            if handle._hedge is not None and not handle._hedge_won:
                handle._hedge[1].cancel()    # primary won: cancel the hedge
                handle._hedge = None
            if self._inflight.get(handle.key) is handle:
                del self._inflight[handle.key]
            if self.cache is not None and not self._closed:
                # degraded answers are refused inside insert(); the
                # certificate's δ is the δ the bound was certified at; the
                # min_epoch guard refuses certificates from before a
                # bump_epoch() that raced this query.
                self.cache.insert(handle.key, result, handle.delta,
                                  min_epoch=self.epoch)

    # --- drive + observe --------------------------------------------------

    def step(self) -> bool:
        """One supervised wave across the pool: advances every replica
        with in-flight work; False when the whole tier is idle. Replica
        faults are absorbed here (breaker bookkeeping happens; the
        affected handles migrate on their next drive)."""
        self._check_open()
        progressed = False
        for i, r in enumerate(self.pool.replicas):
            if r.serving_stats() is not None:
                try:
                    progressed |= self.pool.step_replica(i)
                except ReplicaFault:
                    progressed = True    # quarantine happened: not idle
                except WaveFailedError as e:
                    self.pool.record_failure(i, str(e))
        return progressed

    def healthy(self) -> bool:
        """Liveness: open, at least one routable replica, and no routable
        replica lost a serving shard."""
        if self._closed or self.pool.closed:
            return False
        routable = self.pool.routable()
        return bool(routable) and all(
            not self.pool.replicas[i].lost_shards for i in routable)

    def stats(self) -> Dict[str, object]:
        """One structured snapshot of the whole tier (what ``/metrics``
        serves): gateway counters + per-replica scheduler **and
        supervision** state + cache."""
        snap = self.metrics.snapshot()
        snap["epoch"] = self.epoch
        snap["graph_epoch"] = int(getattr(self.pool.graph, "epoch", 0))
        snap["retiring_epochs"] = sorted({
            e for r in self.pool.replicas if not r.closed
            for e in getattr(r, "retiring_epochs", [])})
        snap["inflight_keys"] = len(self._inflight)
        snap["closed"] = self._closed
        snap["draining"] = self._draining
        snap["shed_backlog_walks"] = self.shed_backlog_walks
        snap["cache"] = (self.cache.stats() if self.cache is not None
                         else None)
        replicas = []
        for i, r in enumerate(self.pool.replicas):
            st = r.serving_stats()
            ps = self.pool.states[i]
            replicas.append({
                "replica": i,
                "queue_depth_walks": 0 if st is None else st.backlog_walks,
                "queued": 0 if st is None else st.queued,
                "active": 0 if st is None else st.active,
                "finished": 0 if st is None else st.finished,
                "rejected": 0 if st is None else st.rejected,
                "waves_run": 0 if st is None else st.waves_run,
                "walks_executed": 0 if st is None else st.walks_executed,
                "wave_occupancy": (0.0 if st is None
                                   else round(st.wave_occupancy, 4)),
                "wave_time_ema_s": None if st is None else st.wave_time_ema_s,
                "lost_shards": [] if st is None else list(st.lost_shards),
                # supervision (PR 8)
                "breaker": self.pool.breaker_state(i),
                "health": round(self.pool.health_score(i), 4),
                "crashed": ps.crashed,
                "consecutive_failures": ps.consecutive_failures,
                "restarts": ps.restarts,
                "pool_wave_time_ema_s": ps.wave_time_ema_s,
                "last_fault": ps.last_fault,
            })
        snap["replicas"] = replicas
        return snap
